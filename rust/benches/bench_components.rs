//! Component micro-benchmarks for the hot paths identified in
//! DESIGN.md §8 (propagation sweep, episode step, MCTS episode, SPMD
//! lowering, liveness, featurization, ranker inference).
//!
//!     cargo bench --offline  (hand-rolled harness; criterion is not
//!     available offline — see DESIGN.md §3)

use automap::cost::composite::{evaluate, CostLedger, CostWeights};
use automap::cost::liveness::peak_memory;
use automap::learner::features::featurize;
use automap::models::transformer::{build_transformer, TransformerConfig};
use automap::partir::actions::{Action, DecisionState};
use automap::partir::dist::DistMap;
use automap::partir::mesh::{AxisId, Mesh};
use automap::partir::program::PartirProgram;
use automap::partir::propagate::PropStats;
use automap::search::env::{EnvAction, RewriteEnv, SearchOptions};
use automap::search::mcts::{search, MctsConfig};
use automap::sim::device::Device;
use automap::spmd::lower::lower;
use automap::util::bench::{black_box, Bencher};

fn megatron_state(model: &automap::models::transformer::TransformerModel) -> DecisionState {
    automap::models::megatron::reference_state(model, AxisId(0))
}

fn main() {
    let mut b = Bencher::new();
    println!("== automap component benchmarks ==");

    for layers in [4usize, 24] {
        let model = build_transformer(&TransformerConfig::tiny(layers));
        let program = PartirProgram::new(model.func.clone(), Mesh::new(&[("model", 4)]));
        let n_ops = program.func.num_nodes();
        let st = megatron_state(&model);
        let (dm_done, _) = program.apply(&st);

        // Propagation: one full forward sweep over the program.
        let mut dm = DistMap::new(&program.func, &program.mesh);
        dm.set(model.layers[0].w1.index(), AxisId(0), 1);
        let mut stats = PropStats::default();
        b.bench(&format!("forward_sweep/{layers}L({n_ops}ops)"), || {
            stats.stuck_nodes.clear();
            program.prop.forward(&program.func, &program.mesh, &mut dm, &mut stats);
            black_box(&dm);
        });

        // Full decision replay (what one episode re-application costs).
        let mut dm2 = DistMap::new(&program.func, &program.mesh);
        let mut stats2 = PropStats::default();
        b.bench(&format!("apply_megatron_state/{layers}L"), || {
            program.apply_into(&st, &mut dm2, &mut stats2);
            black_box(&dm2);
        });

        // SPMD lowering + liveness + full evaluation.
        b.bench(&format!("spmd_lower/{layers}L"), || {
            let sp = lower(&program.func, &program.mesh, &program.prop, &dm_done);
            black_box(sp.collectives.len());
        });
        b.bench(&format!("liveness_peak_memory/{layers}L"), || {
            black_box(peak_memory(&program.func, &program.mesh, &dm_done).peak_bytes);
        });
        b.bench(&format!("evaluate_full/{layers}L"), || {
            black_box(
                evaluate(&program, &dm_done, &Device::tpu_v3(), &CostWeights::default()).cost,
            );
        });
        // Incremental ledger refresh hopping between two maps one
        // decision apart — the episode-loop evaluation pattern.
        let st_partial = DecisionState {
            actions: st.actions[..st.actions.len() - 1].to_vec(),
            atomic: Default::default(),
        };
        let (dm_partial, _) = program.apply(&st_partial);
        let mut ledger =
            CostLedger::new(&program, &dm_done, Device::tpu_v3(), CostWeights::default());
        let mut flip = false;
        b.bench(&format!("ledger_refresh/{layers}L"), || {
            flip = !flip;
            let target = if flip { &dm_partial } else { &dm_done };
            black_box(ledger.refresh(&program, target, None).cost);
        });

        // Featurization (learner input).
        b.bench(&format!("featurize/{layers}L"), || {
            black_box(featurize(&program.func, &program.mesh).arg_ids.len());
        });
    }

    // Episode step + whole MCTS episodes on the fig-6 workload.
    let model = build_transformer(&TransformerConfig::tiny(4));
    let program = PartirProgram::new(model.func.clone(), Mesh::new(&[("model", 4)]));
    let wl = RewriteEnv::default_worklist(&program);
    let env = RewriteEnv::new(
        &program,
        Device::tpu_v3(),
        CostWeights::default(),
        SearchOptions::default(),
        &wl,
    );
    let mut ep = env.reset();
    let acts = env.legal_actions(&ep);
    let tile = acts[0];
    b.bench("env_step_tile/4L", || {
        let mut e = ep.clone();
        env.step(&mut e, tile);
        black_box(e.decisions);
    });
    env.step(&mut ep, tile);
    b.bench("env_evaluate_episode/4L", || {
        black_box(env.reward(&env.evaluate_episode(&ep)));
    });
    let mut seed = 0u64;
    b.bench("mcts_50_episodes/4L", || {
        seed += 1;
        black_box(search(&env, 50, seed, MctsConfig::default()).best_reward);
    });

    // 1F1B schedule simulation (the per-evaluation term the pipeline
    // tactic adds; DESIGN.md §11).
    for k in [4usize, 8] {
        let stage = vec![1e-3; k];
        let xfer = vec![1e-5; k - 1];
        let m = 2 * k;
        b.bench(&format!("schedule_sim/{k}stage"), || {
            black_box(automap::pipeline::simulate_1f1b(&stage, &xfer, m).bubble_fraction);
        });
    }

    // Ranker inference through PJRT (needs `make artifacts`).
    let g = featurize(&program.func, &program.mesh);
    if std::path::Path::new("artifacts/ranker.hlo.txt").exists() {
        use automap::learner::ranker::{PjrtRanker, Ranker};
        let rt = automap::runtime::pjrt::Runtime::new().unwrap();
        let ranker = PjrtRanker::load(&rt, "artifacts/ranker.hlo.txt").unwrap();
        b.bench("pjrt_ranker_score/256nodes", || {
            black_box(ranker.score(&g).unwrap().len());
        });
    } else {
        println!("(skipping pjrt_ranker_score: run `make artifacts` first)");
    }

    println!("== {} benchmarks done ==", b.results().len());
}
