//! Service search-throughput benchmark (DESIGN.md §9): single-thread vs
//! K-thread root-parallel executor episodes/sec, plus plan-cache hit
//! latency. Writes `BENCH_search.json` at the repo root.
//!
//!     cargo bench --bench search_throughput --offline
//!
//! The tier-1 smoke test (`rust/tests/service_bench_smoke.rs`) runs the
//! same measurement with a quick profile, so the JSON exists after every
//! test run; this bench refreshes it with fuller numbers.

use automap::service::throughput::{measure, write_report, ThroughputConfig};

fn main() {
    println!("== automap search throughput ==");
    let cfg = ThroughputConfig::full();
    let report = measure(&cfg).expect("throughput measurement failed");
    println!(
        "BENCH search_throughput/single    episodes_per_sec={:.0} evals_per_sec={:.0}",
        report.single_episodes_per_sec, report.single_evals_per_sec
    );
    println!(
        "BENCH search_throughput/workers{}  episodes_per_sec={:.0} evals_per_sec={:.0} \
         speedup={:.2}x",
        report.workers, report.multi_episodes_per_sec, report.multi_evals_per_sec, report.speedup
    );
    println!(
        "BENCH search_throughput/cache_hit median_ns={:.0} probes={}",
        report.cache_hit_median_ns, report.cache_probes
    );
    println!("BENCH search_throughput/step      median_ns={:.0}", report.step_median_ns);
    println!(
        "BENCH search_throughput/eval      ledger_median_ns={:.0} full_median_ns={:.0} \
         ledger_speedup={:.2}x",
        report.eval_median_ns, report.eval_full_median_ns, report.eval_ledger_speedup
    );
    println!(
        "BENCH search_throughput/caches    eval_memo_hit_rate={:.2} ledger_reuse_rate={:.2}",
        report.eval_memo_hit_rate, report.ledger_reuse_rate
    );
    println!("BENCH search_throughput/stealing  rounds={} steals={}", report.rounds, report.steals);
    if let Some(b) = report.baseline_single_episodes_per_sec {
        println!(
            "BENCH search_throughput/baseline  episodes_per_sec={:.0} improvement={:.2}x",
            b,
            report.single_episodes_per_sec / b.max(1e-9)
        );
    }
    let path = write_report(&report).expect("writing BENCH_search.json failed");
    println!("wrote {}", path.display());
}
