//! Figure-regeneration benchmark harness: runs a scaled-down version of
//! every figure in the paper's evaluation (§3) and prints the same
//! series the paper plots, plus wall-clock per figure. Full-scale
//! parameters: `automap all-figures --config configs/fig6_paper.json`.
//!
//!     cargo bench --offline

use automap::coordinator::figures::{fig6_fig7, fig8, fig9, stats, FigureSetup};
use automap::models::transformer::TransformerConfig;

fn main() {
    println!("== figure harnesses (scaled-down; see EXPERIMENTS.md) ==");

    // Setup-statistics "table" (§3 text): paper-scale model, built
    // structurally (no tensor data).
    let t0 = std::time::Instant::now();
    let _ = stats(&TransformerConfig::paper());
    println!("BENCH figure_stats_paper_scale wall={:.1}s", t0.elapsed().as_secs_f64());

    let setup = FigureSetup {
        layers: 2,
        budgets: vec![50, 200, 800],
        attempts: 8,
        seed: 42,
        ranker_path: "artifacts/ranker.hlo.txt".to_string(),
    };
    let t0 = std::time::Instant::now();
    fig6_fig7(&setup, "results").expect("fig6/7");
    println!("BENCH figure6_7 wall={:.1}s", t0.elapsed().as_secs_f64());

    let setup8 = FigureSetup { layers: 4, seed: 43, ..mk(&setup) };
    let t0 = std::time::Instant::now();
    fig8(&setup8, "results").expect("fig8");
    println!("BENCH figure8 wall={:.1}s", t0.elapsed().as_secs_f64());

    let setup9 = FigureSetup { layers: 4, seed: 44, ..mk(&setup) };
    let t0 = std::time::Instant::now();
    let (grouped, ungrouped) = fig9(&setup9, "results").expect("fig9");
    println!("BENCH figure9 wall={:.1}s", t0.elapsed().as_secs_f64());

    // Shape assertions: the paper's qualitative claims must hold.
    let g_last = grouped.last().unwrap();
    let u_last = ungrouped.last().unwrap();
    assert!(
        g_last.success_rate > u_last.success_rate,
        "Fig 9 shape: grouping must dominate without propagation"
    );

    ablations();
    println!("== figure harness done (claims hold) ==");
}

/// Ablation benches for the design choices DESIGN.md calls out:
/// the infer-rest tactic and the UCT exploration constant.
fn ablations() {
    use automap::cost::composite::CostWeights;
    use automap::models::megatron;
    use automap::models::transformer::build_transformer;
    use automap::partir::mesh::{AxisId, Mesh};
    use automap::partir::program::PartirProgram;
    use automap::search::env::{RewriteEnv, SearchOptions};
    use automap::search::experiment::pressured_device;
    use automap::search::mcts::{search, MctsConfig};
    use automap::sim::device::Device;

    let model = build_transformer(&TransformerConfig::tiny(2));
    let program = PartirProgram::new(model.func.clone(), Mesh::new(&[("model", 4)]));
    let w = CostWeights::default();
    let probe =
        megatron::reference_evaluation(&program, &model, AxisId(0), &Device::tpu_v3(), &w);
    let device = pressured_device(&probe);
    let reference = megatron::reference_evaluation(&program, &model, AxisId(0), &device, &w);
    let wl = RewriteEnv::default_worklist(&program);

    let run = |opts: SearchOptions, cfg: MctsConfig| -> f64 {
        let env = RewriteEnv::new(&program, device.clone(), w.clone(), opts, &wl);
        let mut hits = 0;
        let attempts = 10;
        for s in 0..attempts {
            let r = search(&env, 200, 900 + s, cfg.clone());
            if megatron::check(&r.best_eval, &reference).is_megatron {
                hits += 1;
            }
        }
        hits as f64 / attempts as f64
    };

    println!("== ablations (budget 200, 10 attempts, tiny(2)) ==");
    let base = run(SearchOptions::default(), MctsConfig::default());
    let no_infer = run(
        SearchOptions { auto_infer_rest: false, ..Default::default() },
        MctsConfig::default(),
    );
    println!("ABLATION infer_rest: on={base:.2} off={no_infer:.2}");
    for c in [0.3f64, 1.2, 3.0] {
        let s = run(
            SearchOptions::default(),
            MctsConfig { exploration: c, ..Default::default() },
        );
        println!("ABLATION uct_exploration c={c}: success={s:.2}");
    }
}

fn mk(s: &FigureSetup) -> FigureSetup {
    FigureSetup {
        layers: s.layers,
        budgets: s.budgets.clone(),
        attempts: s.attempts,
        seed: s.seed,
        ranker_path: s.ranker_path.clone(),
    }
}
