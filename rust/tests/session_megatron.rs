//! Golden test for the Session/Tactic API (paper §3 headline + Fig 5):
//! a `Manual` tactic pinning the batch axis (user-managed data
//! parallelism, inputs pre-sharded) composed with `Search` still
//! recovers Megatron column/row sharding on the model axis, measured —
//! like the paper — through collective statistics.

use automap::cost::composite::{evaluate, CostWeights};
use automap::ir::ValueId;
use automap::models::megatron;
use automap::models::transformer::{build_transformer, TransformerConfig};
use automap::partir::actions::{Action, DecisionState};
use automap::partir::mesh::Mesh;
use automap::partir::program::PartirProgram;
use automap::search::env::SearchOptions;
use automap::session::{PartitionPlan, Session, ShardingConstraint, Tactic};
use automap::sim::device::Device;

fn arg_id(program: &PartirProgram, name: &str) -> ValueId {
    ValueId(
        program.func.args.iter().position(|a| a.name == name).expect("arg exists") as u32,
    )
}

#[test]
fn manual_batch_axis_plus_search_recovers_megatron() {
    let model = build_transformer(&TransformerConfig::tiny(2));
    let mesh = Mesh::new(&[("batch", 2), ("model", 4)]);
    let program = PartirProgram::new(model.func.clone(), mesh.clone());
    let w = CostWeights::default();
    let batch_ax = mesh.axis_by_name("batch").unwrap();
    let model_ax = mesh.axis_by_name("model").unwrap();

    // --- deterministic golden references ------------------------------
    let batch_pins = vec![
        Action::Tile { v: arg_id(&program, "tokens"), dim: 0, axis: batch_ax },
        Action::Tile { v: arg_id(&program, "targets"), dim: 0, axis: batch_ax },
    ];
    let batch_only = DecisionState::with_actions(
        batch_pins.iter().cloned().chain([Action::InferRest]).collect(),
    );
    let model_only = megatron::reference_state(&model, model_ax);
    let combined = DecisionState::with_actions(
        batch_pins.iter().cloned().chain(model_only.actions.iter().cloned()).collect(),
    );

    let dev0 = Device::tpu_v3();
    let (dm_b, _) = program.apply(&batch_only);
    let (dm_m, _) = program.apply(&model_only);
    let (dm_c, _) = program.apply(&combined);
    let e_batch = evaluate(&program, &dm_b, &dev0, &w);
    let e_model = evaluate(&program, &dm_m, &dev0, &w);
    let e_comb = evaluate(&program, &dm_c, &dev0, &w);

    // Golden collective counts: Megatron has zero all-gathers, batch
    // parallelism is gather-free, and because the axes tile disjoint
    // tensor dims their all-reduce counts compose additively.
    assert_eq!(e_model.collectives.all_gather_count, 0, "{:?}", e_model.collectives);
    assert_eq!(e_batch.collectives.all_gather_count, 0, "{:?}", e_batch.collectives);
    assert_eq!(e_comb.collectives.all_gather_count, 0, "{:?}", e_comb.collectives);
    assert!(e_model.collectives.all_reduce_count >= 4, "{:?}", e_model.collectives);
    assert!(
        e_batch.collectives.all_reduce_count > 0,
        "data parallelism must all-reduce gradients: {:?}",
        e_batch.collectives
    );
    assert_eq!(
        e_comb.collectives.all_reduce_count,
        e_batch.collectives.all_reduce_count + e_model.collectives.all_reduce_count,
        "batch + model collectives must compose additively"
    );

    // --- the paper's memory pressure ----------------------------------
    let device = Device {
        hbm_bytes: (e_comb.memory.peak_bytes as f64 * 1.3) as i64,
        ..Device::tpu_v3()
    };
    let reference = evaluate(&program, &dm_c, &device, &w);

    // --- Fig 5 pipeline: Manual(batch) + Search(model) ----------------
    let mut session = Session::with_options(
        model.func.clone(),
        mesh,
        device,
        w,
        SearchOptions::default(),
    );
    let plan = session
        .run(&[
            Tactic::Manual {
                constraints: vec![
                    ShardingConstraint::new("tokens", 0, "batch"),
                    ShardingConstraint::new("targets", 0, "batch"),
                ],
                manual_axes: vec!["batch".to_string()],
            },
            Tactic::search(3000, 3),
            Tactic::InferRest,
            Tactic::Lower,
        ])
        .expect("pipeline");

    let verdict = megatron::check(&plan.eval, &reference);
    assert!(
        verdict.is_megatron || verdict.near_megatron,
        "expected (near-)Megatron under manual batch axis: found={:?} ref={:?}",
        plan.eval.collectives,
        reference.collectives
    );

    // The manual axis stayed the user's: pinned inputs are batch-sharded,
    // parameters never are.
    let tokens = plan.input_specs.iter().find(|s| s.name == "tokens").unwrap();
    assert!(tokens.tiled_on("batch"), "pinned sharding must survive search");
    for spec in &plan.input_specs {
        let is_param = spec.name.contains("/w")
            || spec.name == "embed"
            || spec.name.contains("ln")
            || spec.name.contains(".adam_");
        if is_param {
            assert!(
                !spec.tiled_on("batch"),
                "search/propagation assigned the manual batch axis to {}",
                spec.name
            );
        }
    }
    // And search did place model-axis shardings on layer weights.
    assert!(
        plan.input_specs
            .iter()
            .any(|s| s.name.contains("/attn/") || s.name.contains("/mlp/"))
            && plan
                .input_specs
                .iter()
                .filter(|s| s.name.contains("/w") || s.name.contains("/attn/"))
                .any(|s| s.tiled_on("model")),
        "expected model-axis shardings on layer weights"
    );

    // The plan serialises and round-trips through util::json.
    let text = plan.to_json().pretty();
    let back = PartitionPlan::from_json(&automap::util::json::parse(&text).unwrap()).unwrap();
    assert_eq!(back.input_specs, plan.input_specs);
    assert_eq!(back.eval.collectives, plan.eval.collectives);
    assert_eq!(back.trace, plan.trace);
}
