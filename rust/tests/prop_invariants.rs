//! Property-based tests over randomly generated programs and decision
//! sequences (hand-rolled driver in `util::prop`; proptest unavailable
//! offline — DESIGN.md §3).
//!
//! Invariants checked:
//!   * propagation is deterministic and produces only divisible tilings;
//!   * episode-incremental propagation == full replay (the search-env
//!     fast path is exact);
//!   * SPMD lowering never emits collectives for a fully replicated
//!     program; collective payloads are positive;
//!   * sharded peak memory never exceeds replicated peak memory;
//!   * DCE preserves interpreter semantics on random elementwise graphs;
//!   * autodiff matches finite differences on random scalar chains.

use automap::cost::liveness::peak_memory;
use automap::ir::autodiff::gradients;
use automap::ir::interp::{eval, eval_all, Tensor};
use automap::ir::{parse_func, print_func};
use automap::ir::{ArgKind, CmpDir, DType, DotDims, GraphBuilder, TensorType, ValueId};
use automap::partir::actions::{Action, DecisionState};
use automap::partir::dist::DistMap;
use automap::partir::mesh::{AxisId, Mesh};
use automap::partir::program::PartirProgram;
use automap::search::env::{RewriteEnv, SearchOptions};
use automap::spmd::lower::lower;
use automap::util::prop::check;
use automap::util::rng::Rng;

/// Random small elementwise/matmul DAG with args of divisible sizes.
fn random_program(rng: &mut Rng) -> automap::ir::Func {
    let dims = [4i64, 8, 16];
    let mut b = GraphBuilder::new("rand");
    let n_args = 2 + rng.gen_range(3);
    let mut mats = Vec::new();
    for i in 0..n_args {
        let r = *rng.choose(&dims);
        let c = *rng.choose(&dims);
        mats.push(b.arg(
            format!("a{i}"),
            TensorType::f32(&[r, c]),
            if i == 0 { ArgKind::Input } else { ArgKind::Parameter },
        ));
    }
    let mut vals: Vec<ValueId> = mats.clone();
    for _ in 0..(3 + rng.gen_range(8)) {
        let x = *rng.choose(&vals);
        let (xr, xc) = {
            let d = &b.ty(x).dims;
            (d[0], d[1])
        };
        match rng.gen_range(4) {
            0 => {
                // find a shape-compatible rhs for matmul
                let rhs = vals
                    .iter()
                    .copied()
                    .find(|&v| b.ty(v).dims[0] == xc);
                if let Some(rhs) = rhs {
                    vals.push(b.matmul(x, rhs));
                }
            }
            1 => {
                let same = vals.iter().copied().find(|&v| b.ty(v).dims == vec![xr, xc]);
                if let Some(y) = same {
                    vals.push(b.add(x, y));
                }
            }
            2 => vals.push(b.tanh(x)),
            _ => vals.push(b.transpose(x, vec![1, 0])),
        }
    }
    let last = *vals.last().unwrap();
    b.output(last);
    b.finish()
}

/// Random program exercising every op kind, 0–4 arguments of every
/// kind, and nested scopes — food for the textual round-trip property.
fn random_rich_program(rng: &mut Rng) -> automap::ir::Func {
    let mut b = GraphBuilder::new(format!("rich_{}", rng.gen_range(1000)));
    let kinds = [ArgKind::Input, ArgKind::Parameter, ArgKind::OptState, ArgKind::Constant];
    let n_args = rng.gen_range(5);
    let mut pool: Vec<ValueId> = Vec::new();
    for i in 0..n_args {
        let scoped = rng.gen_bool(0.5);
        if scoped {
            b.push_scope(&format!("blk_{i}"));
        }
        pool.push(b.arg(
            format!("a{i}/w.{i}"),
            TensorType::f32(&[4, 8]),
            kinds[rng.gen_range(4)],
        ));
        if scoped {
            b.pop_scope();
        }
    }
    // With zero args the pool seeds from constants instead.
    b.push_scope(&format!("outer_{}", rng.gen_range(3)));
    pool.push(b.constant(rng.gen_f64() - 0.5, TensorType::f32(&[4, 8])));
    pool.push(b.iota(rng.gen_range(2), TensorType::f32(&[4, 8])));
    let pick = |rng: &mut Rng, pool: &[ValueId]| *rng.choose(pool);

    // Elementwise backbone (all [4,8], so any pool member composes).
    let x = pick(rng, &pool);
    let y = pick(rng, &pool);
    let e = b.add(x, y);
    let e = b.sub(e, pick(rng, &pool));
    let e = b.mul(e, pick(rng, &pool));
    let e = b.div(e, pick(rng, &pool));
    let e = b.max(e, pick(rng, &pool));
    let e = b.min(e, pick(rng, &pool));
    b.push_scope("unary");
    let e = b.neg(e);
    let e = b.exp(e);
    let e = b.log(e);
    let e = b.tanh(e);
    let e = b.abs(e);
    let e = b.sqrt(e);
    let e = b.rsqrt(e);
    b.pop_scope();
    let dirs = [CmpDir::Lt, CmpDir::Le, CmpDir::Gt, CmpDir::Ge, CmpDir::Eq, CmpDir::Ne];
    let cmp = b.compare(dirs[rng.gen_range(6)], e, pick(rng, &pool));
    let sel = b.select(cmp, e, pick(rng, &pool));
    let cv = b.convert(sel, DType::BF16);
    let cv = b.convert(cv, DType::F32);

    // Structured ops.
    let table = b.constant(0.25, TensorType::f32(&[10, 8]));
    let dot = DotDims {
        lhs_batch: vec![],
        rhs_batch: vec![],
        lhs_contract: vec![1],
        rhs_contract: vec![1],
    };
    let d = b.dot(dot, cv, table);
    let rs = b.reduce_sum(d, vec![1]);
    let rm = b.reduce_max(d, vec![0]);
    let bc = b.broadcast(rs, vec![0], TensorType::f32(&[4, 10]));
    let rsh = b.reshape(bc, &[40]);
    let tp = b.transpose(d, vec![1, 0]);
    let idsf = b.iota(0, TensorType::f32(&[6]));
    let ids = b.convert(idsf, DType::I32);
    let g = b.gather(table, ids);
    let ss = b.segment_sum(g, ids, 7);
    b.pop_scope();

    b.output(rsh);
    b.output(tp);
    b.output(ss);
    if rng.gen_bool(0.5) {
        b.output(rm);
    }
    if rng.gen_bool(0.3) {
        b.output(pick(rng, &pool));
    }
    b.finish()
}

#[test]
fn prop_parse_print_round_trip_is_exact() {
    check("parse_print_roundtrip", 40, 0x17, |rng| {
        let f = random_rich_program(rng);
        automap::ir::verify::verify(&f).map_err(|e| e.to_string())?;
        let text = print_func(&f);
        let g = parse_func(&text).map_err(|e| format!("{e}\nsource:\n{text}"))?;
        if g != f {
            return Err(format!("parse(print(f)) != f\nsource:\n{text}"));
        }
        Ok(())
    });
}

#[test]
fn prop_parser_never_panics_and_positions_errors() {
    check("parser_corruption", 80, 0x18, |rng| {
        let f = random_rich_program(rng);
        let text = print_func(&f);
        let lines = text.lines().count();
        // Truncation at a random char boundary: the parser must reject
        // (or accept a still-complete prefix) without panicking, and any
        // error must carry a plausible 1-based position.
        let mut cut = rng.gen_range(text.len());
        while !text.is_char_boundary(cut) {
            cut -= 1;
        }
        if let Err(e) = parse_func(&text[..cut]) {
            if e.line < 1 || e.col < 1 || e.line > lines + 1 {
                return Err(format!("implausible position {}:{} ({lines} lines)", e.line, e.col));
            }
        }
        // Single-byte mutation: never a panic (outcome may be either).
        let mut bytes = text.clone().into_bytes();
        let at = rng.gen_range(bytes.len());
        bytes[at] = b"Z#%9"[rng.gen_range(4)];
        if let Ok(mutated) = String::from_utf8(bytes) {
            let _ = parse_func(&mutated);
        }
        Ok(())
    });
}

#[test]
fn prop_propagation_tilings_always_divisible() {
    check("divisible_tilings", 60, 0xA1, |rng| {
        let f = random_program(rng);
        let mesh = Mesh::new(&[("m", 4)]);
        let program = PartirProgram::new(f, mesh);
        // random decision sequence
        let mut st = DecisionState::default();
        for _ in 0..3 {
            let v = ValueId(rng.gen_range(program.func.num_args()) as u32);
            let dim = rng.gen_range(2);
            st.actions.push(Action::Tile { v, dim, axis: AxisId(0) });
        }
        st.actions.push(Action::InferRest);
        let (dm, _) = program.apply(&st);
        for v in 0..program.func.num_values() {
            for (axis, dim) in dm.tilings(v) {
                let size = program.func.value_type(ValueId(v as u32)).dims[dim];
                if size % program.mesh.size(axis) != 0 {
                    return Err(format!("value {v} tiled dim {dim} size {size} not divisible"));
                }
            }
        }
        Ok(())
    });
}

#[test]
fn prop_propagation_is_deterministic() {
    check("deterministic_propagation", 40, 0xB2, |rng| {
        let f = random_program(rng);
        let program = PartirProgram::new(f, Mesh::new(&[("m", 2)]));
        let mut st = DecisionState::default();
        for _ in 0..2 {
            let v = ValueId(rng.gen_range(program.func.num_args()) as u32);
            st.actions.push(Action::Tile { v, dim: rng.gen_range(2), axis: AxisId(0) });
        }
        let (a, _) = program.apply(&st);
        let (b, _) = program.apply(&st);
        if a != b {
            return Err("same decisions -> different DistMaps".into());
        }
        Ok(())
    });
}

#[test]
fn prop_incremental_episode_equals_replay() {
    check("incremental_equals_replay", 40, 0xC3, |rng| {
        let f = random_program(rng);
        let program = PartirProgram::new(f, Mesh::new(&[("m", 4)]));
        let wl = RewriteEnv::default_worklist(&program);
        let env = RewriteEnv::new(
            &program,
            automap::sim::device::Device::tpu_v3(),
            automap::cost::composite::CostWeights::default(),
            SearchOptions { cross_layer_tying: false, ..Default::default() },
            &wl,
        );
        let mut ep = env.reset();
        for _ in 0..4 {
            let acts = env.legal_actions(&ep);
            if acts.is_empty() {
                break;
            }
            let a = *rng.choose(&acts);
            env.step(&mut ep, a);
            if ep.done {
                break;
            }
        }
        let (replayed, _) = program.apply(&ep.state);
        if replayed != ep.dm {
            return Err("incremental episode dm != full replay dm".into());
        }
        // The incrementally maintained stuck set must equal the settled
        // status of the final map.
        if ep.stuck.to_sorted_vec() != program.stuck_set(&ep.dm) {
            return Err("incremental stuck set != settled full-pass stuck set".into());
        }
        Ok(())
    });
}

/// Incremental forward propagation vs full replay over the committed
/// golden corpus (every op kind, nested scopes, zero-arg programs) —
/// the acceptance wall for the dirty-frontier fast path. In debug
/// builds every `env.step` additionally self-checks against a full
/// pass, so this drives both the external and internal equivalence.
#[test]
fn corpus_incremental_propagation_equals_replay() {
    let dir = concat!(env!("CARGO_MANIFEST_DIR"), "/../configs/corpus");
    let mut checked = 0usize;
    for entry in std::fs::read_dir(dir).expect("corpus dir") {
        let path = entry.unwrap().path();
        if path.extension().map(|e| e != "pir").unwrap_or(true) {
            continue;
        }
        let text = std::fs::read_to_string(&path).unwrap();
        let f = parse_func(&text).unwrap_or_else(|e| panic!("{}: {e}", path.display()));
        let program = PartirProgram::new(f, Mesh::new(&[("batch", 2), ("model", 4)]));
        let wl = RewriteEnv::default_worklist(&program);
        if wl.is_empty() {
            continue; // zero-arg corpus program: no decision targets
        }
        let env = RewriteEnv::new(
            &program,
            automap::sim::device::Device::tpu_v3(),
            automap::cost::composite::CostWeights::default(),
            SearchOptions { cross_layer_tying: false, ..Default::default() },
            &wl,
        );
        let mut rng = Rng::new(0xD00D + wl.len() as u64);
        for _attempt in 0..8 {
            let mut ep = env.reset();
            for _ in 0..5 {
                let acts = env.legal_actions(&ep);
                if acts.is_empty() {
                    break;
                }
                let a = *rng.choose(&acts);
                env.step(&mut ep, a);
                let (replayed, _) = program.apply(&ep.state);
                assert_eq!(replayed, ep.dm, "{}: dm diverged", path.display());
                assert_eq!(
                    ep.stuck.to_sorted_vec(),
                    program.stuck_set(&ep.dm),
                    "{}: stuck set diverged",
                    path.display()
                );
                if ep.done {
                    break;
                }
            }
            checked += 1;
        }
    }
    assert!(checked > 0, "golden corpus must contain checkable programs");
}

#[test]
fn prop_replicated_program_has_no_collectives_and_max_memory() {
    check("replicated_baseline", 40, 0xD4, |rng| {
        let f = random_program(rng);
        let program = PartirProgram::new(f, Mesh::new(&[("m", 4)]));
        let dm0 = DistMap::new(&program.func, &program.mesh);
        let sp = lower(&program.func, &program.mesh, &program.prop, &dm0);
        if !sp.collectives.is_empty() {
            return Err(format!("replicated program emitted {} collectives", sp.collectives.len()));
        }
        let m0 = peak_memory(&program.func, &program.mesh, &dm0);

        // any decision state must not increase per-device peak memory
        let mut st = DecisionState::default();
        let v = ValueId(rng.gen_range(program.func.num_args()) as u32);
        st.actions.push(Action::Tile { v, dim: rng.gen_range(2), axis: AxisId(0) });
        st.actions.push(Action::InferRest);
        let (dm, _) = program.apply(&st);
        let m1 = peak_memory(&program.func, &program.mesh, &dm);
        if m1.peak_bytes > m0.peak_bytes {
            return Err(format!("sharding increased memory {} -> {}", m0.peak_bytes, m1.peak_bytes));
        }
        let sp1 = lower(&program.func, &program.mesh, &program.prop, &dm);
        for c in &sp1.collectives {
            if c.bytes <= 0 {
                return Err("collective with non-positive payload".into());
            }
        }
        Ok(())
    });
}

#[test]
fn prop_dce_preserves_semantics() {
    check("dce_semantics", 25, 0xE5, |rng| {
        let f = random_program(rng);
        let (g, _) = automap::ir::dce::dce(&f);
        automap::ir::verify::verify(&g).map_err(|e| e.to_string())?;
        let args: Vec<Tensor> = f
            .args
            .iter()
            .map(|a| {
                let n = a.ty.num_elements() as usize;
                Tensor::new(&a.ty.dims, (0..n).map(|_| rng.gen_f64() - 0.5).collect())
            })
            .collect();
        let ya = eval(&f, &args);
        let yb = eval(&g, &args);
        if ya != yb {
            return Err("DCE changed program outputs".into());
        }
        Ok(())
    });
}

#[test]
fn prop_autodiff_matches_finite_differences() {
    check("autodiff_fd", 15, 0xF6, |rng| {
        // random chain of differentiable unary/binary ops on a vector
        let mut b = GraphBuilder::new("adchain");
        let x = b.arg("x", TensorType::f32(&[5]), ArgKind::Parameter);
        let mut cur = x;
        for _ in 0..(2 + rng.gen_range(4)) {
            cur = match rng.gen_range(5) {
                0 => b.tanh(cur),
                1 => b.exp(cur),
                2 => {
                    let s = b.shift(cur, 2.5);
                    b.log(s)
                }
                3 => b.mul(cur, x),
                _ => {
                    let c = b.scale(cur, 0.7);
                    b.add(c, x)
                }
            };
        }
        let loss = b.reduce_sum(cur, vec![0]);
        let grads = gradients(&mut b, loss, &[x]);
        let g = grads[0].ok_or("missing grad")?;
        b.output(loss);
        b.output(g);
        let f = b.finish();
        let xs = Tensor::new(&[5], (0..5).map(|_| rng.gen_f64() * 0.8 - 0.4).collect());
        let vals = eval_all(&f, &[xs.clone()]);
        let analytic = &vals[g.index()];
        let eps = 1e-6;
        for e in 0..5 {
            let mut plus = xs.clone();
            plus.data[e] += eps;
            let mut minus = xs.clone();
            minus.data[e] -= eps;
            let lp = eval_all(&f, &[plus])[loss.index()].data[0];
            let lm = eval_all(&f, &[minus])[loss.index()].data[0];
            let fd = (lp - lm) / (2.0 * eps);
            let an = analytic.data[e];
            if (fd - an).abs() > 1e-3 * (1.0 + fd.abs().max(an.abs())) {
                return Err(format!("grad[{e}]: fd={fd} analytic={an}"));
            }
        }
        Ok(())
    });
}
