//! End-to-end tests for the persistent plan-cache tier (DESIGN.md §13):
//! the ISSUE acceptance — a fresh process pointed at an existing cache
//! log serves a previously searched fingerprint without running a
//! search, byte-identically — plus torn-log recovery, write-through on
//! publish, and memory-tier promotion of disk hits.

use automap::service::{run_batch, DiskTier, PartitionRequest, PlanService, ServiceConfig};

fn temp_cache_dir(name: &str) -> std::path::PathBuf {
    let dir = std::env::temp_dir().join(format!("automap-persist-{}-{name}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

fn cfg(dir: &std::path::Path) -> ServiceConfig {
    ServiceConfig { persist_path: Some(dir.to_path_buf()), ..ServiceConfig::default() }
}

fn mlp_request(id: &str, seed: u64) -> PartitionRequest {
    PartitionRequest {
        id: id.to_string(),
        model: "mlp".to_string(),
        mesh: "batch=2,model=4".to_string(),
        budget: 40,
        seed,
        ..Default::default()
    }
}

#[test]
fn acceptance_fresh_process_serves_from_disk_without_search() {
    let dir = temp_cache_dir("acceptance");

    // "Process" 1: a cold search, written through to the disk tier.
    let first = {
        let svc = PlanService::try_new(cfg(&dir)).unwrap();
        let r = svc.handle(&mlp_request("warm", 7));
        assert!(r.error.is_none(), "{:?}", r.error);
        assert!(!r.cached && !r.disk);
        assert_eq!(svc.searches_run(), 1);
        let stats = svc.disk_stats().expect("disk tier configured");
        assert_eq!(stats.appends, 1, "publish writes through to the log");
        r
    }; // service dropped — only the log file survives

    // "Process" 2: same fingerprint, fresh memory tier. Must be served
    // from disk, with zero searches and the byte-identical document.
    let svc = PlanService::try_new(cfg(&dir)).unwrap();
    let second = svc.handle(&mlp_request("cold", 7));
    assert!(second.error.is_none(), "{:?}", second.error);
    assert!(second.cached, "disk hits count as cached");
    assert!(second.disk, "response is marked as a disk-tier hit");
    assert!(!second.dedup);
    assert_eq!(svc.searches_run(), 0, "no search may run on a disk hit");
    assert_eq!(svc.disk_hits(), 1);
    assert_eq!(second.fingerprint, first.fingerprint);
    assert_eq!(
        second.plan_json, first.plan_json,
        "disk-served plan must be byte-identical to the searched one"
    );

    // The hit was promoted into the memory tier: the next probe is a
    // plain memory hit, not another disk read.
    let third = svc.handle(&mlp_request("hot", 7));
    assert!(third.cached && !third.disk, "promotion makes the next hit a memory hit");
    assert_eq!(svc.disk_hits(), 1, "disk tier was not probed again");
    assert_eq!(third.plan_json, first.plan_json);

    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn batch_summary_counts_disk_hits() {
    let dir = temp_cache_dir("batch");
    let requests: Vec<PartitionRequest> =
        (0..3).map(|i| mlp_request(&format!("r{i}"), i as u64)).collect();

    {
        let svc = PlanService::try_new(cfg(&dir)).unwrap();
        let (_, summary) = run_batch(&svc, &requests, 2, 4);
        assert_eq!(summary.errors, 0);
        assert_eq!(summary.searches, 3);
        assert_eq!(summary.disk_hits, 0, "cold log, nothing to hit");
    }

    let svc = PlanService::try_new(cfg(&dir)).unwrap();
    let (responses, summary) = run_batch(&svc, &requests, 2, 4);
    assert_eq!(summary.errors, 0);
    assert_eq!(summary.searches, 0, "warm log answers everything");
    assert_eq!(summary.disk_hits, 3);
    assert!(responses.iter().all(|r| r.cached && r.disk));
    assert!(summary.describe().contains("3 disk-tier hits"), "{}", summary.describe());

    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn torn_log_tail_is_recovered_and_intact_entries_still_serve() {
    let dir = temp_cache_dir("torn");
    let first = {
        let svc = PlanService::try_new(cfg(&dir)).unwrap();
        svc.handle(&mlp_request("a", 3))
    };

    // Simulate a crash mid-append: garbage after the valid record.
    let log = dir.join("plans.plog");
    let mut bytes = std::fs::read(&log).unwrap();
    bytes.extend_from_slice(&[0xde, 0xad, 0xbe, 0xef, 0x01]);
    std::fs::write(&log, &bytes).unwrap();

    let svc = PlanService::try_new(cfg(&dir)).unwrap();
    let r = svc.handle(&mlp_request("b", 3));
    assert!(r.cached && r.disk, "the intact record still serves");
    assert_eq!(r.plan_json, first.plan_json);
    let stats = svc.disk_stats().unwrap();
    assert_eq!(stats.corrupt_records, 1, "the torn tail is counted");
    assert_eq!(stats.entries, 1);

    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn distinct_fingerprints_coexist_in_one_log() {
    let dir = temp_cache_dir("multi");
    let (a, b) = {
        let svc = PlanService::try_new(cfg(&dir)).unwrap();
        (svc.handle(&mlp_request("a", 1)), svc.handle(&mlp_request("b", 2)))
    };
    assert_ne!(a.fingerprint, b.fingerprint);

    let svc = PlanService::try_new(cfg(&dir)).unwrap();
    let a2 = svc.handle(&mlp_request("a2", 1));
    let b2 = svc.handle(&mlp_request("b2", 2));
    assert!(a2.disk && b2.disk);
    assert_eq!(a2.plan_json, a.plan_json);
    assert_eq!(b2.plan_json, b.plan_json);
    assert_eq!(svc.searches_run(), 0);
    assert_eq!(svc.disk_stats().unwrap().entries, 2);

    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn concurrent_publishes_race_compaction_without_losing_entries() {
    let dir = temp_cache_dir("race");
    // Tiny compaction threshold: with four writer threads rewriting the
    // same ten keys each, compaction keeps firing while other threads
    // are queued on the tier, exercising the publish-during-compaction
    // interleaving end to end.
    let tier = DiskTier::open_with(&dir, 64).unwrap();
    std::thread::scope(|s| {
        for t in 0..4u64 {
            let tier = &tier;
            s.spawn(move || {
                for i in 0..50u64 {
                    let fp = t * 1000 + (i % 10);
                    tier.put(fp, &format!("{{\"t\":{t},\"i\":{i}}}")).unwrap();
                }
            });
        }
    });
    let stats = tier.stats();
    assert_eq!(stats.entries, 40, "10 live keys per writer thread");
    assert!(stats.compactions > 0, "tiny threshold must have compacted");
    // The newest revision of every key won, regardless of interleaving.
    for t in 0..4u64 {
        for k in 0..10u64 {
            let got = tier.get(t * 1000 + k).expect("live key");
            assert_eq!(got, format!("{{\"t\":{t},\"i\":{}}}", 40 + k));
        }
    }
    // A fresh open replays the compacted log cleanly: every entry
    // intact, nothing counted corrupt, generation carried forward.
    let generation = stats.generation;
    drop(tier);
    let tier = DiskTier::open_with(&dir, 1 << 20).unwrap();
    let reopened = tier.stats();
    assert_eq!(reopened.entries, 40);
    assert_eq!(reopened.corrupt_records, 0);
    assert_eq!(reopened.generation, generation);
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn unconfigured_service_has_no_disk_tier() {
    let svc = PlanService::new(ServiceConfig::default());
    assert!(svc.disk_stats().is_none());
    assert_eq!(svc.disk_hits(), 0);
    let r = svc.handle(&mlp_request("x", 5));
    assert!(!r.disk, "no tier, no disk hits");
}
