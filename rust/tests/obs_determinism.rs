//! Observability contract (DESIGN.md §12): the flight recorder is a pure
//! side channel. Tracing on vs off must leave plan JSON byte-identical
//! for a fixed (seed, K); exported traces must be well-formed Chrome
//! trace-event JSON; histogram percentiles must be exact on bucket
//! boundaries; and `--metrics-out` snapshots must match the committed
//! schema in `configs/metrics_schema.json`.

use std::sync::Mutex;

use automap::obs::metrics::{bucket_index, bucket_lower_bound, Histogram};
use automap::obs::recorder::recorder;
use automap::service::{JobDefaults, PartitionRequest, PlanService, ServiceConfig};
use automap::util::json::{parse, Json};

/// Tests that toggle the process-global recorder hold this lock so their
/// enable/clear/export windows never interleave.
static RECORDER_LOCK: Mutex<()> = Mutex::new(());

fn plan_json(workers: usize, budget: usize) -> String {
    let req = PartitionRequest {
        id: format!("det-{workers}"),
        model: "transformer".to_string(),
        layers: 2,
        mesh: "model=4".to_string(),
        budget,
        seed: 42,
        workers,
        ..Default::default()
    };
    let job = req.build_job(&JobDefaults::default()).expect("well-formed request");
    let report = job.run().expect("search runs");
    report.plan.to_json().to_string()
}

#[test]
fn tracing_on_vs_off_leaves_plan_json_byte_identical() {
    let _g = RECORDER_LOCK.lock().unwrap_or_else(|e| e.into_inner());
    let rec = recorder();
    for workers in [1usize, 4] {
        rec.disable();
        let off = plan_json(workers, 60);
        rec.clear();
        rec.enable();
        let on = plan_json(workers, 60);
        rec.disable();
        rec.clear();
        assert_eq!(off, on, "K={workers}: tracing changed the plan bytes");
        assert!(!off.is_empty());
    }
}

#[test]
fn histogram_percentiles_are_exact_on_bucket_boundaries() {
    let h = Histogram::new();
    for v in [1u64, 2, 4, 8] {
        h.record(v);
    }
    // Exact ranks: p50 -> 2nd smallest (2), p90/p99 -> 4th smallest (8),
    // and powers of two sit exactly on bucket lower bounds.
    assert_eq!(h.percentile(0.50), 2.0);
    assert_eq!(h.percentile(0.90), 8.0);
    assert_eq!(h.percentile(0.99), 8.0);

    // Non-boundary values report their bucket's lower bound: 1000 lives in
    // [2^9.75, 2^10), so every percentile of a single-value histogram is
    // exactly 2^9.75.
    let h = Histogram::new();
    h.record(1000);
    assert_eq!(bucket_lower_bound(bucket_index(1000)), 2f64.powf(9.75));
    assert_eq!(h.percentile(0.50), 2f64.powf(9.75));

    // Monotonicity over a spread.
    let h = Histogram::new();
    for v in 1..=1000u64 {
        h.record(v * 17);
    }
    let (p50, p90, p99) = (h.percentile(0.50), h.percentile(0.90), h.percentile(0.99));
    assert!(0.0 < p50 && p50 <= p90 && p90 <= p99, "p50 {p50} p90 {p90} p99 {p99}");
}

fn smoke_request(id: &str) -> PartitionRequest {
    PartitionRequest {
        id: id.to_string(),
        model: "mlp".to_string(),
        mesh: "model=4".to_string(),
        budget: 40,
        seed: 7,
        workers: 2,
        ..Default::default()
    }
}

#[test]
fn exported_trace_events_are_well_formed() {
    let _g = RECORDER_LOCK.lock().unwrap_or_else(|e| e.into_inner());
    let rec = recorder();
    rec.clear();
    rec.enable();
    let svc = PlanService::new(ServiceConfig::default());
    let first = svc.handle(&smoke_request("t1"));
    assert!(first.error.is_none(), "{:?}", first.error);
    let second = svc.handle(&smoke_request("t1"));
    assert!(second.cached, "repeat request must hit the plan cache");
    let trace = rec.chrome_trace();
    rec.disable();
    rec.clear();

    let events = trace.get("traceEvents").and_then(|j| j.as_arr()).expect("traceEvents array");
    assert!(!events.is_empty(), "tracing a served request must record events");
    // Every B has a matching E per (pid, tid) lane, in stack order; all
    // events carry the required fields; phases are the exported subset.
    let mut depth: std::collections::BTreeMap<(u64, u64), i64> = std::collections::BTreeMap::new();
    for ev in events {
        let ph = ev.get("ph").and_then(|j| j.as_str()).expect("ph");
        let pid = ev.get("pid").and_then(|j| j.as_f64()).expect("pid") as u64;
        let tid = ev.get("tid").and_then(|j| j.as_f64()).expect("tid") as u64;
        assert!(ev.get("name").and_then(|j| j.as_str()).is_some(), "name missing");
        assert!(ev.get("cat").and_then(|j| j.as_str()).is_some(), "cat missing");
        assert!(ev.get("ts").and_then(|j| j.as_f64()).is_some(), "ts missing");
        let d = depth.entry((pid, tid)).or_insert(0);
        match ph {
            "B" => *d += 1,
            "E" => {
                *d -= 1;
                assert!(*d >= 0, "E without a matching B on pid={pid} tid={tid}");
            }
            "X" => {
                let dur = ev.get("dur").and_then(|j| j.as_f64()).expect("X needs dur");
                assert!(dur >= 0.0);
            }
            "i" => {}
            other => panic!("unexpected phase {other:?}"),
        }
    }
    for ((pid, tid), d) in depth {
        assert_eq!(d, 0, "unbalanced spans on pid={pid} tid={tid}");
    }
}

#[test]
fn metrics_snapshot_matches_the_committed_schema() {
    let svc = PlanService::new(ServiceConfig::default());
    let resp = svc.handle(&smoke_request("m1"));
    assert!(resp.error.is_none(), "{:?}", resp.error);
    let snap = automap::obs::metrics_snapshot();

    let schema_path =
        std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("../configs/metrics_schema.json");
    let schema_text = std::fs::read_to_string(schema_path).expect("configs/metrics_schema.json");
    let schema = parse(&schema_text).expect("schema parses");

    let keys = |j: &Json, section: &str| -> Vec<String> {
        match j.get(section) {
            Some(Json::Obj(fields)) => fields.iter().map(|(k, _)| k.clone()).collect(),
            Some(Json::Arr(items)) => {
                items.iter().filter_map(|i| i.as_str()).map(str::to_string).collect()
            }
            _ => panic!("section {section} missing"),
        }
    };
    for section in ["counters", "gauges", "histograms"] {
        let mut got = keys(&snap, section);
        let mut want = keys(&schema, section);
        got.sort();
        want.sort();
        assert_eq!(got, want, "{section}: snapshot keys diverge from configs/metrics_schema.json");
    }
    // The request latency histogram saw at least the request above, and
    // telemetry retained its timeline entry.
    let hist = snap.get("histograms").and_then(|h| h.get("service.request_latency_ns")).unwrap();
    assert!(hist.get("count").and_then(|j| j.as_f64()).unwrap() >= 1.0);
    let requests = snap.get("requests").and_then(|j| j.as_arr()).expect("requests section");
    assert!(!requests.is_empty(), "telemetry hub retained no request entries");
}
