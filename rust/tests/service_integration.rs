//! End-to-end tests for the partition-plan service: the ISSUE acceptance
//! batch (8 requests, 2 unique fingerprints → exactly 2 searches), fixed
//! seed root-parallel determinism, byte-identical cache hits, and
//! in-flight dedup.

use automap::service::{
    run_batch, PartitionRequest, PlanService, ServiceConfig,
};
use automap::util::json::parse;

fn mlp_request(id: &str, seed: u64, workers: usize) -> PartitionRequest {
    PartitionRequest {
        id: id.to_string(),
        model: "mlp".to_string(),
        mesh: "batch=2,model=4".to_string(),
        pin: vec!["batch".to_string()],
        shard: vec!["x:0:batch".to_string()],
        budget: 60,
        seed,
        workers,
        ..Default::default()
    }
}

#[test]
fn acceptance_batch_8_requests_2_fingerprints() {
    // 8 requests alternating over 2 unique fingerprints (seed 0 / seed 1;
    // ids differ but ids are not part of the fingerprint).
    let requests: Vec<PartitionRequest> =
        (0..8).map(|i| mlp_request(&format!("r{i}"), (i % 2) as u64, 2)).collect();
    let svc = PlanService::new(ServiceConfig::default());
    let (responses, summary) = run_batch(&svc, &requests, 2, 4);

    assert_eq!(summary.requests, 8);
    assert_eq!(summary.errors, 0);
    assert_eq!(summary.searches, 2, "exactly one search per unique fingerprint");
    assert_eq!(
        summary.cache_hits + summary.dedup_served,
        6,
        "the other six must be served without a search"
    );

    // Responses come back in input order, and every response for the
    // same fingerprint carries the byte-identical plan document.
    for (i, r) in responses.iter().enumerate() {
        assert_eq!(r.id, format!("r{i}"));
        assert!(r.error.is_none(), "r{i}: {:?}", r.error);
    }
    for parity in 0..2usize {
        let group: Vec<_> = responses.iter().skip(parity).step_by(2).collect();
        let first = group[0].plan_json.as_ref().unwrap();
        for r in &group[1..] {
            assert_eq!(r.plan_json.as_ref().unwrap(), first, "plans must be byte-identical");
            assert_eq!(r.fingerprint, group[0].fingerprint);
        }
    }
    assert_ne!(responses[0].fingerprint, responses[1].fingerprint);
}

#[test]
fn fixed_seed_k4_executor_reproduces_the_same_plan() {
    let req = mlp_request("det", 7, 4);
    let svc_a = PlanService::new(ServiceConfig::default());
    let svc_b = PlanService::new(ServiceConfig::default());
    let a = svc_a.handle(&req);
    let b = svc_b.handle(&req);
    assert!(a.error.is_none() && b.error.is_none());
    assert!(!a.cached && !b.cached, "fresh services, both runs searched");
    assert_eq!(
        a.plan_json, b.plan_json,
        "fixed (seed, K) must reproduce the identical best plan"
    );
}

#[test]
fn cache_hit_returns_byte_identical_plan_json() {
    let svc = PlanService::new(ServiceConfig::default());
    let first = svc.handle(&mlp_request("a", 3, 2));
    let second = svc.handle(&mlp_request("b", 3, 2));
    assert!(!first.cached);
    assert!(second.cached);
    assert_eq!(first.plan_json, second.plan_json);
    // The document parses and round-trips as a PartitionPlan.
    let j = parse(first.plan_json.as_ref().unwrap()).unwrap();
    let plan = automap::session::PartitionPlan::from_json(&j).unwrap();
    assert!(plan.input_specs.iter().any(|s| s.name == "x" && s.tiled_on("batch")));
    assert_eq!(plan.wall_seconds, 0.0, "service plans zero wall time for determinism");
}

#[test]
fn concurrent_duplicates_run_one_search() {
    let svc = PlanService::new(ServiceConfig::default());
    let req = mlp_request("dup", 11, 2);
    std::thread::scope(|s| {
        let handles: Vec<_> = (0..4).map(|_| s.spawn(|| svc.handle(&req))).collect();
        let responses: Vec<_> = handles.into_iter().map(|h| h.join().unwrap()).collect();
        let first = responses[0].plan_json.as_ref().unwrap();
        for r in &responses {
            assert!(r.error.is_none());
            assert_eq!(r.plan_json.as_ref().unwrap(), first);
        }
    });
    assert_eq!(svc.searches_run(), 1, "four concurrent duplicates, one search");
    assert_eq!(svc.served_without_search(), 3);
}

#[test]
fn distinct_configurations_do_not_share_cache_lines() {
    let svc = PlanService::new(ServiceConfig::default());
    let base = svc.handle(&mlp_request("base", 5, 2));
    // Different seed, budget, workers, mesh, or constraints → new search.
    let variants = vec![
        PartitionRequest { seed: 6, ..mlp_request("v1", 5, 2) },
        PartitionRequest { budget: 61, ..mlp_request("v2", 5, 2) },
        PartitionRequest { workers: 3, ..mlp_request("v3", 5, 2) },
        PartitionRequest { mesh: "batch=2,model=2".to_string(), ..mlp_request("v4", 5, 2) },
        PartitionRequest { pin: vec![], ..mlp_request("v5", 5, 2) },
    ];
    let mut fingerprints = vec![base.fingerprint.clone()];
    for v in &variants {
        let r = svc.handle(v);
        assert!(r.error.is_none(), "{:?}: {:?}", v.id, r.error);
        assert!(!r.cached, "{} must not hit another config's cache line", v.id);
        fingerprints.push(r.fingerprint.clone());
    }
    fingerprints.sort();
    fingerprints.dedup();
    assert_eq!(fingerprints.len(), 6, "all six configurations are distinct");
    assert_eq!(svc.searches_run(), 6);
}
