//! Tier-1 smoke run of the search-throughput measurement: proves the
//! root-parallel executor actually scales with workers and refreshes
//! `BENCH_search.json` at the repo root on every test run (the
//! `search_throughput` bench writes the same file with a fuller
//! profile).

use automap::service::throughput::{measure, write_report, ThroughputConfig};

#[test]
fn throughput_smoke_scales_and_writes_bench_json() {
    let report = measure(&ThroughputConfig::quick()).expect("measurement failed");

    assert!(report.single_episodes_per_sec > 0.0);
    assert!(report.multi_episodes_per_sec > 0.0);
    assert!(report.cache_hit_median_ns > 0.0);
    // The scaling evidence (2x on a 4-core runner) lives in
    // BENCH_search.json; a hard wall-clock bar in tier-1 would flake on
    // noisy shared runners. What tier-1 pins is the absence of a
    // catastrophic regression: a 4-worker fan-out running >25% SLOWER
    // than single-worker would mean the executor serialises its workers
    // (e.g. an accidental shared lock), which no scheduler noise
    // produces. (Skipped on a single hardware thread.)
    let cores = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    if cores >= 2 {
        assert!(
            report.speedup > 0.75,
            "multi-worker throughput collapsed vs single-worker on {} cores \
             (workers serialised?): {}",
            cores,
            report.describe()
        );
        if report.speedup < 2.0 {
            println!(
                "note: speedup {:.2}x below the 2x 4-core target on {cores} cores",
                report.speedup
            );
        }
    }
    // A cache hit must be far cheaper than the search it replaces
    // (sub-millisecond vs tens of milliseconds of episodes).
    assert!(
        report.cache_hit_median_ns < 5e6,
        "cache hit median {}ns is implausibly slow",
        report.cache_hit_median_ns
    );

    // The per-episode building blocks are measured and sane: one step
    // and one evaluation each cost something, through both eval paths.
    // No ledger-vs-full speed bar here: debug builds cross-check every
    // ledger evaluation against the full pipeline, which inverts the
    // ratio by construction (the release perf-smoke bench enforces it).
    assert!(report.step_median_ns > 0.0);
    assert!(report.eval_median_ns > 0.0);
    assert!(report.eval_full_median_ns > 0.0);
    assert!(report.eval_ledger_speedup > 0.0);
    assert!(report.single_evals_per_sec > 0.0);
    // The 1F1B schedule simulator is measured too (pipeline subsystem,
    // DESIGN.md §11) — it sits on the pipelined evaluation hot path.
    assert!(report.schedule_sim_median_ns > 0.0);
    assert!((0.0..=1.0).contains(&report.eval_memo_hit_rate));
    assert!((0.0..=1.0).contains(&report.ledger_reuse_rate));
    assert!(report.rounds >= 1, "the multi-worker run must report its round schedule");

    let path = write_report(&report).expect("writing BENCH_search.json failed");
    let text = std::fs::read_to_string(&path).unwrap();
    let j = automap::util::json::parse(&text).unwrap();
    assert_eq!(j.get("bench").unwrap().as_str(), Some("search_throughput"));
    // Positive, not >1: on a single hardware thread (guarded above) a
    // 4-worker run can legitimately be slower than single-worker.
    assert!(j.get("speedup").unwrap().as_f64().unwrap() > 0.0);
    assert!(j.get("step_median_ns").unwrap().as_f64().unwrap() > 0.0);
    assert!(j.get("eval_median_ns").unwrap().as_f64().unwrap() > 0.0);
    // The ledger-vs-full comparison the perf floor check keys on.
    assert!(j.get("eval_full_median_ns").unwrap().as_f64().unwrap() > 0.0);
    assert!(j.get("eval_ledger_speedup").unwrap().as_f64().unwrap() > 0.0);
    assert!(j.get("single_evals_per_sec").unwrap().as_f64().unwrap() > 0.0);
    assert!(j.get("schedule_sim_median_ns").unwrap().as_f64().unwrap() > 0.0);
    assert!(j.get("ledger_reuse_rate").is_some());
    // configs/perf_floor.json is committed, so the report must carry the
    // pre-overhaul baseline alongside the current number.
    assert!(
        j.get("baseline_single_episodes_per_sec").is_some(),
        "baseline from configs/perf_floor.json missing from the report"
    );
    println!("search throughput: {}", report.describe());
}
