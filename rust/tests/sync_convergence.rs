//! Convergence acceptance for the replica anti-entropy protocol
//! (DESIGN.md §15): N in-process replicas seeded with disjoint and
//! overlapping plan sets, synced in randomized interleavings — with and
//! without failpoint storms — must all reach **byte-identical**
//! canonical `plans.plog` files, with zero plans lost and zero
//! corrupted frames applied. Storms replay byte-identically: re-arming
//! the same failpoint seeds over the same schedule reproduces every
//! round report and every final log byte.
//!
//! Like tests/chaos_service.rs, every test arms the PROCESS-GLOBAL
//! failpoint registry, so the suite serializes on one mutex and
//! disarms around each body.

use std::collections::BTreeMap;
use std::path::PathBuf;
use std::sync::{Arc, Mutex};

use automap::service::persist::DiskTier;
use automap::service::sync::{sync_once, InProcessTransport, SyncReport};
use automap::util::failpoints::{
    failpoints, SYNC_CONN_DROP, SYNC_FRAME_CORRUPT, SYNC_PARTIAL_WRITE,
};
use automap::util::rng::Rng;

static FP_LOCK: Mutex<()> = Mutex::new(());

struct Disarm;

impl Drop for Disarm {
    fn drop(&mut self) {
        failpoints().disarm_all();
    }
}

fn with_failpoints<T>(body: impl FnOnce() -> T) -> T {
    let _guard = FP_LOCK.lock().unwrap_or_else(|e| e.into_inner());
    failpoints().disarm_all();
    let _disarm = Disarm;
    body()
}

const N: usize = 4;

fn temp_dir(tag: &str, i: usize) -> PathBuf {
    let dir =
        std::env::temp_dir().join(format!("automap-syncconv-{}-{tag}-{i}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

/// Deterministic plan body for a fingerprint (what a deterministic
/// search would have produced identically on every replica).
fn plan_for(fp: u64) -> String {
    format!("{{\"plan\":{fp},\"cost\":{}}}", fp % 97)
}

/// A seeded fleet: every fingerprint lands on a random nonempty subset
/// of replicas (some disjoint, some overlapping, identical bodies), plus
/// two deliberate same-fingerprint conflicts whose bodies differ across
/// replicas — the symmetric tie-break must pick ONE winner everywhere.
struct Fleet {
    dirs: Vec<PathBuf>,
    tiers: Vec<Arc<DiskTier>>,
    transport: InProcessTransport,
    /// fp → every body some replica originally wrote for it. The
    /// converged value must be drawn from this set (nothing invented,
    /// nothing corrupted-but-applied) — and for conflicts, be its min.
    expected: BTreeMap<u64, Vec<String>>,
}

fn build_fleet(tag: &str, seed: u64) -> Fleet {
    let mut rng = Rng::new(seed);
    let dirs: Vec<PathBuf> = (0..N).map(|i| temp_dir(tag, i)).collect();
    let tiers: Vec<Arc<DiskTier>> =
        dirs.iter().map(|d| Arc::new(DiskTier::open(d).unwrap())).collect();
    let mut expected: BTreeMap<u64, Vec<String>> = BTreeMap::new();
    for k in 0..24u64 {
        // Spread fingerprints across digest buckets (top byte varies).
        let fp = (rng.next_u64() | 1).rotate_left((k * 11 % 64) as u32);
        let subset = (rng.next_u64() % ((1 << N) - 1)) + 1; // nonempty
        let body = plan_for(fp);
        for (i, tier) in tiers.iter().enumerate() {
            if subset & (1 << i) != 0 {
                tier.put(fp, &body).unwrap();
            }
        }
        expected.insert(fp, vec![body]);
    }
    for (c, fp) in [(0u8, 0xC0FFEE01u64), (1, 0xC0FFEE02)] {
        let body_a = format!("{{\"conflict\":\"a{c}\"}}");
        let body_b = format!("{{\"conflict\":\"b{c}\"}}");
        tiers[c as usize].put(fp, &body_a).unwrap();
        tiers[(c as usize + 1) % N].put(fp, &body_b).unwrap();
        expected.insert(fp, vec![body_a, body_b]);
    }
    let mut transport = InProcessTransport::new();
    for (i, tier) in tiers.iter().enumerate() {
        transport.register(&format!("r{i}"), tier.clone());
    }
    Fleet { dirs, tiers, transport, expected }
}

impl Fleet {
    fn sync(&self, i: usize) -> SyncReport {
        sync_once(&format!("r{i}"), &self.tiers[i], &self.transport).unwrap()
    }

    fn logs(&self) -> Vec<Vec<u8>> {
        self.tiers.iter().map(|t| std::fs::read(t.log_path()).unwrap()).collect()
    }

    fn converged(&self) -> bool {
        let logs = self.logs();
        logs.iter().all(|l| l == &logs[0])
    }

    /// Every expected fingerprint present on every replica, every body
    /// drawn from what was originally written (zero lost, zero
    /// invented), conflicts resolved to the lexicographic minimum.
    fn assert_full_union(&self) {
        for tier in &self.tiers {
            for (fp, bodies) in &self.expected {
                let got = tier.get(*fp).unwrap_or_else(|| {
                    panic!("fp {fp:016x} lost on a replica (expected one of {bodies:?})")
                });
                assert!(
                    bodies.contains(&got),
                    "fp {fp:016x}: body {got:?} was never written by any replica"
                );
                if bodies.len() > 1 {
                    let min = bodies.iter().min().unwrap();
                    assert_eq!(&got, min, "fp {fp:016x}: conflict must resolve to the minimum");
                }
            }
            assert_eq!(
                tier.live_index().len(),
                self.expected.len(),
                "no extra fingerprints may appear"
            );
        }
    }

    fn cleanup(self) {
        for d in &self.dirs {
            let _ = std::fs::remove_dir_all(d);
        }
    }
}

/// Fault-free property: ANY random interleaving of sync calls reaches
/// byte-identical logs on all replicas once every replica has synced at
/// least once after the last change — and holds the full union.
#[test]
fn random_interleavings_converge_to_byte_identical_logs() {
    with_failpoints(|| {
        for trial in 0..3u64 {
            let fleet = build_fleet(&format!("clean{trial}"), 1000 + trial);
            let mut rng = Rng::new(42 + trial);
            for _ in 0..12 {
                fleet.sync((rng.next_u64() % N as u64) as usize);
            }
            // A final ordered pass: each replica pulls the settled union.
            for i in 0..N {
                fleet.sync(i);
            }
            assert!(fleet.converged(), "trial {trial}: logs differ after settling pass");
            fleet.assert_full_union();
            // Convergence is stable: another round changes nothing.
            for i in 0..N {
                let r = fleet.sync(i);
                assert!(!r.changed, "trial {trial}: converged fleet must be a fixpoint");
                assert_eq!(r.records_pulled, 0);
            }
            assert!(fleet.converged());
            fleet.cleanup();
        }
    });
}

/// Storm schedule for the chaos trials: a fixed pseudo-random pick
/// sequence, so the only nondeterminism candidate is the failpoints —
/// which are seeded. Returns the per-step reports for replay pinning.
fn run_storm(fleet: &Fleet, schedule_seed: u64, steps: usize) -> Vec<SyncReport> {
    let mut rng = Rng::new(schedule_seed);
    (0..steps).map(|_| fleet.sync((rng.next_u64() % N as u64) as usize)).collect()
}

/// Under a storm of corrupt frames, dropped connections, and torn
/// snapshot publishes: no round is fatal, corrupt frames are quarantined
/// and never applied, and once the faults lift the fleet still converges
/// byte-identically with zero plans lost.
#[test]
fn failpoint_storms_never_corrupt_and_still_converge() {
    with_failpoints(|| {
        let fleet = build_fleet("storm", 77);
        failpoints().arm(SYNC_FRAME_CORRUPT, 0.3, 101).unwrap();
        failpoints().arm(SYNC_CONN_DROP, 0.2, 102).unwrap();
        failpoints().arm(SYNC_PARTIAL_WRITE, 0.2, 103).unwrap();
        let reports = run_storm(&fleet, 9, 20);
        let quarantined: u64 = reports.iter().map(|r| r.frames_quarantined).sum();
        let retries: u64 = reports.iter().map(|r| r.retries).sum();
        assert!(quarantined > 0, "a 30% corrupt-frame storm must quarantine something");
        assert!(retries > 0, "drops and torn publishes must drive retries");
        // Mid-storm invariant: nothing corrupted-but-applied, ever.
        for tier in &fleet.tiers {
            for (fp, _) in tier.live_index() {
                let got = tier.get(fp).expect("live entry readable");
                let bodies = fleet.expected.get(&fp).unwrap_or_else(|| {
                    panic!("fp {fp:016x} appeared out of nowhere mid-storm")
                });
                assert!(bodies.contains(&got), "fp {fp:016x}: corrupted frame applied");
            }
        }
        // Faults lift: the fleet settles to the exact union.
        failpoints().disarm_all();
        for i in 0..N {
            fleet.sync(i);
        }
        assert!(fleet.converged(), "post-storm settling pass must converge");
        fleet.assert_full_union();
        fleet.cleanup();
    });
}

/// The determinism contract: the same fleet seed, the same schedule
/// seed, and the same failpoint seeds replay the storm byte-identically
/// — every per-round report and every final log byte matches.
#[test]
fn storms_replay_byte_identically() {
    with_failpoints(|| {
        let run = |tag: &str| {
            // Re-arming resets each failpoint's serial draw counter, so
            // both runs start from the identical schedule state.
            failpoints().disarm_all();
            failpoints().arm(SYNC_FRAME_CORRUPT, 0.25, 11).unwrap();
            failpoints().arm(SYNC_CONN_DROP, 0.15, 12).unwrap();
            failpoints().arm(SYNC_PARTIAL_WRITE, 0.15, 13).unwrap();
            let fleet = build_fleet(tag, 5);
            let reports = run_storm(&fleet, 31, 16);
            failpoints().disarm_all();
            for i in 0..N {
                fleet.sync(i);
            }
            let logs = fleet.logs();
            assert!(fleet.converged());
            fleet.assert_full_union();
            fleet.cleanup();
            (reports, logs)
        };
        let (reports1, logs1) = run("replay1");
        let (reports2, logs2) = run("replay2");
        assert_eq!(reports1, reports2, "same seeds ⇒ same per-round reports");
        assert_eq!(logs1, logs2, "same seeds ⇒ same final log bytes");
    });
}
