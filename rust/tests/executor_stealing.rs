//! Acceptance tests for the deterministic work-stealing executor
//! (DESIGN.md §9): fixed `(seed, K)` reproduces byte-identical plan
//! JSON across repeated runs — for both a single tree and a 4-way
//! fan-out, whatever the OS makes of the thread interleaving — and
//! stalled trees actually forfeit budget to the leader.

use automap::cost::composite::CostWeights;
use automap::models::mlp::{build_mlp, MlpConfig};
use automap::partir::mesh::Mesh;
use automap::search::env::SearchOptions;
use automap::search::mcts::MctsConfig;
use automap::service::executor::{PlanJob, STALL_ROUNDS};
use automap::session::{ShardingConstraint, Tactic};
use automap::sim::device::Device;

fn job(workers: usize, seed: u64, budget: usize) -> PlanJob {
    PlanJob {
        func: build_mlp(&MlpConfig::small()).func,
        mesh: Mesh::new(&[("batch", 2), ("model", 4)]),
        device: Device::tpu_v3(),
        weights: CostWeights::default(),
        options: SearchOptions::default(),
        pre_tactics: vec![Tactic::Manual {
            constraints: vec![ShardingConstraint::new("x", 0, "batch")],
            manual_axes: vec!["batch".to_string()],
        }],
        budget,
        seed,
        workers,
        mcts: MctsConfig::default(),
        deadline_ms: 0,
    }
}

#[test]
fn byte_identical_plans_across_runs_for_k1_and_k4() {
    for k in [1usize, 4] {
        let j = job(k, 11, 240);
        let a = j.run().unwrap();
        let b = j.run().unwrap();
        assert_eq!(
            a.plan.to_json().to_string(),
            b.plan.to_json().to_string(),
            "K={k}: plan JSON must be byte-identical across runs"
        );
        assert_eq!(a.winner, b.winner, "K={k}");
        assert_eq!(a.worker_costs, b.worker_costs, "K={k}");
        assert_eq!(a.worker_episodes, b.worker_episodes, "K={k}");
        assert_eq!((a.rounds, a.steals), (b.rounds, b.steals), "K={k}");
        assert_eq!(a.worker_episodes.iter().sum::<usize>(), k * 240, "K={k}");
    }
    // A single tree has nobody to steal from.
    assert_eq!(job(1, 11, 240).run().unwrap().steals, 0);
}

#[test]
fn pipelined_plans_are_byte_identical_across_runs_for_k1_and_k4() {
    // The acceptance bar for composing the pipeline tactic with the
    // work-stealing executor: a fixed (seed, K) reproduces the SAME
    // pipelined plan JSON — stage cuts, bubble fraction, send/recv
    // stats and all — run after run.
    let pipelined = |workers: usize| PlanJob {
        func: build_mlp(&MlpConfig::small()).func,
        mesh: Mesh::new(&[("pipe", 2), ("batch", 2), ("model", 4)]),
        device: Device::tpu_v3(),
        weights: CostWeights::default(),
        options: SearchOptions::default(),
        pre_tactics: vec![
            Tactic::Manual {
                constraints: vec![ShardingConstraint::new("x", 0, "batch")],
                manual_axes: vec!["batch".to_string()],
            },
            Tactic::Pipeline { axis: "pipe".to_string(), stages: 2, microbatches: 4 },
        ],
        budget: 120,
        seed: 17,
        workers,
        mcts: MctsConfig::default(),
        deadline_ms: 0,
    };
    for k in [1usize, 4] {
        let j = pipelined(k);
        let a = j.run().unwrap();
        let b = j.run().unwrap();
        let a_json = a.plan.to_json().to_string();
        assert_eq!(
            a_json,
            b.plan.to_json().to_string(),
            "K={k}: pipelined plan JSON must be byte-identical across runs"
        );
        assert_eq!(a.winner, b.winner, "K={k}");
        assert_eq!(a.worker_costs, b.worker_costs, "K={k}");
        assert_eq!(a.worker_episodes, b.worker_episodes, "K={k}");
        // The plan is actually pipelined: schedule terms present and
        // point-to-point transfers priced.
        let pe = a.plan.eval.pipeline.as_ref().expect("plan carries PipelineEval");
        assert_eq!((pe.stages, pe.microbatches), (2, 4), "K={k}");
        assert!(pe.bubble_fraction > 0.0, "K={k}: warm-up/drain bubble");
        assert!(a.plan.eval.collectives.send_count > 0, "K={k}");
        assert_eq!(
            a.plan.eval.collectives.send_count, a.plan.eval.collectives.recv_count,
            "K={k}: every send pairs with a recv"
        );
        assert!(a_json.contains("\"pipeline\""), "K={k}: plan JSON carries the pipeline object");
    }
}

#[test]
fn stalled_trees_forfeit_budget_to_the_leader() {
    // A program whose dims (7, 5) are indivisible by every mesh-axis
    // size offers NO legal tile actions: every tree's root has exactly
    // the InferRest/Stop children, every reward is the baseline 0.0,
    // and UCT alternates the two visits — so each tree's root
    // visit-count entropy pins to ~1.0 from the first barrier onwards.
    // A flat, unmoving temperature is precisely a stall under the
    // tree-temperature detector: after STALL_ROUNDS flat rounds every
    // non-leader (the reward tie makes worker 0 leader) forfeits,
    // deterministically, independent of search stochasticity.
    let budget = 400usize;
    let j = PlanJob {
        func: build_mlp(&MlpConfig { batch: 7, dims: vec![5, 7, 5], training: false }).func,
        mesh: Mesh::new(&[("model", 4)]),
        device: Device::tpu_v3(),
        weights: CostWeights::default(),
        options: SearchOptions::default(),
        pre_tactics: vec![],
        budget,
        seed: 7,
        workers: 4,
        mcts: MctsConfig::default(),
        deadline_ms: 0,
    };
    let r = j.run().unwrap();
    assert_eq!(
        r.worker_episodes.iter().sum::<usize>(),
        r.episodes_total,
        "steals move budget, they never create or drop it"
    );
    assert_eq!(r.episodes_total, 4 * budget);
    assert!(r.rounds > STALL_ROUNDS, "enough rounds to observe stalling: {}", r.rounds);
    assert_eq!(r.steals, 3, "every non-leader tree forfeits exactly once");
    let max = *r.worker_episodes.iter().max().unwrap();
    let min = *r.worker_episodes.iter().min().unwrap();
    assert!(
        max > budget && min < budget,
        "forfeited budget must be re-run by the leader: episodes={:?}",
        r.worker_episodes
    );
    // Forfeiture fires right after the stall threshold: a stalled tree
    // ran exactly (1 first-reading round + STALL_ROUNDS flat rounds)
    // of episodes before handing the rest over (the first temperature
    // reading never counts as a stall — nothing to compare it to).
    let round_size = budget.div_ceil(automap::service::executor::STEAL_ROUNDS);
    assert_eq!(min, (1 + STALL_ROUNDS) * round_size);
    // The reassigned budget still produces the winner by minimum cost.
    let min_cost = r.worker_costs.iter().cloned().fold(f64::INFINITY, f64::min);
    assert_eq!(r.worker_costs[r.winner], min_cost);
}

#[test]
fn entropy_stall_signal_pins_forfeiture_schedule() {
    // Same flat-temperature construction, different shape/mesh/K: dims
    // {5, 7, 11} are indivisible by a 2-way axis, so each tree's root
    // temperature freezes immediately and the entropy detector must
    // forfeit every non-leader exactly once, right after the stall
    // threshold. Pins the schedule arithmetic of the new signal.
    let budget = 200usize;
    let j = PlanJob {
        func: build_mlp(&MlpConfig { batch: 5, dims: vec![7, 11, 7], training: false }).func,
        mesh: Mesh::new(&[("model", 2)]),
        device: Device::tpu_v3(),
        weights: CostWeights::default(),
        options: SearchOptions::default(),
        pre_tactics: vec![],
        budget,
        seed: 13,
        workers: 3,
        mcts: MctsConfig::default(),
        deadline_ms: 0,
    };
    let r = j.run().unwrap();
    let round_size = budget.div_ceil(automap::service::executor::STEAL_ROUNDS);
    assert_eq!(r.steals, 2, "both non-leaders forfeit exactly once");
    assert_eq!(r.worker_episodes.iter().sum::<usize>(), 3 * budget, "budget conserved");
    let min = *r.worker_episodes.iter().min().unwrap();
    assert_eq!(
        min,
        (1 + STALL_ROUNDS) * round_size,
        "forfeiture fires right after STALL_ROUNDS flat-temperature rounds"
    );
    // Reproducible run-to-run, like every other schedule decision.
    let r2 = j.run().unwrap();
    assert_eq!(r.worker_episodes, r2.worker_episodes);
    assert_eq!(r.steals, r2.steals);
}

#[test]
fn stealing_schedule_is_a_function_of_seed_k_budget() {
    // Same (K, budget), different seed: schedules may differ, but each
    // is reproducible; and budget conservation holds for every seed.
    for seed in [1u64, 2, 3] {
        let a = job(4, seed, 160).run().unwrap();
        let b = job(4, seed, 160).run().unwrap();
        assert_eq!(a.worker_episodes, b.worker_episodes, "seed={seed}");
        assert_eq!(a.steals, b.steals, "seed={seed}");
        assert_eq!(a.worker_episodes.iter().sum::<usize>(), 4 * 160, "seed={seed}");
    }
}
