//! PJRT runtime integration: load the AOT ranker artifacts and verify
//! the rust-side execution matches the jax-side numerics recorded by
//! `python/compile/aot.py`. Requires `make artifacts`; tests skip (with a
//! loud message) when artifacts are absent so `cargo test` works on a
//! cold checkout.

use automap::learner::features::{MAX_EDGES, MAX_NODES, NODE_FEATURES};
use automap::runtime::pjrt::{Input, Runtime};
use automap::util::json::parse;

const HLO: &str = "artifacts/ranker.hlo.txt";
const EXAMPLE: &str = "artifacts/ranker_example.json";

fn artifacts_present() -> bool {
    std::path::Path::new(HLO).exists() && std::path::Path::new(EXAMPLE).exists()
}

#[test]
fn ranker_hlo_executes_and_matches_jax_numerics() {
    if !artifacts_present() {
        eprintln!("SKIP: run `make artifacts` to enable PJRT integration tests");
        return;
    }
    let rt = Runtime::new().unwrap();
    let exe = rt.load_hlo_text(HLO).unwrap();

    let ex = parse(&std::fs::read_to_string(EXAMPLE).unwrap()).unwrap();
    let f32s = |k: &str| -> Vec<f32> {
        ex.get(k).unwrap().as_arr().unwrap().iter().map(|x| x.as_f64().unwrap() as f32).collect()
    };
    let i32s = |k: &str| -> Vec<i32> {
        ex.get(k).unwrap().as_arr().unwrap().iter().map(|x| x.as_f64().unwrap() as i32).collect()
    };
    let nodes = f32s("nodes");
    let node_mask = f32s("node_mask");
    let senders = i32s("senders");
    let receivers = i32s("receivers");
    let edge_mask = f32s("edge_mask");
    let expected = f32s("expected_scores");
    assert_eq!(nodes.len(), MAX_NODES * NODE_FEATURES);
    assert_eq!(senders.len(), MAX_EDGES);

    let outs = exe
        .run_f32(&[
            Input::F32(nodes, vec![MAX_NODES as i64, NODE_FEATURES as i64]),
            Input::F32(node_mask.clone(), vec![MAX_NODES as i64]),
            Input::I32(senders, vec![MAX_EDGES as i64]),
            Input::I32(receivers, vec![MAX_EDGES as i64]),
            Input::F32(edge_mask, vec![MAX_EDGES as i64]),
        ])
        .unwrap();
    let scores = &outs[0];
    assert_eq!(scores.len(), MAX_NODES);
    let mut max_err = 0f32;
    for (i, (&got, &want)) in scores.iter().zip(&expected).enumerate() {
        if node_mask[i] > 0.0 {
            max_err = max_err.max((got - want).abs() / (1.0 + want.abs()));
        }
    }
    assert!(
        max_err < 1e-4,
        "rust PJRT execution must match jax numerics (max rel err {max_err})"
    );
    println!("ranker PJRT numerics OK (max rel err {max_err:.2e})");
}

#[test]
fn learned_filter_keeps_megatron_weights_in_topk() {
    if !artifacts_present() {
        eprintln!("SKIP: run `make artifacts` first");
        return;
    }
    use automap::learner::features::featurize;
    use automap::learner::ranker::{top_k_decisions, PjrtRanker, Ranker, TOP_K};
    use automap::models::transformer::{build_transformer, TransformerConfig};
    use automap::partir::mesh::Mesh;
    use automap::partir::program::PartirProgram;

    let model = build_transformer(&TransformerConfig::tiny(2));
    let program = PartirProgram::new(model.func.clone(), Mesh::new(&[("model", 4)]));
    let g = featurize(&program.func, &program.mesh);
    let rt = Runtime::new().unwrap();
    let ranker = PjrtRanker::load(&rt, HLO).unwrap();
    let scores = ranker.score(&g).unwrap();
    let top = top_k_decisions(&model.func, &g, &scores, TOP_K);
    let names: Vec<&str> = top.iter().map(|v| model.func.args[v.index()].name.as_str()).collect();
    // The trained ranker must keep the large layer matrices in the top-k
    // (the property that makes Fig 6's learner curve beat MCTS-only).
    let hits = ["mlp/w1", "mlp/w2", "attn/wq", "attn/wo"]
        .iter()
        .filter(|suf| names.iter().any(|n| n.ends_with(*suf)))
        .count();
    assert!(hits >= 3, "trained ranker lost the Megatron weights: {names:?}");
}
