//! Golden acceptance tests for the pipeline-parallelism subsystem
//! (DESIGN.md §11): the `Pipeline` tactic composed with `Search` must
//! recover a legal 4-stage 1F1B cut with Megatron-style intra-stage
//! sharding on the built-in transformer, the 1F1B simulator must match
//! the closed-form bubble on uniform stages, and pipelined plans must
//! serialise, round-trip, and reproduce byte-identically for a fixed
//! seed.

use automap::cost::composite::{evaluate_pipelined, CostWeights};
use automap::models::transformer::{build_transformer, TransformerConfig};
use automap::partir::dist::DistMap;
use automap::partir::mesh::Mesh;
use automap::partir::program::PartirProgram;
use automap::pipeline::{balanced_cuts, simulate_1f1b, PipelineSpec};
use automap::search::env::SearchOptions;
use automap::session::{PartitionPlan, Session, Tactic};
use automap::sim::device::Device;

#[test]
fn uniform_stage_bubble_matches_the_closed_form() {
    // For K uniform stages and M microbatches with free transfers, the
    // 1F1B bubble fraction is exactly (K-1)/(M+K-1).
    for (k, m) in [(2usize, 4usize), (4, 8), (4, 12), (8, 8), (3, 1)] {
        let stage = vec![1e-3; k];
        let xfer = vec![0.0; k - 1];
        let r = simulate_1f1b(&stage, &xfer, m);
        let expect = (k - 1) as f64 / (m + k - 1) as f64;
        assert!(
            (r.bubble_fraction - expect).abs() < 1e-12,
            "K={k} M={m}: bubble {} != closed form {expect}",
            r.bubble_fraction
        );
        assert!(r.makespan_seconds > 0.0);
    }
}

/// Run the full tactic stack with a 4-stage pipeline on the tiny
/// transformer under memory pressure; returns the plan.
fn pipelined_transformer_plan(budget: usize, seed: u64) -> PartitionPlan {
    let model = build_transformer(&TransformerConfig::tiny(2));
    let mesh = Mesh::new(&[("pipe", 4), ("model", 4)]);
    let w = CostWeights::default();

    // Memory pressure relative to the replicated-but-pipelined
    // baseline: the per-stage peak of the seed cut must overflow, so
    // the search has to shard weights on the model axis to fit.
    let program = PartirProgram::new(model.func.clone(), mesh.clone());
    let dm0 = DistMap::new(&program.func, &program.mesh);
    let spec = PipelineSpec {
        axis: 0,
        microbatches: 8,
        cuts: balanced_cuts(&program.func, 4),
    };
    let probe = evaluate_pipelined(&program, &dm0, &Device::tpu_v3(), &w, Some(&spec));
    let stage_peak = probe.pipeline.as_ref().expect("probe is pipelined").max_stage_peak_bytes;
    let device = Device { hbm_bytes: (stage_peak as f64 * 0.7) as i64, ..Device::tpu_v3() };

    let mut session = Session::with_options(
        model.func.clone(),
        mesh,
        device,
        w,
        SearchOptions::default(),
    );
    let mut tactics = vec![Tactic::pipeline("pipe", 4)];
    tactics.extend(Tactic::default_stack(budget, seed));
    session.run(&tactics).expect("pipelined tactic stack")
}

#[test]
fn pipeline_tactic_recovers_four_balanced_stages_with_megatron_inside() {
    let plan = pipelined_transformer_plan(1500, 3);
    let pe = plan.eval.pipeline.as_ref().expect("plan must carry PipelineEval");

    // A legal 4-stage cut: three strictly increasing boundaries, every
    // stage non-empty, priced through the 1F1B simulator.
    assert_eq!(pe.stages, 4);
    assert_eq!(pe.microbatches, 8);
    assert_eq!(pe.cuts.len(), 3);
    assert!(pe.cuts.windows(2).all(|w| w[0] < w[1]), "cuts must increase: {:?}", pe.cuts);
    assert!(pe.cuts[0] > 0, "first stage must be non-empty");
    assert!(pe.bubble_fraction > 0.0 && pe.bubble_fraction < 1.0, "{}", pe.bubble_fraction);
    assert!(pe.makespan_seconds > 0.0);
    assert!(pe.send_recv_seconds > 0.0, "stage boundaries must price transfers");
    assert!(pe.max_stage_peak_bytes > 0);

    // Nonzero point-to-point traffic, symmetric by construction.
    let c = &plan.eval.collectives;
    assert!(c.send_count > 0, "{c:?}");
    assert_eq!(c.send_count, c.recv_count, "{c:?}");
    assert_eq!(c.send_bytes, c.recv_bytes, "{c:?}");

    // Megatron-style intra-stage sharding: under stage-peak memory
    // pressure the search must tile layer weights on the model axis.
    assert!(
        plan.input_specs
            .iter()
            .filter(|s| s.name.contains("/w") || s.name.contains("/attn/"))
            .any(|s| s.tiled_on("model")),
        "expected model-axis shardings on layer weights: {:?}",
        plan.input_specs.iter().filter(|s| !s.replicated()).collect::<Vec<_>>()
    );
    // The pipeline axis is reserved for stages, never for tiling.
    assert!(
        plan.input_specs.iter().all(|s| !s.tiled_on("pipe")),
        "the pipeline axis must stay out of the tile search"
    );

    // The trace records the tactic and the schedule summary.
    assert!(plan.trace.iter().any(|t| t.starts_with("pipeline:")), "{:?}", plan.trace);
    assert!(plan.trace.iter().any(|t| t.contains("1F1B")), "{:?}", plan.trace);

    // The plan serialises and round-trips through util::json with the
    // pipeline object intact.
    let text = plan.to_json().pretty();
    let back = PartitionPlan::from_json(&automap::util::json::parse(&text).unwrap()).unwrap();
    assert_eq!(back.eval.pipeline, plan.eval.pipeline);
    assert_eq!(back.eval.collectives, plan.eval.collectives);
    assert_eq!(back.input_specs, plan.input_specs);
}

#[test]
fn pipelined_plans_reproduce_byte_identically_for_a_fixed_seed() {
    let a = pipelined_transformer_plan(300, 11);
    let b = pipelined_transformer_plan(300, 11);
    let (mut ja, mut jb) = (a.to_json(), b.to_json());
    // Wall time is the only legitimately nondeterministic field.
    for j in [&mut ja, &mut jb] {
        if let automap::util::json::Json::Obj(m) = j {
            m.remove("wall_seconds");
        }
    }
    assert_eq!(
        ja.to_string(),
        jb.to_string(),
        "fixed (seed, K) must reproduce the pipelined plan byte-identically"
    );
}
