//! Acceptance wall for the incremental cost ledger (DESIGN.md §8):
//! ledger evaluation must be BIT-identical to the full
//! lower + liveness + roofline pipeline — over randomized episodes on
//! every committed golden-corpus program and every built-in model, with
//! auto-infer-rest both on and off — and a ledger maintained across a
//! whole episode must hold exactly the state of one rebuilt from
//! scratch on the final map (no drift, ever).
//!
//! In debug builds `RewriteEnv` additionally self-checks every ledger
//! evaluation against the full pipeline, so this file drives both the
//! external and the internal equivalence.

use automap::cost::composite::{CostLedger, CostWeights};
use automap::ir::parse_func;
use automap::partir::mesh::Mesh;
use automap::partir::program::PartirProgram;
use automap::pipeline::{balanced_cuts, PipelineSpec};
use automap::search::env::{EnvAction, RewriteEnv, SearchOptions};
use automap::search::mcts::{search, MctsConfig};
use automap::sim::device::Device;
use automap::util::rng::Rng;

/// Every program the wall runs over: the committed golden corpus plus
/// the three built-in models, each paired with a 2-axis mesh.
fn wall_programs() -> Vec<(String, PartirProgram)> {
    let mut out = Vec::new();
    let dir = concat!(env!("CARGO_MANIFEST_DIR"), "/../configs/corpus");
    let mut paths: Vec<_> = std::fs::read_dir(dir)
        .expect("corpus dir")
        .map(|e| e.unwrap().path())
        .filter(|p| p.extension().map(|e| e == "pir").unwrap_or(false))
        .collect();
    paths.sort();
    for path in paths {
        let text = std::fs::read_to_string(&path).unwrap();
        let f = parse_func(&text).unwrap_or_else(|e| panic!("{}: {e}", path.display()));
        let name = path.file_name().unwrap().to_string_lossy().into_owned();
        out.push((name, PartirProgram::new(f, Mesh::new(&[("batch", 2), ("model", 4)]))));
    }
    for model in ["mlp", "transformer", "graphnet"] {
        let f = automap::models::build_by_name(model, 2).expect("builtin model");
        out.push((
            model.to_string(),
            PartirProgram::new(f, Mesh::new(&[("batch", 2), ("model", 4)])),
        ));
    }
    out
}

fn assert_bit_identical(
    name: &str,
    inc: &automap::cost::composite::Evaluation,
    full: &automap::cost::composite::Evaluation,
) {
    assert_eq!(inc, full, "{name}: ledger evaluation diverged from the full pipeline");
    assert_eq!(
        inc.cost.to_bits(),
        full.cost.to_bits(),
        "{name}: cost must match the full pipeline to the bit"
    );
    assert_eq!(
        inc.runtime.collective_seconds.to_bits(),
        full.runtime.collective_seconds.to_bits(),
        "{name}: collective seconds must match to the bit"
    );
    assert_eq!(
        inc.runtime.op_seconds.to_bits(),
        full.runtime.op_seconds.to_bits(),
        "{name}: op seconds must match to the bit"
    );
}

#[test]
fn randomized_ledger_vs_full_evaluate_over_corpus_and_models() {
    let mut checked = 0usize;
    for (name, program) in wall_programs() {
        let wl = RewriteEnv::default_worklist(&program);
        if wl.is_empty() {
            continue; // zero-arg corpus program: no decision targets
        }
        for auto_infer in [true, false] {
            let env = RewriteEnv::new(
                &program,
                Device::tpu_v3(),
                CostWeights::default(),
                SearchOptions {
                    cross_layer_tying: false,
                    auto_infer_rest: auto_infer,
                    ..Default::default()
                },
                &wl,
            );
            let mut rng = Rng::new(0xBEEF + wl.len() as u64);
            for _attempt in 0..6 {
                let mut ep = env.reset();
                for _ in 0..5 {
                    let acts = env.legal_actions(&ep);
                    if acts.is_empty() {
                        break;
                    }
                    let a = *rng.choose(&acts);
                    env.step(&mut ep, a);
                    // Evaluate mid-episode too: the ledger must track
                    // arbitrary maps, not just terminal ones.
                    let inc = env.evaluate_episode_ledger(&mut ep);
                    let full = env.evaluate_episode(&ep);
                    assert_bit_identical(&name, &inc, &full);
                    checked += 1;
                    if ep.done {
                        break;
                    }
                }
            }
        }
    }
    assert!(checked > 50, "wall must exercise plenty of evaluations: {checked}");
}

#[test]
fn pipelined_ledger_vs_full_evaluate_stays_bit_identical() {
    // Same wall as above, but with a 2-stage pipeline context: ledger
    // answers must stay bit-identical when the schedule simulator and
    // send/recv terms sit on top of the per-node terms, and cut moves
    // must be part of the randomized action stream.
    let mut checked = 0usize;
    for (name, program) in wall_programs() {
        let wl = RewriteEnv::default_worklist(&program);
        if wl.is_empty() || program.func.num_nodes() < 2 {
            continue;
        }
        let mut env = RewriteEnv::new(
            &program,
            Device::tpu_v3(),
            CostWeights::default(),
            SearchOptions { cross_layer_tying: false, ..Default::default() },
            &wl,
        );
        env.set_pipeline(PipelineSpec {
            axis: 0,
            microbatches: 4,
            cuts: balanced_cuts(&program.func, 2),
        });
        let env = env;
        let mut rng = Rng::new(0xF1F1 + wl.len() as u64);
        for _attempt in 0..4 {
            let mut ep = env.reset();
            for _ in 0..6 {
                let acts = env.legal_actions(&ep);
                if acts.is_empty() {
                    break;
                }
                let a = *rng.choose(&acts);
                env.step(&mut ep, a);
                let inc = env.evaluate_episode_ledger(&mut ep);
                let full = env.evaluate_episode(&ep);
                assert_bit_identical(&name, &inc, &full);
                let pe = inc.pipeline.as_ref().unwrap_or_else(|| {
                    panic!("{name}: pipelined evaluation must carry PipelineEval")
                });
                assert_eq!(pe.stages, 2, "{name}");
                assert_eq!(
                    pe.bubble_fraction.to_bits(),
                    full.pipeline.as_ref().unwrap().bubble_fraction.to_bits(),
                    "{name}: bubble fraction must match to the bit"
                );
                checked += 1;
                if ep.done {
                    break;
                }
            }
        }
    }
    assert!(checked > 30, "pipelined wall must exercise plenty of evaluations: {checked}");
}

#[test]
fn ledger_maintained_across_an_episode_matches_a_scratch_rebuild() {
    for (name, program) in wall_programs() {
        let wl = RewriteEnv::default_worklist(&program);
        if wl.is_empty() {
            continue;
        }
        let env = RewriteEnv::new(
            &program,
            Device::tpu_v3(),
            CostWeights::default(),
            SearchOptions { cross_layer_tying: false, ..Default::default() },
            &wl,
        );
        let mut rng = Rng::new(0xC0FFEE);
        let mut ep = env.reset();
        // A full episode with an evaluation after every action keeps
        // the ledger hopping between inferred maps.
        for _ in 0..8 {
            let acts = env.legal_actions(&ep);
            if acts.is_empty() {
                break;
            }
            let a = *rng.choose(&acts);
            env.step(&mut ep, a);
            let _ = env.evaluate_episode_ledger(&mut ep);
            if ep.done {
                break;
            }
        }
        // Corruption check: rebuild a fresh ledger on the exact map the
        // maintained one last evaluated; every cached term (float bits
        // included) and the liveness state must be identical.
        let maintained = ep.ledger.take().expect("episode carries the ledger");
        let mut probe = ep.dm.clone();
        if env.options.auto_infer_rest {
            let mut stats = automap::partir::propagate::PropStats::default();
            program.prop.infer_rest(&program.func, &program.mesh, &mut probe, &mut stats);
        }
        let fresh = CostLedger::new(&program, &probe, Device::tpu_v3(), CostWeights::default());
        assert_eq!(
            maintained.terms_digest(),
            fresh.terms_digest(),
            "{name}: maintained ledger drifted from a scratch rebuild"
        );
    }
}

#[test]
fn search_results_replay_to_the_same_cost_through_the_full_pipeline() {
    // The ledger sits inside the episode loop, so pin end-to-end that a
    // search's reported best evaluation equals replaying its decision
    // state through the untouched full pipeline — i.e. the ledger
    // changed nothing about what the search reports.
    let f = automap::models::build_by_name("mlp", 2).unwrap();
    let program = PartirProgram::new(f, Mesh::new(&[("model", 4)]));
    let wl = RewriteEnv::default_worklist(&program);
    let env = RewriteEnv::new(
        &program,
        Device::tpu_v3(),
        CostWeights::default(),
        SearchOptions::default(),
        &wl,
    );
    let res = search(&env, 120, 9, MctsConfig::default());
    let (mut dm, mut stats) = program.apply(&res.best_state);
    program.prop.infer_rest(&program.func, &program.mesh, &mut dm, &mut stats);
    let replayed = automap::cost::composite::evaluate(
        &program,
        &dm,
        &Device::tpu_v3(),
        &CostWeights::default(),
    );
    assert_eq!(res.best_eval, replayed);
    assert_eq!(res.best_eval.cost.to_bits(), replayed.cost.to_bits());
    // And the ledger was actually in play.
    assert!(res.ledger_refreshes > 0);
    assert_eq!(res.ledger_refreshes, res.eval_lookups - res.eval_memo_hits);
}

#[test]
fn ledger_answers_memo_misses_without_changing_memo_semantics() {
    let f = automap::models::build_by_name("transformer", 1).unwrap();
    let program = PartirProgram::new(f, Mesh::new(&[("model", 4)]));
    let wl = RewriteEnv::default_worklist(&program);
    let env = RewriteEnv::new(
        &program,
        Device::tpu_v3(),
        CostWeights::default(),
        SearchOptions::default(),
        &wl,
    );
    let mut memo = automap::search::env::EvalMemo::new();
    let mut ep = env.reset();
    env.attach_ledger(&mut ep);
    env.step(&mut ep, EnvAction::Stop);
    let miss = env.evaluate_episode_memo(&mut ep, &mut memo);
    assert_eq!(memo.lookups, 1);
    assert_eq!(memo.hits, 0);
    let lr = ep.ledger.as_ref().unwrap().refreshes;
    assert_eq!(lr, 1, "the miss must be answered by one ledger refresh");
    // A repeat of the same terminal state hits the memo: the ledger is
    // the second tier, never consulted on a hit.
    let hit = env.evaluate_episode_memo(&mut ep, &mut memo);
    assert_eq!(memo.hits, 1);
    assert_eq!(ep.ledger.as_ref().unwrap().refreshes, 1);
    assert_eq!(miss, hit);
    // And both equal the reference pipeline, to the bit.
    let full = env.evaluate_episode(&ep);
    assert_bit_identical("memo-tier", &miss, &full);
}
