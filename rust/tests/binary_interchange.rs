//! The binary-interchange wall (tier-1 twin of the CI `binary-corpus`
//! step): `decode(encode(x)) == x` exactly for every corpus program,
//! every built-in model, and partition plans with and without pipeline
//! state; committed `.pbp` goldens must match the live encoder byte for
//! byte; version/magic/kind skew must fail with a named diagnostic; and
//! corrupt bytes must error, never panic (DESIGN.md §13).

use automap::cost::composite::{Evaluation, PipelineEval};
use automap::cost::liveness::MemoryEstimate;
use automap::ir::{binary, parse_func, print_func};
use automap::service::func_fingerprint;
use automap::session::{PartitionPlan, ShardSpec};
use automap::sim::exec::RuntimeEstimate;
use automap::spmd::collectives::CollectiveStats;
use automap::util::json::parse;

fn corpus_dir() -> std::path::PathBuf {
    std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("../configs/corpus")
}

fn corpus_files() -> Vec<std::path::PathBuf> {
    let dir = corpus_dir();
    let mut files: Vec<std::path::PathBuf> = std::fs::read_dir(&dir)
        .unwrap_or_else(|e| panic!("corpus dir {}: {e}", dir.display()))
        .map(|e| e.expect("corpus entry").path())
        .filter(|p| p.extension().is_some_and(|x| x == "pir"))
        .collect();
    files.sort();
    assert!(files.len() >= 5, "corpus must not shrink (found {} files)", files.len());
    files
}

#[test]
fn every_corpus_program_round_trips_through_binary() {
    for p in corpus_files() {
        let text = std::fs::read_to_string(&p).expect("corpus file readable");
        let f = parse_func(&text).unwrap_or_else(|e| panic!("{}: {e}", p.display()));
        let bytes = binary::encode_program(&f);
        let g = binary::decode_program(&bytes)
            .unwrap_or_else(|e| panic!("{}: {e}", p.display()));
        assert_eq!(g, f, "{}: decode(encode(f)) != f", p.display());
        // The fingerprint is computed over the decoded structure, so
        // binary and textual spellings share a cache line.
        assert_eq!(func_fingerprint(&g), func_fingerprint(&f), "{}", p.display());
        // Encoding is deterministic (goldens are byte-stable).
        assert_eq!(binary::encode_program(&g), bytes, "{}", p.display());
    }
}

#[test]
fn committed_goldens_match_the_live_encoder_byte_for_byte() {
    // Every corpus program ships with a committed `.pbp` golden; a
    // codec change that redefines the byte format must bump the format
    // version and regenerate them, never silently drift.
    for p in corpus_files() {
        let golden = p.with_extension("pbp");
        let want = std::fs::read(&golden)
            .unwrap_or_else(|e| panic!("missing golden {}: {e}", golden.display()));
        let text = std::fs::read_to_string(&p).expect("corpus file readable");
        let f = parse_func(&text).unwrap_or_else(|e| panic!("{}: {e}", p.display()));
        assert_eq!(
            binary::encode_program(&f),
            want,
            "{}: encoder output drifted from the committed golden",
            golden.display()
        );
    }
}

#[test]
fn built_in_models_round_trip_through_binary() {
    for model in ["mlp", "transformer", "graphnet"] {
        let f = automap::models::build_by_name(model, 2).expect("built-in model");
        let bytes = binary::encode_program(&f);
        let g = binary::decode_program(&bytes).unwrap_or_else(|e| panic!("{model}: {e}"));
        assert_eq!(g, f, "{model}: decode(encode(f)) != f");
        assert_eq!(func_fingerprint(&g), func_fingerprint(&f));
    }
}

fn sample_plan(pipeline: bool) -> PartitionPlan {
    PartitionPlan {
        mesh_axes: vec![("batch".into(), 2), ("model".into(), 4)],
        input_specs: vec![
            ShardSpec { name: "tokens".into(), tilings: vec![("batch".into(), 0)] },
            ShardSpec { name: "mask".into(), tilings: vec![] },
        ],
        output_specs: vec![ShardSpec {
            name: "output_0".into(),
            tilings: vec![("batch".into(), 0), ("model".into(), 1)],
        }],
        eval: Evaluation {
            memory: MemoryEstimate { peak_bytes: 123456789, arg_bytes: 1024, peak_node: 17 },
            runtime: RuntimeEstimate {
                compute_seconds: 0.001,
                memory_seconds: 0.0025,
                op_seconds: 0.0025,
                collective_seconds: 0.0005,
                total_flops: 1.5e9,
            },
            collectives: CollectiveStats {
                all_reduce_count: 8,
                all_reduce_bytes: 4096,
                all_gather_count: 1,
                all_gather_bytes: 512,
                send_count: 16,
                send_bytes: 2048,
                recv_count: 16,
                recv_bytes: 2048,
            },
            fits_memory: true,
            cost: 0.0030000001,
            pipeline: pipeline.then(|| PipelineEval {
                stages: 4,
                microbatches: 8,
                cuts: vec![3, 7, 11],
                bubble_fraction: 0.2727272727,
                makespan_seconds: 0.0041,
                send_recv_seconds: 0.0002,
                max_stage_peak_bytes: 98765432,
            }),
        },
        decisions: 7,
        episodes_to_best: 42,
        worklist_size: 25,
        targets: 23,
        wall_seconds: 1.25,
        trace: vec![
            "manual: axis \"batch\" excluded from search".into(),
            "search: tile w1 dim 1 on \"model\"".into(),
        ],
    }
}

#[test]
fn plans_round_trip_through_binary_exactly() {
    for pipelined in [false, true] {
        let plan = sample_plan(pipelined);
        let bytes = binary::encode_plan(&plan);
        let back = binary::decode_plan(&bytes).expect("plan decodes");
        // PartitionPlan carries f64s and no PartialEq; its serialised
        // JSON is the canonical byte-exact spelling of the value.
        assert_eq!(back.to_json().to_string(), plan.to_json().to_string());
        assert_eq!(binary::encode_plan(&back), bytes, "re-encode is deterministic");
    }
}

#[test]
fn a_searched_plan_survives_binary_interchange() {
    // Not a synthetic fixture: run a real (tiny) search and push its
    // plan through the binary form.
    let req = automap::service::PartitionRequest {
        id: "bin".into(),
        model: "mlp".into(),
        mesh: "batch=2,model=4".into(),
        budget: 40,
        ..Default::default()
    };
    let svc = automap::service::PlanService::new(automap::service::ServiceConfig::default());
    let resp = svc.handle(&req);
    let plan_json = resp.plan_json.expect("search succeeded");
    let plan = PartitionPlan::from_json(&parse(&plan_json).unwrap()).unwrap();
    let back = binary::decode_plan(&binary::encode_plan(&plan)).unwrap();
    assert_eq!(back.to_json().to_string(), plan.to_json().to_string());
}

#[test]
fn version_magic_and_kind_skew_fail_with_named_diagnostics() {
    let f = automap::models::build_by_name("mlp", 2).unwrap();
    let good = binary::encode_program(&f);

    let mut wrong_version = good.clone();
    wrong_version[4] = 9; // format_version lives at offset 4 (LE u16)
    let e = binary::decode_program(&wrong_version).unwrap_err().to_string();
    assert!(e.contains("version 9"), "diagnostic must name the found version: {e}");
    assert!(e.contains("version 1"), "diagnostic must name the supported version: {e}");

    let mut wrong_magic = good.clone();
    wrong_magic[0] = b'X';
    let e = binary::decode_program(&wrong_magic).unwrap_err().to_string();
    assert!(e.contains("PLSB"), "diagnostic must name the expected magic: {e}");

    // A program blob is not a plan blob: kind confusion is an error,
    // not a misparse.
    let e = binary::decode_plan(&good).unwrap_err().to_string();
    assert!(e.contains("program") && e.contains("plan"), "kind diagnostic: {e}");

    // Pretty-printed textual IR is obviously not pallas-bin.
    let e = binary::decode_program(print_func(&f).as_bytes()).unwrap_err().to_string();
    assert!(e.contains("magic") || e.contains("truncated"), "{e}");
}

#[test]
fn corrupt_binary_errors_cleanly_never_panics() {
    let text = std::fs::read_to_string(corpus_dir().join("all_ops.pir")).unwrap();
    let bytes = binary::encode_program(&parse_func(&text).unwrap());
    // Every truncation point is either an error or (trivially, the
    // full length) the original — never a panic, never a wrong accept.
    for len in 0..bytes.len() {
        assert!(binary::decode_program(&bytes[..len]).is_err(), "truncation at {len}");
    }
    // Bit flips anywhere in the blob are detected (the payload hash
    // covers the body; explicit checks cover the header).
    for i in (0..bytes.len()).step_by(7) {
        for bit in 0..8 {
            let mut c = bytes.clone();
            c[i] ^= 1 << bit;
            assert!(binary::decode_program(&c).is_err(), "flip byte {i} bit {bit}");
        }
    }
}

#[test]
fn pre_binary_plan_json_still_parses() {
    // A plan document serialised before pallas-bin existed (and before
    // the pipeline subsystem): the JSON schema is pinned — adding the
    // binary interchange must not invalidate old cached/shipped plans.
    let legacy = r#"{
      "mesh": [{"axis": "model", "size": 4}],
      "inputs": [{"name": "x", "tilings": []},
                 {"name": "w", "tilings": [{"axis": "model", "dim": 1}]}],
      "outputs": [{"name": "output_0", "tilings": []}],
      "eval": {"peak_memory_bytes": 4096, "arg_bytes": 512, "peak_node": 3,
               "fits_memory": true, "cost": 0.25,
               "all_reduces": 2, "all_reduce_bytes": 256,
               "all_gathers": 1, "all_gather_bytes": 128,
               "compute_seconds": 0.001, "memory_seconds": 0.002,
               "op_seconds": 0.002, "collective_seconds": 0.0001,
               "total_flops": 1000000.0},
      "decisions": 2, "episodes_to_best": 5, "worklist_size": 4,
      "targets": 4, "wall_seconds": 0.0,
      "trace": ["search: tile w dim 1 on \"model\""]
    }"#;
    let plan = PartitionPlan::from_json(&parse(legacy).unwrap()).unwrap();
    assert_eq!(plan.mesh_axes, vec![("model".to_string(), 4)]);
    assert!(plan.eval.pipeline.is_none());
    assert_eq!(plan.eval.collectives.send_count, 0, "lenient pre-pipeline default");
    // And the legacy plan is encodable going forward.
    let back = binary::decode_plan(&binary::encode_plan(&plan)).unwrap();
    assert_eq!(back.to_json().to_string(), plan.to_json().to_string());
}
