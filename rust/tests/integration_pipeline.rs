//! Integration tests across modules: model zoo -> PartIR -> SPMD -> cost
//! -> search -> coordinator, without AOT artifacts.

use automap::coordinator::automap::{Automap, AutomapOptions, Filter};
use automap::cost::composite::{evaluate, CostWeights};
use automap::models::megatron;
use automap::models::mlp::{build_mlp, MlpConfig};
use automap::models::transformer::{build_transformer, TransformerConfig};
use automap::partir::dist::DistMap;
use automap::partir::mesh::{AxisId, Mesh};
use automap::partir::program::PartirProgram;
use automap::search::env::{RewriteEnv, SearchOptions};
use automap::search::experiment::pressured_device;
use automap::search::mcts::{search, MctsConfig};
use automap::sim::device::Device;
use automap::spmd::lower::lower;
use automap::spmd::printer::print_spmd;

#[test]
fn megatron_reference_scales_linearly_with_depth() {
    let w = CostWeights::default();
    let mut prev = None;
    for layers in [1usize, 2, 4] {
        let model = build_transformer(&TransformerConfig::tiny(layers));
        let program = PartirProgram::new(model.func.clone(), Mesh::new(&[("model", 4)]));
        let e = megatron::reference_evaluation(&program, &model, AxisId(0), &Device::tpu_v3(), &w);
        assert_eq!(e.collectives.all_gather_count, 0, "layers={layers}");
        if let Some((pl, pc)) = prev {
            let per_layer = (e.collectives.all_reduce_count - pc) / (layers - pl);
            // constant per-layer all-reduce count (fwd+bwd)
            assert!(per_layer >= 2 && per_layer <= 8, "per_layer={per_layer}");
        }
        prev = Some((layers, e.collectives.all_reduce_count));
    }
}

#[test]
fn spmd_printer_round_trips_megatron_sharding() {
    let model = build_transformer(&TransformerConfig::tiny(1));
    let program = PartirProgram::new(model.func.clone(), Mesh::new(&[("model", 4)]));
    let st = megatron::reference_state(&model, AxisId(0));
    let (dm, _) = program.apply(&st);
    let sp = lower(&program.func, &program.mesh, &program.prop, &dm);
    let txt = print_spmd(&sp);
    assert!(txt.contains("spmd.all_reduce \"model\""));
    assert!(txt.contains("{\"model\"}"), "distributed types must be rendered");
    assert!(!txt.contains("spmd.all_gather"), "Megatron has no gathers");
}

#[test]
fn automap_partition_transformer_finds_fitting_solution() {
    let model = build_transformer(&TransformerConfig::tiny(2));
    let mesh = Mesh::new(&[("model", 4)]);
    let program = PartirProgram::new(model.func.clone(), mesh.clone());
    let w = CostWeights::default();
    let probe = megatron::reference_evaluation(&program, &model, AxisId(0), &Device::tpu_v3(), &w);
    let device = pressured_device(&probe);
    let opts = AutomapOptions {
        device,
        budget: 800,
        seed: 9,
        filter: Filter::Heuristic,
        ..Default::default()
    };
    let am = Automap::new(model.func.clone(), mesh, opts);
    let report = am.partition().unwrap();
    assert!(report.eval.fits_memory);
    assert!(report.decisions >= 2 && report.decisions <= 20, "paper: 2-20 decisions");
    // Sharded params must include at least one attention or MLP matrix.
    assert!(report
        .input_specs
        .iter()
        .any(|s| !s.tilings.is_empty() && (s.name.contains("/w") || s.name.contains("embed"))));
}

#[test]
fn multi_axis_batch_plus_model_composes() {
    // batch axis manual (user-managed data parallelism), model searched —
    // the paper's Figure 5 workflow.
    let m = build_mlp(&MlpConfig { batch: 8, dims: vec![64, 256, 256, 16], training: true });
    let mesh = Mesh::new(&[("batch", 2), ("model", 4)]).manual("batch");
    let program = PartirProgram::new(m.func.clone(), mesh.clone());
    // manually batch-shard the inputs (dim 0), as a pmap user would
    let mut dm = DistMap::new(&program.func, &program.mesh);
    let batch_ax = program.mesh.axis_by_name("batch").unwrap();
    dm.set(0, batch_ax, 0); // x
    dm.set(1, batch_ax, 0); // target
    let mut stats = automap::partir::propagate::PropStats::default();
    program.prop.forward(&program.func, &program.mesh, &mut dm, &mut stats);
    let e = evaluate(&program, &dm, &Device::tpu_v3(), &CostWeights::default());
    // data parallelism alone: grads all-reduced over batch
    assert!(e.collectives.all_reduce_count > 0);

    // now let automap add model parallelism on top
    let opts = AutomapOptions { budget: 300, seed: 4, ..Default::default() };
    let am = Automap::new(m.func, mesh, opts);
    let report = am.partition().unwrap();
    for s in &report.input_specs {
        for (ax, _) in &s.tilings {
            assert_ne!(ax, "batch");
        }
    }
}

#[test]
fn search_beats_random_rollouts_at_equal_budget() {
    let model = build_transformer(&TransformerConfig::tiny(2));
    let program = PartirProgram::new(model.func.clone(), Mesh::new(&[("model", 4)]));
    let w = CostWeights::default();
    let probe = megatron::reference_evaluation(&program, &model, AxisId(0), &Device::tpu_v3(), &w);
    let device = pressured_device(&probe);
    let wl = RewriteEnv::default_worklist(&program);
    let env = RewriteEnv::new(&program, device, w, SearchOptions::default(), &wl);
    // "random" = MCTS with pure exploration and no tree reuse benefit;
    // approximate with exploration >> reward scale at tiny budget.
    let uct = search(&env, 400, 5, MctsConfig::default());
    let random = search(&env, 400, 5, MctsConfig { exploration: 1e9, rollout_stop_prob: 0.2 });
    assert!(uct.best_reward >= random.best_reward * 0.999);
}

#[test]
fn atomic_decision_keeps_value_replicated_through_search() {
    use automap::partir::actions::{Action, AtomicSet, DecisionState};
    let model = build_transformer(&TransformerConfig::tiny(1));
    let program = PartirProgram::new(model.func.clone(), Mesh::new(&[("model", 4)]));
    let wq = model.layers[0].wq;
    let st = DecisionState {
        actions: vec![
            Action::Atomic { v: wq },
            Action::Tile { v: wq, dim: 1, axis: AxisId(0) }, // must be ignored
            Action::InferRest,
        ],
        atomic: AtomicSet::from(&[wq][..]),
    };
    let (dm, _) = program.apply(&st);
    assert!(!dm.is_tiled(wq.index()), "atomic value must stay replicated");
}
