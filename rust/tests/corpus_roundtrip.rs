//! The golden-corpus wall (tier-1 twin of the CI `corpus-roundtrip`
//! step): every `configs/corpus/*.pir` must parse, verify, and satisfy
//! `parse(print(parse(text))) == parse(text)`, and the corpus as a whole
//! must exercise every op kind — so any grammar or printer change that
//! breaks the public textual format fails here before it ships.

use automap::ir::{parse_func, print_func, OpKind};

fn corpus_dir() -> std::path::PathBuf {
    std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("../configs/corpus")
}

#[test]
fn every_corpus_file_parses_verifies_and_round_trips() {
    let dir = corpus_dir();
    let mut files: Vec<std::path::PathBuf> = std::fs::read_dir(&dir)
        .unwrap_or_else(|e| panic!("corpus dir {}: {e}", dir.display()))
        .map(|e| e.expect("corpus entry").path())
        .filter(|p| p.extension().is_some_and(|x| x == "pir"))
        .collect();
    files.sort();
    assert!(files.len() >= 5, "corpus must not shrink (found {} files)", files.len());

    let mut seen = vec![false; OpKind::NUM_KINDS];
    for p in &files {
        let text = std::fs::read_to_string(p).expect("corpus file readable");
        let f = parse_func(&text).unwrap_or_else(|e| panic!("{}: {e}", p.display()));
        let g = parse_func(&print_func(&f))
            .unwrap_or_else(|e| panic!("{}: printed form failed to re-parse: {e}", p.display()));
        assert_eq!(g, f, "{}: round-trip mismatch", p.display());
        for n in &f.nodes {
            seen[n.op.kind_id()] = true;
        }
    }
    let missing: Vec<usize> = (0..OpKind::NUM_KINDS).filter(|&k| !seen[k]).collect();
    assert!(missing.is_empty(), "corpus must exercise every op kind; missing ids {missing:?}");
}

#[test]
fn corpus_covers_the_edge_cases_the_grammar_promises() {
    let read = |name: &str| {
        let p = corpus_dir().join(name);
        let text = std::fs::read_to_string(&p).unwrap_or_else(|e| panic!("{name}: {e}"));
        parse_func(&text).unwrap_or_else(|e| panic!("{name}: {e}"))
    };
    let zero = read("zero_arg.pir");
    assert_eq!(zero.num_args(), 0);
    assert_eq!(zero.outputs.len(), 2);

    let scoped = read("scoped.pir");
    assert_eq!(scoped.scope_path(scoped.args[1].scope), "enc/dense_0");
    let last = scoped.nodes.last().expect("nodes");
    assert_eq!(scoped.scope_path(last.scope), "enc/act");

    let scalars = read("scalars.pir");
    assert_eq!(scalars.args[0].ty.rank(), 0, "scalar tensor<f32> arg");
    assert_eq!(scalars.args[2].name, "adam.m");
}
