//! Chaos acceptance for the fault-tolerant serving layer (DESIGN.md
//! §14): deterministic fault injection via `util::failpoints` drives
//! worker panics, disk faults, and slow search rounds through the full
//! service stack, and the suite pins the headline guarantees — every
//! request is answered (degraded, never dropped), degraded plans are
//! never cached, and a fault schedule is an exact function of its seed
//! (the same storm replays byte-identically).
//!
//! Every test arms the PROCESS-GLOBAL failpoint registry, so the suite
//! serializes through one mutex and disarms around each body; no other
//! test in this binary can observe the injected faults.

use automap::service::{
    run_batch, serve_jsonl, DiskTier, JobDefaults, PartitionRequest, PlanService, ServiceConfig,
};
use automap::util::failpoints::{
    failpoints, DISK_READ_ERR, DISK_WRITE_ERR, SEARCH_SLOW_ROUND, WORKER_PANIC,
};
use std::sync::Mutex;

static FP_LOCK: Mutex<()> = Mutex::new(());

struct Disarm;

impl Drop for Disarm {
    fn drop(&mut self) {
        failpoints().disarm_all();
    }
}

/// Run `body` with exclusive ownership of the global failpoint
/// registry, disarmed on entry and (via the drop guard) on any exit.
/// `_disarm` is declared after `_guard` so it drops FIRST: the
/// registry is always clean before the mutex is released to the next
/// test.
fn with_failpoints<T>(body: impl FnOnce() -> T) -> T {
    let _guard = FP_LOCK.lock().unwrap_or_else(|e| e.into_inner());
    failpoints().disarm_all();
    let _disarm = Disarm;
    body()
}

fn temp_cache_dir(name: &str) -> std::path::PathBuf {
    let dir = std::env::temp_dir().join(format!("automap-chaos-{}-{name}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

fn req(id: &str, seed: u64) -> PartitionRequest {
    PartitionRequest {
        id: id.to_string(),
        model: "mlp".to_string(),
        mesh: "batch=2,model=2".to_string(),
        budget: 60,
        seed,
        workers: 4,
        ..Default::default()
    }
}

/// In-code mirror of configs/service_smoke.jsonl (model variety, a
/// constrained request, and a pipelined one), usable regardless of the
/// test working directory.
fn smoke_corpus() -> Vec<PartitionRequest> {
    vec![
        PartitionRequest {
            pin: vec!["batch".to_string()],
            shard: vec!["x:0:batch".to_string()],
            ..req("smoke-mlp", 7)
        },
        PartitionRequest {
            model: "transformer".to_string(),
            layers: 2,
            mesh: "model=4".to_string(),
            budget: 80,
            ..req("smoke-transformer", 3)
        },
        PartitionRequest {
            model: "graphnet".to_string(),
            mesh: "model=2".to_string(),
            budget: 40,
            ..req("smoke-graphnet", 5)
        },
        PartitionRequest {
            model: "transformer".to_string(),
            layers: 2,
            mesh: "model=2".to_string(),
            pipeline: "stages=2,microbatches=4".to_string(),
            budget: 40,
            ..req("smoke-pipeline", 3)
        },
    ]
}

/// The ISSUE acceptance: worker panics at 50% probability plus a 1 ms
/// deadline over the smoke corpus — every request is still answered
/// with a plan (anytime or fallback), zero errors, zero aborts.
#[test]
fn acceptance_panic_storm_with_tight_deadline_answers_every_request() {
    with_failpoints(|| {
        failpoints().arm(WORKER_PANIC, 0.5, 11).unwrap();
        let svc = PlanService::new(ServiceConfig {
            defaults: JobDefaults { deadline_ms: 1, ..JobDefaults::default() },
            ..ServiceConfig::default()
        });
        let requests = smoke_corpus();
        let (responses, summary) = run_batch(&svc, &requests, 2, 4);
        assert_eq!(responses.len(), requests.len());
        for r in &responses {
            assert!(r.error.is_none(), "{}: {:?}", r.id, r.error);
            assert!(r.plan_json.is_some(), "{}: every request must get a plan", r.id);
        }
        assert_eq!(summary.errors, 0);
        assert!(
            responses.iter().any(|r| r.degraded.is_some()),
            "a 1ms deadline over cold searches must degrade something: {}",
            summary.describe()
        );
        assert!(
            summary.deadline_hits + summary.fallback_plans > 0,
            "{}",
            summary.describe()
        );
        // Degraded plans must not have been published to the cache.
        for (r, q) in responses.iter().zip(&requests) {
            if r.degraded.is_some() {
                assert!(!r.cached, "{}: degraded responses are never cache hits", q.id);
            }
        }
    });
}

/// The determinism contract: an armed fault schedule is a pure function
/// of `(failpoint seed, round, worker)`, so rerunning the identical
/// storm on a fresh service reproduces every response byte for byte.
#[test]
fn panic_storm_replays_byte_identically() {
    with_failpoints(|| {
        failpoints().arm(WORKER_PANIC, 0.5, 11).unwrap();
        let run = || {
            let svc = PlanService::new(ServiceConfig::default());
            let requests = [req("a", 100), req("b", 101)];
            let (responses, summary) = run_batch(&svc, &requests, 1, 2);
            let lines: Vec<String> = responses.iter().map(|r| r.to_json_line()).collect();
            (lines, summary)
        };
        let (first, s1) = run();
        let (second, s2) = run();
        assert_eq!(first, second, "same faultpoint seed, same storm, same bytes");
        assert!(s1.worker_panics > 0, "seed 11 fires in round 1 for K=4");
        assert_eq!(s1.worker_panics, s2.worker_panics);
        for line in &first {
            assert!(!line.contains("\"error\""), "panics degrade, they do not error: {line}");
        }
    });
}

/// Certain death for every worker: the merge has no live tree left, so
/// the request is answered by the search-free fallback plan, labeled
/// `degraded:"panic"` — and that plan is NOT cached.
#[test]
fn total_panic_storm_serves_the_fallback_plan() {
    with_failpoints(|| {
        failpoints().arm(WORKER_PANIC, 1.0, 1).unwrap();
        let svc = PlanService::new(ServiceConfig::default());
        let doomed = svc.handle(&req("doomed", 3));
        assert!(doomed.error.is_none(), "{:?}", doomed.error);
        assert_eq!(doomed.degraded.as_deref(), Some("panic"));
        assert!(doomed.fallback);
        assert!(doomed.plan_json.is_some());
        let stats = doomed.search.as_ref().expect("the leader carries search stats");
        assert_eq!(stats.worker_panics, 4, "all four workers poisoned in round 1");
        // Lift the faults: the identical fingerprint still runs a real
        // search, because the fallback plan was never published.
        failpoints().disarm_all();
        let clean = svc.handle(&req("retry", 3));
        assert!(clean.error.is_none(), "{:?}", clean.error);
        assert!(!clean.cached, "fallback plans must never be cached");
        assert!(clean.degraded.is_none());
        assert!(!clean.fallback);
        assert_eq!(svc.searches_run(), 2);
    });
}

/// A deadline hit mid-search returns the best-so-far anytime plan,
/// labeled `degraded:"deadline"` — also never cached.
#[test]
fn deadline_hit_returns_anytime_plan_and_skips_the_cache() {
    with_failpoints(|| {
        failpoints().arm(SEARCH_SLOW_ROUND, 1.0, 0).unwrap();
        let svc = PlanService::new(ServiceConfig {
            defaults: JobDefaults { deadline_ms: 5, ..JobDefaults::default() },
            ..ServiceConfig::default()
        });
        let slow = svc.handle(&req("slow", 21));
        assert!(slow.error.is_none(), "{:?}", slow.error);
        assert_eq!(slow.degraded.as_deref(), Some("deadline"));
        assert!(!slow.fallback, "round 1 completed, so an anytime plan exists");
        assert!(slow.plan_json.is_some());
        let again = svc.handle(&req("slow-again", 21));
        assert!(!again.cached, "deadline-degraded plans must never be cached");
        assert_eq!(again.degraded.as_deref(), Some("deadline"));
        assert_eq!(svc.searches_run(), 2);
    });
}

/// Admission control: with one worker pinned down by slow rounds and a
/// pending queue of one, overflow arrivals are shed — answered inline
/// from cache or the fallback plan, labeled `degraded:"shed"`, never
/// dropped and never an error.
#[test]
fn queue_overflow_sheds_instead_of_blocking() {
    with_failpoints(|| {
        failpoints().arm(SEARCH_SLOW_ROUND, 1.0, 0).unwrap();
        let svc = PlanService::new(ServiceConfig::default());
        let input: String = (0..6)
            .map(|i| {
                format!(
                    "{{\"id\":\"s{i}\",\"model\":\"mlp\",\"mesh\":\"model=2\",\
                     \"budget\":40,\"seed\":{i},\"workers\":1}}\n"
                )
            })
            .collect();
        let out = Mutex::new(Vec::<u8>::new());
        let summary =
            serve_jsonl(&svc, std::io::BufReader::new(input.as_bytes()), &out, 1, 1).unwrap();
        assert_eq!(summary.requests, 6, "shed requests are still answered");
        assert_eq!(summary.errors, 0);
        assert!(summary.shed >= 1, "{}", summary.describe());
        assert!(summary.describe().contains("shed"), "{}", summary.describe());
        let text = String::from_utf8(out.into_inner().unwrap()).unwrap();
        assert_eq!(text.lines().count(), 6, "one response line per request");
        assert!(text.contains("\"degraded\":\"shed\""), "{text}");
        for line in text.lines() {
            assert!(automap::util::json::parse(line).is_ok(), "bad response line: {line}");
        }
    });
}

/// No faults armed: the full service path is byte-deterministic for a
/// fixed (seed, K), for both the serial and the root-parallel executor
/// — the wire shape carries no degraded/fallback/panic keys at all.
#[test]
fn fault_free_serving_is_byte_identical_for_k1_and_k4() {
    with_failpoints(|| {
        for workers in [1usize, 4] {
            let serve = || {
                let svc = PlanService::new(ServiceConfig::default());
                let r = svc.handle(&PartitionRequest { workers, ..req("pin", 42) });
                assert!(r.error.is_none(), "{:?}", r.error);
                r.to_json_line()
            };
            let first = serve();
            let second = serve();
            assert_eq!(first, second, "K={workers}: fixed seed must replay identically");
            for key in ["degraded", "fallback", "worker_panics"] {
                assert!(
                    !first.contains(key),
                    "K={workers}: fault-free wire shape must omit '{key}': {first}"
                );
            }
        }
    });
}

/// Injected disk read errors degrade to a cache miss — transient, not
/// corruption: the index entry survives and the very next probe hits.
#[test]
fn disk_read_faults_degrade_to_misses() {
    with_failpoints(|| {
        let dir = temp_cache_dir("read-fault");
        let tier = DiskTier::open_with(&dir, 1 << 20).unwrap();
        tier.put(7, "{\"plan\":true}").unwrap();
        // Seed 9 at p=0.5: draw 0 fires, draw 1 passes.
        failpoints().arm(DISK_READ_ERR, 0.5, 9).unwrap();
        assert!(tier.get(7).is_none(), "injected read error must look like a miss");
        assert_eq!(tier.get(7).as_deref(), Some("{\"plan\":true}"), "the entry survives");
        let stats = tier.stats();
        assert_eq!(stats.corrupt_records, 0, "injected read errors are not corruption");
        assert_eq!(stats.entries, 1);
        assert_eq!(failpoints().fired(DISK_READ_ERR), 1);
        let _ = std::fs::remove_dir_all(&dir);
    });
}

/// A write fault raised mid-compaction degrades to an uncompacted (but
/// fully valid) log: the triggering put still succeeds, nothing is
/// lost, and the next put over the threshold compacts normally.
#[test]
fn disk_write_fault_mid_compaction_never_loses_the_put() {
    with_failpoints(|| {
        let dir = temp_cache_dir("compact-fault");
        // Build up garbage with compaction disabled (huge threshold):
        // three superseded revisions of one key.
        {
            let tier = DiskTier::open_with(&dir, 1 << 20).unwrap();
            for i in 0..3 {
                tier.put(42, &format!("{{\"rev\":{i}}}")).unwrap();
            }
        }
        // Reopen with a tiny threshold so the next put triggers
        // compaction. Seed 7 at p=0.5: draw 0 (the put's own entry
        // check) passes, draw 1 (the compaction check) fires.
        let tier = DiskTier::open_with(&dir, 1).unwrap();
        failpoints().arm(DISK_WRITE_ERR, 0.5, 7).unwrap();
        tier.put(42, "{\"rev\":3}").unwrap();
        assert_eq!(failpoints().fired(DISK_WRITE_ERR), 1, "the compaction draw fired");
        let stats = tier.stats();
        assert_eq!(tier.get(42).as_deref(), Some("{\"rev\":3}"), "the put itself landed");
        assert_eq!(stats.compactions, 0, "the injected fault aborted the rewrite");
        assert_eq!(stats.generation, 0, "a failed compaction keeps the old generation");
        // Faults lifted: the next put retries compaction and wins.
        failpoints().disarm_all();
        tier.put(42, "{\"rev\":4}").unwrap();
        let stats = tier.stats();
        assert_eq!(stats.compactions, 1);
        assert_eq!(stats.generation, 1);
        assert_eq!(stats.entries, 1);
        assert_eq!(tier.get(42).as_deref(), Some("{\"rev\":4}"));
        // And a fresh open replays the compacted log cleanly.
        drop(tier);
        let tier = DiskTier::open_with(&dir, 1 << 20).unwrap();
        assert_eq!(tier.stats().corrupt_records, 0);
        assert_eq!(tier.get(42).as_deref(), Some("{\"rev\":4}"));
        let _ = std::fs::remove_dir_all(&dir);
    });
}

/// `ServiceConfig::failpoints` is the programmatic twin of
/// `PALLAS_FAILPOINTS`: arming through the config is visible to the
/// search, and a garbage spec fails construction loudly.
#[test]
fn service_config_arms_and_validates_failpoint_specs() {
    with_failpoints(|| {
        let svc = PlanService::try_new(ServiceConfig {
            failpoints: Some(format!("{WORKER_PANIC}=1.0@5")),
            ..ServiceConfig::default()
        })
        .unwrap();
        let r = svc.handle(&req("cfg", 9));
        assert!(r.error.is_none(), "{:?}", r.error);
        assert_eq!(r.degraded.as_deref(), Some("panic"));
        let unknown = PlanService::try_new(ServiceConfig {
            failpoints: Some("no.such.failpoint=0.5".to_string()),
            ..ServiceConfig::default()
        });
        assert!(unknown.is_err(), "unknown failpoint names are rejected");
        let out_of_range = PlanService::try_new(ServiceConfig {
            failpoints: Some(format!("{WORKER_PANIC}=2.0")),
            ..ServiceConfig::default()
        });
        assert!(out_of_range.is_err(), "probabilities outside [0,1] are rejected");
    });
}
