//! Composite search objective (paper §3: "The search mechanism is guided
//! by multiple cost statistics. First, a peak liveness analysis exposes
//! an approximate memory estimate ... Second, we minimise the number of
//! bytes communicated through reduction operations.")
//!
//! Megatron-optimality is *emergent*: it is the minimum-collective
//! strategy that fits device memory. Nothing here pattern-matches it.

use super::liveness::MemoryEstimate;
use crate::partir::dist::DistMap;
use crate::partir::program::PartirProgram;
use crate::sim::device::Device;
use crate::sim::exec::{estimate, RuntimeEstimate};
use crate::spmd::collectives::CollectiveStats;
use crate::spmd::lower::lower;

/// Weights for the composite objective.
#[derive(Debug, Clone)]
pub struct CostWeights {
    /// Penalty per byte of HBM overflow (dominant term).
    pub mem_overflow: f64,
    /// Weight on bytes moved through reduction collectives.
    pub comm_bytes: f64,
    /// Weight on estimated runtime seconds.
    pub runtime: f64,
    /// Weight on peak memory even when it fits (prefer leaner solutions).
    pub mem_bytes: f64,
}

impl Default for CostWeights {
    fn default() -> Self {
        CostWeights { mem_overflow: 1e-3, comm_bytes: 1e-9, runtime: 1.0, mem_bytes: 1e-12 }
    }
}

/// Full evaluation of one partitioning solution.
#[derive(Debug, Clone)]
pub struct Evaluation {
    pub memory: MemoryEstimate,
    pub runtime: RuntimeEstimate,
    pub collectives: CollectiveStats,
    pub fits_memory: bool,
    pub cost: f64,
}

/// Evaluate a distribution map end to end: lower to SPMD, run the
/// liveness, communication and runtime models, combine.
pub fn evaluate(p: &PartirProgram, dm: &DistMap, dev: &Device, w: &CostWeights) -> Evaluation {
    let sp = lower(&p.func, &p.mesh, &p.prop, dm);
    let memory =
        super::liveness::peak_memory_cached(&p.func, &p.mesh, dm, &p.prop.global_bytes);
    let runtime = estimate(&sp, dev);
    let collectives = CollectiveStats::from_collectives(&sp.collectives);
    let overflow = (memory.peak_bytes - dev.hbm_bytes).max(0) as f64;
    let cost = w.mem_overflow * overflow
        + w.comm_bytes * collectives.total_bytes() as f64
        + w.runtime * runtime.total_seconds()
        + w.mem_bytes * memory.peak_bytes as f64;
    Evaluation { fits_memory: overflow == 0.0, memory, runtime, collectives, cost }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::{ArgKind, GraphBuilder, TensorType, ValueId};
    use crate::partir::actions::{Action, DecisionState};
    use crate::partir::mesh::{AxisId, Mesh};

    fn big_prog() -> PartirProgram {
        // Two big weights so replication overflows a tiny device.
        let mut b = GraphBuilder::new("big");
        let x = b.arg("x", TensorType::f32(&[64, 4096]), ArgKind::Input);
        let w1 = b.arg("w1", TensorType::f32(&[4096, 16384]), ArgKind::Parameter);
        let w2 = b.arg("w2", TensorType::f32(&[16384, 4096]), ArgKind::Parameter);
        let h = b.matmul(x, w1);
        let g = b.gelu(h);
        let y = b.matmul(g, w2);
        b.output(y);
        PartirProgram::new(b.finish(), Mesh::new(&[("model", 4)]))
    }

    fn tiny_device() -> Device {
        Device { hbm_bytes: 400 << 20, ..Device::tpu_v3() } // 400 MB
    }

    #[test]
    fn replicated_overflows_sharded_fits() {
        let p = big_prog();
        let dev = tiny_device();
        let w = CostWeights::default();
        let dm0 = crate::partir::dist::DistMap::new(&p.func, &p.mesh);
        let e0 = evaluate(&p, &dm0, &dev, &w);
        assert!(!e0.fits_memory);

        let st = DecisionState {
            actions: vec![
                Action::Tile { v: ValueId(1), dim: 1, axis: AxisId(0) },
                Action::Tile { v: ValueId(2), dim: 0, axis: AxisId(0) },
            ],
            atomic: Default::default(),
        };
        let (dm, _) = p.apply(&st);
        let e1 = evaluate(&p, &dm, &dev, &w);
        assert!(e1.fits_memory, "peak={} limit={}", e1.memory.peak_bytes, dev.hbm_bytes);
        assert!(e1.cost < e0.cost);
        assert_eq!(e1.collectives.all_reduce_count, 1);
    }

    #[test]
    fn megatron_beats_gather_heavy_solution() {
        let p = big_prog();
        let dev = tiny_device();
        let w = CostWeights::default();
        // Megatron: col-shard w1, row-shard w2 -> 1 all-reduce.
        let megatron = DecisionState {
            actions: vec![
                Action::Tile { v: ValueId(1), dim: 1, axis: AxisId(0) },
                Action::Tile { v: ValueId(2), dim: 0, axis: AxisId(0) },
            ],
            atomic: Default::default(),
        };
        // Bad: row-shard w1 one-sided (gathers w1) + col-shard w2.
        let bad = DecisionState {
            actions: vec![
                Action::Tile { v: ValueId(1), dim: 0, axis: AxisId(0) },
                Action::Tile { v: ValueId(2), dim: 1, axis: AxisId(0) },
            ],
            atomic: Default::default(),
        };
        let (dm_m, _) = p.apply(&megatron);
        let (dm_b, _) = p.apply(&bad);
        let em = evaluate(&p, &dm_m, &dev, &w);
        let eb = evaluate(&p, &dm_b, &dev, &w);
        assert!(em.cost < eb.cost, "megatron {} vs bad {}", em.cost, eb.cost);
    }
}
