//! Composite search objective (paper §3: "The search mechanism is guided
//! by multiple cost statistics. First, a peak liveness analysis exposes
//! an approximate memory estimate ... Second, we minimise the number of
//! bytes communicated through reduction operations.")
//!
//! Megatron-optimality is *emergent*: it is the minimum-collective
//! strategy that fits device memory. Nothing here pattern-matches it.

use super::liveness::{LivenessTimeline, MemoryEstimate};
use crate::partir::dist::DistMap;
use crate::partir::program::PartirProgram;
use crate::partir::propagate::PropStats;
use crate::sim::device::Device;
use crate::sim::exec::{estimate, node_term, NodeTerm, RuntimeEstimate};
use crate::spmd::collectives::{collective_seconds, Collective, CollectiveKind, CollectiveStats};
use crate::spmd::lower::{lower, lower_node_into};

/// Weights for the composite objective.
#[derive(Debug, Clone)]
pub struct CostWeights {
    /// Penalty per byte of HBM overflow (dominant term).
    pub mem_overflow: f64,
    /// Weight on bytes moved through reduction collectives.
    pub comm_bytes: f64,
    /// Weight on estimated runtime seconds.
    pub runtime: f64,
    /// Weight on peak memory even when it fits (prefer leaner solutions).
    pub mem_bytes: f64,
}

impl Default for CostWeights {
    fn default() -> Self {
        CostWeights { mem_overflow: 1e-3, comm_bytes: 1e-9, runtime: 1.0, mem_bytes: 1e-12 }
    }
}

/// Full evaluation of one partitioning solution.
#[derive(Debug, Clone, PartialEq)]
pub struct Evaluation {
    pub memory: MemoryEstimate,
    pub runtime: RuntimeEstimate,
    pub collectives: CollectiveStats,
    pub fits_memory: bool,
    pub cost: f64,
}

/// Evaluate a distribution map end to end: lower to SPMD, run the
/// liveness, communication and runtime models, combine.
pub fn evaluate(p: &PartirProgram, dm: &DistMap, dev: &Device, w: &CostWeights) -> Evaluation {
    let sp = lower(&p.func, &p.mesh, &p.prop, dm);
    let memory =
        super::liveness::peak_memory_cached(&p.func, &p.mesh, dm, &p.prop.global_bytes);
    let runtime = estimate(&sp, dev);
    let collectives = CollectiveStats::from_collectives(&sp.collectives);
    combine(memory, runtime, collectives, dev.hbm_bytes, w)
}

/// Fold the three model outputs into the composite objective — the ONE
/// definition of the cost formula, shared by [`evaluate`] and
/// [`CostLedger`]: the ledger's bit-identity guarantee rests on there
/// being no second copy to drift.
fn combine(
    memory: MemoryEstimate,
    runtime: RuntimeEstimate,
    collectives: CollectiveStats,
    hbm_bytes: i64,
    w: &CostWeights,
) -> Evaluation {
    let overflow = (memory.peak_bytes - hbm_bytes).max(0) as f64;
    let cost = w.mem_overflow * overflow
        + w.comm_bytes * collectives.total_bytes() as f64
        + w.runtime * runtime.total_seconds()
        + w.mem_bytes * memory.peak_bytes as f64;
    Evaluation { fits_memory: overflow == 0.0, memory, runtime, collectives, cost }
}

/// One cached collective of one node: what the lowering emitted plus its
/// precomputed α-β ring seconds (the device is fixed for a ledger's
/// life, so the seconds never go stale).
#[derive(Debug, Clone, Copy, PartialEq)]
struct CollectiveTerm {
    kind: CollectiveKind,
    bytes: i64,
    seconds: f64,
}

/// Per-node cost ledger: [`evaluate`] decomposed into per-node
/// contributions — each node's lowered collectives
/// ([`lower_node_into`]), its roofline term ([`node_term`]), and its
/// values' resident sizes in a maintained [`LivenessTimeline`] — all
/// keyed to one tracked distribution map.
///
/// [`CostLedger::refresh`] re-costs only the nodes whose operand/result
/// rows differ from the tracked map (found by a flat row diff, which
/// subsumes the search env's dirty-value frontier and also covers the
/// assignments an auto infer-rest pass makes), then re-aggregates:
/// integer quantities (collective counts/bytes, liveness deltas) are
/// maintained by exact deltas, float quantities are re-summed over the
/// cached per-node terms in exactly the order the full pipeline sums
/// them. Evaluation therefore drops from O(nodes × axes × operands)
/// with three allocating passes to O(changed nodes) + one flat re-sum.
///
/// **Exactness (the delta-fixpoint argument, DESIGN.md §8):** every
/// per-node term is a pure function of the distribution rows of that
/// node's operands and result plus immutable program tables. A node
/// outside the diff has all of those rows unchanged, so its cached term
/// is bit-identical to what a fresh computation would produce; a node
/// inside the diff is recomputed by the same functions the full
/// pipeline uses. Aggregation order is the full pipeline's order, so
/// the sums — and the composite cost — are bit-identical, not merely
/// close. Debug builds assert this against [`evaluate`] on every ledger
/// evaluation (`search/env.rs`), and `tests/ledger_equivalence.rs`
/// pins it over randomized episodes, the golden corpus, and all
/// built-in models.
#[derive(Debug, Clone)]
pub struct CostLedger {
    device: Device,
    weights: CostWeights,
    /// The map the cached terms describe.
    dm: DistMap,
    /// Per-node roofline terms.
    terms: Vec<NodeTerm>,
    /// Per-node lowered collectives (emission order preserved).
    coll: Vec<Vec<CollectiveTerm>>,
    /// Maintained liveness intervals + resident argument bytes.
    live: LivenessTimeline,
    /// Scratch: values whose rows changed in the current refresh.
    changed: Vec<u32>,
    /// Scratch: dirty-node list + membership bitmap.
    dirty: Vec<u32>,
    dirty_bits: Vec<bool>,
    /// Scratch for [`lower_node_into`].
    justified: Vec<(usize, usize)>,
    lowered: Vec<Collective>,
    /// Scratch map for the auto-infer-rest evaluation target.
    infer_dm: DistMap,
    /// Refresh calls served.
    pub refreshes: usize,
    /// Node terms recomputed across all refreshes (the dirty work).
    pub nodes_recomputed: usize,
    /// Node terms served from the ledger across all refreshes.
    pub nodes_reused: usize,
}

impl CostLedger {
    /// Build a ledger describing `dm` (every term computed once).
    pub fn new(
        p: &PartirProgram,
        dm: &DistMap,
        device: Device,
        weights: CostWeights,
    ) -> CostLedger {
        let n = p.func.num_nodes();
        let live = LivenessTimeline::new(&p.func, &p.mesh, dm, &p.prop.global_bytes);
        let mut ledger = CostLedger {
            device,
            weights,
            dm: dm.clone(),
            terms: vec![NodeTerm::default(); n],
            coll: vec![Vec::new(); n],
            live,
            changed: Vec::new(),
            dirty: Vec::new(),
            dirty_bits: vec![false; n],
            justified: Vec::new(),
            lowered: Vec::new(),
            infer_dm: DistMap { d: Vec::new(), num_axes: 0 },
            refreshes: 0,
            nodes_recomputed: 0,
            nodes_reused: 0,
        };
        for ni in 0..n {
            ledger.recompute_node(p, ni);
        }
        ledger
    }

    /// Re-cost node `ni` against the tracked map.
    fn recompute_node(&mut self, p: &PartirProgram, ni: usize) {
        self.terms[ni] = node_term(&p.func, &p.mesh, &p.prop, &self.dm, &self.device, ni);
        self.lowered.clear();
        lower_node_into(
            &p.func,
            &p.mesh,
            &p.prop,
            &self.dm,
            ni,
            &mut self.justified,
            &mut self.lowered,
        );
        let terms = &mut self.coll[ni];
        terms.clear();
        for c in &self.lowered {
            terms.push(CollectiveTerm {
                kind: c.kind,
                bytes: c.bytes,
                seconds: collective_seconds(c, &p.mesh, self.device.ici_bw, self.device.alpha),
            });
        }
    }

    /// Bring the ledger to `target` and evaluate it: diff the tracked
    /// map against `target`, re-cost only the nodes a changed value
    /// touches, re-aggregate. Bit-identical to
    /// `evaluate(p, target, device, weights)`.
    pub fn refresh(&mut self, p: &PartirProgram, target: &DistMap) -> Evaluation {
        debug_assert_eq!(self.dm.d.len(), target.d.len(), "ledger bound to a different program");
        self.refreshes += 1;
        self.changed.clear();
        for v in 0..self.dm.d.len() {
            if self.dm.d[v] != target.d[v] {
                self.changed.push(v as u32);
                self.dm.d[v] = target.d[v];
            }
        }
        self.dm.num_axes = target.num_axes;
        // Re-point changed values' liveness intervals and mark every
        // node whose operand or result rows moved. The scratch vectors
        // are moved out while iterated (borrow discipline) and moved
        // back, so their capacity is kept across refreshes.
        let num_args = p.func.num_args();
        let changed = std::mem::take(&mut self.changed);
        for &v in &changed {
            let v = v as usize;
            self.live.set_value(v, self.dm.local_bytes(v, p.prop.global_bytes[v], &p.mesh));
            if v >= num_args {
                self.mark_dirty((v - num_args) as u32);
            }
            for &ni in p.prop.users_of(v) {
                self.mark_dirty(ni);
            }
        }
        self.changed = changed;
        let dirty = std::mem::take(&mut self.dirty);
        for &ni in &dirty {
            self.recompute_node(p, ni as usize);
            self.dirty_bits[ni as usize] = false;
        }
        self.nodes_recomputed += dirty.len();
        self.nodes_reused += p.func.num_nodes() - dirty.len();
        self.dirty = dirty;
        self.dirty.clear();
        self.aggregate()
    }

    #[inline]
    fn mark_dirty(&mut self, ni: u32) {
        if !self.dirty_bits[ni as usize] {
            self.dirty_bits[ni as usize] = true;
            self.dirty.push(ni);
        }
    }

    /// Evaluate `dm` through the ledger, optionally running the
    /// auto-infer-rest pass into ledger-owned scratch first (the same
    /// pass the full evaluation path runs on a terminal episode).
    pub fn evaluate_map(
        &mut self,
        p: &PartirProgram,
        dm: &DistMap,
        infer_rest: bool,
    ) -> Evaluation {
        if !infer_rest {
            return self.refresh(p, dm);
        }
        // Move the scratch out so `refresh` can borrow `self` mutably.
        let empty = DistMap { d: Vec::new(), num_axes: 0 };
        let mut target = std::mem::replace(&mut self.infer_dm, empty);
        if target.d.len() == dm.d.len() {
            target.d.clone_from(&dm.d);
            target.num_axes = dm.num_axes;
        } else {
            target = dm.clone();
        }
        let mut stats = PropStats::default();
        p.prop.infer_rest(&p.func, &p.mesh, &mut target, &mut stats);
        let e = self.refresh(p, &target);
        self.infer_dm = target;
        e
    }

    /// Aggregate the cached terms into a full [`Evaluation`], in exactly
    /// the order the one-shot pipeline accumulates: roofline terms by
    /// ascending node, collective seconds in emission order, liveness
    /// peak by the maintained-delta scan.
    fn aggregate(&self) -> Evaluation {
        let mut runtime = RuntimeEstimate::default();
        let mut collectives = CollectiveStats::default();
        for (t, cs) in self.terms.iter().zip(&self.coll) {
            runtime.add_node_term(t);
            for c in cs {
                collectives.add(c.kind, c.bytes);
                runtime.collective_seconds += c.seconds;
            }
        }
        combine(self.live.peak(), runtime, collectives, self.device.hbm_bytes, &self.weights)
    }

    /// Stable digest of every cached term (float bits included) — lets
    /// tests prove a ledger maintained across a whole episode holds the
    /// same state as one rebuilt from scratch on the final map.
    pub fn terms_digest(&self) -> u64 {
        let mut h = crate::util::hash::Fnv64::new();
        h.usize(self.dm.num_axes);
        for row in &self.dm.d {
            h.bytes(row);
        }
        for t in &self.terms {
            h.f64(t.compute_seconds).f64(t.memory_seconds).f64(t.flops);
        }
        for cs in &self.coll {
            h.usize(cs.len());
            for c in cs {
                h.byte(matches!(c.kind, CollectiveKind::AllReduce) as u8)
                    .i64(c.bytes)
                    .f64(c.seconds);
            }
        }
        let mem = self.live.peak();
        h.i64(mem.peak_bytes).i64(mem.arg_bytes).usize(mem.peak_node);
        h.finish()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::{ArgKind, GraphBuilder, TensorType, ValueId};
    use crate::partir::actions::{Action, DecisionState};
    use crate::partir::mesh::{AxisId, Mesh};

    fn big_prog() -> PartirProgram {
        // Two big weights so replication overflows a tiny device.
        let mut b = GraphBuilder::new("big");
        let x = b.arg("x", TensorType::f32(&[64, 4096]), ArgKind::Input);
        let w1 = b.arg("w1", TensorType::f32(&[4096, 16384]), ArgKind::Parameter);
        let w2 = b.arg("w2", TensorType::f32(&[16384, 4096]), ArgKind::Parameter);
        let h = b.matmul(x, w1);
        let g = b.gelu(h);
        let y = b.matmul(g, w2);
        b.output(y);
        PartirProgram::new(b.finish(), Mesh::new(&[("model", 4)]))
    }

    fn tiny_device() -> Device {
        Device { hbm_bytes: 400 << 20, ..Device::tpu_v3() } // 400 MB
    }

    #[test]
    fn replicated_overflows_sharded_fits() {
        let p = big_prog();
        let dev = tiny_device();
        let w = CostWeights::default();
        let dm0 = crate::partir::dist::DistMap::new(&p.func, &p.mesh);
        let e0 = evaluate(&p, &dm0, &dev, &w);
        assert!(!e0.fits_memory);

        let st = DecisionState {
            actions: vec![
                Action::Tile { v: ValueId(1), dim: 1, axis: AxisId(0) },
                Action::Tile { v: ValueId(2), dim: 0, axis: AxisId(0) },
            ],
            atomic: Default::default(),
        };
        let (dm, _) = p.apply(&st);
        let e1 = evaluate(&p, &dm, &dev, &w);
        assert!(e1.fits_memory, "peak={} limit={}", e1.memory.peak_bytes, dev.hbm_bytes);
        assert!(e1.cost < e0.cost);
        assert_eq!(e1.collectives.all_reduce_count, 1);
    }

    #[test]
    fn ledger_refresh_is_bit_identical_to_full_evaluate() {
        let p = big_prog();
        let dev = tiny_device();
        let w = CostWeights::default();
        let dm0 = crate::partir::dist::DistMap::new(&p.func, &p.mesh);
        let mut ledger = CostLedger::new(&p, &dm0, dev.clone(), w.clone());
        // Walk through three maps (replicated → megatron → bad) and
        // compare every incremental refresh against the full pipeline.
        let states = [
            vec![],
            vec![
                Action::Tile { v: ValueId(1), dim: 1, axis: AxisId(0) },
                Action::Tile { v: ValueId(2), dim: 0, axis: AxisId(0) },
            ],
            vec![
                Action::Tile { v: ValueId(1), dim: 0, axis: AxisId(0) },
                Action::Tile { v: ValueId(2), dim: 1, axis: AxisId(0) },
            ],
        ];
        for actions in states {
            let st = DecisionState { actions, atomic: Default::default() };
            let (dm, _) = p.apply(&st);
            let inc = ledger.refresh(&p, &dm);
            let full = evaluate(&p, &dm, &dev, &w);
            assert_eq!(inc, full);
            assert_eq!(inc.cost.to_bits(), full.cost.to_bits(), "cost must match to the bit");
        }
        assert!(ledger.nodes_reused > 0, "the ledger must actually reuse terms");
    }

    #[test]
    fn ledger_counts_reuse_and_recompute() {
        let p = big_prog();
        let dm0 = crate::partir::dist::DistMap::new(&p.func, &p.mesh);
        let mut ledger = CostLedger::new(&p, &dm0, tiny_device(), CostWeights::default());
        // Refreshing onto the identical map recomputes nothing.
        let _ = ledger.refresh(&p, &dm0);
        assert_eq!(ledger.refreshes, 1);
        assert_eq!(ledger.nodes_recomputed, 0);
        assert_eq!(ledger.nodes_reused, p.func.num_nodes());
        // One decision dirties only the touched region.
        let st = DecisionState {
            actions: vec![Action::Tile { v: ValueId(1), dim: 1, axis: AxisId(0) }],
            atomic: Default::default(),
        };
        let (dm, _) = p.apply(&st);
        let _ = ledger.refresh(&p, &dm);
        assert!(ledger.nodes_recomputed >= 1);
        assert!(ledger.nodes_recomputed < p.func.num_nodes());
    }

    #[test]
    fn ledger_digest_matches_a_fresh_rebuild() {
        let p = big_prog();
        let dev = tiny_device();
        let w = CostWeights::default();
        let dm0 = crate::partir::dist::DistMap::new(&p.func, &p.mesh);
        let mut ledger = CostLedger::new(&p, &dm0, dev.clone(), w.clone());
        let st = DecisionState {
            actions: vec![
                Action::Tile { v: ValueId(1), dim: 1, axis: AxisId(0) },
                Action::Tile { v: ValueId(2), dim: 0, axis: AxisId(0) },
            ],
            atomic: Default::default(),
        };
        let (dm, _) = p.apply(&st);
        let _ = ledger.refresh(&p, &dm);
        let fresh = CostLedger::new(&p, &dm, dev, w);
        assert_eq!(
            ledger.terms_digest(),
            fresh.terms_digest(),
            "a maintained ledger must hold the same terms as a scratch rebuild"
        );
    }

    #[test]
    fn megatron_beats_gather_heavy_solution() {
        let p = big_prog();
        let dev = tiny_device();
        let w = CostWeights::default();
        // Megatron: col-shard w1, row-shard w2 -> 1 all-reduce.
        let megatron = DecisionState {
            actions: vec![
                Action::Tile { v: ValueId(1), dim: 1, axis: AxisId(0) },
                Action::Tile { v: ValueId(2), dim: 0, axis: AxisId(0) },
            ],
            atomic: Default::default(),
        };
        // Bad: row-shard w1 one-sided (gathers w1) + col-shard w2.
        let bad = DecisionState {
            actions: vec![
                Action::Tile { v: ValueId(1), dim: 0, axis: AxisId(0) },
                Action::Tile { v: ValueId(2), dim: 1, axis: AxisId(0) },
            ],
            atomic: Default::default(),
        };
        let (dm_m, _) = p.apply(&megatron);
        let (dm_b, _) = p.apply(&bad);
        let em = evaluate(&p, &dm_m, &dev, &w);
        let eb = evaluate(&p, &dm_b, &dev, &w);
        assert!(em.cost < eb.cost, "megatron {} vs bad {}", em.cost, eb.cost);
    }
}
