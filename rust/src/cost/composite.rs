//! Composite search objective (paper §3: "The search mechanism is guided
//! by multiple cost statistics. First, a peak liveness analysis exposes
//! an approximate memory estimate ... Second, we minimise the number of
//! bytes communicated through reduction operations.")
//!
//! Megatron-optimality is *emergent*: it is the minimum-collective
//! strategy that fits device memory. Nothing here pattern-matches it.

use super::liveness::{LivenessTimeline, MemoryEstimate};
use crate::ir::ArgKind;
use crate::obs::recorder::recorder;
use crate::partir::dist::DistMap;
use crate::partir::program::PartirProgram;
use crate::partir::propagate::PropStats;
use crate::pipeline::{boundary_transfers, simulate_1f1b, PipelineSpec};
use crate::sim::device::Device;
use crate::sim::exec::{estimate, node_term, NodeTerm, RuntimeEstimate};
use crate::spmd::collectives::{collective_seconds, Collective, CollectiveKind, CollectiveStats};
use crate::spmd::lower::{lower, lower_node_into};

/// Weights for the composite objective.
#[derive(Debug, Clone)]
pub struct CostWeights {
    /// Penalty per byte of HBM overflow (dominant term).
    pub mem_overflow: f64,
    /// Weight on bytes moved through reduction collectives.
    pub comm_bytes: f64,
    /// Weight on estimated runtime seconds.
    pub runtime: f64,
    /// Weight on peak memory even when it fits (prefer leaner solutions).
    pub mem_bytes: f64,
}

impl Default for CostWeights {
    fn default() -> Self {
        CostWeights { mem_overflow: 1e-3, comm_bytes: 1e-9, runtime: 1.0, mem_bytes: 1e-12 }
    }
}

/// Pipeline-specific terms of a pipelined evaluation (DESIGN.md §11):
/// the 1F1B schedule outcome, the point-to-point transfer bill, and the
/// per-stage liveness ceiling that replaces the flat peak in the cost.
#[derive(Debug, Clone, PartialEq)]
pub struct PipelineEval {
    pub stages: usize,
    pub microbatches: usize,
    /// The cut vector this evaluation priced.
    pub cuts: Vec<u32>,
    /// Warm-up/drain idle fraction of the 1F1B schedule.
    pub bubble_fraction: f64,
    /// End-to-end 1F1B makespan (replaces the flat runtime in the cost).
    pub makespan_seconds: f64,
    /// Total send/recv seconds across all boundary hops and microbatches.
    pub send_recv_seconds: f64,
    /// Max over stages of resident weights + in-flight activations.
    pub max_stage_peak_bytes: i64,
}

/// Full evaluation of one partitioning solution.
#[derive(Debug, Clone, PartialEq)]
pub struct Evaluation {
    pub memory: MemoryEstimate,
    pub runtime: RuntimeEstimate,
    pub collectives: CollectiveStats,
    pub fits_memory: bool,
    pub cost: f64,
    /// `Some` iff the evaluation priced a pipeline configuration.
    pub pipeline: Option<PipelineEval>,
}

/// Evaluate a distribution map end to end: lower to SPMD, run the
/// liveness, communication and runtime models, combine.
pub fn evaluate(p: &PartirProgram, dm: &DistMap, dev: &Device, w: &CostWeights) -> Evaluation {
    evaluate_pipelined(p, dm, dev, w, None)
}

/// [`evaluate`], optionally composed with a pipeline configuration: the
/// SPMD models run unchanged (their aggregates stay bit-identical to the
/// flat path and still appear in `memory`/`runtime`/`collectives`), and
/// when `pipe` is `Some` the per-node terms are additionally binned into
/// stages and priced through the 1F1B schedule simulator — the cost then
/// uses the makespan and the per-stage liveness ceiling instead of the
/// flat totals.
pub fn evaluate_pipelined(
    p: &PartirProgram,
    dm: &DistMap,
    dev: &Device,
    w: &CostWeights,
    pipe: Option<&PipelineSpec>,
) -> Evaluation {
    let sp = lower(&p.func, &p.mesh, &p.prop, dm);
    let memory =
        super::liveness::peak_memory_cached(&p.func, &p.mesh, dm, &p.prop.global_bytes);
    let runtime = estimate(&sp, dev);
    let mut collectives = CollectiveStats::from_collectives(&sp.collectives);
    let spec = match pipe {
        None => return combine(memory, runtime, collectives, dev.hbm_bytes, w),
        Some(spec) => spec,
    };
    // Per-node terms exactly as the ledger caches them — same shared
    // function, so the pipeline pricing below is bit-identical to the
    // ledger's re-aggregation of its cached terms.
    let n = p.func.num_nodes();
    let mut terms = vec![NodeTerm::default(); n];
    let mut coll: Vec<Vec<CollectiveTerm>> = vec![Vec::new(); n];
    let mut justified = Vec::new();
    let mut lowered = Vec::new();
    for ni in 0..n {
        terms[ni] =
            node_cost_terms(p, dm, dev, ni, &mut justified, &mut lowered, &mut coll[ni]);
    }
    let pe = pipeline_terms(p, dm, dev, &terms, &coll, spec, &mut collectives);
    combine_pipelined(memory, runtime, collectives, pe, dev.hbm_bytes, w)
}

/// Fold the three model outputs into the composite objective — the ONE
/// definition of the cost formula, shared by [`evaluate`] and
/// [`CostLedger`]: the ledger's bit-identity guarantee rests on there
/// being no second copy to drift.
fn combine(
    memory: MemoryEstimate,
    runtime: RuntimeEstimate,
    collectives: CollectiveStats,
    hbm_bytes: i64,
    w: &CostWeights,
) -> Evaluation {
    let overflow = (memory.peak_bytes - hbm_bytes).max(0) as f64;
    let cost = w.mem_overflow * overflow
        + w.comm_bytes * collectives.total_bytes() as f64
        + w.runtime * runtime.total_seconds()
        + w.mem_bytes * memory.peak_bytes as f64;
    Evaluation { fits_memory: overflow == 0.0, memory, runtime, collectives, cost, pipeline: None }
}

/// Pipelined counterpart of [`combine`] — again the ONE definition, so
/// [`evaluate_pipelined`] and the ledger cannot drift: the effective
/// peak is the per-stage liveness ceiling and the effective runtime is
/// the 1F1B makespan, while `memory`/`runtime` keep the flat SPMD
/// aggregates for inspection.
fn combine_pipelined(
    memory: MemoryEstimate,
    runtime: RuntimeEstimate,
    collectives: CollectiveStats,
    pipe: PipelineEval,
    hbm_bytes: i64,
    w: &CostWeights,
) -> Evaluation {
    let overflow = (pipe.max_stage_peak_bytes - hbm_bytes).max(0) as f64;
    let cost = w.mem_overflow * overflow
        + w.comm_bytes * collectives.total_bytes() as f64
        + w.runtime * pipe.makespan_seconds
        + w.mem_bytes * pipe.max_stage_peak_bytes as f64;
    Evaluation {
        fits_memory: overflow == 0.0,
        memory,
        runtime,
        collectives,
        cost,
        pipeline: Some(pipe),
    }
}

/// One cached collective of one node: what the lowering emitted plus its
/// precomputed α-β ring seconds (the device is fixed for a ledger's
/// life, so the seconds never go stale).
#[derive(Debug, Clone, Copy, PartialEq)]
struct CollectiveTerm {
    kind: CollectiveKind,
    bytes: i64,
    seconds: f64,
}

/// Compute node `ni`'s cached cost terms — roofline [`NodeTerm`] plus
/// lowered collectives with precomputed seconds — into `out`. The ONE
/// per-node recompute shared by [`CostLedger`] and the pipelined full
/// path in [`evaluate_pipelined`]; both therefore hold bit-identical
/// term tables for the same map.
fn node_cost_terms(
    p: &PartirProgram,
    dm: &DistMap,
    dev: &Device,
    ni: usize,
    justified: &mut Vec<(usize, usize)>,
    lowered: &mut Vec<Collective>,
    out: &mut Vec<CollectiveTerm>,
) -> NodeTerm {
    let t = node_term(&p.func, &p.mesh, &p.prop, dm, dev, ni);
    lowered.clear();
    lower_node_into(&p.func, &p.mesh, &p.prop, dm, ni, justified, lowered);
    out.clear();
    for c in lowered.iter() {
        out.push(CollectiveTerm {
            kind: c.kind,
            bytes: c.bytes,
            seconds: collective_seconds(c, &p.mesh, dev.ici_bw, dev.alpha),
        });
    }
    t
}

/// Price a pipeline configuration from per-node cost terms (DESIGN.md
/// §11). Inputs are the tables [`node_cost_terms`] produces, so the full
/// path and the ledger feed bit-identical data through this single
/// definition:
///
/// - per-stage busy seconds = Σ over the stage's nodes of
///   `max(compute, memory) + intra-stage collective seconds`;
/// - boundary hops from [`boundary_transfers`], each priced as `M`
///   point-to-point transfers of `bytes/M` (`α + (bytes/M)/ici_bw` per
///   microbatch) and folded into `collectives` as send/recv pairs;
/// - the 1F1B simulator turns stage/transfer seconds into makespan and
///   bubble;
/// - per-stage peak = resident parameter/opt-state bytes (placed at the
///   argument's first consumer) + `min(M, K - s)` in-flight microbatch
///   activation slices (1F1B keeps at most that many alive on stage s).
fn pipeline_terms(
    p: &PartirProgram,
    dm: &DistMap,
    dev: &Device,
    terms: &[NodeTerm],
    coll: &[Vec<CollectiveTerm>],
    spec: &PipelineSpec,
    collectives: &mut CollectiveStats,
) -> PipelineEval {
    let k = spec.stages();
    let m = spec.microbatches.max(1);
    let prof = stage_profile(p, dm, dev, terms, coll, spec);
    // Stats record each hop's send/recv pair: M ops per side, the full
    // local bytes crossing in total (integer adds, so folding them after
    // the profile loop is exact).
    for &(_, bytes) in &prof.transfers {
        collectives.send_count += m;
        collectives.send_bytes += bytes;
        collectives.recv_count += m;
        collectives.recv_bytes += bytes;
    }
    let sched = simulate_1f1b(&prof.stage_seconds, &prof.xfer, m);
    // Per-stage liveness ceiling (integer arithmetic, order-free).
    let mut max_stage_peak = 0i64;
    for s in 0..k {
        let inflight = m.min(k - s) as i64;
        let peak = prof.weight_bytes[s] + inflight * (prof.act_bytes[s] / m as i64);
        max_stage_peak = max_stage_peak.max(peak);
    }
    PipelineEval {
        stages: k,
        microbatches: m,
        cuts: spec.cuts.clone(),
        bubble_fraction: sched.bubble_fraction,
        makespan_seconds: sched.makespan_seconds,
        send_recv_seconds: prof.send_recv_seconds,
        max_stage_peak_bytes: max_stage_peak,
    }
}

/// Per-stage accumulation for one pipeline spec, computed from the
/// per-node tables. The ONE accumulation behind [`pipeline_terms`] and
/// [`stage_timeline`], so the traced schedule cannot drift from the
/// priced one.
struct StageProfile {
    /// Busy seconds per stage for the FULL batch, nodes ascending (the
    /// deterministic accumulation order of the contract).
    stage_seconds: Vec<f64>,
    /// Full-batch activation bytes resident per stage.
    act_bytes: Vec<i64>,
    /// Parameter / optimiser-state bytes per stage (placed at the
    /// argument's first consumer, which holds them all schedule long).
    weight_bytes: Vec<i64>,
    /// Per-microbatch boundary transfer seconds (`len = stages - 1`).
    xfer: Vec<f64>,
    /// Total send/recv seconds across all hops and microbatches.
    send_recv_seconds: f64,
    /// `(boundary, full local bytes)` per cross-stage hop, for the
    /// caller's collective-stats folding.
    transfers: Vec<(usize, i64)>,
}

fn stage_profile(
    p: &PartirProgram,
    dm: &DistMap,
    dev: &Device,
    terms: &[NodeTerm],
    coll: &[Vec<CollectiveTerm>],
    spec: &PipelineSpec,
) -> StageProfile {
    let k = spec.stages();
    let m = spec.microbatches.max(1);
    let num_args = p.func.num_args();
    let mut stage_seconds = vec![0.0f64; k];
    let mut act_bytes = vec![0i64; k];
    for (ni, t) in terms.iter().enumerate() {
        let s = spec.stage_of(ni);
        let mut secs = t.compute_seconds.max(t.memory_seconds);
        for c in &coll[ni] {
            secs += c.seconds;
        }
        stage_seconds[s] += secs;
        let out_v = num_args + ni;
        act_bytes[s] += dm.local_bytes(out_v, p.prop.global_bytes[out_v], &p.mesh);
    }
    let mut weight_bytes = vec![0i64; k];
    let mut placed = vec![false; num_args];
    for (ni, node) in p.func.nodes.iter().enumerate() {
        let s = spec.stage_of(ni);
        for &inp in &node.inputs {
            let v = inp.index();
            if v < num_args && !placed[v] {
                placed[v] = true;
                if matches!(p.func.args[v].kind, ArgKind::Parameter | ArgKind::OptState) {
                    weight_bytes[s] += dm.local_bytes(v, p.prop.global_bytes[v], &p.mesh);
                }
            }
        }
    }
    // Cross-stage hops: M microbatched point-to-point transfers each;
    // the schedule sees the per-microbatch seconds.
    let mut xfer = vec![0.0f64; k.saturating_sub(1)];
    let mut send_recv_seconds = 0.0f64;
    let mut transfers = Vec::new();
    for t in boundary_transfers(&p.func, spec) {
        let bytes = dm.local_bytes(t.value, p.prop.global_bytes[t.value], &p.mesh);
        let per_mb = dev.alpha + (bytes as f64 / m as f64) / dev.ici_bw;
        xfer[t.boundary] += per_mb;
        send_recv_seconds += m as f64 * per_mb;
        transfers.push((t.boundary, bytes));
    }
    StageProfile { stage_seconds, act_bytes, weight_bytes, xfer, send_recv_seconds, transfers }
}

/// Tracing-only companion to [`evaluate_pipelined`]: the per-stage busy
/// seconds and per-microbatch boundary transfer seconds the 1F1B
/// simulator would run on for `(dm, spec)`. The executor calls this once
/// per pipelined request — for the winning plan — to emit schedule
/// slices into the flight recorder; it shares [`stage_profile`] and
/// [`node_cost_terms`] with the pricing path, so the traced timeline is
/// exactly the priced one.
pub fn stage_timeline(
    p: &PartirProgram,
    dm: &DistMap,
    dev: &Device,
    spec: &PipelineSpec,
) -> (Vec<f64>, Vec<f64>) {
    let n = p.func.num_nodes();
    let mut terms = vec![NodeTerm::default(); n];
    let mut coll: Vec<Vec<CollectiveTerm>> = vec![Vec::new(); n];
    let mut justified = Vec::new();
    let mut lowered = Vec::new();
    for ni in 0..n {
        terms[ni] =
            node_cost_terms(p, dm, dev, ni, &mut justified, &mut lowered, &mut coll[ni]);
    }
    let prof = stage_profile(p, dm, dev, &terms, &coll, spec);
    (prof.stage_seconds, prof.xfer)
}

/// Per-node cost ledger: [`evaluate`] decomposed into per-node
/// contributions — each node's lowered collectives
/// ([`lower_node_into`]), its roofline term ([`node_term`]), and its
/// values' resident sizes in a maintained [`LivenessTimeline`] — all
/// keyed to one tracked distribution map.
///
/// [`CostLedger::refresh`] re-costs only the nodes whose operand/result
/// rows differ from the tracked map (found by a flat row diff, which
/// subsumes the search env's dirty-value frontier and also covers the
/// assignments an auto infer-rest pass makes), then re-aggregates:
/// integer quantities (collective counts/bytes, liveness deltas) are
/// maintained by exact deltas, float quantities are re-summed over the
/// cached per-node terms in exactly the order the full pipeline sums
/// them. Evaluation therefore drops from O(nodes × axes × operands)
/// with three allocating passes to O(changed nodes) + one flat re-sum.
///
/// **Exactness (the delta-fixpoint argument, DESIGN.md §8):** every
/// per-node term is a pure function of the distribution rows of that
/// node's operands and result plus immutable program tables. A node
/// outside the diff has all of those rows unchanged, so its cached term
/// is bit-identical to what a fresh computation would produce; a node
/// inside the diff is recomputed by the same functions the full
/// pipeline uses. Aggregation order is the full pipeline's order, so
/// the sums — and the composite cost — are bit-identical, not merely
/// close. Debug builds assert this against [`evaluate`] on every ledger
/// evaluation (`search/env.rs`), and `tests/ledger_equivalence.rs`
/// pins it over randomized episodes, the golden corpus, and all
/// built-in models.
#[derive(Debug, Clone)]
pub struct CostLedger {
    device: Device,
    weights: CostWeights,
    /// The map the cached terms describe.
    dm: DistMap,
    /// Per-node roofline terms.
    terms: Vec<NodeTerm>,
    /// Per-node lowered collectives (emission order preserved).
    coll: Vec<Vec<CollectiveTerm>>,
    /// Maintained liveness intervals + resident argument bytes.
    live: LivenessTimeline,
    /// Scratch: values whose rows changed in the current refresh.
    changed: Vec<u32>,
    /// Scratch: dirty-node list + membership bitmap.
    dirty: Vec<u32>,
    dirty_bits: Vec<bool>,
    /// Scratch for [`lower_node_into`].
    justified: Vec<(usize, usize)>,
    lowered: Vec<Collective>,
    /// Scratch map for the auto-infer-rest evaluation target.
    infer_dm: DistMap,
    /// Refresh calls served.
    pub refreshes: usize,
    /// Node terms recomputed across all refreshes (the dirty work).
    pub nodes_recomputed: usize,
    /// Node terms served from the ledger across all refreshes.
    pub nodes_reused: usize,
}

impl CostLedger {
    /// Build a ledger describing `dm` (every term computed once).
    pub fn new(
        p: &PartirProgram,
        dm: &DistMap,
        device: Device,
        weights: CostWeights,
    ) -> CostLedger {
        let n = p.func.num_nodes();
        let live = LivenessTimeline::new(&p.func, &p.mesh, dm, &p.prop.global_bytes);
        let mut ledger = CostLedger {
            device,
            weights,
            dm: dm.clone(),
            terms: vec![NodeTerm::default(); n],
            coll: vec![Vec::new(); n],
            live,
            changed: Vec::new(),
            dirty: Vec::new(),
            dirty_bits: vec![false; n],
            justified: Vec::new(),
            lowered: Vec::new(),
            infer_dm: DistMap { d: Vec::new(), num_axes: 0 },
            refreshes: 0,
            nodes_recomputed: 0,
            nodes_reused: 0,
        };
        for ni in 0..n {
            ledger.recompute_node(p, ni);
        }
        ledger
    }

    /// Re-cost node `ni` against the tracked map (the shared
    /// [`node_cost_terms`] definition).
    fn recompute_node(&mut self, p: &PartirProgram, ni: usize) {
        self.terms[ni] = node_cost_terms(
            p,
            &self.dm,
            &self.device,
            ni,
            &mut self.justified,
            &mut self.lowered,
            &mut self.coll[ni],
        );
    }

    /// Bring the ledger to `target` and evaluate it: diff the tracked
    /// map against `target`, re-cost only the nodes a changed value
    /// touches, re-aggregate. Bit-identical to
    /// `evaluate_pipelined(p, target, device, weights, pipe)` — the
    /// pipeline terms, when requested, are re-priced from the cached
    /// per-node tables through the same shared [`pipeline_terms`]
    /// definition (stage cuts don't change any per-node term, so a cut
    /// move costs only the O(nodes) re-aggregation, never a re-lower).
    pub fn refresh(
        &mut self,
        p: &PartirProgram,
        target: &DistMap,
        pipe: Option<&PipelineSpec>,
    ) -> Evaluation {
        debug_assert_eq!(self.dm.d.len(), target.d.len(), "ledger bound to a different program");
        // Flight-recorder gate: one relaxed atomic load when tracing is
        // off; a timestamp read when on. The span itself is recorded in
        // one shot at the end (`Complete`), when the reuse counts exist.
        let rec = recorder();
        let trace_start = if rec.enabled() { Some(rec.now_ns()) } else { None };
        self.refreshes += 1;
        self.changed.clear();
        for v in 0..self.dm.d.len() {
            if self.dm.d[v] != target.d[v] {
                self.changed.push(v as u32);
                self.dm.d[v] = target.d[v];
            }
        }
        self.dm.num_axes = target.num_axes;
        // Re-point changed values' liveness intervals and mark every
        // node whose operand or result rows moved. The scratch vectors
        // are moved out while iterated (borrow discipline) and moved
        // back, so their capacity is kept across refreshes.
        let num_args = p.func.num_args();
        let changed = std::mem::take(&mut self.changed);
        for &v in &changed {
            let v = v as usize;
            self.live.set_value(v, self.dm.local_bytes(v, p.prop.global_bytes[v], &p.mesh));
            if v >= num_args {
                self.mark_dirty((v - num_args) as u32);
            }
            for &ni in p.prop.users_of(v) {
                self.mark_dirty(ni);
            }
        }
        self.changed = changed;
        let dirty = std::mem::take(&mut self.dirty);
        for &ni in &dirty {
            self.recompute_node(p, ni as usize);
            self.dirty_bits[ni as usize] = false;
        }
        let recomputed = dirty.len();
        self.nodes_recomputed += recomputed;
        self.nodes_reused += p.func.num_nodes() - recomputed;
        self.dirty = dirty;
        self.dirty.clear();
        if let Some(start_ns) = trace_start {
            let reused = (p.func.num_nodes() - recomputed) as i64;
            rec.complete(
                "ledger.refresh",
                "ledger",
                0,
                start_ns,
                &[("recomputed", recomputed as i64), ("reused", reused)],
            );
        }
        self.aggregate_with(p, pipe)
    }

    #[inline]
    fn mark_dirty(&mut self, ni: u32) {
        if !self.dirty_bits[ni as usize] {
            self.dirty_bits[ni as usize] = true;
            self.dirty.push(ni);
        }
    }

    /// Evaluate `dm` through the ledger, optionally running the
    /// auto-infer-rest pass into ledger-owned scratch first (the same
    /// pass the full evaluation path runs on a terminal episode).
    pub fn evaluate_map(
        &mut self,
        p: &PartirProgram,
        dm: &DistMap,
        infer_rest: bool,
        pipe: Option<&PipelineSpec>,
    ) -> Evaluation {
        if !infer_rest {
            return self.refresh(p, dm, pipe);
        }
        // Move the scratch out so `refresh` can borrow `self` mutably.
        let empty = DistMap { d: Vec::new(), num_axes: 0 };
        let mut target = std::mem::replace(&mut self.infer_dm, empty);
        if target.d.len() == dm.d.len() {
            target.d.clone_from(&dm.d);
            target.num_axes = dm.num_axes;
        } else {
            target = dm.clone();
        }
        let mut stats = PropStats::default();
        p.prop.infer_rest(&p.func, &p.mesh, &mut target, &mut stats);
        let e = self.refresh(p, &target, pipe);
        self.infer_dm = target;
        e
    }

    /// Aggregate the cached terms into a full [`Evaluation`], in exactly
    /// the order the one-shot pipeline accumulates: roofline terms by
    /// ascending node, collective seconds in emission order, liveness
    /// peak by the maintained segment tree. With a pipeline spec the
    /// cached tables additionally flow through the shared
    /// [`pipeline_terms`] + [`combine_pipelined`] pair.
    fn aggregate_with(&self, p: &PartirProgram, pipe: Option<&PipelineSpec>) -> Evaluation {
        let mut runtime = RuntimeEstimate::default();
        let mut collectives = CollectiveStats::default();
        for (t, cs) in self.terms.iter().zip(&self.coll) {
            runtime.add_node_term(t);
            for c in cs {
                collectives.add(c.kind, c.bytes);
                runtime.collective_seconds += c.seconds;
            }
        }
        let memory = self.live.peak();
        match pipe {
            None => combine(memory, runtime, collectives, self.device.hbm_bytes, &self.weights),
            Some(spec) => {
                let pe = pipeline_terms(
                    p,
                    &self.dm,
                    &self.device,
                    &self.terms,
                    &self.coll,
                    spec,
                    &mut collectives,
                );
                combine_pipelined(
                    memory,
                    runtime,
                    collectives,
                    pe,
                    self.device.hbm_bytes,
                    &self.weights,
                )
            }
        }
    }

    /// Stable digest of every cached term (float bits included) — lets
    /// tests prove a ledger maintained across a whole episode holds the
    /// same state as one rebuilt from scratch on the final map.
    pub fn terms_digest(&self) -> u64 {
        let mut h = crate::util::hash::Fnv64::new();
        h.usize(self.dm.num_axes);
        for row in &self.dm.d {
            h.bytes(row);
        }
        for t in &self.terms {
            h.f64(t.compute_seconds).f64(t.memory_seconds).f64(t.flops);
        }
        for cs in &self.coll {
            h.usize(cs.len());
            for c in cs {
                let kind = match c.kind {
                    CollectiveKind::AllReduce => 0u8,
                    CollectiveKind::AllGather => 1,
                    CollectiveKind::Send => 2,
                    CollectiveKind::Recv => 3,
                };
                h.byte(kind).i64(c.bytes).f64(c.seconds);
            }
        }
        let mem = self.live.peak();
        h.i64(mem.peak_bytes).i64(mem.arg_bytes).usize(mem.peak_node);
        h.finish()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::{ArgKind, GraphBuilder, TensorType, ValueId};
    use crate::partir::actions::{Action, DecisionState};
    use crate::partir::mesh::{AxisId, Mesh};

    fn big_prog() -> PartirProgram {
        // Two big weights so replication overflows a tiny device.
        let mut b = GraphBuilder::new("big");
        let x = b.arg("x", TensorType::f32(&[64, 4096]), ArgKind::Input);
        let w1 = b.arg("w1", TensorType::f32(&[4096, 16384]), ArgKind::Parameter);
        let w2 = b.arg("w2", TensorType::f32(&[16384, 4096]), ArgKind::Parameter);
        let h = b.matmul(x, w1);
        let g = b.gelu(h);
        let y = b.matmul(g, w2);
        b.output(y);
        PartirProgram::new(b.finish(), Mesh::new(&[("model", 4)]))
    }

    fn tiny_device() -> Device {
        Device { hbm_bytes: 400 << 20, ..Device::tpu_v3() } // 400 MB
    }

    #[test]
    fn replicated_overflows_sharded_fits() {
        let p = big_prog();
        let dev = tiny_device();
        let w = CostWeights::default();
        let dm0 = crate::partir::dist::DistMap::new(&p.func, &p.mesh);
        let e0 = evaluate(&p, &dm0, &dev, &w);
        assert!(!e0.fits_memory);

        let st = DecisionState {
            actions: vec![
                Action::Tile { v: ValueId(1), dim: 1, axis: AxisId(0) },
                Action::Tile { v: ValueId(2), dim: 0, axis: AxisId(0) },
            ],
            atomic: Default::default(),
        };
        let (dm, _) = p.apply(&st);
        let e1 = evaluate(&p, &dm, &dev, &w);
        assert!(e1.fits_memory, "peak={} limit={}", e1.memory.peak_bytes, dev.hbm_bytes);
        assert!(e1.cost < e0.cost);
        assert_eq!(e1.collectives.all_reduce_count, 1);
    }

    #[test]
    fn ledger_refresh_is_bit_identical_to_full_evaluate() {
        let p = big_prog();
        let dev = tiny_device();
        let w = CostWeights::default();
        let dm0 = crate::partir::dist::DistMap::new(&p.func, &p.mesh);
        let mut ledger = CostLedger::new(&p, &dm0, dev.clone(), w.clone());
        // Walk through three maps (replicated → megatron → bad) and
        // compare every incremental refresh against the full pipeline.
        let states = [
            vec![],
            vec![
                Action::Tile { v: ValueId(1), dim: 1, axis: AxisId(0) },
                Action::Tile { v: ValueId(2), dim: 0, axis: AxisId(0) },
            ],
            vec![
                Action::Tile { v: ValueId(1), dim: 0, axis: AxisId(0) },
                Action::Tile { v: ValueId(2), dim: 1, axis: AxisId(0) },
            ],
        ];
        for actions in states {
            let st = DecisionState { actions, atomic: Default::default() };
            let (dm, _) = p.apply(&st);
            let inc = ledger.refresh(&p, &dm, None);
            let full = evaluate(&p, &dm, &dev, &w);
            assert_eq!(inc, full);
            assert_eq!(inc.cost.to_bits(), full.cost.to_bits(), "cost must match to the bit");
        }
        assert!(ledger.nodes_reused > 0, "the ledger must actually reuse terms");
    }

    #[test]
    fn ledger_counts_reuse_and_recompute() {
        let p = big_prog();
        let dm0 = crate::partir::dist::DistMap::new(&p.func, &p.mesh);
        let mut ledger = CostLedger::new(&p, &dm0, tiny_device(), CostWeights::default());
        // Refreshing onto the identical map recomputes nothing.
        let _ = ledger.refresh(&p, &dm0, None);
        assert_eq!(ledger.refreshes, 1);
        assert_eq!(ledger.nodes_recomputed, 0);
        assert_eq!(ledger.nodes_reused, p.func.num_nodes());
        // One decision dirties only the touched region.
        let st = DecisionState {
            actions: vec![Action::Tile { v: ValueId(1), dim: 1, axis: AxisId(0) }],
            atomic: Default::default(),
        };
        let (dm, _) = p.apply(&st);
        let _ = ledger.refresh(&p, &dm, None);
        assert!(ledger.nodes_recomputed >= 1);
        assert!(ledger.nodes_recomputed < p.func.num_nodes());
    }

    #[test]
    fn ledger_digest_matches_a_fresh_rebuild() {
        let p = big_prog();
        let dev = tiny_device();
        let w = CostWeights::default();
        let dm0 = crate::partir::dist::DistMap::new(&p.func, &p.mesh);
        let mut ledger = CostLedger::new(&p, &dm0, dev.clone(), w.clone());
        let st = DecisionState {
            actions: vec![
                Action::Tile { v: ValueId(1), dim: 1, axis: AxisId(0) },
                Action::Tile { v: ValueId(2), dim: 0, axis: AxisId(0) },
            ],
            atomic: Default::default(),
        };
        let (dm, _) = p.apply(&st);
        let _ = ledger.refresh(&p, &dm, None);
        let fresh = CostLedger::new(&p, &dm, dev, w);
        assert_eq!(
            ledger.terms_digest(),
            fresh.terms_digest(),
            "a maintained ledger must hold the same terms as a scratch rebuild"
        );
    }

    #[test]
    fn pipelined_evaluation_prices_bubble_sends_and_stays_ledger_identical() {
        let p = big_prog();
        let dev = tiny_device();
        let w = CostWeights::default();
        // 3 nodes (matmul, gelu, matmul) → 3 single-node stages.
        let spec = PipelineSpec { axis: 0, microbatches: 4, cuts: vec![1, 2] };
        let dm = crate::partir::dist::DistMap::new(&p.func, &p.mesh);
        let e = evaluate_pipelined(&p, &dm, &dev, &w, Some(&spec));
        let pe = e.pipeline.as_ref().expect("pipelined evaluation carries terms");
        assert_eq!((pe.stages, pe.microbatches), (3, 4));
        assert_eq!(pe.cuts, vec![1, 2]);
        assert!(pe.bubble_fraction > 0.0 && pe.bubble_fraction < 1.0, "{}", pe.bubble_fraction);
        assert!(pe.makespan_seconds > 0.0);
        assert!(pe.send_recv_seconds > 0.0);
        assert!(pe.max_stage_peak_bytes > 0);
        // Two boundary hops, M sends/recvs each.
        assert_eq!(e.collectives.send_count, 8);
        assert_eq!(e.collectives.recv_count, 8);
        assert!(e.collectives.send_bytes > 0);
        assert_eq!(e.collectives.send_bytes, e.collectives.recv_bytes);
        // The flat evaluation is untouched by the pipeline terms.
        let flat = evaluate(&p, &dm, &dev, &w);
        assert_eq!(e.memory, flat.memory);
        assert_eq!(e.runtime, flat.runtime);
        assert!(flat.pipeline.is_none());
        // Ledger path is bit-identical with pipeline terms too.
        let mut ledger = CostLedger::new(&p, &dm, dev.clone(), w.clone());
        let inc = ledger.refresh(&p, &dm, Some(&spec));
        assert_eq!(inc, e);
        assert_eq!(inc.cost.to_bits(), e.cost.to_bits(), "cost must match to the bit");
    }

    #[test]
    fn megatron_beats_gather_heavy_solution() {
        let p = big_prog();
        let dev = tiny_device();
        let w = CostWeights::default();
        // Megatron: col-shard w1, row-shard w2 -> 1 all-reduce.
        let megatron = DecisionState {
            actions: vec![
                Action::Tile { v: ValueId(1), dim: 1, axis: AxisId(0) },
                Action::Tile { v: ValueId(2), dim: 0, axis: AxisId(0) },
            ],
            atomic: Default::default(),
        };
        // Bad: row-shard w1 one-sided (gathers w1) + col-shard w2.
        let bad = DecisionState {
            actions: vec![
                Action::Tile { v: ValueId(1), dim: 0, axis: AxisId(0) },
                Action::Tile { v: ValueId(2), dim: 1, axis: AxisId(0) },
            ],
            atomic: Default::default(),
        };
        let (dm_m, _) = p.apply(&megatron);
        let (dm_b, _) = p.apply(&bad);
        let em = evaluate(&p, &dm_m, &dev, &w);
        let eb = evaluate(&p, &dm_b, &dev, &w);
        assert!(em.cost < eb.cost, "megatron {} vs bad {}", em.cost, eb.cost);
    }
}
