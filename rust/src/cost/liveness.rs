//! Peak-memory estimation via liveness analysis (paper §3: "a peak
//! liveness analysis exposes an approximate memory estimate. This is a
//! conservative estimate, and XLA compilation can further improve
//! required memory through optimisations such as fusion").
//!
//! Arguments (params, optimiser state, inputs) are resident for the whole
//! program; a node's buffer is allocated at its definition and freed
//! after its last use (outputs live to the end). All sizes are per-device
//! local bytes under the given distribution.

use crate::ir::Func;
use crate::partir::dist::DistMap;
use crate::partir::mesh::Mesh;

#[derive(Debug, Clone, Default, PartialEq)]
pub struct MemoryEstimate {
    /// Peak simultaneous per-device bytes.
    pub peak_bytes: i64,
    /// Resident argument bytes (params + opt state + inputs).
    pub arg_bytes: i64,
    /// Node index where the peak occurs.
    pub peak_node: usize,
}

/// Compute the peak per-device memory of `f` under distribution `dm`.
pub fn peak_memory(f: &Func, mesh: &Mesh, dm: &DistMap) -> MemoryEstimate {
    let bytes: Vec<i64> = (0..f.num_values())
        .map(|v| f.value_type(crate::ir::ValueId(v as u32)).byte_size())
        .collect();
    peak_memory_cached(f, mesh, dm, &bytes)
}

/// Same, with a precomputed global-byte-size table (the search hot path —
/// see EXPERIMENTS.md §Perf opt 1).
///
/// Implementation is flat and allocation-light (§Perf opt 3): a value
/// defined at node `t0` with last use at `t1` occupies the interval
/// `[t0, t1]`; peak = max prefix sum of interval deltas — no nested
/// free-lists.
pub fn peak_memory_cached(f: &Func, mesh: &Mesh, dm: &DistMap, bytes: &[i64]) -> MemoryEstimate {
    LivenessTimeline::new(f, mesh, dm, bytes).peak()
}

/// The liveness interval timeline held mutable: per-value local sizes,
/// the allocate/free delta track, and the resident argument total. The
/// cost ledger keeps one of these per episode and, after an action,
/// re-points only the *changed* values' intervals; the peak is then
/// re-scanned over the maintained deltas.
///
/// All quantities are `i64` sums, so delta maintenance is exact: a
/// timeline updated value-by-value holds bit-identical state to one
/// rebuilt from scratch over the same map, and [`LivenessTimeline::peak`]
/// runs the same scan [`peak_memory_cached`] always ran.
#[derive(Debug, Clone, PartialEq)]
pub struct LivenessTimeline {
    /// Last use per value (node index); outputs pinned past the end.
    last_use: Vec<u32>,
    /// Per-device local bytes per value under the tracked distribution.
    local: Vec<i64>,
    /// `delta[t]` = bytes allocated at t minus bytes freed entering t.
    delta: Vec<i64>,
    arg_bytes: i64,
    num_args: usize,
}

impl LivenessTimeline {
    pub fn new(f: &Func, mesh: &Mesh, dm: &DistMap, bytes: &[i64]) -> LivenessTimeline {
        let num_args = f.num_args();
        let end = f.num_nodes();
        // Last use per value (node index); outputs pinned to the end.
        let mut last_use: Vec<u32> = vec![0; f.num_values()];
        for (ni, node) in f.nodes.iter().enumerate() {
            for &inp in &node.inputs {
                last_use[inp.index()] = ni as u32;
            }
        }
        for &o in &f.outputs {
            last_use[o.index()] = end as u32;
        }

        let local: Vec<i64> =
            (0..f.num_values()).map(|v| dm.local_bytes(v, bytes[v], mesh)).collect();
        let arg_bytes: i64 = local[..num_args].iter().sum();

        let mut delta: Vec<i64> = vec![0; end + 1];
        for ni in 0..end {
            let v = num_args + ni;
            let s = local[v];
            delta[ni] += s;
            let free_at = last_use[v] as usize + 1;
            if free_at <= end {
                delta[free_at] -= s;
            }
        }
        LivenessTimeline { last_use, local, delta, arg_bytes, num_args }
    }

    /// Re-point value `v`'s interval to a new local size (its
    /// distribution row changed): arguments adjust the resident total,
    /// node results adjust their allocate/free deltas by the difference.
    #[inline]
    pub fn set_value(&mut self, v: usize, new_local: i64) {
        let diff = new_local - self.local[v];
        if diff == 0 {
            return;
        }
        self.local[v] = new_local;
        if v < self.num_args {
            self.arg_bytes += diff;
            return;
        }
        let end = self.delta.len() - 1;
        let ni = v - self.num_args;
        self.delta[ni] += diff;
        let free_at = self.last_use[v] as usize + 1;
        if free_at <= end {
            self.delta[free_at] -= diff;
        }
    }

    /// Scan the maintained deltas for the peak — the same max-prefix-sum
    /// pass the one-shot path runs, so the result is identical.
    pub fn peak(&self) -> MemoryEstimate {
        let end = self.delta.len() - 1;
        let mut current = self.arg_bytes;
        let mut peak = self.arg_bytes;
        let mut peak_node = 0usize;
        for (ni, &d) in self.delta.iter().enumerate().take(end) {
            current += d;
            if current > peak {
                peak = current;
                peak_node = ni;
            }
        }
        MemoryEstimate { peak_bytes: peak, arg_bytes: self.arg_bytes, peak_node }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::{ArgKind, GraphBuilder, TensorType, ValueId};
    use crate::partir::actions::{Action, DecisionState};
    use crate::partir::mesh::AxisId;
    use crate::partir::program::PartirProgram;

    fn chain() -> PartirProgram {
        // x:[1024] -> neg -> exp -> sum  : intermediate buffers die quickly
        let mut b = GraphBuilder::new("chain");
        let x = b.arg("x", TensorType::f32(&[1024]), ArgKind::Input);
        let n = b.neg(x);
        let e = b.exp(n);
        let s = b.reduce_sum(e, vec![0]);
        b.output(s);
        PartirProgram::new(b.finish(), Mesh::new(&[("shard", 4)]))
    }

    #[test]
    fn unsharded_peak_counts_live_buffers() {
        let p = chain();
        let dm = DistMap::new(&p.func, &p.mesh);
        let m = peak_memory(&p.func, &p.mesh, &dm);
        // peak at exp: x (arg, resident) + neg + exp = 3 * 4KB
        assert_eq!(m.arg_bytes, 4096);
        assert_eq!(m.peak_bytes, 4096 * 2 + 4096);
        assert_eq!(m.peak_node, 1);
    }

    #[test]
    fn sharding_reduces_peak() {
        let p = chain();
        let st = DecisionState {
            actions: vec![Action::Tile { v: ValueId(0), dim: 0, axis: AxisId(0) }],
            atomic: Default::default(),
        };
        let (dm, _) = p.apply(&st);
        let m = peak_memory(&p.func, &p.mesh, &dm);
        // everything tiled 4-ways except the scalar sum
        assert_eq!(m.peak_bytes, (4096 * 3) / 4);
    }

    #[test]
    fn timeline_updates_match_rebuild() {
        // Maintain a timeline across a distribution change and compare
        // against one rebuilt from scratch: state and peak identical.
        let p = chain();
        let dm0 = DistMap::new(&p.func, &p.mesh);
        let bytes: Vec<i64> = (0..p.func.num_values())
            .map(|v| p.func.value_type(ValueId(v as u32)).byte_size())
            .collect();
        let mut live = LivenessTimeline::new(&p.func, &p.mesh, &dm0, &bytes);
        assert_eq!(live.peak(), peak_memory(&p.func, &p.mesh, &dm0));

        let st = DecisionState {
            actions: vec![Action::Tile { v: ValueId(0), dim: 0, axis: AxisId(0) }],
            atomic: Default::default(),
        };
        let (dm, _) = p.apply(&st);
        for v in 0..p.func.num_values() {
            if dm.d[v] != dm0.d[v] {
                live.set_value(v, dm.local_bytes(v, bytes[v], &p.mesh));
            }
        }
        let rebuilt = LivenessTimeline::new(&p.func, &p.mesh, &dm, &bytes);
        assert_eq!(live, rebuilt, "maintained timeline must equal a fresh build");
        assert_eq!(live.peak(), peak_memory(&p.func, &p.mesh, &dm));
    }

    #[test]
    fn buffers_freed_after_last_use() {
        // y = neg(x); z = neg(y); out = neg(z) — only 2 temporaries live at once.
        let mut b = GraphBuilder::new("f");
        let x = b.arg("x", TensorType::f32(&[256]), ArgKind::Input);
        let y = b.neg(x);
        let z = b.neg(y);
        let o = b.neg(z);
        b.output(o);
        let p = PartirProgram::new(b.finish(), Mesh::new(&[("s", 1)]));
        let dm = DistMap::new(&p.func, &p.mesh);
        let m = peak_memory(&p.func, &p.mesh, &dm);
        let kb = 256 * 4;
        assert_eq!(m.peak_bytes, kb * 3); // x resident + two temporaries
    }
}
