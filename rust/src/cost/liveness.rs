//! Peak-memory estimation via liveness analysis (paper §3: "a peak
//! liveness analysis exposes an approximate memory estimate. This is a
//! conservative estimate, and XLA compilation can further improve
//! required memory through optimisations such as fusion").
//!
//! Arguments (params, optimiser state, inputs) are resident for the whole
//! program; a node's buffer is allocated at its definition and freed
//! after its last use (outputs live to the end). All sizes are per-device
//! local bytes under the given distribution.

use crate::ir::Func;
use crate::partir::dist::DistMap;
use crate::partir::mesh::Mesh;

#[derive(Debug, Clone, Default, PartialEq)]
pub struct MemoryEstimate {
    /// Peak simultaneous per-device bytes.
    pub peak_bytes: i64,
    /// Resident argument bytes (params + opt state + inputs).
    pub arg_bytes: i64,
    /// Node index where the peak occurs.
    pub peak_node: usize,
}

/// Compute the peak per-device memory of `f` under distribution `dm`.
pub fn peak_memory(f: &Func, mesh: &Mesh, dm: &DistMap) -> MemoryEstimate {
    let bytes: Vec<i64> = (0..f.num_values())
        .map(|v| f.value_type(crate::ir::ValueId(v as u32)).byte_size())
        .collect();
    peak_memory_cached(f, mesh, dm, &bytes)
}

/// Same, with a precomputed global-byte-size table (the search hot path —
/// see EXPERIMENTS.md §Perf opt 1).
///
/// Implementation is flat and allocation-light (§Perf opt 3): a value
/// defined at node `t0` with last use at `t1` occupies the interval
/// `[t0, t1]`; peak = max prefix sum of interval deltas — no nested
/// free-lists.
pub fn peak_memory_cached(f: &Func, mesh: &Mesh, dm: &DistMap, bytes: &[i64]) -> MemoryEstimate {
    LivenessTimeline::new(f, mesh, dm, bytes).peak()
}

/// One segment-tree node over the delta track: the segment's total sum,
/// the maximum over its nonempty prefix sums, and the leftmost leaf
/// index achieving that maximum (matching the strict-greater linear
/// scan's first-occurrence tie-break).
#[derive(Debug, Clone, Copy, PartialEq)]
struct SegNode {
    sum: i64,
    maxp: i64,
    arg: u32,
}

/// Identity padding: contributes nothing to sums and never wins a
/// prefix-max comparison (`saturating_add` keeps `i64::MIN` absorbing).
const SEG_PAD: SegNode = SegNode { sum: 0, maxp: i64::MIN, arg: 0 };

#[inline]
fn seg_combine(l: SegNode, r: SegNode) -> SegNode {
    let cand = l.sum.saturating_add(r.maxp);
    if l.maxp >= cand {
        SegNode { sum: l.sum + r.sum, maxp: l.maxp, arg: l.arg }
    } else {
        SegNode { sum: l.sum + r.sum, maxp: cand, arg: r.arg }
    }
}

/// The liveness interval timeline held mutable: per-value local sizes,
/// the allocate/free delta track, and the resident argument total. The
/// cost ledger keeps one of these per episode and, after an action,
/// re-points only the *changed* values' intervals.
///
/// The peak (max prefix sum of the deltas) is maintained in a segment
/// tree over `delta[0..num_nodes]`: each `set_value` is at most two
/// O(log n) point updates, and [`LivenessTimeline::peak`] reads the
/// root in O(1) — no full re-scan on the search hot path.
///
/// All quantities are `i64` sums, so delta maintenance is exact, and
/// every tree node is a pure function of its leaves: a timeline updated
/// value-by-value holds bit-identical state (tree included) to one
/// rebuilt from scratch over the same map.
#[derive(Debug, Clone, PartialEq)]
pub struct LivenessTimeline {
    /// Last use per value (node index); outputs pinned past the end.
    last_use: Vec<u32>,
    /// Per-device local bytes per value under the tracked distribution.
    local: Vec<i64>,
    /// `delta[t]` = bytes allocated at t minus bytes freed entering t.
    delta: Vec<i64>,
    /// Segment tree over `delta[0..num_nodes]` (1-based heap layout,
    /// leaves at `seg_size..`; `delta[num_nodes]` is past every scan
    /// point and stays outside the tree).
    tree: Vec<SegNode>,
    seg_size: usize,
    arg_bytes: i64,
    num_args: usize,
}

impl LivenessTimeline {
    pub fn new(f: &Func, mesh: &Mesh, dm: &DistMap, bytes: &[i64]) -> LivenessTimeline {
        let num_args = f.num_args();
        let end = f.num_nodes();
        // Last use per value (node index); outputs pinned to the end.
        let mut last_use: Vec<u32> = vec![0; f.num_values()];
        for (ni, node) in f.nodes.iter().enumerate() {
            for &inp in &node.inputs {
                last_use[inp.index()] = ni as u32;
            }
        }
        for &o in &f.outputs {
            last_use[o.index()] = end as u32;
        }

        let local: Vec<i64> =
            (0..f.num_values()).map(|v| dm.local_bytes(v, bytes[v], mesh)).collect();
        let arg_bytes: i64 = local[..num_args].iter().sum();

        let mut delta: Vec<i64> = vec![0; end + 1];
        for ni in 0..end {
            let v = num_args + ni;
            let s = local[v];
            delta[ni] += s;
            let free_at = last_use[v] as usize + 1;
            if free_at <= end {
                delta[free_at] -= s;
            }
        }
        let seg_size = end.max(1).next_power_of_two();
        let mut tree = vec![SEG_PAD; 2 * seg_size];
        for (i, &d) in delta.iter().enumerate().take(end) {
            tree[seg_size + i] = SegNode { sum: d, maxp: d, arg: i as u32 };
        }
        for i in (1..seg_size).rev() {
            tree[i] = seg_combine(tree[2 * i], tree[2 * i + 1]);
        }
        LivenessTimeline { last_use, local, delta, tree, seg_size, arg_bytes, num_args }
    }

    /// Re-derive leaf `i` from the delta track and recombine its
    /// ancestors (O(log n)).
    #[inline]
    fn seg_update(&mut self, i: usize) {
        let d = self.delta[i];
        let mut p = self.seg_size + i;
        self.tree[p] = SegNode { sum: d, maxp: d, arg: i as u32 };
        p >>= 1;
        while p >= 1 {
            self.tree[p] = seg_combine(self.tree[2 * p], self.tree[2 * p + 1]);
            p >>= 1;
        }
    }

    /// Re-point value `v`'s interval to a new local size (its
    /// distribution row changed): arguments adjust the resident total,
    /// node results adjust their allocate/free deltas by the difference.
    #[inline]
    pub fn set_value(&mut self, v: usize, new_local: i64) {
        let diff = new_local - self.local[v];
        if diff == 0 {
            return;
        }
        self.local[v] = new_local;
        if v < self.num_args {
            self.arg_bytes += diff;
            return;
        }
        let end = self.delta.len() - 1;
        let ni = v - self.num_args;
        self.delta[ni] += diff;
        self.seg_update(ni);
        let free_at = self.last_use[v] as usize + 1;
        if free_at <= end {
            self.delta[free_at] -= diff;
            // `delta[end]` sits past every scan point; it has no leaf.
            if free_at < end {
                self.seg_update(free_at);
            }
        }
    }

    /// Read the maintained peak: `arg_bytes` plus the tree root's max
    /// prefix sum when positive — exactly what the strict-greater linear
    /// scan over `delta[0..num_nodes]` produced, leftmost tie-break
    /// included, now in O(1).
    pub fn peak(&self) -> MemoryEstimate {
        let root = self.tree[1];
        if root.maxp > 0 {
            MemoryEstimate {
                peak_bytes: self.arg_bytes + root.maxp,
                arg_bytes: self.arg_bytes,
                peak_node: root.arg as usize,
            }
        } else {
            MemoryEstimate {
                peak_bytes: self.arg_bytes,
                arg_bytes: self.arg_bytes,
                peak_node: 0,
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::{ArgKind, GraphBuilder, TensorType, ValueId};
    use crate::partir::actions::{Action, DecisionState};
    use crate::partir::mesh::AxisId;
    use crate::partir::program::PartirProgram;

    fn chain() -> PartirProgram {
        // x:[1024] -> neg -> exp -> sum  : intermediate buffers die quickly
        let mut b = GraphBuilder::new("chain");
        let x = b.arg("x", TensorType::f32(&[1024]), ArgKind::Input);
        let n = b.neg(x);
        let e = b.exp(n);
        let s = b.reduce_sum(e, vec![0]);
        b.output(s);
        PartirProgram::new(b.finish(), Mesh::new(&[("shard", 4)]))
    }

    #[test]
    fn unsharded_peak_counts_live_buffers() {
        let p = chain();
        let dm = DistMap::new(&p.func, &p.mesh);
        let m = peak_memory(&p.func, &p.mesh, &dm);
        // peak at exp: x (arg, resident) + neg + exp = 3 * 4KB
        assert_eq!(m.arg_bytes, 4096);
        assert_eq!(m.peak_bytes, 4096 * 2 + 4096);
        assert_eq!(m.peak_node, 1);
    }

    #[test]
    fn sharding_reduces_peak() {
        let p = chain();
        let st = DecisionState {
            actions: vec![Action::Tile { v: ValueId(0), dim: 0, axis: AxisId(0) }],
            atomic: Default::default(),
        };
        let (dm, _) = p.apply(&st);
        let m = peak_memory(&p.func, &p.mesh, &dm);
        // everything tiled 4-ways except the scalar sum
        assert_eq!(m.peak_bytes, (4096 * 3) / 4);
    }

    #[test]
    fn timeline_updates_match_rebuild() {
        // Maintain a timeline across a distribution change and compare
        // against one rebuilt from scratch: state and peak identical.
        let p = chain();
        let dm0 = DistMap::new(&p.func, &p.mesh);
        let bytes: Vec<i64> = (0..p.func.num_values())
            .map(|v| p.func.value_type(ValueId(v as u32)).byte_size())
            .collect();
        let mut live = LivenessTimeline::new(&p.func, &p.mesh, &dm0, &bytes);
        assert_eq!(live.peak(), peak_memory(&p.func, &p.mesh, &dm0));

        let st = DecisionState {
            actions: vec![Action::Tile { v: ValueId(0), dim: 0, axis: AxisId(0) }],
            atomic: Default::default(),
        };
        let (dm, _) = p.apply(&st);
        for v in 0..p.func.num_values() {
            if dm.d[v] != dm0.d[v] {
                live.set_value(v, dm.local_bytes(v, bytes[v], &p.mesh));
            }
        }
        let rebuilt = LivenessTimeline::new(&p.func, &p.mesh, &dm, &bytes);
        assert_eq!(live, rebuilt, "maintained timeline must equal a fresh build");
        assert_eq!(live.peak(), peak_memory(&p.func, &p.mesh, &dm));
    }

    #[test]
    fn segment_tree_tracks_repeated_updates_and_degenerate_peaks() {
        // y = neg(x): one leaf in the tree, free slot pinned past the end.
        let mut b = GraphBuilder::new("one");
        let x = b.arg("x", TensorType::f32(&[64]), ArgKind::Input);
        let y = b.neg(x);
        b.output(y);
        let p = PartirProgram::new(b.finish(), Mesh::new(&[("s", 2)]));
        let dm = DistMap::new(&p.func, &p.mesh);
        let bytes: Vec<i64> = (0..p.func.num_values())
            .map(|v| p.func.value_type(ValueId(v as u32)).byte_size())
            .collect();
        let mut live = LivenessTimeline::new(&p.func, &p.mesh, &dm, &bytes);
        let m = live.peak();
        assert_eq!(m.peak_bytes, 256 + 256);
        assert_eq!(m.peak_node, 0);
        // Shrink the only node buffer to zero: the max prefix sum is no
        // longer positive, so the peak falls back to the resident args.
        live.set_value(1, 0);
        assert_eq!(live.peak(), MemoryEstimate { peak_bytes: 256, arg_bytes: 256, peak_node: 0 });
        // Grow it back through several updates; every intermediate state
        // must equal a scratch rebuild (tree included — derived PartialEq).
        for sz in [8i64, 1024, 256] {
            live.set_value(1, sz);
            assert_eq!(live.peak().peak_bytes, 256 + sz);
        }
        let rebuilt = LivenessTimeline::new(&p.func, &p.mesh, &dm, &bytes);
        assert_eq!(live, rebuilt);
    }

    #[test]
    fn buffers_freed_after_last_use() {
        // y = neg(x); z = neg(y); out = neg(z) — only 2 temporaries live at once.
        let mut b = GraphBuilder::new("f");
        let x = b.arg("x", TensorType::f32(&[256]), ArgKind::Input);
        let y = b.neg(x);
        let z = b.neg(y);
        let o = b.neg(z);
        b.output(o);
        let p = PartirProgram::new(b.finish(), Mesh::new(&[("s", 1)]));
        let dm = DistMap::new(&p.func, &p.mesh);
        let m = peak_memory(&p.func, &p.mesh, &dm);
        let kb = 256 * 4;
        assert_eq!(m.peak_bytes, kb * 3); // x resident + two temporaries
    }
}
