//! Cost models guiding the search: liveness-based peak memory and the
//! composite objective (memory-fit, reduction-communication bytes,
//! simulated runtime).

pub mod composite;
pub mod liveness;

pub use composite::{evaluate, CostLedger, CostWeights, Evaluation};
pub use liveness::{peak_memory, LivenessTimeline, MemoryEstimate};
