//! Node ranking (paper §2.3): "a learned model predicts for each input
//! to the MLIR program a ranking corresponding to the importance of this
//! node to be partitioned, and the top-k (k = 25) most relevant nodes are
//! then passed to MCTS".
//!
//! Two implementations:
//!   * [`PjrtRanker`] — the real learned model: the Interaction-Network
//!     GNN trained at build time in JAX (with Pallas kernels), AOT-lowered
//!     to `artifacts/ranker.hlo.txt`, executed here through PJRT.
//!   * [`HeuristicRanker`] — deterministic fallback used when artifacts
//!     are absent (tests, cold builds): ranks by parameter size.

use super::features::{FeatureGraph, MAX_EDGES, MAX_NODES, NODE_FEATURES};
use crate::ir::ValueId;
use crate::runtime::pjrt::{Executable, Input, Runtime};
use anyhow::Result;

/// k in the paper.
pub const TOP_K: usize = 25;

pub trait Ranker {
    /// One relevance score per node slot in the feature graph.
    fn score(&self, graph: &FeatureGraph) -> Result<Vec<f32>>;
}

/// Select the top-k arg ids by score (ties broken by program order).
pub fn top_k(graph: &FeatureGraph, scores: &[f32], k: usize) -> Vec<ValueId> {
    let n = graph.arg_ids.len();
    let mut idx: Vec<usize> = (0..n).collect();
    idx.sort_by(|&a, &b| {
        scores[b].partial_cmp(&scores[a]).unwrap_or(std::cmp::Ordering::Equal).then(a.cmp(&b))
    });
    idx.into_iter().take(k).map(|i| graph.arg_ids[i]).collect()
}

/// Top-k restricted to decision targets (optimiser state is excluded —
/// it follows its parameter through infer-rest and never appears on the
/// search worklist).
pub fn top_k_decisions(
    func: &crate::ir::Func,
    graph: &FeatureGraph,
    scores: &[f32],
    k: usize,
) -> Vec<ValueId> {
    let n = graph.arg_ids.len();
    let mut idx: Vec<usize> = (0..n)
        .filter(|&i| func.args[graph.arg_ids[i].index()].kind != crate::ir::ArgKind::OptState)
        .collect();
    idx.sort_by(|&a, &b| {
        scores[b].partial_cmp(&scores[a]).unwrap_or(std::cmp::Ordering::Equal).then(a.cmp(&b))
    });
    idx.into_iter().take(k).map(|i| graph.arg_ids[i]).collect()
}

/// The learned ranker, backed by the AOT-compiled GNN.
pub struct PjrtRanker {
    exe: Executable,
}

impl PjrtRanker {
    /// Load `artifacts/ranker.hlo.txt` (or a custom path).
    pub fn load(rt: &Runtime, path: &str) -> Result<PjrtRanker> {
        Ok(PjrtRanker { exe: rt.load_hlo_text(path)? })
    }
}

impl Ranker for PjrtRanker {
    fn score(&self, g: &FeatureGraph) -> Result<Vec<f32>> {
        debug_assert_eq!(g.nodes.len(), MAX_NODES * NODE_FEATURES);
        let outs = self.exe.run_f32(&[
            Input::F32(g.nodes.clone(), vec![MAX_NODES as i64, NODE_FEATURES as i64]),
            Input::F32(g.node_mask.clone(), vec![MAX_NODES as i64]),
            Input::I32(g.senders.clone(), vec![MAX_EDGES as i64]),
            Input::I32(g.receivers.clone(), vec![MAX_EDGES as i64]),
            Input::F32(g.edge_mask.clone(), vec![MAX_EDGES as i64]),
        ])?;
        Ok(outs.into_iter().next().expect("ranker returns one output"))
    }
}

/// Size-based fallback ranker (no learning): big multi-dim parameters
/// first — roughly what a practitioner would eyeball.
pub struct HeuristicRanker<'f> {
    pub func: &'f crate::ir::Func,
}

impl<'f> Ranker for HeuristicRanker<'f> {
    fn score(&self, g: &FeatureGraph) -> Result<Vec<f32>> {
        let mut s = vec![0f32; MAX_NODES];
        for (i, &v) in g.arg_ids.iter().enumerate() {
            let a = &self.func.args[v.index()];
            let size = (a.ty.num_elements() as f32).log2();
            let rank_bonus = if a.ty.rank() >= 2 { 8.0 } else { 0.0 };
            let kind_bonus = match a.kind {
                crate::ir::ArgKind::Parameter => 4.0,
                _ => 0.0,
            };
            s[i] = size + rank_bonus + kind_bonus;
        }
        Ok(s)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::learner::features::featurize;
    use crate::models::transformer::{build_transformer, TransformerConfig};
    use crate::partir::mesh::Mesh;

    #[test]
    fn heuristic_ranks_weights_over_biases() {
        let m = build_transformer(&TransformerConfig::tiny(2));
        let mesh = Mesh::new(&[("model", 4)]);
        let g = featurize(&m.func, &mesh);
        let ranker = HeuristicRanker { func: &m.func };
        let scores = ranker.score(&g).unwrap();
        let top = top_k(&g, &scores, TOP_K);
        assert_eq!(top.len(), TOP_K);
        let top_names: Vec<&str> =
            top.iter().map(|v| m.func.args[v.index()].name.as_str()).collect();
        // all the megatron-relevant matrices of both layers fit in top-25
        for suffix in ["attn/wq", "attn/wo", "mlp/w1", "mlp/w2"] {
            for l in 0..2 {
                let want = format!("layer_{l}/{suffix}");
                assert!(
                    top_names.iter().any(|n| *n == want),
                    "{want} missing from top-k: {top_names:?}"
                );
            }
        }
    }

    #[test]
    fn top_k_is_stable_under_ties() {
        let m = build_transformer(&TransformerConfig::tiny(1));
        let g = featurize(&m.func, &Mesh::new(&[("model", 4)]));
        let scores = vec![1.0f32; MAX_NODES];
        let a = top_k(&g, &scores, 5);
        let b = top_k(&g, &scores, 5);
        assert_eq!(a, b);
        assert_eq!(a[0], g.arg_ids[0]);
    }
}
