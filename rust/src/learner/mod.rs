//! The learned component (paper §2.3): program featurization, training
//! dataset generation (best-strategy imitation), and node rankers
//! (PJRT-backed GNN + heuristic fallback) that filter the MCTS worklist
//! to the top-k most relevant arguments.

pub mod dataset;
pub mod features;
pub mod ranker;

pub use features::{featurize, FeatureGraph, MAX_EDGES, MAX_NODES, NODE_FEATURES};
pub use ranker::{top_k, HeuristicRanker, PjrtRanker, Ranker, TOP_K};
