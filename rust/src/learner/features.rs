//! Program-graph featurization for the learned node ranker (paper §2.3:
//! "Our compiler featurises operation nodes as a concatenation of
//! operation type, operand shapes, and existing partitioned axes. Edges
//! encode program dataflow and MLIR program structure.")
//!
//! Arguments are the ranked entities (the paper ranks "each input to the
//! MLIR program"). Features and padding sizes MUST stay in sync with
//! `python/compile/model.py` (checked by `artifacts/ranker_meta.json`).

use crate::ir::{Func, OpKind, ValueId};
use crate::partir::mesh::Mesh;

/// Feature vector length per node.
pub const NODE_FEATURES: usize = 40;
/// Padded node count of the ranker input.
pub const MAX_NODES: usize = 256;
/// Padded edge count.
pub const MAX_EDGES: usize = 2048;

/// Featurized program graph, padded to fixed shapes for the AOT ranker.
#[derive(Debug, Clone)]
pub struct FeatureGraph {
    /// `[MAX_NODES * NODE_FEATURES]`, row-major.
    pub nodes: Vec<f32>,
    /// `[MAX_NODES]` 1.0 for real nodes.
    pub node_mask: Vec<f32>,
    /// `[MAX_EDGES]` sender node index (0 when padded).
    pub senders: Vec<i32>,
    /// `[MAX_EDGES]` receiver node index.
    pub receivers: Vec<i32>,
    /// `[MAX_EDGES]` 1.0 for real edges.
    pub edge_mask: Vec<f32>,
    /// Which arg each node row corresponds to.
    pub arg_ids: Vec<ValueId>,
}

/// Featurize the arguments of `f` (kept in arg order, truncated to
/// `MAX_NODES` by descending byte size if necessary).
pub fn featurize(f: &Func, mesh: &Mesh) -> FeatureGraph {
    // Select up to MAX_NODES args (all, or the largest by bytes).
    let mut arg_ids: Vec<ValueId> = (0..f.num_args() as u32).map(ValueId).collect();
    if arg_ids.len() > MAX_NODES {
        arg_ids.sort_by_key(|&v| -f.value_type(v).byte_size());
        arg_ids.truncate(MAX_NODES);
        arg_ids.sort(); // restore program order
    }
    let slot_of: std::collections::HashMap<u32, usize> =
        arg_ids.iter().enumerate().map(|(i, v)| (v.0, i)).collect();

    let users = f.users();
    let mut nodes = vec![0f32; MAX_NODES * NODE_FEATURES];
    let mut node_mask = vec![0f32; MAX_NODES];
    for (slot, &v) in arg_ids.iter().enumerate() {
        node_mask[slot] = 1.0;
        let a = &f.args[v.index()];
        let row = &mut nodes[slot * NODE_FEATURES..(slot + 1) * NODE_FEATURES];
        // [0..4) arg-kind one-hot
        row[a.kind.kind_id()] = 1.0;
        // [4] rank / 4
        row[4] = a.ty.rank() as f32 / 4.0;
        // [5..9) log2(dim)/16, first 4 dims
        for (i, &d) in a.ty.dims.iter().take(4).enumerate() {
            row[5 + i] = (d as f32).log2() / 16.0;
        }
        // [9] log2(total elements)/32
        row[9] = (a.ty.num_elements().max(1) as f32).log2() / 32.0;
        // [10] float flag
        row[10] = if a.ty.dtype.is_float() { 1.0 } else { 0.0 };
        // [11] log2(1+fanout)/8
        row[11] = (1.0 + users[v.index()].len() as f32).log2() / 8.0;
        // [12] fraction of dims divisible by every searchable axis size
        let axes = mesh.searchable_axes();
        if a.ty.rank() > 0 && !axes.is_empty() {
            let div = a
                .ty
                .dims
                .iter()
                .filter(|&&d| axes.iter().all(|&ax| d % mesh.size(ax) == 0))
                .count();
            row[12] = div as f32 / a.ty.rank() as f32;
        }
        // [13] square-matrix flag (attention projections)
        if a.ty.rank() == 2 && a.ty.dims[0] == a.ty.dims[1] {
            row[13] = 1.0;
        }
        // [14..40) consumer op-kind histogram (normalised)
        let mut hist = [0f32; OpKind::NUM_KINDS];
        for &ni in &users[v.index()] {
            hist[f.nodes[ni].op.kind_id()] += 1.0;
        }
        let total: f32 = hist.iter().sum();
        if total > 0.0 {
            for (i, h) in hist.iter().enumerate() {
                row[14 + i] = h / total;
            }
        }
    }

    // Edges: co-consumption (two args feeding the same node), both
    // directions, deduplicated, capped at MAX_EDGES.
    let mut senders = vec![0i32; MAX_EDGES];
    let mut receivers = vec![0i32; MAX_EDGES];
    let mut edge_mask = vec![0f32; MAX_EDGES];
    let mut seen = std::collections::HashSet::new();
    let mut ne = 0usize;
    'outer: for node in &f.nodes {
        let arg_inputs: Vec<usize> = node
            .inputs
            .iter()
            .filter_map(|&x| slot_of.get(&x.0).copied())
            .collect();
        for (ia, &sa) in arg_inputs.iter().enumerate() {
            for &sb in arg_inputs.iter().skip(ia + 1) {
                for (s, r) in [(sa, sb), (sb, sa)] {
                    if s != r && seen.insert((s, r)) {
                        if ne >= MAX_EDGES {
                            break 'outer;
                        }
                        senders[ne] = s as i32;
                        receivers[ne] = r as i32;
                        edge_mask[ne] = 1.0;
                        ne += 1;
                    }
                }
            }
        }
    }

    FeatureGraph { nodes, node_mask, senders, receivers, edge_mask, arg_ids }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::transformer::{build_transformer, TransformerConfig};
    use crate::partir::mesh::Mesh;

    #[test]
    fn featurizes_tiny_transformer() {
        let m = build_transformer(&TransformerConfig::tiny(2));
        let mesh = Mesh::new(&[("model", 4)]);
        let g = featurize(&m.func, &mesh);
        let n_args = m.func.num_args().min(MAX_NODES);
        assert_eq!(g.arg_ids.len(), n_args);
        assert_eq!(g.node_mask.iter().filter(|&&x| x == 1.0).count(), n_args);
        assert_eq!(g.nodes.len(), MAX_NODES * NODE_FEATURES);
        // wq is a square matrix: flag set
        let wq_slot = g
            .arg_ids
            .iter()
            .position(|&v| m.func.args[v.index()].name.ends_with("attn/wq"))
            .unwrap();
        assert_eq!(g.nodes[wq_slot * NODE_FEATURES + 13], 1.0);
        // some real edges exist and indices are in range
        let ne = g.edge_mask.iter().filter(|&&x| x == 1.0).count();
        assert!(ne > 0);
        for e in 0..ne {
            assert!((g.senders[e] as usize) < n_args);
            assert!((g.receivers[e] as usize) < n_args);
        }
    }

    #[test]
    fn truncates_to_largest_args_at_paper_scale() {
        // 1150+ args -> top 256 by size, params dominate.
        let m = build_transformer(&TransformerConfig::tiny(40)); // 40*48+9 args
        let mesh = Mesh::new(&[("model", 4)]);
        let g = featurize(&m.func, &mesh);
        assert_eq!(g.arg_ids.len(), MAX_NODES);
        // every kept node is at least as large as the dropped scalar-ish ones
        let kept_min = g
            .arg_ids
            .iter()
            .map(|&v| m.func.value_type(v).byte_size())
            .min()
            .unwrap();
        assert!(kept_min >= 4);
    }

    #[test]
    fn features_are_bounded() {
        let m = build_transformer(&TransformerConfig::tiny(1));
        let g = featurize(&m.func, &Mesh::new(&[("model", 4)]));
        for &x in &g.nodes {
            assert!(x.is_finite() && (-1.0..=4.0).contains(&x), "feature {x} out of range");
        }
    }
}
