//! Training-set generation for the learned ranker (paper §3: "To
//! generate training data, we selected random model arguments ... and
//! exhaustively partitioned all argument dimensions. Our model was
//! trained to imitate the highest scoring strategy.")
//!
//! We sample transformer variants, find the best strategy by greedy
//! exhaustive improvement over all (argument, dim) tilings under the
//! real cost model, and label the arguments participating in that
//! strategy. Exported as JSON for `python/compile/train.py`
//! (paper: 20k variants; default here is CI-scale and configurable).

use super::features::{featurize, FeatureGraph};
use crate::cost::composite::{evaluate, CostWeights};
use crate::models::transformer::{build_transformer, TransformerConfig};
use crate::partir::actions::{action_valid, Action, DecisionState};
use crate::partir::mesh::Mesh;
use crate::partir::program::PartirProgram;
use crate::search::env::role_key;
use crate::sim::device::Device;
use crate::util::json::Json;
use crate::util::rng::Rng;

/// One labelled sample: a featurized program with per-node labels.
pub struct Sample {
    pub graph: FeatureGraph,
    /// `[MAX_NODES]`: 1.0 if the arg participates in the best strategy.
    pub labels: Vec<f32>,
}

/// Sample a random small transformer variant. Proportions follow the
/// paper's regime (layer weights dominate memory: d_ff = 4·d_model,
/// modest vocab/seq), scaled down for build-time tractability.
pub fn random_variant(rng: &mut Rng) -> TransformerConfig {
    let d_model = *rng.choose(&[64i64, 128, 256]);
    let n_heads = *rng.choose(&[2i64, 4]);
    let ff_mult = *rng.choose(&[4i64, 8]);
    TransformerConfig {
        layers: 1 + rng.gen_range(3),
        d_model,
        n_heads,
        d_ff: d_model * ff_mult,
        vocab: *rng.choose(&[128i64, 256]),
        seq: *rng.choose(&[16i64, 32]),
        batch: 1 + rng.gen_range(2) as i64,
        training: true,
    }
}

/// Greedy exhaustive improvement: repeatedly apply the single
/// (cross-layer-tied) tile action that lowers cost the most, until no
/// action improves. Returns the chosen actions.
pub fn best_strategy(program: &PartirProgram, dev: &Device, w: &CostWeights) -> DecisionState {
    let f = &program.func;
    let mesh = &program.mesh;
    let mut state = DecisionState::default();
    let (mut dm, _) = program.apply(&state);
    let mut current = evaluate(program, &dm, dev, w).cost;

    // Candidate actions: one representative arg per role key, all dims/axes.
    let mut reps: Vec<(String, crate::ir::ValueId)> = Vec::new();
    for i in 0..f.num_args() {
        if f.args[i].kind == crate::ir::ArgKind::OptState {
            continue;
        }
        let key = role_key(&f.args[i].name);
        if !reps.iter().any(|(k, _)| *k == key) {
            reps.push((key, crate::ir::ValueId(i as u32)));
        }
    }

    loop {
        let mut best: Option<(f64, Vec<Action>)> = None;
        for (key, v) in &reps {
            let rank = f.value_type(*v).rank();
            for axis in mesh.searchable_axes() {
                for dim in 0..rank {
                    let probe = Action::Tile { v: *v, dim, axis };
                    if !action_valid(f, mesh, &dm, &state, &probe) {
                        continue;
                    }
                    // Tie across all args with the same role key.
                    let tied: Vec<Action> = (0..f.num_args())
                        .filter(|&i| {
                            f.args[i].kind != crate::ir::ArgKind::OptState
                                && role_key(&f.args[i].name) == *key
                        })
                        .map(|i| Action::Tile { v: crate::ir::ValueId(i as u32), dim, axis })
                        .collect();
                    let mut trial = state.clone();
                    trial.actions.extend(tied.iter().copied());
                    trial.actions.push(Action::InferRest);
                    let (tdm, _) = program.apply(&trial);
                    let cost = evaluate(program, &tdm, dev, w).cost;
                    if cost < current - 1e-12
                        && best.as_ref().map(|(c, _)| cost < *c).unwrap_or(true)
                    {
                        best = Some((cost, tied));
                    }
                }
            }
        }
        match best {
            Some((cost, tied)) => {
                state.actions.extend(tied);
                current = cost;
                let (ndm, _) = program.apply(&state);
                dm = ndm;
            }
            None => break,
        }
    }
    state.actions.push(Action::InferRest);
    state
}

/// Generate one labelled sample from a variant config.
pub fn make_sample(cfg: &TransformerConfig, axis_size: i64) -> Sample {
    let model = build_transformer(cfg);
    let mesh = Mesh::new(&[("model", axis_size)]);
    let program = PartirProgram::new(model.func.clone(), mesh);
    let w = CostWeights::default();
    // Memory-pressured device relative to this variant.
    let dm0 = crate::partir::dist::DistMap::new(&program.func, &program.mesh);
    let probe = evaluate(&program, &dm0, &Device::tpu_v3(), &w);
    let dev = Device {
        hbm_bytes: (probe.memory.peak_bytes as f64 * 0.3) as i64,
        ..Device::tpu_v3()
    };
    let strategy = best_strategy(&program, &dev, &w);
    // Label every argument that ends up tiled in the best strategy's
    // final distribution (explicit decisions + infer-rest closure): these
    // are the "important to be partitioned" nodes the ranker imitates.
    let (final_dm, _) = program.apply(&strategy);
    let graph = featurize(&program.func, &program.mesh);
    // Optimiser state follows its parameter through infer-rest and is
    // never a worklist entry — exclude it from the positives so the
    // top-k budget goes to actual decision targets.
    let labels: Vec<f32> = graph
        .arg_ids
        .iter()
        .map(|v| {
            let tiled = final_dm.is_tiled(v.index());
            let is_opt = program.func.args[v.index()].kind == crate::ir::ArgKind::OptState;
            if tiled && !is_opt {
                1.0
            } else {
                0.0
            }
        })
        .collect();
    let mut padded = vec![0f32; super::features::MAX_NODES];
    padded[..labels.len()].copy_from_slice(&labels);
    Sample { graph, labels: padded }
}

/// Generate `count` samples and serialise to JSON.
pub fn generate_dataset(count: usize, seed: u64, axis_size: i64) -> Json {
    let mut rng = Rng::new(seed);
    let mut samples = Vec::with_capacity(count);
    for _ in 0..count {
        let cfg = random_variant(&mut rng);
        let s = make_sample(&cfg, axis_size);
        samples.push(sample_to_json(&s));
    }
    Json::obj(vec![
        ("node_features", Json::num(super::features::NODE_FEATURES as f64)),
        ("max_nodes", Json::num(super::features::MAX_NODES as f64)),
        ("max_edges", Json::num(super::features::MAX_EDGES as f64)),
        ("samples", Json::Arr(samples)),
    ])
}

fn sample_to_json(s: &Sample) -> Json {
    let f32s = |xs: &[f32]| Json::Arr(xs.iter().map(|&x| Json::Num(x as f64)).collect());
    let i32s = |xs: &[i32]| Json::Arr(xs.iter().map(|&x| Json::Num(x as f64)).collect());
    Json::obj(vec![
        ("nodes", f32s(&s.graph.nodes)),
        ("node_mask", f32s(&s.graph.node_mask)),
        ("senders", i32s(&s.graph.senders)),
        ("receivers", i32s(&s.graph.receivers)),
        ("edge_mask", f32s(&s.graph.edge_mask)),
        ("labels", f32s(&s.labels)),
    ])
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn greedy_strategy_recovers_megatron_labels() {
        // On a weight-dominated variant (the paper's regime) the greedy
        // search should select the attention/MLP weight matrices (the
        // Megatron set). On activation-dominated tiny variants the best
        // strategy is legitimately different (e.g. vocab sharding).
        let cfg = TransformerConfig {
            layers: 1,
            d_model: 128,
            n_heads: 4,
            d_ff: 1024,
            vocab: 128,
            seq: 16,
            batch: 1,
            training: true,
        };
        let s = make_sample(&cfg, 4);
        let model = build_transformer(&cfg);
        let mesh = Mesh::new(&[("model", 4)]);
        let program = PartirProgram::new(model.func.clone(), mesh);
        let g = featurize(&program.func, &program.mesh);
        let mut labelled_names: Vec<String> = g
            .arg_ids
            .iter()
            .zip(&s.labels)
            .filter(|(_, &l)| l == 1.0)
            .map(|(v, _)| program.func.args[v.index()].name.clone())
            .collect();
        labelled_names.sort();
        let has = |suffix: &str| labelled_names.iter().any(|n| n.ends_with(suffix));
        assert!(has("mlp/w1"), "labels: {labelled_names:?}");
        assert!(has("mlp/w2"), "labels: {labelled_names:?}");
        assert!(has("attn/wq") || has("attn/wv"), "labels: {labelled_names:?}");
    }

    #[test]
    fn dataset_json_roundtrips() {
        let j = generate_dataset(2, 9, 4);
        let txt = j.to_string();
        let back = crate::util::json::parse(&txt).unwrap();
        assert_eq!(back.get("samples").unwrap().as_arr().unwrap().len(), 2);
        assert_eq!(
            back.get("node_features").unwrap().as_usize().unwrap(),
            super::super::features::NODE_FEATURES
        );
    }

    #[test]
    fn variants_are_diverse_and_divisible() {
        let mut rng = Rng::new(4);
        let mut dims = std::collections::HashSet::new();
        for _ in 0..20 {
            let c = random_variant(&mut rng);
            dims.insert(c.d_model);
            assert_eq!(c.d_model % c.n_heads, 0);
            assert_eq!(c.d_model % 4, 0);
        }
        assert!(dims.len() >= 2);
    }
}
