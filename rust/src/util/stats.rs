//! Small descriptive-statistics helpers shared by the figure harnesses
//! and the bench harness.

/// Mean of a slice (0.0 for empty input).
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Population standard deviation.
pub fn std_dev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / xs.len() as f64).sqrt()
}

/// p-th quantile (0..=1) by nearest-rank on a copy.
pub fn quantile(xs: &[f64], p: f64) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let idx = (p.clamp(0.0, 1.0) * (v.len() - 1) as f64).round() as usize;
    v[idx]
}

pub fn median(xs: &[f64]) -> f64 {
    quantile(xs, 0.5)
}

/// `part / whole` as a fraction, 0.0 when `whole` is 0 — the one
/// definition of a hit/reuse rate shared by the search-cache counters
/// (`SearchStats`, `ServeSummary`, the throughput report).
pub fn fraction(part: u64, whole: u64) -> f64 {
    part as f64 / whole.max(1) as f64
}

/// Fraction of values satisfying a predicate — used for success rates.
pub fn rate<T, F: Fn(&T) -> bool>(xs: &[T], pred: F) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().filter(|x| pred(x)).count() as f64 / xs.len() as f64
}

/// Format a byte count human-readably.
pub fn fmt_bytes(b: f64) -> String {
    if b < 1e3 {
        format!("{b:.0}B")
    } else if b < 1e6 {
        format!("{:.1}KB", b / 1e3)
    } else if b < 1e9 {
        format!("{:.1}MB", b / 1e6)
    } else {
        format!("{:.2}GB", b / 1e9)
    }
}

/// Format seconds human-readably.
pub fn fmt_secs(s: f64) -> String {
    if s < 1e-6 {
        format!("{:.1}ns", s * 1e9)
    } else if s < 1e-3 {
        format!("{:.1}us", s * 1e6)
    } else if s < 1.0 {
        format!("{:.2}ms", s * 1e3)
    } else {
        format!("{s:.3}s")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn basic_stats() {
        let xs = [1.0, 2.0, 3.0, 4.0, 5.0];
        assert_eq!(mean(&xs), 3.0);
        assert_eq!(median(&xs), 3.0);
        assert!((std_dev(&xs) - (2.0f64).sqrt()).abs() < 1e-12);
        assert_eq!(quantile(&xs, 0.0), 1.0);
        assert_eq!(quantile(&xs, 1.0), 5.0);
    }

    #[test]
    fn fraction_handles_zero_denominator() {
        assert_eq!(fraction(3, 4), 0.75);
        assert_eq!(fraction(0, 10), 0.0);
        assert_eq!(fraction(0, 0), 0.0);
        assert_eq!(fraction(5, 5), 1.0);
    }

    #[test]
    fn rate_counts_predicate() {
        let xs = [1, 2, 3, 4];
        assert_eq!(rate(&xs, |x| *x % 2 == 0), 0.5);
        let empty: [i32; 0] = [];
        assert_eq!(rate(&empty, |_| true), 0.0);
    }

    #[test]
    fn formatting() {
        assert_eq!(fmt_bytes(2.6e10), "26.00GB");
        assert_eq!(fmt_secs(0.0025), "2.50ms");
    }
}
