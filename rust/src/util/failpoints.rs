//! Deterministic fault-injection harness (DESIGN.md §14).
//!
//! A **failpoint** is a named site in the code where a fault can be
//! injected on demand: a worker thread panic, a disk read/write error,
//! a slow search round. Production binaries carry the sites but they
//! compile down to one relaxed atomic load when nothing is armed — the
//! hot path never pays for the harness.
//!
//! Arming is textual (`PALLAS_FAILPOINTS=worker.panic=0.5@11`) or
//! programmatic ([`Failpoints::arm`]). Every armed failpoint carries a
//! probability and a seed, and each *draw* hashes
//! `(seed, name, site-key)` through SplitMix64 — a pure function, so a
//! fault schedule reproduces exactly across runs and across machines.
//! Callers on concurrent paths pass an explicit site key
//! ([`Failpoints::should_fail_at`]) so the schedule does not depend on
//! thread interleaving; serial paths use the per-failpoint draw counter
//! ([`Failpoints::should_fail`]).
//!
//! The registry is process-global ([`failpoints()`]) because faults
//! must reach code (the disk tier, worker threads) that has no request
//! context to thread a handle through. Tests that arm the global
//! registry must serialise on a lock and disarm afterwards.

use crate::util::hash::Fnv64;
use std::collections::HashMap;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Mutex, OnceLock};

/// Panic inside an MCTS worker thread at a round barrier.
pub const WORKER_PANIC: &str = "worker.panic";
/// I/O error on a disk-tier record read.
pub const DISK_READ_ERR: &str = "disk.read_err";
/// I/O error on a disk-tier append or compaction write.
pub const DISK_WRITE_ERR: &str = "disk.write_err";
/// Sleep [`SLOW_ROUND_SLEEP_MS`](crate::service::executor::SLOW_ROUND_SLEEP_MS)
/// inside a worker's search round (exercises deadlines).
pub const SEARCH_SLOW_ROUND: &str = "search.slow_round";
/// Corrupt a pulled sync frame in flight (anti-entropy, DESIGN.md §15):
/// the frame must be quarantined, never applied and never fatal.
pub const SYNC_FRAME_CORRUPT: &str = "sync.frame_corrupt";
/// Drop the connection to a sync peer mid-pull: the round retries with
/// capped deterministic backoff, then skips the peer.
pub const SYNC_CONN_DROP: &str = "sync.conn_drop";
/// Tear a sync snapshot publish partway through the write: the atomic
/// tmp+rename publish must leave the previous snapshot serving.
pub const SYNC_PARTIAL_WRITE: &str = "sync.partial_write";

/// Every failpoint the codebase defines. `arm_spec` rejects names
/// outside this list so a typo in `PALLAS_FAILPOINTS` fails loudly
/// instead of silently arming nothing.
pub const ALL: &[&str] = &[
    WORKER_PANIC,
    DISK_READ_ERR,
    DISK_WRITE_ERR,
    SEARCH_SLOW_ROUND,
    SYNC_FRAME_CORRUPT,
    SYNC_CONN_DROP,
    SYNC_PARTIAL_WRITE,
];

struct Armed {
    prob: f64,
    seed: u64,
    /// Serial-path draw counter (the site key when none is supplied).
    draws: AtomicU64,
    /// How many draws actually fired (for tests and diagnostics).
    fired: AtomicU64,
}

/// A registry of armed failpoints. The process-global instance is
/// [`failpoints()`]; tests construct private instances.
#[derive(Default)]
pub struct Failpoints {
    /// Fast-path guard: `false` means NOTHING is armed and every
    /// `should_fail*` call returns after one relaxed load.
    any_armed: AtomicBool,
    table: Mutex<HashMap<&'static str, Armed>>,
}

impl Failpoints {
    pub fn new() -> Self {
        Self::default()
    }

    /// Arm `name` to fire with probability `prob` under `seed`.
    pub fn arm(&self, name: &str, prob: f64, seed: u64) -> anyhow::Result<()> {
        let name = ALL
            .iter()
            .find(|&&n| n == name)
            .copied()
            .ok_or_else(|| anyhow::anyhow!("unknown failpoint \"{name}\" (known: {ALL:?})"))?;
        if !(0.0..=1.0).contains(&prob) {
            anyhow::bail!("failpoint \"{name}\": probability {prob} is outside [0, 1]");
        }
        let mut t = self.table.lock().unwrap();
        t.insert(
            name,
            Armed { prob, seed, draws: AtomicU64::new(0), fired: AtomicU64::new(0) },
        );
        self.any_armed.store(true, Ordering::Release);
        Ok(())
    }

    /// Arm from a spec string: `name=prob[@seed][,name=prob[@seed]]...`
    /// (seed defaults to 0). Empty specs are a no-op.
    pub fn arm_spec(&self, spec: &str) -> anyhow::Result<()> {
        for part in spec.split(',').map(str::trim).filter(|p| !p.is_empty()) {
            let (name, rest) = part
                .split_once('=')
                .ok_or_else(|| anyhow::anyhow!("failpoint spec \"{part}\": expected name=prob[@seed]"))?;
            let (prob_s, seed_s) = match rest.split_once('@') {
                Some((p, s)) => (p, Some(s)),
                None => (rest, None),
            };
            let prob: f64 = prob_s
                .trim()
                .parse()
                .map_err(|_| anyhow::anyhow!("failpoint spec \"{part}\": bad probability \"{prob_s}\""))?;
            let seed: u64 = match seed_s {
                Some(s) => s
                    .trim()
                    .parse()
                    .map_err(|_| anyhow::anyhow!("failpoint spec \"{part}\": bad seed \"{s}\""))?,
                None => 0,
            };
            self.arm(name.trim(), prob, seed)?;
        }
        Ok(())
    }

    /// Disarm everything, restoring the one-atomic-load fast path.
    pub fn disarm_all(&self) {
        let mut t = self.table.lock().unwrap();
        t.clear();
        self.any_armed.store(false, Ordering::Release);
    }

    /// How many times `name` actually fired since it was armed.
    pub fn fired(&self, name: &str) -> u64 {
        if !self.any_armed.load(Ordering::Acquire) {
            return 0;
        }
        let t = self.table.lock().unwrap();
        t.get(name).map(|a| a.fired.load(Ordering::Relaxed)).unwrap_or(0)
    }

    /// Serial-path draw: the site key is the failpoint's own draw
    /// counter. Deterministic only when calls to this failpoint happen
    /// in a deterministic order (single-threaded paths).
    pub fn should_fail(&self, name: &str) -> bool {
        if !self.any_armed.load(Ordering::Relaxed) {
            return false;
        }
        let t = self.table.lock().unwrap();
        let Some(a) = t.get(name) else { return false };
        let site = a.draws.fetch_add(1, Ordering::Relaxed);
        Self::draw(a, name, site)
    }

    /// Concurrent-path draw: the caller supplies the site key (e.g.
    /// `round << 32 | worker`), making the schedule independent of
    /// thread interleaving.
    pub fn should_fail_at(&self, name: &str, site: u64) -> bool {
        if !self.any_armed.load(Ordering::Relaxed) {
            return false;
        }
        let t = self.table.lock().unwrap();
        let Some(a) = t.get(name) else { return false };
        Self::draw(a, name, site)
    }

    fn draw(a: &Armed, name: &str, site: u64) -> bool {
        if a.prob <= 0.0 {
            return false;
        }
        let mut h = Fnv64::new();
        h.bytes(name.as_bytes());
        let mut z = a.seed ^ h.finish() ^ site.wrapping_mul(0x9e3779b97f4a7c15);
        // SplitMix64 finaliser: full avalanche, so adjacent sites and
        // seeds decorrelate.
        z = z.wrapping_add(0x9e3779b97f4a7c15);
        z = (z ^ (z >> 30)).wrapping_mul(0xbf58476d1ce4e5b9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94d049bb133111eb);
        z ^= z >> 31;
        // Top 53 bits → uniform f64 in [0, 1).
        let u = (z >> 11) as f64 / (1u64 << 53) as f64;
        let fire = u < a.prob;
        if fire {
            a.fired.fetch_add(1, Ordering::Relaxed);
        }
        fire
    }
}

/// The process-global registry every instrumented site consults.
pub fn failpoints() -> &'static Failpoints {
    static GLOBAL: OnceLock<Failpoints> = OnceLock::new();
    GLOBAL.get_or_init(Failpoints::new)
}

/// Arm the global registry from `PALLAS_FAILPOINTS`, if set. Called by
/// the CLI entry points; library users call [`Failpoints::arm_spec`].
pub fn arm_from_env() -> anyhow::Result<()> {
    if let Ok(spec) = std::env::var("PALLAS_FAILPOINTS") {
        failpoints().arm_spec(&spec)?;
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn unarmed_registry_never_fires() {
        let fp = Failpoints::new();
        assert!(!fp.should_fail(WORKER_PANIC));
        assert!(!fp.should_fail_at(DISK_READ_ERR, 42));
        assert_eq!(fp.fired(WORKER_PANIC), 0);
    }

    #[test]
    fn draws_are_a_pure_function_of_seed_name_site() {
        let a = Failpoints::new();
        let b = Failpoints::new();
        a.arm(WORKER_PANIC, 0.5, 11).unwrap();
        b.arm(WORKER_PANIC, 0.5, 11).unwrap();
        let sched_a: Vec<bool> = (0..64).map(|s| a.should_fail_at(WORKER_PANIC, s)).collect();
        let sched_b: Vec<bool> = (0..64).map(|s| b.should_fail_at(WORKER_PANIC, s)).collect();
        assert_eq!(sched_a, sched_b, "same (seed, name, site) ⇒ same schedule");
        assert!(sched_a.iter().any(|&f| f), "p=0.5 over 64 sites must fire");
        assert!(sched_a.iter().any(|&f| !f), "p=0.5 over 64 sites must also pass");
    }

    #[test]
    fn different_seeds_and_names_decorrelate() {
        let fp = Failpoints::new();
        fp.arm(WORKER_PANIC, 0.5, 1).unwrap();
        fp.arm(DISK_READ_ERR, 0.5, 1).unwrap();
        let by_name: Vec<(bool, bool)> = (0..64)
            .map(|s| (fp.should_fail_at(WORKER_PANIC, s), fp.should_fail_at(DISK_READ_ERR, s)))
            .collect();
        assert!(by_name.iter().any(|&(a, b)| a != b), "names must not share a schedule");
        let fp2 = Failpoints::new();
        fp2.arm(WORKER_PANIC, 0.5, 2).unwrap();
        let differs = (0..64).any(|s| fp.should_fail_at(WORKER_PANIC, s) != fp2.should_fail_at(WORKER_PANIC, s));
        assert!(differs, "seeds must not share a schedule");
    }

    #[test]
    fn probability_extremes_are_exact() {
        let fp = Failpoints::new();
        fp.arm(DISK_WRITE_ERR, 1.0, 3).unwrap();
        fp.arm(SEARCH_SLOW_ROUND, 0.0, 3).unwrap();
        for s in 0..32 {
            assert!(fp.should_fail_at(DISK_WRITE_ERR, s), "p=1 always fires");
            assert!(!fp.should_fail_at(SEARCH_SLOW_ROUND, s), "p=0 never fires");
        }
        assert_eq!(fp.fired(DISK_WRITE_ERR), 32);
        assert_eq!(fp.fired(SEARCH_SLOW_ROUND), 0);
    }

    #[test]
    fn spec_strings_parse_and_reject_garbage() {
        let fp = Failpoints::new();
        fp.arm_spec("worker.panic=0.5@11, disk.read_err=0.25").unwrap();
        assert!(fp.should_fail_at(WORKER_PANIC, 0) || !fp.should_fail_at(WORKER_PANIC, 0));
        assert!(fp.arm_spec("no.such.failpoint=0.5").is_err());
        assert!(fp.arm_spec("worker.panic").is_err());
        assert!(fp.arm_spec("worker.panic=nope").is_err());
        assert!(fp.arm_spec("worker.panic=0.5@nope").is_err());
        assert!(fp.arm_spec("worker.panic=1.5").is_err());
        fp.arm_spec("").unwrap();
        fp.arm_spec(" , ").unwrap();
    }

    #[test]
    fn serial_draws_advance_the_counter() {
        let fp = Failpoints::new();
        fp.arm(DISK_READ_ERR, 0.5, 9).unwrap();
        let first: Vec<bool> = (0..32).map(|_| fp.should_fail(DISK_READ_ERR)).collect();
        assert!(first.iter().any(|&f| f) && first.iter().any(|&f| !f));
        // Counter-keyed draws match explicit-site draws over the same range.
        let fp2 = Failpoints::new();
        fp2.arm(DISK_READ_ERR, 0.5, 9).unwrap();
        let keyed: Vec<bool> = (0..32).map(|s| fp2.should_fail_at(DISK_READ_ERR, s)).collect();
        assert_eq!(first, keyed);
    }

    #[test]
    fn disarm_restores_the_fast_path() {
        let fp = Failpoints::new();
        fp.arm(WORKER_PANIC, 1.0, 0).unwrap();
        assert!(fp.should_fail_at(WORKER_PANIC, 0));
        fp.disarm_all();
        assert!(!fp.should_fail_at(WORKER_PANIC, 0));
        assert_eq!(fp.fired(WORKER_PANIC), 0, "disarm clears fire counts");
    }
}
