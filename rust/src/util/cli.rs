//! Tiny command-line flag parser — substrate replacing `clap`
//! (registry unavailable offline; DESIGN.md §3).
//!
//! Supports `--flag value`, `--flag=value`, boolean `--flag`, and
//! positional arguments. Unknown flags are an error so typos surface.

use std::collections::BTreeMap;

#[derive(Debug, Clone)]
pub struct Args {
    flags: BTreeMap<String, String>,
    bools: Vec<String>,
    pub positional: Vec<String>,
    known: Vec<String>,
}

#[derive(Debug)]
pub struct CliError(pub String);

impl std::fmt::Display for CliError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}", self.0)
    }
}
impl std::error::Error for CliError {}

impl Args {
    /// Parse `argv` given the set of value-taking flags and boolean flags
    /// (names without the leading `--`).
    pub fn parse(
        argv: &[String],
        value_flags: &[&str],
        bool_flags: &[&str],
    ) -> Result<Args, CliError> {
        let mut a = Args {
            flags: BTreeMap::new(),
            bools: Vec::new(),
            positional: Vec::new(),
            known: value_flags
                .iter()
                .chain(bool_flags.iter())
                .map(|s| s.to_string())
                .collect(),
        };
        let mut i = 0;
        while i < argv.len() {
            let tok = &argv[i];
            if let Some(stripped) = tok.strip_prefix("--") {
                let (name, inline_val) = match stripped.split_once('=') {
                    Some((n, v)) => (n.to_string(), Some(v.to_string())),
                    None => (stripped.to_string(), None),
                };
                if bool_flags.contains(&name.as_str()) {
                    if inline_val.is_some() {
                        return Err(CliError(format!("--{name} takes no value")));
                    }
                    a.bools.push(name);
                } else if value_flags.contains(&name.as_str()) {
                    let val = match inline_val {
                        Some(v) => v,
                        None => {
                            i += 1;
                            argv.get(i)
                                .cloned()
                                .ok_or_else(|| CliError(format!("--{name} needs a value")))?
                        }
                    };
                    a.flags.insert(name, val);
                } else {
                    return Err(CliError(format!(
                        "unknown flag --{name} (known: {})",
                        a.known.join(", ")
                    )));
                }
            } else {
                a.positional.push(tok.clone());
            }
            i += 1;
        }
        Ok(a)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.flags.get(name).map(|s| s.as_str())
    }

    pub fn get_bool(&self, name: &str) -> bool {
        self.bools.iter().any(|b| b == name)
    }

    pub fn get_usize(&self, name: &str, default: usize) -> Result<usize, CliError> {
        match self.get(name) {
            None => Ok(default),
            Some(s) => s.parse().map_err(|_| CliError(format!("--{name}: bad integer '{s}'"))),
        }
    }

    pub fn get_u64(&self, name: &str, default: u64) -> Result<u64, CliError> {
        match self.get(name) {
            None => Ok(default),
            Some(s) => s.parse().map_err(|_| CliError(format!("--{name}: bad integer '{s}'"))),
        }
    }

    pub fn get_f64(&self, name: &str, default: f64) -> Result<f64, CliError> {
        match self.get(name) {
            None => Ok(default),
            Some(s) => s.parse().map_err(|_| CliError(format!("--{name}: bad float '{s}'"))),
        }
    }

    pub fn get_str(&self, name: &str, default: &str) -> String {
        self.get(name).unwrap_or(default).to_string()
    }

    /// Parse a comma-separated list of usizes, e.g. `--budgets 100,500,1000`.
    pub fn get_usize_list(&self, name: &str, default: &[usize]) -> Result<Vec<usize>, CliError> {
        match self.get(name) {
            None => Ok(default.to_vec()),
            Some(s) => s
                .split(',')
                .map(|t| {
                    t.trim()
                        .parse()
                        .map_err(|_| CliError(format!("--{name}: bad integer '{t}'")))
                })
                .collect(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn argv(s: &[&str]) -> Vec<String> {
        s.iter().map(|x| x.to_string()).collect()
    }

    #[test]
    fn parses_values_and_bools() {
        let a = Args::parse(
            &argv(&["--layers", "24", "--verbose", "--name=gpt", "pos1"]),
            &["layers", "name"],
            &["verbose"],
        )
        .unwrap();
        assert_eq!(a.get_usize("layers", 0).unwrap(), 24);
        assert_eq!(a.get("name"), Some("gpt"));
        assert!(a.get_bool("verbose"));
        assert_eq!(a.positional, vec!["pos1"]);
    }

    #[test]
    fn unknown_flag_is_error() {
        assert!(Args::parse(&argv(&["--nope"]), &["x"], &[]).is_err());
    }

    #[test]
    fn missing_value_is_error() {
        assert!(Args::parse(&argv(&["--layers"]), &["layers"], &[]).is_err());
    }

    #[test]
    fn usize_list() {
        let a = Args::parse(&argv(&["--budgets", "10, 20,30"]), &["budgets"], &[]).unwrap();
        assert_eq!(a.get_usize_list("budgets", &[]).unwrap(), vec![10, 20, 30]);
        let b = Args::parse(&argv(&[]), &["budgets"], &[]).unwrap();
        assert_eq!(b.get_usize_list("budgets", &[1, 2]).unwrap(), vec![1, 2]);
    }
}
