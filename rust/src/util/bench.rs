//! Micro-benchmark harness — substrate replacing `criterion`
//! (registry unavailable offline; DESIGN.md §3).
//!
//! Measures wall-clock per iteration with warmup, reports median /
//! mean / p10 / p90 over sample batches, and prints one machine-greppable
//! line per benchmark (`BENCH <name> median=...`). Used by
//! `rust/benches/*.rs` with `harness = false`.

use std::time::{Duration, Instant};

#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub iters: u64,
    pub median_ns: f64,
    pub mean_ns: f64,
    pub p10_ns: f64,
    pub p90_ns: f64,
}

impl BenchResult {
    pub fn print(&self) {
        println!(
            "BENCH {:<44} median={} mean={} p10={} p90={} iters={}",
            self.name,
            fmt_ns(self.median_ns),
            fmt_ns(self.mean_ns),
            fmt_ns(self.p10_ns),
            fmt_ns(self.p90_ns),
            self.iters
        );
    }
}

pub fn fmt_ns(ns: f64) -> String {
    if ns < 1e3 {
        format!("{ns:.1}ns")
    } else if ns < 1e6 {
        format!("{:.2}us", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2}ms", ns / 1e6)
    } else {
        format!("{:.3}s", ns / 1e9)
    }
}

/// Benchmark runner. `target_time` bounds total measurement time per bench.
pub struct Bencher {
    pub warmup: Duration,
    pub target_time: Duration,
    pub samples: usize,
    results: Vec<BenchResult>,
}

impl Default for Bencher {
    fn default() -> Self {
        Bencher {
            warmup: Duration::from_millis(200),
            target_time: Duration::from_secs(2),
            samples: 20,
            results: Vec::new(),
        }
    }
}

/// Prevent the optimizer from eliding the benchmarked computation.
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

impl Bencher {
    pub fn new() -> Self {
        Self::default()
    }

    /// Quick profile for smoke runs (CI): short warmup, few samples.
    pub fn quick() -> Self {
        Bencher {
            warmup: Duration::from_millis(50),
            target_time: Duration::from_millis(400),
            samples: 8,
            results: Vec::new(),
        }
    }

    /// Run `f` repeatedly; `f` should perform ONE unit of work per call.
    pub fn bench<F: FnMut()>(&mut self, name: &str, mut f: F) -> &BenchResult {
        // Warmup + estimate per-iteration time.
        let warm_start = Instant::now();
        let mut warm_iters: u64 = 0;
        while warm_start.elapsed() < self.warmup {
            f();
            warm_iters += 1;
        }
        let per_iter = warm_start.elapsed().as_nanos() as f64 / warm_iters.max(1) as f64;

        // Choose batch size so a sample takes ~target_time/samples.
        let sample_ns = self.target_time.as_nanos() as f64 / self.samples as f64;
        let batch = ((sample_ns / per_iter.max(1.0)).ceil() as u64).max(1);

        let mut sample_times: Vec<f64> = Vec::with_capacity(self.samples);
        let mut total_iters = 0u64;
        for _ in 0..self.samples {
            let t0 = Instant::now();
            for _ in 0..batch {
                f();
            }
            sample_times.push(t0.elapsed().as_nanos() as f64 / batch as f64);
            total_iters += batch;
        }
        sample_times.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let q = |p: f64| -> f64 {
            let idx = (p * (sample_times.len() - 1) as f64).round() as usize;
            sample_times[idx]
        };
        let mean = sample_times.iter().sum::<f64>() / sample_times.len() as f64;
        let r = BenchResult {
            name: name.to_string(),
            iters: total_iters,
            median_ns: q(0.5),
            mean_ns: mean,
            p10_ns: q(0.1),
            p90_ns: q(0.9),
        };
        r.print();
        self.results.push(r);
        self.results.last().unwrap()
    }

    pub fn results(&self) -> &[BenchResult] {
        &self.results
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_something_positive() {
        let mut b = Bencher {
            warmup: Duration::from_millis(5),
            target_time: Duration::from_millis(20),
            samples: 4,
            results: Vec::new(),
        };
        let mut acc = 0u64;
        let r = b.bench("noop_add", || {
            acc = black_box(acc.wrapping_add(1));
        });
        assert!(r.median_ns > 0.0);
        assert!(r.p10_ns <= r.p90_ns);
    }
}
