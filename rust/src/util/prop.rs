//! Small property-testing driver — substrate replacing `proptest`
//! (registry unavailable offline; DESIGN.md §3).
//!
//! A property is a closure from a seeded [`Rng`](super::rng::Rng) to
//! `Result<(), String>`. The driver runs N cases with derived seeds and,
//! on failure, reports the failing seed so the case is reproducible with
//! `check_one`.

use super::rng::Rng;

/// Run `cases` random cases of `prop`; panic with the failing seed on error.
pub fn check<F>(name: &str, cases: usize, base_seed: u64, mut prop: F)
where
    F: FnMut(&mut Rng) -> Result<(), String>,
{
    for i in 0..cases {
        let seed = base_seed.wrapping_add(i as u64).wrapping_mul(0x9E3779B97F4A7C15);
        let mut rng = Rng::new(seed);
        if let Err(msg) = prop(&mut rng) {
            panic!(
                "property '{name}' failed on case {i} (seed={seed:#x}):\n  {msg}\n\
                 reproduce with util::prop::check_one(\"{name}\", {seed:#x}, prop)"
            );
        }
    }
}

/// Re-run a single failing case by seed.
pub fn check_one<F>(name: &str, seed: u64, mut prop: F)
where
    F: FnMut(&mut Rng) -> Result<(), String>,
{
    let mut rng = Rng::new(seed);
    if let Err(msg) = prop(&mut rng) {
        panic!("property '{name}' failed (seed={seed:#x}): {msg}");
    }
}

/// Helper: assert approximate equality of floats inside a property.
pub fn approx_eq(a: f64, b: f64, tol: f64) -> Result<(), String> {
    if (a - b).abs() <= tol * (1.0 + a.abs().max(b.abs())) {
        Ok(())
    } else {
        Err(format!("{a} !~ {b} (tol={tol})"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        check("add_commutes", 50, 1, |rng| {
            let a = rng.gen_range(1000) as i64;
            let b = rng.gen_range(1000) as i64;
            if a + b == b + a {
                Ok(())
            } else {
                Err("math broke".into())
            }
        });
    }

    #[test]
    #[should_panic(expected = "property 'always_fails' failed")]
    fn failing_property_panics_with_seed() {
        check("always_fails", 3, 2, |_| Err("nope".into()));
    }

    #[test]
    fn approx_eq_tolerance() {
        assert!(approx_eq(1.0, 1.0 + 1e-12, 1e-9).is_ok());
        assert!(approx_eq(1.0, 1.1, 1e-9).is_err());
    }
}
