//! Minimal JSON value, emitter, and recursive-descent parser — substrate
//! replacing `serde`/`serde_json` (registry unavailable offline; DESIGN.md §3).
//!
//! Used for experiment configs, result series written by the figure
//! harnesses, and the learner training-set interchange with python.

use std::collections::BTreeMap;
use std::fmt;

/// A JSON value. Object keys are ordered (BTreeMap) so output is stable.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }
    pub fn arr<I: IntoIterator<Item = Json>>(xs: I) -> Json {
        Json::Arr(xs.into_iter().collect())
    }
    pub fn num<T: Into<f64>>(x: T) -> Json {
        Json::Num(x.into())
    }
    pub fn str(s: impl Into<String>) -> Json {
        Json::Str(s.into())
    }
    pub fn f64_arr(xs: &[f64]) -> Json {
        Json::Arr(xs.iter().map(|&x| Json::Num(x)).collect())
    }
    pub fn usize_arr(xs: &[usize]) -> Json {
        Json::Arr(xs.iter().map(|&x| Json::Num(x as f64)).collect())
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }
    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|x| x as usize)
    }
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// Pretty-print with 2-space indentation.
    pub fn pretty(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, 0, true);
        s
    }

    fn write(&self, out: &mut String, indent: usize, pretty: bool) {
        let pad = |out: &mut String, n: usize| {
            if pretty {
                out.push('\n');
                for _ in 0..n {
                    out.push_str("  ");
                }
            }
        };
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(x) => {
                if x.fract() == 0.0 && x.abs() < 1e15 {
                    out.push_str(&format!("{}", *x as i64));
                } else {
                    out.push_str(&format!("{x}"));
                }
            }
            Json::Str(s) => {
                out.push('"');
                for c in s.chars() {
                    match c {
                        '"' => out.push_str("\\\""),
                        '\\' => out.push_str("\\\\"),
                        '\n' => out.push_str("\\n"),
                        '\t' => out.push_str("\\t"),
                        '\r' => out.push_str("\\r"),
                        c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
                        c => out.push(c),
                    }
                }
                out.push('"');
            }
            Json::Arr(v) => {
                out.push('[');
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    pad(out, indent + 1);
                    x.write(out, indent + 1, pretty);
                }
                if !v.is_empty() {
                    pad(out, indent);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    pad(out, indent + 1);
                    Json::Str(k.clone()).write(out, indent + 1, false);
                    out.push(':');
                    if pretty {
                        out.push(' ');
                    }
                    v.write(out, indent + 1, pretty);
                }
                if !m.is_empty() {
                    pad(out, indent);
                }
                out.push('}');
            }
        }
    }
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let mut s = String::new();
        self.write(&mut s, 0, false);
        f.write_str(&s)
    }
}

/// Parse error with byte offset.
#[derive(Debug, Clone, PartialEq)]
pub struct ParseError {
    pub pos: usize,
    pub msg: String,
}

impl fmt::Display for ParseError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json parse error at byte {}: {}", self.pos, self.msg)
    }
}
impl std::error::Error for ParseError {}

struct Parser<'a> {
    b: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err<T>(&self, msg: &str) -> Result<T, ParseError> {
        Err(ParseError { pos: self.pos, msg: msg.to_string() })
    }

    fn skip_ws(&mut self) {
        while self.pos < self.b.len() && matches!(self.b[self.pos], b' ' | b'\t' | b'\n' | b'\r') {
            self.pos += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.pos).copied()
    }

    fn expect(&mut self, c: u8) -> Result<(), ParseError> {
        if self.peek() == Some(c) {
            self.pos += 1;
            Ok(())
        } else {
            self.err(&format!("expected '{}'", c as char))
        }
    }

    fn value(&mut self) -> Result<Json, ParseError> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => self.err("unexpected character"),
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, ParseError> {
        if self.b[self.pos..].starts_with(s.as_bytes()) {
            self.pos += s.len();
            Ok(v)
        } else {
            self.err(&format!("expected '{s}'"))
        }
    }

    fn number(&mut self) -> Result<Json, ParseError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(
            self.peek(),
            Some(c) if c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-')
        ) {
            self.pos += 1;
        }
        let s = std::str::from_utf8(&self.b[start..self.pos]).unwrap();
        match s.parse::<f64>() {
            Ok(x) => Ok(Json::Num(x)),
            Err(_) => self.err("bad number"),
        }
    }

    fn string(&mut self) -> Result<String, ParseError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return self.err("unterminated string"),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'n') => s.push('\n'),
                        Some(b't') => s.push('\t'),
                        Some(b'r') => s.push('\r'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'u') => {
                            if self.pos + 4 >= self.b.len() {
                                return self.err("bad \\u escape");
                            }
                            let hex =
                                std::str::from_utf8(&self.b[self.pos + 1..self.pos + 5]).unwrap();
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| ParseError { pos: self.pos, msg: "bad hex".into() })?;
                            s.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                            self.pos += 4;
                        }
                        _ => return self.err("bad escape"),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // Copy a run of plain bytes (UTF-8 passes through intact).
                    let start = self.pos;
                    while matches!(self.peek(), Some(c) if c != b'"' && c != b'\\') {
                        self.pos += 1;
                    }
                    s.push_str(std::str::from_utf8(&self.b[start..self.pos]).map_err(|_| {
                        ParseError { pos: start, msg: "invalid utf-8".into() }
                    })?);
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, ParseError> {
        self.expect(b'[')?;
        let mut v = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            v.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(v));
                }
                _ => return self.err("expected ',' or ']'"),
            }
        }
    }

    fn object(&mut self) -> Result<Json, ParseError> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let v = self.value()?;
            m.insert(k, v);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(m));
                }
                _ => return self.err("expected ',' or '}'"),
            }
        }
    }
}

/// Parse a JSON document. Trailing whitespace is allowed; trailing garbage is an error.
pub fn parse(s: &str) -> Result<Json, ParseError> {
    let mut p = Parser { b: s.as_bytes(), pos: 0 };
    let v = p.value()?;
    p.skip_ws();
    if p.pos != p.b.len() {
        return p.err("trailing garbage");
    }
    Ok(v)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_scalars() {
        for s in ["null", "true", "false", "0", "-3.5", "\"hi\""] {
            let v = parse(s).unwrap();
            assert_eq!(parse(&v.to_string()).unwrap(), v);
        }
    }

    #[test]
    fn roundtrip_nested() {
        let v = Json::obj(vec![
            ("a", Json::arr(vec![Json::num(1.0), Json::num(2.5), Json::Null])),
            ("b", Json::obj(vec![("c", Json::str("x\"y\n"))])),
            ("d", Json::Bool(true)),
        ]);
        assert_eq!(parse(&v.to_string()).unwrap(), v);
        assert_eq!(parse(&v.pretty()).unwrap(), v);
    }

    #[test]
    fn parse_whitespace_and_unicode() {
        let v = parse(" { \"k\" : [ 1 , \"\\u00e9日本\" ] } ").unwrap();
        assert_eq!(v.get("k").unwrap().as_arr().unwrap()[1].as_str().unwrap(), "é日本");
    }

    #[test]
    fn errors_have_positions() {
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("12 34").is_err());
        assert!(parse("\"abc").is_err());
    }

    #[test]
    fn integers_print_without_fraction() {
        assert_eq!(Json::num(3.0).to_string(), "3");
        assert_eq!(Json::num(3.25).to_string(), "3.25");
    }
}
