//! Stable structural hashing — substrate replacing `fxhash`/`siphasher`
//! (registry unavailable offline; DESIGN.md §3).
//!
//! `std::hash::DefaultHasher` makes no cross-version stability promise,
//! but service fingerprints (DESIGN.md §9) are compared across processes
//! and potentially persisted, so the plan cache needs a hash whose value
//! is pinned by this crate: FNV-1a with explicit 64-bit folding.

/// FNV-1a 64-bit incremental hasher. Deterministic across platforms,
/// processes, and releases; not cryptographic (cache keys only).
#[derive(Debug, Clone)]
pub struct Fnv64 {
    state: u64,
}

const FNV_OFFSET: u64 = 0xcbf29ce484222325;
const FNV_PRIME: u64 = 0x100000001b3;

impl Default for Fnv64 {
    fn default() -> Self {
        Fnv64::new()
    }
}

impl Fnv64 {
    pub fn new() -> Fnv64 {
        Fnv64 { state: FNV_OFFSET }
    }

    #[inline]
    pub fn byte(&mut self, b: u8) -> &mut Self {
        self.state ^= b as u64;
        self.state = self.state.wrapping_mul(FNV_PRIME);
        self
    }

    #[inline]
    pub fn bytes(&mut self, bs: &[u8]) -> &mut Self {
        for &b in bs {
            self.byte(b);
        }
        self
    }

    /// Hash a u64 as 8 little-endian bytes.
    #[inline]
    pub fn u64(&mut self, x: u64) -> &mut Self {
        self.bytes(&x.to_le_bytes())
    }

    #[inline]
    pub fn i64(&mut self, x: i64) -> &mut Self {
        self.u64(x as u64)
    }

    #[inline]
    pub fn usize(&mut self, x: usize) -> &mut Self {
        self.u64(x as u64)
    }

    /// Hash an f64 by its bit pattern (distinguishes -0.0 from 0.0,
    /// which is fine for cache keys — equal inputs hash equal).
    #[inline]
    pub fn f64(&mut self, x: f64) -> &mut Self {
        self.u64(x.to_bits())
    }

    #[inline]
    pub fn bool(&mut self, x: bool) -> &mut Self {
        self.byte(x as u8)
    }

    /// Hash a string length-prefixed, so `("ab","c")` and `("a","bc")`
    /// fold differently.
    #[inline]
    pub fn str(&mut self, s: &str) -> &mut Self {
        self.u64(s.len() as u64);
        self.bytes(s.as_bytes())
    }

    pub fn finish(&self) -> u64 {
        self.state
    }
}

/// One-shot convenience: hash a byte slice.
pub fn fnv64(bs: &[u8]) -> u64 {
    let mut h = Fnv64::new();
    h.bytes(bs);
    h.finish()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn known_vectors() {
        // Reference FNV-1a 64 values.
        assert_eq!(fnv64(b""), 0xcbf29ce484222325);
        assert_eq!(fnv64(b"a"), 0xaf63dc4c8601ec8c);
        assert_eq!(fnv64(b"foobar"), 0x85944171f73967e8);
    }

    #[test]
    fn deterministic_and_order_sensitive() {
        let mut a = Fnv64::new();
        a.str("x").u64(7).f64(1.5);
        let mut b = Fnv64::new();
        b.str("x").u64(7).f64(1.5);
        assert_eq!(a.finish(), b.finish());
        let mut c = Fnv64::new();
        c.u64(7).str("x").f64(1.5);
        assert_ne!(a.finish(), c.finish());
    }

    #[test]
    fn length_prefix_disambiguates_strings() {
        let mut a = Fnv64::new();
        a.str("ab").str("c");
        let mut b = Fnv64::new();
        b.str("a").str("bc");
        assert_ne!(a.finish(), b.finish());
    }
}
