//! Infrastructure substrates built from scratch because the crate
//! registry is unreachable in this environment (DESIGN.md §3):
//! PRNG (`rng`), JSON (`json`), CLI flags (`cli`), bench harness
//! (`bench`), stable hashing (`hash`), property testing (`prop`),
//! descriptive stats (`stats`), and the deterministic fault-injection
//! harness (`failpoints`, DESIGN.md §14).

pub mod bench;
pub mod cli;
pub mod failpoints;
pub mod hash;
pub mod json;
pub mod prop;
pub mod rng;
pub mod stats;
