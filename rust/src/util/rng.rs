//! Deterministic, seedable PRNG (xoshiro256++) — substrate replacing the
//! `rand` crate (registry unavailable offline; see DESIGN.md §3).
//!
//! All stochastic components (MCTS rollouts, dataset sampling, workload
//! generators) take an explicit `Rng` so experiments are reproducible.

/// xoshiro256++ generator (Blackman & Vigna). Passes BigCrush; more than
/// adequate for Monte-Carlo search.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

#[inline]
fn rotl(x: u64, k: u32) -> u64 {
    (x << k) | (x >> (64 - k))
}

/// splitmix64, used to expand a 64-bit seed into the 256-bit state.
#[inline]
fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

impl Rng {
    /// Create a generator from a 64-bit seed. Equal seeds yield equal
    /// streams on every platform.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s }
    }

    /// Derive an independent child stream (for per-attempt / per-thread rngs).
    pub fn fork(&mut self, tag: u64) -> Rng {
        Rng::new(self.next_u64() ^ tag.wrapping_mul(0x9E3779B97F4A7C15))
    }

    /// Next raw 64-bit output.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = rotl(self.s[0].wrapping_add(self.s[3]), 23).wrapping_add(self.s[0]);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = rotl(self.s[3], 45);
        result
    }

    /// Uniform in `[0, n)`. Uses Lemire's multiply-shift rejection method.
    #[inline]
    pub fn gen_range(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        let n = n as u64;
        let mut x = self.next_u64();
        let mut m = (x as u128).wrapping_mul(n as u128);
        let mut l = m as u64;
        if l < n {
            let t = n.wrapping_neg() % n;
            while l < t {
                x = self.next_u64();
                m = (x as u128).wrapping_mul(n as u128);
                l = m as u64;
            }
        }
        (m >> 64) as usize
    }

    /// Uniform f64 in `[0, 1)`.
    #[inline]
    pub fn gen_f64(&mut self) -> f64 {
        // 53 random mantissa bits.
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in `[0, 1)`.
    #[inline]
    pub fn gen_f32(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 * (1.0 / (1u64 << 24) as f32)
    }

    /// Bernoulli trial with probability `p`.
    #[inline]
    pub fn gen_bool(&mut self, p: f64) -> bool {
        self.gen_f64() < p
    }

    /// Standard normal via Box–Muller (one value per call; fine off the hot path).
    pub fn gen_normal(&mut self) -> f64 {
        let u1 = self.gen_f64().max(1e-300);
        let u2 = self.gen_f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Choose a uniformly random element of a slice.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.gen_range(xs.len())]
    }

    /// In-place Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.gen_range(i + 1);
            xs.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_across_instances() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn gen_range_in_bounds() {
        let mut r = Rng::new(7);
        for n in [1usize, 2, 3, 10, 1000] {
            for _ in 0..200 {
                assert!(r.gen_range(n) < n);
            }
        }
    }

    #[test]
    fn gen_f64_unit_interval() {
        let mut r = Rng::new(9);
        let mut sum = 0.0;
        for _ in 0..10_000 {
            let x = r.gen_f64();
            assert!((0.0..1.0).contains(&x));
            sum += x;
        }
        let mean = sum / 10_000.0;
        assert!((mean - 0.5).abs() < 0.02, "mean={mean}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(3);
        let mut v: Vec<u32> = (0..50).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn fork_streams_are_independent() {
        let mut base = Rng::new(11);
        let mut c1 = base.fork(1);
        let mut c2 = base.fork(2);
        let a: Vec<u64> = (0..10).map(|_| c1.next_u64()).collect();
        let b: Vec<u64> = (0..10).map(|_| c2.next_u64()).collect();
        assert_ne!(a, b);
    }
}
