//! Stage partitioner (DESIGN.md §11): cut a topologically-ordered
//! [`Func`] into K contiguous node intervals ("stages") over a dedicated
//! mesh axis.
//!
//! Nodes are stored in topological order (the builder only lets a node
//! reference already-created values), so ANY strictly increasing cut
//! vector yields a valid acyclic stage assignment — which is what makes
//! cut positions cheap search actions: moving a cut never needs a
//! legality re-check, only a re-price.
//!
//! The balance score is the classic parameter+FLOP load per stage:
//! matmuls are weighted `2·N·K·M`, everything else by its output element
//! count, and parameter/optimiser-state bytes count toward the stage of
//! their first use (that stage holds the weights resident). The greedy
//! prefix-sum split lands each cut at the first node where the running
//! weight crosses the stage's even share — the seed the search then
//! refines with `CutMove` actions.

use crate::ir::{ArgKind, Func, OpKind, ValueId};
use anyhow::{bail, Result};

/// A resolved pipeline configuration: the mesh axis carrying the stages,
/// the microbatch count, and the cut positions. `cuts[i]` is the node
/// index that STARTS stage `i+1`; strictly increasing, each in
/// `1..num_nodes`. The stage of node `ni` is the number of cuts `<= ni`.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct PipelineSpec {
    /// Mesh axis index the stages are laid out over.
    pub axis: usize,
    /// Microbatch count `M` for the 1F1B schedule.
    pub microbatches: usize,
    /// Stage-cut node indices, strictly increasing.
    pub cuts: Vec<u32>,
}

impl PipelineSpec {
    pub fn stages(&self) -> usize {
        self.cuts.len() + 1
    }

    /// Stage index of node `ni` (number of cuts at or before it).
    pub fn stage_of(&self, ni: usize) -> usize {
        // cuts is sorted; partition_point = first cut > ni.
        self.cuts.partition_point(|&c| (c as usize) <= ni)
    }
}

/// Per-node balance weight: FLOPs for matmuls (2·out_elems·contract),
/// output element count for everything else, plus the bytes of any
/// parameter/optimiser-state argument first consumed by this node.
fn node_weight(f: &Func, ni: usize, first_use: &[Option<u32>]) -> f64 {
    let node = &f.nodes[ni];
    let out_elems = node.ty.num_elements() as f64;
    let flops = match &node.op {
        OpKind::Dot(d) => {
            let lhs_dims = dims_of(f, node.inputs[0]);
            let k: f64 = d.lhs_contract.iter().map(|&c| lhs_dims[c] as f64).product();
            2.0 * out_elems * k
        }
        _ => out_elems,
    };
    let mut param_bytes = 0.0;
    for (ai, arg) in f.args.iter().enumerate() {
        if first_use[ai] == Some(ni as u32)
            && matches!(arg.kind, ArgKind::Parameter | ArgKind::OptState)
        {
            param_bytes += arg.ty.byte_size() as f64;
        }
    }
    flops + param_bytes
}

fn dims_of(f: &Func, v: ValueId) -> &[i64] {
    if v.index() < f.num_args() {
        &f.args[v.index()].ty.dims
    } else {
        &f.nodes[v.index() - f.num_args()].ty.dims
    }
}

/// First consuming node per argument (`None` = unused).
fn arg_first_use(f: &Func) -> Vec<Option<u32>> {
    let mut first = vec![None; f.num_args()];
    for (ni, node) in f.nodes.iter().enumerate() {
        for &inp in &node.inputs {
            let i = inp.index();
            if i < f.num_args() && first[i].is_none() {
                first[i] = Some(ni as u32);
            }
        }
    }
    first
}

/// Greedy balanced interval cut: `k - 1` strictly increasing cut points
/// over the node weights' prefix sums, each at the first node where the
/// running weight reaches that stage's even share. Deterministic.
/// Returns fewer cuts when the program has fewer than `k` nodes.
pub fn balanced_cuts(f: &Func, k: usize) -> Vec<u32> {
    let n = f.num_nodes();
    if k <= 1 || n < 2 {
        return Vec::new();
    }
    let k = k.min(n);
    let first_use = arg_first_use(f);
    let w: Vec<f64> = (0..n).map(|ni| node_weight(f, ni, &first_use)).collect();
    let total: f64 = w.iter().sum();
    let mut cuts = Vec::with_capacity(k - 1);
    let mut acc = 0.0;
    for (ni, &wi) in w.iter().enumerate() {
        acc += wi;
        let j = cuts.len() + 1; // next cut index (1-based share)
        if j < k && acc >= total * j as f64 / k as f64 {
            // Cut AFTER ni; keep room so every later stage is non-empty.
            let cut = ((ni + 1) as u32).min((n - (k - j)) as u32);
            let lo = cuts.last().map_or(1, |&c: &u32| c + 1);
            cuts.push(cut.max(lo));
        }
    }
    // Degenerate weight distributions (all mass on the last node) can
    // leave cuts unplaced; pad from the tail, keeping them increasing.
    while cuts.len() < k - 1 {
        let j = cuts.len() + 1;
        let cut = ((n - (k - j)) as u32).max(cuts.last().map_or(1, |&c| c + 1));
        cuts.push(cut);
    }
    cuts
}

/// Per-stage balance weights under a cut vector (for traces and tests).
pub fn stage_weights(f: &Func, cuts: &[u32]) -> Vec<f64> {
    let first_use = arg_first_use(f);
    let mut out = vec![0.0; cuts.len() + 1];
    let spec = PipelineSpec { axis: 0, microbatches: 1, cuts: cuts.to_vec() };
    for ni in 0..f.num_nodes() {
        out[spec.stage_of(ni)] += node_weight(f, ni, &first_use);
    }
    out
}

/// One cross-stage activation transfer: value `value` must hop the
/// boundary between stages `boundary` and `boundary + 1` to reach a
/// consumer. Values are forwarded stage to stage (a value consumed in
/// stages 1 and 3 crosses boundaries 0, 1, and 2 exactly once each).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct BoundaryTransfer {
    /// Value id crossing the boundary.
    pub value: usize,
    /// Boundary index (between stage `boundary` and `boundary + 1`).
    pub boundary: usize,
    /// Consumer node that pulled the value across.
    pub node: usize,
}

/// Enumerate every boundary crossing under `spec`, deterministically
/// (nodes ascending, inputs in operand order). Node results start at
/// their producer's stage; arguments are resident at the stage of their
/// first use (no transfer for the first consumer). Each value is
/// forwarded at most once per boundary — later consumers reuse the
/// already-transferred copy.
pub fn boundary_transfers(f: &Func, spec: &PipelineSpec) -> Vec<BoundaryTransfer> {
    let num_args = f.num_args();
    let mut out = Vec::new();
    if spec.cuts.is_empty() {
        return out;
    }
    // Highest stage each value has reached so far (usize::MAX = not yet
    // placed; for args that means "resident wherever first used").
    let mut at: Vec<usize> = vec![usize::MAX; f.num_values()];
    for (ni, node) in f.nodes.iter().enumerate() {
        let cs = spec.stage_of(ni);
        for &inp in &node.inputs {
            let v = inp.index();
            if at[v] == usize::MAX {
                debug_assert!(v < num_args, "node results are placed at production");
                at[v] = cs;
                continue;
            }
            let from = at[v];
            for b in from..cs {
                out.push(BoundaryTransfer { value: v, boundary: b, node: ni });
            }
            if cs > from {
                at[v] = cs;
            }
        }
        at[num_args + ni] = cs;
    }
    out
}

/// Parsed `--pipeline stages=K[,microbatches=M][,axis=NAME]` flag.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct PipelineFlag {
    pub stages: usize,
    pub microbatches: usize,
    pub axis: String,
}

/// Parse the CLI / request pipeline flag. `stages` is required;
/// `microbatches` defaults to `2 * stages` (a common 1F1B choice that
/// keeps the bubble under a third); `axis` defaults to `"pipe"`.
pub fn parse_pipeline_flag(s: &str) -> Result<PipelineFlag> {
    let mut stages: Option<usize> = None;
    let mut microbatches: Option<usize> = None;
    let mut axis = "pipe".to_string();
    for part in s.split(',') {
        let part = part.trim();
        if part.is_empty() {
            continue;
        }
        let (key, val) = match part.split_once('=') {
            Some(kv) => kv,
            None => bail!("pipeline flag: expected key=value, found '{part}'"),
        };
        match key.trim() {
            "stages" => {
                let v: usize = val.trim().parse().map_err(|_| {
                    anyhow::anyhow!("pipeline flag: stages must be a positive integer, found '{val}'")
                })?;
                if v == 0 {
                    bail!("pipeline flag: stages must be >= 1");
                }
                stages = Some(v);
            }
            "microbatches" => {
                let v: usize = val.trim().parse().map_err(|_| {
                    anyhow::anyhow!(
                        "pipeline flag: microbatches must be a positive integer, found '{val}'"
                    )
                })?;
                if v == 0 {
                    bail!("pipeline flag: microbatches must be >= 1");
                }
                microbatches = Some(v);
            }
            "axis" => {
                let v = val.trim();
                if v.is_empty() {
                    bail!("pipeline flag: axis name must be non-empty");
                }
                axis = v.to_string();
            }
            other => bail!("pipeline flag: unknown key '{other}' (expected stages/microbatches/axis)"),
        }
    }
    let stages = match stages {
        Some(s) => s,
        None => bail!("pipeline flag: 'stages=K' is required"),
    };
    Ok(PipelineFlag { stages, microbatches: microbatches.unwrap_or(2 * stages), axis })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::{ArgKind, GraphBuilder, TensorType};

    /// x -> neg -> exp -> neg -> exp chain with a param consumed by the
    /// middle node.
    fn chain() -> Func {
        let mut b = GraphBuilder::new("chain");
        let x = b.arg("x", TensorType::f32(&[8, 16]), ArgKind::Input);
        let w = b.arg("w", TensorType::f32(&[16, 16]), ArgKind::Parameter);
        let a = b.neg(x);
        let c = b.exp(a);
        let d = b.matmul(c, w);
        let e = b.neg(d);
        let f2 = b.exp(e);
        b.output(f2);
        b.finish()
    }

    #[test]
    fn stage_of_counts_cuts() {
        let spec = PipelineSpec { axis: 0, microbatches: 4, cuts: vec![2, 4] };
        assert_eq!(spec.stages(), 3);
        assert_eq!(spec.stage_of(0), 0);
        assert_eq!(spec.stage_of(1), 0);
        assert_eq!(spec.stage_of(2), 1);
        assert_eq!(spec.stage_of(3), 1);
        assert_eq!(spec.stage_of(4), 2);
        assert_eq!(spec.stage_of(9), 2);
    }

    #[test]
    fn balanced_cuts_are_strictly_increasing_and_cover_all_stages() {
        let f = chain();
        for k in [1usize, 2, 3, 4, 5] {
            let cuts = balanced_cuts(&f, k);
            let k_eff = k.min(f.num_nodes());
            assert_eq!(cuts.len(), k_eff.saturating_sub(1), "k={k}");
            for w in cuts.windows(2) {
                assert!(w[0] < w[1], "cuts must be strictly increasing: {cuts:?}");
            }
            if let (Some(&first), Some(&last)) = (cuts.first(), cuts.last()) {
                assert!(first >= 1 && (last as usize) < f.num_nodes(), "{cuts:?}");
            }
            // Every stage is non-empty by construction.
            let sw = stage_weights(&f, &cuts);
            assert_eq!(sw.len(), k_eff);
            assert!(sw.iter().all(|&w| w > 0.0), "k={k}: {sw:?}");
        }
    }

    #[test]
    fn balanced_cuts_prefer_even_weight() {
        let f = chain();
        let cuts = balanced_cuts(&f, 2);
        let sw = stage_weights(&f, &cuts);
        let total: f64 = sw.iter().sum();
        // The matmul dominates; the greedy split must not put everything
        // in one stage.
        assert!(sw.iter().all(|&w| w < 0.95 * total), "{sw:?}");
    }

    #[test]
    fn boundary_transfers_forward_values_once_per_boundary() {
        let f = chain();
        // Cut between every node: 5 stages.
        let spec = PipelineSpec { axis: 0, microbatches: 2, cuts: vec![1, 2, 3, 4] };
        let xfers = boundary_transfers(&f, &spec);
        // Chain program: each node's result crosses exactly the one
        // boundary to its consumer; args are resident at first use.
        assert_eq!(xfers.len(), 4, "{xfers:?}");
        for (b, x) in xfers.iter().enumerate() {
            assert_eq!(x.boundary, b);
        }
        // No cuts, no transfers.
        let none = PipelineSpec { axis: 0, microbatches: 2, cuts: vec![] };
        assert!(boundary_transfers(&f, &none).is_empty());
    }

    #[test]
    fn skip_connections_hop_every_intermediate_boundary() {
        let mut b = GraphBuilder::new("skip");
        let x = b.arg("x", TensorType::f32(&[8]), ArgKind::Input);
        let a = b.neg(x);
        let c = b.exp(a);
        let d = b.neg(c);
        let e = b.add(a, d); // consumes stage-0 value in stage 3
        b.output(e);
        let f = b.finish();
        let spec = PipelineSpec { axis: 0, microbatches: 2, cuts: vec![1, 2, 3] };
        let xfers = boundary_transfers(&f, &spec);
        // a (value of node 0) crosses boundary 0 (to node 1) and then
        // boundaries 1, 2 (forwarded to node 3); c crosses 1; d crosses 2.
        let a_hops: Vec<usize> = xfers
            .iter()
            .filter(|t| t.value == f.num_args())
            .map(|t| t.boundary)
            .collect();
        assert_eq!(a_hops, vec![0, 1, 2], "{xfers:?}");
    }

    #[test]
    fn flag_parses_with_defaults_and_rejects_junk() {
        let p = parse_pipeline_flag("stages=4").unwrap();
        assert_eq!(p, PipelineFlag { stages: 4, microbatches: 8, axis: "pipe".into() });
        let p = parse_pipeline_flag("stages=2,microbatches=16,axis=stage").unwrap();
        assert_eq!(p, PipelineFlag { stages: 2, microbatches: 16, axis: "stage".into() });
        assert!(parse_pipeline_flag("").is_err(), "stages required");
        assert!(parse_pipeline_flag("stages=0").is_err());
        assert!(parse_pipeline_flag("stages=4,microbatches=0").is_err());
        assert!(parse_pipeline_flag("bogus=1").is_err());
        assert!(parse_pipeline_flag("stages").is_err());
    }
}
