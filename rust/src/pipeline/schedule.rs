//! 1F1B pipeline schedule simulator (DESIGN.md §11).
//!
//! Given per-stage busy seconds (for the FULL batch), per-boundary
//! transfer seconds (per microbatch), and a microbatch count `M`, the
//! simulator prices the steady-state one-forward-one-backward schedule:
//! each stage processes its `M` microbatches in order, a microbatch
//! reaches stage `s+1` only after stage `s` finished it and its
//! activations crossed the boundary, and the warm-up/drain bubble falls
//! out of the recurrence rather than being bolted on.
//!
//! The recurrence is the standard O(K·M)-time, O(K)-memory DP:
//!
//! ```text
//! finish[s] after microbatch m:
//!     arrive = (s == 0) ? 0 : finish[s-1] + xfer[s-1]
//!     finish[s] = max(arrive, finish[s]) + t[s]
//! ```
//!
//! where `t[s]` is the per-microbatch stage time (`stage_seconds[s]/M`).
//! On uniform stages with zero transfer cost the resulting bubble
//! fraction is exactly the closed form `(K-1)/(M+K-1)` — pinned by a
//! unit test below and by the acceptance criteria in
//! `tests/session_pipeline.rs`.

/// Result of one 1F1B simulation.
#[derive(Debug, Clone, PartialEq)]
pub struct ScheduleResult {
    /// End-to-end seconds for all `M` microbatches through all stages.
    pub makespan_seconds: f64,
    /// Fraction of the `K · makespan` stage-seconds that is idle:
    /// `1 - M·Σt[s] / (K·makespan)`. Zero for `K = 1`; exactly
    /// `(K-1)/(M+K-1)` on uniform stages with free transfers.
    pub bubble_fraction: f64,
    /// Per-microbatch busy seconds per stage (`stage_seconds[s]/M`),
    /// the `t[s]` the DP ran on.
    pub stage_microbatch_seconds: Vec<f64>,
}

/// One stage's busy interval for one microbatch, in simulated seconds —
/// the unit the flight recorder renders as a Perfetto `ph:"X"` slice
/// (`rust/src/obs/recorder.rs`, `EventKind::Slice`).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct StageSlice {
    pub stage: usize,
    pub microbatch: usize,
    pub start_seconds: f64,
    pub end_seconds: f64,
}

/// Simulate a 1F1B schedule.
///
/// * `stage_seconds[s]` — busy seconds of stage `s` for the FULL batch
///   (compute + intra-stage collectives, from the roofline model).
/// * `xfer_seconds[s]` — seconds one microbatch's activations take to
///   cross the boundary between stages `s` and `s+1`
///   (`len = stages - 1`; pass `&[]` for a single stage).
/// * `microbatches` — `M`, clamped to at least 1.
pub fn simulate_1f1b(
    stage_seconds: &[f64],
    xfer_seconds: &[f64],
    microbatches: usize,
) -> ScheduleResult {
    run_dp(stage_seconds, xfer_seconds, microbatches, |_, _, _, _| {})
}

/// [`simulate_1f1b`] that also returns every (stage, microbatch) busy
/// interval. Tracing-only — the executor calls it once per pipelined
/// request, for the winning plan, so the per-episode hot path never pays
/// for slice materialisation.
pub fn simulate_1f1b_slices(
    stage_seconds: &[f64],
    xfer_seconds: &[f64],
    microbatches: usize,
) -> (ScheduleResult, Vec<StageSlice>) {
    let mut slices = Vec::with_capacity(stage_seconds.len() * microbatches.max(1));
    let on_slice = |stage: usize, microbatch: usize, start: f64, end: f64| {
        slices.push(StageSlice { stage, microbatch, start_seconds: start, end_seconds: end });
    };
    let result = run_dp(stage_seconds, xfer_seconds, microbatches, on_slice);
    (result, slices)
}

/// The shared O(K·M) recurrence. `on_slice(stage, microbatch, start, end)`
/// fires once per DP step with that microbatch's busy interval on that
/// stage; `simulate_1f1b` passes a no-op closure, which inlines away.
fn run_dp(
    stage_seconds: &[f64],
    xfer_seconds: &[f64],
    microbatches: usize,
    mut on_slice: impl FnMut(usize, usize, f64, f64),
) -> ScheduleResult {
    let k = stage_seconds.len();
    if k == 0 {
        return ScheduleResult {
            makespan_seconds: 0.0,
            bubble_fraction: 0.0,
            stage_microbatch_seconds: Vec::new(),
        };
    }
    debug_assert_eq!(xfer_seconds.len(), k - 1, "one transfer term per boundary");
    let m = microbatches.max(1);
    let t: Vec<f64> = stage_seconds.iter().map(|&s| s / m as f64).collect();

    let mut finish = vec![0.0f64; k];
    for mb in 0..m {
        for s in 0..k {
            let arrive = if s == 0 { 0.0 } else { finish[s - 1] + xfer_seconds[s - 1] };
            let start = arrive.max(finish[s]);
            finish[s] = start + t[s];
            on_slice(s, mb, start, finish[s]);
        }
    }
    let makespan = finish[k - 1];
    let busy: f64 = t.iter().sum::<f64>() * m as f64;
    let bubble = if makespan > 0.0 && k > 1 {
        (1.0 - busy / (k as f64 * makespan)).max(0.0)
    } else {
        0.0
    };
    ScheduleResult {
        makespan_seconds: makespan,
        bubble_fraction: bubble,
        stage_microbatch_seconds: t,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn uniform_stages_match_the_closed_form_bubble() {
        // (K-1)/(M+K-1): small-integer float arithmetic, so the DP and
        // the closed form agree to full precision.
        for (k, m) in [(4usize, 8usize), (2, 4), (8, 16), (4, 1)] {
            let r = simulate_1f1b(&vec![1.0; k], &vec![0.0; k - 1], m);
            let closed = (k - 1) as f64 / (m + k - 1) as f64;
            assert!(
                (r.bubble_fraction - closed).abs() < 1e-12,
                "K={k} M={m}: got {} want {closed}",
                r.bubble_fraction
            );
            // Uniform makespan is (M + K - 1) per-microbatch slots.
            let slot = 1.0 / m as f64;
            assert!((r.makespan_seconds - (m + k - 1) as f64 * slot).abs() < 1e-12);
        }
    }

    #[test]
    fn single_stage_degenerates_to_the_flat_runtime() {
        let r = simulate_1f1b(&[0.125], &[], 8);
        assert_eq!(r.bubble_fraction, 0.0);
        assert!((r.makespan_seconds - 0.125).abs() < 1e-15, "M microbatches of total/M");
        let r1 = simulate_1f1b(&[0.125], &[], 1);
        assert_eq!(r1.makespan_seconds, 0.125);
    }

    #[test]
    fn transfers_stretch_the_makespan() {
        let free = simulate_1f1b(&[1.0, 1.0], &[0.0], 4);
        let paid = simulate_1f1b(&[1.0, 1.0], &[0.1], 4);
        assert!(paid.makespan_seconds > free.makespan_seconds);
        assert!(paid.bubble_fraction > free.bubble_fraction);
    }

    #[test]
    fn imbalance_is_priced_by_the_slowest_stage() {
        // The slow stage serialises: makespan >= M * t_slow.
        let r = simulate_1f1b(&[1.0, 3.0, 1.0], &[0.0, 0.0], 6);
        assert!(r.makespan_seconds >= 6.0 * (3.0 / 6.0));
        let balanced = simulate_1f1b(&[5.0 / 3.0; 3], &[0.0, 0.0], 6);
        assert!(
            balanced.makespan_seconds < r.makespan_seconds,
            "same total work, balanced cuts must win"
        );
    }

    #[test]
    fn more_microbatches_shrink_the_bubble() {
        let few = simulate_1f1b(&[1.0; 4], &[0.0; 3], 4);
        let many = simulate_1f1b(&[1.0; 4], &[0.0; 3], 32);
        assert!(many.bubble_fraction < few.bubble_fraction);
    }

    #[test]
    fn empty_input_is_harmless() {
        let r = simulate_1f1b(&[], &[], 4);
        assert_eq!(r.makespan_seconds, 0.0);
        assert_eq!(r.bubble_fraction, 0.0);
    }

    #[test]
    fn slices_agree_with_the_plain_simulation() {
        let stage = [1.0, 3.0, 1.0];
        let xfer = [0.1, 0.2];
        let plain = simulate_1f1b(&stage, &xfer, 6);
        let (with_slices, slices) = simulate_1f1b_slices(&stage, &xfer, 6);
        assert_eq!(plain, with_slices, "slice capture must not change the DP");
        assert_eq!(slices.len(), 3 * 6, "one slice per (stage, microbatch)");
        // Each slice spans exactly t[s]; per-stage slices never overlap;
        // the last slice ends at the makespan.
        for sl in &slices {
            let t = with_slices.stage_microbatch_seconds[sl.stage];
            assert!((sl.end_seconds - sl.start_seconds - t).abs() < 1e-12);
        }
        for s in 0..3 {
            let mut per_stage: Vec<_> = slices.iter().filter(|x| x.stage == s).collect();
            per_stage.sort_by(|a, b| a.microbatch.cmp(&b.microbatch));
            for w in per_stage.windows(2) {
                assert!(w[1].start_seconds >= w[0].end_seconds - 1e-12);
            }
        }
        let last_end = slices.iter().map(|x| x.end_seconds).fold(0.0f64, f64::max);
        assert!((last_end - with_slices.makespan_seconds).abs() < 1e-12);
    }
}
