//! Inter-operator pipeline parallelism (DESIGN.md §11): a stage
//! partitioner that cuts a [`crate::ir::Func`] into K contiguous stages
//! over a dedicated mesh axis, and a 1F1B schedule simulator that prices
//! microbatched execution — warm-up/drain bubble included — from the
//! same per-node roofline terms the SPMD cost model produces.
//!
//! This is the second level of the two-level parallelism hierarchy
//! (inter-op stages × intra-op SPMD tiles): stage-cut positions are
//! search actions alongside tile actions, cross-stage activation
//! transfers are priced as `Send`/`Recv` collectives
//! ([`crate::spmd::collectives`]), and the composite evaluation
//! ([`crate::cost::composite::evaluate_pipelined`]) replaces the flat
//! runtime/memory terms with the 1F1B makespan and per-stage liveness
//! ceilings.

pub mod partition;
pub mod schedule;

pub use partition::{
    balanced_cuts, boundary_transfers, parse_pipeline_flag, stage_weights, BoundaryTransfer,
    PipelineFlag, PipelineSpec,
};
pub use schedule::{simulate_1f1b, simulate_1f1b_slices, ScheduleResult, StageSlice};
