//! # automap — reproduction of "Automap: Towards Ergonomic Automated
//! # Parallelism for ML Models" (Schaarschmidt et al., 2021)
//!
//! An automated SPMD partitioner: a PartIR-style rewriting layer over a
//! base tensor dialect, inductive propagation tactics, MCTS search, and a
//! learned node-ranking filter, evaluated on transformer / GraphNet
//! training graphs with collective-statistics Megatron detection and an
//! analytical TPU-v3 runtime model.
//!
//! The user-facing entry point is [`session::Session`], which executes
//! composable [`session::Tactic`] pipelines (manual constraints →
//! filter → search → infer-rest → lower) and returns a serialisable
//! [`session::PartitionPlan`]. The [`service`] layer turns sessions into
//! a concurrent planning service: fingerprint-keyed plan cache,
//! root-parallel search executor, and a JSONL serve/batch front-end.
//! See README.md for the quickstart.

pub mod ir;
pub mod coordinator;
pub mod cost;
pub mod learner;
pub mod models;
pub mod obs;
pub mod partir;
pub mod pipeline;
pub mod runtime;
pub mod search;
pub mod service;
pub mod session;
pub mod sim;
pub mod spmd;
pub mod util;
