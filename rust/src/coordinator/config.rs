//! Experiment/config system: JSON config files that override the figure
//! and partition defaults, so runs are reproducible and scriptable
//! (`automap fig6 --config configs/fig6_paper.json`).

use crate::coordinator::figures::FigureSetup;
use crate::util::json::{parse, Json};
use anyhow::{Context, Result};

/// Load a JSON config file.
pub fn load(path: &str) -> Result<Json> {
    let txt = std::fs::read_to_string(path).with_context(|| format!("reading {path}"))?;
    parse(&txt).map_err(|e| anyhow::anyhow!("{path}: {e}"))
}

/// Apply config overrides onto a `FigureSetup`.
pub fn apply_figure(setup: &mut FigureSetup, cfg: &Json) {
    if let Some(l) = cfg.get("layers").and_then(|v| v.as_usize()) {
        setup.layers = l;
    }
    if let Some(a) = cfg.get("attempts").and_then(|v| v.as_usize()) {
        setup.attempts = a;
    }
    if let Some(s) = cfg.get("seed").and_then(|v| v.as_f64()) {
        setup.seed = s as u64;
    }
    if let Some(b) = cfg.get("budgets").and_then(|v| v.as_arr()) {
        setup.budgets = b.iter().filter_map(|x| x.as_usize()).collect();
    }
    if let Some(r) = cfg.get("ranker").and_then(|v| v.as_str()) {
        setup.ranker_path = r.to_string();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn overrides_apply() {
        let mut s = FigureSetup::default();
        let cfg = parse(r#"{"layers": 8, "budgets": [10, 20], "seed": 7}"#).unwrap();
        apply_figure(&mut s, &cfg);
        assert_eq!(s.layers, 8);
        assert_eq!(s.budgets, vec![10, 20]);
        assert_eq!(s.seed, 7);
        // untouched fields keep defaults
        assert_eq!(s.attempts, FigureSetup::default().attempts);
    }

    #[test]
    fn load_missing_file_errors() {
        assert!(load("/definitely/not/here.json").is_err());
    }
}
