//! The end-user entry point, mirroring the paper's Figure 5 workflow:
//!
//! ```text
//! partitioned_fn, specs = automap(update_fn, mesh={"batch":2,"model":4},
//!                                 manual_axes=["batch"])
//! ```
//!
//! Given a training-step function and a mesh, `Automap::partition` runs
//! featurization → (optional) learned top-k filter → MCTS → SPMD
//! lowering, and returns the partitioning *specification* for every
//! input/output plus the cost evaluation — "in addition to a partitioned
//! callable, automap returns a specification of partitioning decisions
//! for inputs and outputs".

use crate::cost::composite::{evaluate, CostWeights, Evaluation};
use crate::ir::Func;
use crate::learner::features::featurize;
use crate::learner::ranker::{top_k_decisions, HeuristicRanker, PjrtRanker, Ranker, TOP_K};
use crate::partir::dist::DistMap;
use crate::partir::mesh::Mesh;
use crate::partir::program::PartirProgram;
use crate::partir::propagate::PropStats;
use crate::search::env::{RewriteEnv, SearchOptions};
use crate::search::mcts::{search, MctsConfig};
use crate::sim::device::Device;
use crate::util::json::Json;
use anyhow::Result;

/// How the MCTS worklist is filtered.
#[derive(Debug, Clone, PartialEq)]
pub enum Filter {
    /// All arguments (MCTS-only mode of Fig 6).
    None,
    /// The learned GNN ranker via PJRT (requires `make artifacts`).
    Learned { hlo_path: String },
    /// Deterministic size-based ranker (no artifacts required).
    Heuristic,
}

/// Options for one partition call.
#[derive(Clone)]
pub struct AutomapOptions {
    pub device: Device,
    pub weights: CostWeights,
    pub search: SearchOptions,
    pub mcts: MctsConfig,
    pub budget: usize,
    pub seed: u64,
    pub filter: Filter,
    pub top_k: usize,
}

impl Default for AutomapOptions {
    fn default() -> Self {
        AutomapOptions {
            device: Device::tpu_v3(),
            weights: CostWeights::default(),
            search: SearchOptions::default(),
            mcts: MctsConfig::default(),
            budget: 500,
            seed: 0,
            filter: Filter::Heuristic,
            top_k: TOP_K,
        }
    }
}

/// Partitioning decision for one function argument or output.
#[derive(Debug, Clone)]
pub struct ShardSpec {
    pub name: String,
    /// `(axis name, tensor dim)` pairs; empty = replicated.
    pub tilings: Vec<(String, usize)>,
}

/// The result of a partition call.
pub struct PartitionReport {
    pub input_specs: Vec<ShardSpec>,
    pub output_specs: Vec<ShardSpec>,
    pub eval: Evaluation,
    pub dm: DistMap,
    pub decisions: usize,
    pub episodes_to_best: usize,
    pub worklist_size: usize,
    pub wall_seconds: f64,
}

impl PartitionReport {
    /// Summarise as JSON (written by the CLI).
    pub fn to_json(&self, mesh: &Mesh) -> Json {
        let specs = |xs: &[ShardSpec]| {
            Json::Arr(
                xs.iter()
                    .filter(|s| !s.tilings.is_empty())
                    .map(|s| {
                        Json::obj(vec![
                            ("name", Json::str(s.name.clone())),
                            (
                                "tilings",
                                Json::Arr(
                                    s.tilings
                                        .iter()
                                        .map(|(a, d)| {
                                            Json::obj(vec![
                                                ("axis", Json::str(a.clone())),
                                                ("dim", Json::num(*d as f64)),
                                            ])
                                        })
                                        .collect(),
                                ),
                            ),
                        ])
                    })
                    .collect(),
            )
        };
        Json::obj(vec![
            ("mesh", Json::str(mesh.describe())),
            ("sharded_inputs", specs(&self.input_specs)),
            ("sharded_outputs", specs(&self.output_specs)),
            ("peak_memory_bytes", Json::num(self.eval.memory.peak_bytes as f64)),
            ("fits_memory", Json::Bool(self.eval.fits_memory)),
            ("all_reduces", Json::num(self.eval.collectives.all_reduce_count as f64)),
            ("all_gathers", Json::num(self.eval.collectives.all_gather_count as f64)),
            ("comm_bytes", Json::num(self.eval.collectives.total_bytes() as f64)),
            ("sim_runtime_seconds", Json::num(self.eval.runtime.total_seconds())),
            ("decisions", Json::num(self.decisions as f64)),
            ("episodes_to_best", Json::num(self.episodes_to_best as f64)),
            ("wall_seconds", Json::num(self.wall_seconds)),
        ])
    }
}

/// The automap session: program + options.
pub struct Automap {
    pub program: PartirProgram,
    pub options: AutomapOptions,
}

impl Automap {
    pub fn new(func: Func, mesh: Mesh, options: AutomapOptions) -> Automap {
        Automap { program: PartirProgram::new(func, mesh), options }
    }

    /// Build the (possibly filtered) worklist.
    pub fn worklist(&self) -> Result<Vec<crate::ir::ValueId>> {
        let full = RewriteEnv::default_worklist(&self.program);
        match &self.options.filter {
            Filter::None => Ok(full),
            Filter::Heuristic => {
                let g = featurize(&self.program.func, &self.program.mesh);
                let ranker = HeuristicRanker { func: &self.program.func };
                let scores = ranker.score(&g)?;
                Ok(top_k_decisions(&self.program.func, &g, &scores, self.options.top_k))
            }
            Filter::Learned { hlo_path } => {
                let rt = crate::runtime::pjrt::Runtime::new()?;
                let ranker = PjrtRanker::load(&rt, hlo_path)?;
                let g = featurize(&self.program.func, &self.program.mesh);
                let scores = ranker.score(&g)?;
                Ok(top_k_decisions(&self.program.func, &g, &scores, self.options.top_k))
            }
        }
    }

    /// Run the full pipeline and return the partitioning report.
    pub fn partition(&self) -> Result<PartitionReport> {
        let t0 = std::time::Instant::now();
        let worklist = self.worklist()?;
        let env = RewriteEnv::new(
            &self.program,
            self.options.device.clone(),
            self.options.weights.clone(),
            self.options.search.clone(),
            &worklist,
        );
        let result = search(&env, self.options.budget, self.options.seed, self.options.mcts.clone());

        // Materialise the final distribution (with infer-rest closure).
        let (mut dm, _) = self.program.apply(&result.best_state);
        if self.options.search.auto_infer_rest {
            let mut stats = PropStats::default();
            self.program.prop.infer_rest(
                &self.program.func,
                &self.program.mesh,
                &mut dm,
                &mut stats,
            );
        }
        let eval = evaluate(&self.program, &dm, &self.options.device, &self.options.weights);

        let f = &self.program.func;
        let mesh = &self.program.mesh;
        let spec_for = |v: crate::ir::ValueId, name: String| ShardSpec {
            name,
            tilings: dm
                .tilings(v.index())
                .into_iter()
                .map(|(a, d)| (mesh.name(a).to_string(), d))
                .collect(),
        };
        let input_specs = (0..f.num_args())
            .map(|i| spec_for(crate::ir::ValueId(i as u32), f.args[i].name.clone()))
            .collect();
        let output_specs = f
            .outputs
            .iter()
            .enumerate()
            .map(|(i, &o)| spec_for(o, format!("output_{i}")))
            .collect();

        Ok(PartitionReport {
            input_specs,
            output_specs,
            eval,
            dm,
            decisions: result
                .best_state
                .actions
                .iter()
                .filter(|a| matches!(a, crate::partir::actions::Action::Tile { .. }))
                .count(),
            episodes_to_best: result.episodes_to_best,
            worklist_size: worklist.len(),
            wall_seconds: t0.elapsed().as_secs_f64(),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::mlp::{build_mlp, MlpConfig};
    use crate::models::transformer::{build_transformer, TransformerConfig};

    #[test]
    fn partition_mlp_end_to_end_heuristic() {
        let m = build_mlp(&MlpConfig::small());
        let mesh = Mesh::new(&[("model", 4)]);
        // memory-pressured device
        let prog = PartirProgram::new(m.func.clone(), mesh.clone());
        let dm0 = DistMap::new(&prog.func, &prog.mesh);
        let probe = evaluate(&prog, &dm0, &Device::tpu_v3(), &CostWeights::default());
        let opts = AutomapOptions {
            device: Device { hbm_bytes: probe.memory.peak_bytes / 2, ..Device::tpu_v3() },
            budget: 200,
            seed: 11,
            ..Default::default()
        };
        let am = Automap::new(m.func, mesh, opts);
        let report = am.partition().unwrap();
        assert!(report.eval.fits_memory);
        assert!(report.input_specs.iter().any(|s| !s.tilings.is_empty()));
        let j = report.to_json(&am.program.mesh);
        assert!(j.get("fits_memory").unwrap().as_bool().unwrap());
    }

    #[test]
    fn heuristic_filter_shrinks_worklist() {
        let m = build_transformer(&TransformerConfig::tiny(4));
        let mesh = Mesh::new(&[("model", 4)]);
        let am = Automap::new(m.func, mesh, AutomapOptions::default());
        let wl = am.worklist().unwrap();
        assert_eq!(wl.len(), TOP_K);
        let full = RewriteEnv::default_worklist(&am.program);
        assert!(full.len() > TOP_K);
    }

    #[test]
    fn manual_axes_are_respected() {
        // "batch" marked manual: search may only use "model".
        let m = build_mlp(&MlpConfig::small());
        let mesh = Mesh::new(&[("batch", 2), ("model", 4)]).manual("batch");
        let opts = AutomapOptions { budget: 100, ..Default::default() };
        let am = Automap::new(m.func, mesh, opts);
        let report = am.partition().unwrap();
        for s in &report.input_specs {
            for (axis, _) in &s.tilings {
                assert_ne!(axis, "batch", "search must not assign the manual axis");
            }
        }
    }
}
