//! Compatibility shim over the [`crate::session`] API, kept for callers
//! written against the original one-shot entry point.
//!
//! `Automap::partition` is now a fixed tactic pipeline executed by a
//! [`Session`] — filter → search → infer-rest → lower — equivalent to:
//!
//! ```ignore
//! let mut s = Session::with_options(func, mesh, device, weights, search);
//! let plan = s.run(&[
//!     Tactic::Filter { ranker, top_k },
//!     Tactic::Search { budget, seed, mcts },
//!     Tactic::InferRest,   // when auto_infer_rest
//!     Tactic::Lower,
//! ])?;
//! ```
//!
//! New code should use [`Session`] directly: it additionally supports
//! `Manual` constraints (pinned axes and `(name, dim, axis)` shardings,
//! paper Fig 5), stage reordering, and serialisable [`PartitionPlan`]s.

use crate::cost::composite::{CostWeights, Evaluation};
use crate::ir::Func;
use crate::learner::ranker::TOP_K;
use crate::partir::dist::DistMap;
use crate::partir::mesh::Mesh;
use crate::partir::program::PartirProgram;
use crate::search::env::SearchOptions;
use crate::search::mcts::MctsConfig;
use crate::session::{resolve_worklist, PartitionPlan, RankerSpec, Session, Tactic};
use crate::sim::device::Device;
use crate::util::json::Json;
use anyhow::Result;

pub use crate::session::plan::ShardSpec;

/// How the MCTS worklist is filtered (legacy spelling of [`RankerSpec`]).
#[derive(Debug, Clone, PartialEq)]
pub enum Filter {
    /// All arguments (MCTS-only mode of Fig 6).
    None,
    /// The learned GNN ranker via PJRT (requires `make artifacts`).
    Learned { hlo_path: String },
    /// Deterministic size-based ranker (no artifacts required).
    Heuristic,
}

impl Filter {
    pub fn to_ranker_spec(&self) -> RankerSpec {
        match self {
            Filter::None => RankerSpec::None,
            Filter::Heuristic => RankerSpec::Heuristic,
            Filter::Learned { hlo_path } => RankerSpec::Learned { hlo_path: hlo_path.clone() },
        }
    }
}

/// Options for one partition call.
#[derive(Clone)]
pub struct AutomapOptions {
    pub device: Device,
    pub weights: CostWeights,
    pub search: SearchOptions,
    pub mcts: MctsConfig,
    pub budget: usize,
    pub seed: u64,
    pub filter: Filter,
    pub top_k: usize,
}

impl Default for AutomapOptions {
    fn default() -> Self {
        AutomapOptions {
            device: Device::tpu_v3(),
            weights: CostWeights::default(),
            search: SearchOptions::default(),
            mcts: MctsConfig::default(),
            budget: 500,
            seed: 0,
            filter: Filter::Heuristic,
            top_k: TOP_K,
        }
    }
}

/// The result of a partition call (legacy shape of [`PartitionPlan`]).
pub struct PartitionReport {
    pub input_specs: Vec<ShardSpec>,
    pub output_specs: Vec<ShardSpec>,
    pub eval: Evaluation,
    pub dm: DistMap,
    pub decisions: usize,
    pub episodes_to_best: usize,
    pub worklist_size: usize,
    pub wall_seconds: f64,
}

impl PartitionReport {
    fn from_plan(plan: PartitionPlan, dm: DistMap) -> PartitionReport {
        PartitionReport {
            input_specs: plan.input_specs,
            output_specs: plan.output_specs,
            eval: plan.eval,
            dm,
            decisions: plan.decisions,
            episodes_to_best: plan.episodes_to_best,
            worklist_size: plan.worklist_size,
            wall_seconds: plan.wall_seconds,
        }
    }

    /// Summarise as JSON (written by the CLI).
    pub fn to_json(&self, mesh: &Mesh) -> Json {
        let specs = |xs: &[ShardSpec]| {
            Json::Arr(xs.iter().filter(|s| !s.replicated()).map(|s| s.to_json()).collect())
        };
        Json::obj(vec![
            ("mesh", Json::str(mesh.describe())),
            ("sharded_inputs", specs(&self.input_specs)),
            ("sharded_outputs", specs(&self.output_specs)),
            ("peak_memory_bytes", Json::num(self.eval.memory.peak_bytes as f64)),
            ("fits_memory", Json::Bool(self.eval.fits_memory)),
            ("all_reduces", Json::num(self.eval.collectives.all_reduce_count as f64)),
            ("all_gathers", Json::num(self.eval.collectives.all_gather_count as f64)),
            ("comm_bytes", Json::num(self.eval.collectives.total_bytes() as f64)),
            ("sim_runtime_seconds", Json::num(self.eval.runtime.total_seconds())),
            ("decisions", Json::num(self.decisions as f64)),
            ("episodes_to_best", Json::num(self.episodes_to_best as f64)),
            ("wall_seconds", Json::num(self.wall_seconds)),
        ])
    }
}

/// The legacy one-shot entry point: program + options.
pub struct Automap {
    pub program: PartirProgram,
    pub options: AutomapOptions,
}

impl Automap {
    pub fn new(func: Func, mesh: Mesh, options: AutomapOptions) -> Automap {
        Automap { program: PartirProgram::new(func, mesh), options }
    }

    /// Build the (possibly filtered) worklist.
    pub fn worklist(&self) -> Result<Vec<crate::ir::ValueId>> {
        let (wl, _) = resolve_worklist(
            &self.program,
            &self.options.filter.to_ranker_spec(),
            self.options.top_k,
        )?;
        Ok(wl)
    }

    /// Run the fixed pipeline through a [`Session`] and return the report.
    pub fn partition(&self) -> Result<PartitionReport> {
        let mut session = Session::with_options(
            self.program.func.clone(),
            self.program.mesh.clone(),
            self.options.device.clone(),
            self.options.weights.clone(),
            self.options.search.clone(),
        );
        let mut tactics = vec![
            Tactic::Filter {
                ranker: self.options.filter.to_ranker_spec(),
                top_k: self.options.top_k,
            },
            Tactic::Search {
                budget: self.options.budget,
                seed: self.options.seed,
                mcts: self.options.mcts.clone(),
            },
        ];
        if self.options.search.auto_infer_rest {
            tactics.push(Tactic::InferRest);
        }
        tactics.push(Tactic::Lower);
        let plan = session.run(&tactics)?;
        let dm = session.dist_map().clone();
        Ok(PartitionReport::from_plan(plan, dm))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cost::composite::evaluate;
    use crate::models::mlp::{build_mlp, MlpConfig};
    use crate::models::transformer::{build_transformer, TransformerConfig};
    use crate::search::env::RewriteEnv;

    #[test]
    fn partition_mlp_end_to_end_heuristic() {
        let m = build_mlp(&MlpConfig::small());
        let mesh = Mesh::new(&[("model", 4)]);
        // memory-pressured device
        let prog = PartirProgram::new(m.func.clone(), mesh.clone());
        let dm0 = DistMap::new(&prog.func, &prog.mesh);
        let probe = evaluate(&prog, &dm0, &Device::tpu_v3(), &CostWeights::default());
        let opts = AutomapOptions {
            device: Device { hbm_bytes: probe.memory.peak_bytes / 2, ..Device::tpu_v3() },
            budget: 200,
            seed: 11,
            ..Default::default()
        };
        let am = Automap::new(m.func, mesh, opts);
        let report = am.partition().unwrap();
        assert!(report.eval.fits_memory);
        assert!(report.input_specs.iter().any(|s| !s.tilings.is_empty()));
        let j = report.to_json(&am.program.mesh);
        assert!(j.get("fits_memory").unwrap().as_bool().unwrap());
    }

    #[test]
    fn heuristic_filter_shrinks_worklist() {
        let m = build_transformer(&TransformerConfig::tiny(4));
        let mesh = Mesh::new(&[("model", 4)]);
        let am = Automap::new(m.func, mesh, AutomapOptions::default());
        let wl = am.worklist().unwrap();
        assert_eq!(wl.len(), TOP_K);
        let full = RewriteEnv::default_worklist(&am.program);
        assert!(full.len() > TOP_K);
    }

    #[test]
    fn manual_axes_are_respected() {
        // "batch" marked manual: search may only use "model".
        let m = build_mlp(&MlpConfig::small());
        let mesh = Mesh::new(&[("batch", 2), ("model", 4)]).manual("batch");
        let opts = AutomapOptions { budget: 100, ..Default::default() };
        let am = Automap::new(m.func, mesh, opts);
        let report = am.partition().unwrap();
        for s in &report.input_specs {
            for (axis, _) in &s.tilings {
                assert_ne!(axis, "batch", "search must not assign the manual axis");
            }
        }
    }
}
