//! L3 coordinator: the legacy `Automap` one-shot API (now a shim over
//! [`crate::session::Session`]), the experiment config system, and the
//! figure harnesses.

pub mod automap;
pub mod config;
pub mod figures;

pub use automap::{Automap, AutomapOptions, Filter, PartitionReport, ShardSpec};
pub use figures::FigureSetup;
