//! L3 coordinator: the public `Automap` API (Fig 5 workflow), the
//! experiment config system, and the figure harnesses.

pub mod automap;
pub mod config;
pub mod figures;

pub use automap::{Automap, AutomapOptions, Filter, PartitionReport, ShardSpec};
pub use figures::FigureSetup;
