//! Figure harnesses: regenerate every figure in the paper's evaluation
//! (§3) — printed as the same series the paper plots and written as JSON
//! under `results/`.
//!
//!   Fig 6 — Megatron discovery success rate vs. search budget,
//!           MCTS-only vs. MCTS + learned filter.
//!   Fig 7 — TPU-v3 (simulated) runtimes of the solutions found.
//!   Fig 8 — effect of grouping repeated blocks via compiler hints.
//!   Fig 9 — grouping when cross-layer shared-dependency propagation is
//!           unavailable (ungrouped deep models fail).

use crate::models::transformer::{build_transformer, TransformerConfig};
use crate::partir::mesh::{AxisId, Mesh};
use crate::partir::program::PartirProgram;
use crate::search::env::SearchOptions;
use crate::search::experiment::{run_sweep, BudgetRow, ExperimentConfig};
use crate::util::json::Json;
use anyhow::Result;

/// Shared workload settings for the figure experiments.
pub struct FigureSetup {
    pub layers: usize,
    pub budgets: Vec<usize>,
    pub attempts: usize,
    pub seed: u64,
    /// Path to the AOT ranker; falls back to the heuristic ranker if absent.
    pub ranker_path: String,
}

impl Default for FigureSetup {
    fn default() -> Self {
        FigureSetup {
            layers: 4,
            budgets: vec![50, 100, 250, 500, 1000, 2000],
            attempts: 20,
            seed: 42,
            ranker_path: "artifacts/ranker.hlo.txt".to_string(),
        }
    }
}

fn build(layers: usize) -> (PartirProgram, crate::models::transformer::TransformerModel) {
    let model = build_transformer(&TransformerConfig::tiny(layers));
    let program = PartirProgram::new(model.func.clone(), Mesh::new(&[("model", 4)]));
    (program, model)
}

/// Resolve the learner filter through the session Filter tactic's
/// resolver: PJRT ranker if artifacts exist (and the `pjrt` feature is
/// built in), else the heuristic ranker (clearly labelled in output).
pub fn learned_worklist(
    program: &PartirProgram,
    ranker_path: &str,
    k: usize,
) -> Result<(Vec<crate::ir::ValueId>, &'static str)> {
    crate::session::resolve_worklist(
        program,
        &crate::session::RankerSpec::Auto { hlo_path: ranker_path.to_string() },
        k,
    )
}

fn rows_to_json(rows: &[BudgetRow]) -> Json {
    Json::Arr(
        rows.iter()
            .map(|r| {
                Json::obj(vec![
                    ("budget", Json::num(r.budget as f64)),
                    ("success_rate", Json::num(r.success_rate)),
                    ("near_rate", Json::num(r.near_rate)),
                    ("mean_runtime", Json::num(r.mean_runtime)),
                    ("megatron_runtime", Json::num(r.megatron_runtime)),
                    ("mean_decisions", Json::num(r.mean_decisions)),
                ])
            })
            .collect(),
    )
}

fn print_series(name: &str, rows: &[BudgetRow], runtime: bool) {
    println!("  series: {name}");
    for r in rows {
        if runtime {
            println!(
                "    budget={:<6} runtime={:<12} (megatron={}) near_rate={:.2}",
                r.budget,
                crate::util::stats::fmt_secs(r.mean_runtime),
                crate::util::stats::fmt_secs(r.megatron_runtime),
                r.near_rate
            );
        } else {
            println!(
                "    budget={:<6} success={:.2} near={:.2} decisions={:.1}",
                r.budget, r.success_rate, r.near_rate, r.mean_decisions
            );
        }
    }
}

fn write_json(path: &str, j: &Json) -> Result<()> {
    if let Some(dir) = std::path::Path::new(path).parent() {
        std::fs::create_dir_all(dir)?;
    }
    std::fs::write(path, j.pretty())?;
    println!("  wrote {path}");
    Ok(())
}

/// Figures 6 + 7 share their runs: sweep budgets for MCTS-only and for
/// MCTS + learned top-k filter; Fig 6 reports success rates, Fig 7 the
/// simulated runtimes of the best solutions.
pub fn fig6_fig7(setup: &FigureSetup, out_dir: &str) -> Result<(Vec<BudgetRow>, Vec<BudgetRow>)> {
    let (program, model) = build(setup.layers);
    let mk_cfg = || ExperimentConfig {
        budgets: setup.budgets.clone(),
        attempts: setup.attempts,
        seed: setup.seed,
        options: SearchOptions::default(), // cross-layer tying ON (paper base)
        ..Default::default()
    };

    println!(
        "Figure 6: Megatron discovery success rate ({} layers, {} attempts)",
        setup.layers, setup.attempts
    );
    let (mcts_rows, _) = run_sweep(&program, &model, AxisId(0), &mk_cfg(), None);
    print_series("mcts-only", &mcts_rows, false);

    let (wl, label) = learned_worklist(&program, &setup.ranker_path, crate::learner::TOP_K)?;
    println!("  (learner filter: {label}, worklist {} -> {})",
        crate::search::env::RewriteEnv::default_worklist(&program).len(), wl.len());
    let (learned_rows, _) = run_sweep(&program, &model, AxisId(0), &mk_cfg(), Some(wl));
    print_series("mcts+learner", &learned_rows, false);

    println!("Figure 7: simulated TPU-v3 runtimes of found solutions");
    print_series("mcts-only", &mcts_rows, true);
    print_series("mcts+learner", &learned_rows, true);

    write_json(
        &format!("{out_dir}/fig6.json"),
        &Json::obj(vec![
            ("mcts_only", rows_to_json(&mcts_rows)),
            ("mcts_learner", rows_to_json(&learned_rows)),
            ("learner_kind", Json::str(label)),
            ("layers", Json::num(setup.layers as f64)),
            ("attempts", Json::num(setup.attempts as f64)),
        ]),
    )?;
    write_json(
        &format!("{out_dir}/fig7.json"),
        &Json::obj(vec![
            ("mcts_only", rows_to_json(&mcts_rows)),
            ("mcts_learner", rows_to_json(&learned_rows)),
        ]),
    )?;
    Ok((mcts_rows, learned_rows))
}

/// Figure 8: grouped layer blocks (compiler hints) vs. ungrouped, on a
/// deeper model. Grouping exposes one decision set per repeated block.
pub fn fig8(setup: &FigureSetup, out_dir: &str) -> Result<(Vec<BudgetRow>, Vec<BudgetRow>)> {
    let (program, model) = build(setup.layers);
    let base = |grouping: bool, tying: bool| ExperimentConfig {
        budgets: setup.budgets.clone(),
        attempts: setup.attempts,
        seed: setup.seed ^ 0x8888,
        options: SearchOptions { grouping, cross_layer_tying: tying, ..Default::default() },
        ..Default::default()
    };
    println!("Figure 8: grouping via compiler hints ({} layers)", setup.layers);
    let (grouped, _) = run_sweep(&program, &model, AxisId(0), &base(true, false), None);
    print_series("grouped", &grouped, false);
    let (ungrouped, _) = run_sweep(&program, &model, AxisId(0), &base(false, true), None);
    print_series("ungrouped (shared-dep propagation)", &ungrouped, false);
    write_json(
        &format!("{out_dir}/fig8.json"),
        &Json::obj(vec![
            ("grouped", rows_to_json(&grouped)),
            ("ungrouped", rows_to_json(&ungrouped)),
            ("layers", Json::num(setup.layers as f64)),
        ]),
    )?;
    Ok((grouped, ungrouped))
}

/// Figure 9: with shared-dependency propagation DISABLED (its brittleness
/// is the paper's motivation for grouping), grouped search still finds
/// Megatron while ungrouped deep models do not.
pub fn fig9(setup: &FigureSetup, out_dir: &str) -> Result<(Vec<BudgetRow>, Vec<BudgetRow>)> {
    let (program, model) = build(setup.layers);
    let base = |grouping: bool| ExperimentConfig {
        budgets: setup.budgets.clone(),
        attempts: setup.attempts,
        seed: setup.seed ^ 0x9999,
        options: SearchOptions {
            grouping,
            cross_layer_tying: false, // the Fig 9 ablation
            ..Default::default()
        },
        ..Default::default()
    };
    println!(
        "Figure 9: grouping without cross-layer propagation ({} layers)",
        setup.layers
    );
    let (grouped, _) = run_sweep(&program, &model, AxisId(0), &base(true), None);
    print_series("grouped", &grouped, false);
    let (ungrouped, _) = run_sweep(&program, &model, AxisId(0), &base(false), None);
    print_series("ungrouped (no propagation)", &ungrouped, false);
    write_json(
        &format!("{out_dir}/fig9.json"),
        &Json::obj(vec![
            ("grouped", rows_to_json(&grouped)),
            ("ungrouped", rows_to_json(&ungrouped)),
            ("layers", Json::num(setup.layers as f64)),
        ]),
    )?;
    Ok((grouped, ungrouped))
}

/// Setup-statistics table (§3 text): args / ops / memory of the paper
/// config vs. what we build.
pub fn stats(cfg: &TransformerConfig) -> Json {
    let model = build_transformer(cfg);
    let mesh = Mesh::new(&[("model", 4)]);
    let program = PartirProgram::new(model.func.clone(), mesh);
    let dm = crate::partir::dist::DistMap::new(&program.func, &program.mesh);
    let mem = crate::cost::liveness::peak_memory(&program.func, &program.mesh, &dm);
    let j = Json::obj(vec![
        ("layers", Json::num(cfg.layers as f64)),
        ("d_model", Json::num(cfg.d_model as f64)),
        ("params", Json::num(cfg.param_count() as f64)),
        ("arguments", Json::num(model.func.num_args() as f64)),
        ("operations", Json::num(model.func.num_nodes() as f64)),
        ("peak_memory_bytes", Json::num(mem.peak_bytes as f64)),
        ("paper_arguments", Json::num(1150.0)),
        ("paper_operations", Json::num(50000.0)),
        ("paper_memory_gb", Json::num(26.0)),
    ]);
    println!(
        "setup stats: layers={} args={} (paper 1150) ops={} (paper >50k, XLA granularity) \
         peak={} (paper ~26GB)",
        cfg.layers,
        model.func.num_args(),
        model.func.num_nodes(),
        crate::util::stats::fmt_bytes(mem.peak_bytes as f64)
    );
    j
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn small_fig6_run_has_sane_shape() {
        let setup = FigureSetup {
            layers: 1,
            budgets: vec![10, 100],
            attempts: 3,
            seed: 5,
            ranker_path: "/nonexistent".into(),
        };
        let dir = std::env::temp_dir().join("automap_figtest");
        let (m, l) = fig6_fig7(&setup, dir.to_str().unwrap()).unwrap();
        assert_eq!(m.len(), 2);
        assert_eq!(l.len(), 2);
        assert!(dir.join("fig6.json").exists());
        assert!(dir.join("fig7.json").exists());
    }

    #[test]
    fn stats_reports_paper_fields() {
        let j = stats(&TransformerConfig::tiny(2));
        assert!(j.get("arguments").unwrap().as_usize().unwrap() > 50);
        assert_eq!(j.get("paper_arguments").unwrap().as_usize().unwrap(), 1150);
    }
}
