//! Rewrite actions exposed to the automated partitioner (paper §2.2):
//! tiling a value's dimension along a mesh axis, declaring a value atomic
//! (keep replicated), the global infer-rest pass, and stopping.
//!
//! Rewrites preserve semantics by construction — a `Tile` only records a
//! distribution choice; the SPMD lowering inserts whatever collectives
//! make it correct. This decouples search policy from correctness.

use super::dist::DistMap;
use super::mesh::{AxisId, Mesh};
use crate::ir::{Func, ValueId};

/// One rewrite decision.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Action {
    /// Express value `v` as a tiling loop over `axis` on tensor dim `dim`
    /// (paper Fig. 2 middle: `partir.tile`).
    Tile { v: ValueId, dim: usize, axis: AxisId },
    /// Declare `v` atomic: it stays replicated and no later action may
    /// tile it (paper Fig. 2 bottom: `partir.atomic`).
    Atomic { v: ValueId },
    /// Global pass inferring tilings of remaining values from decided ones.
    InferRest,
    /// Terminate the episode.
    Stop,
}

impl Action {
    pub fn describe(&self, f: &Func, mesh: &Mesh) -> String {
        match self {
            Action::Tile { v, dim, axis } => {
                format!("tile {} dim {} on \"{}\"", f.value_name(*v), dim, mesh.name(*axis))
            }
            Action::Atomic { v } => format!("atomic {}", f.value_name(*v)),
            Action::InferRest => "infer-rest".to_string(),
            Action::Stop => "stop".to_string(),
        }
    }
}

/// The decision state of one search episode: explicit actions taken plus
/// the atomic set. The derived `DistMap` is recomputed by the env.
#[derive(Debug, Clone, Default)]
pub struct DecisionState {
    pub actions: Vec<Action>,
    pub atomic: Vec<ValueId>,
}

impl DecisionState {
    pub fn is_atomic(&self, v: ValueId) -> bool {
        self.atomic.contains(&v)
    }
}

/// Is `action` applicable given the current distribution map?
pub fn action_valid(
    f: &Func,
    mesh: &Mesh,
    dm: &DistMap,
    state: &DecisionState,
    action: &Action,
) -> bool {
    match action {
        Action::Tile { v, dim, axis } => {
            if state.is_atomic(*v) {
                return false;
            }
            let ty = f.value_type(*v);
            if *dim >= ty.rank() {
                return false;
            }
            if ty.dims[*dim] % mesh.size(*axis) != 0 {
                return false;
            }
            if dm.get(v.index(), *axis).is_some() {
                return false; // already tiled on this axis
            }
            if dm.dim_taken(v.index(), *axis, *dim) {
                return false; // dim already owned by another axis
            }
            true
        }
        Action::Atomic { v } => !state.is_atomic(*v) && !dm.is_tiled(v.index()),
        Action::InferRest | Action::Stop => true,
    }
}

/// Enumerate all valid `Tile` actions for a value on the searchable axes.
pub fn tile_actions_for(
    f: &Func,
    mesh: &Mesh,
    dm: &DistMap,
    state: &DecisionState,
    v: ValueId,
) -> Vec<Action> {
    let mut out = Vec::new();
    let rank = f.value_type(v).rank();
    for axis in mesh.searchable_axes() {
        for dim in 0..rank {
            let a = Action::Tile { v, dim, axis };
            if action_valid(f, mesh, dm, state, &a) {
                out.push(a);
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::{ArgKind, GraphBuilder, TensorType};

    fn setup() -> (Func, Mesh) {
        let mut b = GraphBuilder::new("t");
        let _w = b.arg("w", TensorType::f32(&[16, 64]), ArgKind::Parameter);
        let _o = b.arg("odd", TensorType::f32(&[3, 5]), ArgKind::Parameter);
        let x = b.arg("x", TensorType::f32(&[16]), ArgKind::Input);
        let y = b.neg(x);
        b.output(y);
        (b.finish(), Mesh::new(&[("batch", 2), ("model", 4)]))
    }

    #[test]
    fn tile_validity_checks_divisibility() {
        let (f, mesh) = setup();
        let dm = DistMap::new(&f, &mesh);
        let st = DecisionState::default();
        let model = mesh.axis_by_name("model").unwrap();
        assert!(action_valid(&f, &mesh, &dm, &st, &Action::Tile { v: ValueId(0), dim: 1, axis: model }));
        // 3 and 5 are not divisible by 2 or 4
        assert!(tile_actions_for(&f, &mesh, &dm, &st, ValueId(1)).is_empty());
    }

    #[test]
    fn atomic_blocks_tiling() {
        let (f, mesh) = setup();
        let dm = DistMap::new(&f, &mesh);
        let mut st = DecisionState::default();
        st.atomic.push(ValueId(0));
        let model = mesh.axis_by_name("model").unwrap();
        assert!(!action_valid(&f, &mesh, &dm, &st, &Action::Tile { v: ValueId(0), dim: 0, axis: model }));
    }

    #[test]
    fn same_axis_twice_invalid_other_axis_other_dim_ok() {
        let (f, mesh) = setup();
        let mut dm = DistMap::new(&f, &mesh);
        let st = DecisionState::default();
        let model = mesh.axis_by_name("model").unwrap();
        let batch = mesh.axis_by_name("batch").unwrap();
        dm.set(0, model, 1);
        assert!(!action_valid(&f, &mesh, &dm, &st, &Action::Tile { v: ValueId(0), dim: 0, axis: model }));
        assert!(!action_valid(&f, &mesh, &dm, &st, &Action::Tile { v: ValueId(0), dim: 1, axis: batch }));
        assert!(action_valid(&f, &mesh, &dm, &st, &Action::Tile { v: ValueId(0), dim: 0, axis: batch }));
    }

    #[test]
    fn enumerates_expected_action_count() {
        let (f, mesh) = setup();
        let dm = DistMap::new(&f, &mesh);
        let st = DecisionState::default();
        // w is 16x64: both dims divisible by both axes -> 2 axes * 2 dims.
        assert_eq!(tile_actions_for(&f, &mesh, &dm, &st, ValueId(0)).len(), 4);
    }
}
