//! Rewrite actions exposed to the automated partitioner (paper §2.2):
//! tiling a value's dimension along a mesh axis, declaring a value atomic
//! (keep replicated), the global infer-rest pass, and stopping.
//!
//! Rewrites preserve semantics by construction — a `Tile` only records a
//! distribution choice; the SPMD lowering inserts whatever collectives
//! make it correct. This decouples search policy from correctness.

use super::dist::DistMap;
use super::mesh::{AxisId, Mesh};
use crate::ir::{Func, ValueId};

/// One rewrite decision.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Action {
    /// Express value `v` as a tiling loop over `axis` on tensor dim `dim`
    /// (paper Fig. 2 middle: `partir.tile`).
    Tile { v: ValueId, dim: usize, axis: AxisId },
    /// Declare `v` atomic: it stays replicated and no later action may
    /// tile it (paper Fig. 2 bottom: `partir.atomic`).
    Atomic { v: ValueId },
    /// Global pass inferring tilings of remaining values from decided ones.
    InferRest,
    /// Terminate the episode.
    Stop,
}

impl Action {
    pub fn describe(&self, f: &Func, mesh: &Mesh) -> String {
        match self {
            Action::Tile { v, dim, axis } => {
                format!("tile {} dim {} on \"{}\"", f.value_name(*v), dim, mesh.name(*axis))
            }
            Action::Atomic { v } => format!("atomic {}", f.value_name(*v)),
            Action::InferRest => "infer-rest".to_string(),
            Action::Stop => "stop".to_string(),
        }
    }
}

/// Membership set over [`ValueId`]s, stored as a bitset so the episode
/// hot path (`is_atomic` inside `action_valid`, called for every
/// candidate action of every MCTS step) is O(1) instead of the O(n)
/// `Vec::contains` scan it replaced.
#[derive(Debug, Default)]
pub struct AtomicSet {
    bits: Vec<u64>,
}

/// Manual impl so `clone_from` reuses the existing word buffer — the
/// MCTS episode loop resets its scratch episode this way (DESIGN.md §8).
impl Clone for AtomicSet {
    fn clone(&self) -> AtomicSet {
        AtomicSet { bits: self.bits.clone() }
    }

    fn clone_from(&mut self, src: &AtomicSet) {
        self.bits.clone_from(&src.bits);
    }
}

/// Equality is by membership: trailing zero words (from pre-sizing via
/// [`AtomicSet::with_capacity`]) are ignored.
impl PartialEq for AtomicSet {
    fn eq(&self, other: &AtomicSet) -> bool {
        let (short, long) =
            if self.bits.len() <= other.bits.len() { (self, other) } else { (other, self) };
        short.bits == long.bits[..short.bits.len()]
            && long.bits[short.bits.len()..].iter().all(|&w| w == 0)
    }
}

impl Eq for AtomicSet {}

impl AtomicSet {
    /// Pre-size for a program with `num_values` values so inserts on the
    /// hot path never reallocate.
    pub fn with_capacity(num_values: usize) -> AtomicSet {
        AtomicSet { bits: vec![0; (num_values + 63) / 64] }
    }

    #[inline]
    pub fn insert(&mut self, v: ValueId) {
        let (word, bit) = (v.index() / 64, v.index() % 64);
        if word >= self.bits.len() {
            self.bits.resize(word + 1, 0);
        }
        self.bits[word] |= 1u64 << bit;
    }

    #[inline]
    pub fn contains(&self, v: ValueId) -> bool {
        self.bits
            .get(v.index() / 64)
            .map_or(false, |w| (w >> (v.index() % 64)) & 1 == 1)
    }

    pub fn is_empty(&self) -> bool {
        self.bits.iter().all(|&w| w == 0)
    }

    pub fn len(&self) -> usize {
        self.bits.iter().map(|w| w.count_ones() as usize).sum()
    }

    /// Iterate members in increasing `ValueId` order.
    pub fn iter(&self) -> impl Iterator<Item = ValueId> + '_ {
        self.bits.iter().enumerate().flat_map(|(wi, &w)| {
            (0..64usize)
                .filter(move |&b| (w >> b) & 1 == 1)
                .map(move |b| ValueId((wi * 64 + b) as u32))
        })
    }
}

impl From<&[ValueId]> for AtomicSet {
    fn from(vs: &[ValueId]) -> AtomicSet {
        let mut s = AtomicSet::default();
        for &v in vs {
            s.insert(v);
        }
        s
    }
}

/// The decision state of one search episode: explicit actions taken plus
/// the atomic set. The derived `DistMap` is recomputed by the env.
#[derive(Debug, Default)]
pub struct DecisionState {
    pub actions: Vec<Action>,
    pub atomic: AtomicSet,
}

/// Manual impl so `clone_from` reuses the action vector and atomic
/// bitmap allocations on the episode-reset hot path.
impl Clone for DecisionState {
    fn clone(&self) -> DecisionState {
        DecisionState { actions: self.actions.clone(), atomic: self.atomic.clone() }
    }

    fn clone_from(&mut self, src: &DecisionState) {
        self.actions.clone_from(&src.actions);
        self.atomic.clone_from(&src.atomic);
    }
}

impl DecisionState {
    /// A state that replays `actions` with an empty atomic set.
    pub fn with_actions(actions: Vec<Action>) -> DecisionState {
        DecisionState { actions, atomic: AtomicSet::default() }
    }

    #[inline]
    pub fn is_atomic(&self, v: ValueId) -> bool {
        self.atomic.contains(v)
    }
}

/// Is `action` applicable given the current distribution map?
pub fn action_valid(
    f: &Func,
    mesh: &Mesh,
    dm: &DistMap,
    state: &DecisionState,
    action: &Action,
) -> bool {
    match action {
        Action::Tile { v, dim, axis } => {
            if state.is_atomic(*v) {
                return false;
            }
            let ty = f.value_type(*v);
            if *dim >= ty.rank() {
                return false;
            }
            if ty.dims[*dim] % mesh.size(*axis) != 0 {
                return false;
            }
            if dm.get(v.index(), *axis).is_some() {
                return false; // already tiled on this axis
            }
            if dm.dim_taken(v.index(), *axis, *dim) {
                return false; // dim already owned by another axis
            }
            true
        }
        Action::Atomic { v } => !state.is_atomic(*v) && !dm.is_tiled(v.index()),
        Action::InferRest | Action::Stop => true,
    }
}

/// Enumerate all valid `Tile` actions for a value on the searchable axes.
pub fn tile_actions_for(
    f: &Func,
    mesh: &Mesh,
    dm: &DistMap,
    state: &DecisionState,
    v: ValueId,
) -> Vec<Action> {
    let mut out = Vec::new();
    let rank = f.value_type(v).rank();
    for axis in mesh.searchable_axes() {
        for dim in 0..rank {
            let a = Action::Tile { v, dim, axis };
            if action_valid(f, mesh, dm, state, &a) {
                out.push(a);
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::{ArgKind, GraphBuilder, TensorType};

    fn setup() -> (Func, Mesh) {
        let mut b = GraphBuilder::new("t");
        let _w = b.arg("w", TensorType::f32(&[16, 64]), ArgKind::Parameter);
        let _o = b.arg("odd", TensorType::f32(&[3, 5]), ArgKind::Parameter);
        let x = b.arg("x", TensorType::f32(&[16]), ArgKind::Input);
        let y = b.neg(x);
        b.output(y);
        (b.finish(), Mesh::new(&[("batch", 2), ("model", 4)]))
    }

    #[test]
    fn tile_validity_checks_divisibility() {
        let (f, mesh) = setup();
        let dm = DistMap::new(&f, &mesh);
        let st = DecisionState::default();
        let model = mesh.axis_by_name("model").unwrap();
        let tile = Action::Tile { v: ValueId(0), dim: 1, axis: model };
        assert!(action_valid(&f, &mesh, &dm, &st, &tile));
        // 3 and 5 are not divisible by 2 or 4
        assert!(tile_actions_for(&f, &mesh, &dm, &st, ValueId(1)).is_empty());
    }

    #[test]
    fn atomic_blocks_tiling() {
        let (f, mesh) = setup();
        let dm = DistMap::new(&f, &mesh);
        let mut st = DecisionState::default();
        st.atomic.insert(ValueId(0));
        let model = mesh.axis_by_name("model").unwrap();
        let tile_d0_model = Action::Tile { v: ValueId(0), dim: 0, axis: model };
        assert!(!action_valid(&f, &mesh, &dm, &st, &tile_d0_model));
    }

    #[test]
    fn same_axis_twice_invalid_other_axis_other_dim_ok() {
        let (f, mesh) = setup();
        let mut dm = DistMap::new(&f, &mesh);
        let st = DecisionState::default();
        let model = mesh.axis_by_name("model").unwrap();
        let batch = mesh.axis_by_name("batch").unwrap();
        dm.set(0, model, 1);
        let tile_d0_model = Action::Tile { v: ValueId(0), dim: 0, axis: model };
        assert!(!action_valid(&f, &mesh, &dm, &st, &tile_d0_model));
        let tile_d1_batch = Action::Tile { v: ValueId(0), dim: 1, axis: batch };
        assert!(!action_valid(&f, &mesh, &dm, &st, &tile_d1_batch));
        let tile_d0_batch = Action::Tile { v: ValueId(0), dim: 0, axis: batch };
        assert!(action_valid(&f, &mesh, &dm, &st, &tile_d0_batch));
    }

    #[test]
    fn atomic_set_bitset_semantics() {
        let mut s = AtomicSet::with_capacity(100);
        assert!(s.is_empty());
        for i in [0u32, 63, 64, 99] {
            s.insert(ValueId(i));
        }
        assert_eq!(s.len(), 4);
        assert!(s.contains(ValueId(63)));
        assert!(s.contains(ValueId(64)));
        assert!(!s.contains(ValueId(65)));
        // out-of-range queries are false, not a panic
        assert!(!s.contains(ValueId(100_000)));
        // growth past the pre-sized capacity
        s.insert(ValueId(1000));
        assert!(s.contains(ValueId(1000)));
        let members: Vec<u32> = s.iter().map(|v| v.0).collect();
        assert_eq!(members, vec![0, 63, 64, 99, 1000]);
        assert_eq!(AtomicSet::from(&[ValueId(7)][..]).len(), 1);
        // equality is by membership, regardless of pre-sized capacity
        assert_eq!(AtomicSet::with_capacity(100), AtomicSet::default());
        let mut a = AtomicSet::with_capacity(1000);
        a.insert(ValueId(7));
        assert_eq!(a, AtomicSet::from(&[ValueId(7)][..]));
        let mut b = AtomicSet::default();
        b.insert(ValueId(8));
        assert_ne!(a, b);
    }

    #[test]
    fn enumerates_expected_action_count() {
        let (f, mesh) = setup();
        let dm = DistMap::new(&f, &mesh);
        let st = DecisionState::default();
        // w is 16x64: both dims divisible by both axes -> 2 axes * 2 dims.
        assert_eq!(tile_actions_for(&f, &mesh, &dm, &st, ValueId(0)).len(), 4);
    }
}
