//! Propagation engine (paper §2.1/§2.3): pushes tiling decisions through
//! the program using the per-op rule registry.
//!
//! Three tactics, mirroring the paper:
//!   * `forward`  — operands → results (run after every rewrite action);
//!   * `infer_rest` — results → operands as well ("a pass that infers the
//!     tiling of the rest of the arguments from only some of them");
//!   * stuck-node detection — nodes where information conflicts or hits
//!     an unmapped dim "resurface back to our worklist".
//!
//! This is the single hottest code path in the system: it runs after
//! every MCTS action over programs with up to ~100k values. Rules are
//! precomputed per node; the sweep itself is allocation-free.

use super::dist::{DistMap, UNKNOWN};
use super::mesh::{AxisId, Mesh};
use super::registry::{rule_for, OpRule};
use crate::ir::{Func, TensorType, ValueId};

/// Precomputed propagation context for one program (immutable during search).
pub struct Propagator {
    pub rules: Vec<OpRule>,
    /// Global dims per value (flattened copy for cache-friendly access).
    dims: Vec<Vec<i64>>,
    /// Global byte size per value (perf: liveness/runtime models read this
    /// instead of re-walking dim vectors — EXPERIMENTS.md §Perf opt 1).
    pub global_bytes: Vec<i64>,
    /// Global element count per value.
    pub global_elems: Vec<i64>,
}

/// Result of a propagation sweep.
#[derive(Debug, Default, Clone)]
pub struct PropStats {
    /// Node indices where propagation got stuck (conflict / unmapped dim).
    pub stuck_nodes: Vec<u32>,
    /// Number of value-axis assignments made.
    pub assigned: usize,
}

impl Propagator {
    pub fn new(f: &Func) -> Propagator {
        let rules = f
            .nodes
            .iter()
            .map(|n| {
                let ins: Vec<&TensorType> =
                    n.inputs.iter().map(|&v| f.value_type(v)).collect();
                rule_for(&n.op, &ins, &n.ty)
            })
            .collect();
        let dims: Vec<Vec<i64>> = (0..f.num_values())
            .map(|v| f.value_type(ValueId(v as u32)).dims.clone())
            .collect();
        let global_bytes = (0..f.num_values())
            .map(|v| f.value_type(ValueId(v as u32)).byte_size())
            .collect();
        let global_elems = (0..f.num_values())
            .map(|v| f.value_type(ValueId(v as u32)).num_elements())
            .collect();
        Propagator { rules, dims, global_bytes, global_elems }
    }

    /// Global dims of a value (borrowed; avoids re-walking the Func).
    #[inline]
    pub fn dims_of(&self, v: usize) -> &[i64] {
        &self.dims[v]
    }

    #[inline]
    fn divisible(&self, v: usize, dim: usize, size: i64) -> bool {
        self.dims[v][dim] % size == 0
    }

    /// Forward sweep: one pass in topological order, all axes at once.
    /// Pre-assigned output dists (explicit actions on internal nodes) are
    /// never overwritten.
    pub fn forward(&self, f: &Func, mesh: &Mesh, dm: &mut DistMap, stats: &mut PropStats) {
        let num_axes = mesh.num_axes();
        for (ni, node) in f.nodes.iter().enumerate() {
            let rule = &self.rules[ni];
            let out_v = f.num_args() + ni;
            for a in 0..num_axes {
                let axis = AxisId(a);
                let asize = mesh.size(axis);
                if asize == 1 {
                    continue;
                }
                // Reduced-tie hit on this axis?
                let mut reduced_hit = false;
                let mut reduced_conflict = false;
                for group in &rule.reduced_ties {
                    let mut any = false;
                    let mut all = true;
                    for &(oi, od) in group {
                        let iv = node.inputs[oi].index();
                        if dm.d[iv][a] == od as u8 {
                            any = true;
                        } else {
                            all = false;
                        }
                    }
                    if any {
                        reduced_hit = true;
                        if !all && group.len() > 1 {
                            // only one side of a contraction is tiled:
                            // lowering must slice/gather — mark stuck.
                            reduced_conflict = true;
                        }
                    }
                }
                // Output-dim candidate from operand tilings.
                let mut cand: Option<usize> = None;
                let mut conflict = false;
                for (od, ties) in rule.out_ties.iter().enumerate() {
                    for &(oi, idim) in ties {
                        let iv = node.inputs[oi].index();
                        if dm.d[iv][a] == idim as u8 {
                            match cand {
                                None => cand = Some(od),
                                Some(c) if c != od => conflict = true,
                                _ => {}
                            }
                        }
                    }
                }
                let pre_set = dm.d[out_v][a] != UNKNOWN;
                match (cand, reduced_hit) {
                    (Some(od), rh) => {
                        if !pre_set
                            && self.divisible(out_v, od, asize)
                            && !dm.dim_taken(out_v, axis, od)
                        {
                            dm.set(out_v, axis, od);
                            stats.assigned += 1;
                        } else if !pre_set {
                            conflict = true;
                        }
                        if rh || conflict || reduced_conflict {
                            stats.stuck_nodes.push(ni as u32);
                        }
                    }
                    (None, true) => {
                        // Pure contraction tiling: output replicated on this
                        // axis, all-reduce inserted at lowering.
                        if reduced_conflict {
                            stats.stuck_nodes.push(ni as u32);
                        }
                    }
                    (None, false) => {
                        if conflict {
                            stats.stuck_nodes.push(ni as u32);
                        }
                    }
                }
            }
        }
    }

    /// Backward sweep: infer operand tilings from tiled results. Only
    /// assigns to values that are still Unknown. Returns assignments made.
    pub fn backward(&self, f: &Func, mesh: &Mesh, dm: &mut DistMap) -> usize {
        let num_axes = mesh.num_axes();
        let mut assigned = 0;
        for ni in (0..f.num_nodes()).rev() {
            let node = &f.nodes[ni];
            let rule = &self.rules[ni];
            let out_v = f.num_args() + ni;
            for a in 0..num_axes {
                let axis = AxisId(a);
                let asize = mesh.size(axis);
                if asize == 1 {
                    continue;
                }
                let od = match dm.get(out_v, axis) {
                    Some(od) => od,
                    None => continue,
                };
                if od >= rule.out_ties.len() {
                    continue;
                }
                for &(oi, idim) in &rule.out_ties[od] {
                    let iv = node.inputs[oi].index();
                    if dm.d[iv][a] == UNKNOWN
                        && self.divisible(iv, idim, asize)
                        && !dm.dim_taken(iv, axis, idim)
                    {
                        dm.set(iv, axis, idim);
                        assigned += 1;
                    }
                }
            }
        }
        assigned
    }

    /// The paper's "infer the tiling of the rest of the arguments" global
    /// pass: alternate backward/forward sweeps to a bounded fixpoint.
    pub fn infer_rest(&self, f: &Func, mesh: &Mesh, dm: &mut DistMap, stats: &mut PropStats) {
        for _ in 0..3 {
            let n = self.backward(f, mesh, dm);
            self.forward(f, mesh, dm, stats);
            if n == 0 {
                break;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::{ArgKind, GraphBuilder, TensorType};

    /// Paper Figure 2: linear layer, tile %arg1 (weights) on dim 1.
    fn fig2() -> (Func, Mesh) {
        let mut b = GraphBuilder::new("main");
        let x = b.arg("x", TensorType::f32(&[8, 16]), ArgKind::Input);
        let w = b.arg("w", TensorType::f32(&[16, 64]), ArgKind::Parameter);
        let bias = b.arg("b", TensorType::f32(&[64]), ArgKind::Parameter);
        let dot = b.matmul(x, w);
        let ty = b.ty(dot).clone();
        let bb = b.broadcast_to(bias, ty);
        let out = b.add(dot, bb);
        b.output(out);
        (b.finish(), Mesh::new(&[("shard", 2)]))
    }

    #[test]
    fn figure2_column_sharding_propagates() {
        let (f, mesh) = fig2();
        let p = Propagator::new(&f);
        let mut dm = DistMap::new(&f, &mesh);
        let ax = AxisId(0);
        dm.set(1, ax, 1); // tile w on dim 1
        let mut st = PropStats::default();
        p.forward(&f, &mesh, &mut dm, &mut st);
        // dot result tiled dim 1, add result tiled dim 1
        let dot_v = f.num_args(); // node 0
        let out_v = f.num_args() + 2;
        assert_eq!(dm.get(dot_v, ax), Some(1));
        assert_eq!(dm.get(out_v, ax), Some(1));
        assert!(st.stuck_nodes.is_empty());
        // x (arg0) untouched — stays replicated ("atomic" in Fig 2).
        assert_eq!(dm.get(0, ax), None);
    }

    #[test]
    fn figure2_backward_infers_bias() {
        let (f, mesh) = fig2();
        let p = Propagator::new(&f);
        let mut dm = DistMap::new(&f, &mesh);
        let ax = AxisId(0);
        dm.set(1, ax, 1);
        let mut st = PropStats::default();
        p.forward(&f, &mesh, &mut dm, &mut st);
        p.infer_rest(&f, &mesh, &mut dm, &mut st);
        // bias (arg2) inferred tiled dim 0 via broadcast tie.
        assert_eq!(dm.get(2, ax), Some(0));
    }

    #[test]
    fn contraction_tiling_makes_output_replicated() {
        // Megatron row-sharding: tile w on its CONTRACTING dim.
        let (f, mesh) = fig2();
        let p = Propagator::new(&f);
        let mut dm = DistMap::new(&f, &mesh);
        let ax = AxisId(0);
        dm.set(1, ax, 0); // w dim 0 = contraction
        let mut st = PropStats::default();
        p.forward(&f, &mesh, &mut dm, &mut st);
        let dot_v = f.num_args();
        assert_eq!(dm.get(dot_v, ax), None); // partial sum -> replicated
        // one-sided contraction: x not tiled on dim 1 -> stuck node reported
        assert_eq!(st.stuck_nodes, vec![0]);
    }

    #[test]
    fn two_sided_contraction_is_not_stuck() {
        let (f, mesh) = fig2();
        let p = Propagator::new(&f);
        let mut dm = DistMap::new(&f, &mesh);
        let ax = AxisId(0);
        dm.set(0, ax, 1); // x dim 1 (contract)
        dm.set(1, ax, 0); // w dim 0 (contract)
        let mut st = PropStats::default();
        p.forward(&f, &mesh, &mut dm, &mut st);
        assert!(st.stuck_nodes.is_empty());
        assert_eq!(dm.get(f.num_args(), ax), None);
    }

    #[test]
    fn conflicting_tilings_get_stuck() {
        let mut b = GraphBuilder::new("c");
        let x = b.arg("x", TensorType::f32(&[4, 4]), ArgKind::Input);
        let y = b.arg("y", TensorType::f32(&[4, 4]), ArgKind::Input);
        let s = b.add(x, y);
        b.output(s);
        let f = b.finish();
        let mesh = Mesh::new(&[("shard", 2)]);
        let p = Propagator::new(&f);
        let mut dm = DistMap::new(&f, &mesh);
        dm.set(0, AxisId(0), 0);
        dm.set(1, AxisId(0), 1); // conflicting dims
        let mut st = PropStats::default();
        p.forward(&f, &mesh, &mut dm, &mut st);
        assert_eq!(st.stuck_nodes, vec![0]);
        // first-wins: output tiled at dim 0
        assert_eq!(dm.get(2, AxisId(0)), Some(0));
    }

    #[test]
    fn indivisible_dims_not_tiled() {
        let mut b = GraphBuilder::new("c");
        let x = b.arg("x", TensorType::f32(&[3, 4]), ArgKind::Input);
        let n = b.neg(x);
        b.output(n);
        let f = b.finish();
        let mesh = Mesh::new(&[("shard", 2)]);
        let p = Propagator::new(&f);
        let mut dm = DistMap::new(&f, &mesh);
        dm.set(0, AxisId(0), 0); // dim of size 3, axis of size 2
        let mut st = PropStats::default();
        p.forward(&f, &mesh, &mut dm, &mut st);
        assert_eq!(dm.get(1, AxisId(0)), None);
        assert_eq!(st.stuck_nodes, vec![0]);
    }

    #[test]
    fn reshape_merge_propagates_head_tiling() {
        // [B,S,H,Dh] -> [B,S,D] with H tiled: merged dim stays tiled.
        let mut b = GraphBuilder::new("r");
        let x = b.arg("x", TensorType::f32(&[2, 8, 4, 16]), ArgKind::Input);
        let r = b.reshape(x, &[2, 8, 64]);
        b.output(r);
        let f = b.finish();
        let mesh = Mesh::new(&[("model", 4)]);
        let p = Propagator::new(&f);
        let mut dm = DistMap::new(&f, &mesh);
        dm.set(0, AxisId(0), 2); // tile H
        let mut st = PropStats::default();
        p.forward(&f, &mesh, &mut dm, &mut st);
        assert_eq!(dm.get(1, AxisId(0)), Some(2)); // merged dim tiled
        assert!(st.stuck_nodes.is_empty());
    }

    #[test]
    fn multi_axis_propagation_is_independent() {
        let (f, mesh) = {
            let mut b = GraphBuilder::new("m");
            let x = b.arg("x", TensorType::f32(&[8, 16]), ArgKind::Input);
            let w = b.arg("w", TensorType::f32(&[16, 64]), ArgKind::Parameter);
            let y = b.matmul(x, w);
            b.output(y);
            (b.finish(), Mesh::new(&[("batch", 2), ("model", 4)]))
        };
        let p = Propagator::new(&f);
        let mut dm = DistMap::new(&f, &mesh);
        dm.set(0, AxisId(0), 0); // batch-tile x rows
        dm.set(1, AxisId(1), 1); // model-tile w cols
        let mut st = PropStats::default();
        p.forward(&f, &mesh, &mut dm, &mut st);
        let y = f.num_args();
        assert_eq!(dm.get(y, AxisId(0)), Some(0));
        assert_eq!(dm.get(y, AxisId(1)), Some(1));
        assert!(st.stuck_nodes.is_empty());
    }
}
