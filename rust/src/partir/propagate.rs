//! Propagation engine (paper §2.1/§2.3): pushes tiling decisions through
//! the program using the per-op rule registry.
//!
//! Three tactics, mirroring the paper:
//!   * `forward`  — operands → results (run after every rewrite action);
//!   * `infer_rest` — results → operands as well ("a pass that infers the
//!     tiling of the rest of the arguments from only some of them");
//!   * stuck-node detection — nodes where information conflicts or hits
//!     an unmapped dim "resurface back to our worklist".
//!
//! This is the single hottest code path in the system: it runs after
//! every MCTS action over programs with up to ~100k values. Rules are
//! precomputed per node; the sweep itself is allocation-free.
//!
//! Two sweep forms exist (DESIGN.md §8):
//!   * [`Propagator::forward`] — the full pass over every node, the
//!     reference semantics used by replay ([`super::program`]);
//!   * [`Propagator::forward_from`] — the incremental pass the search
//!     env uses per action: only nodes reachable from the dirty-value
//!     frontier are re-swept, in the same ascending-index order the full
//!     pass uses, so starting from a forward-fixpoint map the result is
//!     bit-identical to the full pass (debug cross-check in
//!     `search/env.rs`; property + corpus tests in
//!     `tests/prop_invariants.rs`).

use super::dist::{DistMap, UNKNOWN};
use super::mesh::{AxisId, Mesh};
use super::registry::{rule_for, OpRule};
use crate::ir::{Func, TensorType, ValueId};
use std::cmp::Reverse;
use std::collections::BinaryHeap;

/// Precomputed propagation context for one program (immutable during search).
pub struct Propagator {
    pub rules: Vec<OpRule>,
    /// Global dims per value (flattened copy for cache-friendly access).
    dims: Vec<Vec<i64>>,
    /// Global byte size per value (perf: liveness/runtime models read this
    /// instead of re-walking dim vectors — EXPERIMENTS.md §Perf opt 1).
    pub global_bytes: Vec<i64>,
    /// Global element count per value.
    pub global_elems: Vec<i64>,
    /// Consumer node indices per value — the fan-out edges the
    /// incremental sweep follows from a dirty value.
    users: Vec<Vec<u32>>,
}

/// Result of a propagation sweep.
#[derive(Debug, Default, Clone)]
pub struct PropStats {
    /// Node indices where propagation got stuck (conflict / unmapped dim).
    pub stuck_nodes: Vec<u32>,
    /// Number of value-axis assignments made.
    pub assigned: usize,
}

/// Outcome of sweeping one node ([`Propagator::forward_node`]).
#[derive(Debug, Clone, Copy, Default)]
pub struct NodeSweep {
    /// The node's output distribution changed (consumers must re-sweep).
    pub changed: bool,
    /// Propagation is stuck at this node w.r.t. the current map.
    pub stuck: bool,
    /// Value-axis assignments made at this node.
    pub assigned: u32,
}

/// Persistent stuck-node set for incremental sweeps: a bitmap plus a
/// member count, updated per visited node so the search env never has
/// to re-derive stuckness with a full pass. Semantics: "the set a fresh
/// full forward pass over the current map would report".
#[derive(Debug, Default)]
pub struct StuckSet {
    bits: Vec<u64>,
    count: usize,
}

impl StuckSet {
    pub fn with_capacity(num_nodes: usize) -> StuckSet {
        StuckSet { bits: vec![0; (num_nodes + 63) / 64], count: 0 }
    }

    #[inline]
    pub fn insert(&mut self, ni: u32) {
        let (word, bit) = (ni as usize / 64, ni as usize % 64);
        if word >= self.bits.len() {
            self.bits.resize(word + 1, 0);
        }
        if self.bits[word] >> bit & 1 == 0 {
            self.bits[word] |= 1u64 << bit;
            self.count += 1;
        }
    }

    #[inline]
    pub fn remove(&mut self, ni: u32) {
        let (word, bit) = (ni as usize / 64, ni as usize % 64);
        if word < self.bits.len() && self.bits[word] >> bit & 1 == 1 {
            self.bits[word] &= !(1u64 << bit);
            self.count -= 1;
        }
    }

    #[inline]
    pub fn contains(&self, ni: u32) -> bool {
        self.bits
            .get(ni as usize / 64)
            .map_or(false, |w| w >> (ni as usize % 64) & 1 == 1)
    }

    pub fn len(&self) -> usize {
        self.count
    }

    pub fn is_empty(&self) -> bool {
        self.count == 0
    }

    pub fn clear(&mut self) {
        self.bits.iter_mut().for_each(|w| *w = 0);
        self.count = 0;
    }

    /// Replace the membership with `nodes` (duplicates tolerated).
    pub fn rebuild(&mut self, nodes: &[u32]) {
        self.clear();
        for &n in nodes {
            self.insert(n);
        }
    }

    /// Members in ascending node order (the full pass's report order).
    pub fn to_sorted_vec(&self) -> Vec<u32> {
        let mut out = Vec::with_capacity(self.count);
        for (wi, &w) in self.bits.iter().enumerate() {
            if w == 0 {
                continue;
            }
            for b in 0..64 {
                if w >> b & 1 == 1 {
                    out.push((wi * 64 + b) as u32);
                }
            }
        }
        out
    }
}

impl Clone for StuckSet {
    fn clone(&self) -> StuckSet {
        StuckSet { bits: self.bits.clone(), count: self.count }
    }

    fn clone_from(&mut self, src: &StuckSet) {
        self.bits.clone_from(&src.bits);
        self.count = src.count;
    }
}

/// Reusable pending-node queue for the incremental sweep: a min-heap of
/// dirty node indices plus an in-queue bitmap so a node is swept at most
/// once per position. Drained empty by every [`Propagator::forward_from`]
/// call, so clones never copy queue contents.
#[derive(Debug, Default)]
pub struct FrontierScratch {
    heap: BinaryHeap<Reverse<u32>>,
    queued: Vec<bool>,
}

impl FrontierScratch {
    pub fn with_capacity(num_nodes: usize) -> FrontierScratch {
        FrontierScratch { heap: BinaryHeap::with_capacity(64), queued: vec![false; num_nodes] }
    }

    #[inline]
    fn push(&mut self, ni: u32) {
        let i = ni as usize;
        if i >= self.queued.len() {
            self.queued.resize(i + 1, false);
        }
        if !self.queued[i] {
            self.queued[i] = true;
            self.heap.push(Reverse(ni));
        }
    }
}

impl Clone for FrontierScratch {
    fn clone(&self) -> FrontierScratch {
        // The queue is empty between sweeps (invariant), so a clone only
        // needs a same-sized all-false bitmap.
        FrontierScratch {
            heap: BinaryHeap::with_capacity(64),
            queued: vec![false; self.queued.len()],
        }
    }

    fn clone_from(&mut self, src: &FrontierScratch) {
        self.heap.clear();
        self.queued.clear();
        self.queued.resize(src.queued.len(), false);
    }
}

impl Propagator {
    pub fn new(f: &Func) -> Propagator {
        let rules = f
            .nodes
            .iter()
            .map(|n| {
                let ins: Vec<&TensorType> =
                    n.inputs.iter().map(|&v| f.value_type(v)).collect();
                rule_for(&n.op, &ins, &n.ty)
            })
            .collect();
        let dims: Vec<Vec<i64>> = (0..f.num_values())
            .map(|v| f.value_type(ValueId(v as u32)).dims.clone())
            .collect();
        let global_bytes = (0..f.num_values())
            .map(|v| f.value_type(ValueId(v as u32)).byte_size())
            .collect();
        let global_elems = (0..f.num_values())
            .map(|v| f.value_type(ValueId(v as u32)).num_elements())
            .collect();
        let mut users = vec![Vec::new(); f.num_values()];
        for (ni, node) in f.nodes.iter().enumerate() {
            for &inp in &node.inputs {
                users[inp.index()].push(ni as u32);
            }
        }
        Propagator { rules, dims, global_bytes, global_elems, users }
    }

    /// Global dims of a value (borrowed; avoids re-walking the Func).
    #[inline]
    pub fn dims_of(&self, v: usize) -> &[i64] {
        &self.dims[v]
    }

    /// Consumer node indices of a value — the fan-out edges both the
    /// incremental propagation sweep and the cost ledger's dirty-node
    /// marking follow.
    #[inline]
    pub fn users_of(&self, v: usize) -> &[u32] {
        &self.users[v]
    }

    #[inline]
    fn divisible(&self, v: usize, dim: usize, size: i64) -> bool {
        self.dims[v][dim] % size == 0
    }

    /// Sweep one node across all axes: the shared body of the full and
    /// incremental forward passes. A node's outcome is a pure function
    /// of the current map at its inputs and output, so re-sweeping an
    /// unchanged node is a no-op — the property both the full-pass
    /// fixpoint argument and the incremental sweep rest on.
    #[inline]
    pub fn forward_node(&self, f: &Func, mesh: &Mesh, dm: &mut DistMap, ni: usize) -> NodeSweep {
        let node = &f.nodes[ni];
        let rule = &self.rules[ni];
        let out_v = f.num_args() + ni;
        let num_axes = mesh.num_axes();
        let mut sweep = NodeSweep::default();
        for a in 0..num_axes {
            let axis = AxisId(a);
            let asize = mesh.size(axis);
            if asize == 1 {
                continue;
            }
            // Reduced-tie hit on this axis?
            let mut reduced_hit = false;
            let mut reduced_conflict = false;
            for group in &rule.reduced_ties {
                let mut any = false;
                let mut all = true;
                for &(oi, od) in group {
                    let iv = node.inputs[oi].index();
                    if dm.d[iv][a] == od as u8 {
                        any = true;
                    } else {
                        all = false;
                    }
                }
                if any {
                    reduced_hit = true;
                    if !all && group.len() > 1 {
                        // only one side of a contraction is tiled:
                        // lowering must slice/gather — mark stuck.
                        reduced_conflict = true;
                    }
                }
            }
            // Output-dim candidate from operand tilings.
            let mut cand: Option<usize> = None;
            let mut conflict = false;
            for (od, ties) in rule.out_ties.iter().enumerate() {
                for &(oi, idim) in ties {
                    let iv = node.inputs[oi].index();
                    if dm.d[iv][a] == idim as u8 {
                        match cand {
                            None => cand = Some(od),
                            Some(c) if c != od => conflict = true,
                            _ => {}
                        }
                    }
                }
            }
            let pre_set = dm.d[out_v][a] != UNKNOWN;
            match (cand, reduced_hit) {
                (Some(od), rh) => {
                    if !pre_set
                        && self.divisible(out_v, od, asize)
                        && !dm.dim_taken(out_v, axis, od)
                    {
                        dm.set(out_v, axis, od);
                        sweep.assigned += 1;
                        sweep.changed = true;
                    } else if !pre_set {
                        conflict = true;
                    }
                    if rh || conflict || reduced_conflict {
                        sweep.stuck = true;
                    }
                }
                (None, true) => {
                    // Pure contraction tiling: output replicated on this
                    // axis, all-reduce inserted at lowering.
                    if reduced_conflict {
                        sweep.stuck = true;
                    }
                }
                (None, false) => {
                    if conflict {
                        sweep.stuck = true;
                    }
                }
            }
        }
        sweep
    }

    /// Forward sweep: one pass in topological order, all axes at once.
    /// Pre-assigned output dists (explicit actions on internal nodes) are
    /// never overwritten. Stuck nodes are reported once per node, in
    /// ascending order.
    pub fn forward(&self, f: &Func, mesh: &Mesh, dm: &mut DistMap, stats: &mut PropStats) {
        for ni in 0..f.num_nodes() {
            let sweep = self.forward_node(f, mesh, dm, ni);
            stats.assigned += sweep.assigned as usize;
            if sweep.stuck {
                stats.stuck_nodes.push(ni as u32);
            }
        }
    }

    /// Mark everything that depends on `v` dirty: its consumers, and —
    /// when `v` is a node result — its producing node (whose `pre_set`
    /// view changed).
    #[inline]
    pub fn seed_dirty(&self, f: &Func, scratch: &mut FrontierScratch, v: ValueId) {
        if let Some(ni) = f.node_of(v) {
            scratch.push(ni as u32);
        }
        for &ni in &self.users[v.index()] {
            scratch.push(ni);
        }
    }

    /// Incremental forward sweep from the dirty frontier seeded via
    /// [`Propagator::seed_dirty`] (DESIGN.md §8): pending nodes are
    /// processed in ascending index order — exactly the order the full
    /// pass visits them — and every changed output re-queues its
    /// consumers. `stuck` is maintained as the stuck set w.r.t. the
    /// resulting map (visited nodes update their status; unvisited nodes
    /// keep theirs, which is unchanged because their inputs are).
    /// Starting from a forward-fixpoint map this is bit-identical to a
    /// full [`Propagator::forward`] pass.
    pub fn forward_from(
        &self,
        f: &Func,
        mesh: &Mesh,
        dm: &mut DistMap,
        stuck: &mut StuckSet,
        assigned: &mut usize,
        scratch: &mut FrontierScratch,
    ) {
        while let Some(Reverse(ni)) = scratch.heap.pop() {
            scratch.queued[ni as usize] = false;
            let sweep = self.forward_node(f, mesh, dm, ni as usize);
            *assigned += sweep.assigned as usize;
            if sweep.stuck {
                stuck.insert(ni);
            } else {
                stuck.remove(ni);
            }
            if sweep.changed {
                let out_v = f.num_args() + ni as usize;
                for &nj in &self.users[out_v] {
                    scratch.push(nj);
                }
            }
        }
    }

    /// Backward sweep: infer operand tilings from tiled results. Only
    /// assigns to values that are still Unknown. Returns assignments made.
    pub fn backward(&self, f: &Func, mesh: &Mesh, dm: &mut DistMap) -> usize {
        let num_axes = mesh.num_axes();
        let mut assigned = 0;
        for ni in (0..f.num_nodes()).rev() {
            let node = &f.nodes[ni];
            let rule = &self.rules[ni];
            let out_v = f.num_args() + ni;
            for a in 0..num_axes {
                let axis = AxisId(a);
                let asize = mesh.size(axis);
                if asize == 1 {
                    continue;
                }
                let od = match dm.get(out_v, axis) {
                    Some(od) => od,
                    None => continue,
                };
                if od >= rule.out_ties.len() {
                    continue;
                }
                for &(oi, idim) in &rule.out_ties[od] {
                    let iv = node.inputs[oi].index();
                    if dm.d[iv][a] == UNKNOWN
                        && self.divisible(iv, idim, asize)
                        && !dm.dim_taken(iv, axis, idim)
                    {
                        dm.set(iv, axis, idim);
                        assigned += 1;
                    }
                }
            }
        }
        assigned
    }

    /// The paper's "infer the tiling of the rest of the arguments" global
    /// pass: alternate backward/forward sweeps to a bounded fixpoint.
    pub fn infer_rest(&self, f: &Func, mesh: &Mesh, dm: &mut DistMap, stats: &mut PropStats) {
        for _ in 0..3 {
            let n = self.backward(f, mesh, dm);
            self.forward(f, mesh, dm, stats);
            if n == 0 {
                break;
            }
        }
    }

    /// [`Propagator::infer_rest`], but `stats.stuck_nodes` reports only
    /// the FINAL forward pass's stuck set — the settled status w.r.t.
    /// the resulting map — instead of the union across iterations.
    /// The search env uses this form so its incremental stuck set stays
    /// consistent after an infer-rest action; `assigned` still
    /// accumulates across iterations. The map mutations are identical
    /// to `infer_rest` (same sweep sequence).
    pub fn infer_rest_settle(
        &self,
        f: &Func,
        mesh: &Mesh,
        dm: &mut DistMap,
        stats: &mut PropStats,
    ) {
        for _ in 0..3 {
            let n = self.backward(f, mesh, dm);
            stats.stuck_nodes.clear();
            self.forward(f, mesh, dm, stats);
            if n == 0 {
                break;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::{ArgKind, GraphBuilder, TensorType};

    /// Paper Figure 2: linear layer, tile %arg1 (weights) on dim 1.
    fn fig2() -> (Func, Mesh) {
        let mut b = GraphBuilder::new("main");
        let x = b.arg("x", TensorType::f32(&[8, 16]), ArgKind::Input);
        let w = b.arg("w", TensorType::f32(&[16, 64]), ArgKind::Parameter);
        let bias = b.arg("b", TensorType::f32(&[64]), ArgKind::Parameter);
        let dot = b.matmul(x, w);
        let ty = b.ty(dot).clone();
        let bb = b.broadcast_to(bias, ty);
        let out = b.add(dot, bb);
        b.output(out);
        (b.finish(), Mesh::new(&[("shard", 2)]))
    }

    #[test]
    fn figure2_column_sharding_propagates() {
        let (f, mesh) = fig2();
        let p = Propagator::new(&f);
        let mut dm = DistMap::new(&f, &mesh);
        let ax = AxisId(0);
        dm.set(1, ax, 1); // tile w on dim 1
        let mut st = PropStats::default();
        p.forward(&f, &mesh, &mut dm, &mut st);
        // dot result tiled dim 1, add result tiled dim 1
        let dot_v = f.num_args(); // node 0
        let out_v = f.num_args() + 2;
        assert_eq!(dm.get(dot_v, ax), Some(1));
        assert_eq!(dm.get(out_v, ax), Some(1));
        assert!(st.stuck_nodes.is_empty());
        // x (arg0) untouched — stays replicated ("atomic" in Fig 2).
        assert_eq!(dm.get(0, ax), None);
    }

    #[test]
    fn figure2_backward_infers_bias() {
        let (f, mesh) = fig2();
        let p = Propagator::new(&f);
        let mut dm = DistMap::new(&f, &mesh);
        let ax = AxisId(0);
        dm.set(1, ax, 1);
        let mut st = PropStats::default();
        p.forward(&f, &mesh, &mut dm, &mut st);
        p.infer_rest(&f, &mesh, &mut dm, &mut st);
        // bias (arg2) inferred tiled dim 0 via broadcast tie.
        assert_eq!(dm.get(2, ax), Some(0));
    }

    #[test]
    fn contraction_tiling_makes_output_replicated() {
        // Megatron row-sharding: tile w on its CONTRACTING dim.
        let (f, mesh) = fig2();
        let p = Propagator::new(&f);
        let mut dm = DistMap::new(&f, &mesh);
        let ax = AxisId(0);
        dm.set(1, ax, 0); // w dim 0 = contraction
        let mut st = PropStats::default();
        p.forward(&f, &mesh, &mut dm, &mut st);
        let dot_v = f.num_args();
        assert_eq!(dm.get(dot_v, ax), None); // partial sum -> replicated
        // one-sided contraction: x not tiled on dim 1 -> stuck node reported
        assert_eq!(st.stuck_nodes, vec![0]);
    }

    #[test]
    fn two_sided_contraction_is_not_stuck() {
        let (f, mesh) = fig2();
        let p = Propagator::new(&f);
        let mut dm = DistMap::new(&f, &mesh);
        let ax = AxisId(0);
        dm.set(0, ax, 1); // x dim 1 (contract)
        dm.set(1, ax, 0); // w dim 0 (contract)
        let mut st = PropStats::default();
        p.forward(&f, &mesh, &mut dm, &mut st);
        assert!(st.stuck_nodes.is_empty());
        assert_eq!(dm.get(f.num_args(), ax), None);
    }

    #[test]
    fn conflicting_tilings_get_stuck() {
        let mut b = GraphBuilder::new("c");
        let x = b.arg("x", TensorType::f32(&[4, 4]), ArgKind::Input);
        let y = b.arg("y", TensorType::f32(&[4, 4]), ArgKind::Input);
        let s = b.add(x, y);
        b.output(s);
        let f = b.finish();
        let mesh = Mesh::new(&[("shard", 2)]);
        let p = Propagator::new(&f);
        let mut dm = DistMap::new(&f, &mesh);
        dm.set(0, AxisId(0), 0);
        dm.set(1, AxisId(0), 1); // conflicting dims
        let mut st = PropStats::default();
        p.forward(&f, &mesh, &mut dm, &mut st);
        assert_eq!(st.stuck_nodes, vec![0]);
        // first-wins: output tiled at dim 0
        assert_eq!(dm.get(2, AxisId(0)), Some(0));
    }

    #[test]
    fn indivisible_dims_not_tiled() {
        let mut b = GraphBuilder::new("c");
        let x = b.arg("x", TensorType::f32(&[3, 4]), ArgKind::Input);
        let n = b.neg(x);
        b.output(n);
        let f = b.finish();
        let mesh = Mesh::new(&[("shard", 2)]);
        let p = Propagator::new(&f);
        let mut dm = DistMap::new(&f, &mesh);
        dm.set(0, AxisId(0), 0); // dim of size 3, axis of size 2
        let mut st = PropStats::default();
        p.forward(&f, &mesh, &mut dm, &mut st);
        assert_eq!(dm.get(1, AxisId(0)), None);
        assert_eq!(st.stuck_nodes, vec![0]);
    }

    #[test]
    fn reshape_merge_propagates_head_tiling() {
        // [B,S,H,Dh] -> [B,S,D] with H tiled: merged dim stays tiled.
        let mut b = GraphBuilder::new("r");
        let x = b.arg("x", TensorType::f32(&[2, 8, 4, 16]), ArgKind::Input);
        let r = b.reshape(x, &[2, 8, 64]);
        b.output(r);
        let f = b.finish();
        let mesh = Mesh::new(&[("model", 4)]);
        let p = Propagator::new(&f);
        let mut dm = DistMap::new(&f, &mesh);
        dm.set(0, AxisId(0), 2); // tile H
        let mut st = PropStats::default();
        p.forward(&f, &mesh, &mut dm, &mut st);
        assert_eq!(dm.get(1, AxisId(0)), Some(2)); // merged dim tiled
        assert!(st.stuck_nodes.is_empty());
    }

    #[test]
    fn incremental_forward_matches_full_pass_on_fig2() {
        let (f, mesh) = fig2();
        let p = Propagator::new(&f);
        let ax = AxisId(0);
        // Reference: explicit set + full pass.
        let mut full = DistMap::new(&f, &mesh);
        full.set(1, ax, 1);
        let mut st = PropStats::default();
        p.forward(&f, &mesh, &mut full, &mut st);
        // Incremental: same explicit set, dirty frontier = {w}.
        let mut inc = DistMap::new(&f, &mesh);
        let mut stuck = StuckSet::with_capacity(f.num_nodes());
        let mut scratch = FrontierScratch::with_capacity(f.num_nodes());
        let mut assigned = 0usize;
        inc.set(1, ax, 1);
        p.seed_dirty(&f, &mut scratch, ValueId(1));
        p.forward_from(&f, &mesh, &mut inc, &mut stuck, &mut assigned, &mut scratch);
        assert_eq!(inc, full);
        assert_eq!(stuck.to_sorted_vec(), st.stuck_nodes);
        assert_eq!(assigned, st.assigned);

        // A second decision re-sweeps only the affected region and still
        // matches a fresh full pass over the whole map.
        full.set(0, ax, 0);
        let mut st2 = PropStats::default();
        let mut full2 = full.clone();
        p.forward(&f, &mesh, &mut full2, &mut st2);
        inc.set(0, ax, 0);
        p.seed_dirty(&f, &mut scratch, ValueId(0));
        p.forward_from(&f, &mesh, &mut inc, &mut stuck, &mut assigned, &mut scratch);
        assert_eq!(inc, full2);
        assert_eq!(stuck.to_sorted_vec(), st2.stuck_nodes);
    }

    #[test]
    fn stuck_set_insert_remove_rebuild() {
        let mut s = StuckSet::with_capacity(10);
        assert!(s.is_empty());
        s.insert(3);
        s.insert(70); // past pre-sized capacity: grows
        s.insert(3); // duplicate insert is a no-op
        assert_eq!(s.len(), 2);
        assert!(s.contains(3) && s.contains(70) && !s.contains(4));
        assert_eq!(s.to_sorted_vec(), vec![3, 70]);
        s.remove(3);
        s.remove(3); // duplicate remove is a no-op
        assert_eq!(s.len(), 1);
        s.rebuild(&[5, 1, 5]);
        assert_eq!(s.to_sorted_vec(), vec![1, 5]);
        s.clear();
        assert!(s.is_empty() && !s.contains(1));
    }

    #[test]
    fn infer_rest_settle_reports_final_pass_stuck_and_same_map() {
        let (f, mesh) = fig2();
        let p = Propagator::new(&f);
        let ax = AxisId(0);
        let mut a = DistMap::new(&f, &mesh);
        a.set(1, ax, 1);
        let mut sa = PropStats::default();
        p.forward(&f, &mesh, &mut a, &mut sa);
        let mut b = a.clone();
        let mut sb = PropStats::default();
        p.infer_rest(&f, &mesh, &mut a, &mut sa);
        p.infer_rest_settle(&f, &mesh, &mut b, &mut sb);
        assert_eq!(a, b, "settle variant must mutate the map identically");
        // The settled stuck list equals one status pass over the result.
        let mut probe = b.clone();
        let mut sp = PropStats::default();
        p.forward(&f, &mesh, &mut probe, &mut sp);
        assert_eq!(probe, b, "infer_rest must end on a forward fixpoint");
        assert_eq!(sb.stuck_nodes, sp.stuck_nodes);
    }

    #[test]
    fn multi_axis_propagation_is_independent() {
        let (f, mesh) = {
            let mut b = GraphBuilder::new("m");
            let x = b.arg("x", TensorType::f32(&[8, 16]), ArgKind::Input);
            let w = b.arg("w", TensorType::f32(&[16, 64]), ArgKind::Parameter);
            let y = b.matmul(x, w);
            b.output(y);
            (b.finish(), Mesh::new(&[("batch", 2), ("model", 4)]))
        };
        let p = Propagator::new(&f);
        let mut dm = DistMap::new(&f, &mesh);
        dm.set(0, AxisId(0), 0); // batch-tile x rows
        dm.set(1, AxisId(1), 1); // model-tile w cols
        let mut st = PropStats::default();
        p.forward(&f, &mesh, &mut dm, &mut st);
        let y = f.num_args();
        assert_eq!(dm.get(y, AxisId(0)), Some(0));
        assert_eq!(dm.get(y, AxisId(1)), Some(1));
        assert!(st.stuck_nodes.is_empty());
    }
}
