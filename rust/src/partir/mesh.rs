//! Logical device meshes (paper §2.2): users declare named axes with
//! fixed sizes, e.g. `{("batch", 2), ("model", 4)}` for 8 devices, and
//! the partitioner only searches over axes it is instructed to use.

/// Index of an axis within a [`Mesh`].
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct AxisId(pub usize);

/// Maximum number of mesh axes supported (dist maps are fixed-width
/// arrays for speed; 4 covers batch/model/pipeline/expert layouts).
pub const MAX_AXES: usize = 4;

#[derive(Debug, Clone)]
pub struct Axis {
    pub name: String,
    pub size: i64,
    /// Whether the automated partitioner may assign this axis (paper:
    /// users keep manual control of e.g. the data-parallel axis).
    pub searchable: bool,
}

/// A rectangular logical device mesh.
#[derive(Debug, Clone)]
pub struct Mesh {
    pub axes: Vec<Axis>,
}

impl Mesh {
    pub fn new(axes: &[(&str, i64)]) -> Mesh {
        assert!(axes.len() <= MAX_AXES, "at most {MAX_AXES} mesh axes supported");
        assert!(!axes.is_empty(), "mesh needs at least one axis");
        Mesh {
            axes: axes
                .iter()
                .map(|(n, s)| {
                    assert!(*s >= 1, "axis size must be >= 1");
                    Axis { name: n.to_string(), size: *s, searchable: true }
                })
                .collect(),
        }
    }

    /// Parse the CLI/request syntax `name=size[,name=size]`, e.g.
    /// `"batch=2,model=4"`. Axis order in the spec is mesh order.
    ///
    /// Diagnostics name the offending token with its 1-based column and
    /// an expected/found pair, matching the textual-IR parser's style
    /// (`ir::parser`), so `serve`/`batch` reject bad requests with
    /// errors the sender can act on.
    pub fn parse(spec: &str) -> Result<Mesh, String> {
        let mut axes: Vec<(String, i64)> = Vec::new();
        let mut offset = 0usize;
        for part in spec.split(',') {
            let part_start = offset;
            offset += part.len() + 1; // +1 for the ',' split away
            let trimmed = part.trim();
            if trimmed.is_empty() {
                continue;
            }
            // 1-based column of the first non-space char of this part.
            let col = part_start + (part.len() - part.trim_start().len()) + 1;
            let err = |msg: String| format!("mesh spec '{spec}': at column {col}: {msg}");
            let Some((name, size)) = trimmed.split_once('=') else {
                return Err(err(format!(
                    "expected 'name=size', found '{trimmed}' (missing '=')"
                )));
            };
            let name = name.trim();
            let size_s = size.trim();
            if name.is_empty() {
                return Err(err(format!("expected axis name before '=', found '{trimmed}'")));
            }
            let size: i64 = size_s.parse().map_err(|_| {
                err(format!("expected integer size after '{name}=', found '{size_s}'"))
            })?;
            if size < 1 {
                return Err(err(format!("axis \"{name}\": size must be >= 1, found {size}")));
            }
            // Duplicate names would make axis_by_name silently resolve
            // only the first, so a --pin/manual_axes on the duplicate
            // would leave its twin searchable.
            if axes.iter().any(|(n, _)| *n == name) {
                return Err(err(format!("duplicate axis \"{name}\"")));
            }
            axes.push((name.to_string(), size));
        }
        if axes.is_empty() {
            return Err(format!(
                "mesh spec '{spec}': expected 'name=size[,name=size]', found no axes"
            ));
        }
        if axes.len() > MAX_AXES {
            return Err(format!(
                "mesh spec '{spec}': at most {MAX_AXES} axes supported, found {}",
                axes.len()
            ));
        }
        let named: Vec<(&str, i64)> = axes.iter().map(|(n, s)| (n.as_str(), *s)).collect();
        Ok(Mesh::new(&named))
    }

    /// Mark an axis as manually managed (excluded from search).
    pub fn manual(mut self, name: &str) -> Mesh {
        let ax = self.axis_by_name(name).expect("no such axis");
        self.axes[ax.0].searchable = false;
        self
    }

    pub fn num_axes(&self) -> usize {
        self.axes.len()
    }

    pub fn axis_by_name(&self, name: &str) -> Option<AxisId> {
        self.axes.iter().position(|a| a.name == name).map(AxisId)
    }

    pub fn size(&self, a: AxisId) -> i64 {
        self.axes[a.0].size
    }

    pub fn name(&self, a: AxisId) -> &str {
        &self.axes[a.0].name
    }

    /// Total device count (product of axis sizes).
    pub fn num_devices(&self) -> i64 {
        self.axes.iter().map(|a| a.size).product()
    }

    pub fn searchable_axes(&self) -> Vec<AxisId> {
        (0..self.axes.len()).map(AxisId).filter(|&a| self.axes[a.0].searchable).collect()
    }

    pub fn describe(&self) -> String {
        let parts: Vec<String> =
            self.axes.iter().map(|a| format!("\"{}\"={}", a.name, a.size)).collect();
        format!("#partir.mesh<{}>", parts.join(", "))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mesh_basics() {
        let m = Mesh::new(&[("batch", 2), ("model", 4)]);
        assert_eq!(m.num_devices(), 8);
        assert_eq!(m.axis_by_name("model"), Some(AxisId(1)));
        assert_eq!(m.size(AxisId(1)), 4);
        assert_eq!(m.describe(), "#partir.mesh<\"batch\"=2, \"model\"=4>");
    }

    #[test]
    fn manual_axes_excluded_from_search() {
        let m = Mesh::new(&[("batch", 2), ("model", 4)]).manual("batch");
        assert_eq!(m.searchable_axes(), vec![AxisId(1)]);
    }

    #[test]
    #[should_panic]
    fn too_many_axes_rejected() {
        Mesh::new(&[("a", 2), ("b", 2), ("c", 2), ("d", 2), ("e", 2)]);
    }

    #[test]
    fn parse_mesh_specs() {
        let m = Mesh::parse("batch=2, model=4").unwrap();
        assert_eq!(m.num_axes(), 2);
        assert_eq!(m.axis_by_name("batch"), Some(AxisId(0)));
        assert_eq!(m.size(AxisId(1)), 4);
        assert!(Mesh::parse("").is_err());
        assert!(Mesh::parse("batch").is_err());
        assert!(Mesh::parse("batch=x").is_err());
        assert!(Mesh::parse("batch=0").is_err());
        assert!(Mesh::parse("a=2,b=2,c=2,d=2,e=2").is_err());
        assert!(Mesh::parse("model=2,model=4").is_err(), "duplicate axis names rejected");
    }

    #[test]
    fn parse_errors_carry_position_and_expected_found() {
        let e = Mesh::parse("batch").unwrap_err();
        assert!(e.contains("column 1") && e.contains("expected 'name=size'"), "{e}");
        assert!(e.contains("found 'batch'"), "{e}");
        let e = Mesh::parse("batch=2, model=x").unwrap_err();
        assert!(e.contains("column 10"), "{e}");
        assert!(e.contains("expected integer size after 'model='"), "{e}");
        assert!(e.contains("found 'x'"), "{e}");
        let e = Mesh::parse("batch=2,batch=4").unwrap_err();
        assert!(e.contains("column 9") && e.contains("duplicate axis \"batch\""), "{e}");
        let e = Mesh::parse("m=0").unwrap_err();
        assert!(e.contains("size must be >= 1, found 0"), "{e}");
        let e = Mesh::parse("=4").unwrap_err();
        assert!(e.contains("expected axis name before '='"), "{e}");
    }
}
