//! Per-value distribution state: for every mesh axis, whether a value is
//! (so far) replicated or tiled along one of its tensor dimensions.
//!
//! Stored as a fixed-width byte array per value (`MAX_AXES`), so the
//! whole distribution map of a 50k-op program is a few hundred KB and a
//! propagation sweep stays cache-friendly — this map is rebuilt after
//! every MCTS action (hot path, see DESIGN.md §8).

use super::mesh::{AxisId, Mesh, MAX_AXES};
use crate::ir::Func;

/// Distribution of one value along one axis.
/// Encoded as u8: `UNKNOWN` = not tiled (lowered as replicated), else the
/// tensor dimension index tiled by that axis.
pub const UNKNOWN: u8 = 0xFF;

/// Distribution state for every value in a function.
#[derive(Debug, Clone, PartialEq)]
pub struct DistMap {
    /// `d[v][a]` = dim tiled by axis `a` for value `v`, or `UNKNOWN`.
    pub d: Vec<[u8; MAX_AXES]>,
    pub num_axes: usize,
}

impl DistMap {
    pub fn new(f: &Func, mesh: &Mesh) -> DistMap {
        DistMap { d: vec![[UNKNOWN; MAX_AXES]; f.num_values()], num_axes: mesh.num_axes() }
    }

    #[inline]
    pub fn get(&self, v: usize, a: AxisId) -> Option<usize> {
        let x = self.d[v][a.0];
        if x == UNKNOWN {
            None
        } else {
            Some(x as usize)
        }
    }

    #[inline]
    pub fn set(&mut self, v: usize, a: AxisId, dim: usize) {
        debug_assert!(dim < UNKNOWN as usize);
        self.d[v][a.0] = dim as u8;
    }

    #[inline]
    pub fn clear(&mut self, v: usize, a: AxisId) {
        self.d[v][a.0] = UNKNOWN;
    }

    /// Is the value tiled along any axis?
    pub fn is_tiled(&self, v: usize) -> bool {
        self.d[v][..self.num_axes].iter().any(|&x| x != UNKNOWN)
    }

    /// Tensor dims used by this value's tiling, per axis.
    pub fn tilings(&self, v: usize) -> Vec<(AxisId, usize)> {
        (0..self.num_axes)
            .filter_map(|a| self.get(v, AxisId(a)).map(|d| (AxisId(a), d)))
            .collect()
    }

    /// Would tiling value `v` on `axis` at `dim` clash with an existing
    /// tiling of the same tensor dim by another axis?
    pub fn dim_taken(&self, v: usize, axis: AxisId, dim: usize) -> bool {
        (0..self.num_axes)
            .any(|a| a != axis.0 && self.d[v][a] == dim as u8)
    }

    /// The per-device (local) dims of value `v` given global dims.
    pub fn local_dims(&self, v: usize, global: &[i64], mesh: &Mesh) -> Vec<i64> {
        let mut dims = global.to_vec();
        for a in 0..self.num_axes {
            if let Some(d) = self.get(v, AxisId(a)) {
                debug_assert_eq!(dims[d] % mesh.size(AxisId(a)), 0);
                dims[d] /= mesh.size(AxisId(a));
            }
        }
        dims
    }

    /// Per-device byte size of value `v`.
    pub fn local_bytes(&self, v: usize, global_bytes: i64, mesh: &Mesh) -> i64 {
        let mut b = global_bytes;
        for a in 0..self.num_axes {
            if self.d[v][a] != UNKNOWN {
                b /= mesh.size(AxisId(a));
            }
        }
        b
    }

    /// Render a type like the paper's Fig. 3: `f32[16,64{"model"}]`.
    pub fn render_type(&self, v: usize, global: &[i64], mesh: &Mesh, dtype: &str) -> String {
        let mut parts = Vec::with_capacity(global.len());
        for (dim, &size) in global.iter().enumerate() {
            let mut axes = Vec::new();
            for a in 0..self.num_axes {
                if self.d[v][a] == dim as u8 {
                    axes.push(format!("\"{}\"", mesh.name(AxisId(a))));
                }
            }
            if axes.is_empty() {
                parts.push(format!("{size}"));
            } else {
                parts.push(format!("{size}{{{}}}", axes.join(",")));
            }
        }
        format!("{dtype}[{}]", parts.join(", "))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::{ArgKind, GraphBuilder, TensorType};

    fn setup() -> (Func, Mesh) {
        let mut b = GraphBuilder::new("t");
        let x = b.arg("x", TensorType::f32(&[16, 64]), ArgKind::Parameter);
        let _ = b.neg(x);
        (b.finish(), Mesh::new(&[("batch", 2), ("model", 4)]))
    }

    #[test]
    fn set_get_clear() {
        let (f, mesh) = setup();
        let mut dm = DistMap::new(&f, &mesh);
        let model = mesh.axis_by_name("model").unwrap();
        assert_eq!(dm.get(0, model), None);
        dm.set(0, model, 1);
        assert_eq!(dm.get(0, model), Some(1));
        assert!(dm.is_tiled(0));
        dm.clear(0, model);
        assert!(!dm.is_tiled(0));
    }

    #[test]
    fn local_shape_and_bytes() {
        let (f, mesh) = setup();
        let mut dm = DistMap::new(&f, &mesh);
        let model = mesh.axis_by_name("model").unwrap();
        let batch = mesh.axis_by_name("batch").unwrap();
        dm.set(0, model, 1);
        assert_eq!(dm.local_dims(0, &[16, 64], &mesh), vec![16, 16]);
        dm.set(0, batch, 0);
        assert_eq!(dm.local_dims(0, &[16, 64], &mesh), vec![8, 16]);
        assert_eq!(dm.local_bytes(0, 16 * 64 * 4, &mesh), 16 * 64 * 4 / 8);
    }

    #[test]
    fn dim_taken_detects_cross_axis_clash() {
        let (f, mesh) = setup();
        let mut dm = DistMap::new(&f, &mesh);
        let model = mesh.axis_by_name("model").unwrap();
        let batch = mesh.axis_by_name("batch").unwrap();
        dm.set(0, model, 1);
        assert!(dm.dim_taken(0, batch, 1));
        assert!(!dm.dim_taken(0, batch, 0));
        assert!(!dm.dim_taken(0, model, 1)); // same axis is not a clash
    }

    #[test]
    fn renders_distributed_type() {
        let (f, mesh) = setup();
        let mut dm = DistMap::new(&f, &mesh);
        let model = mesh.axis_by_name("model").unwrap();
        dm.set(0, model, 1);
        assert_eq!(dm.render_type(0, &[16, 64], &mesh, "f32"), "f32[16, 64{\"model\"}]");
    }
}
