//! Declarative per-operator partitioning rules (paper §2.1: "a *registry*
//! containing a declarative specification of this behaviour for each
//! operator in the underlying tensor dialect").
//!
//! A rule relates tensor dimensions of an op's operands and result:
//!   - `out_ties[od]` — (operand, operand_dim) pairs tied to output dim
//!     `od`: tiling any member implies the output may be tiled at `od`
//!     (and vice versa for backward propagation).
//!   - `reduced_ties` — operand dim groups that are summed away (dot
//!     contraction dims, reduce dims, segment/gather source rows): tiling
//!     one makes the result a partial sum, lowered to an all-reduce.
//!
//! Dims not appearing in any tie are "unmapped": propagation cannot move
//! information through them, and a tiling that reaches one gets *stuck*
//! (resurfacing the node to the search worklist, §2.3).

use crate::ir::{OpKind, TensorType};

/// Dimension-relation rule for one node. Precomputed once per program.
#[derive(Debug, Clone, Default)]
pub struct OpRule {
    /// Per output dim: tied (operand_index, operand_dim) pairs.
    pub out_ties: Vec<Vec<(usize, usize)>>,
    /// Summed-away operand dim groups.
    pub reduced_ties: Vec<Vec<(usize, usize)>>,
}

/// Build the rule for `op` given operand and result types.
pub fn rule_for(op: &OpKind, ins: &[&TensorType], out: &TensorType) -> OpRule {
    let out_rank = out.rank();
    let mut r = OpRule { out_ties: vec![Vec::new(); out_rank], reduced_ties: Vec::new() };
    match op {
        // Output dims freely tileable, nothing to tie (a shard of a splat
        // constant or iota can always be materialised locally).
        OpKind::Const { .. } | OpKind::Iota { .. } => {}

        // Elementwise: dim d of every operand ties to output dim d.
        _ if op.is_elementwise() => {
            for od in 0..out_rank {
                for (i, t) in ins.iter().enumerate() {
                    if t.rank() == out_rank {
                        r.out_ties[od].push((i, od));
                    }
                }
            }
        }

        OpKind::Dot(d) => {
            let lhs_free = d.free_dims(ins[0].rank(), &d.lhs_batch, &d.lhs_contract);
            let rhs_free = d.free_dims(ins[1].rank(), &d.rhs_batch, &d.rhs_contract);
            let nb = d.lhs_batch.len();
            for (k, (&lb, &rb)) in d.lhs_batch.iter().zip(&d.rhs_batch).enumerate() {
                r.out_ties[k].push((0, lb));
                r.out_ties[k].push((1, rb));
            }
            for (k, &f) in lhs_free.iter().enumerate() {
                r.out_ties[nb + k].push((0, f));
            }
            for (k, &f) in rhs_free.iter().enumerate() {
                r.out_ties[nb + lhs_free.len() + k].push((1, f));
            }
            for (&lc, &rc) in d.lhs_contract.iter().zip(&d.rhs_contract) {
                r.reduced_ties.push(vec![(0, lc), (1, rc)]);
            }
        }

        OpKind::Reduce { dims, .. } => {
            let kept: Vec<usize> = (0..ins[0].rank()).filter(|i| !dims.contains(i)).collect();
            for (od, &id) in kept.iter().enumerate() {
                r.out_ties[od].push((0, id));
            }
            for &d in dims {
                r.reduced_ties.push(vec![(0, d)]);
            }
        }

        OpKind::Broadcast { dims } => {
            for (id, &od) in dims.iter().enumerate() {
                // A size-1 stretched dim cannot carry a tiling.
                if ins[0].dims[id] == out.dims[od] {
                    r.out_ties[od].push((0, id));
                }
            }
        }

        OpKind::Reshape => {
            for (id, od) in reshape_ties(&ins[0].dims, &out.dims) {
                r.out_ties[od].push((0, id));
            }
        }

        OpKind::Transpose { perm } => {
            for (od, &id) in perm.iter().enumerate() {
                r.out_ties[od].push((0, id));
            }
        }

        OpKind::Gather => {
            // output dims = indices dims ++ table dims[1..].
            let n_idx = ins[1].rank();
            for od in 0..n_idx {
                r.out_ties[od].push((1, od));
            }
            for t in 1..ins[0].rank() {
                r.out_ties[n_idx + t - 1].push((0, t));
            }
            // table dim 0 (vocab) is unmapped: tiling it gets stuck.
        }

        OpKind::SegmentSum { .. } => {
            for t in 1..ins[0].rank() {
                r.out_ties[t].push((0, t));
            }
            // Edge rows of data and ids are summed away into segments.
            r.reduced_ties.push(vec![(0, 0), (1, 0)]);
            // output dim 0 (segments) is unmapped.
        }

        // Covered by the elementwise arm above; kept for exhaustiveness.
        _ => {}
    }
    r
}

/// Dimension ties across a reshape, by row-major chunk matching.
///
/// Walk both shapes accumulating products until they agree — that closes
/// a "chunk". Within a chunk, the FIRST input dim ties to the FIRST
/// output dim (valid for row-major data: sharding the outermost dim of a
/// merged group equals sharding the merged dim, provided sizes divide —
/// divisibility is checked at propagation time). Inner dims of a chunk
/// stay unmapped, so tilings reaching them get stuck — exactly the
/// paper's "propagation can get stuck in internal nodes".
pub fn reshape_ties(in_dims: &[i64], out_dims: &[i64]) -> Vec<(usize, usize)> {
    let mut ties = Vec::new();
    let (mut i, mut j) = (0usize, 0usize);
    while i < in_dims.len() && j < out_dims.len() {
        let (ci, cj) = (i, j);
        let mut pi = in_dims[i];
        let mut pj = out_dims[j];
        while pi != pj {
            if pi < pj {
                i += 1;
                if i >= in_dims.len() {
                    return ties;
                }
                pi *= in_dims[i];
            } else {
                j += 1;
                if j >= out_dims.len() {
                    return ties;
                }
                pj *= out_dims[j];
            }
        }
        // chunk = in_dims[ci..=i] <-> out_dims[cj..=j]
        ties.push((ci, cj));
        i += 1;
        j += 1;
    }
    ties
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::{DotDims, ReduceKind};

    #[test]
    fn elementwise_ties_all_dims() {
        let t = TensorType::f32(&[2, 3]);
        let r = rule_for(&OpKind::Add, &[&t, &t], &t);
        assert_eq!(r.out_ties[0], vec![(0, 0), (1, 0)]);
        assert_eq!(r.out_ties[1], vec![(0, 1), (1, 1)]);
        assert!(r.reduced_ties.is_empty());
    }

    #[test]
    fn dot_ties_and_contract() {
        let a = TensorType::f32(&[8, 16]);
        let b = TensorType::f32(&[16, 64]);
        let o = TensorType::f32(&[8, 64]);
        let r = rule_for(&OpKind::Dot(DotDims::matmul(2)), &[&a, &b], &o);
        assert_eq!(r.out_ties[0], vec![(0, 0)]);
        assert_eq!(r.out_ties[1], vec![(1, 1)]);
        assert_eq!(r.reduced_ties, vec![vec![(0, 1), (1, 0)]]);
    }

    #[test]
    fn batched_dot_ties_batch_dims_to_both() {
        let q = TensorType::f32(&[2, 4, 8, 16]);
        let k = TensorType::f32(&[2, 4, 8, 16]);
        let o = TensorType::f32(&[2, 4, 8, 8]);
        let d = DotDims {
            lhs_batch: vec![0, 1],
            rhs_batch: vec![0, 1],
            lhs_contract: vec![3],
            rhs_contract: vec![3],
        };
        let r = rule_for(&OpKind::Dot(d), &[&q, &k], &o);
        assert_eq!(r.out_ties[1], vec![(0, 1), (1, 1)]);
        assert_eq!(r.out_ties[2], vec![(0, 2)]);
        assert_eq!(r.out_ties[3], vec![(1, 2)]);
    }

    #[test]
    fn reduce_marks_contracted_dims() {
        let x = TensorType::f32(&[2, 3, 4]);
        let o = TensorType::f32(&[2, 4]);
        let r = rule_for(&OpKind::Reduce { kind: ReduceKind::Sum, dims: vec![1] }, &[&x], &o);
        assert_eq!(r.out_ties[0], vec![(0, 0)]);
        assert_eq!(r.out_ties[1], vec![(0, 2)]);
        assert_eq!(r.reduced_ties, vec![vec![(0, 1)]]);
    }

    #[test]
    fn reshape_chunks() {
        // [B,S,H,D] -> [B,S,H*D]: B<->B, S<->S, H<->(H*D)
        assert_eq!(reshape_ties(&[2, 8, 4, 16], &[2, 8, 64]), vec![(0, 0), (1, 1), (2, 2)]);
        // split back
        assert_eq!(reshape_ties(&[2, 8, 64], &[2, 8, 4, 16]), vec![(0, 0), (1, 1), (2, 2)]);
        // total flatten
        assert_eq!(reshape_ties(&[4, 5], &[20]), vec![(0, 0)]);
        // identity
        assert_eq!(reshape_ties(&[3, 7], &[3, 7]), vec![(0, 0), (1, 1)]);
    }

    #[test]
    fn broadcast_skips_stretched_dims() {
        let v = TensorType::f32(&[1, 4]);
        let o = TensorType::f32(&[8, 4]);
        let r = rule_for(&OpKind::Broadcast { dims: vec![0, 1] }, &[&v], &o);
        assert!(r.out_ties[0].is_empty()); // size-1 stretch not tied
        assert_eq!(r.out_ties[1], vec![(0, 1)]);
    }

    #[test]
    fn gather_vocab_dim_unmapped() {
        let table = TensorType::f32(&[100, 8]);
        let ids = TensorType::i32(&[2, 5]);
        let o = TensorType::f32(&[2, 5, 8]);
        let r = rule_for(&OpKind::Gather, &[&table, &ids], &o);
        assert_eq!(r.out_ties[0], vec![(1, 0)]);
        assert_eq!(r.out_ties[1], vec![(1, 1)]);
        assert_eq!(r.out_ties[2], vec![(0, 1)]);
        // no tie mentions table dim 0
        assert!(!r.out_ties.iter().flatten().any(|&(i, d)| i == 0 && d == 0));
    }
}
