//! PartIR-view printer: renders a program with its tiling decisions in
//! the notation of the paper's Figure 2 (middle/bottom) — `partir.tile`
//! loops for tiled values, `partir.slice` for operands sliced inside a
//! tiling loop, and `partir.atomic` for explicitly replicated values.

use super::actions::AtomicSet;
use super::dist::DistMap;
use super::mesh::{AxisId, Mesh};
use crate::ir::{Func, ValueId};
use std::fmt::Write;

/// Render the PartIR view of `f` under distribution `dm`.
pub fn print_partir(f: &Func, mesh: &Mesh, dm: &DistMap, atomic: &AtomicSet) -> String {
    let mut s = String::new();
    write!(s, "func @{}(", f.name).unwrap();
    for (i, a) in f.args.iter().enumerate() {
        if i > 0 {
            s.push_str(", ");
        }
        write!(s, "%arg{i}: {}", a.ty).unwrap();
    }
    s.push_str(")\n");
    writeln!(s, "    attributes {{mesh_shape = {}}} {{", mesh.describe()).unwrap();

    // Argument distribution block.
    for (i, a) in f.args.iter().enumerate() {
        let tilings = dm.tilings(i);
        if atomic.contains(ValueId(i as u32)) {
            writeln!(s, "  // %arg{i} ({}): partir.atomic {{ replicated }}", a.name).unwrap();
        } else if !tilings.is_empty() {
            for (axis, dim) in tilings {
                writeln!(
                    s,
                    "  // %arg{i} ({}): partir.tile {dim} \"{}\" (%r : !partir.range<{}>) \
                     {{ partir.slice {dim} %arg{i}[%r] }}",
                    a.name,
                    mesh.name(axis),
                    mesh.size(axis)
                )
                .unwrap();
            }
        }
    }

    for (ni, node) in f.nodes.iter().enumerate() {
        let v = f.num_args() + ni;
        let ins: Vec<String> = node
            .inputs
            .iter()
            .map(|&x| match f.node_of(x) {
                None => format!("%arg{}", x.index()),
                Some(n) => format!("%{n}"),
            })
            .collect();
        let dist = dm.render_type(v, &node.ty.dims, mesh, node.ty.dtype.name());
        writeln!(s, "  %{ni} = {} {} : {}", node.op.name(), ins.join(", "), dist).unwrap();
    }
    let outs: Vec<String> = f
        .outputs
        .iter()
        .map(|&o| match f.node_of(o) {
            None => format!("%arg{}", o.index()),
            Some(n) => format!("%{n}"),
        })
        .collect();
    writeln!(s, "  return {}", outs.join(", ")).unwrap();
    s.push_str("}\n");
    s
}

/// Summary line: how many values are tiled per axis.
pub fn summarize(f: &Func, mesh: &Mesh, dm: &DistMap) -> String {
    let mut per_axis = vec![0usize; mesh.num_axes()];
    for v in 0..f.num_values() {
        for a in 0..mesh.num_axes() {
            if dm.get(v, AxisId(a)).is_some() {
                per_axis[a] += 1;
            }
        }
    }
    let parts: Vec<String> = per_axis
        .iter()
        .enumerate()
        .map(|(a, n)| format!("\"{}\": {n}/{} values tiled", mesh.name(AxisId(a)), f.num_values()))
        .collect();
    parts.join(", ")
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::{ArgKind, GraphBuilder, TensorType};
    use crate::partir::program::PartirProgram;
    use crate::partir::actions::{Action, DecisionState};

    #[test]
    fn prints_tile_and_atomic_annotations() {
        let mut b = GraphBuilder::new("main");
        let _x = b.arg("x", TensorType::f32(&[8, 16]), ArgKind::Input);
        let w = b.arg("w", TensorType::f32(&[16, 64]), ArgKind::Parameter);
        let y = b.matmul(ValueId(0), w);
        b.output(y);
        let p = PartirProgram::new(b.finish(), Mesh::new(&[("shard", 2)]));
        let st = DecisionState {
            actions: vec![Action::Tile { v: ValueId(1), dim: 1, axis: AxisId(0) }],
            atomic: AtomicSet::from(&[ValueId(0)][..]),
        };
        let (dm, _) = p.apply(&st);
        let txt = print_partir(&p.func, &p.mesh, &dm, &st.atomic);
        assert!(txt.contains("partir.tile 1 \"shard\""));
        assert!(txt.contains("partir.atomic"));
        assert!(txt.contains("mesh_shape = #partir.mesh<\"shard\"=2>"));
        assert!(txt.contains("f32[8, 64{\"shard\"}]"));
        let sum = summarize(&p.func, &p.mesh, &dm);
        assert!(sum.contains("values tiled"));
    }
}
