//! `PartirProgram`: a base-dialect function paired with a mesh and the
//! precomputed propagation rules — the immutable context shared by all
//! search episodes. Applying a [`DecisionState`] yields a [`DistMap`]
//! (the PartIR view of the program) plus propagation statistics.

use super::actions::{action_valid, Action, DecisionState};
use super::dist::DistMap;
use super::mesh::Mesh;
use super::propagate::{PropStats, Propagator};
use crate::ir::{ArgKind, Func, ValueId};

pub struct PartirProgram {
    pub func: Func,
    pub mesh: Mesh,
    pub prop: Propagator,
}

impl PartirProgram {
    pub fn new(func: Func, mesh: Mesh) -> PartirProgram {
        let prop = Propagator::new(&func);
        PartirProgram { func, mesh, prop }
    }

    /// The initial worklist of "interesting operation nodes" (paper §2.3):
    /// function arguments — weights, biases, optimiser state, model inputs.
    pub fn initial_worklist(&self) -> Vec<ValueId> {
        (0..self.func.num_args() as u32).map(ValueId).collect()
    }

    /// Interesting *parameter-like* args (params + optimiser state):
    /// what the learner ranks.
    pub fn decision_args(&self) -> Vec<ValueId> {
        (0..self.func.num_args())
            .filter(|&i| {
                matches!(self.func.args[i].kind, ArgKind::Parameter | ArgKind::OptState)
            })
            .map(|i| ValueId(i as u32))
            .collect()
    }

    /// The stuck-node set of a settled (forward-fixpoint) distribution
    /// map: one status-collection pass, reported once per node in
    /// ascending order. `dm` is not modified — every map produced by
    /// [`PartirProgram::apply`] or a search-env step is a fixpoint, so
    /// the pass assigns nothing.
    pub fn stuck_set(&self, dm: &DistMap) -> Vec<u32> {
        let mut scratch = dm.clone();
        let mut stats = PropStats::default();
        self.prop.forward(&self.func, &self.mesh, &mut scratch, &mut stats);
        debug_assert_eq!(&scratch, dm, "stuck_set expects a forward-fixpoint map");
        stats.stuck_nodes
    }

    /// Apply a decision sequence: replay explicit actions with forward
    /// propagation after each, exactly as the search env does.
    pub fn apply(&self, state: &DecisionState) -> (DistMap, PropStats) {
        let mut dm = DistMap::new(&self.func, &self.mesh);
        let mut stats = PropStats::default();
        self.apply_into(state, &mut dm, &mut stats);
        (dm, stats)
    }

    /// Same as [`apply`] but reusing caller-provided buffers (hot path).
    pub fn apply_into(&self, state: &DecisionState, dm: &mut DistMap, stats: &mut PropStats) {
        dm.d.iter_mut().for_each(|x| *x = [super::dist::UNKNOWN; super::mesh::MAX_AXES]);
        stats.stuck_nodes.clear();
        stats.assigned = 0;
        let mut replay = DecisionState::default();
        for action in &state.actions {
            match action {
                Action::Tile { v, dim, axis } => {
                    if action_valid(&self.func, &self.mesh, dm, &replay, action) {
                        dm.set(v.index(), *axis, *dim);
                        stats.stuck_nodes.clear();
                        self.prop.forward(&self.func, &self.mesh, dm, stats);
                    }
                }
                Action::Atomic { v } => replay.atomic.insert(*v),
                Action::InferRest => {
                    stats.stuck_nodes.clear();
                    self.prop.infer_rest(&self.func, &self.mesh, dm, stats);
                }
                Action::Stop => break,
            }
            replay.actions.push(*action);
        }
        stats.stuck_nodes.sort_unstable();
        stats.stuck_nodes.dedup();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::{ArgKind, GraphBuilder, TensorType};
    use crate::partir::mesh::AxisId;

    fn linear() -> PartirProgram {
        let mut b = GraphBuilder::new("main");
        let x = b.arg("x", TensorType::f32(&[8, 16]), ArgKind::Input);
        let w = b.arg("w", TensorType::f32(&[16, 64]), ArgKind::Parameter);
        let bias = b.arg("b", TensorType::f32(&[64]), ArgKind::Parameter);
        let dot = b.matmul(x, w);
        let ty = b.ty(dot).clone();
        let bb = b.broadcast_to(bias, ty);
        let out = b.add(dot, bb);
        b.output(out);
        PartirProgram::new(b.finish(), Mesh::new(&[("shard", 2)]))
    }

    #[test]
    fn apply_replays_actions_with_propagation() {
        let p = linear();
        let st = DecisionState {
            actions: vec![
                Action::Tile { v: ValueId(1), dim: 1, axis: AxisId(0) },
                Action::InferRest,
            ],
            atomic: Default::default(),
        };
        let (dm, stats) = p.apply(&st);
        assert_eq!(dm.get(1, AxisId(0)), Some(1));
        assert_eq!(dm.get(2, AxisId(0)), Some(0)); // bias inferred
        assert!(stats.assigned > 0);
    }

    #[test]
    fn invalid_actions_in_replay_are_skipped() {
        let p = linear();
        let st = DecisionState {
            actions: vec![
                Action::Tile { v: ValueId(1), dim: 1, axis: AxisId(0) },
                // second tile of same value+axis is invalid -> skipped
                Action::Tile { v: ValueId(1), dim: 0, axis: AxisId(0) },
            ],
            atomic: Default::default(),
        };
        let (dm, _) = p.apply(&st);
        assert_eq!(dm.get(1, AxisId(0)), Some(1));
    }

    #[test]
    fn worklists() {
        let p = linear();
        assert_eq!(p.initial_worklist().len(), 3);
        assert_eq!(p.decision_args(), vec![ValueId(1), ValueId(2)]);
    }
}
