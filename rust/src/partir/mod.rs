//! PartIR layer (paper §2.1–2.2): meshes, per-value distribution state,
//! the declarative per-op partitioning registry, the propagation engine,
//! rewrite actions, and the Fig-2-style printer.

pub mod actions;
pub mod dist;
pub mod mesh;
pub mod printer;
pub mod program;
pub mod propagate;
pub mod registry;

pub use actions::{Action, AtomicSet, DecisionState};
pub use dist::DistMap;
pub use mesh::{Axis, AxisId, Mesh, MAX_AXES};
pub use program::PartirProgram;
pub use propagate::{PropStats, Propagator};
