//! The user-facing partitioning API (paper Fig 5):
//!
//! ```text
//! partitioned_fn, specs = automap(update_fn, mesh={"batch":2,"model":4},
//!                                 manual_axes=["batch"])
//! ```
//!
//! is expressed here as a [`Session`] that owns the program and runs a
//! composable pipeline of [`Tactic`]s:
//!
//! ```ignore
//! let mut session = Session::new(update_fn, mesh);
//! let plan = session.run(&[
//!     Tactic::Manual {
//!         constraints: vec![ShardingConstraint::new("tokens", 0, "batch")],
//!         manual_axes: vec!["batch".into()],
//!     },
//!     Tactic::filter(RankerSpec::Heuristic),
//!     Tactic::search(1000, 0),
//!     Tactic::InferRest,
//!     Tactic::Lower,
//! ])?;
//! ```
//!
//! Each stage is a first-class value, so callers can pin axes and seed
//! decisions (`Manual`, the user-constraint half of GSPMD-style
//! annotation+propagation), shrink the worklist (`Filter`), search
//! (`Search`), close over the remaining values (`InferRest`), and lower
//! to SPMD with a cost evaluation (`Lower`) — in any order, repeatedly,
//! PartIR-tactic style. The result is a serialisable [`PartitionPlan`].
//!
//! `coordinator::automap` is a thin compatibility shim over this module.

pub mod plan;
pub mod session;
pub mod tactic;

pub use plan::{PartitionPlan, ShardSpec};
pub use session::{resolve_worklist, Session};
pub use tactic::{RankerSpec, ShardingConstraint, Tactic};
