//! [`Session`]: owns the [`PartirProgram`], the cached [`Propagator`]
//! (inside the program), and reusable [`DistMap`]/[`PropStats`] buffers,
//! and executes composable [`Tactic`] pipelines over them.

use super::plan::{PartitionPlan, ShardSpec};
use super::tactic::{RankerSpec, ShardingConstraint, Tactic};
use crate::cost::composite::{evaluate_pipelined, CostWeights, Evaluation};
use crate::ir::{Func, ValueId};
use crate::learner::features::featurize;
use crate::learner::ranker::{top_k_decisions, HeuristicRanker, PjrtRanker, Ranker};
use crate::partir::actions::{action_valid, Action, DecisionState};
use crate::partir::dist::DistMap;
use crate::partir::mesh::Mesh;
use crate::partir::program::PartirProgram;
use crate::partir::propagate::PropStats;
use crate::pipeline::{balanced_cuts, PipelineSpec};
use crate::search::env::{RewriteEnv, SearchOptions};
use crate::search::mcts::{search, MctsConfig};
use crate::sim::device::Device;
use crate::util::stats::fmt_bytes;
use anyhow::{anyhow, bail, Result};

/// Resolve a worklist according to a [`RankerSpec`]. Returns the list
/// plus a label describing which ranker actually ran (the `Auto` spec
/// falls back to the heuristic when artifacts or PJRT are absent).
pub fn resolve_worklist(
    program: &PartirProgram,
    ranker: &RankerSpec,
    k: usize,
) -> Result<(Vec<ValueId>, &'static str)> {
    match ranker {
        RankerSpec::None => Ok((RewriteEnv::default_worklist(program), "none")),
        RankerSpec::Heuristic => {
            let g = featurize(&program.func, &program.mesh);
            let r = HeuristicRanker { func: &program.func };
            let scores = r.score(&g)?;
            Ok((top_k_decisions(&program.func, &g, &scores, k), "heuristic"))
        }
        RankerSpec::Learned { hlo_path } => {
            let rt = crate::runtime::pjrt::Runtime::new()?;
            let r = PjrtRanker::load(&rt, hlo_path)?;
            let g = featurize(&program.func, &program.mesh);
            let scores = r.score(&g)?;
            Ok((top_k_decisions(&program.func, &g, &scores, k), "learned(pjrt)"))
        }
        RankerSpec::Auto { hlo_path } => {
            if crate::runtime::pjrt::pjrt_available() && std::path::Path::new(hlo_path).exists() {
                resolve_worklist(program, &RankerSpec::Learned { hlo_path: hlo_path.clone() }, k)
            } else {
                let (wl, _) = resolve_worklist(program, &RankerSpec::Heuristic, k)?;
                Ok((wl, "heuristic(fallback)"))
            }
        }
    }
}

/// A partitioning session: one program + mesh, driven by tactics.
pub struct Session {
    pub program: PartirProgram,
    pub device: Device,
    pub weights: CostWeights,
    pub options: SearchOptions,
    // Reusable buffers (hot path: every stage replays into these).
    dm: DistMap,
    stats: PropStats,
    // Pipeline state.
    state: DecisionState,
    /// `searchable` flag per mesh axis at construction, so `reset` can
    /// undo `Manual` tactics' manual-axis markings.
    initial_searchable: Vec<bool>,
    worklist: Option<Vec<ValueId>>,
    /// Active pipeline configuration (set by `Tactic::Pipeline`): the
    /// stage axis, microbatch count, and the current cut vector —
    /// refined in place when a later `Search` tactic moves cuts.
    pipeline: Option<PipelineSpec>,
    trace: Vec<String>,
    decisions: usize,
    episodes_to_best: usize,
    worklist_size: usize,
    targets: usize,
    last_eval: Option<Evaluation>,
}

impl Session {
    /// Paper Fig 5 entry point: a session with default device (TPU v3),
    /// cost weights, and search options.
    pub fn new(func: Func, mesh: Mesh) -> Session {
        Session::with_options(
            func,
            mesh,
            Device::tpu_v3(),
            CostWeights::default(),
            SearchOptions::default(),
        )
    }

    pub fn with_options(
        func: Func,
        mesh: Mesh,
        device: Device,
        weights: CostWeights,
        options: SearchOptions,
    ) -> Session {
        let program = PartirProgram::new(func, mesh);
        let dm = DistMap::new(&program.func, &program.mesh);
        let num_values = program.func.num_values();
        let initial_searchable = program.mesh.axes.iter().map(|a| a.searchable).collect();
        Session {
            program,
            device,
            weights,
            options,
            dm,
            stats: PropStats::default(),
            state: DecisionState {
                actions: Vec::new(),
                atomic: crate::partir::actions::AtomicSet::with_capacity(num_values),
            },
            initial_searchable,
            worklist: None,
            pipeline: None,
            trace: Vec::new(),
            decisions: 0,
            episodes_to_best: 0,
            worklist_size: 0,
            targets: 0,
            last_eval: None,
        }
    }

    /// Build a session from the textual IR form (DESIGN.md §10) — the
    /// entry point for external frontends that submit programs as text
    /// rather than through [`crate::ir::GraphBuilder`]. The text is
    /// parsed *and verified*; parse errors carry line/column positions.
    pub fn from_text(src: &str, mesh: Mesh) -> Result<Session> {
        let func = crate::ir::parser::parse_func(src).map_err(|e| anyhow!("{e}"))?;
        Ok(Session::new(func, mesh))
    }

    /// One-shot convenience entry point: build a session, run a tactic
    /// pipeline, return the plan. (The root-parallel executor no longer
    /// goes through this — it shares ONE session across its workers and
    /// adopts the winning search result; see `service::executor`.)
    pub fn plan_for(
        func: Func,
        mesh: Mesh,
        device: Device,
        weights: CostWeights,
        options: SearchOptions,
        tactics: &[Tactic],
    ) -> Result<PartitionPlan> {
        Session::with_options(func, mesh, device, weights, options).run(tactics)
    }

    pub fn mesh(&self) -> &Mesh {
        &self.program.mesh
    }

    /// The decisions accumulated so far (manual pins + search results).
    pub fn state(&self) -> &DecisionState {
        &self.state
    }

    /// The current distribution map.
    pub fn dist_map(&self) -> &DistMap {
        &self.dm
    }

    /// The active pipeline configuration, if a `Pipeline` tactic ran.
    pub fn pipeline_spec(&self) -> Option<&PipelineSpec> {
        self.pipeline.as_ref()
    }

    /// The stage/decision trace accumulated so far.
    pub fn trace(&self) -> &[String] {
        &self.trace
    }

    /// The worklist a `Search` tactic would run over right now: the
    /// `Filter` tactic's selection if one ran, the default worklist
    /// otherwise. The root-parallel executor uses this to build ONE
    /// shared environment instead of one per worker.
    pub fn resolved_worklist(&self) -> Vec<ValueId> {
        match &self.worklist {
            Some(wl) => wl.clone(),
            None => RewriteEnv::default_worklist(&self.program),
        }
    }

    /// Adopt a search result produced by an external driver (the
    /// root-parallel executor) over an environment seeded with this
    /// session's current state: append the new decisions to the trace,
    /// replay the winning state into the session buffers, and record the
    /// bookkeeping exactly as [`Tactic::Search`] would have.
    pub fn adopt_search_result(
        &mut self,
        result: &crate::search::SearchResult,
        targets: usize,
        worklist_size: usize,
    ) {
        let prior_actions = self.state.actions.len();
        for a in result.best_state.actions.iter().skip(prior_actions) {
            if matches!(a, Action::Tile { .. }) {
                self.decisions += 1;
            }
            let line = format!("search: {}", a.describe(&self.program.func, &self.program.mesh));
            self.trace.push(line);
        }
        self.state = result.best_state.clone();
        if let Some(spec) = &mut self.pipeline {
            if spec.cuts != result.best_cuts {
                self.trace.push(format!("search: stage cuts refined to {:?}", result.best_cuts));
                spec.cuts = result.best_cuts.clone();
            }
        }
        self.program.apply_into(&self.state, &mut self.dm, &mut self.stats);
        self.episodes_to_best = result.episodes_to_best;
        self.targets = targets;
        self.worklist_size = worklist_size;
        self.trace.push(format!(
            "search: {} episodes over {} targets, best at episode {}",
            result.episodes_run, targets, result.episodes_to_best
        ));
        self.last_eval = None;
    }

    /// Drop all decisions and pipeline state — including manual-axis
    /// markings applied by `Manual` tactics — keeping the program and
    /// cached propagator (sessions are reusable across pipelines).
    pub fn reset(&mut self) {
        let num_values = self.program.func.num_values();
        for (axis, &searchable) in
            self.program.mesh.axes.iter_mut().zip(&self.initial_searchable)
        {
            axis.searchable = searchable;
        }
        self.dm = DistMap::new(&self.program.func, &self.program.mesh);
        self.stats = PropStats::default();
        self.state = DecisionState {
            actions: Vec::new(),
            atomic: crate::partir::actions::AtomicSet::with_capacity(num_values),
        };
        self.worklist = None;
        self.pipeline = None;
        self.trace.clear();
        self.decisions = 0;
        self.episodes_to_best = 0;
        self.worklist_size = 0;
        self.targets = 0;
        self.last_eval = None;
    }

    /// Execute a tactic pipeline and return the resulting plan. Stages
    /// compose: decisions taken by earlier tactics constrain later ones,
    /// and repeated `run` calls continue from the session's state (call
    /// [`Session::reset`] for a fresh start).
    pub fn run(&mut self, tactics: &[Tactic]) -> Result<PartitionPlan> {
        let t0 = std::time::Instant::now();
        for t in tactics {
            self.apply(t)?;
        }
        Ok(self.plan(t0.elapsed().as_secs_f64()))
    }

    /// Execute one pipeline stage.
    pub fn apply(&mut self, tactic: &Tactic) -> Result<()> {
        match tactic {
            Tactic::Manual { constraints, manual_axes } => {
                self.apply_manual(constraints, manual_axes)
            }
            Tactic::Filter { ranker, top_k } => self.apply_filter(ranker, *top_k),
            Tactic::Search { budget, seed, mcts } => self.apply_search(*budget, *seed, mcts),
            Tactic::Pipeline { axis, stages, microbatches } => {
                self.apply_pipeline(axis, *stages, *microbatches)
            }
            Tactic::InferRest => {
                self.apply_infer_rest();
                Ok(())
            }
            Tactic::Lower => {
                self.apply_lower();
                Ok(())
            }
        }
    }

    fn resolve_axis(&self, name: &str) -> Result<crate::partir::mesh::AxisId> {
        self.program.mesh.axis_by_name(name).ok_or_else(|| {
            anyhow!("\"{name}\" is not a mesh axis (mesh is {})", self.program.mesh.describe())
        })
    }

    fn resolve_arg(&self, name: &str) -> Result<ValueId> {
        self.program
            .func
            .args
            .iter()
            .position(|a| a.name == name)
            .map(|i| ValueId(i as u32))
            .ok_or_else(|| {
                anyhow!(
                    "\"{name}\" is not a function argument ({} args, e.g. \"{}\")",
                    self.program.func.num_args(),
                    self.program.func.args.first().map(|a| a.name.as_str()).unwrap_or("")
                )
            })
    }

    fn apply_manual(
        &mut self,
        constraints: &[ShardingConstraint],
        manual_axes: &[String],
    ) -> Result<()> {
        for axis_name in manual_axes {
            let ax = self.resolve_axis(axis_name)?;
            self.program.mesh.axes[ax.0].searchable = false;
            self.trace.push(format!("manual: axis \"{axis_name}\" excluded from search"));
        }
        for c in constraints {
            let v = self.resolve_arg(&c.name)?;
            let axis = self.resolve_axis(&c.axis)?;
            let action = Action::Tile { v, dim: c.dim, axis };
            if !action_valid(&self.program.func, &self.program.mesh, &self.dm, &self.state, &action)
            {
                bail!(
                    "manual constraint {}:{}:{} is not applicable \
                     (dim out of range, size not divisible by the axis, or already tiled)",
                    c.name,
                    c.dim,
                    c.axis
                );
            }
            self.dm.set(v.index(), axis, c.dim);
            self.state.actions.push(action);
            self.decisions += 1;
            self.stats.stuck_nodes.clear();
            self.program.prop.forward(
                &self.program.func,
                &self.program.mesh,
                &mut self.dm,
                &mut self.stats,
            );
            let line =
                format!("manual: {}", action.describe(&self.program.func, &self.program.mesh));
            self.trace.push(line);
            self.last_eval = None;
        }
        Ok(())
    }

    /// `Tactic::Pipeline`: resolve the stage axis, exclude it from the
    /// SPMD search (it carries whole stages, not tiles), and seed the
    /// cut vector with the balanced interval split — the position a
    /// later `Search` tactic refines via cut-move actions.
    fn apply_pipeline(&mut self, axis: &str, stages: usize, microbatches: usize) -> Result<()> {
        let ax = self.resolve_axis(axis)?;
        if stages == 0 {
            bail!("pipeline: stages must be >= 1");
        }
        if microbatches == 0 {
            bail!("pipeline: microbatches must be >= 1");
        }
        let n = self.program.func.num_nodes();
        if stages > n {
            bail!("pipeline: {stages} stages over a {n}-node program");
        }
        self.program.mesh.axes[ax.0].searchable = false;
        let cuts = balanced_cuts(&self.program.func, stages);
        let spec = PipelineSpec { axis: ax.0, microbatches, cuts };
        self.trace.push(format!(
            "pipeline: {} stages over axis \"{axis}\" ({} microbatches), seed cuts {:?}",
            spec.stages(),
            microbatches,
            spec.cuts
        ));
        self.pipeline = Some(spec);
        self.last_eval = None;
        Ok(())
    }

    fn apply_filter(&mut self, ranker: &RankerSpec, top_k: usize) -> Result<()> {
        let full = RewriteEnv::default_worklist(&self.program).len();
        let (wl, label) = resolve_worklist(&self.program, ranker, top_k)?;
        self.trace.push(format!("filter({label}): worklist {} -> {}", full, wl.len()));
        self.worklist_size = wl.len();
        self.worklist = Some(wl);
        Ok(())
    }

    fn apply_search(&mut self, budget: usize, seed: u64, mcts: &MctsConfig) -> Result<()> {
        let worklist = self.resolved_worklist();
        self.worklist_size = worklist.len();
        let prior_actions = self.state.actions.len();
        let result = {
            let mut env = RewriteEnv::with_seed(
                &self.program,
                self.device.clone(),
                self.weights.clone(),
                self.options.clone(),
                &worklist,
                self.state.clone(),
            );
            if let Some(spec) = &self.pipeline {
                env.set_pipeline(spec.clone());
            }
            self.targets = env.targets.len();
            search(&env, budget, seed, mcts.clone())
        };
        self.episodes_to_best = result.episodes_to_best;
        for a in result.best_state.actions.iter().skip(prior_actions) {
            if matches!(a, Action::Tile { .. }) {
                self.decisions += 1;
            }
            let line = format!("search: {}", a.describe(&self.program.func, &self.program.mesh));
            self.trace.push(line);
        }
        self.state = result.best_state;
        if let Some(spec) = &mut self.pipeline {
            if spec.cuts != result.best_cuts {
                self.trace.push(format!("search: stage cuts refined to {:?}", result.best_cuts));
                spec.cuts = result.best_cuts;
            }
        }
        self.program.apply_into(&self.state, &mut self.dm, &mut self.stats);
        self.trace.push(format!(
            "search: {budget} episodes over {} targets, best at episode {}",
            self.targets, result.episodes_to_best
        ));
        self.last_eval = None;
        Ok(())
    }

    fn apply_infer_rest(&mut self) {
        self.stats.stuck_nodes.clear();
        self.program.prop.infer_rest(
            &self.program.func,
            &self.program.mesh,
            &mut self.dm,
            &mut self.stats,
        );
        self.state.actions.push(Action::InferRest);
        self.trace.push(format!(
            "infer-rest: {} assignments, {} stuck nodes",
            self.stats.assigned,
            self.stats.stuck_nodes.len()
        ));
        self.last_eval = None;
    }

    fn apply_lower(&mut self) {
        let eval = evaluate_pipelined(
            &self.program,
            &self.dm,
            &self.device,
            &self.weights,
            self.pipeline.as_ref(),
        );
        self.trace.push(format!(
            "lower: {} all-reduces + {} all-gathers ({} moved), peak {} (fits={})",
            eval.collectives.all_reduce_count,
            eval.collectives.all_gather_count,
            fmt_bytes(eval.collectives.total_bytes() as f64),
            fmt_bytes(eval.memory.peak_bytes as f64),
            eval.fits_memory
        ));
        if let Some(pe) = &eval.pipeline {
            self.trace.push(format!(
                "lower: 1F1B {}x{} bubble {:.1}%, {} sends ({}), stage peak {}",
                pe.stages,
                pe.microbatches,
                pe.bubble_fraction * 100.0,
                eval.collectives.send_count,
                fmt_bytes(eval.collectives.send_bytes as f64),
                fmt_bytes(pe.max_stage_peak_bytes as f64)
            ));
        }
        self.last_eval = Some(eval);
    }

    /// Materialise the plan for the current session state.
    fn plan(&mut self, wall_seconds: f64) -> PartitionPlan {
        let eval = match self.last_eval.clone() {
            Some(e) => e,
            None => evaluate_pipelined(
                &self.program,
                &self.dm,
                &self.device,
                &self.weights,
                self.pipeline.as_ref(),
            ),
        };
        let f = &self.program.func;
        let mesh = &self.program.mesh;
        let dm = &self.dm;
        let spec_for = |v: ValueId, name: String| ShardSpec {
            name,
            tilings: dm
                .tilings(v.index())
                .into_iter()
                .map(|(a, d)| (mesh.name(a).to_string(), d))
                .collect(),
        };
        let input_specs = (0..f.num_args())
            .map(|i| spec_for(ValueId(i as u32), f.args[i].name.clone()))
            .collect();
        let output_specs = f
            .outputs
            .iter()
            .enumerate()
            .map(|(i, &o)| spec_for(o, format!("output_{i}")))
            .collect();
        PartitionPlan {
            mesh_axes: mesh.axes.iter().map(|a| (a.name.clone(), a.size)).collect(),
            input_specs,
            output_specs,
            eval,
            decisions: self.decisions,
            episodes_to_best: self.episodes_to_best,
            worklist_size: self.worklist_size,
            targets: self.targets,
            wall_seconds,
            trace: self.trace.clone(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::mlp::{build_mlp, MlpConfig};

    fn batch_model_session() -> Session {
        let m = build_mlp(&MlpConfig::small());
        Session::new(m.func, Mesh::new(&[("batch", 2), ("model", 4)]))
    }

    #[test]
    fn manual_tactic_pins_axis_and_sharding() {
        let mut s = batch_model_session();
        s.run(&[Tactic::Manual {
            constraints: vec![ShardingConstraint::new("x", 0, "batch")],
            manual_axes: vec!["batch".to_string()],
        }])
        .unwrap();
        assert!(!s.mesh().axes[0].searchable, "batch must be manual");
        let batch = s.mesh().axis_by_name("batch").unwrap();
        assert_eq!(s.dist_map().get(0, batch), Some(0), "x pinned on batch");
        assert_eq!(s.state().actions.len(), 1);
        assert!(s.trace().iter().any(|t| t.contains("excluded from search")));
    }

    #[test]
    fn manual_rejects_unknown_names_and_bad_dims() {
        let mut s = batch_model_session();
        assert!(s.run(&[Tactic::manual_axes(&["expert"])]).is_err());
        assert!(s.run(&[Tactic::pin("nope", 0, "batch")]).is_err());
        // dim out of range
        assert!(s.run(&[Tactic::pin("x", 9, "batch")]).is_err());
    }

    #[test]
    fn search_after_manual_respects_manual_axis() {
        let mut s = batch_model_session();
        let plan = s
            .run(&[
                Tactic::Manual {
                    constraints: vec![ShardingConstraint::new("x", 0, "batch")],
                    manual_axes: vec!["batch".to_string()],
                },
                Tactic::search(150, 7),
                Tactic::InferRest,
                Tactic::Lower,
            ])
            .unwrap();
        // the pin survives search
        let x = plan.input_specs.iter().find(|sp| sp.name == "x").unwrap();
        assert!(x.tiled_on("batch"));
        // parameters never land on the manual axis
        for sp in &plan.input_specs {
            if sp.name.ends_with("/w") || sp.name.ends_with("/b") {
                assert!(!sp.tiled_on("batch"), "{} tiled on manual axis", sp.name);
            }
        }
        assert!(plan.decisions >= 1);
        assert!(plan.trace.iter().any(|t| t.starts_with("manual:")));
        assert!(plan.trace.iter().any(|t| t.starts_with("search:")));
    }

    #[test]
    fn pipeline_produces_serialisable_plan() {
        let mut s = batch_model_session();
        let plan = s.run(&Tactic::default_stack(100, 3)).unwrap();
        let j = plan.to_json();
        let back =
            PartitionPlan::from_json(&crate::util::json::parse(&j.pretty()).unwrap()).unwrap();
        assert_eq!(back.input_specs, plan.input_specs);
        assert_eq!(back.eval.collectives, plan.eval.collectives);
        assert_eq!(back.decisions, plan.decisions);
    }

    #[test]
    fn sessions_build_from_textual_programs() {
        let text = crate::ir::printer::print_func(&build_mlp(&MlpConfig::small()).func);
        let mut s = Session::from_text(&text, Mesh::new(&[("batch", 2), ("model", 4)])).unwrap();
        // The parsed program keeps its argument names, so name-addressed
        // manual constraints work exactly as for the built-in models.
        let plan = s
            .run(&[
                Tactic::Manual {
                    constraints: vec![ShardingConstraint::new("x", 0, "batch")],
                    manual_axes: vec!["batch".to_string()],
                },
                Tactic::InferRest,
                Tactic::Lower,
            ])
            .unwrap();
        let x = plan.input_specs.iter().find(|sp| sp.name == "x").unwrap();
        assert!(x.tiled_on("batch"));
        // Parse errors surface with positions.
        let err = Session::from_text("func nope", Mesh::new(&[("m", 2)])).unwrap_err();
        assert!(err.to_string().contains("line 1"), "{err}");
    }

    #[test]
    fn pipeline_tactic_seeds_cuts_and_prices_the_schedule() {
        let m = build_mlp(&MlpConfig::small());
        let mut s = Session::new(m.func, Mesh::new(&[("pipe", 2), ("model", 4)]));
        let plan = s
            .run(&[Tactic::pipeline("pipe", 2), Tactic::InferRest, Tactic::Lower])
            .unwrap();
        let spec = s.pipeline_spec().expect("pipeline tactic must persist");
        assert_eq!(spec.stages(), 2);
        assert_eq!(spec.microbatches, 4);
        assert!(!s.mesh().axes[0].searchable, "stage axis is excluded from SPMD search");
        let pe = plan.eval.pipeline.as_ref().expect("plan eval carries pipeline terms");
        assert_eq!((pe.stages, pe.microbatches), (2, 4));
        assert!(pe.makespan_seconds > 0.0);
        assert!(plan.eval.collectives.send_count > 0, "stage boundary must move activations");
        assert!(plan.trace.iter().any(|t| t.starts_with("pipeline:")), "{:?}", plan.trace);
        // Unknown axis or impossible stage counts fail loudly.
        s.reset();
        assert!(s.pipeline_spec().is_none(), "reset clears the pipeline");
        assert!(s.run(&[Tactic::pipeline("nope", 2)]).is_err());
        assert!(s.run(&[Tactic::pipeline("pipe", 10_000)]).is_err());
    }

    #[test]
    fn sessions_are_reusable_after_reset() {
        let mut s = batch_model_session();
        let _ = s
            .run(&[
                Tactic::Manual {
                    constraints: vec![ShardingConstraint::new("x", 0, "batch")],
                    manual_axes: vec!["batch".to_string()],
                },
                Tactic::InferRest,
            ])
            .unwrap();
        assert!(!s.state().actions.is_empty());
        assert!(!s.mesh().axes[0].searchable);
        s.reset();
        assert!(s.state().actions.is_empty());
        assert!(s.trace().is_empty());
        assert!(s.mesh().axes[0].searchable, "reset must undo manual-axis markings");
        let plan = s.run(&[Tactic::Lower]).unwrap();
        assert_eq!(plan.decisions, 0);
        assert!(plan.input_specs.iter().all(|sp| sp.replicated()));
    }
}
