//! Pipeline stages ([`Tactic`]) and user sharding constraints.
//!
//! A partitioning run is a sequence of tactics, mirroring PartIR's
//! "composable sequence of tactics" and the paper's Figure 5 workflow:
//! user-supplied constraints first, then inductive/search tactics.

use crate::learner::ranker::TOP_K;
use crate::search::mcts::MctsConfig;
use anyhow::{anyhow, Result};

/// A user-supplied sharding constraint: tile argument `name`'s tensor
/// dimension `dim` along mesh axis `axis` before any search runs — the
/// GSPMD-style per-tensor annotation that propagation then spreads.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ShardingConstraint {
    pub name: String,
    pub dim: usize,
    pub axis: String,
}

impl ShardingConstraint {
    pub fn new(name: &str, dim: usize, axis: &str) -> ShardingConstraint {
        ShardingConstraint { name: name.to_string(), dim, axis: axis.to_string() }
    }

    /// Parse the CLI syntax `name:dim:axis`, e.g. `tokens:0:batch`.
    pub fn parse(spec: &str) -> Result<ShardingConstraint> {
        let parts: Vec<&str> = spec.trim().split(':').collect();
        if parts.len() != 3 {
            return Err(anyhow!("bad shard spec '{spec}' (want name:dim:axis)"));
        }
        let dim: usize = parts[1]
            .parse()
            .map_err(|_| anyhow!("bad shard spec '{spec}': dim '{}' is not an integer", parts[1]))?;
        Ok(ShardingConstraint::new(parts[0], dim, parts[2]))
    }
}

/// How the `Filter` tactic ranks the decision worklist (paper §2.3's
/// learned top-k node filter, plus fallbacks).
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum RankerSpec {
    /// No filtering: the full default worklist (MCTS-only mode, Fig 6).
    None,
    /// Deterministic size-based ranker (no artifacts required).
    Heuristic,
    /// The learned GNN ranker via PJRT; errors if unavailable.
    Learned { hlo_path: String },
    /// `Learned` when the artifact file exists and PJRT is built in,
    /// `Heuristic` otherwise (the figure-harness default).
    Auto { hlo_path: String },
}

/// One stage of a partitioning pipeline.
#[derive(Debug, Clone)]
pub enum Tactic {
    /// User constraints applied before search (paper Fig 5): pin whole
    /// mesh axes as manually managed (excluded from search) and/or seed
    /// explicit `(name, dim, axis)` shardings that every later stage
    /// builds on.
    Manual { constraints: Vec<ShardingConstraint>, manual_axes: Vec<String> },
    /// Rank decision candidates and keep the top-k (paper §2.3).
    Filter { ranker: RankerSpec, top_k: usize },
    /// MCTS over the (possibly filtered) worklist, seeded with every
    /// decision taken so far.
    Search { budget: usize, seed: u64, mcts: MctsConfig },
    /// Cut the program into `stages` contiguous intervals over mesh axis
    /// `axis` and price execution through the 1F1B schedule simulator
    /// (DESIGN.md §11). Seeds balanced cuts; a later `Search` tactic
    /// refines them with cut-move actions alongside tile actions.
    Pipeline { axis: String, stages: usize, microbatches: usize },
    /// Infer tilings of the remaining values from the decided ones.
    InferRest,
    /// Lower to SPMD and record the cost evaluation + collective summary.
    Lower,
}

impl Tactic {
    /// `Manual` with only manual axes (no explicit shardings).
    pub fn manual_axes(axes: &[&str]) -> Tactic {
        Tactic::Manual {
            constraints: Vec::new(),
            manual_axes: axes.iter().map(|a| a.to_string()).collect(),
        }
    }

    /// `Manual` pinning one sharding: `pin("tokens", 0, "batch")`.
    pub fn pin(name: &str, dim: usize, axis: &str) -> Tactic {
        Tactic::Manual {
            constraints: vec![ShardingConstraint::new(name, dim, axis)],
            manual_axes: Vec::new(),
        }
    }

    /// `Filter` with the paper's default k.
    pub fn filter(ranker: RankerSpec) -> Tactic {
        Tactic::Filter { ranker, top_k: TOP_K }
    }

    /// `Search` with default MCTS hyperparameters.
    pub fn search(budget: usize, seed: u64) -> Tactic {
        Tactic::Search { budget, seed, mcts: MctsConfig::default() }
    }

    /// `Pipeline` with the common 1F1B microbatch default (`2 * stages`).
    pub fn pipeline(axis: &str, stages: usize) -> Tactic {
        Tactic::Pipeline { axis: axis.to_string(), stages, microbatches: 2 * stages }
    }

    /// The standard tactic stack: heuristic filter → search → infer-rest
    /// → lower. Prepend a `Manual` tactic to constrain it.
    ///
    /// (Renamed from `default_pipeline` — "pipeline" now means the
    /// inter-op parallelism tactic, not the tactic sequence.)
    pub fn default_stack(budget: usize, seed: u64) -> Vec<Tactic> {
        vec![
            Tactic::filter(RankerSpec::Heuristic),
            Tactic::search(budget, seed),
            Tactic::InferRest,
            Tactic::Lower,
        ]
    }

    /// Deprecated alias of [`Tactic::default_stack`].
    #[deprecated(note = "renamed to `default_stack`; `Pipeline` is now a tactic")]
    pub fn default_pipeline(budget: usize, seed: u64) -> Vec<Tactic> {
        Tactic::default_stack(budget, seed)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_shard_specs() {
        let c = ShardingConstraint::parse("tokens:0:batch").unwrap();
        assert_eq!(c, ShardingConstraint::new("tokens", 0, "batch"));
        let c = ShardingConstraint::parse(" layer_0/attn/wq:1:model ").unwrap();
        assert_eq!(c.name, "layer_0/attn/wq");
        assert_eq!(c.dim, 1);
        assert!(ShardingConstraint::parse("tokens:batch").is_err());
        assert!(ShardingConstraint::parse("tokens:x:batch").is_err());
    }

    #[test]
    fn constructors_build_expected_tactics() {
        match Tactic::manual_axes(&["batch"]) {
            Tactic::Manual { constraints, manual_axes } => {
                assert!(constraints.is_empty());
                assert_eq!(manual_axes, vec!["batch"]);
            }
            _ => panic!("wrong tactic"),
        }
        assert_eq!(Tactic::default_stack(10, 0).len(), 4);
        match Tactic::pipeline("pipe", 4) {
            Tactic::Pipeline { axis, stages, microbatches } => {
                assert_eq!((axis.as_str(), stages, microbatches), ("pipe", 4, 8));
            }
            _ => panic!("wrong tactic"),
        }
    }

    #[test]
    #[allow(deprecated)]
    fn default_pipeline_alias_still_builds_the_stack() {
        assert_eq!(Tactic::default_pipeline(10, 0).len(), 4);
    }
}
