//! The unified result of a partitioning pipeline: per-argument
//! [`ShardSpec`]s, the cost [`Evaluation`], a collectives summary, and
//! the decision trace — serialisable to/from JSON via `util::json` so
//! plans can be cached, diffed, and shipped between tools.

use crate::cost::composite::{Evaluation, PipelineEval};
use crate::cost::liveness::MemoryEstimate;
use crate::sim::exec::RuntimeEstimate;
use crate::spmd::collectives::CollectiveStats;
use crate::util::json::Json;
use anyhow::{anyhow, Context, Result};

/// Partitioning decision for one function argument or output:
/// `(axis name, tensor dim)` pairs; empty = replicated.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ShardSpec {
    pub name: String,
    pub tilings: Vec<(String, usize)>,
}

impl ShardSpec {
    pub fn replicated(&self) -> bool {
        self.tilings.is_empty()
    }

    /// Is this value tiled along the named mesh axis?
    pub fn tiled_on(&self, axis: &str) -> bool {
        self.tilings.iter().any(|(a, _)| a == axis)
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("name", Json::str(self.name.clone())),
            (
                "tilings",
                Json::Arr(
                    self.tilings
                        .iter()
                        .map(|(a, d)| {
                            Json::obj(vec![
                                ("axis", Json::str(a.clone())),
                                ("dim", Json::num(*d as f64)),
                            ])
                        })
                        .collect(),
                ),
            ),
        ])
    }

    pub fn from_json(j: &Json) -> Result<ShardSpec> {
        let name = j
            .get("name")
            .and_then(|v| v.as_str())
            .context("spec missing 'name'")?
            .to_string();
        let mut tilings = Vec::new();
        for t in j.get("tilings").and_then(|v| v.as_arr()).context("spec missing 'tilings'")? {
            let axis = t.get("axis").and_then(|v| v.as_str()).context("tiling missing 'axis'")?;
            let dim = t.get("dim").and_then(|v| v.as_usize()).context("tiling missing 'dim'")?;
            tilings.push((axis.to_string(), dim));
        }
        Ok(ShardSpec { name, tilings })
    }
}

/// The unified output of [`crate::session::Session::run`].
#[derive(Debug, Clone)]
pub struct PartitionPlan {
    /// Mesh axes as `(name, size)`, in mesh order.
    pub mesh_axes: Vec<(String, i64)>,
    pub input_specs: Vec<ShardSpec>,
    pub output_specs: Vec<ShardSpec>,
    pub eval: Evaluation,
    /// Explicit tile decisions (manual + search).
    pub decisions: usize,
    /// Episode at which search found its best solution (0 = no search).
    pub episodes_to_best: usize,
    /// Worklist size the search stage saw.
    pub worklist_size: usize,
    /// Decision targets after grouping (== worklist size when ungrouped).
    pub targets: usize,
    pub wall_seconds: f64,
    /// Human-readable record of every pipeline stage and decision.
    pub trace: Vec<String>,
}

impl PartitionPlan {
    /// Specs that actually shard something (convenience for reports).
    pub fn sharded_inputs(&self) -> impl Iterator<Item = &ShardSpec> {
        self.input_specs.iter().filter(|s| !s.replicated())
    }

    pub fn to_json(&self) -> Json {
        let specs = |xs: &[ShardSpec]| Json::Arr(xs.iter().map(|s| s.to_json()).collect());
        let c = &self.eval.collectives;
        let r = &self.eval.runtime;
        let mut eval_fields = vec![
            ("peak_memory_bytes", Json::num(self.eval.memory.peak_bytes as f64)),
            ("arg_bytes", Json::num(self.eval.memory.arg_bytes as f64)),
            ("peak_node", Json::num(self.eval.memory.peak_node as f64)),
            ("fits_memory", Json::Bool(self.eval.fits_memory)),
            ("cost", Json::Num(self.eval.cost)),
            ("all_reduces", Json::num(c.all_reduce_count as f64)),
            ("all_reduce_bytes", Json::num(c.all_reduce_bytes as f64)),
            ("all_gathers", Json::num(c.all_gather_count as f64)),
            ("all_gather_bytes", Json::num(c.all_gather_bytes as f64)),
            ("sends", Json::num(c.send_count as f64)),
            ("send_bytes", Json::num(c.send_bytes as f64)),
            ("recvs", Json::num(c.recv_count as f64)),
            ("recv_bytes", Json::num(c.recv_bytes as f64)),
            ("compute_seconds", Json::Num(r.compute_seconds)),
            ("memory_seconds", Json::Num(r.memory_seconds)),
            ("op_seconds", Json::Num(r.op_seconds)),
            ("collective_seconds", Json::Num(r.collective_seconds)),
            ("total_flops", Json::Num(r.total_flops)),
        ];
        if let Some(pe) = &self.eval.pipeline {
            eval_fields.push((
                "pipeline",
                Json::obj(vec![
                    ("stages", Json::num(pe.stages as f64)),
                    ("microbatches", Json::num(pe.microbatches as f64)),
                    (
                        "cuts",
                        Json::Arr(pe.cuts.iter().map(|&c| Json::num(c as f64)).collect()),
                    ),
                    ("bubble_fraction", Json::Num(pe.bubble_fraction)),
                    ("makespan_seconds", Json::Num(pe.makespan_seconds)),
                    ("send_recv_seconds", Json::Num(pe.send_recv_seconds)),
                    ("max_stage_peak_bytes", Json::num(pe.max_stage_peak_bytes as f64)),
                ]),
            ));
        }
        Json::obj(vec![
            (
                "mesh",
                Json::Arr(
                    self.mesh_axes
                        .iter()
                        .map(|(n, s)| {
                            Json::obj(vec![
                                ("axis", Json::str(n.clone())),
                                ("size", Json::num(*s as f64)),
                            ])
                        })
                        .collect(),
                ),
            ),
            ("inputs", specs(&self.input_specs)),
            ("outputs", specs(&self.output_specs)),
            ("eval", Json::obj(eval_fields)),
            ("decisions", Json::num(self.decisions as f64)),
            ("episodes_to_best", Json::num(self.episodes_to_best as f64)),
            ("worklist_size", Json::num(self.worklist_size as f64)),
            ("targets", Json::num(self.targets as f64)),
            ("wall_seconds", Json::Num(self.wall_seconds)),
            ("trace", Json::Arr(self.trace.iter().map(|t| Json::str(t.clone())).collect())),
        ])
    }

    pub fn from_json(j: &Json) -> Result<PartitionPlan> {
        let specs = |key: &str| -> Result<Vec<ShardSpec>> {
            j.get(key)
                .and_then(|v| v.as_arr())
                .ok_or_else(|| anyhow!("plan missing '{key}'"))?
                .iter()
                .map(ShardSpec::from_json)
                .collect()
        };
        let num = |obj: &Json, key: &str| -> Result<f64> {
            obj.get(key).and_then(|v| v.as_f64()).ok_or_else(|| anyhow!("plan missing '{key}'"))
        };
        // Lenient: plans written before the pipeline subsystem carry
        // neither point-to-point stats nor a "pipeline" object.
        let opt = |obj: &Json, key: &str| -> f64 {
            obj.get(key).and_then(|v| v.as_f64()).unwrap_or(0.0)
        };
        let e = j.get("eval").ok_or_else(|| anyhow!("plan missing 'eval'"))?;
        let pipeline = match e.get("pipeline") {
            None => None,
            Some(p) => {
                let cuts = p
                    .get("cuts")
                    .and_then(|v| v.as_arr())
                    .ok_or_else(|| anyhow!("pipeline eval missing 'cuts'"))?
                    .iter()
                    .map(|c| c.as_f64().map(|f| f as u32).context("bad pipeline cut"))
                    .collect::<Result<Vec<u32>>>()?;
                Some(PipelineEval {
                    stages: num(p, "stages")? as usize,
                    microbatches: num(p, "microbatches")? as usize,
                    cuts,
                    bubble_fraction: num(p, "bubble_fraction")?,
                    makespan_seconds: num(p, "makespan_seconds")?,
                    send_recv_seconds: num(p, "send_recv_seconds")?,
                    max_stage_peak_bytes: num(p, "max_stage_peak_bytes")? as i64,
                })
            }
        };
        let eval = Evaluation {
            memory: MemoryEstimate {
                peak_bytes: num(e, "peak_memory_bytes")? as i64,
                arg_bytes: num(e, "arg_bytes")? as i64,
                peak_node: num(e, "peak_node")? as usize,
            },
            runtime: RuntimeEstimate {
                compute_seconds: num(e, "compute_seconds")?,
                memory_seconds: num(e, "memory_seconds")?,
                op_seconds: num(e, "op_seconds")?,
                collective_seconds: num(e, "collective_seconds")?,
                total_flops: num(e, "total_flops")?,
            },
            collectives: CollectiveStats {
                all_reduce_count: num(e, "all_reduces")? as usize,
                all_reduce_bytes: num(e, "all_reduce_bytes")? as i64,
                all_gather_count: num(e, "all_gathers")? as usize,
                all_gather_bytes: num(e, "all_gather_bytes")? as i64,
                send_count: opt(e, "sends") as usize,
                send_bytes: opt(e, "send_bytes") as i64,
                recv_count: opt(e, "recvs") as usize,
                recv_bytes: opt(e, "recv_bytes") as i64,
            },
            fits_memory: e
                .get("fits_memory")
                .and_then(|v| v.as_bool())
                .ok_or_else(|| anyhow!("plan missing 'fits_memory'"))?,
            cost: num(e, "cost")?,
            pipeline,
        };
        let mut mesh_axes = Vec::new();
        let mesh_arr =
            j.get("mesh").and_then(|v| v.as_arr()).ok_or_else(|| anyhow!("plan missing 'mesh'"))?;
        for m in mesh_arr {
            let name = m.get("axis").and_then(|v| v.as_str()).context("mesh axis missing name")?;
            let size = m.get("size").and_then(|v| v.as_f64()).context("mesh axis missing size")?;
            mesh_axes.push((name.to_string(), size as i64));
        }
        let trace = j
            .get("trace")
            .and_then(|v| v.as_arr())
            .map(|a| a.iter().filter_map(|t| t.as_str().map(str::to_string)).collect())
            .unwrap_or_default();
        Ok(PartitionPlan {
            mesh_axes,
            input_specs: specs("inputs")?,
            output_specs: specs("outputs")?,
            eval,
            decisions: num(j, "decisions")? as usize,
            episodes_to_best: num(j, "episodes_to_best")? as usize,
            worklist_size: num(j, "worklist_size")? as usize,
            targets: num(j, "targets")? as usize,
            wall_seconds: num(j, "wall_seconds")?,
            trace,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::json::parse;

    fn sample_plan() -> PartitionPlan {
        PartitionPlan {
            mesh_axes: vec![("batch".into(), 2), ("model".into(), 4)],
            input_specs: vec![
                ShardSpec {
                    name: "tokens".into(),
                    tilings: vec![("batch".into(), 0)],
                },
                ShardSpec { name: "causal_mask".into(), tilings: vec![] },
                ShardSpec {
                    name: "layer_0/mlp/w1".into(),
                    tilings: vec![("model".into(), 1)],
                },
            ],
            output_specs: vec![ShardSpec {
                name: "output_0".into(),
                tilings: vec![("batch".into(), 0)],
            }],
            eval: Evaluation {
                memory: MemoryEstimate { peak_bytes: 123456789, arg_bytes: 1024, peak_node: 17 },
                runtime: RuntimeEstimate {
                    compute_seconds: 0.001,
                    memory_seconds: 0.0025,
                    op_seconds: 0.0025,
                    collective_seconds: 0.0005,
                    total_flops: 1.5e9,
                },
                collectives: CollectiveStats {
                    all_reduce_count: 8,
                    all_reduce_bytes: 4096,
                    all_gather_count: 1,
                    all_gather_bytes: 512,
                    send_count: 16,
                    send_bytes: 2048,
                    recv_count: 16,
                    recv_bytes: 2048,
                },
                fits_memory: true,
                cost: 0.0030000001,
                pipeline: Some(PipelineEval {
                    stages: 4,
                    microbatches: 8,
                    cuts: vec![3, 7, 11],
                    bubble_fraction: 0.2727272727,
                    makespan_seconds: 0.0041,
                    send_recv_seconds: 0.0002,
                    max_stage_peak_bytes: 98765432,
                }),
            },
            decisions: 7,
            episodes_to_best: 42,
            worklist_size: 25,
            targets: 23,
            wall_seconds: 1.25,
            trace: vec![
                "manual: axis \"batch\" excluded from search".into(),
                "search: tile layer_0/mlp/w1 dim 1 on \"model\"".into(),
            ],
        }
    }

    #[test]
    fn plan_json_round_trips_exactly() {
        let plan = sample_plan();
        let j = plan.to_json();
        // through the compact AND the pretty printer
        for text in [j.to_string(), j.pretty()] {
            let back = PartitionPlan::from_json(&parse(&text).unwrap()).unwrap();
            assert_eq!(back.mesh_axes, plan.mesh_axes);
            assert_eq!(back.input_specs, plan.input_specs);
            assert_eq!(back.output_specs, plan.output_specs);
            assert_eq!(back.decisions, plan.decisions);
            assert_eq!(back.episodes_to_best, plan.episodes_to_best);
            assert_eq!(back.worklist_size, plan.worklist_size);
            assert_eq!(back.targets, plan.targets);
            assert_eq!(back.wall_seconds, plan.wall_seconds);
            assert_eq!(back.trace, plan.trace);
            assert_eq!(back.eval.memory.peak_bytes, plan.eval.memory.peak_bytes);
            assert_eq!(back.eval.memory.arg_bytes, plan.eval.memory.arg_bytes);
            assert_eq!(back.eval.memory.peak_node, plan.eval.memory.peak_node);
            assert_eq!(back.eval.fits_memory, plan.eval.fits_memory);
            assert_eq!(back.eval.cost, plan.eval.cost);
            assert_eq!(back.eval.collectives, plan.eval.collectives);
            assert_eq!(back.eval.runtime.compute_seconds, plan.eval.runtime.compute_seconds);
            assert_eq!(back.eval.runtime.op_seconds, plan.eval.runtime.op_seconds);
            assert_eq!(
                back.eval.runtime.collective_seconds,
                plan.eval.runtime.collective_seconds
            );
            assert_eq!(back.eval.runtime.total_flops, plan.eval.runtime.total_flops);
            assert_eq!(back.eval.pipeline, plan.eval.pipeline);
        }
    }

    #[test]
    fn pre_pipeline_plans_still_parse() {
        // Drop the new keys to simulate a plan cached before the
        // pipeline subsystem existed.
        let j = sample_plan().to_json();
        let mut root = match j {
            Json::Obj(m) => m,
            _ => unreachable!(),
        };
        let mut e = match root.remove("eval").unwrap() {
            Json::Obj(m) => m,
            _ => unreachable!(),
        };
        for key in ["sends", "send_bytes", "recvs", "recv_bytes", "pipeline"] {
            e.remove(key);
        }
        root.insert("eval".to_string(), Json::Obj(e));
        let back = PartitionPlan::from_json(&Json::Obj(root)).unwrap();
        assert_eq!(back.eval.collectives.send_count, 0);
        assert_eq!(back.eval.collectives.recv_bytes, 0);
        assert!(back.eval.pipeline.is_none());
    }

    #[test]
    fn shard_spec_round_trips_and_queries() {
        let s = ShardSpec {
            name: "layer_3/attn/wq".into(),
            tilings: vec![("model".into(), 1), ("batch".into(), 0)],
        };
        let back = ShardSpec::from_json(&parse(&s.to_json().to_string()).unwrap()).unwrap();
        assert_eq!(back, s);
        assert!(s.tiled_on("model"));
        assert!(!s.tiled_on("expert"));
        assert!(!s.replicated());
        let r = ShardSpec { name: "mask".into(), tilings: vec![] };
        assert!(r.replicated());
        assert_eq!(ShardSpec::from_json(&parse(&r.to_json().to_string()).unwrap()).unwrap(), r);
    }

    #[test]
    fn from_json_rejects_malformed_plans() {
        assert!(PartitionPlan::from_json(&parse("{}").unwrap()).is_err());
        let j = sample_plan().to_json();
        let mut m = match j {
            Json::Obj(m) => m,
            _ => unreachable!(),
        };
        m.remove("eval");
        assert!(PartitionPlan::from_json(&Json::Obj(m)).is_err());
    }
}
