//! SPMD lowering of PartIR views: distributed types, collective
//! insertion, collective statistics, and the Fig-3-style printer.

pub mod collectives;
pub mod lower;
pub mod printer;

pub use collectives::{Collective, CollectiveKind, CollectiveStats};
pub use lower::{lower, SpmdProgram};
