//! Collective operations inserted by the SPMD lowering, and aggregate
//! statistics over them. The paper measures "achieving Megatron ...
//! through gathering statistics on collectives in the partitioned model"
//! (§3) — these stats are exactly that measurement.

use crate::partir::mesh::{AxisId, Mesh};

/// Kind of collective.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum CollectiveKind {
    /// Sum partial results across an axis (from tiled contractions).
    AllReduce,
    /// Replicate a tiled value across an axis (distribution mismatch).
    AllGather,
    /// Point-to-point send of a value to the next pipeline stage
    /// (DESIGN.md §11); priced `α + bytes/bw`, no ring factor.
    Send,
    /// Point-to-point receive from the previous pipeline stage.
    Recv,
}

/// One collective in the lowered SPMD program.
#[derive(Debug, Clone)]
pub struct Collective {
    pub kind: CollectiveKind,
    pub axis: AxisId,
    /// Node index in the base program this collective is attached to.
    pub node: usize,
    /// Per-device payload bytes (local shard size involved).
    pub bytes: i64,
}

/// Aggregate collective statistics.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct CollectiveStats {
    pub all_reduce_count: usize,
    pub all_reduce_bytes: i64,
    pub all_gather_count: usize,
    pub all_gather_bytes: i64,
    pub send_count: usize,
    pub send_bytes: i64,
    pub recv_count: usize,
    pub recv_bytes: i64,
}

impl CollectiveStats {
    pub fn from_collectives(cs: &[Collective]) -> CollectiveStats {
        let mut s = CollectiveStats::default();
        for c in cs {
            s.add(c.kind, c.bytes);
        }
        s
    }

    /// Fold one collective into the aggregate. Counts and bytes are
    /// integers, so accumulation order cannot change the result — the
    /// per-node cost ledger relies on this when it re-aggregates cached
    /// node stats.
    #[inline]
    pub fn add(&mut self, kind: CollectiveKind, bytes: i64) {
        match kind {
            CollectiveKind::AllReduce => {
                self.all_reduce_count += 1;
                self.all_reduce_bytes += bytes;
            }
            CollectiveKind::AllGather => {
                self.all_gather_count += 1;
                self.all_gather_bytes += bytes;
            }
            CollectiveKind::Send => {
                self.send_count += 1;
                self.send_bytes += bytes;
            }
            CollectiveKind::Recv => {
                self.recv_count += 1;
                self.recv_bytes += bytes;
            }
        }
    }

    pub fn total_count(&self) -> usize {
        self.all_reduce_count + self.all_gather_count + self.send_count + self.recv_count
    }
    pub fn total_bytes(&self) -> i64 {
        self.all_reduce_bytes + self.all_gather_bytes + self.send_bytes + self.recv_bytes
    }
}

/// α-β cost of one collective on `mesh` (seconds). Ring formulas for
/// the axis-wide collectives; point-to-point `α + bytes/bw` for
/// send/recv (one hop, independent of the axis size — the axis only
/// records which mesh dimension the stages are laid out over).
pub fn collective_seconds(c: &Collective, mesh: &Mesh, link_bw: f64, alpha: f64) -> f64 {
    let bytes = c.bytes as f64;
    if matches!(c.kind, CollectiveKind::Send | CollectiveKind::Recv) {
        return alpha + bytes / link_bw;
    }
    let n = mesh.size(c.axis) as f64;
    if n <= 1.0 {
        return 0.0;
    }
    match c.kind {
        // ring all-reduce: 2(n-1)/n * payload over the link + latency hops
        CollectiveKind::AllReduce => 2.0 * (n - 1.0) / n * bytes / link_bw + (n - 1.0) * alpha,
        // ring all-gather: (n-1)/n * full payload
        CollectiveKind::AllGather => (n - 1.0) / n * bytes / link_bw + (n - 1.0) * alpha,
        CollectiveKind::Send | CollectiveKind::Recv => unreachable!("handled above"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_aggregate() {
        let cs = vec![
            Collective { kind: CollectiveKind::AllReduce, axis: AxisId(0), node: 0, bytes: 100 },
            Collective { kind: CollectiveKind::AllReduce, axis: AxisId(0), node: 1, bytes: 50 },
            Collective { kind: CollectiveKind::AllGather, axis: AxisId(0), node: 2, bytes: 10 },
        ];
        let s = CollectiveStats::from_collectives(&cs);
        assert_eq!(s.all_reduce_count, 2);
        assert_eq!(s.all_reduce_bytes, 150);
        assert_eq!(s.all_gather_count, 1);
        assert_eq!(s.total_count(), 3);
        assert_eq!(s.total_bytes(), 160);
    }

    #[test]
    fn ring_cost_scales_with_axis_size() {
        let mesh = Mesh::new(&[("m", 4)]);
        let c = Collective {
            kind: CollectiveKind::AllReduce,
            axis: AxisId(0),
            node: 0,
            bytes: 1_000_000_000,
        };
        let t = collective_seconds(&c, &mesh, 70e9, 1e-6);
        // 2 * 3/4 * 1GB / 70GB/s ~ 21.4ms
        assert!((t - (1.5 * 1e9 / 70e9 + 3e-6)).abs() < 1e-9);
        let mesh1 = Mesh::new(&[("m", 1)]);
        let c1 = Collective { axis: AxisId(0), ..c };
        assert_eq!(collective_seconds(&c1, &mesh1, 70e9, 1e-6), 0.0);
    }

    #[test]
    fn send_recv_are_point_to_point() {
        let mesh = Mesh::new(&[("pipe", 4)]);
        for kind in [CollectiveKind::Send, CollectiveKind::Recv] {
            let c = Collective { kind, axis: AxisId(0), node: 0, bytes: 70_000 };
            let t = collective_seconds(&c, &mesh, 70e9, 1e-6);
            // α + bytes/bw, no (n-1) ring factor.
            assert!((t - (1e-6 + 70_000.0 / 70e9)).abs() < 1e-15, "{t}");
            // Point-to-point cost does not vanish on a size-1 axis.
            let mesh1 = Mesh::new(&[("pipe", 1)]);
            assert!(collective_seconds(&c, &mesh1, 70e9, 1e-6) > 0.0);
        }
        let mut s = CollectiveStats::default();
        s.add(CollectiveKind::Send, 128);
        s.add(CollectiveKind::Recv, 128);
        assert_eq!((s.send_count, s.send_bytes, s.recv_count, s.recv_bytes), (1, 128, 1, 128));
        assert_eq!(s.total_count(), 2);
        assert_eq!(s.total_bytes(), 256);
    }
}
