//! Collective operations inserted by the SPMD lowering, and aggregate
//! statistics over them. The paper measures "achieving Megatron ...
//! through gathering statistics on collectives in the partitioned model"
//! (§3) — these stats are exactly that measurement.

use crate::partir::mesh::{AxisId, Mesh};

/// Kind of collective.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum CollectiveKind {
    /// Sum partial results across an axis (from tiled contractions).
    AllReduce,
    /// Replicate a tiled value across an axis (distribution mismatch).
    AllGather,
}

/// One collective in the lowered SPMD program.
#[derive(Debug, Clone)]
pub struct Collective {
    pub kind: CollectiveKind,
    pub axis: AxisId,
    /// Node index in the base program this collective is attached to.
    pub node: usize,
    /// Per-device payload bytes (local shard size involved).
    pub bytes: i64,
}

/// Aggregate collective statistics.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct CollectiveStats {
    pub all_reduce_count: usize,
    pub all_reduce_bytes: i64,
    pub all_gather_count: usize,
    pub all_gather_bytes: i64,
}

impl CollectiveStats {
    pub fn from_collectives(cs: &[Collective]) -> CollectiveStats {
        let mut s = CollectiveStats::default();
        for c in cs {
            s.add(c.kind, c.bytes);
        }
        s
    }

    /// Fold one collective into the aggregate. Counts and bytes are
    /// integers, so accumulation order cannot change the result — the
    /// per-node cost ledger relies on this when it re-aggregates cached
    /// node stats.
    #[inline]
    pub fn add(&mut self, kind: CollectiveKind, bytes: i64) {
        match kind {
            CollectiveKind::AllReduce => {
                self.all_reduce_count += 1;
                self.all_reduce_bytes += bytes;
            }
            CollectiveKind::AllGather => {
                self.all_gather_count += 1;
                self.all_gather_bytes += bytes;
            }
        }
    }

    pub fn total_count(&self) -> usize {
        self.all_reduce_count + self.all_gather_count
    }
    pub fn total_bytes(&self) -> i64 {
        self.all_reduce_bytes + self.all_gather_bytes
    }
}

/// α-β ring cost of one collective on `mesh` (seconds).
pub fn collective_seconds(c: &Collective, mesh: &Mesh, link_bw: f64, alpha: f64) -> f64 {
    let n = mesh.size(c.axis) as f64;
    if n <= 1.0 {
        return 0.0;
    }
    let bytes = c.bytes as f64;
    match c.kind {
        // ring all-reduce: 2(n-1)/n * payload over the link + latency hops
        CollectiveKind::AllReduce => 2.0 * (n - 1.0) / n * bytes / link_bw + (n - 1.0) * alpha,
        // ring all-gather: (n-1)/n * full payload
        CollectiveKind::AllGather => (n - 1.0) / n * bytes / link_bw + (n - 1.0) * alpha,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_aggregate() {
        let cs = vec![
            Collective { kind: CollectiveKind::AllReduce, axis: AxisId(0), node: 0, bytes: 100 },
            Collective { kind: CollectiveKind::AllReduce, axis: AxisId(0), node: 1, bytes: 50 },
            Collective { kind: CollectiveKind::AllGather, axis: AxisId(0), node: 2, bytes: 10 },
        ];
        let s = CollectiveStats::from_collectives(&cs);
        assert_eq!(s.all_reduce_count, 2);
        assert_eq!(s.all_reduce_bytes, 150);
        assert_eq!(s.all_gather_count, 1);
        assert_eq!(s.total_count(), 3);
        assert_eq!(s.total_bytes(), 160);
    }

    #[test]
    fn ring_cost_scales_with_axis_size() {
        let mesh = Mesh::new(&[("m", 4)]);
        let c = Collective {
            kind: CollectiveKind::AllReduce,
            axis: AxisId(0),
            node: 0,
            bytes: 1_000_000_000,
        };
        let t = collective_seconds(&c, &mesh, 70e9, 1e-6);
        // 2 * 3/4 * 1GB / 70GB/s ~ 21.4ms
        assert!((t - (1.5 * 1e9 / 70e9 + 3e-6)).abs() < 1e-9);
        let mesh1 = Mesh::new(&[("m", 1)]);
        let c1 = Collective { axis: AxisId(0), ..c };
        assert_eq!(collective_seconds(&c1, &mesh1, 70e9, 1e-6), 0.0);
    }
}
