//! SPMD-dialect printer in the notation of the paper's Figure 3:
//! distributed tensor types like `f32[16, 64{"shard"}]` and explicit
//! collectives.

use super::lower::SpmdProgram;
use crate::spmd::collectives::CollectiveKind;
use std::fmt::Write;

pub fn print_spmd(p: &SpmdProgram) -> String {
    let f = p.func;
    let mut s = String::new();
    write!(s, "spmd.func @{}(", f.name).unwrap();
    for (i, a) in f.args.iter().enumerate() {
        if i > 0 {
            s.push_str(", ");
        }
        let t = p.dm.render_type(i, &a.ty.dims, p.mesh, a.ty.dtype.name());
        write!(s, "%arg{i}: {t}").unwrap();
    }
    writeln!(s, ") {{  // mesh {}", p.mesh.describe()).unwrap();
    for (ni, node) in f.nodes.iter().enumerate() {
        let v = f.num_args() + ni;
        let ins: Vec<String> = node
            .inputs
            .iter()
            .map(|&x| match f.node_of(x) {
                None => format!("%arg{}", x.index()),
                Some(n) => format!("%{n}"),
            })
            .collect();
        // Collectives attached to this node print before it.
        for c in p.collectives.iter().filter(|c| c.node == ni) {
            let kind = match c.kind {
                CollectiveKind::AllReduce => "spmd.all_reduce",
                CollectiveKind::AllGather => "spmd.all_gather",
                CollectiveKind::Send => "spmd.send",
                CollectiveKind::Recv => "spmd.recv",
            };
            writeln!(
                s,
                "  {kind} \"{}\" {{bytes = {}}}",
                p.mesh.name(c.axis),
                c.bytes
            )
            .unwrap();
        }
        let t = p.dm.render_type(v, &node.ty.dims, p.mesh, node.ty.dtype.name());
        writeln!(s, "  %{ni} = {} {} : {t}", node.op.name(), ins.join(", ")).unwrap();
    }
    s.push_str("}\n");
    s
}

#[cfg(test)]
mod tests {
    use crate::ir::{ArgKind, GraphBuilder, TensorType, ValueId};
    use crate::partir::actions::{Action, DecisionState};
    use crate::partir::mesh::{AxisId, Mesh};
    use crate::partir::program::PartirProgram;
    use crate::spmd::lower::lower;

    #[test]
    fn prints_figure3_style() {
        let mut b = GraphBuilder::new("main");
        let x = b.arg("x", TensorType::f32(&[8, 16]), ArgKind::Input);
        let w = b.arg("w", TensorType::f32(&[16, 64]), ArgKind::Parameter);
        let y = b.matmul(x, w);
        b.output(y);
        let p = PartirProgram::new(b.finish(), Mesh::new(&[("shard", 2)]));
        let st = DecisionState {
            actions: vec![
                Action::Tile { v: ValueId(0), dim: 1, axis: AxisId(0) },
                Action::Tile { v: ValueId(1), dim: 0, axis: AxisId(0) },
            ],
            atomic: Default::default(),
        };
        let (dm, _) = p.apply(&st);
        let sp = lower(&p.func, &p.mesh, &p.prop, &dm);
        let txt = super::print_spmd(&sp);
        assert!(txt.contains("f32[16, 64{\"shard\"}]") || txt.contains("f32[8, 16{\"shard\"}]"));
        assert!(txt.contains("spmd.all_reduce \"shard\""));
    }
}
