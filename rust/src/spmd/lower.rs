//! Lowering of a PartIR view (program + DistMap) to an SPMD program:
//! per-device local shapes plus the collectives required to make every
//! node's operands consistent with its result distribution (paper §2.1:
//! "the tiling loops in our IR lower to a dialect suitable for expressing
//! SPMD computations ... optimising data transfers and reasoning about
//! cost happens at this level").
//!
//! Insertion rules per node, per mesh axis `a`:
//!   * contraction group fully tiled on `a`   → partial sums → ALL-REDUCE
//!     of the result across `a`;
//!   * contraction group partially tiled      → ALL-GATHER the tiled
//!     operands (mismatch repair);
//!   * operand tiled at a dim tied to a result dim that is tiled the same
//!     way → free (compatible slicing);
//!   * operand tiled any other way            → ALL-GATHER it;
//!   * operand replicated where a slice is needed → free (`partir.slice`
//!     of a replicated value costs nothing).

use super::collectives::{Collective, CollectiveKind};
use crate::ir::Func;
use crate::partir::dist::DistMap;
use crate::partir::mesh::{AxisId, Mesh};
use crate::partir::propagate::Propagator;

/// A lowered SPMD program: the base function plus its distribution map
/// and the inserted collectives.
pub struct SpmdProgram<'a> {
    pub func: &'a Func,
    pub mesh: &'a Mesh,
    pub dm: &'a DistMap,
    pub prop: &'a Propagator,
    pub collectives: Vec<Collective>,
}

/// Lower `f` under distribution `dm`, returning the collectives.
/// `prop` supplies the precomputed per-node dimension rules.
pub fn lower<'a>(
    f: &'a Func,
    mesh: &'a Mesh,
    prop: &'a Propagator,
    dm: &'a DistMap,
) -> SpmdProgram<'a> {
    let mut collectives = Vec::new();
    let mut justified: Vec<(usize, usize)> = Vec::new();
    for ni in 0..f.num_nodes() {
        lower_node_into(f, mesh, prop, dm, ni, &mut justified, &mut collectives);
    }
    SpmdProgram { func: f, mesh, dm, prop, collectives }
}

/// Lower ONE node: append the collectives node `ni` requires under `dm`
/// to `out`, in the same order the full [`lower`] pass emits them (per
/// axis: the all-reduce first, then the gathers). A node's collectives
/// are a pure function of the distribution rows of its operands and its
/// result, which is what lets the cost ledger
/// ([`crate::cost::composite::CostLedger`]) cache them per node and
/// re-lower only nodes whose rows changed. `justified` is caller-owned
/// scratch (cleared per axis) so the hot path allocates nothing.
pub fn lower_node_into(
    f: &Func,
    mesh: &Mesh,
    prop: &Propagator,
    dm: &DistMap,
    ni: usize,
    justified: &mut Vec<(usize, usize)>,
    out: &mut Vec<Collective>,
) {
    let node = &f.nodes[ni];
    let rule = &prop.rules[ni];
    let out_v = f.num_args() + ni;
    for a in 0..mesh.num_axes() {
        let axis = AxisId(a);
        let n = mesh.size(axis);
        if n == 1 {
            continue;
        }
        // Track which operand tilings are justified on this axis.
        // (operand_slot, dim) pairs that participate in a full
        // contraction or match the result tiling are free.
        justified.clear();

        // 1. Contractions.
        let mut all_reduce_emitted = false;
        for group in &rule.reduced_ties {
            let tiled: Vec<&(usize, usize)> = group
                .iter()
                .filter(|&&(oi, od)| dm.d[node.inputs[oi].index()][a] == od as u8)
                .collect();
            if tiled.is_empty() {
                continue;
            }
            if tiled.len() == group.len() {
                // Fully tiled contraction: result is a partial sum.
                justified.extend(group.iter().copied());
                if !all_reduce_emitted && dm.get(out_v, axis).is_none() {
                    out.push(Collective {
                        kind: CollectiveKind::AllReduce,
                        axis,
                        node: ni,
                        bytes: dm.local_bytes(out_v, prop.global_bytes[out_v], mesh),
                    });
                    all_reduce_emitted = true;
                }
                // If the result is ALSO tiled on this axis (explicit
                // internal decision), the partial-sum shards do not
                // line up: fall through to gathering below by not
                // justifying. Revert in that case.
                if dm.get(out_v, axis).is_some() {
                    for g in group {
                        justified.retain(|j| j != g);
                    }
                }
            }
            // Partially tiled groups: tiled members stay unjustified
            // and will be gathered below.
        }

        // 2. Result-compatible tilings.
        if let Some(od) = dm.get(out_v, axis) {
            if od < rule.out_ties.len() {
                for &(oi, idim) in &rule.out_ties[od] {
                    if dm.d[node.inputs[oi].index()][a] == idim as u8 {
                        justified.push((oi, idim));
                    }
                }
            }
        }

        // 3. Gather every remaining tiled operand.
        for (oi, &iv) in node.inputs.iter().enumerate() {
            let ivx = iv.index();
            if let Some(idim) = dm.get(ivx, axis) {
                if !justified.contains(&(oi, idim)) {
                    let local = dm.local_bytes(ivx, prop.global_bytes[ivx], mesh);
                    out.push(Collective {
                        kind: CollectiveKind::AllGather,
                        axis,
                        node: ni,
                        // global payload on the gathered axis
                        bytes: local * n,
                    });
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::{ArgKind, GraphBuilder, TensorType, ValueId};
    use crate::partir::actions::{Action, DecisionState};
    use crate::partir::program::PartirProgram;
    use crate::spmd::collectives::CollectiveStats;

    fn linear(mesh: Mesh) -> PartirProgram {
        let mut b = GraphBuilder::new("main");
        let x = b.arg("x", TensorType::f32(&[8, 16]), ArgKind::Input);
        let w = b.arg("w", TensorType::f32(&[16, 64]), ArgKind::Parameter);
        let bias = b.arg("b", TensorType::f32(&[64]), ArgKind::Parameter);
        let dot = b.matmul(x, w);
        let ty = b.ty(dot).clone();
        let bb = b.broadcast_to(bias, ty);
        let out = b.add(dot, bb);
        b.output(out);
        PartirProgram::new(b.finish(), mesh)
    }

    fn stats_for(p: &PartirProgram, actions: Vec<Action>) -> CollectiveStats {
        let st = DecisionState { actions, atomic: Default::default() };
        let (dm, _) = p.apply(&st);
        let s = lower(&p.func, &p.mesh, &p.prop, &dm);
        CollectiveStats::from_collectives(&s.collectives)
    }

    #[test]
    fn column_sharding_needs_no_collectives() {
        // Fig 2: tile w on output dim -> everything slices, zero comm.
        let p = linear(Mesh::new(&[("shard", 2)]));
        let s = stats_for(
            &p,
            vec![Action::Tile { v: ValueId(1), dim: 1, axis: AxisId(0) }],
        );
        assert_eq!(s.total_count(), 0);
    }

    #[test]
    fn row_sharding_one_sided_gathers() {
        // Tile w on contraction dim only: x not tiled -> gather w.
        let p = linear(Mesh::new(&[("shard", 2)]));
        let s = stats_for(
            &p,
            vec![Action::Tile { v: ValueId(1), dim: 0, axis: AxisId(0) }],
        );
        assert_eq!(s.all_gather_count, 1);
        assert_eq!(s.all_reduce_count, 0);
        // gathered payload = full w
        assert_eq!(s.all_gather_bytes, 16 * 64 * 4);
    }

    #[test]
    fn row_sharding_two_sided_all_reduces() {
        // Tile both sides of the contraction: partial sums -> 1 all-reduce.
        let p = linear(Mesh::new(&[("shard", 2)]));
        let s = stats_for(
            &p,
            vec![
                Action::Tile { v: ValueId(0), dim: 1, axis: AxisId(0) },
                Action::Tile { v: ValueId(1), dim: 0, axis: AxisId(0) },
            ],
        );
        assert_eq!(s.all_reduce_count, 1);
        assert_eq!(s.all_gather_count, 0);
        // payload = result bytes (8x64 f32)
        assert_eq!(s.all_reduce_bytes, 8 * 64 * 4);
    }

    #[test]
    fn batch_parallelism_is_free() {
        let p = linear(Mesh::new(&[("batch", 2)]));
        let s = stats_for(
            &p,
            vec![Action::Tile { v: ValueId(0), dim: 0, axis: AxisId(0) }],
        );
        assert_eq!(s.total_count(), 0);
    }

    #[test]
    fn megatron_two_layer_mlp_single_allreduce() {
        // h = gelu(x @ w1); y = h @ w2 with w1 col-sharded, w2 row-sharded:
        // exactly ONE all-reduce (the Megatron MLP pattern).
        let mut b = GraphBuilder::new("mlp");
        let x = b.arg("x", TensorType::f32(&[8, 32]), ArgKind::Input);
        let w1 = b.arg("w1", TensorType::f32(&[32, 128]), ArgKind::Parameter);
        let w2 = b.arg("w2", TensorType::f32(&[128, 32]), ArgKind::Parameter);
        let h = b.matmul(x, w1);
        let g = b.gelu(h);
        let y = b.matmul(g, w2);
        b.output(y);
        let p = PartirProgram::new(b.finish(), Mesh::new(&[("model", 4)]));
        let s = stats_for(
            &p,
            vec![
                Action::Tile { v: ValueId(1), dim: 1, axis: AxisId(0) },
                Action::Tile { v: ValueId(2), dim: 0, axis: AxisId(0) },
            ],
        );
        assert_eq!(s.all_reduce_count, 1, "Megatron MLP = exactly one all-reduce");
        assert_eq!(s.all_gather_count, 0);
    }
}
