//! SSA graph representation of a function in the base tensor dialect.
//!
//! Values are densely numbered: ids `0..num_args` are function arguments,
//! ids `num_args..` are node results (one result per node). Nodes are
//! stored in topological order by construction (the builder only lets a
//! node reference already-created values), which lets every analysis be a
//! single forward or backward sweep.

use super::op::OpKind;
use super::types::TensorType;

/// Dense value id: argument or node result.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct ValueId(pub u32);

impl ValueId {
    pub fn index(self) -> usize {
        self.0 as usize
    }
}

/// Interned named-scope id (Haiku-style module paths, e.g.
/// `"transformer/layer_3/attn"`). Scope 0 is the root `""`.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct ScopeId(pub u32);

pub const ROOT_SCOPE: ScopeId = ScopeId(0);

/// What role a function argument plays — the worklist and the featurizer
/// both key off this (paper §2.3: "weights and biases, optimiser state,
/// and model inputs").
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum ArgKind {
    /// Trainable parameter.
    Parameter,
    /// Optimiser state (Adam moments, step counter).
    OptState,
    /// Model input (tokens, targets, graph features...).
    Input,
    /// Non-trainable constant passed in (masks, scales).
    Constant,
}

impl ArgKind {
    pub fn name(&self) -> &'static str {
        match self {
            ArgKind::Parameter => "param",
            ArgKind::OptState => "opt_state",
            ArgKind::Input => "input",
            ArgKind::Constant => "const",
        }
    }
    pub fn kind_id(&self) -> usize {
        match self {
            ArgKind::Parameter => 0,
            ArgKind::OptState => 1,
            ArgKind::Input => 2,
            ArgKind::Constant => 3,
        }
    }
    pub const NUM_KINDS: usize = 4;
}

/// A function argument.
#[derive(Debug, Clone)]
pub struct Arg {
    pub name: String,
    pub ty: TensorType,
    pub kind: ArgKind,
    pub scope: ScopeId,
}

/// A node: one operation producing one value.
#[derive(Debug, Clone)]
pub struct Node {
    pub op: OpKind,
    pub inputs: Vec<ValueId>,
    pub ty: TensorType,
    pub scope: ScopeId,
}

/// A function: the unit the partitioner operates on (the paper partitions
/// the whole training-update function).
#[derive(Debug, Clone)]
pub struct Func {
    pub name: String,
    pub args: Vec<Arg>,
    pub nodes: Vec<Node>,
    pub outputs: Vec<ValueId>,
    /// Interned scope path strings; index = ScopeId.0.
    pub scopes: Vec<String>,
}

/// Structural equality: two functions are equal when they have the same
/// name, arguments (name, type, kind, scope *path*), nodes (op, inputs,
/// type, scope *path*), and outputs. The scope intern tables themselves
/// are representation detail — interning order and unreferenced entries
/// do not affect equality — which is what makes `parse(print(f)) == f`
/// well-defined for the textual round-trip (`ir::parser`).
///
/// `Const` values compare by bit pattern with all NaNs identified
/// (float `==` would make any NaN-bearing program unequal to itself,
/// breaking the round-trip contract; the printer collapses NaN payloads
/// to the canonical `NaN` anyway). `-0.0` and `0.0` stay distinct, as
/// they do textually.
fn op_eq(a: &OpKind, b: &OpKind) -> bool {
    match (a, b) {
        (OpKind::Const { value: x }, OpKind::Const { value: y }) => {
            x.to_bits() == y.to_bits() || (x.is_nan() && y.is_nan())
        }
        _ => a == b,
    }
}

impl PartialEq for Func {
    fn eq(&self, other: &Func) -> bool {
        self.name == other.name
            && self.outputs == other.outputs
            && self.args.len() == other.args.len()
            && self.args.iter().zip(&other.args).all(|(a, b)| {
                a.name == b.name
                    && a.ty == b.ty
                    && a.kind == b.kind
                    && self.scope_path(a.scope) == other.scope_path(b.scope)
            })
            && self.nodes.len() == other.nodes.len()
            && self.nodes.iter().zip(&other.nodes).all(|(a, b)| {
                op_eq(&a.op, &b.op)
                    && a.inputs == b.inputs
                    && a.ty == b.ty
                    && self.scope_path(a.scope) == other.scope_path(b.scope)
            })
    }
}

impl Func {
    pub fn new(name: impl Into<String>) -> Func {
        Func {
            name: name.into(),
            args: Vec::new(),
            nodes: Vec::new(),
            outputs: Vec::new(),
            scopes: vec![String::new()],
        }
    }

    pub fn num_args(&self) -> usize {
        self.args.len()
    }
    pub fn num_nodes(&self) -> usize {
        self.nodes.len()
    }
    pub fn num_values(&self) -> usize {
        self.args.len() + self.nodes.len()
    }

    pub fn is_arg(&self, v: ValueId) -> bool {
        v.index() < self.args.len()
    }

    /// Node index for a node-result value (None for arguments).
    pub fn node_of(&self, v: ValueId) -> Option<usize> {
        v.index().checked_sub(self.args.len())
    }

    pub fn value_of_node(&self, node_idx: usize) -> ValueId {
        ValueId((self.args.len() + node_idx) as u32)
    }

    pub fn value_type(&self, v: ValueId) -> &TensorType {
        match self.node_of(v) {
            None => &self.args[v.index()].ty,
            Some(n) => &self.nodes[n].ty,
        }
    }

    pub fn value_scope(&self, v: ValueId) -> ScopeId {
        match self.node_of(v) {
            None => self.args[v.index()].scope,
            Some(n) => self.nodes[n].scope,
        }
    }

    /// Human-readable name for a value (`%argN:name` or `%N`).
    pub fn value_name(&self, v: ValueId) -> String {
        match self.node_of(v) {
            None => format!("%arg{}:{}", v.index(), self.args[v.index()].name),
            Some(n) => format!("%{n}"),
        }
    }

    /// Intern a scope path string.
    pub fn intern_scope(&mut self, path: &str) -> ScopeId {
        if let Some(i) = self.scopes.iter().position(|s| s == path) {
            return ScopeId(i as u32);
        }
        self.scopes.push(path.to_string());
        ScopeId((self.scopes.len() - 1) as u32)
    }

    pub fn scope_path(&self, s: ScopeId) -> &str {
        &self.scopes[s.0 as usize]
    }

    /// Use lists: for every value, indices of the nodes consuming it
    /// (duplicates kept if a node uses a value twice).
    pub fn users(&self) -> Vec<Vec<usize>> {
        let mut users = vec![Vec::new(); self.num_values()];
        for (ni, node) in self.nodes.iter().enumerate() {
            for &inp in &node.inputs {
                users[inp.index()].push(ni);
            }
        }
        users
    }

    /// Total bytes of all argument tensors (one replicated copy each).
    pub fn arg_bytes(&self) -> i64 {
        self.args.iter().map(|a| a.ty.byte_size()).sum()
    }

    /// Count arguments by kind.
    pub fn count_args(&self, kind: ArgKind) -> usize {
        self.args.iter().filter(|a| a.kind == kind).count()
    }

    /// Node indices reachable backwards from the outputs (live set).
    pub fn live_nodes(&self) -> Vec<bool> {
        let mut live = vec![false; self.num_nodes()];
        let mut stack: Vec<usize> =
            self.outputs.iter().filter_map(|&o| self.node_of(o)).collect();
        while let Some(n) = stack.pop() {
            if live[n] {
                continue;
            }
            live[n] = true;
            for &inp in &self.nodes[n].inputs {
                if let Some(m) = self.node_of(inp) {
                    if !live[m] {
                        stack.push(m);
                    }
                }
            }
        }
        live
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::types::DType;

    fn tiny() -> Func {
        let mut f = Func::new("t");
        f.args.push(Arg {
            name: "x".into(),
            ty: TensorType::f32(&[4]),
            kind: ArgKind::Input,
            scope: ROOT_SCOPE,
        });
        f.nodes.push(Node {
            op: OpKind::Neg,
            inputs: vec![ValueId(0)],
            ty: TensorType::f32(&[4]),
            scope: ROOT_SCOPE,
        });
        f.outputs.push(ValueId(1));
        f
    }

    #[test]
    fn value_indexing() {
        let f = tiny();
        assert!(f.is_arg(ValueId(0)));
        assert!(!f.is_arg(ValueId(1)));
        assert_eq!(f.node_of(ValueId(1)), Some(0));
        assert_eq!(f.value_of_node(0), ValueId(1));
        assert_eq!(f.value_type(ValueId(1)).dims, vec![4]);
        assert_eq!(f.num_values(), 2);
    }

    #[test]
    fn users_and_liveness() {
        let f = tiny();
        let users = f.users();
        assert_eq!(users[0], vec![0]);
        assert!(users[1].is_empty());
        assert_eq!(f.live_nodes(), vec![true]);
    }

    #[test]
    fn scope_interning() {
        let mut f = Func::new("t");
        let a = f.intern_scope("layer_0/attn");
        let b = f.intern_scope("layer_0/attn");
        let c = f.intern_scope("layer_1/attn");
        assert_eq!(a, b);
        assert_ne!(a, c);
        assert_eq!(f.scope_path(a), "layer_0/attn");
        assert_eq!(f.scope_path(ROOT_SCOPE), "");
    }

    #[test]
    fn arg_kinds() {
        assert_eq!(ArgKind::Parameter.kind_id(), 0);
        assert_eq!(DType::F32.bytes(), 4);
    }

    #[test]
    fn structural_equality_ignores_scope_interning_order() {
        let mut a = tiny();
        let mut b = tiny();
        // Interning extra (unreferenced) scopes, or the same referenced
        // path at different table indices, must not break equality.
        a.intern_scope("unused/extra");
        a.intern_scope("unused/extra2");
        let sa = a.intern_scope("layer_0");
        b.intern_scope("layer_0/other_first");
        let sb = b.intern_scope("layer_0");
        a.nodes[0].scope = sa;
        b.nodes[0].scope = sb;
        assert_ne!(a.nodes[0].scope, b.nodes[0].scope, "intern ids really differ");
        assert_eq!(a, b, "equality is over scope paths, not intern ids");
        // ...while a genuinely different path does break it.
        b.nodes[0].scope = ROOT_SCOPE;
        assert_ne!(a, b);
        // And so does any structural difference.
        let mut c = tiny();
        c.name = "other".into();
        assert_ne!(tiny(), c);
    }
}
