//! Operator set of the base tensor dialect. This is the subset of
//! HLO/MHLO needed to express full training graphs (fwd + bwd + Adam) for
//! the paper's evaluation models (transformer, MLP, GraphNet), chosen so
//! that every op has a total VJP rule in `autodiff.rs` and a declarative
//! partitioning rule in `partir::registry`.

use std::fmt;

/// Comparison direction for `Compare`.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum CmpDir {
    Lt,
    Le,
    Gt,
    Ge,
    Eq,
    Ne,
}

/// Reduction kind for `Reduce`.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum ReduceKind {
    Sum,
    Max,
}

/// Dimension numbers for a general dot product (dot_general).
/// Result dims are ordered: batch dims, then lhs free dims, then rhs free dims.
#[derive(Debug, Clone, PartialEq, Eq, Hash, Default)]
pub struct DotDims {
    pub lhs_batch: Vec<usize>,
    pub rhs_batch: Vec<usize>,
    pub lhs_contract: Vec<usize>,
    pub rhs_contract: Vec<usize>,
}

impl DotDims {
    /// Plain matmul: contract last dim of lhs with first dim of rhs.
    pub fn matmul(lhs_rank: usize) -> DotDims {
        DotDims {
            lhs_batch: vec![],
            rhs_batch: vec![],
            lhs_contract: vec![lhs_rank - 1],
            rhs_contract: vec![0],
        }
    }
    pub fn free_dims(&self, rank: usize, batch: &[usize], contract: &[usize]) -> Vec<usize> {
        (0..rank).filter(|d| !batch.contains(d) && !contract.contains(d)).collect()
    }
}

/// Operator kind (with attributes inlined).
#[derive(Debug, Clone, PartialEq)]
pub enum OpKind {
    /// Splat constant of the node's type.
    Const { value: f64 },
    /// `iota` along `dim` (i32 or f32 output).
    Iota { dim: usize },

    // Elementwise binary (operands must have identical shapes).
    Add,
    Sub,
    Mul,
    Div,
    Max,
    Min,

    // Elementwise unary.
    Neg,
    Exp,
    Log,
    Tanh,
    Rsqrt,
    Sqrt,
    Abs,

    /// Elementwise comparison; Bool output.
    Compare { dir: CmpDir },
    /// `(pred: bool, on_true, on_false)`.
    Select,
    /// Elementwise dtype cast to the node's type.
    Convert,

    /// General dot product.
    Dot(DotDims),
    /// Reduction over `dims` (kept dims removed from the shape).
    Reduce { kind: ReduceKind, dims: Vec<usize> },
    /// `broadcast_in_dim`: operand dim `i` maps to result dim `dims[i]`.
    Broadcast { dims: Vec<usize> },
    /// Reshape to the node's type (same element count).
    Reshape,
    /// Transpose with permutation `perm` (result dim i = operand dim perm[i]).
    Transpose { perm: Vec<usize> },

    /// `(table [V, ...], indices i32 [..I])` → `[..I, ...]`: row lookup
    /// along table dim 0 (embedding lookup).
    Gather,
    /// `(data [E, ...], ids i32 [E])` → `[num, ...]`: scatter-add rows of
    /// `data` into `num` segments (embedding grad / GraphNet aggregation).
    SegmentSum { num: i64 },
}

impl OpKind {
    /// Mnemonic used by printers and featurization.
    pub fn name(&self) -> &'static str {
        match self {
            OpKind::Const { .. } => "const",
            OpKind::Iota { .. } => "iota",
            OpKind::Add => "add",
            OpKind::Sub => "sub",
            OpKind::Mul => "mul",
            OpKind::Div => "div",
            OpKind::Max => "max",
            OpKind::Min => "min",
            OpKind::Neg => "neg",
            OpKind::Exp => "exp",
            OpKind::Log => "log",
            OpKind::Tanh => "tanh",
            OpKind::Rsqrt => "rsqrt",
            OpKind::Sqrt => "sqrt",
            OpKind::Abs => "abs",
            OpKind::Compare { .. } => "compare",
            OpKind::Select => "select",
            OpKind::Convert => "convert",
            OpKind::Dot(_) => "dot",
            OpKind::Reduce { kind: ReduceKind::Sum, .. } => "reduce_sum",
            OpKind::Reduce { kind: ReduceKind::Max, .. } => "reduce_max",
            OpKind::Broadcast { .. } => "broadcast_in_dim",
            OpKind::Reshape => "reshape",
            OpKind::Transpose { .. } => "transpose",
            OpKind::Gather => "gather",
            OpKind::SegmentSum { .. } => "segment_sum",
        }
    }

    /// Stable small integer id per op kind — used by the featurizer
    /// (learner) and must stay in sync with `python/compile/model.py`'s
    /// `NUM_OP_KINDS`.
    pub fn kind_id(&self) -> usize {
        match self {
            OpKind::Const { .. } => 0,
            OpKind::Iota { .. } => 1,
            OpKind::Add => 2,
            OpKind::Sub => 3,
            OpKind::Mul => 4,
            OpKind::Div => 5,
            OpKind::Max => 6,
            OpKind::Min => 7,
            OpKind::Neg => 8,
            OpKind::Exp => 9,
            OpKind::Log => 10,
            OpKind::Tanh => 11,
            OpKind::Rsqrt => 12,
            OpKind::Sqrt => 13,
            OpKind::Abs => 14,
            OpKind::Compare { .. } => 15,
            OpKind::Select => 16,
            OpKind::Convert => 17,
            OpKind::Dot(_) => 18,
            OpKind::Reduce { kind: ReduceKind::Sum, .. } => 19,
            OpKind::Reduce { kind: ReduceKind::Max, .. } => 20,
            OpKind::Broadcast { .. } => 21,
            OpKind::Reshape => 22,
            OpKind::Transpose { .. } => 23,
            OpKind::Gather => 24,
            OpKind::SegmentSum { .. } => 25,
        }
    }

    pub const NUM_KINDS: usize = 26;

    pub fn is_elementwise(&self) -> bool {
        matches!(
            self,
            OpKind::Add
                | OpKind::Sub
                | OpKind::Mul
                | OpKind::Div
                | OpKind::Max
                | OpKind::Min
                | OpKind::Neg
                | OpKind::Exp
                | OpKind::Log
                | OpKind::Tanh
                | OpKind::Rsqrt
                | OpKind::Sqrt
                | OpKind::Abs
                | OpKind::Compare { .. }
                | OpKind::Select
                | OpKind::Convert
        )
    }

    /// Approximate FLOPs per output element (runtime model input).
    pub fn flops_per_output(&self) -> f64 {
        match self {
            OpKind::Exp | OpKind::Log | OpKind::Tanh | OpKind::Rsqrt | OpKind::Sqrt => 8.0,
            OpKind::Dot(_) => 0.0, // handled specially (2*K per output)
            _ => 1.0,
        }
    }
}

impl fmt::Display for OpKind {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.name())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn kind_ids_are_unique_and_dense() {
        let ops: Vec<OpKind> = vec![
            OpKind::Const { value: 0.0 },
            OpKind::Iota { dim: 0 },
            OpKind::Add,
            OpKind::Sub,
            OpKind::Mul,
            OpKind::Div,
            OpKind::Max,
            OpKind::Min,
            OpKind::Neg,
            OpKind::Exp,
            OpKind::Log,
            OpKind::Tanh,
            OpKind::Rsqrt,
            OpKind::Sqrt,
            OpKind::Abs,
            OpKind::Compare { dir: CmpDir::Lt },
            OpKind::Select,
            OpKind::Convert,
            OpKind::Dot(DotDims::default()),
            OpKind::Reduce { kind: ReduceKind::Sum, dims: vec![] },
            OpKind::Reduce { kind: ReduceKind::Max, dims: vec![] },
            OpKind::Broadcast { dims: vec![] },
            OpKind::Reshape,
            OpKind::Transpose { perm: vec![] },
            OpKind::Gather,
            OpKind::SegmentSum { num: 1 },
        ];
        let mut seen = vec![false; OpKind::NUM_KINDS];
        for op in &ops {
            let id = op.kind_id();
            assert!(id < OpKind::NUM_KINDS);
            assert!(!seen[id], "duplicate kind_id {id}");
            seen[id] = true;
        }
        assert!(seen.iter().all(|&s| s), "kind ids not dense");
    }

    #[test]
    fn matmul_dims() {
        let d = DotDims::matmul(2);
        assert_eq!(d.lhs_contract, vec![1]);
        assert_eq!(d.rhs_contract, vec![0]);
        assert_eq!(d.free_dims(2, &d.lhs_batch, &d.lhs_contract), vec![0]);
    }
}
