//! Reference interpreter for the base dialect, over f64 storage.
//!
//! This is NOT on any hot path: it exists so transformations
//! (autodiff, DCE) and the SPMD lowering can be validated numerically
//! in tests (e.g. autodiff vs. finite differences; SPMD per-shard
//! execution vs. the unpartitioned program).

use super::graph::{Func, ValueId};
use super::op::{CmpDir, DotDims, OpKind, ReduceKind};
use super::types::TensorType;

/// A dense row-major tensor with f64 storage (bools are 0.0/1.0,
/// integers are exact up to 2^53 — plenty for index arithmetic).
#[derive(Debug, Clone, PartialEq)]
pub struct Tensor {
    pub dims: Vec<i64>,
    pub data: Vec<f64>,
}

impl Tensor {
    pub fn new(dims: &[i64], data: Vec<f64>) -> Tensor {
        assert_eq!(dims.iter().product::<i64>() as usize, data.len());
        Tensor { dims: dims.to_vec(), data }
    }
    pub fn splat(dims: &[i64], v: f64) -> Tensor {
        Tensor { dims: dims.to_vec(), data: vec![v; dims.iter().product::<i64>() as usize] }
    }
    pub fn scalar(v: f64) -> Tensor {
        Tensor { dims: vec![], data: vec![v] }
    }
    pub fn rank(&self) -> usize {
        self.dims.len()
    }
    pub fn len(&self) -> usize {
        self.data.len()
    }
    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    /// Row-major strides.
    pub fn strides(&self) -> Vec<usize> {
        let mut s = vec![1usize; self.rank()];
        for i in (0..self.rank().saturating_sub(1)).rev() {
            s[i] = s[i + 1] * self.dims[i + 1] as usize;
        }
        s
    }

    fn map2(&self, other: &Tensor, f: impl Fn(f64, f64) -> f64) -> Tensor {
        assert_eq!(self.dims, other.dims);
        Tensor {
            dims: self.dims.clone(),
            data: self.data.iter().zip(&other.data).map(|(&a, &b)| f(a, b)).collect(),
        }
    }
    fn map1(&self, f: impl Fn(f64) -> f64) -> Tensor {
        Tensor { dims: self.dims.clone(), data: self.data.iter().map(|&a| f(a)).collect() }
    }
}

/// Iterate multi-indices of `dims` in row-major order, calling `f(idx)`.
fn for_each_index(dims: &[i64], mut f: impl FnMut(&[i64])) {
    let rank = dims.len();
    let mut idx = vec![0i64; rank];
    let total: i64 = dims.iter().product();
    for _ in 0..total {
        f(&idx);
        for d in (0..rank).rev() {
            idx[d] += 1;
            if idx[d] < dims[d] {
                break;
            }
            idx[d] = 0;
        }
    }
    if rank == 0 {
        // total == 1 handled above (product of empty = 1), nothing more.
    }
}

fn flat_index(idx: &[i64], strides: &[usize]) -> usize {
    idx.iter().zip(strides).map(|(&i, &s)| i as usize * s).sum()
}

/// Evaluate `f` on the given argument tensors; returns values for ALL
/// value ids (args + nodes) so tests can inspect intermediates.
pub fn eval_all(f: &Func, args: &[Tensor]) -> Vec<Tensor> {
    assert_eq!(args.len(), f.num_args(), "wrong number of argument tensors");
    for (i, (a, spec)) in args.iter().zip(&f.args).enumerate() {
        assert_eq!(a.dims, spec.ty.dims, "arg {i} ({}) shape mismatch", spec.name);
    }
    let mut vals: Vec<Tensor> = args.to_vec();
    for node in &f.nodes {
        let get = |v: ValueId| &vals[v.index()];
        let out = eval_node(&node.op, &node.ty, &node.inputs, &get);
        vals.push(out);
    }
    vals
}

/// Evaluate `f`, returning only its outputs.
pub fn eval(f: &Func, args: &[Tensor]) -> Vec<Tensor> {
    let vals = eval_all(f, args);
    f.outputs.iter().map(|&o| vals[o.index()].clone()).collect()
}

fn eval_node<'a>(
    op: &OpKind,
    out_ty: &TensorType,
    inputs: &[ValueId],
    get: &impl Fn(ValueId) -> &'a Tensor,
) -> Tensor {
    match op {
        OpKind::Const { value } => Tensor::splat(&out_ty.dims, *value),
        OpKind::Iota { dim } => {
            let mut t = Tensor::splat(&out_ty.dims, 0.0);
            let strides = t.strides();
            let dims = t.dims.clone();
            let mut data = std::mem::take(&mut t.data);
            for_each_index(&dims, |idx| {
                data[flat_index(idx, &strides)] = idx[*dim] as f64;
            });
            t.data = data;
            t
        }
        OpKind::Add => get(inputs[0]).map2(get(inputs[1]), |a, b| a + b),
        OpKind::Sub => get(inputs[0]).map2(get(inputs[1]), |a, b| a - b),
        OpKind::Mul => get(inputs[0]).map2(get(inputs[1]), |a, b| a * b),
        OpKind::Div => get(inputs[0]).map2(get(inputs[1]), |a, b| a / b),
        OpKind::Max => get(inputs[0]).map2(get(inputs[1]), f64::max),
        OpKind::Min => get(inputs[0]).map2(get(inputs[1]), f64::min),
        OpKind::Neg => get(inputs[0]).map1(|a| -a),
        OpKind::Exp => get(inputs[0]).map1(f64::exp),
        OpKind::Log => get(inputs[0]).map1(f64::ln),
        OpKind::Tanh => get(inputs[0]).map1(f64::tanh),
        OpKind::Rsqrt => get(inputs[0]).map1(|a| 1.0 / a.sqrt()),
        OpKind::Sqrt => get(inputs[0]).map1(f64::sqrt),
        OpKind::Abs => get(inputs[0]).map1(f64::abs),
        OpKind::Compare { dir } => {
            let f = |a: f64, b: f64| -> f64 {
                let r = match dir {
                    CmpDir::Lt => a < b,
                    CmpDir::Le => a <= b,
                    CmpDir::Gt => a > b,
                    CmpDir::Ge => a >= b,
                    CmpDir::Eq => a == b,
                    CmpDir::Ne => a != b,
                };
                if r {
                    1.0
                } else {
                    0.0
                }
            };
            get(inputs[0]).map2(get(inputs[1]), f)
        }
        OpKind::Select => {
            let p = get(inputs[0]);
            let t = get(inputs[1]);
            let e = get(inputs[2]);
            assert_eq!(p.dims, t.dims);
            let data = p
                .data
                .iter()
                .zip(t.data.iter().zip(&e.data))
                .map(|(&p, (&t, &e))| if p != 0.0 { t } else { e })
                .collect();
            Tensor { dims: t.dims.clone(), data }
        }
        OpKind::Convert => get(inputs[0]).clone(),
        OpKind::Dot(d) => eval_dot(d, get(inputs[0]), get(inputs[1]), out_ty),
        OpKind::Reduce { kind, dims } => eval_reduce(*kind, dims, get(inputs[0]), out_ty),
        OpKind::Broadcast { dims } => eval_broadcast(dims, get(inputs[0]), out_ty),
        OpKind::Reshape => Tensor { dims: out_ty.dims.clone(), data: get(inputs[0]).data.clone() },
        OpKind::Transpose { perm } => eval_transpose(perm, get(inputs[0])),
        OpKind::Gather => eval_gather(get(inputs[0]), get(inputs[1])),
        OpKind::SegmentSum { num } => eval_segment_sum(*num, get(inputs[0]), get(inputs[1])),
    }
}

fn eval_dot(d: &DotDims, lhs: &Tensor, rhs: &Tensor, out_ty: &TensorType) -> Tensor {
    let lhs_free = d.free_dims(lhs.rank(), &d.lhs_batch, &d.lhs_contract);
    let rhs_free = d.free_dims(rhs.rank(), &d.rhs_batch, &d.rhs_contract);
    let batch_dims: Vec<i64> = d.lhs_batch.iter().map(|&b| lhs.dims[b]).collect();
    let lf_dims: Vec<i64> = lhs_free.iter().map(|&f| lhs.dims[f]).collect();
    let rf_dims: Vec<i64> = rhs_free.iter().map(|&f| rhs.dims[f]).collect();
    let c_dims: Vec<i64> = d.lhs_contract.iter().map(|&c| lhs.dims[c]).collect();

    let ls = lhs.strides();
    let rs = rhs.strides();
    let mut out = Tensor::splat(&out_ty.dims, 0.0);
    let os = out.strides();
    let mut out_data = std::mem::take(&mut out.data);

    // Iterate batch x lhs_free x rhs_free x contract.
    let mut loop_dims = batch_dims.clone();
    loop_dims.extend(&lf_dims);
    loop_dims.extend(&rf_dims);
    loop_dims.extend(&c_dims);
    let nb = batch_dims.len();
    let nlf = lf_dims.len();
    let nrf = rf_dims.len();

    let mut lidx = vec![0i64; lhs.rank()];
    let mut ridx = vec![0i64; rhs.rank()];
    let mut oidx = vec![0i64; out_ty.dims.len()];
    for_each_index(&loop_dims, |idx| {
        let (b, rest) = idx.split_at(nb);
        let (lf, rest2) = rest.split_at(nlf);
        let (rf, c) = rest2.split_at(nrf);
        for (k, &bd) in d.lhs_batch.iter().enumerate() {
            lidx[bd] = b[k];
        }
        for (k, &bd) in d.rhs_batch.iter().enumerate() {
            ridx[bd] = b[k];
        }
        for (k, &fd) in lhs_free.iter().enumerate() {
            lidx[fd] = lf[k];
        }
        for (k, &fd) in rhs_free.iter().enumerate() {
            ridx[fd] = rf[k];
        }
        for (k, &cd) in d.lhs_contract.iter().enumerate() {
            lidx[cd] = c[k];
        }
        for (k, &cd) in d.rhs_contract.iter().enumerate() {
            ridx[cd] = c[k];
        }
        for (k, &v) in b.iter().enumerate() {
            oidx[k] = v;
        }
        for (k, &v) in lf.iter().enumerate() {
            oidx[nb + k] = v;
        }
        for (k, &v) in rf.iter().enumerate() {
            oidx[nb + nlf + k] = v;
        }
        out_data[flat_index(&oidx, &os)] +=
            lhs.data[flat_index(&lidx, &ls)] * rhs.data[flat_index(&ridx, &rs)];
    });
    out.data = out_data;
    out
}

fn eval_reduce(kind: ReduceKind, rdims: &[usize], x: &Tensor, out_ty: &TensorType) -> Tensor {
    let init = match kind {
        ReduceKind::Sum => 0.0,
        ReduceKind::Max => f64::NEG_INFINITY,
    };
    let mut out = Tensor::splat(&out_ty.dims, init);
    let os = out.strides();
    let xs = x.strides();
    let keep: Vec<usize> = (0..x.rank()).filter(|d| !rdims.contains(d)).collect();
    let mut out_data = std::mem::take(&mut out.data);
    let mut oidx = vec![0i64; keep.len()];
    for_each_index(&x.dims, |idx| {
        for (k, &d) in keep.iter().enumerate() {
            oidx[k] = idx[d];
        }
        let o = flat_index(&oidx, &os);
        let v = x.data[flat_index(idx, &xs)];
        out_data[o] = match kind {
            ReduceKind::Sum => out_data[o] + v,
            ReduceKind::Max => out_data[o].max(v),
        };
    });
    out.data = out_data;
    out
}

fn eval_broadcast(bdims: &[usize], x: &Tensor, out_ty: &TensorType) -> Tensor {
    let mut out = Tensor::splat(&out_ty.dims, 0.0);
    let os = out.strides();
    let xs = x.strides();
    let mut out_data = std::mem::take(&mut out.data);
    let mut xidx = vec![0i64; x.rank()];
    for_each_index(&out_ty.dims, |idx| {
        for (i, &rd) in bdims.iter().enumerate() {
            xidx[i] = if x.dims[i] == 1 { 0 } else { idx[rd] };
        }
        out_data[flat_index(idx, &os)] = x.data[flat_index(&xidx, &xs)];
    });
    out.data = out_data;
    out
}

fn eval_transpose(perm: &[usize], x: &Tensor) -> Tensor {
    let out_dims: Vec<i64> = perm.iter().map(|&p| x.dims[p]).collect();
    let mut out = Tensor::splat(&out_dims, 0.0);
    let os = out.strides();
    let xs = x.strides();
    let mut out_data = std::mem::take(&mut out.data);
    let mut xidx = vec![0i64; x.rank()];
    for_each_index(&out_dims, |idx| {
        for (i, &p) in perm.iter().enumerate() {
            xidx[p] = idx[i];
        }
        out_data[flat_index(idx, &os)] = x.data[flat_index(&xidx, &xs)];
    });
    out.data = out_data;
    out
}

fn eval_gather(table: &Tensor, indices: &Tensor) -> Tensor {
    let row: usize = table.dims[1..].iter().product::<i64>() as usize;
    let mut out_dims = indices.dims.clone();
    out_dims.extend_from_slice(&table.dims[1..]);
    let mut data = Vec::with_capacity(indices.len() * row);
    for &i in &indices.data {
        let i = i as usize;
        assert!(i < table.dims[0] as usize, "gather index out of range");
        data.extend_from_slice(&table.data[i * row..(i + 1) * row]);
    }
    Tensor::new(&out_dims, data)
}

fn eval_segment_sum(num: i64, data: &Tensor, ids: &Tensor) -> Tensor {
    let row: usize = data.dims[1..].iter().product::<i64>() as usize;
    let mut out_dims = data.dims.clone();
    out_dims[0] = num;
    let mut out = Tensor::splat(&out_dims, 0.0);
    for (e, &seg) in ids.data.iter().enumerate() {
        let s = seg as usize;
        assert!(s < num as usize, "segment id out of range");
        for j in 0..row {
            out.data[s * row + j] += data.data[e * row + j];
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::builder::GraphBuilder;
    use crate::ir::graph::ArgKind;
    use crate::ir::op::DotDims;
    use crate::ir::types::{DType, TensorType};

    #[test]
    fn matmul_plus_bias() {
        let mut b = GraphBuilder::new("f");
        let x = b.arg("x", TensorType::f32(&[2, 2]), ArgKind::Input);
        let w = b.arg("w", TensorType::f32(&[2, 2]), ArgKind::Parameter);
        let y = b.matmul(x, w);
        let y2 = b.shift(y, 2.0);
        b.output(y2);
        let f = b.finish();
        let out = eval(
            &f,
            &[
                Tensor::new(&[2, 2], vec![1.0, 2.0, 3.0, 4.0]),
                Tensor::new(&[2, 2], vec![1.0, 1.0, 1.0, 1.0]),
            ],
        );
        // same numbers as /opt/xla-example/load_hlo.rs
        assert_eq!(out[0].data, vec![5.0, 5.0, 9.0, 9.0]);
    }

    #[test]
    fn batched_dot() {
        // [B=2, S=2, D=2] x [B=2, D=2, T=2] contracting D with batch B.
        let mut b = GraphBuilder::new("f");
        let q = b.arg("q", TensorType::f32(&[2, 2, 2]), ArgKind::Input);
        let k = b.arg("k", TensorType::f32(&[2, 2, 2]), ArgKind::Input);
        let d = DotDims {
            lhs_batch: vec![0],
            rhs_batch: vec![0],
            lhs_contract: vec![2],
            rhs_contract: vec![1],
        };
        let s = b.dot(d, q, k);
        b.output(s);
        let f = b.finish();
        let q = Tensor::new(&[2, 2, 2], (1..=8).map(|x| x as f64).collect());
        let k = Tensor::new(&[2, 2, 2], vec![1.0; 8]);
        let out = eval(&f, &[q, k]);
        assert_eq!(out[0].dims, vec![2, 2, 2]);
        // batch 0: [[1,2],[3,4]] @ ones = [[3,3],[7,7]]
        assert_eq!(&out[0].data[0..4], &[3.0, 3.0, 7.0, 7.0]);
        // batch 1: [[5,6],[7,8]] @ ones = [[11,11],[15,15]]
        assert_eq!(&out[0].data[4..8], &[11.0, 11.0, 15.0, 15.0]);
    }

    #[test]
    fn softmax_rows_sum_to_one() {
        let mut b = GraphBuilder::new("f");
        let x = b.arg("x", TensorType::f32(&[3, 5]), ArgKind::Input);
        let s = b.softmax_last(x);
        b.output(s);
        let f = b.finish();
        let xs = Tensor::new(&[3, 5], (0..15).map(|i| (i as f64) * 0.3 - 2.0).collect());
        let out = eval(&f, &[xs]);
        for r in 0..3 {
            let row: f64 = out[0].data[r * 5..(r + 1) * 5].iter().sum();
            assert!((row - 1.0).abs() < 1e-12);
        }
    }

    #[test]
    fn transpose_and_reshape() {
        let mut b = GraphBuilder::new("f");
        let x = b.arg("x", TensorType::f32(&[2, 3]), ArgKind::Input);
        let t = b.transpose(x, vec![1, 0]);
        let r = b.reshape(t, &[6]);
        b.output(r);
        let f = b.finish();
        let out = eval(&f, &[Tensor::new(&[2, 3], vec![1., 2., 3., 4., 5., 6.])]);
        assert_eq!(out[0].data, vec![1., 4., 2., 5., 3., 6.]);
    }

    #[test]
    fn gather_segment_sum_roundtrip() {
        let mut b = GraphBuilder::new("f");
        let table = b.arg("t", TensorType::f32(&[4, 2]), ArgKind::Parameter);
        let ids = b.arg("i", TensorType::new(DType::I32, &[3]), ArgKind::Input);
        let g = b.gather(table, ids);
        let s = b.segment_sum(g, ids, 4);
        b.output(s);
        let f = b.finish();
        let t = Tensor::new(&[4, 2], (0..8).map(|x| x as f64).collect());
        let i = Tensor::new(&[3], vec![2.0, 0.0, 2.0]);
        let out = eval(&f, &[t, i]);
        // row 0 gathered once -> [0,1]; row 2 gathered twice -> [8,10]
        assert_eq!(out[0].data, vec![0., 1., 0., 0., 8., 10., 0., 0.]);
    }

    #[test]
    fn iota_and_compare_select() {
        let mut b = GraphBuilder::new("f");
        let ty = TensorType::f32(&[4]);
        let i = b.iota(0, ty.clone());
        let two = b.constant(2.0, ty.clone());
        let p = b.compare(crate::ir::op::CmpDir::Lt, i, two);
        let ones = b.constant(1.0, ty.clone());
        let zeros = b.constant(0.0, ty);
        let s = b.select(p, ones, zeros);
        b.output(s);
        let f = b.finish();
        let out = eval(&f, &[]);
        assert_eq!(out[0].data, vec![1.0, 1.0, 0.0, 0.0]);
    }
}
