//! Dead-code elimination: drop nodes not reachable from the outputs and
//! compact value ids. Model builders run this before handing graphs to
//! the partitioner so op counts reported in EXPERIMENTS.md are honest.

use super::graph::{Func, Node, ValueId};

/// Returns a new function with dead nodes removed, plus the value remap
/// (old id -> new id; None if removed). Arguments are always kept (they
/// are the partitioner's decision points even when unused).
pub fn dce(f: &Func) -> (Func, Vec<Option<ValueId>>) {
    let live = f.live_nodes();
    let mut remap: Vec<Option<ValueId>> = vec![None; f.num_values()];
    for i in 0..f.num_args() {
        remap[i] = Some(ValueId(i as u32));
    }
    let mut new_nodes: Vec<Node> = Vec::with_capacity(f.num_nodes());
    for (ni, node) in f.nodes.iter().enumerate() {
        if !live[ni] {
            continue;
        }
        let new_inputs: Vec<ValueId> = node
            .inputs
            .iter()
            .map(|&v| remap[v.index()].expect("live node uses dead value"))
            .collect();
        new_nodes.push(Node {
            op: node.op.clone(),
            inputs: new_inputs,
            ty: node.ty.clone(),
            scope: node.scope,
        });
        remap[f.value_of_node(ni).index()] =
            Some(ValueId((f.num_args() + new_nodes.len() - 1) as u32));
    }
    let out = Func {
        name: f.name.clone(),
        args: f.args.clone(),
        nodes: new_nodes,
        outputs: f.outputs.iter().map(|&o| remap[o.index()].unwrap()).collect(),
        scopes: f.scopes.clone(),
    };
    (out, remap)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::builder::GraphBuilder;
    use crate::ir::graph::ArgKind;
    use crate::ir::interp::{eval, Tensor};
    use crate::ir::types::TensorType;
    use crate::ir::verify::verify;

    #[test]
    fn removes_dead_nodes_and_preserves_semantics() {
        let mut b = GraphBuilder::new("d");
        let x = b.arg("x", TensorType::f32(&[3]), ArgKind::Input);
        let live1 = b.neg(x);
        let _dead1 = b.exp(x);
        let _dead2 = b.tanh(x);
        let out = b.mul(live1, x);
        b.output(out);
        let f = b.finish();
        assert_eq!(f.num_nodes(), 4);

        let (g, remap) = dce(&f);
        verify(&g).unwrap();
        assert_eq!(g.num_nodes(), 2);
        assert!(remap[f.node_of(out).unwrap() + f.num_args()].is_some());

        let xs = Tensor::new(&[3], vec![1.0, -2.0, 0.5]);
        assert_eq!(eval(&f, &[xs.clone()]), eval(&g, &[xs]));
    }

    #[test]
    fn keeps_unused_args() {
        let mut b = GraphBuilder::new("d");
        let x = b.arg("x", TensorType::f32(&[2]), ArgKind::Input);
        let _unused = b.arg("u", TensorType::f32(&[2]), ArgKind::Parameter);
        let y = b.neg(x);
        b.output(y);
        let (g, _) = dce(&b.finish());
        assert_eq!(g.num_args(), 2);
        verify(&g).unwrap();
    }
}
