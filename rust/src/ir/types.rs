//! Statically-shaped tensor types for the base IR (the "MHLO-like"
//! dialect PartIR is layered on, per paper §2.1).

use std::fmt;

/// Element type. The partitioner itself only needs byte widths, but the
/// interpreter and printers use the full tag.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum DType {
    F32,
    BF16,
    I32,
    Bool,
}

impl DType {
    pub fn bytes(&self) -> i64 {
        match self {
            DType::F32 | DType::I32 => 4,
            DType::BF16 => 2,
            DType::Bool => 1,
        }
    }
    pub fn name(&self) -> &'static str {
        match self {
            DType::F32 => "f32",
            DType::BF16 => "bf16",
            DType::I32 => "i32",
            DType::Bool => "i1",
        }
    }
    pub fn is_float(&self) -> bool {
        matches!(self, DType::F32 | DType::BF16)
    }
}

/// A statically-shaped tensor type: `tensor<8x16xf32>`.
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub struct TensorType {
    pub dtype: DType,
    pub dims: Vec<i64>,
}

impl TensorType {
    pub fn new(dtype: DType, dims: &[i64]) -> Self {
        debug_assert!(dims.iter().all(|&d| d > 0), "dims must be positive: {dims:?}");
        TensorType { dtype, dims: dims.to_vec() }
    }
    pub fn f32(dims: &[i64]) -> Self {
        Self::new(DType::F32, dims)
    }
    pub fn i32(dims: &[i64]) -> Self {
        Self::new(DType::I32, dims)
    }
    pub fn scalar(dtype: DType) -> Self {
        TensorType { dtype, dims: vec![] }
    }

    pub fn rank(&self) -> usize {
        self.dims.len()
    }
    pub fn num_elements(&self) -> i64 {
        self.dims.iter().product()
    }
    /// Size in bytes of one (replicated) copy of this tensor.
    pub fn byte_size(&self) -> i64 {
        self.num_elements() * self.dtype.bytes()
    }
    pub fn with_dims(&self, dims: Vec<i64>) -> TensorType {
        TensorType { dtype: self.dtype, dims }
    }
}

impl fmt::Display for TensorType {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "tensor<")?;
        for d in &self.dims {
            write!(f, "{d}x")?;
        }
        write!(f, "{}>", self.dtype.name())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn byte_sizes() {
        assert_eq!(TensorType::f32(&[8, 16]).byte_size(), 8 * 16 * 4);
        assert_eq!(TensorType::new(DType::BF16, &[4]).byte_size(), 8);
        assert_eq!(TensorType::scalar(DType::F32).byte_size(), 4);
        assert_eq!(TensorType::scalar(DType::F32).num_elements(), 1);
    }

    #[test]
    fn display() {
        assert_eq!(TensorType::f32(&[8, 64]).to_string(), "tensor<8x64xf32>");
        assert_eq!(TensorType::scalar(DType::I32).to_string(), "tensor<i32>");
    }
}
