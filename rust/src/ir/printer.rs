//! Textual printer for the base dialect, in an MLIR-flavoured notation
//! close to the paper's Figure 2 (top). The emitted form is the
//! interchange format of DESIGN.md §10: it is lossless (argument names
//! and scope paths included), and [`crate::ir::parser::parse_func`]
//! reconstructs the exact [`Func`] — `parse(print(f)) == f`, within
//! §10's restrictions on the two printed-raw fields (identifier
//! function names; scope paths without newlines or edge whitespace).

use super::graph::{Func, ValueId, ROOT_SCOPE};
use super::op::OpKind;
use std::fmt::Write;

/// Print the whole function.
pub fn print_func(f: &Func) -> String {
    let mut s = String::new();
    write!(s, "func @{}(", f.name).unwrap();
    for (i, a) in f.args.iter().enumerate() {
        if i > 0 {
            s.push_str(", ");
        }
        write!(s, "%arg{i}: {} {{{}, name = {}", a.ty, a.kind.name(), quote(&a.name)).unwrap();
        if a.scope != ROOT_SCOPE {
            write!(s, ", scope = {}", quote(f.scope_path(a.scope))).unwrap();
        }
        s.push('}');
    }
    s.push_str(")\n");
    let out_tys: Vec<String> =
        f.outputs.iter().map(|&o| f.value_type(o).to_string()).collect();
    writeln!(s, "    -> ({}) {{", out_tys.join(", ")).unwrap();
    for (ni, node) in f.nodes.iter().enumerate() {
        let ins: Vec<String> = node.inputs.iter().map(|&v| ref_name(f, v)).collect();
        let attrs = op_attrs(&node.op);
        let scope = f.scope_path(node.scope);
        let scope_str = if scope.is_empty() { String::new() } else { format!("  // {scope}") };
        writeln!(
            s,
            "  %{ni} = {} {}{} : {}{}",
            node.op.name(),
            ins.join(", "),
            attrs,
            node.ty,
            scope_str
        )
        .unwrap();
    }
    let outs: Vec<String> = f.outputs.iter().map(|&o| ref_name(f, o)).collect();
    writeln!(s, "  return {}", outs.join(", ")).unwrap();
    s.push_str("}\n");
    s
}

/// Quote a string literal for the textual form. Escapes `"`, `\`, and
/// line/tab whitespace so even pathological argument names survive the
/// round-trip (scope paths are printed raw in `//` trailers and carry
/// the documented no-newline/no-edge-whitespace restriction instead).
fn quote(s: &str) -> String {
    let mut out = String::with_capacity(s.len() + 2);
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\t' => out.push_str("\\t"),
            '\r' => out.push_str("\\r"),
            _ => out.push(c),
        }
    }
    out.push('"');
    out
}

fn ref_name(f: &Func, v: ValueId) -> String {
    match f.node_of(v) {
        None => format!("%arg{}", v.index()),
        Some(n) => format!("%{n}"),
    }
}

fn op_attrs(op: &OpKind) -> String {
    match op {
        OpKind::Const { value } => format!(" {{value = {value}}}"),
        OpKind::Iota { dim } => format!(" {{dim = {dim}}}"),
        OpKind::Compare { dir } => format!(" {{dir = {dir:?}}}"),
        OpKind::Dot(d) => format!(
            " {{batch = {:?}x{:?}, contract = {:?}x{:?}}}",
            d.lhs_batch, d.rhs_batch, d.lhs_contract, d.rhs_contract
        ),
        OpKind::Reduce { dims, .. } => format!(" {{dims = {dims:?}}}"),
        OpKind::Broadcast { dims } => format!(" {{broadcast_dims = {dims:?}}}"),
        OpKind::Transpose { perm } => format!(" {{perm = {perm:?}}}"),
        OpKind::SegmentSum { num } => format!(" {{num = {num}}}"),
        _ => String::new(),
    }
}

#[cfg(test)]
mod tests {
    use crate::ir::builder::GraphBuilder;
    use crate::ir::graph::ArgKind;
    use crate::ir::types::TensorType;

    #[test]
    fn prints_figure2_style_program() {
        // The paper's Figure 2 (top): one linear layer.
        let mut b = GraphBuilder::new("main");
        let x = b.arg("x", TensorType::f32(&[8, 16]), ArgKind::Input);
        let w = b.arg("w", TensorType::f32(&[16, 64]), ArgKind::Parameter);
        let bias = b.arg("b", TensorType::f32(&[64]), ArgKind::Parameter);
        let dot = b.matmul(x, w);
        let ty = b.ty(dot).clone();
        let bb = b.broadcast_to(bias, ty);
        let out = b.add(dot, bb);
        b.output(out);
        let s = super::print_func(&b.finish());
        assert!(s.contains("func @main"));
        assert!(s.contains("%arg0: tensor<8x16xf32> {input, name = \"x\"}"));
        assert!(s.contains("dot %arg0, %arg1"));
        assert!(s.contains("broadcast_in_dim %arg2 {broadcast_dims = [1]}"));
        assert!(s.contains("tensor<8x64xf32>"));
        assert!(s.contains("return %2"));
    }

    #[test]
    fn prints_arg_scopes_and_quoted_names() {
        let mut b = GraphBuilder::new("scoped");
        b.push_scope("dense_0");
        let w = b.arg("dense_0/w", TensorType::f32(&[4, 4]), ArgKind::Parameter);
        b.pop_scope();
        let y = b.neg(w);
        b.output(y);
        let s = super::print_func(&b.finish());
        assert!(
            s.contains("{param, name = \"dense_0/w\", scope = \"dense_0\"}"),
            "arg scope must be printed: {s}"
        );
        assert_eq!(super::quote("a\"b\\c"), "\"a\\\"b\\\\c\"");
        assert_eq!(super::quote("a\nb\tc"), "\"a\\nb\\tc\"");
    }
}
