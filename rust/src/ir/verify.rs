//! Shape/type inference and graph verification. `infer_type` is the single
//! source of truth for operator result types — the builder uses it to
//! construct nodes and the verifier re-checks every node against it, so a
//! malformed graph cannot silently enter the partitioner.

use super::graph::{Func, ValueId};
use super::op::{DotDims, OpKind};
use super::types::{DType, TensorType};

#[derive(Debug, thiserror::Error)]
pub enum IrError {
    #[error("shape error in {op}: {msg}")]
    Shape { op: String, msg: String },
    #[error("verification failed at node {node}: {msg}")]
    Verify { node: usize, msg: String },
}

fn err<T>(op: &OpKind, msg: impl Into<String>) -> Result<T, IrError> {
    Err(IrError::Shape { op: op.name().to_string(), msg: msg.into() })
}

/// Infer the result type of `op` applied to operands of types `ins`.
/// `hint` carries attributes that live in the result type (Reshape target
/// shape, Convert target dtype, Const/Iota type).
pub fn infer_type(
    op: &OpKind,
    ins: &[&TensorType],
    hint: Option<&TensorType>,
) -> Result<TensorType, IrError> {
    let arity_ok = |n: usize| -> Result<(), IrError> {
        if ins.len() == n {
            Ok(())
        } else {
            Err(IrError::Shape {
                op: op.name().to_string(),
                msg: format!("expected {n} operands, got {}", ins.len()),
            })
        }
    };
    match op {
        OpKind::Const { .. } | OpKind::Iota { .. } => {
            arity_ok(0)?;
            let t = hint.ok_or_else(|| IrError::Shape {
                op: op.name().into(),
                msg: "const/iota needs a type hint".into(),
            })?;
            if let OpKind::Iota { dim } = op {
                if *dim >= t.rank() {
                    return err(op, format!("iota dim {dim} out of range for {t}"));
                }
            }
            Ok(t.clone())
        }
        OpKind::Add | OpKind::Sub | OpKind::Mul | OpKind::Div | OpKind::Max | OpKind::Min => {
            arity_ok(2)?;
            if ins[0] != ins[1] {
                return err(op, format!("operand mismatch: {} vs {}", ins[0], ins[1]));
            }
            Ok(ins[0].clone())
        }
        OpKind::Neg
        | OpKind::Exp
        | OpKind::Log
        | OpKind::Tanh
        | OpKind::Rsqrt
        | OpKind::Sqrt
        | OpKind::Abs => {
            arity_ok(1)?;
            Ok(ins[0].clone())
        }
        OpKind::Compare { .. } => {
            arity_ok(2)?;
            if ins[0].dims != ins[1].dims {
                return err(op, format!("operand mismatch: {} vs {}", ins[0], ins[1]));
            }
            Ok(TensorType::new(DType::Bool, &ins[0].dims))
        }
        OpKind::Select => {
            arity_ok(3)?;
            if ins[0].dtype != DType::Bool {
                return err(op, "predicate must be bool");
            }
            if ins[0].dims != ins[1].dims || ins[1] != ins[2] {
                return err(op, "select operands must agree in shape");
            }
            Ok(ins[1].clone())
        }
        OpKind::Convert => {
            arity_ok(1)?;
            let t = hint.ok_or_else(|| IrError::Shape {
                op: "convert".into(),
                msg: "convert needs a target-dtype hint".into(),
            })?;
            if t.dims != ins[0].dims {
                return err(op, "convert cannot change shape");
            }
            Ok(t.clone())
        }
        OpKind::Dot(d) => {
            arity_ok(2)?;
            infer_dot(op, d, ins[0], ins[1])
        }
        OpKind::Reduce { dims, .. } => {
            arity_ok(1)?;
            let r = ins[0].rank();
            for &d in dims {
                if d >= r {
                    return err(op, format!("reduce dim {d} out of range (rank {r})"));
                }
            }
            let out: Vec<i64> =
                (0..r).filter(|i| !dims.contains(i)).map(|i| ins[0].dims[i]).collect();
            Ok(ins[0].with_dims(out))
        }
        OpKind::Broadcast { dims } => {
            arity_ok(1)?;
            let t = hint.ok_or_else(|| IrError::Shape {
                op: "broadcast_in_dim".into(),
                msg: "broadcast needs a result-shape hint".into(),
            })?;
            if dims.len() != ins[0].rank() {
                return err(op, "broadcast dims must map every operand dim");
            }
            for (i, &rd) in dims.iter().enumerate() {
                if rd >= t.rank() {
                    return err(op, format!("broadcast target dim {rd} out of range"));
                }
                if ins[0].dims[i] != t.dims[rd] && ins[0].dims[i] != 1 {
                    return err(
                        op,
                        format!(
                            "operand dim {i} (={}) incompatible with result dim {rd} (={})",
                            ins[0].dims[i], t.dims[rd]
                        ),
                    );
                }
            }
            if t.dtype != ins[0].dtype {
                return err(op, "broadcast cannot change dtype");
            }
            Ok(t.clone())
        }
        OpKind::Reshape => {
            arity_ok(1)?;
            let t = hint.ok_or_else(|| IrError::Shape {
                op: "reshape".into(),
                msg: "reshape needs a result-shape hint".into(),
            })?;
            if t.num_elements() != ins[0].num_elements() || t.dtype != ins[0].dtype {
                return err(op, format!("cannot reshape {} to {}", ins[0], t));
            }
            Ok(t.clone())
        }
        OpKind::Transpose { perm } => {
            arity_ok(1)?;
            let r = ins[0].rank();
            let mut seen = vec![false; r];
            if perm.len() != r {
                return err(op, "perm length must equal rank");
            }
            for &p in perm {
                if p >= r || seen[p] {
                    return err(op, format!("bad permutation {perm:?}"));
                }
                seen[p] = true;
            }
            let out: Vec<i64> = perm.iter().map(|&p| ins[0].dims[p]).collect();
            Ok(ins[0].with_dims(out))
        }
        OpKind::Gather => {
            arity_ok(2)?;
            if ins[1].dtype != DType::I32 {
                return err(op, "gather indices must be i32");
            }
            if ins[0].rank() == 0 {
                return err(op, "gather table must have rank >= 1");
            }
            let mut out = ins[1].dims.clone();
            out.extend_from_slice(&ins[0].dims[1..]);
            Ok(ins[0].with_dims(out))
        }
        OpKind::SegmentSum { num } => {
            arity_ok(2)?;
            if ins[1].dtype != DType::I32 || ins[1].rank() != 1 {
                return err(op, "segment ids must be i32 of rank 1");
            }
            if ins[0].rank() == 0 || ins[0].dims[0] != ins[1].dims[0] {
                return err(op, "data dim 0 must equal number of ids");
            }
            let mut out = ins[0].dims.clone();
            out[0] = *num;
            Ok(ins[0].with_dims(out))
        }
    }
}

fn infer_dot(
    op: &OpKind,
    d: &DotDims,
    lhs: &TensorType,
    rhs: &TensorType,
) -> Result<TensorType, IrError> {
    if d.lhs_batch.len() != d.rhs_batch.len() || d.lhs_contract.len() != d.rhs_contract.len() {
        return err(op, "batch/contract dim counts must match");
    }
    for (&lb, &rb) in d.lhs_batch.iter().zip(&d.rhs_batch) {
        if lhs.dims.get(lb) != rhs.dims.get(rb) {
            return err(op, format!("batch dims differ: lhs[{lb}] vs rhs[{rb}]"));
        }
    }
    for (&lc, &rc) in d.lhs_contract.iter().zip(&d.rhs_contract) {
        if lhs.dims.get(lc) != rhs.dims.get(rc) {
            return err(
                op,
                format!(
                    "contract dims differ: lhs[{lc}]={:?} vs rhs[{rc}]={:?}",
                    lhs.dims.get(lc),
                    rhs.dims.get(rc)
                ),
            );
        }
    }
    let lhs_free = d.free_dims(lhs.rank(), &d.lhs_batch, &d.lhs_contract);
    let rhs_free = d.free_dims(rhs.rank(), &d.rhs_batch, &d.rhs_contract);
    let mut out: Vec<i64> = d.lhs_batch.iter().map(|&b| lhs.dims[b]).collect();
    out.extend(lhs_free.iter().map(|&f| lhs.dims[f]));
    out.extend(rhs_free.iter().map(|&f| rhs.dims[f]));
    Ok(lhs.with_dims(out))
}

/// Verify the whole function: operand ids in range and topologically
/// earlier than their users, node types matching `infer_type`, and output
/// ids valid.
pub fn verify(f: &Func) -> Result<(), IrError> {
    for (ai, arg) in f.args.iter().enumerate() {
        if arg.scope.0 as usize >= f.scopes.len() {
            return Err(IrError::Verify {
                node: usize::MAX,
                msg: format!("argument {ai} ({}) has a bad scope id", arg.name),
            });
        }
    }
    for (ni, node) in f.nodes.iter().enumerate() {
        let own_value = f.value_of_node(ni);
        for &inp in &node.inputs {
            if inp.index() >= f.num_values() {
                return Err(IrError::Verify {
                    node: ni,
                    msg: format!("input {inp:?} out of range"),
                });
            }
            if inp >= own_value {
                return Err(IrError::Verify {
                    node: ni,
                    msg: format!("input {inp:?} not topologically earlier"),
                });
            }
        }
        let in_tys: Vec<&TensorType> = node.inputs.iter().map(|&v| f.value_type(v)).collect();
        let inferred = infer_type(&node.op, &in_tys, Some(&node.ty))
            .map_err(|e| IrError::Verify { node: ni, msg: e.to_string() })?;
        if inferred != node.ty {
            return Err(IrError::Verify {
                node: ni,
                msg: format!("stored type {} != inferred {}", node.ty, inferred),
            });
        }
        if node.scope.0 as usize >= f.scopes.len() {
            return Err(IrError::Verify { node: ni, msg: "bad scope id".into() });
        }
    }
    for &o in &f.outputs {
        if o.index() >= f.num_values() {
            return Err(IrError::Verify {
                node: usize::MAX,
                msg: format!("output {o:?} out of range"),
            });
        }
    }
    Ok(())
}

#[allow(dead_code)]
fn value_in_range(f: &Func, v: ValueId) -> bool {
    v.index() < f.num_values()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn binary_shapes_must_match() {
        let a = TensorType::f32(&[2, 3]);
        let b = TensorType::f32(&[2, 4]);
        assert!(infer_type(&OpKind::Add, &[&a, &a], None).is_ok());
        assert!(infer_type(&OpKind::Add, &[&a, &b], None).is_err());
    }

    #[test]
    fn dot_matmul() {
        let a = TensorType::f32(&[8, 16]);
        let b = TensorType::f32(&[16, 64]);
        let t = infer_type(&OpKind::Dot(DotDims::matmul(2)), &[&a, &b], None).unwrap();
        assert_eq!(t.dims, vec![8, 64]);
    }

    #[test]
    fn dot_batched() {
        // attention scores: [B,H,S,D] x [B,H,S,D] contracting D -> [B,H,S,S]
        let q = TensorType::f32(&[2, 4, 16, 8]);
        let k = TensorType::f32(&[2, 4, 16, 8]);
        let d = DotDims {
            lhs_batch: vec![0, 1],
            rhs_batch: vec![0, 1],
            lhs_contract: vec![3],
            rhs_contract: vec![3],
        };
        let t = infer_type(&OpKind::Dot(d), &[&q, &k], None).unwrap();
        assert_eq!(t.dims, vec![2, 4, 16, 16]);
    }

    #[test]
    fn reduce_and_broadcast() {
        let a = TensorType::f32(&[2, 3, 4]);
        let t = infer_type(
            &OpKind::Reduce { kind: super::super::op::ReduceKind::Sum, dims: vec![1] },
            &[&a],
            None,
        )
        .unwrap();
        assert_eq!(t.dims, vec![2, 4]);

        let v = TensorType::f32(&[4]);
        let target = TensorType::f32(&[2, 4]);
        let t = infer_type(&OpKind::Broadcast { dims: vec![1] }, &[&v], Some(&target)).unwrap();
        assert_eq!(t.dims, vec![2, 4]);
        // bad mapping
        let bad = infer_type(&OpKind::Broadcast { dims: vec![0] }, &[&v], Some(&target));
        assert!(bad.is_err());
    }

    #[test]
    fn gather_and_segment_sum() {
        let table = TensorType::f32(&[100, 8]);
        let ids = TensorType::i32(&[2, 5]);
        let t = infer_type(&OpKind::Gather, &[&table, &ids], None).unwrap();
        assert_eq!(t.dims, vec![2, 5, 8]);

        let data = TensorType::f32(&[10, 8]);
        let sid = TensorType::i32(&[10]);
        let t = infer_type(&OpKind::SegmentSum { num: 4 }, &[&data, &sid], None).unwrap();
        assert_eq!(t.dims, vec![4, 8]);
    }

    #[test]
    fn transpose_checks_perm() {
        let a = TensorType::f32(&[2, 3, 4]);
        let t = infer_type(&OpKind::Transpose { perm: vec![2, 0, 1] }, &[&a], None).unwrap();
        assert_eq!(t.dims, vec![4, 2, 3]);
        assert!(infer_type(&OpKind::Transpose { perm: vec![0, 0, 1] }, &[&a], None).is_err());
    }

    #[test]
    fn reshape_preserves_elements() {
        let a = TensorType::f32(&[2, 6]);
        assert!(infer_type(&OpKind::Reshape, &[&a], Some(&TensorType::f32(&[3, 4]))).is_ok());
        assert!(infer_type(&OpKind::Reshape, &[&a], Some(&TensorType::f32(&[5]))).is_err());
    }
}
