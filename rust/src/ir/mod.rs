//! Base tensor dialect: the statically-shaped, MHLO-like IR that the
//! PartIR layer (paper §2.1) is layered on. Includes a builder, verifier,
//! reference interpreter, reverse-mode autodiff, DCE, and a textual
//! printer/parser pair that round-trips exactly (DESIGN.md §10).

pub mod autodiff;
pub mod binary;
pub mod builder;
pub mod dce;
pub mod graph;
pub mod interp;
pub mod op;
pub mod parser;
pub mod printer;
pub mod types;
pub mod verify;

pub use binary::{decode_plan, decode_program, encode_plan, encode_program, DecodeError};
pub use builder::GraphBuilder;
pub use graph::{Arg, ArgKind, Func, Node, ScopeId, ValueId, ROOT_SCOPE};
pub use op::{CmpDir, DotDims, OpKind, ReduceKind};
pub use parser::{parse_func, ParseError};
pub use printer::print_func;
pub use types::{DType, TensorType};
