//! Ergonomic graph builder. All shape inference goes through
//! `verify::infer_type`, so graphs are correct by construction; `verify`
//! re-checks them in tests.

use super::graph::{Arg, ArgKind, Func, Node, ScopeId, ValueId, ROOT_SCOPE};
use super::op::{CmpDir, DotDims, OpKind, ReduceKind};
use super::types::{DType, TensorType};
use super::verify::infer_type;

/// Builder over a [`Func`] with a current named scope (Haiku-style).
pub struct GraphBuilder {
    pub func: Func,
    scope_stack: Vec<ScopeId>,
}

impl GraphBuilder {
    pub fn new(name: impl Into<String>) -> Self {
        GraphBuilder { func: Func::new(name), scope_stack: vec![ROOT_SCOPE] }
    }

    pub fn current_scope(&self) -> ScopeId {
        *self.scope_stack.last().unwrap()
    }

    /// Push a nested named scope (`with_scope("layer_0", |b| ...)` style).
    pub fn push_scope(&mut self, name: &str) {
        let parent = self.func.scope_path(self.current_scope()).to_string();
        let path = if parent.is_empty() { name.to_string() } else { format!("{parent}/{name}") };
        let id = self.func.intern_scope(&path);
        self.scope_stack.push(id);
    }

    pub fn pop_scope(&mut self) {
        assert!(self.scope_stack.len() > 1, "cannot pop root scope");
        self.scope_stack.pop();
    }

    /// Push an already-interned scope id (used by autodiff so backward
    /// nodes inherit the scope of their forward node).
    pub fn push_scope_id(&mut self, s: ScopeId) {
        self.scope_stack.push(s);
    }

    /// Declare a function argument.
    pub fn arg(&mut self, name: impl Into<String>, ty: TensorType, kind: ArgKind) -> ValueId {
        let scope = self.current_scope();
        self.func.args.push(Arg { name: name.into(), ty, kind, scope });
        assert!(
            self.func.nodes.is_empty(),
            "all arguments must be declared before the first node"
        );
        ValueId((self.func.args.len() - 1) as u32)
    }

    fn push(&mut self, op: OpKind, inputs: Vec<ValueId>, hint: Option<TensorType>) -> ValueId {
        let in_tys: Vec<&TensorType> = inputs.iter().map(|&v| self.func.value_type(v)).collect();
        let ty = infer_type(&op, &in_tys, hint.as_ref())
            .unwrap_or_else(|e| panic!("builder: {e} (op={op:?})"));
        let scope = self.current_scope();
        self.func.nodes.push(Node { op, inputs, ty, scope });
        self.func.value_of_node(self.func.nodes.len() - 1)
    }

    pub fn output(&mut self, v: ValueId) {
        self.func.outputs.push(v);
    }

    pub fn finish(self) -> Func {
        self.func
    }

    pub fn ty(&self, v: ValueId) -> &TensorType {
        self.func.value_type(v)
    }
    pub fn dims(&self, v: ValueId) -> Vec<i64> {
        self.func.value_type(v).dims.clone()
    }

    // ---- op helpers -----------------------------------------------------

    pub fn constant(&mut self, value: f64, ty: TensorType) -> ValueId {
        self.push(OpKind::Const { value }, vec![], Some(ty))
    }
    pub fn scalar(&mut self, value: f64) -> ValueId {
        self.constant(value, TensorType::scalar(DType::F32))
    }
    pub fn iota(&mut self, dim: usize, ty: TensorType) -> ValueId {
        self.push(OpKind::Iota { dim }, vec![], Some(ty))
    }

    pub fn add(&mut self, a: ValueId, b: ValueId) -> ValueId {
        self.push(OpKind::Add, vec![a, b], None)
    }
    pub fn sub(&mut self, a: ValueId, b: ValueId) -> ValueId {
        self.push(OpKind::Sub, vec![a, b], None)
    }
    pub fn mul(&mut self, a: ValueId, b: ValueId) -> ValueId {
        self.push(OpKind::Mul, vec![a, b], None)
    }
    pub fn div(&mut self, a: ValueId, b: ValueId) -> ValueId {
        self.push(OpKind::Div, vec![a, b], None)
    }
    pub fn max(&mut self, a: ValueId, b: ValueId) -> ValueId {
        self.push(OpKind::Max, vec![a, b], None)
    }
    pub fn min(&mut self, a: ValueId, b: ValueId) -> ValueId {
        self.push(OpKind::Min, vec![a, b], None)
    }
    pub fn neg(&mut self, a: ValueId) -> ValueId {
        self.push(OpKind::Neg, vec![a], None)
    }
    pub fn exp(&mut self, a: ValueId) -> ValueId {
        self.push(OpKind::Exp, vec![a], None)
    }
    pub fn log(&mut self, a: ValueId) -> ValueId {
        self.push(OpKind::Log, vec![a], None)
    }
    pub fn tanh(&mut self, a: ValueId) -> ValueId {
        self.push(OpKind::Tanh, vec![a], None)
    }
    pub fn rsqrt(&mut self, a: ValueId) -> ValueId {
        self.push(OpKind::Rsqrt, vec![a], None)
    }
    pub fn sqrt(&mut self, a: ValueId) -> ValueId {
        self.push(OpKind::Sqrt, vec![a], None)
    }
    pub fn abs(&mut self, a: ValueId) -> ValueId {
        self.push(OpKind::Abs, vec![a], None)
    }
    pub fn compare(&mut self, dir: CmpDir, a: ValueId, b: ValueId) -> ValueId {
        self.push(OpKind::Compare { dir }, vec![a, b], None)
    }
    pub fn select(&mut self, pred: ValueId, t: ValueId, f: ValueId) -> ValueId {
        self.push(OpKind::Select, vec![pred, t, f], None)
    }
    pub fn convert(&mut self, a: ValueId, dtype: DType) -> ValueId {
        let dims = self.dims(a);
        self.push(OpKind::Convert, vec![a], Some(TensorType::new(dtype, &dims)))
    }

    pub fn dot(&mut self, d: DotDims, a: ValueId, b: ValueId) -> ValueId {
        self.push(OpKind::Dot(d), vec![a, b], None)
    }
    /// Plain matmul contracting `a`'s last dim with `b`'s first dim.
    pub fn matmul(&mut self, a: ValueId, b: ValueId) -> ValueId {
        let d = DotDims::matmul(self.ty(a).rank());
        self.dot(d, a, b)
    }

    pub fn reduce_sum(&mut self, a: ValueId, dims: Vec<usize>) -> ValueId {
        self.push(OpKind::Reduce { kind: ReduceKind::Sum, dims }, vec![a], None)
    }
    pub fn reduce_max(&mut self, a: ValueId, dims: Vec<usize>) -> ValueId {
        self.push(OpKind::Reduce { kind: ReduceKind::Max, dims }, vec![a], None)
    }

    pub fn broadcast(&mut self, a: ValueId, dims: Vec<usize>, result: TensorType) -> ValueId {
        self.push(OpKind::Broadcast { dims }, vec![a], Some(result))
    }
    /// Broadcast a scalar to `result` shape.
    pub fn splat(&mut self, a: ValueId, result: TensorType) -> ValueId {
        assert_eq!(self.ty(a).rank(), 0, "splat needs a scalar operand");
        self.push(OpKind::Broadcast { dims: vec![] }, vec![a], Some(result))
    }
    /// Broadcast `a` (rank r) into `result` aligning `a`'s dims with the
    /// TRAILING dims of `result` (numpy-style right alignment).
    pub fn broadcast_to(&mut self, a: ValueId, result: TensorType) -> ValueId {
        let r_op = self.ty(a).rank();
        let r_res = result.rank();
        assert!(r_op <= r_res);
        let dims: Vec<usize> = (r_res - r_op..r_res).collect();
        self.push(OpKind::Broadcast { dims }, vec![a], Some(result))
    }

    pub fn reshape(&mut self, a: ValueId, dims: &[i64]) -> ValueId {
        let dtype = self.ty(a).dtype;
        self.push(OpKind::Reshape, vec![a], Some(TensorType::new(dtype, dims)))
    }
    pub fn transpose(&mut self, a: ValueId, perm: Vec<usize>) -> ValueId {
        self.push(OpKind::Transpose { perm }, vec![a], None)
    }
    pub fn gather(&mut self, table: ValueId, indices: ValueId) -> ValueId {
        self.push(OpKind::Gather, vec![table, indices], None)
    }
    pub fn segment_sum(&mut self, data: ValueId, ids: ValueId, num: i64) -> ValueId {
        self.push(OpKind::SegmentSum { num }, vec![data, ids], None)
    }

    // ---- composite helpers (decomposed, as XLA would see them) ----------

    /// `a * scalar_const` (splat + mul).
    pub fn scale(&mut self, a: ValueId, c: f64) -> ValueId {
        let ty = self.ty(a).clone();
        let k = self.constant(c, ty);
        self.mul(a, k)
    }

    /// `a + scalar_const`.
    pub fn shift(&mut self, a: ValueId, c: f64) -> ValueId {
        let ty = self.ty(a).clone();
        let k = self.constant(c, ty);
        self.add(a, k)
    }

    /// Numerically-stable softmax along the last dim, decomposed into
    /// primitive ops (max, sub, exp, sum, div) as a compiler would see it.
    pub fn softmax_last(&mut self, a: ValueId) -> ValueId {
        let dims = self.dims(a);
        let last = dims.len() - 1;
        let m = self.reduce_max(a, vec![last]);
        let ty = self.ty(a).clone();
        let bcast_dims: Vec<usize> = (0..last).collect();
        let mb = self.broadcast(m, bcast_dims.clone(), ty.clone());
        let centered = self.sub(a, mb);
        let e = self.exp(centered);
        let s = self.reduce_sum(e, vec![last]);
        let sb = self.broadcast(s, bcast_dims, ty);
        self.div(e, sb)
    }

    /// GELU via the tanh approximation, fully decomposed.
    pub fn gelu(&mut self, x: ValueId) -> ValueId {
        // 0.5 * x * (1 + tanh(sqrt(2/pi) * (x + 0.044715 x^3)))
        let x2 = self.mul(x, x);
        let x3 = self.mul(x2, x);
        let inner_c = self.scale(x3, 0.044715);
        let inner = self.add(x, inner_c);
        let scaled = self.scale(inner, 0.7978845608028654);
        let t = self.tanh(scaled);
        let one_plus = self.shift(t, 1.0);
        let half_x = self.scale(x, 0.5);
        self.mul(half_x, one_plus)
    }

    /// Layer norm over the last dim (mean/var decomposition); `gamma`,
    /// `beta` are rank-1 of the last-dim size.
    pub fn layer_norm(&mut self, x: ValueId, gamma: ValueId, beta: ValueId) -> ValueId {
        let dims = self.dims(x);
        let last = dims.len() - 1;
        let n = dims[last] as f64;
        let ty = self.ty(x).clone();
        let bcast_dims: Vec<usize> = (0..last).collect();

        let s = self.reduce_sum(x, vec![last]);
        let mean = self.scale(s, 1.0 / n);
        let mean_b = self.broadcast(mean, bcast_dims.clone(), ty.clone());
        let centered = self.sub(x, mean_b);
        let sq = self.mul(centered, centered);
        let var_s = self.reduce_sum(sq, vec![last]);
        let var = self.scale(var_s, 1.0 / n);
        let var_eps = self.shift(var, 1e-5);
        let rstd = self.rsqrt(var_eps);
        let rstd_b = self.broadcast(rstd, bcast_dims, ty.clone());
        let normed = self.mul(centered, rstd_b);
        let gamma_b = self.broadcast_to(gamma, ty.clone());
        let beta_b = self.broadcast_to(beta, ty);
        let scaled = self.mul(normed, gamma_b);
        self.add(scaled, beta_b)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::verify::verify;

    #[test]
    fn linear_layer_builds_and_verifies() {
        let mut b = GraphBuilder::new("linear");
        let x = b.arg("x", TensorType::f32(&[8, 16]), ArgKind::Input);
        let w = b.arg("w", TensorType::f32(&[16, 64]), ArgKind::Parameter);
        let bias = b.arg("b", TensorType::f32(&[64]), ArgKind::Parameter);
        let y = b.matmul(x, w);
        let yty = b.ty(y).clone();
        let bb = b.broadcast_to(bias, yty);
        let out = b.add(y, bb);
        b.output(out);
        let f = b.finish();
        assert_eq!(f.value_type(out).dims, vec![8, 64]);
        verify(&f).unwrap();
    }

    #[test]
    fn softmax_and_gelu_verify() {
        let mut b = GraphBuilder::new("sm");
        let x = b.arg("x", TensorType::f32(&[2, 4, 8]), ArgKind::Input);
        let s = b.softmax_last(x);
        let g = b.gelu(s);
        b.output(g);
        let f = b.finish();
        verify(&f).unwrap();
        assert_eq!(f.value_type(g).dims, vec![2, 4, 8]);
    }

    #[test]
    fn layer_norm_verifies() {
        let mut b = GraphBuilder::new("ln");
        let x = b.arg("x", TensorType::f32(&[4, 32]), ArgKind::Input);
        let g = b.arg("gamma", TensorType::f32(&[32]), ArgKind::Parameter);
        let be = b.arg("beta", TensorType::f32(&[32]), ArgKind::Parameter);
        let y = b.layer_norm(x, g, be);
        b.output(y);
        verify(&b.finish()).unwrap();
        let _ = y;
    }

    #[test]
    fn scopes_propagate_to_nodes() {
        let mut b = GraphBuilder::new("s");
        let x = b.arg("x", TensorType::f32(&[2]), ArgKind::Input);
        b.push_scope("layer_0");
        b.push_scope("attn");
        let y = b.neg(x);
        b.pop_scope();
        b.pop_scope();
        b.output(y);
        let f = b.finish();
        let n = f.node_of(y).unwrap();
        assert_eq!(f.scope_path(f.nodes[n].scope), "layer_0/attn");
    }

    #[test]
    #[should_panic(expected = "builder:")]
    fn bad_shapes_panic_at_build_time() {
        let mut b = GraphBuilder::new("bad");
        let x = b.arg("x", TensorType::f32(&[2]), ArgKind::Input);
        let y = b.arg("y", TensorType::f32(&[3]), ArgKind::Input);
        b.add(x, y);
    }
}
