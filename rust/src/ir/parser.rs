//! Parser for the textual IR emitted by [`super::printer::print_func`]
//! (DESIGN.md §10). The grammar is the MLIR-flavoured notation of the
//! paper's Figure 2: a `func` header with typed `%argN {kind}` arguments,
//! a declared result-type list, numbered `%N = op ...` nodes with
//! per-op attributes and optional `// scope/path` trailers, and a final
//! `return`.
//!
//! The parser is strict and total: every accepted program is verified
//! (`verify::verify`) before it is returned, declared result types are
//! checked against the returned values, and every rejection carries a
//! 1-based line/column position with an expected/found message. For any
//! function `f` within DESIGN.md §10's printed-raw-field restrictions
//! (identifier function name; no newline / edge-whitespace scope
//! paths), `parse_func(print_func(&f))` reconstructs `f` exactly
//! (structural equality; see `Func`'s `PartialEq`), which is pinned by
//! the corpus round-trip CI wall and the property tests.

use super::graph::{Arg, ArgKind, Func, Node, ScopeId, ValueId, ROOT_SCOPE};
use super::op::{CmpDir, DotDims, OpKind, ReduceKind};
use super::types::{DType, TensorType};
use super::verify::verify;

/// A parse (or post-parse verification) failure, positioned in the
/// source text. `line`/`col` are 1-based.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ParseError {
    pub line: usize,
    pub col: usize,
    pub msg: String,
}

impl std::fmt::Display for ParseError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "parse error at line {}, column {}: {}", self.line, self.col, self.msg)
    }
}

impl std::error::Error for ParseError {}

/// Parse one textual function into a verified [`Func`].
pub fn parse_func(src: &str) -> Result<Func, ParseError> {
    Parser::new(src).parse()
}

struct Parser<'a> {
    src: &'a str,
    pos: usize,
    line: usize,
    col: usize,
}

impl<'a> Parser<'a> {
    fn new(src: &'a str) -> Parser<'a> {
        Parser { src, pos: 0, line: 1, col: 1 }
    }

    // ---- cursor primitives ----------------------------------------------

    fn rest(&self) -> &'a str {
        &self.src[self.pos..]
    }

    fn peek(&self) -> Option<char> {
        self.rest().chars().next()
    }

    fn bump(&mut self) -> Option<char> {
        let c = self.peek()?;
        self.pos += c.len_utf8();
        if c == '\n' {
            self.line += 1;
            self.col = 1;
        } else {
            self.col += 1;
        }
        Some(c)
    }

    fn err(&self, msg: impl Into<String>) -> ParseError {
        ParseError { line: self.line, col: self.col, msg: msg.into() }
    }

    fn err_at(&self, line: usize, col: usize, msg: impl Into<String>) -> ParseError {
        ParseError { line, col, msg: msg.into() }
    }

    /// Human description of what sits at the cursor, for "found ..."
    /// halves of diagnostics.
    fn found(&self) -> String {
        match self.peek() {
            None => "end of input".to_string(),
            Some('\n') => "end of line".to_string(),
            Some(_) => {
                let tok: String = self
                    .rest()
                    .chars()
                    .take_while(|c| !c.is_whitespace())
                    .take(12)
                    .collect();
                if tok.is_empty() {
                    "whitespace".to_string()
                } else {
                    format!("'{tok}'")
                }
            }
        }
    }

    /// Skip spaces, tabs, and newlines.
    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(' ') | Some('\t') | Some('\n') | Some('\r')) {
            self.bump();
        }
    }

    /// Skip spaces and tabs only (stay on the current line).
    fn skip_inline_ws(&mut self) {
        while matches!(self.peek(), Some(' ') | Some('\t')) {
            self.bump();
        }
    }

    fn eat(&mut self, c: char) -> bool {
        if self.peek() == Some(c) {
            self.bump();
            true
        } else {
            false
        }
    }

    fn expect(&mut self, c: char) -> Result<(), ParseError> {
        if self.eat(c) {
            Ok(())
        } else {
            Err(self.err(format!("expected '{c}', found {}", self.found())))
        }
    }

    /// Consume `s` if it sits at the cursor verbatim (no boundary check;
    /// used for `arg` in `%arg0`, where a digit follows).
    fn eat_str(&mut self, s: &str) -> bool {
        if !self.rest().starts_with(s) {
            return false;
        }
        for _ in 0..s.chars().count() {
            self.bump();
        }
        true
    }

    /// True if the keyword sits at the cursor with a word boundary after
    /// it; consumes it when it does.
    fn eat_kw(&mut self, kw: &str) -> bool {
        if !self.rest().starts_with(kw) {
            return false;
        }
        let after = self.rest()[kw.len()..].chars().next();
        if matches!(after, Some(c) if c.is_ascii_alphanumeric() || c == '_') {
            return false;
        }
        for _ in 0..kw.len() {
            self.bump();
        }
        true
    }

    fn expect_kw(&mut self, kw: &str) -> Result<(), ParseError> {
        if self.eat_kw(kw) {
            Ok(())
        } else {
            Err(self.err(format!("expected '{kw}', found {}", self.found())))
        }
    }

    /// Identifier: `[A-Za-z_][A-Za-z0-9_./-]*` (covers func names, op
    /// mnemonics, attribute keys, and arg-kind names).
    fn ident(&mut self) -> Result<String, ParseError> {
        match self.peek() {
            Some(c) if c.is_ascii_alphabetic() || c == '_' => {}
            _ => return Err(self.err(format!("expected identifier, found {}", self.found()))),
        }
        let mut s = String::new();
        while let Some(c) = self.peek() {
            if c.is_ascii_alphanumeric() || matches!(c, '_' | '.' | '/' | '-') {
                s.push(c);
                self.bump();
            } else {
                break;
            }
        }
        Ok(s)
    }

    fn uint(&mut self) -> Result<usize, ParseError> {
        if !matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            return Err(self.err(format!("expected integer, found {}", self.found())));
        }
        let mut n: usize = 0;
        while let Some(c) = self.peek() {
            if let Some(d) = c.to_digit(10) {
                n = n
                    .checked_mul(10)
                    .and_then(|n| n.checked_add(d as usize))
                    .ok_or_else(|| self.err("integer literal overflows"))?;
                self.bump();
            } else {
                break;
            }
        }
        Ok(n)
    }

    fn int(&mut self) -> Result<i64, ParseError> {
        let (line, col) = (self.line, self.col);
        let neg = self.eat('-');
        let n = self.uint()?;
        // Bounds-checked: `-(n as i64)` would overflow for i64::MIN's
        // magnitude, and larger literals must be rejected, not wrapped.
        let limit = (i64::MAX as usize) + usize::from(neg);
        if n > limit {
            return Err(self.err_at(line, col, "integer literal overflows i64"));
        }
        if neg {
            Ok((n as u64).wrapping_neg() as i64)
        } else {
            Ok(n as i64)
        }
    }

    /// Float literal in the form `f64`'s `Display`/`FromStr` round-trip
    /// uses (plain decimal, `inf`, `-inf`, `NaN`, scientific accepted).
    fn float(&mut self) -> Result<f64, ParseError> {
        let (line, col) = (self.line, self.col);
        let mut s = String::new();
        while let Some(c) = self.peek() {
            if c.is_ascii_alphanumeric() || matches!(c, '+' | '-' | '.') {
                s.push(c);
                self.bump();
            } else {
                break;
            }
        }
        s.parse::<f64>()
            .map_err(|_| self.err_at(line, col, format!("expected float literal, found '{s}'")))
    }

    /// Quoted string with `\"`, `\\`, `\n`, `\t`, and `\r` escapes
    /// (the exact set `printer::quote` emits).
    fn quoted(&mut self) -> Result<String, ParseError> {
        self.expect('"')?;
        let mut s = String::new();
        loop {
            match self.bump() {
                None | Some('\n') => {
                    return Err(self.err("unterminated string literal"));
                }
                Some('"') => return Ok(s),
                Some('\\') => match self.bump() {
                    Some('"') => s.push('"'),
                    Some('\\') => s.push('\\'),
                    Some('n') => s.push('\n'),
                    Some('t') => s.push('\t'),
                    Some('r') => s.push('\r'),
                    _ => {
                        return Err(
                            self.err("bad escape (\\\" \\\\ \\n \\t \\r are the valid escapes)")
                        )
                    }
                },
                Some(c) => s.push(c),
            }
        }
    }

    /// `[a, b, c]` of unsigned integers (the `{:?}` form of `Vec<usize>`).
    fn uint_list(&mut self) -> Result<Vec<usize>, ParseError> {
        self.expect('[')?;
        let mut xs = Vec::new();
        self.skip_inline_ws();
        if self.eat(']') {
            return Ok(xs);
        }
        loop {
            xs.push(self.uint()?);
            self.skip_inline_ws();
            if self.eat(',') {
                self.skip_inline_ws();
            } else {
                self.expect(']')?;
                return Ok(xs);
            }
        }
    }

    // ---- grammar --------------------------------------------------------

    /// `tensor<8x16xf32>` / `tensor<f32>`. Dtypes: f32, bf16, i32, i1.
    fn tensor_type(&mut self) -> Result<TensorType, ParseError> {
        let (line, col) = (self.line, self.col);
        self.expect_kw("tensor")
            .map_err(|_| self.err(format!("expected tensor type, found {}", self.found())))?;
        self.expect('<')?;
        let mut body = String::new();
        loop {
            match self.peek() {
                None | Some('\n') => {
                    return Err(self.err_at(line, col, "unterminated tensor type"));
                }
                Some('>') => {
                    self.bump();
                    break;
                }
                Some(c) => {
                    body.push(c);
                    self.bump();
                }
            }
        }
        let bad = |msg: String| self.err_at(line, col, msg);
        let pieces: Vec<&str> = body.split('x').collect();
        let (dims_s, dtype_s) = pieces.split_at(pieces.len() - 1);
        let dtype = match dtype_s[0] {
            "f32" => DType::F32,
            "bf16" => DType::BF16,
            "i32" => DType::I32,
            "i1" => DType::Bool,
            other => {
                return Err(bad(format!(
                    "bad tensor type 'tensor<{body}>': \
                     expected dtype f32|bf16|i32|i1, found '{other}'"
                )))
            }
        };
        let mut dims = Vec::with_capacity(dims_s.len());
        for d in dims_s {
            let n: i64 = d.parse().map_err(|_| {
                bad(format!("bad tensor type 'tensor<{body}>': bad dimension '{d}'"))
            })?;
            if n <= 0 {
                return Err(bad(format!(
                    "bad tensor type 'tensor<{body}>': dimensions must be positive"
                )));
            }
            dims.push(n);
        }
        Ok(TensorType { dtype, dims })
    }

    /// `%argN` or `%N`, resolved against what has been parsed so far.
    fn value_ref(&mut self, func: &Func) -> Result<ValueId, ParseError> {
        let (line, col) = (self.line, self.col);
        self.expect('%')?;
        if matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            let n = self.uint()?;
            if n >= func.num_nodes() {
                return Err(self.err_at(
                    line,
                    col,
                    format!(
                        "%{n} referenced before its definition (next node is %{})",
                        func.num_nodes()
                    ),
                ));
            }
            return Ok(func.value_of_node(n));
        }
        if !self.eat_str("arg") {
            return Err(self.err(format!("expected value id %N or %argN, found {}", self.found())));
        }
        let n = self.uint()?;
        if n >= func.num_args() {
            return Err(self.err_at(
                line,
                col,
                format!("%arg{n} out of range (function has {} arguments)", func.num_args()),
            ));
        }
        Ok(ValueId(n as u32))
    }

    /// `%argN: type {kind[, name = "..."][, scope = "..."]}`.
    fn arg(&mut self, func: &mut Func) -> Result<(), ParseError> {
        let (line, col) = (self.line, self.col);
        self.expect('%')?;
        if !self.eat_str("arg") {
            return Err(self.err(format!("expected %argN, found {}", self.found())));
        }
        let n = self.uint()?;
        if n != func.num_args() {
            return Err(self.err_at(
                line,
                col,
                format!(
                    "arguments must be numbered in order: expected %arg{}, found %arg{n}",
                    func.num_args()
                ),
            ));
        }
        self.skip_inline_ws();
        self.expect(':')?;
        self.skip_inline_ws();
        let ty = self.tensor_type()?;
        self.skip_inline_ws();
        self.expect('{')?;
        self.skip_inline_ws();
        let (kline, kcol) = (self.line, self.col);
        let kind_name = self.ident()?;
        let kind = match kind_name.as_str() {
            "param" => ArgKind::Parameter,
            "opt_state" => ArgKind::OptState,
            "input" => ArgKind::Input,
            "const" => ArgKind::Constant,
            other => {
                return Err(self.err_at(
                    kline,
                    kcol,
                    format!("expected arg kind param|opt_state|input|const, found '{other}'"),
                ))
            }
        };
        let mut name: Option<String> = None;
        let mut scope: Option<String> = None;
        self.skip_inline_ws();
        while self.eat(',') {
            self.skip_inline_ws();
            let (aline, acol) = (self.line, self.col);
            let key = self.ident()?;
            self.skip_inline_ws();
            self.expect('=')?;
            self.skip_inline_ws();
            let val = self.quoted()?;
            match key.as_str() {
                "name" if name.is_none() => name = Some(val),
                "scope" if scope.is_none() => scope = Some(val),
                "name" | "scope" => {
                    return Err(self.err_at(aline, acol, format!("duplicate '{key}' attribute")))
                }
                other => {
                    return Err(self.err_at(
                        aline,
                        acol,
                        format!("expected 'name' or 'scope' attribute, found '{other}'"),
                    ))
                }
            }
            self.skip_inline_ws();
        }
        self.expect('}')?;
        let scope = match scope {
            None => ROOT_SCOPE,
            Some(path) => func.intern_scope(&path),
        };
        let name = name.unwrap_or_else(|| format!("arg{n}"));
        func.args.push(Arg { name, ty, kind, scope });
        Ok(())
    }

    /// Attributes for `opname`, consuming the `{...}` block when the op
    /// requires one. Ops without attributes reject a block outright.
    fn op_with_attrs(
        &mut self,
        opname: &str,
        oline: usize,
        ocol: usize,
    ) -> Result<OpKind, ParseError> {
        // Ops without attributes: map the mnemonic, then reject a block.
        let simple = match opname {
            "add" => Some(OpKind::Add),
            "sub" => Some(OpKind::Sub),
            "mul" => Some(OpKind::Mul),
            "div" => Some(OpKind::Div),
            "max" => Some(OpKind::Max),
            "min" => Some(OpKind::Min),
            "neg" => Some(OpKind::Neg),
            "exp" => Some(OpKind::Exp),
            "log" => Some(OpKind::Log),
            "tanh" => Some(OpKind::Tanh),
            "rsqrt" => Some(OpKind::Rsqrt),
            "sqrt" => Some(OpKind::Sqrt),
            "abs" => Some(OpKind::Abs),
            "select" => Some(OpKind::Select),
            "convert" => Some(OpKind::Convert),
            "reshape" => Some(OpKind::Reshape),
            "gather" => Some(OpKind::Gather),
            _ => None,
        };
        if let Some(op) = simple {
            if self.peek() == Some('{') {
                return Err(self.err(format!("op '{opname}' takes no attributes")));
            }
            return Ok(op);
        }
        match opname {
            "const" => {
                self.attr_open("value")?;
                let value = self.float()?;
                self.attr_close()?;
                Ok(OpKind::Const { value })
            }
            "iota" => {
                self.attr_open("dim")?;
                let dim = self.uint()?;
                self.attr_close()?;
                Ok(OpKind::Iota { dim })
            }
            "compare" => {
                self.attr_open("dir")?;
                let (dline, dcol) = (self.line, self.col);
                let dir_name = self.ident()?;
                let dir = match dir_name.as_str() {
                    "Lt" => CmpDir::Lt,
                    "Le" => CmpDir::Le,
                    "Gt" => CmpDir::Gt,
                    "Ge" => CmpDir::Ge,
                    "Eq" => CmpDir::Eq,
                    "Ne" => CmpDir::Ne,
                    other => {
                        return Err(self.err_at(
                            dline,
                            dcol,
                            format!("expected dir Lt|Le|Gt|Ge|Eq|Ne, found '{other}'"),
                        ))
                    }
                };
                self.attr_close()?;
                Ok(OpKind::Compare { dir })
            }
            "dot" => {
                self.attr_open("batch")?;
                let lhs_batch = self.uint_list()?;
                self.expect('x')?;
                let rhs_batch = self.uint_list()?;
                self.skip_inline_ws();
                self.expect(',')?;
                self.skip_inline_ws();
                self.expect_kw("contract")?;
                self.skip_inline_ws();
                self.expect('=')?;
                self.skip_inline_ws();
                let lhs_contract = self.uint_list()?;
                self.expect('x')?;
                let rhs_contract = self.uint_list()?;
                self.attr_close()?;
                Ok(OpKind::Dot(DotDims { lhs_batch, rhs_batch, lhs_contract, rhs_contract }))
            }
            "reduce_sum" | "reduce_max" => {
                self.attr_open("dims")?;
                let dims = self.uint_list()?;
                self.attr_close()?;
                let kind = if opname == "reduce_sum" { ReduceKind::Sum } else { ReduceKind::Max };
                Ok(OpKind::Reduce { kind, dims })
            }
            "broadcast_in_dim" => {
                self.attr_open("broadcast_dims")?;
                let dims = self.uint_list()?;
                self.attr_close()?;
                Ok(OpKind::Broadcast { dims })
            }
            "transpose" => {
                self.attr_open("perm")?;
                let perm = self.uint_list()?;
                self.attr_close()?;
                Ok(OpKind::Transpose { perm })
            }
            "segment_sum" => {
                self.attr_open("num")?;
                let num = self.int()?;
                self.attr_close()?;
                Ok(OpKind::SegmentSum { num })
            }
            other => Err(self.err_at(oline, ocol, format!("unknown op '{other}'"))),
        }
    }

    /// `{key = ` of a required attribute block.
    fn attr_open(&mut self, key: &str) -> Result<(), ParseError> {
        self.skip_inline_ws();
        if !self.eat('{') {
            return Err(
                self.err(format!("expected attributes '{{{key} = ...}}', found {}", self.found()))
            );
        }
        self.skip_inline_ws();
        self.expect_kw(key)?;
        self.skip_inline_ws();
        self.expect('=')?;
        self.skip_inline_ws();
        Ok(())
    }

    fn attr_close(&mut self) -> Result<(), ParseError> {
        self.skip_inline_ws();
        self.expect('}')
    }

    /// `%N = op [operands] [attrs] : type [// scope]`.
    fn node(&mut self, func: &mut Func) -> Result<(usize, usize), ParseError> {
        let (line, col) = (self.line, self.col);
        self.expect('%')?;
        let n = self.uint()?;
        if n != func.num_nodes() {
            return Err(self.err_at(
                line,
                col,
                format!(
                    "nodes must be numbered in order: expected %{}, found %{n}",
                    func.num_nodes()
                ),
            ));
        }
        self.skip_inline_ws();
        self.expect('=')?;
        self.skip_inline_ws();
        let (oline, ocol) = (self.line, self.col);
        let opname = self.ident()?;
        let mut inputs = Vec::new();
        self.skip_inline_ws();
        while self.peek() == Some('%') {
            inputs.push(self.value_ref(func)?);
            self.skip_inline_ws();
            if self.eat(',') {
                self.skip_inline_ws();
                if self.peek() != Some('%') {
                    return Err(self.err(format!(
                        "expected value id after ',', found {}",
                        self.found()
                    )));
                }
            } else {
                break;
            }
        }
        let op = self.op_with_attrs(&opname, oline, ocol)?;
        self.skip_inline_ws();
        self.expect(':')?;
        self.skip_inline_ws();
        let ty = self.tensor_type()?;
        let scope = self.line_scope(func)?;
        func.nodes.push(Node { op, inputs, ty, scope });
        Ok((line, col))
    }

    /// Optional `// scope/path` trailer, up to end of line.
    fn line_scope(&mut self, func: &mut Func) -> Result<ScopeId, ParseError> {
        self.skip_inline_ws();
        if !self.rest().starts_with("//") {
            return Ok(ROOT_SCOPE);
        }
        self.bump();
        self.bump();
        self.skip_inline_ws();
        let mut path = String::new();
        while let Some(c) = self.peek() {
            if c == '\n' {
                break;
            }
            path.push(c);
            self.bump();
        }
        let path = path.trim_end().to_string();
        if path.is_empty() {
            return Err(self.err("empty scope path after '//'"));
        }
        Ok(func.intern_scope(&path))
    }

    fn parse(&mut self) -> Result<Func, ParseError> {
        self.skip_ws();
        self.expect_kw("func")?;
        self.skip_inline_ws();
        self.expect('@')?;
        let name = self.ident()?;
        let mut func = Func::new(name);
        self.skip_inline_ws();
        self.expect('(')?;
        self.skip_ws();
        if self.peek() != Some(')') {
            loop {
                self.arg(&mut func)?;
                self.skip_ws();
                if self.eat(',') {
                    self.skip_ws();
                } else {
                    break;
                }
            }
        }
        self.expect(')')?;
        self.skip_ws();
        self.expect_kw("->")?;
        self.skip_ws();
        self.expect('(')?;
        let mut out_tys: Vec<(TensorType, usize, usize)> = Vec::new();
        self.skip_ws();
        if self.peek() != Some(')') {
            loop {
                let (line, col) = (self.line, self.col);
                out_tys.push((self.tensor_type()?, line, col));
                self.skip_ws();
                if self.eat(',') {
                    self.skip_ws();
                } else {
                    break;
                }
            }
        }
        self.expect(')')?;
        self.skip_ws();
        self.expect('{')?;
        let mut node_pos: Vec<(usize, usize)> = Vec::new();
        let (rline, rcol) = loop {
            self.skip_ws();
            let (line, col) = (self.line, self.col);
            if self.eat_kw("return") {
                break (line, col);
            }
            if self.peek() == Some('%') {
                node_pos.push(self.node(&mut func)?);
            } else {
                return Err(self.err(format!(
                    "expected '%N = op ...' or 'return', found {}",
                    self.found()
                )));
            }
        };
        self.skip_inline_ws();
        while self.peek() == Some('%') {
            let v = self.value_ref(&func)?;
            func.outputs.push(v);
            self.skip_inline_ws();
            if self.eat(',') {
                self.skip_inline_ws();
                if self.peek() != Some('%') {
                    return Err(self.err(format!(
                        "expected value id after ',', found {}",
                        self.found()
                    )));
                }
            } else {
                break;
            }
        }
        self.skip_ws();
        self.expect('}')?;
        self.skip_ws();
        if self.peek().is_some() {
            return Err(self.err(format!("unexpected input after '}}': {}", self.found())));
        }

        // Declared result types must match the returned values.
        if out_tys.len() != func.outputs.len() {
            return Err(self.err_at(
                rline,
                rcol,
                format!(
                    "return has {} values but the header declares {} result types",
                    func.outputs.len(),
                    out_tys.len()
                ),
            ));
        }
        for ((ty, tline, tcol), &o) in out_tys.iter().zip(&func.outputs) {
            let actual = func.value_type(o);
            if actual != ty {
                return Err(self.err_at(
                    *tline,
                    *tcol,
                    format!(
                        "declared result type {ty} does not match returned value's type {actual}"
                    ),
                ));
            }
        }

        // Full verification, mapped back to source positions.
        verify(&func).map_err(|e| match &e {
            super::verify::IrError::Verify { node, .. } if *node < node_pos.len() => {
                let (line, col) = node_pos[*node];
                self.err_at(line, col, e.to_string())
            }
            _ => self.err_at(rline, rcol, e.to_string()),
        })?;
        Ok(func)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::builder::GraphBuilder;
    use crate::ir::printer::print_func;

    fn roundtrip(f: &Func) -> Func {
        let text = print_func(f);
        match parse_func(&text) {
            Ok(g) => g,
            Err(e) => panic!("{e}\nsource:\n{text}"),
        }
    }

    #[test]
    fn round_trips_a_linear_layer() {
        let mut b = GraphBuilder::new("main");
        let x = b.arg("x", TensorType::f32(&[8, 16]), ArgKind::Input);
        let w = b.arg("w", TensorType::f32(&[16, 64]), ArgKind::Parameter);
        let bias = b.arg("b", TensorType::f32(&[64]), ArgKind::Parameter);
        let dot = b.matmul(x, w);
        let ty = b.ty(dot).clone();
        let bb = b.broadcast_to(bias, ty);
        let out = b.add(dot, bb);
        b.output(out);
        let f = b.finish();
        assert_eq!(roundtrip(&f), f);
    }

    #[test]
    fn round_trips_scoped_args_and_nodes() {
        let mut b = GraphBuilder::new("scoped");
        b.push_scope("enc");
        b.push_scope("dense_0");
        let w = b.arg("enc/dense_0/w", TensorType::f32(&[4, 4]), ArgKind::Parameter);
        b.pop_scope();
        b.pop_scope();
        let x = b.arg("x", TensorType::f32(&[4, 4]), ArgKind::Input);
        b.push_scope("enc");
        let y = b.matmul(x, w);
        b.push_scope("act");
        let z = b.tanh(y);
        b.pop_scope();
        b.pop_scope();
        b.output(z);
        let f = b.finish();
        let g = roundtrip(&f);
        assert_eq!(g, f);
        let zn = g.node_of(ValueId(g.num_args() as u32 + 1)).unwrap();
        assert_eq!(g.scope_path(g.nodes[zn].scope), "enc/act");
        assert_eq!(g.scope_path(g.args[0].scope), "enc/dense_0");
        assert_eq!(g.args[0].name, "enc/dense_0/w");
    }

    #[test]
    fn round_trips_zero_arg_and_multi_output_functions() {
        let mut b = GraphBuilder::new("zero_arg");
        let c = b.constant(2.5, TensorType::f32(&[4]));
        let i = b.iota(0, TensorType::f32(&[4]));
        let s = b.add(c, i);
        b.output(s);
        b.output(c);
        let f = b.finish();
        assert_eq!(roundtrip(&f), f);
    }

    #[test]
    fn parses_hand_written_text_with_defaults() {
        let f = parse_func(
            "func @t(%arg0: tensor<4xf32> {input})\n    -> (tensor<4xf32>) {\n  \
             %0 = neg %arg0 : tensor<4xf32>\n  return %0\n}\n",
        )
        .unwrap();
        assert_eq!(f.args[0].name, "arg0", "missing name attr defaults to argN");
        assert_eq!(f.num_nodes(), 1);
    }

    #[test]
    fn diagnostics_carry_line_and_column() {
        // Unknown op on line 3.
        let e = parse_func(
            "func @t(%arg0: tensor<4xf32> {input})\n    -> (tensor<4xf32>) {\n  \
             %0 = wiggle %arg0 : tensor<4xf32>\n  return %0\n}\n",
        )
        .unwrap_err();
        assert_eq!((e.line, e.col), (3, 8), "{e}");
        assert!(e.msg.contains("unknown op 'wiggle'"), "{e}");

        // Forward reference.
        let e = parse_func(
            "func @t(%arg0: tensor<4xf32> {input})\n    -> (tensor<4xf32>) {\n  \
             %0 = add %arg0, %1 : tensor<4xf32>\n  return %0\n}\n",
        )
        .unwrap_err();
        assert_eq!(e.line, 3, "{e}");
        assert!(e.msg.contains("referenced before its definition"), "{e}");

        // Type error found by the verifier maps to the node's line.
        let e = parse_func(
            "func @t(%arg0: tensor<4xf32> {input})\n    -> (tensor<8xf32>) {\n  \
             %0 = neg %arg0 : tensor<8xf32>\n  return %0\n}\n",
        )
        .unwrap_err();
        assert_eq!((e.line, e.col), (3, 3), "{e}");
        assert!(e.msg.contains("stored type"), "{e}");

        // Declared result type mismatch points at the declaration.
        let e = parse_func(
            "func @t(%arg0: tensor<4xf32> {input})\n    -> (tensor<8xf32>) {\n  \
             %0 = neg %arg0 : tensor<4xf32>\n  return %0\n}\n",
        )
        .unwrap_err();
        assert_eq!(e.line, 2, "{e}");
        assert!(e.msg.contains("declared result type"), "{e}");

        // Malformed header.
        let e = parse_func("func main()").unwrap_err();
        assert_eq!((e.line, e.col), (1, 6), "{e}");
        assert!(e.msg.contains("expected '@'"), "{e}");

        // Bad arg kind.
        let e = parse_func(
            "func @t(%arg0: tensor<4xf32> {weight})\n    -> () {\n  return\n}\n",
        )
        .unwrap_err();
        assert_eq!((e.line, e.col), (1, 31), "{e}");
        assert!(e.msg.contains("param|opt_state|input|const"), "{e}");

        // Bad dtype.
        let e = parse_func(
            "func @t(%arg0: tensor<4xf64> {input})\n    -> () {\n  return\n}\n",
        )
        .unwrap_err();
        assert_eq!(e.line, 1, "{e}");
        assert!(e.msg.contains("f32|bf16|i32|i1"), "{e}");
    }

    #[test]
    fn rejects_out_of_order_numbering_and_trailing_garbage() {
        let e = parse_func(
            "func @t(%arg0: tensor<4xf32> {input})\n    -> (tensor<4xf32>) {\n  \
             %1 = neg %arg0 : tensor<4xf32>\n  return %1\n}\n",
        )
        .unwrap_err();
        assert!(e.msg.contains("expected %0"), "{e}");

        let e = parse_func(
            "func @t(%arg1: tensor<4xf32> {input})\n    -> () {\n  return\n}\n",
        )
        .unwrap_err();
        assert!(e.msg.contains("expected %arg0"), "{e}");

        let e = parse_func(
            "func @t(%arg0: tensor<4xf32> {input})\n    -> () {\n  return\n}\ntrailing\n",
        )
        .unwrap_err();
        assert_eq!(e.line, 5, "{e}");
        assert!(e.msg.contains("unexpected input"), "{e}");
    }

    #[test]
    fn return_arity_must_match_declared_types() {
        let e = parse_func(
            "func @t(%arg0: tensor<4xf32> {input})\n    -> (tensor<4xf32>) {\n  return\n}\n",
        )
        .unwrap_err();
        assert_eq!(e.line, 3, "{e}");
        assert!(e.msg.contains("declares 1 result type"), "{e}");
    }

    #[test]
    fn pathological_names_round_trip_and_big_ints_are_rejected() {
        // Quotes, backslashes, and line/tab whitespace in an argument
        // name all survive the escape round-trip.
        let mut b = GraphBuilder::new("q");
        let x = b.arg("a\nb\t\"c\\d", TensorType::f32(&[2]), ArgKind::Input);
        let y = b.neg(x);
        b.output(y);
        let f = b.finish();
        assert_eq!(roundtrip(&f), f);

        // Integer attributes overflow to an error, never a wrap/panic.
        let src = "func @t(%arg0: tensor<4x8xf32> {input}, %arg1: tensor<4xi32> {input})\n    \
                   -> () {\n  \
                   %0 = segment_sum %arg0, %arg1 {num = 18446744073709551615} : \
                   tensor<2x8xf32>\n  return\n}\n";
        let e = parse_func(src).unwrap_err();
        assert!(e.msg.contains("overflows i64"), "{e}");
    }

    #[test]
    fn const_values_round_trip_exactly() {
        // NaN and -0.0 included: Func equality compares Const values by
        // bit pattern with NaNs identified, so the round-trip contract
        // holds for every value the printer can emit.
        let values = [
            0.0,
            -0.0,
            -0.5,
            1e-5,
            0.044715,
            0.7978845608028654,
            123456789.25,
            f64::INFINITY,
            f64::NEG_INFINITY,
            f64::NAN,
        ];
        for v in values {
            let mut b = GraphBuilder::new("c");
            let c = b.constant(v, TensorType::f32(&[2]));
            b.output(c);
            let f = b.finish();
            let g = roundtrip(&f);
            assert_eq!(g, f, "const {v} failed to round-trip");
        }
    }
}
