//! Reverse-mode automatic differentiation on the base dialect.
//!
//! `gradients` extends a function under construction with backward nodes
//! computing d(loss)/d(wrt_i) for a scalar `loss`. Every op in the dialect
//! has a total VJP rule here, so the model zoo can emit full training
//! graphs (the paper partitions the *update* function: params, grads,
//! optimiser state — 1150 arguments for its 24-layer transformer).
//!
//! Backward nodes inherit the named scope of their forward node, which is
//! what makes layer-grouping (paper Figures 8–9) apply to the backward
//! pass as well.

use super::builder::GraphBuilder;
use super::graph::ValueId;
use super::op::{CmpDir, DotDims, OpKind, ReduceKind};

/// Compute gradients of scalar `loss` w.r.t. each value in `wrt`.
/// Returns one `Option<ValueId>` per entry (None = loss independent of it).
pub fn gradients(
    b: &mut GraphBuilder,
    loss: ValueId,
    wrt: &[ValueId],
) -> Vec<Option<ValueId>> {
    assert_eq!(b.ty(loss).rank(), 0, "loss must be scalar");
    let num_fwd_nodes = b.func.num_nodes();
    let num_fwd_values = b.func.num_values();

    // Cotangent accumulator per forward value.
    let mut grad: Vec<Option<ValueId>> = vec![None; num_fwd_values];
    let one = {
        let ty = b.ty(loss).clone();
        b.constant(1.0, ty)
    };
    grad[loss.index()] = Some(one);

    // Reverse sweep over the forward nodes only.
    for ni in (0..num_fwd_nodes).rev() {
        let out_v = b.func.value_of_node(ni);
        let g = match grad[out_v.index()] {
            Some(g) => g,
            None => continue,
        };
        let node_op = b.func.nodes[ni].op.clone();
        let inputs = b.func.nodes[ni].inputs.clone();
        let scope = b.func.nodes[ni].scope;
        b.push_scope_id(scope);
        let input_grads = vjp(b, &node_op, &inputs, out_v, g);
        b.pop_scope();
        for (inp, ig) in inputs.iter().zip(input_grads) {
            if let Some(ig) = ig {
                accumulate(b, &mut grad, *inp, ig);
            }
        }
    }

    wrt.iter().map(|v| grad[v.index()]).collect()
}

fn accumulate(b: &mut GraphBuilder, grad: &mut [Option<ValueId>], v: ValueId, g: ValueId) {
    grad[v.index()] = Some(match grad[v.index()] {
        None => g,
        Some(prev) => b.add(prev, g),
    });
}

/// Vector-Jacobian product: cotangents for each input of `op` given the
/// cotangent `g` of its output `out_v`.
fn vjp(
    b: &mut GraphBuilder,
    op: &OpKind,
    inputs: &[ValueId],
    out_v: ValueId,
    g: ValueId,
) -> Vec<Option<ValueId>> {
    match op {
        OpKind::Const { .. } | OpKind::Iota { .. } => vec![],
        OpKind::Add => vec![Some(g), Some(g)],
        OpKind::Sub => {
            let ng = b.neg(g);
            vec![Some(g), Some(ng)]
        }
        OpKind::Mul => {
            let ga = b.mul(g, inputs[1]);
            let gb = b.mul(g, inputs[0]);
            vec![Some(ga), Some(gb)]
        }
        OpKind::Div => {
            // d/da (a/b) = 1/b ; d/db = -a/b^2 = -(a/b)/b = -out/b
            let ga = b.div(g, inputs[1]);
            let gy = b.mul(g, out_v);
            let gyb = b.div(gy, inputs[1]);
            let gb = b.neg(gyb);
            vec![Some(ga), Some(gb)]
        }
        OpKind::Max | OpKind::Min => {
            let dir = if matches!(op, OpKind::Max) { CmpDir::Ge } else { CmpDir::Le };
            let pred = b.compare(dir, inputs[0], inputs[1]);
            let ty = b.ty(g).clone();
            let zero = b.constant(0.0, ty);
            let ga = b.select(pred, g, zero);
            let gb = b.select(pred, zero, g);
            vec![Some(ga), Some(gb)]
        }
        OpKind::Neg => {
            let ng = b.neg(g);
            vec![Some(ng)]
        }
        OpKind::Exp => {
            // y = e^x, dy = y
            let gx = b.mul(g, out_v);
            vec![Some(gx)]
        }
        OpKind::Log => {
            let gx = b.div(g, inputs[0]);
            vec![Some(gx)]
        }
        OpKind::Tanh => {
            // 1 - y^2
            let y2 = b.mul(out_v, out_v);
            let ty = b.ty(y2).clone();
            let one = b.constant(1.0, ty);
            let d = b.sub(one, y2);
            let gx = b.mul(g, d);
            vec![Some(gx)]
        }
        OpKind::Rsqrt => {
            // y = x^{-1/2}; dy/dx = -1/2 x^{-3/2} = -0.5 y^3
            let y2 = b.mul(out_v, out_v);
            let y3 = b.mul(y2, out_v);
            let s = b.scale(y3, -0.5);
            let gx = b.mul(g, s);
            vec![Some(gx)]
        }
        OpKind::Sqrt => {
            // dy/dx = 0.5 / y
            let gy = b.scale(g, 0.5);
            let gx = b.div(gy, out_v);
            vec![Some(gx)]
        }
        OpKind::Abs => {
            let ty = b.ty(inputs[0]).clone();
            let zero = b.constant(0.0, ty.clone());
            let pred = b.compare(CmpDir::Ge, inputs[0], zero);
            let ng = b.neg(g);
            let gx = b.select(pred, g, ng);
            vec![Some(gx)]
        }
        OpKind::Compare { .. } => vec![None, None],
        OpKind::Select => {
            let ty = b.ty(g).clone();
            let zero = b.constant(0.0, ty);
            let gt = b.select(inputs[0], g, zero);
            let ge = b.select(inputs[0], zero, g);
            vec![None, Some(gt), Some(ge)]
        }
        OpKind::Convert => {
            let dtype = b.ty(inputs[0]).dtype;
            let gx = b.convert(g, dtype);
            vec![Some(gx)]
        }
        OpKind::Dot(d) => vjp_dot(b, d, inputs, g),
        OpKind::Reduce { kind: ReduceKind::Sum, dims } => {
            let in_ty = b.ty(inputs[0]).clone();
            let kept: Vec<usize> = (0..in_ty.rank()).filter(|i| !dims.contains(i)).collect();
            let gx = b.broadcast(g, kept, in_ty);
            vec![Some(gx)]
        }
        OpKind::Reduce { kind: ReduceKind::Max, dims } => {
            // indicator(x == broadcast(y)) * broadcast(g)
            let in_ty = b.ty(inputs[0]).clone();
            let kept: Vec<usize> = (0..in_ty.rank()).filter(|i| !dims.contains(i)).collect();
            let yb = b.broadcast(out_v, kept.clone(), in_ty.clone());
            let gb = b.broadcast(g, kept, in_ty.clone());
            let pred = b.compare(CmpDir::Eq, inputs[0], yb);
            let zero = b.constant(0.0, in_ty);
            let gx = b.select(pred, gb, zero);
            vec![Some(gx)]
        }
        OpKind::Broadcast { dims } => {
            let in_ty = b.ty(inputs[0]).clone();
            let out_rank = b.ty(out_v).rank();
            // Only pure (non size-1-stretching, increasing-dims) broadcasts
            // are emitted by the builder helpers.
            debug_assert!(dims.windows(2).all(|w| w[0] < w[1]));
            for (i, &rd) in dims.iter().enumerate() {
                debug_assert_eq!(
                    b.ty(inputs[0]).dims[i],
                    b.ty(out_v).dims[rd],
                    "size-1 stretching broadcast has no autodiff rule"
                );
            }
            let reduce_dims: Vec<usize> = (0..out_rank).filter(|d| !dims.contains(d)).collect();
            let gx = if reduce_dims.is_empty() {
                g
            } else {
                b.reduce_sum(g, reduce_dims)
            };
            // After reducing, dims are the kept (mapped) dims in increasing
            // order == operand dims order.
            let _ = in_ty;
            vec![Some(gx)]
        }
        OpKind::Reshape => {
            let in_dims = b.dims(inputs[0]);
            let gx = b.reshape(g, &in_dims);
            vec![Some(gx)]
        }
        OpKind::Transpose { perm } => {
            let mut inv = vec![0usize; perm.len()];
            for (i, &p) in perm.iter().enumerate() {
                inv[p] = i;
            }
            let gx = b.transpose(g, inv);
            vec![Some(gx)]
        }
        OpKind::Gather => {
            // grad_table[v, ...] = sum over lookups of g rows with index v.
            let table_ty = b.ty(inputs[0]).clone();
            let ids_ty = b.ty(inputs[1]).clone();
            let e_total: i64 = ids_ty.dims.iter().product();
            let mut flat_g_dims = vec![e_total];
            flat_g_dims.extend_from_slice(&table_ty.dims[1..]);
            let gf = b.reshape(g, &flat_g_dims);
            let ids_flat = b.reshape(inputs[1], &[e_total]);
            let gt = b.segment_sum(gf, ids_flat, table_ty.dims[0]);
            vec![Some(gt), None]
        }
        OpKind::SegmentSum { .. } => {
            // grad_data[e, ...] = g[ids[e], ...]
            let gd = b.gather(g, inputs[1]);
            vec![Some(gd), None]
        }
    }
}

/// VJP for dot_general. Output canonical layout is
/// `[batch..., lhs_free..., rhs_free...]`.
fn vjp_dot(
    b: &mut GraphBuilder,
    d: &DotDims,
    inputs: &[ValueId],
    g: ValueId,
) -> Vec<Option<ValueId>> {
    let lhs = inputs[0];
    let rhs = inputs[1];
    let lhs_rank = b.ty(lhs).rank();
    let rhs_rank = b.ty(rhs).rank();
    let lhs_free = d.free_dims(lhs_rank, &d.lhs_batch, &d.lhs_contract);
    let rhs_free = d.free_dims(rhs_rank, &d.rhs_batch, &d.rhs_contract);
    let nb = d.lhs_batch.len();
    let nlf = lhs_free.len();
    let nrf = rhs_free.len();

    // ---- grad lhs: dot(g, rhs) contracting g's rhs_free block with rhs's
    // free dims; canonical result layout [batch, lhs_free, lhs_contract].
    let d_l = DotDims {
        lhs_batch: (0..nb).collect(),
        rhs_batch: d.rhs_batch.clone(),
        lhs_contract: (nb + nlf..nb + nlf + nrf).collect(),
        rhs_contract: rhs_free.clone(),
    };
    let gl_canon = b.dot(d_l, g, rhs);
    // Transpose canonical -> lhs layout: lhs dim `dim` sits at canonical
    // position pos(dim); transpose result dim i = operand dim perm[i],
    // we want result dim `dim` = canonical pos(dim).
    let mut perm_l = vec![0usize; lhs_rank];
    for (k, &bd) in d.lhs_batch.iter().enumerate() {
        perm_l[bd] = k;
    }
    for (k, &fd) in lhs_free.iter().enumerate() {
        perm_l[fd] = nb + k;
    }
    for (k, &cd) in d.lhs_contract.iter().enumerate() {
        perm_l[cd] = nb + nlf + k;
    }
    let gl = if perm_l.iter().enumerate().all(|(i, &p)| i == p) {
        gl_canon
    } else {
        b.transpose(gl_canon, perm_l)
    };

    // ---- grad rhs: dot(g, lhs) contracting g's lhs_free block with lhs's
    // free dims; canonical result layout [batch, rhs_free, rhs_contract].
    let d_r = DotDims {
        lhs_batch: (0..nb).collect(),
        rhs_batch: d.lhs_batch.clone(),
        lhs_contract: (nb..nb + nlf).collect(),
        rhs_contract: lhs_free,
    };
    let gr_canon = b.dot(d_r, g, lhs);
    let mut perm_r = vec![0usize; rhs_rank];
    for (k, &bd) in d.rhs_batch.iter().enumerate() {
        perm_r[bd] = k;
    }
    for (k, &fd) in rhs_free.iter().enumerate() {
        perm_r[fd] = nb + k;
    }
    for (k, &cd) in d.rhs_contract.iter().enumerate() {
        perm_r[cd] = nb + nrf + k;
    }
    let gr = if perm_r.iter().enumerate().all(|(i, &p)| i == p) {
        gr_canon
    } else {
        b.transpose(gr_canon, perm_r)
    };

    vec![Some(gl), Some(gr)]
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::graph::ArgKind;
    use crate::ir::interp::{eval_all, Tensor};
    use crate::ir::types::TensorType;
    use crate::ir::verify::verify;
    use crate::util::rng::Rng;

    /// Check d(loss)/d(args) against central finite differences.
    fn check_grads(build: impl Fn(&mut GraphBuilder) -> (Vec<ValueId>, ValueId), seed: u64) {
        let mut b = GraphBuilder::new("grad_test");
        let (wrt, loss) = build(&mut b);
        let grads = gradients(&mut b, loss, &wrt);
        // Output loss and each gradient.
        b.output(loss);
        let grad_ids: Vec<ValueId> = grads.iter().map(|g| g.expect("grad missing")).collect();
        for &g in &grad_ids {
            b.output(g);
        }
        let f = b.finish();
        verify(&f).unwrap();

        let mut rng = Rng::new(seed);
        let args: Vec<Tensor> = f
            .args
            .iter()
            .map(|a| {
                let n = a.ty.num_elements() as usize;
                Tensor::new(&a.ty.dims, (0..n).map(|_| rng.gen_f64() * 2.0 - 1.0).collect())
            })
            .collect();
        let vals = eval_all(&f, &args);
        let eps = 1e-5;
        for (wi, &w) in wrt.iter().enumerate() {
            let analytic = &vals[grad_ids[wi].index()];
            let ai = w.index(); // wrt must be args in this harness
            for e in 0..args[ai].len() {
                let mut plus = args.clone();
                plus[ai].data[e] += eps;
                let mut minus = args.clone();
                minus[ai].data[e] -= eps;
                let lp = eval_all(&f, &plus)[loss.index()].data[0];
                let lm = eval_all(&f, &minus)[loss.index()].data[0];
                let fd = (lp - lm) / (2.0 * eps);
                let an = analytic.data[e];
                assert!(
                    (fd - an).abs() < 1e-4 * (1.0 + fd.abs().max(an.abs())),
                    "grad mismatch wrt arg{ai}[{e}]: fd={fd} analytic={an}"
                );
            }
        }
    }

    #[test]
    fn grad_matmul_bias_reduce() {
        check_grads(
            |b| {
                let x = b.arg("x", TensorType::f32(&[3, 4]), ArgKind::Input);
                let w = b.arg("w", TensorType::f32(&[4, 2]), ArgKind::Parameter);
                let bias = b.arg("b", TensorType::f32(&[2]), ArgKind::Parameter);
                let y = b.matmul(x, w);
                let yty = b.ty(y).clone();
                let bb = b.broadcast_to(bias, yty);
                let z = b.add(y, bb);
                let loss = b.reduce_sum(z, vec![0, 1]);
                (vec![w, bias], loss)
            },
            1,
        );
    }

    #[test]
    fn grad_elementwise_chain() {
        check_grads(
            |b| {
                let x = b.arg("x", TensorType::f32(&[5]), ArgKind::Parameter);
                let e = b.exp(x);
                let t = b.tanh(e);
                let s = b.mul(t, x);
                let q = b.shift(s, 3.0);
                let l = b.log(q);
                let loss = b.reduce_sum(l, vec![0]);
                (vec![x], loss)
            },
            2,
        );
    }

    #[test]
    fn grad_softmax() {
        check_grads(
            |b| {
                let x = b.arg("x", TensorType::f32(&[2, 3]), ArgKind::Parameter);
                let s = b.softmax_last(x);
                let s2 = b.mul(s, s);
                let loss = b.reduce_sum(s2, vec![0, 1]);
                (vec![x], loss)
            },
            3,
        );
    }

    #[test]
    fn grad_gelu_layernorm() {
        check_grads(
            |b| {
                let x = b.arg("x", TensorType::f32(&[2, 4]), ArgKind::Parameter);
                let gamma = b.arg("gamma", TensorType::f32(&[4]), ArgKind::Parameter);
                let beta = b.arg("beta", TensorType::f32(&[4]), ArgKind::Parameter);
                let n = b.layer_norm(x, gamma, beta);
                let g = b.gelu(n);
                let loss = b.reduce_sum(g, vec![0, 1]);
                (vec![x, gamma, beta], loss)
            },
            4,
        );
    }

    #[test]
    fn grad_batched_dot_with_transpose() {
        check_grads(
            |b| {
                let q = b.arg("q", TensorType::f32(&[2, 3, 4]), ArgKind::Parameter);
                let k = b.arg("k", TensorType::f32(&[2, 3, 4]), ArgKind::Parameter);
                // scores[b,i,j] = sum_d q[b,i,d] k[b,j,d]
                let d = DotDims {
                    lhs_batch: vec![0],
                    rhs_batch: vec![0],
                    lhs_contract: vec![2],
                    rhs_contract: vec![2],
                };
                let s = b.dot(d, q, k);
                let sm = b.softmax_last(s);
                let loss_pre = b.mul(sm, sm);
                let loss = b.reduce_sum(loss_pre, vec![0, 1, 2]);
                (vec![q, k], loss)
            },
            5,
        );
    }

    #[test]
    fn grad_div_sqrt_rsqrt_abs() {
        check_grads(
            |b| {
                let x = b.arg("x", TensorType::f32(&[4]), ArgKind::Parameter);
                let shifted = b.shift(x, 3.0); // keep positive-ish
                let s = b.sqrt(shifted);
                let r = b.rsqrt(shifted);
                let a = b.abs(x);
                let num = b.add(s, a);
                let q = b.div(num, r);
                let loss = b.reduce_sum(q, vec![0]);
                (vec![x], loss)
            },
            6,
        );
    }

    #[test]
    fn grad_gather_segment_sum() {
        // Embedding-style: loss = sum(gather(table, ids)^2)
        let mut b = GraphBuilder::new("g");
        let table = b.arg("t", TensorType::f32(&[4, 3]), ArgKind::Parameter);
        let ids = b.arg("i", TensorType::i32(&[5]), ArgKind::Input);
        let g = b.gather(table, ids);
        let g2 = b.mul(g, g);
        let loss = b.reduce_sum(g2, vec![0, 1]);
        let grads = gradients(&mut b, loss, &[table]);
        let gt = grads[0].unwrap();
        b.output(loss);
        b.output(gt);
        let f = b.finish();
        verify(&f).unwrap();

        let t = Tensor::new(&[4, 3], (0..12).map(|x| x as f64 * 0.1).collect());
        let i = Tensor::new(&[5], vec![1.0, 3.0, 1.0, 0.0, 2.0]);
        let vals = eval_all(&f, &[t.clone(), i]);
        let gt_v = &vals[gt.index()];
        // grad_table[v] = 2 * t[v] * count(v in ids)
        let counts = [1.0, 2.0, 1.0, 1.0];
        for v in 0..4 {
            for c in 0..3 {
                let expect = 2.0 * t.data[v * 3 + c] * counts[v];
                let got = gt_v.data[v * 3 + c];
                assert!((got - expect).abs() < 1e-12, "v={v} c={c}: {got} vs {expect}");
            }
        }
    }

    #[test]
    fn grad_max_reduce_and_select() {
        check_grads(
            |b| {
                let x = b.arg("x", TensorType::f32(&[3, 3]), ArgKind::Parameter);
                let m = b.reduce_max(x, vec![1]);
                let loss = b.reduce_sum(m, vec![0]);
                (vec![x], loss)
            },
            7,
        );
    }

    #[test]
    fn unused_arg_has_no_grad() {
        let mut b = GraphBuilder::new("g");
        let x = b.arg("x", TensorType::f32(&[2]), ArgKind::Parameter);
        let y = b.arg("y", TensorType::f32(&[2]), ArgKind::Parameter);
        let s = b.reduce_sum(x, vec![0]);
        let grads = gradients(&mut b, s, &[x, y]);
        assert!(grads[0].is_some());
        assert!(grads[1].is_none());
    }
}
