//! "pallas-bin": the versioned binary interchange format (`.pbp`) for
//! programs and partition plans (DESIGN.md §13).
//!
//! The textual IR (§10) is the human frontend; this is the machine one —
//! what replicas, caches, and CI artifacts ship instead of re-parsing
//! text on every cold load. Layout:
//!
//! ```text
//! offset  size  field
//!      0     4  magic  b"PLSB"
//!      4     2  format version (u16 LE) — currently 1
//!      6     2  kind    (u16 LE): 1 = program (Func), 2 = PartitionPlan
//!      8     8  payload length (u64 LE)
//!     16     8  payload integrity hash: FNV-1a 64 (util::hash, pinned)
//!     24     8  reserved, must be zero
//!     32     —  payload
//! ```
//!
//! The 32-byte fixed header is mmap-friendly: a reader can classify and
//! integrity-check a blob without decoding it. All integers are
//! little-endian; floats travel as `f64::to_bits` so round-trips are
//! bit-exact (`-0.0`, subnormals, and the canonical NaN survive).
//!
//! The decoder is total: every read is bounds-checked, counts are
//! validated against the remaining payload before allocation, reserved
//! bytes must be zero, trailing bytes are rejected, and decoded programs
//! must pass [`crate::ir::verify::verify`]. Corrupt or version-skewed
//! input yields a [`DecodeError`] naming what went wrong — never a panic.
//!
//! Version policy: the format version is bumped only for layout changes
//! that old decoders cannot skip; a decoder rejects unknown versions with
//! a diagnostic naming both the blob's version and its own.

use std::fmt;

use crate::cost::composite::{Evaluation, PipelineEval};
use crate::cost::liveness::MemoryEstimate;
use crate::ir::graph::{Arg, ArgKind, Func, Node, ScopeId, ValueId};
use crate::ir::op::{CmpDir, DotDims, OpKind, ReduceKind};
use crate::ir::types::{DType, TensorType};
use crate::session::plan::{PartitionPlan, ShardSpec};
use crate::sim::exec::RuntimeEstimate;
use crate::spmd::collectives::CollectiveStats;
use crate::util::hash::fnv64;

/// File magic: "PaLlaS Binary".
pub const MAGIC: [u8; 4] = *b"PLSB";
/// Format version this build encodes and decodes.
pub const FORMAT_VERSION: u16 = 1;
/// Header size in bytes (fixed across versions by policy).
pub const HEADER_LEN: usize = 32;
/// Payload kind: a [`Func`] program.
pub const KIND_PROGRAM: u16 = 1;
/// Payload kind: a [`PartitionPlan`].
pub const KIND_PLAN: u16 = 2;

/// Decode failure: corrupt bytes, version skew, or a payload that does
/// not verify. Carries a human-readable diagnostic.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct DecodeError {
    pub msg: String,
}

impl DecodeError {
    fn new(msg: impl Into<String>) -> DecodeError {
        DecodeError { msg: msg.into() }
    }
}

impl fmt::Display for DecodeError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "pallas-bin decode error: {}", self.msg)
    }
}

impl std::error::Error for DecodeError {}

fn kind_name(kind: u16) -> &'static str {
    match kind {
        KIND_PROGRAM => "program",
        KIND_PLAN => "partition plan",
        _ => "unknown",
    }
}

/// Does this byte slice start with the pallas-bin magic? (Used to sniff
/// `@file.pbp` request payloads apart from textual IR.)
pub fn is_pallas_bin(bytes: &[u8]) -> bool {
    bytes.len() >= MAGIC.len() && bytes[..MAGIC.len()] == MAGIC
}

/// Payload kind of a framed blob, if the magic matches (no validation
/// beyond the first 8 header bytes).
pub fn sniff_kind(bytes: &[u8]) -> Option<u16> {
    if !is_pallas_bin(bytes) || bytes.len() < 8 {
        return None;
    }
    Some(u16::from_le_bytes([bytes[6], bytes[7]]))
}

// ---------------------------------------------------------------------------
// Encoder
// ---------------------------------------------------------------------------

#[derive(Default)]
struct Enc {
    buf: Vec<u8>,
}

impl Enc {
    fn u8(&mut self, v: u8) {
        self.buf.push(v);
    }
    fn u32(&mut self, v: u32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }
    fn u64(&mut self, v: u64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }
    fn i64(&mut self, v: i64) {
        self.u64(v as u64);
    }
    fn f64(&mut self, v: f64) {
        self.u64(v.to_bits());
    }
    fn count(&mut self, n: usize) {
        self.u32(u32::try_from(n).expect("pallas-bin: count exceeds u32"));
    }
    fn str(&mut self, s: &str) {
        self.count(s.len());
        self.buf.extend_from_slice(s.as_bytes());
    }
    fn usizes(&mut self, xs: &[usize]) {
        self.count(xs.len());
        for &x in xs {
            self.u64(x as u64);
        }
    }
    fn ty(&mut self, t: &TensorType) {
        self.u8(dtype_tag(t.dtype));
        self.count(t.dims.len());
        for &d in &t.dims {
            self.i64(d);
        }
    }
}

fn dtype_tag(d: DType) -> u8 {
    match d {
        DType::F32 => 0,
        DType::BF16 => 1,
        DType::I32 => 2,
        DType::Bool => 3,
    }
}

fn cmp_tag(d: CmpDir) -> u8 {
    match d {
        CmpDir::Lt => 0,
        CmpDir::Le => 1,
        CmpDir::Gt => 2,
        CmpDir::Ge => 3,
        CmpDir::Eq => 4,
        CmpDir::Ne => 5,
    }
}

fn encode_op(e: &mut Enc, op: &OpKind) {
    e.u8(op.kind_id() as u8);
    match op {
        OpKind::Const { value } => e.f64(*value),
        OpKind::Iota { dim } => e.u64(*dim as u64),
        OpKind::Compare { dir } => e.u8(cmp_tag(*dir)),
        OpKind::Dot(d) => {
            e.usizes(&d.lhs_batch);
            e.usizes(&d.rhs_batch);
            e.usizes(&d.lhs_contract);
            e.usizes(&d.rhs_contract);
        }
        OpKind::Reduce { dims, .. } => e.usizes(dims),
        OpKind::Broadcast { dims } => e.usizes(dims),
        OpKind::Transpose { perm } => e.usizes(perm),
        OpKind::SegmentSum { num } => e.i64(*num),
        _ => {}
    }
}

/// Frame a payload with the 32-byte pallas-bin header.
fn frame(kind: u16, payload: Vec<u8>) -> Vec<u8> {
    let mut out = Vec::with_capacity(HEADER_LEN + payload.len());
    out.extend_from_slice(&MAGIC);
    out.extend_from_slice(&FORMAT_VERSION.to_le_bytes());
    out.extend_from_slice(&kind.to_le_bytes());
    out.extend_from_slice(&(payload.len() as u64).to_le_bytes());
    out.extend_from_slice(&fnv64(&payload).to_le_bytes());
    out.extend_from_slice(&[0u8; 8]);
    out.extend_from_slice(&payload);
    out
}

/// Encode a program. `decode_program(encode_program(f))` returns a `Func`
/// equal to `f` — and stronger than structural equality: the scope intern
/// table is carried verbatim, so even `ScopeId`s survive.
pub fn encode_program(f: &Func) -> Vec<u8> {
    let mut e = Enc::default();
    e.str(&f.name);
    e.count(f.scopes.len());
    for s in &f.scopes {
        e.str(s);
    }
    e.count(f.args.len());
    for a in &f.args {
        e.str(&a.name);
        e.u8(a.kind.kind_id() as u8);
        e.u32(a.scope.0);
        e.ty(&a.ty);
    }
    e.count(f.nodes.len());
    for n in &f.nodes {
        encode_op(&mut e, &n.op);
        e.count(n.inputs.len());
        for v in &n.inputs {
            e.u32(v.0);
        }
        e.ty(&n.ty);
        e.u32(n.scope.0);
    }
    e.count(f.outputs.len());
    for o in &f.outputs {
        e.u32(o.0);
    }
    frame(KIND_PROGRAM, e.buf)
}

/// Encode a partition plan. Floats are bit-exact, so
/// `decode_plan(encode_plan(p)).to_json() == p.to_json()` byte for byte.
pub fn encode_plan(p: &PartitionPlan) -> Vec<u8> {
    let mut e = Enc::default();
    e.count(p.mesh_axes.len());
    for (name, size) in &p.mesh_axes {
        e.str(name);
        e.i64(*size);
    }
    for specs in [&p.input_specs, &p.output_specs] {
        e.count(specs.len());
        for s in specs.iter() {
            e.str(&s.name);
            e.count(s.tilings.len());
            for (axis, dim) in &s.tilings {
                e.str(axis);
                e.u64(*dim as u64);
            }
        }
    }
    let ev = &p.eval;
    e.i64(ev.memory.peak_bytes);
    e.i64(ev.memory.arg_bytes);
    e.u64(ev.memory.peak_node as u64);
    e.f64(ev.runtime.compute_seconds);
    e.f64(ev.runtime.memory_seconds);
    e.f64(ev.runtime.op_seconds);
    e.f64(ev.runtime.collective_seconds);
    e.f64(ev.runtime.total_flops);
    let c = &ev.collectives;
    e.u64(c.all_reduce_count as u64);
    e.i64(c.all_reduce_bytes);
    e.u64(c.all_gather_count as u64);
    e.i64(c.all_gather_bytes);
    e.u64(c.send_count as u64);
    e.i64(c.send_bytes);
    e.u64(c.recv_count as u64);
    e.i64(c.recv_bytes);
    e.u8(ev.fits_memory as u8);
    e.f64(ev.cost);
    match &ev.pipeline {
        None => e.u8(0),
        Some(pe) => {
            e.u8(1);
            e.u64(pe.stages as u64);
            e.u64(pe.microbatches as u64);
            e.count(pe.cuts.len());
            for &cut in &pe.cuts {
                e.u32(cut);
            }
            e.f64(pe.bubble_fraction);
            e.f64(pe.makespan_seconds);
            e.f64(pe.send_recv_seconds);
            e.i64(pe.max_stage_peak_bytes);
        }
    }
    e.u64(p.decisions as u64);
    e.u64(p.episodes_to_best as u64);
    e.u64(p.worklist_size as u64);
    e.u64(p.targets as u64);
    e.f64(p.wall_seconds);
    e.count(p.trace.len());
    for t in &p.trace {
        e.str(t);
    }
    frame(KIND_PLAN, e.buf)
}

// ---------------------------------------------------------------------------
// Decoder
// ---------------------------------------------------------------------------

struct Dec<'a> {
    b: &'a [u8],
    pos: usize,
}

type DResult<T> = Result<T, DecodeError>;

impl<'a> Dec<'a> {
    fn new(b: &'a [u8]) -> Dec<'a> {
        Dec { b, pos: 0 }
    }

    fn remaining(&self) -> usize {
        self.b.len() - self.pos
    }

    fn take(&mut self, n: usize, what: &str) -> DResult<&'a [u8]> {
        if self.remaining() < n {
            return Err(DecodeError::new(format!(
                "truncated payload: {what} at byte {} needs {n} bytes, {} left",
                self.pos,
                self.remaining()
            )));
        }
        let s = &self.b[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    fn u8(&mut self, what: &str) -> DResult<u8> {
        Ok(self.take(1, what)?[0])
    }

    fn u32(&mut self, what: &str) -> DResult<u32> {
        let s = self.take(4, what)?;
        Ok(u32::from_le_bytes([s[0], s[1], s[2], s[3]]))
    }

    fn u64(&mut self, what: &str) -> DResult<u64> {
        let s = self.take(8, what)?;
        Ok(u64::from_le_bytes([s[0], s[1], s[2], s[3], s[4], s[5], s[6], s[7]]))
    }

    fn i64(&mut self, what: &str) -> DResult<i64> {
        Ok(self.u64(what)? as i64)
    }

    fn f64(&mut self, what: &str) -> DResult<f64> {
        Ok(f64::from_bits(self.u64(what)?))
    }

    /// Read an item count and sanity-check it against the remaining
    /// payload (each item occupies at least `min_item_bytes`), so a
    /// corrupt count cannot drive a huge allocation.
    fn count(&mut self, min_item_bytes: usize, what: &str) -> DResult<usize> {
        let n = self.u32(what)? as usize;
        if n.saturating_mul(min_item_bytes) > self.remaining() {
            return Err(DecodeError::new(format!(
                "corrupt count: {n} {what} cannot fit in {} remaining payload bytes",
                self.remaining()
            )));
        }
        Ok(n)
    }

    fn str(&mut self, what: &str) -> DResult<String> {
        let n = self.count(1, what)?;
        let s = self.take(n, what)?;
        String::from_utf8(s.to_vec())
            .map_err(|_| DecodeError::new(format!("{what}: invalid UTF-8 in string")))
    }

    fn usizes(&mut self, what: &str) -> DResult<Vec<usize>> {
        let n = self.count(8, what)?;
        let mut out = Vec::with_capacity(n);
        for _ in 0..n {
            out.push(self.u64(what)? as usize);
        }
        Ok(out)
    }

    fn ty(&mut self, what: &str) -> DResult<TensorType> {
        let dtype = match self.u8("dtype tag")? {
            0 => DType::F32,
            1 => DType::BF16,
            2 => DType::I32,
            3 => DType::Bool,
            t => return Err(DecodeError::new(format!("{what}: unknown dtype tag {t}"))),
        };
        let rank = self.count(8, "dims")?;
        let mut dims = Vec::with_capacity(rank);
        for _ in 0..rank {
            let d = self.i64("dim")?;
            if d <= 0 {
                return Err(DecodeError::new(format!("{what}: non-positive dimension {d}")));
            }
            dims.push(d);
        }
        Ok(TensorType { dtype, dims })
    }

    fn done(&self, what: &str) -> DResult<()> {
        if self.remaining() != 0 {
            return Err(DecodeError::new(format!(
                "{} trailing bytes after the {what} payload",
                self.remaining()
            )));
        }
        Ok(())
    }
}

/// Validate the 32-byte header and return the payload slice.
fn check_header(bytes: &[u8], want_kind: u16) -> DResult<&[u8]> {
    if bytes.len() < HEADER_LEN {
        return Err(DecodeError::new(format!(
            "truncated header: {} bytes, need {HEADER_LEN}",
            bytes.len()
        )));
    }
    if bytes[..4] != MAGIC {
        return Err(DecodeError::new(format!(
            "bad magic {:02x}{:02x}{:02x}{:02x}, expected \"PLSB\" — not a pallas-bin file",
            bytes[0], bytes[1], bytes[2], bytes[3]
        )));
    }
    let version = u16::from_le_bytes([bytes[4], bytes[5]]);
    if version != FORMAT_VERSION {
        return Err(DecodeError::new(format!(
            "unsupported format version {version}; this decoder supports version {FORMAT_VERSION}"
        )));
    }
    let kind = u16::from_le_bytes([bytes[6], bytes[7]]);
    if kind != want_kind {
        return Err(DecodeError::new(format!(
            "kind mismatch: blob holds a {} (kind {kind}), expected a {} (kind {want_kind})",
            kind_name(kind),
            kind_name(want_kind)
        )));
    }
    let mut len8 = [0u8; 8];
    len8.copy_from_slice(&bytes[8..16]);
    let payload_len = u64::from_le_bytes(len8);
    let actual = (bytes.len() - HEADER_LEN) as u64;
    if payload_len != actual {
        return Err(DecodeError::new(format!(
            "payload length mismatch: header pins {payload_len} bytes, file carries {actual}"
        )));
    }
    if bytes[24..32].iter().any(|&b| b != 0) {
        return Err(DecodeError::new("reserved header bytes are not zero".to_string()));
    }
    let mut hash8 = [0u8; 8];
    hash8.copy_from_slice(&bytes[16..24]);
    let pinned = u64::from_le_bytes(hash8);
    let payload = &bytes[HEADER_LEN..];
    let got = fnv64(payload);
    if got != pinned {
        return Err(DecodeError::new(format!(
            "integrity hash mismatch: payload hashes to {got:016x}, header pins {pinned:016x}"
        )));
    }
    Ok(payload)
}

fn decode_op(d: &mut Dec) -> DResult<OpKind> {
    let tag = d.u8("op tag")?;
    Ok(match tag {
        0 => OpKind::Const { value: d.f64("const value")? },
        1 => OpKind::Iota { dim: d.u64("iota dim")? as usize },
        2 => OpKind::Add,
        3 => OpKind::Sub,
        4 => OpKind::Mul,
        5 => OpKind::Div,
        6 => OpKind::Max,
        7 => OpKind::Min,
        8 => OpKind::Neg,
        9 => OpKind::Exp,
        10 => OpKind::Log,
        11 => OpKind::Tanh,
        12 => OpKind::Rsqrt,
        13 => OpKind::Sqrt,
        14 => OpKind::Abs,
        15 => {
            let dir = match d.u8("compare dir")? {
                0 => CmpDir::Lt,
                1 => CmpDir::Le,
                2 => CmpDir::Gt,
                3 => CmpDir::Ge,
                4 => CmpDir::Eq,
                5 => CmpDir::Ne,
                t => return Err(DecodeError::new(format!("unknown compare direction tag {t}"))),
            };
            OpKind::Compare { dir }
        }
        16 => OpKind::Select,
        17 => OpKind::Convert,
        18 => OpKind::Dot(DotDims {
            lhs_batch: d.usizes("dot lhs_batch")?,
            rhs_batch: d.usizes("dot rhs_batch")?,
            lhs_contract: d.usizes("dot lhs_contract")?,
            rhs_contract: d.usizes("dot rhs_contract")?,
        }),
        19 => OpKind::Reduce { kind: ReduceKind::Sum, dims: d.usizes("reduce dims")? },
        20 => OpKind::Reduce { kind: ReduceKind::Max, dims: d.usizes("reduce dims")? },
        21 => OpKind::Broadcast { dims: d.usizes("broadcast dims")? },
        22 => OpKind::Reshape,
        23 => OpKind::Transpose { perm: d.usizes("transpose perm")? },
        24 => OpKind::Gather,
        25 => OpKind::SegmentSum { num: d.i64("segment_sum num")? },
        t => return Err(DecodeError::new(format!("unknown op tag {t}"))),
    })
}

/// Decode a program blob. The result is verified (`ir::verify`) before it
/// is returned, so a decoded `Func` is as trustworthy as a parsed one.
pub fn decode_program(bytes: &[u8]) -> DResult<Func> {
    let payload = check_header(bytes, KIND_PROGRAM)?;
    let mut d = Dec::new(payload);
    let name = d.str("function name")?;
    let num_scopes = d.count(4, "scopes")?;
    let mut scopes = Vec::with_capacity(num_scopes);
    for _ in 0..num_scopes {
        scopes.push(d.str("scope path")?);
    }
    if scopes.is_empty() {
        return Err(DecodeError::new("empty scope table (scope 0 is the root)".to_string()));
    }
    let scope_ref = |d: &mut Dec, what: &str| -> DResult<ScopeId> {
        let s = d.u32(what)?;
        if s as usize >= num_scopes {
            return Err(DecodeError::new(format!(
                "{what}: scope id {s} out of range ({num_scopes} scopes)"
            )));
        }
        Ok(ScopeId(s))
    };
    let num_args = d.count(10, "args")?;
    let mut args = Vec::with_capacity(num_args);
    for _ in 0..num_args {
        let name = d.str("arg name")?;
        let kind = match d.u8("arg kind")? {
            0 => ArgKind::Parameter,
            1 => ArgKind::OptState,
            2 => ArgKind::Input,
            3 => ArgKind::Constant,
            t => return Err(DecodeError::new(format!("unknown arg kind tag {t}"))),
        };
        let scope = scope_ref(&mut d, "arg scope")?;
        let ty = d.ty("arg type")?;
        args.push(Arg { name, ty, kind, scope });
    }
    let num_nodes = d.count(11, "nodes")?;
    let mut nodes = Vec::with_capacity(num_nodes);
    for ni in 0..num_nodes {
        let op = decode_op(&mut d)?;
        let num_inputs = d.count(4, "node inputs")?;
        let mut inputs = Vec::with_capacity(num_inputs);
        for _ in 0..num_inputs {
            let v = d.u32("input value id")?;
            // Topological-order invariant: a node may only reference
            // arguments or earlier nodes.
            if v as usize >= num_args + ni {
                return Err(DecodeError::new(format!(
                    "node {ni}: input value id {v} is not an argument or earlier node"
                )));
            }
            inputs.push(ValueId(v));
        }
        let ty = d.ty("node type")?;
        let scope = scope_ref(&mut d, "node scope")?;
        nodes.push(Node { op, inputs, ty, scope });
    }
    let num_outputs = d.count(4, "outputs")?;
    let mut outputs = Vec::with_capacity(num_outputs);
    for _ in 0..num_outputs {
        let v = d.u32("output value id")?;
        if v as usize >= num_args + num_nodes {
            return Err(DecodeError::new(format!(
                "output value id {v} out of range ({} values)",
                num_args + num_nodes
            )));
        }
        outputs.push(ValueId(v));
    }
    d.done("program")?;
    let f = Func { name, args, nodes, outputs, scopes };
    crate::ir::verify::verify(&f)
        .map_err(|e| DecodeError::new(format!("decoded program fails verification: {e}")))?;
    Ok(f)
}

/// Decode a partition-plan blob.
pub fn decode_plan(bytes: &[u8]) -> DResult<PartitionPlan> {
    let payload = check_header(bytes, KIND_PLAN)?;
    let mut d = Dec::new(payload);
    let num_axes = d.count(12, "mesh axes")?;
    let mut mesh_axes = Vec::with_capacity(num_axes);
    for _ in 0..num_axes {
        let name = d.str("mesh axis name")?;
        let size = d.i64("mesh axis size")?;
        mesh_axes.push((name, size));
    }
    let mut specs = |label: &str| -> DResult<Vec<ShardSpec>> {
        let n = d.count(8, label)?;
        let mut out = Vec::with_capacity(n);
        for _ in 0..n {
            let name = d.str("spec name")?;
            let nt = d.count(12, "tilings")?;
            let mut tilings = Vec::with_capacity(nt);
            for _ in 0..nt {
                let axis = d.str("tiling axis")?;
                let dim = d.u64("tiling dim")? as usize;
                tilings.push((axis, dim));
            }
            out.push(ShardSpec { name, tilings });
        }
        Ok(out)
    };
    let input_specs = specs("input specs")?;
    let output_specs = specs("output specs")?;
    let memory = MemoryEstimate {
        peak_bytes: d.i64("peak_bytes")?,
        arg_bytes: d.i64("arg_bytes")?,
        peak_node: d.u64("peak_node")? as usize,
    };
    let runtime = RuntimeEstimate {
        compute_seconds: d.f64("compute_seconds")?,
        memory_seconds: d.f64("memory_seconds")?,
        op_seconds: d.f64("op_seconds")?,
        collective_seconds: d.f64("collective_seconds")?,
        total_flops: d.f64("total_flops")?,
    };
    let collectives = CollectiveStats {
        all_reduce_count: d.u64("all_reduce_count")? as usize,
        all_reduce_bytes: d.i64("all_reduce_bytes")?,
        all_gather_count: d.u64("all_gather_count")? as usize,
        all_gather_bytes: d.i64("all_gather_bytes")?,
        send_count: d.u64("send_count")? as usize,
        send_bytes: d.i64("send_bytes")?,
        recv_count: d.u64("recv_count")? as usize,
        recv_bytes: d.i64("recv_bytes")?,
    };
    let fits_memory = match d.u8("fits_memory")? {
        0 => false,
        1 => true,
        t => return Err(DecodeError::new(format!("bad fits_memory flag {t}"))),
    };
    let cost = d.f64("cost")?;
    let pipeline = match d.u8("pipeline flag")? {
        0 => None,
        1 => {
            let stages = d.u64("pipeline stages")? as usize;
            let microbatches = d.u64("pipeline microbatches")? as usize;
            let nc = d.count(4, "pipeline cuts")?;
            let mut cuts = Vec::with_capacity(nc);
            for _ in 0..nc {
                cuts.push(d.u32("pipeline cut")?);
            }
            Some(PipelineEval {
                stages,
                microbatches,
                cuts,
                bubble_fraction: d.f64("bubble_fraction")?,
                makespan_seconds: d.f64("makespan_seconds")?,
                send_recv_seconds: d.f64("send_recv_seconds")?,
                max_stage_peak_bytes: d.i64("max_stage_peak_bytes")?,
            })
        }
        t => return Err(DecodeError::new(format!("bad pipeline-present flag {t}"))),
    };
    let eval = Evaluation { memory, runtime, collectives, fits_memory, cost, pipeline };
    let decisions = d.u64("decisions")? as usize;
    let episodes_to_best = d.u64("episodes_to_best")? as usize;
    let worklist_size = d.u64("worklist_size")? as usize;
    let targets = d.u64("targets")? as usize;
    let wall_seconds = d.f64("wall_seconds")?;
    let nt = d.count(4, "trace")?;
    let mut trace = Vec::with_capacity(nt);
    for _ in 0..nt {
        trace.push(d.str("trace line")?);
    }
    d.done("plan")?;
    Ok(PartitionPlan {
        mesh_axes,
        input_specs,
        output_specs,
        eval,
        decisions,
        episodes_to_best,
        worklist_size,
        targets,
        wall_seconds,
        trace,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::graph::ROOT_SCOPE;

    fn tiny() -> Func {
        let mut f = Func::new("tiny");
        let s = f.intern_scope("layer_0/dense");
        f.args.push(Arg {
            name: "x".into(),
            ty: TensorType::f32(&[4, 8]),
            kind: ArgKind::Input,
            scope: ROOT_SCOPE,
        });
        f.args.push(Arg {
            name: "w".into(),
            ty: TensorType::f32(&[8, 2]),
            kind: ArgKind::Parameter,
            scope: s,
        });
        f.nodes.push(Node {
            op: OpKind::Dot(DotDims::matmul(2)),
            inputs: vec![ValueId(0), ValueId(1)],
            ty: TensorType::f32(&[4, 2]),
            scope: s,
        });
        f.nodes.push(Node {
            op: OpKind::Tanh,
            inputs: vec![ValueId(2)],
            ty: TensorType::f32(&[4, 2]),
            scope: ROOT_SCOPE,
        });
        f.outputs.push(ValueId(3));
        f
    }

    #[test]
    fn program_round_trips_exactly() {
        let f = tiny();
        let bytes = encode_program(&f);
        assert!(is_pallas_bin(&bytes));
        assert_eq!(sniff_kind(&bytes), Some(KIND_PROGRAM));
        let back = decode_program(&bytes).unwrap();
        assert_eq!(back, f);
        // Stronger than structural equality: the intern table travels
        // verbatim, ScopeIds included.
        assert_eq!(back.scopes, f.scopes);
        // Deterministic encoding.
        assert_eq!(encode_program(&back), bytes);
    }

    #[test]
    fn wrong_magic_names_the_format() {
        let mut bytes = encode_program(&tiny());
        bytes[0] = b'X';
        let err = decode_program(&bytes).unwrap_err();
        assert!(err.msg.contains("PLSB"), "{err}");
    }

    #[test]
    fn version_skew_names_both_versions() {
        let mut bytes = encode_program(&tiny());
        bytes[4] = 7;
        let err = decode_program(&bytes).unwrap_err();
        assert!(err.msg.contains("version 7"), "{err}");
        assert!(err.msg.contains(&format!("version {FORMAT_VERSION}")), "{err}");
    }

    #[test]
    fn kind_mismatch_is_rejected() {
        let mut bytes = encode_program(&tiny());
        // Flip the kind field to "plan" and re-check: the header check
        // runs before any payload decoding, so this must fail cleanly.
        bytes[6] = KIND_PLAN as u8;
        let err = decode_program(&bytes).unwrap_err();
        assert!(err.msg.contains("kind"), "{err}");
        assert!(err.msg.contains("partition plan"), "{err}");
    }

    #[test]
    fn every_single_bit_flip_is_detected() {
        let f = tiny();
        let bytes = encode_program(&f);
        for i in 0..bytes.len() {
            for bit in 0..8 {
                let mut c = bytes.clone();
                c[i] ^= 1 << bit;
                assert!(decode_program(&c).is_err(), "flip of byte {i} bit {bit} undetected");
            }
        }
    }

    #[test]
    fn every_truncation_is_detected() {
        let bytes = encode_program(&tiny());
        for n in 0..bytes.len() {
            assert!(decode_program(&bytes[..n]).is_err(), "truncation to {n} bytes accepted");
        }
    }
}
