//! Partition-plan service (DESIGN.md §9): the layer that turns the
//! one-shot [`Session`](crate::session::Session) pipeline into a
//! reusable, concurrent planning service.
//!
//! The paper positions automap as infrastructure that "seamlessly
//! integrates into existing compilers and existing user workflows" —
//! compiler-adjacent serving, not a one-shot CLI. Users re-submit
//! identical models constantly (Alpa's `@parallelize` workflow), so the
//! service is built around a content fingerprint:
//!
//! * [`fingerprint`] — canonical structural hash of
//!   `(Func, Mesh, constraints, cost weights, search config)`, stable
//!   across value-id renumbering;
//! * [`cache`] — sharded, lock-striped, byte-budgeted LRU of serialised
//!   plans keyed by fingerprint;
//! * [`persist`] — the durable tier under the LRU: an append-only,
//!   CRC-framed, compacting log so plans survive the process (probe
//!   order memory → disk → search; DESIGN.md §13);
//! * [`executor`] — root-parallel MCTS fan-out (`K` workers, derived
//!   seeds, deterministic best-cost merge);
//! * [`request`] / [`server`] — JSONL request/response schema, in-flight
//!   dedup of identical concurrent searches, and a bounded work queue
//!   over a thread pool (`automap serve --stdin-jsonl`, `automap batch`);
//! * [`throughput`] — the episodes/sec + cache-latency measurement
//!   behind `BENCH_search.json`;
//! * [`sync`] — replica anti-entropy over the persistent tier: Merkle
//!   digest diffing, CRC-framed delta pulls, and canonical compaction
//!   so converged replicas hold byte-identical logs (DESIGN.md §15).

pub mod cache;
pub mod executor;
pub mod fingerprint;
pub mod persist;
pub mod request;
pub mod server;
pub mod sync;
pub mod throughput;

pub use cache::{CacheStats, PlanCache};
pub use executor::{ExecutorReport, PlanJob};
pub use fingerprint::{func_fingerprint, request_fingerprint, Fingerprint};
pub use persist::{DiskTier, DiskTierStats};
pub use request::{JobDefaults, PartitionRequest, PlanResponse, SearchStats};
pub use server::{run_batch, serve_jsonl, PlanService, ServeSummary, ServiceConfig};
pub use sync::{sync_once, InProcessTransport, MailboxTransport, SyncReport, SyncTransport};
pub use throughput::{measure, ThroughputConfig, ThroughputReport};
