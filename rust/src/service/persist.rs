//! Persistent plan-cache tier (DESIGN.md §13): an append-only,
//! CRC-framed log of `fingerprint → plan JSON` entries that sits under
//! the sharded in-memory LRU ([`super::cache::PlanCache`]).
//!
//! Probe order in the service is memory → disk → search; publishes write
//! through both tiers. The log outlives the process, which is what turns
//! the cache from a per-process optimization into a fleet asset: replicas
//! and CI runs warm from the same file (`actions/cache` carries it
//! between workflow runs).
//!
//! On-disk layout (all integers little-endian):
//!
//! ```text
//! file header (32 bytes, mmap-friendly fixed size):
//!   0  4  magic b"PLOG"
//!   4  2  log format version (u16) — currently 1
//!   6  2  reserved, zero
//!   8  8  generation (u64): bumped by each compaction
//!  16 16  reserved, zero
//! record (repeated until EOF):
//!   0  4  payload length (u32)
//!   4  4  CRC-32 (IEEE) of the payload
//!   8  —  payload: fingerprint (u64) + plan JSON (UTF-8)
//! ```
//!
//! Later records for the same fingerprint supersede earlier ones, so a
//! `put` never rewrites in place. `open` scans the log, verifies every
//! CRC, and truncates at the first corrupt record (counting it), so a
//! torn tail from a killed process costs at most the entries behind it.
//! When the superseded fraction crosses one half (and the log is past a
//! minimum size), the tier compacts: live entries are rewritten to a
//! fresh log with the generation bumped, fsynced, and renamed into place.

use std::collections::HashMap;
use std::fs::{File, OpenOptions};
use std::io::{Read, Seek, SeekFrom, Write};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, OnceLock};

use crate::obs::metrics::{metrics, names, Counter};
use crate::util::failpoints::{failpoints, DISK_READ_ERR, DISK_WRITE_ERR};
use anyhow::{bail, Context, Result};

/// Log file magic.
pub const LOG_MAGIC: [u8; 4] = *b"PLOG";
/// Log format version this build reads and writes.
pub const LOG_VERSION: u16 = 1;
/// Fixed log header size.
pub const LOG_HEADER_LEN: u64 = 32;
/// Per-record framing overhead (length + CRC).
const RECORD_OVERHEAD: u64 = 8;
/// Default minimum log size before compaction is considered.
const DEFAULT_COMPACT_MIN_BYTES: u64 = 1 << 20;

/// CRC-32 (IEEE 802.3, the zlib/PNG polynomial), table-driven.
pub fn crc32(bytes: &[u8]) -> u32 {
    static TABLE: OnceLock<[u32; 256]> = OnceLock::new();
    let table = TABLE.get_or_init(|| {
        let mut t = [0u32; 256];
        for (i, e) in t.iter_mut().enumerate() {
            let mut c = i as u32;
            for _ in 0..8 {
                c = if c & 1 != 0 { 0xedb8_8320 ^ (c >> 1) } else { c >> 1 };
            }
            *e = c;
        }
        t
    });
    let mut c = 0xffff_ffffu32;
    for &b in bytes {
        c = table[((c ^ b as u32) & 0xff) as usize] ^ (c >> 8);
    }
    c ^ 0xffff_ffff
}

/// Location of a live record's payload within the log.
#[derive(Clone, Copy)]
struct IndexEntry {
    offset: u64,
    len: u32,
}

struct State {
    file: File,
    index: HashMap<u64, IndexEntry>,
    /// Write position (== file length).
    tail: u64,
    generation: u64,
    /// Bytes occupied by live records, framing included.
    live_bytes: u64,
}

/// Point-in-time counters and sizes for one tier instance.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct DiskTierStats {
    pub entries: usize,
    pub generation: u64,
    pub file_bytes: u64,
    pub live_bytes: u64,
    pub hits: u64,
    pub misses: u64,
    pub appends: u64,
    pub corrupt_records: u64,
    pub compactions: u64,
    /// Irrecoverably corrupt logs moved aside on open (DESIGN.md §14).
    pub quarantined: u64,
}

/// Handles into the process-global metrics registry, resolved once.
struct TierMetrics {
    hits: Arc<Counter>,
    misses: Arc<Counter>,
    appends: Arc<Counter>,
    corrupt: Arc<Counter>,
    compactions: Arc<Counter>,
    quarantined: Arc<Counter>,
}

impl TierMetrics {
    fn new() -> TierMetrics {
        let m = metrics();
        TierMetrics {
            hits: m.counter(names::PERSIST_DISK_HITS),
            misses: m.counter(names::PERSIST_DISK_MISSES),
            appends: m.counter(names::PERSIST_APPENDS),
            corrupt: m.counter(names::PERSIST_CORRUPT_RECORDS),
            compactions: m.counter(names::PERSIST_COMPACTIONS),
            quarantined: m.counter(names::PERSIST_QUARANTINED),
        }
    }
}

/// The persistent tier: one append-only log plus an in-memory offset
/// index rebuilt on open.
pub struct DiskTier {
    log_path: PathBuf,
    state: Mutex<State>,
    compact_min_bytes: u64,
    hits: AtomicU64,
    misses: AtomicU64,
    appends: AtomicU64,
    corrupt_records: AtomicU64,
    compactions: AtomicU64,
    quarantined: AtomicU64,
    mx: TierMetrics,
}

fn log_header(generation: u64) -> [u8; LOG_HEADER_LEN as usize] {
    let mut h = [0u8; LOG_HEADER_LEN as usize];
    h[..4].copy_from_slice(&LOG_MAGIC);
    h[4..6].copy_from_slice(&LOG_VERSION.to_le_bytes());
    h[8..16].copy_from_slice(&generation.to_le_bytes());
    h
}

impl DiskTier {
    /// Open (or create) the cache log inside `dir` with the default
    /// compaction threshold.
    pub fn open(dir: &Path) -> Result<DiskTier> {
        Self::open_with(dir, DEFAULT_COMPACT_MIN_BYTES)
    }

    /// Open with an explicit minimum log size (bytes) before compaction
    /// is considered — tests use a tiny threshold to force it.
    pub fn open_with(dir: &Path, compact_min_bytes: u64) -> Result<DiskTier> {
        std::fs::create_dir_all(dir)
            .with_context(|| format!("creating cache dir {}", dir.display()))?;
        let log_path = dir.join("plans.plog");
        let mut file = OpenOptions::new()
            .read(true)
            .write(true)
            .create(true)
            .truncate(false)
            .open(&log_path)
            .with_context(|| format!("opening cache log {}", log_path.display()))?;
        let mut buf = Vec::new();
        file.read_to_end(&mut buf).context("reading cache log")?;

        let mut corrupt = 0u64;
        let mut quarantined = 0u64;
        let generation;
        let mut index = HashMap::new();
        let tail;
        if buf.is_empty() {
            generation = 0;
            file.write_all(&log_header(0)).context("writing cache log header")?;
            file.flush()?;
            tail = LOG_HEADER_LEN;
        } else if buf.len() < LOG_HEADER_LEN as usize
            || buf[..4] != LOG_MAGIC
            || u16::from_le_bytes([buf[4], buf[5]]) != LOG_VERSION
        {
            // Unusable header (foreign file, version skew, torn create):
            // QUARANTINE the file — move it aside under a name that
            // records its claimed generation — and start a fresh log,
            // rather than destroying the bytes (an operator or a newer
            // build may still be able to read them) or refusing to
            // serve (the service must come up; DESIGN.md §14).
            drop(file);
            let qpath = quarantine_path(&log_path, &buf);
            std::fs::rename(&log_path, &qpath).with_context(|| {
                format!("quarantining corrupt cache log to {}", qpath.display())
            })?;
            quarantined += 1;
            file = OpenOptions::new()
                .read(true)
                .write(true)
                .create(true)
                .truncate(false)
                .open(&log_path)
                .with_context(|| format!("recreating cache log {}", log_path.display()))?;
            generation = 0;
            file.write_all(&log_header(0)).context("writing cache log header")?;
            file.flush()?;
            tail = LOG_HEADER_LEN;
        } else {
            let mut g8 = [0u8; 8];
            g8.copy_from_slice(&buf[8..16]);
            generation = u64::from_le_bytes(g8);
            // Scan records; truncate at the first corrupt one.
            let mut pos = LOG_HEADER_LEN as usize;
            loop {
                if pos == buf.len() {
                    break;
                }
                if buf.len() - pos < RECORD_OVERHEAD as usize {
                    corrupt += 1;
                    break;
                }
                let len = read_u32_at(&buf, pos) as usize;
                let crc = read_u32_at(&buf, pos + 4);
                let start = pos + RECORD_OVERHEAD as usize;
                if len < 8 || buf.len() - start < len {
                    corrupt += 1;
                    break;
                }
                let payload = &buf[start..start + len];
                if crc32(payload) != crc {
                    corrupt += 1;
                    break;
                }
                let mut fp8 = [0u8; 8];
                fp8.copy_from_slice(&payload[..8]);
                let fp = u64::from_le_bytes(fp8);
                index.insert(fp, IndexEntry { offset: start as u64, len: len as u32 });
                pos = start + len;
            }
            if pos < buf.len() {
                file.set_len(pos as u64)?;
            }
            file.seek(SeekFrom::Start(pos as u64))?;
            tail = pos as u64;
        }
        let live_bytes: u64 = index.values().map(|e| RECORD_OVERHEAD + e.len as u64).sum();
        let tier = DiskTier {
            log_path,
            state: Mutex::new(State { file, index, tail, generation, live_bytes }),
            compact_min_bytes,
            hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
            appends: AtomicU64::new(0),
            corrupt_records: AtomicU64::new(corrupt),
            compactions: AtomicU64::new(0),
            quarantined: AtomicU64::new(quarantined),
            mx: TierMetrics::new(),
        };
        tier.mx.corrupt.add(corrupt);
        tier.mx.quarantined.add(quarantined);
        Ok(tier)
    }

    pub fn log_path(&self) -> &Path {
        &self.log_path
    }

    /// Look up a fingerprint. A corrupt payload read counts as corrupt
    /// AND a miss; the caller falls through to search either way.
    pub fn get(&self, fp: u64) -> Option<String> {
        let mut st = self.state.lock().expect("disk tier poisoned");
        let entry = match st.index.get(&fp) {
            Some(e) => *e,
            None => {
                self.misses.fetch_add(1, Ordering::Relaxed);
                self.mx.misses.add(1);
                return None;
            }
        };
        // Injected transient read error (DESIGN.md §14): degrade to a
        // plain miss WITHOUT dropping the index entry — the bytes on
        // disk are fine, only this read failed — so the caller falls
        // through to search and a later probe can still hit.
        if failpoints().should_fail(DISK_READ_ERR) {
            self.misses.fetch_add(1, Ordering::Relaxed);
            self.mx.misses.add(1);
            return None;
        }
        match read_payload(&mut st.file, entry) {
            Some(payload) if payload.len() >= 8 && payload[..8] == fp.to_le_bytes() => {
                match String::from_utf8(payload[8..].to_vec()) {
                    Ok(plan) => {
                        self.hits.fetch_add(1, Ordering::Relaxed);
                        self.mx.hits.add(1);
                        Some(plan)
                    }
                    Err(_) => self.miss_corrupt(&mut st, fp),
                }
            }
            _ => self.miss_corrupt(&mut st, fp),
        }
    }

    fn miss_corrupt(&self, st: &mut State, fp: u64) -> Option<String> {
        st.index.remove(&fp);
        self.corrupt_records.fetch_add(1, Ordering::Relaxed);
        self.mx.corrupt.add(1);
        self.misses.fetch_add(1, Ordering::Relaxed);
        self.mx.misses.add(1);
        None
    }

    /// Append (or supersede) an entry and flush it to disk. Compacts when
    /// over half the log is superseded and the log is past the minimum.
    pub fn put(&self, fp: u64, plan_json: &str) -> Result<()> {
        let mut st = self.state.lock().expect("disk tier poisoned");
        // Injected append error, raised BEFORE any state mutation so a
        // failed put leaves the tier exactly as it was.
        if failpoints().should_fail(DISK_WRITE_ERR) {
            bail!("injected failpoint: {DISK_WRITE_ERR}");
        }
        let mut payload = Vec::with_capacity(8 + plan_json.len());
        payload.extend_from_slice(&fp.to_le_bytes());
        payload.extend_from_slice(plan_json.as_bytes());
        let mut rec = Vec::with_capacity(RECORD_OVERHEAD as usize + payload.len());
        rec.extend_from_slice(&(payload.len() as u32).to_le_bytes());
        rec.extend_from_slice(&crc32(&payload).to_le_bytes());
        rec.extend_from_slice(&payload);
        let tail = st.tail;
        st.file.seek(SeekFrom::Start(tail)).context("seeking cache log tail")?;
        st.file.write_all(&rec).context("appending cache log record")?;
        st.file.flush().context("flushing cache log")?;
        let entry = IndexEntry { offset: tail + RECORD_OVERHEAD, len: payload.len() as u32 };
        if let Some(old) = st.index.insert(fp, entry) {
            st.live_bytes -= RECORD_OVERHEAD + old.len as u64;
        }
        st.live_bytes += rec.len() as u64;
        st.tail += rec.len() as u64;
        self.appends.fetch_add(1, Ordering::Relaxed);
        self.mx.appends.add(1);
        let total = st.tail - LOG_HEADER_LEN;
        if total >= self.compact_min_bytes && st.live_bytes * 2 < total {
            // A failed compaction degrades to an uncompacted-but-valid
            // log, never a failed put: the append above already landed,
            // `compact` mutates `st` only after the new log is fully
            // installed, and the next put over the threshold retries
            // (a stale .tmp is truncated by its `File::create`).
            let _ = self.compact(&mut st);
        }
        Ok(())
    }

    /// Rewrite the log with live entries only, bumping the generation.
    /// Crash-safe: the new log is fully written and fsynced under a temp
    /// name before the rename; a crash leaves the old log intact.
    fn compact(&self, st: &mut State) -> Result<()> {
        // Injected compaction-write error, raised before the tmp file
        // exists: the live log is untouched and stays generation N.
        if failpoints().should_fail(DISK_WRITE_ERR) {
            bail!("injected failpoint: {DISK_WRITE_ERR} (mid-compaction)");
        }
        let mut entries: Vec<(u64, Vec<u8>)> = Vec::with_capacity(st.index.len());
        let mut fps: Vec<u64> = st.index.keys().copied().collect();
        fps.sort_unstable();
        for fp in fps {
            let e = st.index[&fp];
            let payload = read_payload(&mut st.file, e)
                .with_context(|| format!("reading record {fp:016x} during compaction"))?;
            entries.push((fp, payload));
        }
        let generation = st.generation + 1;
        let tmp_path = self.log_path.with_extension("plog.tmp");
        let mut tmp = File::create(&tmp_path)
            .with_context(|| format!("creating {}", tmp_path.display()))?;
        tmp.write_all(&log_header(generation))?;
        let mut tail = LOG_HEADER_LEN;
        let mut index = HashMap::with_capacity(entries.len());
        for (fp, payload) in &entries {
            tmp.write_all(&(payload.len() as u32).to_le_bytes())?;
            tmp.write_all(&crc32(payload).to_le_bytes())?;
            tmp.write_all(payload)?;
            index.insert(
                *fp,
                IndexEntry { offset: tail + RECORD_OVERHEAD, len: payload.len() as u32 },
            );
            tail += RECORD_OVERHEAD + payload.len() as u64;
        }
        tmp.sync_all().context("fsyncing compacted cache log")?;
        drop(tmp);
        std::fs::rename(&tmp_path, &self.log_path).context("installing compacted cache log")?;
        let file = OpenOptions::new()
            .read(true)
            .write(true)
            .open(&self.log_path)
            .context("reopening compacted cache log")?;
        *st = State { file, index, tail, generation, live_bytes: tail - LOG_HEADER_LEN };
        self.compactions.fetch_add(1, Ordering::Relaxed);
        self.mx.compactions.add(1);
        Ok(())
    }

    pub fn stats(&self) -> DiskTierStats {
        let st = self.state.lock().expect("disk tier poisoned");
        DiskTierStats {
            entries: st.index.len(),
            generation: st.generation,
            file_bytes: st.tail,
            live_bytes: st.live_bytes,
            hits: self.hits.load(Ordering::Relaxed),
            misses: self.misses.load(Ordering::Relaxed),
            appends: self.appends.load(Ordering::Relaxed),
            corrupt_records: self.corrupt_records.load(Ordering::Relaxed),
            compactions: self.compactions.load(Ordering::Relaxed),
            quarantined: self.quarantined.load(Ordering::Relaxed),
        }
    }
}

/// Where an unreadable log gets moved: `plans.plog.corrupt-<gen>`, with
/// `<gen>` taken from the header when the magic still matches (version
/// skew) and 0 otherwise (foreign bytes), plus a numeric suffix when a
/// previous quarantine already claimed the name.
fn quarantine_path(log_path: &Path, buf: &[u8]) -> PathBuf {
    let gen = if buf.len() >= 16 && buf[..4] == LOG_MAGIC {
        let mut g8 = [0u8; 8];
        g8.copy_from_slice(&buf[8..16]);
        u64::from_le_bytes(g8)
    } else {
        0
    };
    let base = log_path.with_extension(format!("plog.corrupt-{gen}"));
    if !base.exists() {
        return base;
    }
    for i in 1u32.. {
        let p = log_path.with_extension(format!("plog.corrupt-{gen}.{i}"));
        if !p.exists() {
            return p;
        }
    }
    unreachable!("u32 quarantine suffixes exhausted")
}

fn read_u32_at(buf: &[u8], pos: usize) -> u32 {
    u32::from_le_bytes([buf[pos], buf[pos + 1], buf[pos + 2], buf[pos + 3]])
}

fn read_payload(file: &mut File, e: IndexEntry) -> Option<Vec<u8>> {
    let mut payload = vec![0u8; e.len as usize];
    file.seek(SeekFrom::Start(e.offset)).ok()?;
    file.read_exact(&mut payload).ok()?;
    Some(payload)
}

impl std::fmt::Debug for DiskTier {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let s = self.stats();
        write!(
            f,
            "DiskTier({}, {} entries, gen {}, {} bytes)",
            self.log_path.display(),
            s.entries,
            s.generation,
            s.file_bytes
        )
    }
}

/// Validate a log header out-of-band (used by tooling/tests); returns the
/// generation.
pub fn read_log_generation(path: &Path) -> Result<u64> {
    let mut h = [0u8; LOG_HEADER_LEN as usize];
    let mut f = File::open(path).with_context(|| format!("opening {}", path.display()))?;
    f.read_exact(&mut h).context("log shorter than its fixed header")?;
    if h[..4] != LOG_MAGIC {
        bail!("bad log magic (expected \"PLOG\")");
    }
    let version = u16::from_le_bytes([h[4], h[5]]);
    if version != LOG_VERSION {
        bail!("unsupported log version {version}; this build supports version {LOG_VERSION}");
    }
    let mut g8 = [0u8; 8];
    g8.copy_from_slice(&h[8..16]);
    Ok(u64::from_le_bytes(g8))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn temp_dir(name: &str) -> PathBuf {
        let dir = std::env::temp_dir()
            .join(format!("automap-persist-unit-{}-{name}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        dir
    }

    #[test]
    fn crc32_known_vector() {
        assert_eq!(crc32(b"123456789"), 0xcbf4_3926);
        assert_eq!(crc32(b""), 0);
    }

    #[test]
    fn put_get_and_supersede() {
        let dir = temp_dir("putget");
        let tier = DiskTier::open(&dir).unwrap();
        assert_eq!(tier.get(1), None);
        tier.put(1, "{\"v\":1}").unwrap();
        tier.put(2, "{\"v\":2}").unwrap();
        assert_eq!(tier.get(1).as_deref(), Some("{\"v\":1}"));
        tier.put(1, "{\"v\":3}").unwrap();
        assert_eq!(tier.get(1).as_deref(), Some("{\"v\":3}"), "later records supersede");
        let s = tier.stats();
        assert_eq!((s.entries, s.appends, s.hits, s.misses), (2, 3, 2, 1));
        assert_eq!(s.corrupt_records, 0);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn reopen_recovers_the_index() {
        let dir = temp_dir("reopen");
        {
            let tier = DiskTier::open(&dir).unwrap();
            tier.put(7, "{\"plan\":true}").unwrap();
        }
        let tier = DiskTier::open(&dir).unwrap();
        assert_eq!(tier.get(7).as_deref(), Some("{\"plan\":true}"));
        assert_eq!(tier.stats().corrupt_records, 0);
        assert_eq!(read_log_generation(tier.log_path()).unwrap(), 0);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn torn_tail_is_truncated_and_counted() {
        let dir = temp_dir("torn");
        let log = {
            let tier = DiskTier::open(&dir).unwrap();
            tier.put(1, "{\"keep\":true}").unwrap();
            tier.log_path().to_path_buf()
        };
        // Simulate a crash mid-append: garbage after the good record.
        let mut f = OpenOptions::new().append(true).open(&log).unwrap();
        f.write_all(&[0xde, 0xad, 0xbe]).unwrap();
        drop(f);
        let tier = DiskTier::open(&dir).unwrap();
        assert_eq!(tier.get(1).as_deref(), Some("{\"keep\":true}"));
        assert_eq!(tier.stats().corrupt_records, 1);
        // The truncation healed the log: a fresh open is clean.
        drop(tier);
        let tier = DiskTier::open(&dir).unwrap();
        assert_eq!(tier.stats().corrupt_records, 0);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn compaction_preserves_entries_and_bumps_generation() {
        let dir = temp_dir("compact");
        // Tiny threshold so rewriting the same key triggers compaction.
        let tier = DiskTier::open_with(&dir, 64).unwrap();
        for i in 0..20 {
            tier.put(42, &format!("{{\"rev\":{i}}}")).unwrap();
            tier.put(7, "{\"stable\":true}").unwrap();
        }
        let s = tier.stats();
        assert!(s.compactions > 0, "superseded log must have compacted: {s:?}");
        assert_eq!(s.entries, 2);
        assert_eq!(tier.get(42).as_deref(), Some("{\"rev\":19}"));
        assert_eq!(tier.get(7).as_deref(), Some("{\"stable\":true}"));
        let gen = read_log_generation(tier.log_path()).unwrap();
        assert!(gen >= 1, "compaction bumps the generation");
        // Entries survive a reopen of the compacted log.
        drop(tier);
        let tier = DiskTier::open(&dir).unwrap();
        assert_eq!(tier.get(42).as_deref(), Some("{\"rev\":19}"));
        assert_eq!(tier.stats().generation, gen);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn foreign_file_is_quarantined_not_trusted_or_destroyed() {
        let dir = temp_dir("foreign");
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("plans.plog"), b"not a log at all").unwrap();
        let tier = DiskTier::open(&dir).unwrap();
        let s = tier.stats();
        assert_eq!(s.quarantined, 1, "unreadable log must be quarantined");
        assert_eq!(s.corrupt_records, 0, "quarantine is not a record-level event");
        // The fresh log serves normally...
        tier.put(5, "{}").unwrap();
        assert_eq!(tier.get(5).as_deref(), Some("{}"));
        // ...and the original bytes survive for forensics under the
        // generation-stamped name (foreign bytes have no generation → 0).
        let q = dir.join("plans.plog.corrupt-0");
        assert_eq!(std::fs::read(&q).unwrap(), b"not a log at all");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn repeated_quarantines_never_collide() {
        let dir = temp_dir("quarantine-twice");
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("plans.plog"), b"garbage one").unwrap();
        drop(DiskTier::open(&dir).unwrap());
        std::fs::write(dir.join("plans.plog"), b"garbage two").unwrap();
        let tier = DiskTier::open(&dir).unwrap();
        assert_eq!(tier.stats().quarantined, 1, "per-open count");
        assert_eq!(std::fs::read(dir.join("plans.plog.corrupt-0")).unwrap(), b"garbage one");
        assert_eq!(std::fs::read(dir.join("plans.plog.corrupt-0.1")).unwrap(), b"garbage two");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn version_skewed_log_is_quarantined_under_its_generation() {
        let dir = temp_dir("quarantine-skew");
        std::fs::create_dir_all(&dir).unwrap();
        // A well-formed header from an imaginary future format version,
        // generation 9: the quarantine name must preserve the generation.
        let mut h = log_header(9).to_vec();
        h[4..6].copy_from_slice(&(LOG_VERSION + 1).to_le_bytes());
        std::fs::write(dir.join("plans.plog"), &h).unwrap();
        let tier = DiskTier::open(&dir).unwrap();
        assert_eq!(tier.stats().quarantined, 1);
        assert!(dir.join("plans.plog.corrupt-9").exists());
        let _ = std::fs::remove_dir_all(&dir);
    }
}
