//! Persistent plan-cache tier (DESIGN.md §13): an append-only,
//! CRC-framed log of `fingerprint → plan JSON` entries that sits under
//! the sharded in-memory LRU ([`super::cache::PlanCache`]).
//!
//! Probe order in the service is memory → disk → search; publishes write
//! through both tiers. The log outlives the process, which is what turns
//! the cache from a per-process optimization into a fleet asset: replicas
//! and CI runs warm from the same file (`actions/cache` carries it
//! between workflow runs).
//!
//! On-disk layout (all integers little-endian):
//!
//! ```text
//! file header (32 bytes, mmap-friendly fixed size):
//!   0  4  magic b"PLOG"
//!   4  2  log format version (u16) — currently 1
//!   6  2  reserved, zero
//!   8  8  generation (u64): bumped by each compaction
//!  16 16  reserved, zero
//! record (repeated until EOF):
//!   0  4  payload length (u32)
//!   4  4  CRC-32 (IEEE) of the payload
//!   8  —  payload: fingerprint (u64) + plan JSON (UTF-8)
//! ```
//!
//! Later records for the same fingerprint supersede earlier ones, so a
//! `put` never rewrites in place. `open` scans the log, verifies every
//! CRC, and truncates at the first corrupt record (counting it), so a
//! torn tail from a killed process costs at most the entries behind it.
//! When the superseded fraction crosses one half (and the log is past a
//! minimum size), the tier compacts: live entries are rewritten to a
//! fresh log with the generation bumped, fsynced, and renamed into place.
//!
//! Logs past a size threshold are read through a memory map instead of
//! being slurped into the heap (open-time scans and record probes both),
//! with a buffered-read fallback on platforms without `mmap` and for
//! records appended after the map was established. Results are identical
//! either way — pinned by test.
//!
//! The replica sync layer (DESIGN.md §15) additionally needs: a live
//! `(fingerprint, crc)` listing for digest trees ([`DiskTier::live_index`]),
//! raw payload export ([`DiskTier::export_records`]), and a *canonical*
//! compaction ([`DiskTier::compact_canonical`]) whose generation is a
//! pure function of the live record set — so two replicas holding the
//! same plans compact to byte-identical logs.

use std::collections::HashMap;
use std::fs::{File, OpenOptions};
use std::io::{Read, Seek, SeekFrom, Write};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, OnceLock};

use crate::obs::metrics::{metrics, names, Counter};
use crate::util::failpoints::{failpoints, DISK_READ_ERR, DISK_WRITE_ERR};
use crate::util::hash::Fnv64;
use anyhow::{bail, Context, Result};

/// Log file magic.
pub const LOG_MAGIC: [u8; 4] = *b"PLOG";
/// Log format version this build reads and writes.
pub const LOG_VERSION: u16 = 1;
/// Fixed log header size.
pub const LOG_HEADER_LEN: u64 = 32;
/// Per-record framing overhead (length + CRC).
pub(crate) const RECORD_OVERHEAD: u64 = 8;
/// Default minimum log size before compaction is considered.
const DEFAULT_COMPACT_MIN_BYTES: u64 = 1 << 20;
/// Default log size above which reads go through a memory map instead
/// of loading the whole file (or per-record buffered reads).
const DEFAULT_MMAP_THRESHOLD: u64 = 4 << 20;
/// How many quarantined files (`*.corrupt-*`) survive pruning.
pub const MAX_QUARANTINES: usize = 4;

/// Minimal read-only memory map over a file, with a raw-FFI `mmap` on
/// unix (libc is already linked through std; no new crate) and a
/// never-maps stub elsewhere so every caller keeps the buffered-read
/// fallback path.
#[cfg(unix)]
mod mapped {
    use std::ffi::c_void;
    use std::fs::File;
    use std::os::unix::io::AsRawFd;

    extern "C" {
        fn mmap(
            addr: *mut c_void,
            len: usize,
            prot: i32,
            flags: i32,
            fd: i32,
            offset: i64,
        ) -> *mut c_void;
        fn munmap(addr: *mut c_void, len: usize) -> i32;
    }

    const PROT_READ: i32 = 1;
    const MAP_PRIVATE: i32 = 2;

    pub struct Mmap {
        ptr: *mut c_void,
        len: usize,
    }

    // The mapping is read-only and owned exclusively by the tier's
    // mutex-guarded state; moving it across threads is safe.
    unsafe impl Send for Mmap {}

    impl Mmap {
        /// Map the first `len` bytes of `file` read-only; `None` on an
        /// empty file or any mapping failure (callers fall back to
        /// buffered reads).
        pub fn map(file: &File, len: u64) -> Option<Mmap> {
            if len == 0 || len > usize::MAX as u64 {
                return None;
            }
            let ptr = unsafe {
                mmap(std::ptr::null_mut(), len as usize, PROT_READ, MAP_PRIVATE, file.as_raw_fd(), 0)
            };
            if ptr as isize == -1 || ptr.is_null() {
                None
            } else {
                Some(Mmap { ptr, len: len as usize })
            }
        }

        pub fn as_slice(&self) -> &[u8] {
            unsafe { std::slice::from_raw_parts(self.ptr as *const u8, self.len) }
        }
    }

    impl Drop for Mmap {
        fn drop(&mut self) {
            unsafe {
                munmap(self.ptr, self.len);
            }
        }
    }
}

#[cfg(not(unix))]
mod mapped {
    use std::fs::File;

    pub struct Mmap {}

    impl Mmap {
        pub fn map(_file: &File, _len: u64) -> Option<Mmap> {
            None
        }

        pub fn as_slice(&self) -> &[u8] {
            &[]
        }
    }
}

use mapped::Mmap;

/// CRC-32 (IEEE 802.3, the zlib/PNG polynomial), table-driven.
pub fn crc32(bytes: &[u8]) -> u32 {
    static TABLE: OnceLock<[u32; 256]> = OnceLock::new();
    let table = TABLE.get_or_init(|| {
        let mut t = [0u32; 256];
        for (i, e) in t.iter_mut().enumerate() {
            let mut c = i as u32;
            for _ in 0..8 {
                c = if c & 1 != 0 { 0xedb8_8320 ^ (c >> 1) } else { c >> 1 };
            }
            *e = c;
        }
        t
    });
    let mut c = 0xffff_ffffu32;
    for &b in bytes {
        c = table[((c ^ b as u32) & 0xff) as usize] ^ (c >> 8);
    }
    c ^ 0xffff_ffff
}

/// Location of a live record's payload within the log, plus its CRC so
/// digest trees and sync diffs never have to touch the file.
#[derive(Clone, Copy)]
struct IndexEntry {
    offset: u64,
    len: u32,
    crc: u32,
}

struct State {
    file: File,
    index: HashMap<u64, IndexEntry>,
    /// Write position (== file length).
    tail: u64,
    generation: u64,
    /// Bytes occupied by live records, framing included.
    live_bytes: u64,
    /// Read-only map over the log's first `map.len()` bytes, present
    /// when the log crossed the mmap threshold at open/compaction time.
    /// Records appended later sit beyond the map and fall back to
    /// buffered reads; the map is rebuilt by the next compaction.
    map: Option<Mmap>,
}

impl State {
    /// Read one record payload, through the map when it covers the
    /// record and via seek+read otherwise. Identical bytes either way.
    fn read_payload(&mut self, e: IndexEntry) -> Option<Vec<u8>> {
        if let Some(m) = &self.map {
            let start = e.offset as usize;
            let end = start.checked_add(e.len as usize)?;
            let bytes = m.as_slice();
            if end <= bytes.len() {
                return Some(bytes[start..end].to_vec());
            }
        }
        let mut payload = vec![0u8; e.len as usize];
        self.file.seek(SeekFrom::Start(e.offset)).ok()?;
        self.file.read_exact(&mut payload).ok()?;
        Some(payload)
    }
}

/// Point-in-time counters and sizes for one tier instance.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct DiskTierStats {
    pub entries: usize,
    pub generation: u64,
    pub file_bytes: u64,
    pub live_bytes: u64,
    pub hits: u64,
    pub misses: u64,
    pub appends: u64,
    pub corrupt_records: u64,
    pub compactions: u64,
    /// Irrecoverably corrupt logs moved aside on open (DESIGN.md §14).
    pub quarantined: u64,
    /// Old quarantine files deleted to cap quarantine growth.
    pub quarantine_pruned: u64,
}

/// Handles into the process-global metrics registry, resolved once.
struct TierMetrics {
    hits: Arc<Counter>,
    misses: Arc<Counter>,
    appends: Arc<Counter>,
    corrupt: Arc<Counter>,
    compactions: Arc<Counter>,
    quarantined: Arc<Counter>,
    quarantine_pruned: Arc<Counter>,
}

impl TierMetrics {
    fn new() -> TierMetrics {
        let m = metrics();
        TierMetrics {
            hits: m.counter(names::PERSIST_DISK_HITS),
            misses: m.counter(names::PERSIST_DISK_MISSES),
            appends: m.counter(names::PERSIST_APPENDS),
            corrupt: m.counter(names::PERSIST_CORRUPT_RECORDS),
            compactions: m.counter(names::PERSIST_COMPACTIONS),
            quarantined: m.counter(names::PERSIST_QUARANTINED),
            quarantine_pruned: m.counter(names::PERSIST_QUARANTINE_PRUNED),
        }
    }
}

/// The persistent tier: one append-only log plus an in-memory offset
/// index rebuilt on open.
pub struct DiskTier {
    log_path: PathBuf,
    state: Mutex<State>,
    compact_min_bytes: u64,
    mmap_threshold: u64,
    hits: AtomicU64,
    misses: AtomicU64,
    appends: AtomicU64,
    corrupt_records: AtomicU64,
    compactions: AtomicU64,
    quarantined: AtomicU64,
    quarantine_pruned: AtomicU64,
    mx: TierMetrics,
}

fn log_header(generation: u64) -> [u8; LOG_HEADER_LEN as usize] {
    let mut h = [0u8; LOG_HEADER_LEN as usize];
    h[..4].copy_from_slice(&LOG_MAGIC);
    h[4..6].copy_from_slice(&LOG_VERSION.to_le_bytes());
    h[8..16].copy_from_slice(&generation.to_le_bytes());
    h
}

impl DiskTier {
    /// Open (or create) the cache log inside `dir` with the default
    /// compaction threshold.
    pub fn open(dir: &Path) -> Result<DiskTier> {
        Self::open_with(dir, DEFAULT_COMPACT_MIN_BYTES)
    }

    /// Open with an explicit minimum log size (bytes) before compaction
    /// is considered — tests use a tiny threshold to force it.
    pub fn open_with(dir: &Path, compact_min_bytes: u64) -> Result<DiskTier> {
        Self::open_with_opts(dir, compact_min_bytes, DEFAULT_MMAP_THRESHOLD)
    }

    /// Open with explicit compaction and mmap thresholds. Logs at or
    /// above `mmap_threshold` bytes are scanned and probed through a
    /// memory map instead of being slurped; results are identical.
    pub fn open_with_opts(
        dir: &Path,
        compact_min_bytes: u64,
        mmap_threshold: u64,
    ) -> Result<DiskTier> {
        std::fs::create_dir_all(dir)
            .with_context(|| format!("creating cache dir {}", dir.display()))?;
        let log_path = dir.join("plans.plog");
        let mut file = OpenOptions::new()
            .read(true)
            .write(true)
            .create(true)
            .truncate(false)
            .open(&log_path)
            .with_context(|| format!("opening cache log {}", log_path.display()))?;
        let file_len = file.metadata().context("statting cache log")?.len();

        // Pick the scan source: a map for big logs, a slurp otherwise.
        let mut map = if file_len >= mmap_threshold { Mmap::map(&file, file_len) } else { None };
        let mut slurped = Vec::new();
        let scan = match &map {
            Some(m) => ScanOutcome::scan(m.as_slice()),
            None => {
                file.read_to_end(&mut slurped).context("reading cache log")?;
                ScanOutcome::scan(&slurped)
            }
        };

        let mut corrupt = 0u64;
        let mut quarantined = 0u64;
        let mut pruned = 0u64;
        let generation;
        let mut index = HashMap::new();
        let tail;
        match scan {
            ScanOutcome::Empty => {
                map = None;
                generation = 0;
                file.write_all(&log_header(0)).context("writing cache log header")?;
                file.flush()?;
                tail = LOG_HEADER_LEN;
            }
            ScanOutcome::BadHeader { header } => {
                // Unusable header (foreign file, version skew, torn
                // create): QUARANTINE the file — move it aside under a
                // name that records its claimed generation — and start a
                // fresh log, rather than destroying the bytes (an
                // operator or a newer build may still be able to read
                // them) or refusing to serve (the service must come up;
                // DESIGN.md §14).
                map = None;
                drop(file);
                let qpath = quarantine_path(&log_path, &header);
                std::fs::rename(&log_path, &qpath).with_context(|| {
                    format!("quarantining corrupt cache log to {}", qpath.display())
                })?;
                quarantined += 1;
                // Cap quarantine growth: repeated corruption must never
                // fill the disk, so only the newest few stay around.
                pruned += prune_quarantines(dir, "plans.plog", MAX_QUARANTINES);
                file = OpenOptions::new()
                    .read(true)
                    .write(true)
                    .create(true)
                    .truncate(false)
                    .open(&log_path)
                    .with_context(|| format!("recreating cache log {}", log_path.display()))?;
                generation = 0;
                file.write_all(&log_header(0)).context("writing cache log header")?;
                file.flush()?;
                tail = LOG_HEADER_LEN;
            }
            ScanOutcome::Records { generation: g, index: idx, tail: t, corrupt: c } => {
                generation = g;
                index = idx;
                corrupt = c;
                if t < file_len {
                    // Torn tail: truncating shrinks the file under any
                    // live map, so drop it and remap the valid prefix.
                    map = None;
                    file.set_len(t)?;
                    if t >= mmap_threshold {
                        map = Mmap::map(&file, t);
                    }
                }
                file.seek(SeekFrom::Start(t))?;
                tail = t;
            }
        }
        let live_bytes: u64 = index.values().map(|e| RECORD_OVERHEAD + e.len as u64).sum();
        let tier = DiskTier {
            log_path,
            state: Mutex::new(State { file, index, tail, generation, live_bytes, map }),
            compact_min_bytes,
            mmap_threshold,
            hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
            appends: AtomicU64::new(0),
            corrupt_records: AtomicU64::new(corrupt),
            compactions: AtomicU64::new(0),
            quarantined: AtomicU64::new(quarantined),
            quarantine_pruned: AtomicU64::new(pruned),
            mx: TierMetrics::new(),
        };
        tier.mx.corrupt.add(corrupt);
        tier.mx.quarantined.add(quarantined);
        tier.mx.quarantine_pruned.add(pruned);
        Ok(tier)
    }

    pub fn log_path(&self) -> &Path {
        &self.log_path
    }

    /// Look up a fingerprint. A corrupt payload read counts as corrupt
    /// AND a miss; the caller falls through to search either way.
    pub fn get(&self, fp: u64) -> Option<String> {
        let mut st = self.state.lock().expect("disk tier poisoned");
        let entry = match st.index.get(&fp) {
            Some(e) => *e,
            None => {
                self.misses.fetch_add(1, Ordering::Relaxed);
                self.mx.misses.add(1);
                return None;
            }
        };
        // Injected transient read error (DESIGN.md §14): degrade to a
        // plain miss WITHOUT dropping the index entry — the bytes on
        // disk are fine, only this read failed — so the caller falls
        // through to search and a later probe can still hit.
        if failpoints().should_fail(DISK_READ_ERR) {
            self.misses.fetch_add(1, Ordering::Relaxed);
            self.mx.misses.add(1);
            return None;
        }
        match st.read_payload(entry) {
            Some(payload) if payload.len() >= 8 && payload[..8] == fp.to_le_bytes() => {
                match String::from_utf8(payload[8..].to_vec()) {
                    Ok(plan) => {
                        self.hits.fetch_add(1, Ordering::Relaxed);
                        self.mx.hits.add(1);
                        Some(plan)
                    }
                    Err(_) => self.miss_corrupt(&mut st, fp),
                }
            }
            _ => self.miss_corrupt(&mut st, fp),
        }
    }

    fn miss_corrupt(&self, st: &mut State, fp: u64) -> Option<String> {
        st.index.remove(&fp);
        self.corrupt_records.fetch_add(1, Ordering::Relaxed);
        self.mx.corrupt.add(1);
        self.misses.fetch_add(1, Ordering::Relaxed);
        self.mx.misses.add(1);
        None
    }

    /// Append (or supersede) an entry and flush it to disk. Compacts when
    /// over half the log is superseded and the log is past the minimum.
    pub fn put(&self, fp: u64, plan_json: &str) -> Result<()> {
        let mut st = self.state.lock().expect("disk tier poisoned");
        // Injected append error, raised BEFORE any state mutation so a
        // failed put leaves the tier exactly as it was.
        if failpoints().should_fail(DISK_WRITE_ERR) {
            bail!("injected failpoint: {DISK_WRITE_ERR}");
        }
        let mut payload = Vec::with_capacity(8 + plan_json.len());
        payload.extend_from_slice(&fp.to_le_bytes());
        payload.extend_from_slice(plan_json.as_bytes());
        let crc = crc32(&payload);
        let mut rec = Vec::with_capacity(RECORD_OVERHEAD as usize + payload.len());
        rec.extend_from_slice(&(payload.len() as u32).to_le_bytes());
        rec.extend_from_slice(&crc.to_le_bytes());
        rec.extend_from_slice(&payload);
        let tail = st.tail;
        st.file.seek(SeekFrom::Start(tail)).context("seeking cache log tail")?;
        st.file.write_all(&rec).context("appending cache log record")?;
        st.file.flush().context("flushing cache log")?;
        let entry = IndexEntry { offset: tail + RECORD_OVERHEAD, len: payload.len() as u32, crc };
        if let Some(old) = st.index.insert(fp, entry) {
            st.live_bytes -= RECORD_OVERHEAD + old.len as u64;
        }
        st.live_bytes += rec.len() as u64;
        st.tail += rec.len() as u64;
        self.appends.fetch_add(1, Ordering::Relaxed);
        self.mx.appends.add(1);
        let total = st.tail - LOG_HEADER_LEN;
        if total >= self.compact_min_bytes && st.live_bytes * 2 < total {
            // A failed compaction degrades to an uncompacted-but-valid
            // log, never a failed put: the append above already landed,
            // `compact` mutates `st` only after the new log is fully
            // installed, and the next put over the threshold retries
            // (a stale .tmp is truncated by its `File::create`).
            let _ = self.compact(&mut st);
        }
        Ok(())
    }

    /// Rewrite the log with live entries only, bumping the generation.
    /// Crash-safe: the new log is fully written and fsynced under a temp
    /// name before the rename; a crash leaves the old log intact.
    fn compact(&self, st: &mut State) -> Result<()> {
        let generation = st.generation + 1;
        self.rewrite(st, generation)
    }

    /// Rewrite the log (live entries, fingerprint order, `generation` in
    /// the header) via tmp+fsync+rename. Shared by threshold compaction
    /// and the sync layer's canonical compaction.
    fn rewrite(&self, st: &mut State, generation: u64) -> Result<()> {
        // Injected compaction-write error, raised before the tmp file
        // exists: the live log is untouched and stays generation N.
        if failpoints().should_fail(DISK_WRITE_ERR) {
            bail!("injected failpoint: {DISK_WRITE_ERR} (mid-compaction)");
        }
        let mut entries: Vec<(u64, Vec<u8>)> = Vec::with_capacity(st.index.len());
        let mut fps: Vec<u64> = st.index.keys().copied().collect();
        fps.sort_unstable();
        for fp in fps {
            let e = st.index[&fp];
            let payload = st
                .read_payload(e)
                .with_context(|| format!("reading record {fp:016x} during compaction"))?;
            entries.push((fp, payload));
        }
        let tmp_path = self.log_path.with_extension("plog.tmp");
        let mut tmp = File::create(&tmp_path)
            .with_context(|| format!("creating {}", tmp_path.display()))?;
        tmp.write_all(&log_header(generation))?;
        let mut tail = LOG_HEADER_LEN;
        let mut index = HashMap::with_capacity(entries.len());
        for (fp, payload) in &entries {
            let crc = crc32(payload);
            tmp.write_all(&(payload.len() as u32).to_le_bytes())?;
            tmp.write_all(&crc.to_le_bytes())?;
            tmp.write_all(payload)?;
            index.insert(
                *fp,
                IndexEntry { offset: tail + RECORD_OVERHEAD, len: payload.len() as u32, crc },
            );
            tail += RECORD_OVERHEAD + payload.len() as u64;
        }
        tmp.sync_all().context("fsyncing compacted cache log")?;
        drop(tmp);
        std::fs::rename(&tmp_path, &self.log_path).context("installing compacted cache log")?;
        let file = OpenOptions::new()
            .read(true)
            .write(true)
            .open(&self.log_path)
            .context("reopening compacted cache log")?;
        let map = if tail >= self.mmap_threshold { Mmap::map(&file, tail) } else { None };
        *st = State { file, index, tail, generation, live_bytes: tail - LOG_HEADER_LEN, map };
        self.compactions.fetch_add(1, Ordering::Relaxed);
        self.mx.compactions.add(1);
        Ok(())
    }

    /// Live `(fingerprint, payload CRC)` pairs in fingerprint order,
    /// straight off the in-memory index — the raw material for the sync
    /// layer's digest tree (DESIGN.md §15). No file I/O.
    pub fn live_index(&self) -> Vec<(u64, u32)> {
        let st = self.state.lock().expect("disk tier poisoned");
        let mut out: Vec<(u64, u32)> = st.index.iter().map(|(fp, e)| (*fp, e.crc)).collect();
        out.sort_unstable_by_key(|&(fp, _)| fp);
        out
    }

    /// Raw payloads (fingerprint prefix + plan JSON) for the requested
    /// fingerprints, in request order, skipping unknown or unreadable
    /// records. Bypasses hit/miss accounting: this is the sync export
    /// path, not a serving probe.
    pub fn export_records(&self, fps: &[u64]) -> Vec<(u64, Vec<u8>)> {
        let mut st = self.state.lock().expect("disk tier poisoned");
        let mut out = Vec::with_capacity(fps.len());
        for &fp in fps {
            let Some(e) = st.index.get(&fp).copied() else { continue };
            let Some(payload) = st.read_payload(e) else { continue };
            if crc32(&payload) == e.crc && payload.len() >= 8 && payload[..8] == fp.to_le_bytes() {
                out.push((fp, payload));
            }
        }
        out
    }

    /// Digest of the live record set: a pure function of the sorted
    /// `(fingerprint, crc, len)` triples (plus the count), independent of
    /// append order, supersession history, and generation counters. Two
    /// tiers holding the same plans have equal digests.
    pub fn content_digest(&self) -> u64 {
        let st = self.state.lock().expect("disk tier poisoned");
        Self::digest_of(&st.index)
    }

    fn digest_of(index: &HashMap<u64, IndexEntry>) -> u64 {
        let mut fps: Vec<u64> = index.keys().copied().collect();
        fps.sort_unstable();
        let mut h = Fnv64::new();
        h.str("automap-plog-content-v1");
        h.u64(fps.len() as u64);
        for fp in fps {
            let e = index[&fp];
            h.u64(fp).u64(e.crc as u64).u64(e.len as u64);
        }
        h.finish()
    }

    /// Canonical compaction for the sync layer: rewrite the log with the
    /// generation set to the content digest, so replicas that hold the
    /// same live set produce byte-identical `plans.plog` files (same
    /// header, same fingerprint-ordered records). A no-op when the log
    /// is already in canonical form. Crash-safe like [`compact`]: the
    /// rename either happens or the old log survives intact.
    pub fn compact_canonical(&self) -> Result<()> {
        let mut st = self.state.lock().expect("disk tier poisoned");
        let digest = Self::digest_of(&st.index);
        // Already canonical: the header carries the content digest and
        // every byte of the record region is live (no superseded or
        // duplicate records, which canonical rewrites never leave).
        if st.generation == digest && st.live_bytes == st.tail - LOG_HEADER_LEN {
            return Ok(());
        }
        self.rewrite(&mut st, digest)
    }

    pub fn stats(&self) -> DiskTierStats {
        let st = self.state.lock().expect("disk tier poisoned");
        DiskTierStats {
            entries: st.index.len(),
            generation: st.generation,
            file_bytes: st.tail,
            live_bytes: st.live_bytes,
            hits: self.hits.load(Ordering::Relaxed),
            misses: self.misses.load(Ordering::Relaxed),
            appends: self.appends.load(Ordering::Relaxed),
            corrupt_records: self.corrupt_records.load(Ordering::Relaxed),
            compactions: self.compactions.load(Ordering::Relaxed),
            quarantined: self.quarantined.load(Ordering::Relaxed),
            quarantine_pruned: self.quarantine_pruned.load(Ordering::Relaxed),
        }
    }
}

/// What an open-time scan of the log bytes found.
enum ScanOutcome {
    /// Zero-length file: brand-new log, write a fresh header.
    Empty,
    /// Unusable header; `header` holds the first bytes for quarantine
    /// naming (claimed generation extraction).
    BadHeader { header: Vec<u8> },
    /// Valid header; records indexed up to `tail` (< file length when a
    /// torn tail must be truncated), with `corrupt` counting the cut.
    Records { generation: u64, index: HashMap<u64, IndexEntry>, tail: u64, corrupt: u64 },
}

impl ScanOutcome {
    fn scan(buf: &[u8]) -> ScanOutcome {
        if buf.is_empty() {
            return ScanOutcome::Empty;
        }
        if buf.len() < LOG_HEADER_LEN as usize
            || buf[..4] != LOG_MAGIC
            || u16::from_le_bytes([buf[4], buf[5]]) != LOG_VERSION
        {
            return ScanOutcome::BadHeader { header: buf[..buf.len().min(16)].to_vec() };
        }
        let mut g8 = [0u8; 8];
        g8.copy_from_slice(&buf[8..16]);
        let generation = u64::from_le_bytes(g8);
        // Scan records; truncate at the first corrupt one.
        let mut corrupt = 0u64;
        let mut index = HashMap::new();
        let mut pos = LOG_HEADER_LEN as usize;
        loop {
            if pos == buf.len() {
                break;
            }
            if buf.len() - pos < RECORD_OVERHEAD as usize {
                corrupt += 1;
                break;
            }
            let len = read_u32_at(buf, pos) as usize;
            let crc = read_u32_at(buf, pos + 4);
            let start = pos + RECORD_OVERHEAD as usize;
            if len < 8 || buf.len() - start < len {
                corrupt += 1;
                break;
            }
            let payload = &buf[start..start + len];
            if crc32(payload) != crc {
                corrupt += 1;
                break;
            }
            let mut fp8 = [0u8; 8];
            fp8.copy_from_slice(&payload[..8]);
            let fp = u64::from_le_bytes(fp8);
            index.insert(fp, IndexEntry { offset: start as u64, len: len as u32, crc });
            pos = start + len;
        }
        ScanOutcome::Records { generation, index, tail: pos as u64, corrupt }
    }
}

/// Delete all but the `keep` newest `<stem>.corrupt-*` files in `dir`
/// (newest by mtime, name-descending on ties), returning how many were
/// removed. Shared by the plan-log quarantine and the sync-frame
/// quarantine so neither can grow without bound.
pub fn prune_quarantines(dir: &Path, stem: &str, keep: usize) -> u64 {
    let prefix = format!("{stem}.corrupt-");
    let Ok(entries) = std::fs::read_dir(dir) else { return 0 };
    let mut found: Vec<(std::time::SystemTime, String, PathBuf)> = Vec::new();
    for entry in entries.flatten() {
        let name = entry.file_name().to_string_lossy().into_owned();
        if !name.starts_with(&prefix) {
            continue;
        }
        let mtime = entry
            .metadata()
            .and_then(|m| m.modified())
            .unwrap_or(std::time::SystemTime::UNIX_EPOCH);
        found.push((mtime, name, entry.path()));
    }
    if found.len() <= keep {
        return 0;
    }
    found.sort_by(|a, b| b.0.cmp(&a.0).then_with(|| b.1.cmp(&a.1)));
    let mut pruned = 0u64;
    for (_, _, path) in found.drain(keep..) {
        if std::fs::remove_file(&path).is_ok() {
            pruned += 1;
        }
    }
    pruned
}

/// Where an unreadable log gets moved: `plans.plog.corrupt-<gen>`, with
/// `<gen>` taken from the header when the magic still matches (version
/// skew) and 0 otherwise (foreign bytes), plus a numeric suffix when a
/// previous quarantine already claimed the name.
fn quarantine_path(log_path: &Path, buf: &[u8]) -> PathBuf {
    let gen = if buf.len() >= 16 && buf[..4] == LOG_MAGIC {
        let mut g8 = [0u8; 8];
        g8.copy_from_slice(&buf[8..16]);
        u64::from_le_bytes(g8)
    } else {
        0
    };
    let base = log_path.with_extension(format!("plog.corrupt-{gen}"));
    if !base.exists() {
        return base;
    }
    for i in 1u32.. {
        let p = log_path.with_extension(format!("plog.corrupt-{gen}.{i}"));
        if !p.exists() {
            return p;
        }
    }
    unreachable!("u32 quarantine suffixes exhausted")
}

fn read_u32_at(buf: &[u8], pos: usize) -> u32 {
    u32::from_le_bytes([buf[pos], buf[pos + 1], buf[pos + 2], buf[pos + 3]])
}

impl std::fmt::Debug for DiskTier {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let s = self.stats();
        write!(
            f,
            "DiskTier({}, {} entries, gen {}, {} bytes)",
            self.log_path.display(),
            s.entries,
            s.generation,
            s.file_bytes
        )
    }
}

/// Validate a log header out-of-band (used by tooling/tests); returns the
/// generation.
pub fn read_log_generation(path: &Path) -> Result<u64> {
    let mut h = [0u8; LOG_HEADER_LEN as usize];
    let mut f = File::open(path).with_context(|| format!("opening {}", path.display()))?;
    f.read_exact(&mut h).context("log shorter than its fixed header")?;
    if h[..4] != LOG_MAGIC {
        bail!("bad log magic (expected \"PLOG\")");
    }
    let version = u16::from_le_bytes([h[4], h[5]]);
    if version != LOG_VERSION {
        bail!("unsupported log version {version}; this build supports version {LOG_VERSION}");
    }
    let mut g8 = [0u8; 8];
    g8.copy_from_slice(&h[8..16]);
    Ok(u64::from_le_bytes(g8))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn temp_dir(name: &str) -> PathBuf {
        let dir = std::env::temp_dir()
            .join(format!("automap-persist-unit-{}-{name}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        dir
    }

    #[test]
    fn crc32_known_vector() {
        assert_eq!(crc32(b"123456789"), 0xcbf4_3926);
        assert_eq!(crc32(b""), 0);
    }

    #[test]
    fn put_get_and_supersede() {
        let dir = temp_dir("putget");
        let tier = DiskTier::open(&dir).unwrap();
        assert_eq!(tier.get(1), None);
        tier.put(1, "{\"v\":1}").unwrap();
        tier.put(2, "{\"v\":2}").unwrap();
        assert_eq!(tier.get(1).as_deref(), Some("{\"v\":1}"));
        tier.put(1, "{\"v\":3}").unwrap();
        assert_eq!(tier.get(1).as_deref(), Some("{\"v\":3}"), "later records supersede");
        let s = tier.stats();
        assert_eq!((s.entries, s.appends, s.hits, s.misses), (2, 3, 2, 1));
        assert_eq!(s.corrupt_records, 0);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn reopen_recovers_the_index() {
        let dir = temp_dir("reopen");
        {
            let tier = DiskTier::open(&dir).unwrap();
            tier.put(7, "{\"plan\":true}").unwrap();
        }
        let tier = DiskTier::open(&dir).unwrap();
        assert_eq!(tier.get(7).as_deref(), Some("{\"plan\":true}"));
        assert_eq!(tier.stats().corrupt_records, 0);
        assert_eq!(read_log_generation(tier.log_path()).unwrap(), 0);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn torn_tail_is_truncated_and_counted() {
        let dir = temp_dir("torn");
        let log = {
            let tier = DiskTier::open(&dir).unwrap();
            tier.put(1, "{\"keep\":true}").unwrap();
            tier.log_path().to_path_buf()
        };
        // Simulate a crash mid-append: garbage after the good record.
        let mut f = OpenOptions::new().append(true).open(&log).unwrap();
        f.write_all(&[0xde, 0xad, 0xbe]).unwrap();
        drop(f);
        let tier = DiskTier::open(&dir).unwrap();
        assert_eq!(tier.get(1).as_deref(), Some("{\"keep\":true}"));
        assert_eq!(tier.stats().corrupt_records, 1);
        // The truncation healed the log: a fresh open is clean.
        drop(tier);
        let tier = DiskTier::open(&dir).unwrap();
        assert_eq!(tier.stats().corrupt_records, 0);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn compaction_preserves_entries_and_bumps_generation() {
        let dir = temp_dir("compact");
        // Tiny threshold so rewriting the same key triggers compaction.
        let tier = DiskTier::open_with(&dir, 64).unwrap();
        for i in 0..20 {
            tier.put(42, &format!("{{\"rev\":{i}}}")).unwrap();
            tier.put(7, "{\"stable\":true}").unwrap();
        }
        let s = tier.stats();
        assert!(s.compactions > 0, "superseded log must have compacted: {s:?}");
        assert_eq!(s.entries, 2);
        assert_eq!(tier.get(42).as_deref(), Some("{\"rev\":19}"));
        assert_eq!(tier.get(7).as_deref(), Some("{\"stable\":true}"));
        let gen = read_log_generation(tier.log_path()).unwrap();
        assert!(gen >= 1, "compaction bumps the generation");
        // Entries survive a reopen of the compacted log.
        drop(tier);
        let tier = DiskTier::open(&dir).unwrap();
        assert_eq!(tier.get(42).as_deref(), Some("{\"rev\":19}"));
        assert_eq!(tier.stats().generation, gen);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn foreign_file_is_quarantined_not_trusted_or_destroyed() {
        let dir = temp_dir("foreign");
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("plans.plog"), b"not a log at all").unwrap();
        let tier = DiskTier::open(&dir).unwrap();
        let s = tier.stats();
        assert_eq!(s.quarantined, 1, "unreadable log must be quarantined");
        assert_eq!(s.corrupt_records, 0, "quarantine is not a record-level event");
        // The fresh log serves normally...
        tier.put(5, "{}").unwrap();
        assert_eq!(tier.get(5).as_deref(), Some("{}"));
        // ...and the original bytes survive for forensics under the
        // generation-stamped name (foreign bytes have no generation → 0).
        let q = dir.join("plans.plog.corrupt-0");
        assert_eq!(std::fs::read(&q).unwrap(), b"not a log at all");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn repeated_quarantines_never_collide() {
        let dir = temp_dir("quarantine-twice");
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("plans.plog"), b"garbage one").unwrap();
        drop(DiskTier::open(&dir).unwrap());
        std::fs::write(dir.join("plans.plog"), b"garbage two").unwrap();
        let tier = DiskTier::open(&dir).unwrap();
        assert_eq!(tier.stats().quarantined, 1, "per-open count");
        assert_eq!(std::fs::read(dir.join("plans.plog.corrupt-0")).unwrap(), b"garbage one");
        assert_eq!(std::fs::read(dir.join("plans.plog.corrupt-0.1")).unwrap(), b"garbage two");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn mmap_reads_match_buffered_reads_exactly() {
        let dir = temp_dir("mmap");
        {
            let tier = DiskTier::open(&dir).unwrap();
            for i in 0u64..32 {
                tier.put(i, &format!("{{\"plan\":{i}}}")).unwrap();
            }
            tier.put(3, "{\"plan\":\"superseded\"}").unwrap();
        }
        // Buffered open (threshold never reached) vs mapped open
        // (threshold 1 byte): identical probes, identical live index.
        let buffered = DiskTier::open_with_opts(&dir, 1 << 20, u64::MAX).unwrap();
        let mapped = DiskTier::open_with_opts(&dir, 1 << 20, 1).unwrap();
        assert_eq!(buffered.live_index(), mapped.live_index());
        assert_eq!(buffered.content_digest(), mapped.content_digest());
        for i in 0u64..32 {
            assert_eq!(buffered.get(i), mapped.get(i), "fp {i} diverges under mmap");
        }
        assert_eq!(mapped.get(3).as_deref(), Some("{\"plan\":\"superseded\"}"));
        // Appends past the map fall back to buffered reads transparently.
        mapped.put(1000, "{\"fresh\":true}").unwrap();
        assert_eq!(mapped.get(1000).as_deref(), Some("{\"fresh\":true}"));
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn mmap_open_truncates_torn_tails_too() {
        let dir = temp_dir("mmap-torn");
        let log = {
            let tier = DiskTier::open(&dir).unwrap();
            tier.put(1, "{\"keep\":true}").unwrap();
            tier.log_path().to_path_buf()
        };
        let mut f = OpenOptions::new().append(true).open(&log).unwrap();
        f.write_all(&[0xde, 0xad]).unwrap();
        drop(f);
        let tier = DiskTier::open_with_opts(&dir, 1 << 20, 1).unwrap();
        assert_eq!(tier.get(1).as_deref(), Some("{\"keep\":true}"));
        assert_eq!(tier.stats().corrupt_records, 1);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn quarantine_growth_is_capped() {
        let dir = temp_dir("quarantine-cap");
        std::fs::create_dir_all(&dir).unwrap();
        let mut pruned_total = 0;
        for i in 0..7u32 {
            std::fs::write(dir.join("plans.plog"), format!("garbage {i}")).unwrap();
            let tier = DiskTier::open(&dir).unwrap();
            pruned_total += tier.stats().quarantine_pruned;
        }
        let corrupt: Vec<String> = std::fs::read_dir(&dir)
            .unwrap()
            .flatten()
            .map(|e| e.file_name().to_string_lossy().into_owned())
            .filter(|n| n.contains(".corrupt-"))
            .collect();
        assert_eq!(
            corrupt.len(),
            MAX_QUARANTINES,
            "quarantines must be pruned to the cap: {corrupt:?}"
        );
        assert_eq!(pruned_total, 7 - MAX_QUARANTINES as u64, "every prune is counted");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn canonical_compaction_is_append_order_independent() {
        let dir_a = temp_dir("canon-a");
        let dir_b = temp_dir("canon-b");
        let a = DiskTier::open(&dir_a).unwrap();
        let b = DiskTier::open(&dir_b).unwrap();
        // Same final live set, different append orders and histories.
        a.put(10, "{\"p\":10}").unwrap();
        a.put(20, "{\"old\":true}").unwrap();
        a.put(30, "{\"p\":30}").unwrap();
        a.put(20, "{\"p\":20}").unwrap();
        b.put(30, "{\"p\":30}").unwrap();
        b.put(20, "{\"p\":20}").unwrap();
        b.put(10, "{\"p\":10}").unwrap();
        assert_eq!(a.content_digest(), b.content_digest(), "digest ignores history");
        a.compact_canonical().unwrap();
        b.compact_canonical().unwrap();
        let bytes_a = std::fs::read(a.log_path()).unwrap();
        let bytes_b = std::fs::read(b.log_path()).unwrap();
        assert_eq!(bytes_a, bytes_b, "canonical logs must be byte-identical");
        assert_eq!(read_log_generation(a.log_path()).unwrap(), a.content_digest());
        // Idempotent: a second canonical pass rewrites nothing.
        let compactions = a.stats().compactions;
        a.compact_canonical().unwrap();
        assert_eq!(a.stats().compactions, compactions, "canonical form is a no-op");
        // The canonical log still serves and reopens.
        assert_eq!(a.get(20).as_deref(), Some("{\"p\":20}"));
        drop(a);
        let a = DiskTier::open(&dir_a).unwrap();
        assert_eq!(a.get(10).as_deref(), Some("{\"p\":10}"));
        let _ = std::fs::remove_dir_all(&dir_a);
        let _ = std::fs::remove_dir_all(&dir_b);
    }

    #[test]
    fn live_index_and_export_expose_the_live_set() {
        let dir = temp_dir("export");
        let tier = DiskTier::open(&dir).unwrap();
        tier.put(5, "{\"p\":5}").unwrap();
        tier.put(9, "{\"old\":9}").unwrap();
        tier.put(9, "{\"p\":9}").unwrap();
        let idx = tier.live_index();
        assert_eq!(idx.len(), 2);
        assert_eq!(idx[0].0, 5, "live_index is fingerprint-sorted");
        assert_eq!(idx[1].0, 9);
        let recs = tier.export_records(&[9, 5, 77]);
        assert_eq!(recs.len(), 2, "unknown fingerprints are skipped");
        assert_eq!(recs[0].0, 9);
        assert_eq!(&recs[0].1[..8], &9u64.to_le_bytes());
        assert_eq!(&recs[0].1[8..], b"{\"p\":9}");
        let crc = idx.iter().find(|(fp, _)| *fp == 9).unwrap().1;
        assert_eq!(crc, crc32(&recs[0].1), "index CRC matches the payload");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn version_skewed_log_is_quarantined_under_its_generation() {
        let dir = temp_dir("quarantine-skew");
        std::fs::create_dir_all(&dir).unwrap();
        // A well-formed header from an imaginary future format version,
        // generation 9: the quarantine name must preserve the generation.
        let mut h = log_header(9).to_vec();
        h[4..6].copy_from_slice(&(LOG_VERSION + 1).to_le_bytes());
        std::fs::write(dir.join("plans.plog"), &h).unwrap();
        let tier = DiskTier::open(&dir).unwrap();
        assert_eq!(tier.stats().quarantined, 1);
        assert!(dir.join("plans.plog.corrupt-9").exists());
        let _ = std::fs::remove_dir_all(&dir);
    }
}
