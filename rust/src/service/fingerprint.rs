//! Canonical structural fingerprints for partition requests (DESIGN.md
//! §9): a Merkle-style DAG hash of the program combined with the mesh,
//! target device, user constraints, cost weights, and search
//! configuration.
//!
//! The hash is *structural*, not positional: every value's hash is
//! derived from its own content plus the hashes of its operands, never
//! from raw `ValueId` numbering. Two builds of the same program whose
//! independent nodes were created in a different order — and therefore
//! carry different value ids — produce the same fingerprint, so
//! semantically identical requests hit the same cache line. Dead nodes
//! (unreachable from the outputs) do not contribute, making the
//! fingerprint DCE-invariant as well.

use crate::cost::composite::CostWeights;
use crate::ir::Func;
use crate::partir::mesh::Mesh;
use crate::search::env::SearchOptions;
use crate::search::mcts::MctsConfig;
use crate::session::{RankerSpec, Tactic};
use crate::sim::device::Device;
use crate::util::hash::Fnv64;

/// A 64-bit request fingerprint (the plan-cache key).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct Fingerprint(pub u64);

impl Fingerprint {
    /// Fixed-width lowercase hex, the wire form used in responses.
    pub fn hex(&self) -> String {
        format!("{:016x}", self.0)
    }
}

impl std::fmt::Display for Fingerprint {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(&self.hex())
    }
}

/// Structural hash of a function: per-value Merkle hashes folded over
/// the argument list (in signature order) and the output list.
pub fn func_fingerprint(f: &Func) -> u64 {
    let mut vh = vec![0u64; f.num_values()];
    for (i, arg) in f.args.iter().enumerate() {
        let mut h = Fnv64::new();
        h.str("arg");
        h.str(&arg.name);
        h.str(arg.kind.name());
        h.str(arg.ty.dtype.name());
        for &d in &arg.ty.dims {
            h.i64(d);
        }
        h.str(f.scope_path(arg.scope));
        vh[i] = h.finish();
    }
    for (ni, node) in f.nodes.iter().enumerate() {
        let mut h = Fnv64::new();
        h.str("node");
        // Debug form covers the op kind AND its attributes (dot dims,
        // reduce dims, permutations, const values, ...), which `name()`
        // alone would not.
        h.str(&format!("{:?}", node.op));
        h.str(node.ty.dtype.name());
        for &d in &node.ty.dims {
            h.i64(d);
        }
        for &inp in &node.inputs {
            h.u64(vh[inp.index()]);
        }
        h.str(f.scope_path(node.scope));
        vh[f.num_args() + ni] = h.finish();
    }
    let mut h = Fnv64::new();
    h.str("func");
    h.usize(f.num_args());
    for i in 0..f.num_args() {
        h.u64(vh[i]);
    }
    h.usize(f.outputs.len());
    for &o in &f.outputs {
        h.u64(vh[o.index()]);
    }
    h.finish()
}

fn hash_mesh(h: &mut Fnv64, mesh: &Mesh) {
    h.str("mesh");
    h.usize(mesh.num_axes());
    for axis in &mesh.axes {
        h.str(&axis.name);
        h.i64(axis.size);
        h.bool(axis.searchable);
    }
}

fn hash_device(h: &mut Fnv64, d: &Device) {
    h.str("device");
    h.str(d.name);
    h.f64(d.flops);
    h.f64(d.hbm_bw);
    h.f64(d.ici_bw);
    h.f64(d.alpha);
    h.i64(d.hbm_bytes);
}

fn hash_weights(h: &mut Fnv64, w: &CostWeights) {
    h.str("weights");
    h.f64(w.mem_overflow);
    h.f64(w.comm_bytes);
    h.f64(w.runtime);
    h.f64(w.mem_bytes);
}

fn hash_options(h: &mut Fnv64, o: &SearchOptions) {
    h.str("options");
    h.usize(o.max_decisions);
    h.bool(o.grouping);
    h.bool(o.cross_layer_tying);
    h.bool(o.auto_infer_rest);
}

fn hash_mcts(h: &mut Fnv64, m: &MctsConfig) {
    h.str("mcts");
    h.f64(m.exploration);
    h.f64(m.rollout_stop_prob);
}

fn hash_ranker(h: &mut Fnv64, r: &RankerSpec) {
    match r {
        RankerSpec::None => {
            h.str("ranker:none");
        }
        RankerSpec::Heuristic => {
            h.str("ranker:heuristic");
        }
        RankerSpec::Learned { hlo_path } => {
            h.str("ranker:learned");
            h.str(hlo_path);
        }
        RankerSpec::Auto { hlo_path } => {
            h.str("ranker:auto");
            h.str(hlo_path);
        }
    }
}

fn hash_tactic(h: &mut Fnv64, t: &Tactic) {
    match t {
        Tactic::Manual { constraints, manual_axes } => {
            h.str("manual");
            h.usize(constraints.len());
            for c in constraints {
                h.str(&c.name);
                h.usize(c.dim);
                h.str(&c.axis);
            }
            h.usize(manual_axes.len());
            for a in manual_axes {
                h.str(a);
            }
        }
        Tactic::Filter { ranker, top_k } => {
            h.str("filter");
            hash_ranker(h, ranker);
            h.usize(*top_k);
        }
        Tactic::Search { budget, seed, mcts } => {
            h.str("search");
            h.usize(*budget);
            h.u64(*seed);
            hash_mcts(h, mcts);
        }
        Tactic::Pipeline { axis, stages, microbatches } => {
            h.str("pipeline");
            h.str(axis);
            h.usize(*stages);
            h.usize(*microbatches);
        }
        Tactic::InferRest => {
            h.str("infer-rest");
        }
        Tactic::Lower => {
            h.str("lower");
        }
    }
}

/// Fingerprint of a full partition request: program structure, mesh,
/// target device, pre-search tactics (manual constraints + filter),
/// cost weights, search options, and the executor configuration.
/// Everything that can change the returned plan is folded in — the
/// device included, so replicas configured for different hardware never
/// share a cache line — and a cache hit is always safe to serve.
#[allow(clippy::too_many_arguments)]
pub fn request_fingerprint(
    func: &Func,
    mesh: &Mesh,
    device: &Device,
    weights: &CostWeights,
    options: &SearchOptions,
    pre_tactics: &[Tactic],
    budget: usize,
    seed: u64,
    workers: usize,
    mcts: &MctsConfig,
) -> Fingerprint {
    let mut h = Fnv64::new();
    h.str("automap-plan-request-v1");
    h.u64(func_fingerprint(func));
    hash_mesh(&mut h, mesh);
    hash_device(&mut h, device);
    hash_weights(&mut h, weights);
    hash_options(&mut h, options);
    h.usize(pre_tactics.len());
    for t in pre_tactics {
        hash_tactic(&mut h, t);
    }
    h.usize(budget);
    h.u64(seed);
    h.usize(workers);
    hash_mcts(&mut h, mcts);
    Fingerprint(h.finish())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::{ArgKind, GraphBuilder, TensorType};
    use crate::models::mlp::{build_mlp, MlpConfig};
    use crate::session::ShardingConstraint;

    /// Two builds of the same two-chain program with the independent
    /// middle nodes created in opposite orders: node ids differ, the
    /// structure does not.
    fn two_chain(neg_first: bool) -> Func {
        let mut b = GraphBuilder::new("two_chain");
        let x = b.arg("x", TensorType::f32(&[8, 8]), ArgKind::Input);
        let y = b.arg("y", TensorType::f32(&[8, 8]), ArgKind::Input);
        let (a, c) = if neg_first {
            let a = b.neg(x);
            let c = b.abs(y);
            (a, c)
        } else {
            let c = b.abs(y);
            let a = b.neg(x);
            (a, c)
        };
        b.output(a);
        b.output(c);
        b.finish()
    }

    #[test]
    fn stable_across_value_id_renumbering() {
        let f1 = two_chain(true);
        let f2 = two_chain(false);
        // The interleaved builds really do number the nodes differently…
        assert_ne!(format!("{:?}", f1.nodes[0].op), format!("{:?}", f2.nodes[0].op));
        // …yet the structural fingerprint is identical.
        assert_eq!(func_fingerprint(&f1), func_fingerprint(&f2));
    }

    #[test]
    fn distinguishes_programs_meshes_and_configs() {
        let f = build_mlp(&MlpConfig::small()).func;
        let f_other = two_chain(true);
        assert_ne!(func_fingerprint(&f), func_fingerprint(&f_other));

        let mesh_a = Mesh::new(&[("model", 4)]);
        let mesh_b = Mesh::new(&[("model", 8)]);
        let v3 = Device::tpu_v3();
        let v2 = Device::tpu_v2();
        let w = CostWeights::default();
        let o = SearchOptions::default();
        let m = MctsConfig::default();
        let base = request_fingerprint(&f, &mesh_a, &v3, &w, &o, &[], 100, 0, 4, &m);
        assert_eq!(base, request_fingerprint(&f, &mesh_a, &v3, &w, &o, &[], 100, 0, 4, &m));
        assert_ne!(base, request_fingerprint(&f, &mesh_b, &v3, &w, &o, &[], 100, 0, 4, &m));
        assert_ne!(base, request_fingerprint(&f, &mesh_a, &v2, &w, &o, &[], 100, 0, 4, &m));
        assert_ne!(base, request_fingerprint(&f, &mesh_a, &v3, &w, &o, &[], 200, 0, 4, &m));
        assert_ne!(base, request_fingerprint(&f, &mesh_a, &v3, &w, &o, &[], 100, 1, 4, &m));
        assert_ne!(base, request_fingerprint(&f, &mesh_a, &v3, &w, &o, &[], 100, 0, 2, &m));

        let pinned = [Tactic::Manual {
            constraints: vec![ShardingConstraint::new("x", 0, "model")],
            manual_axes: vec![],
        }];
        assert_ne!(base, request_fingerprint(&f, &mesh_a, &v3, &w, &o, &pinned, 100, 0, 4, &m));
    }

    #[test]
    fn textual_round_trip_preserves_structure_and_fingerprint() {
        use crate::ir::{parse_func, print_func};
        use crate::models::graphnet::{build_graphnet, GraphNetConfig};
        use crate::models::transformer::{build_transformer, TransformerConfig};
        for f in [
            build_mlp(&MlpConfig::small()).func,
            build_transformer(&TransformerConfig::tiny(2)).func,
            build_graphnet(&GraphNetConfig::small()).func,
        ] {
            let name = f.name.clone();
            let g = parse_func(&print_func(&f))
                .unwrap_or_else(|e| panic!("printed {name} must parse: {e}"));
            assert_eq!(g, f, "parse(print(f)) != f for {name}");
            assert_eq!(
                func_fingerprint(&g),
                func_fingerprint(&f),
                "fingerprint must survive the textual round-trip for {name}"
            );
        }
    }

    #[test]
    fn hex_form_is_fixed_width() {
        assert_eq!(Fingerprint(0xab).hex(), "00000000000000ab");
        assert_eq!(Fingerprint(u64::MAX).hex(), "ffffffffffffffff");
    }
}
