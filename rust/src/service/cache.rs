//! Sharded, lock-striped LRU plan cache (DESIGN.md §9).
//!
//! Plans are stored as their serialised JSON strings keyed by request
//! [`Fingerprint`], so a cache hit returns the *byte-identical* document
//! the original search produced — important for clients that diff or
//! checksum plans. The map is split into `N` shards, each behind its own
//! mutex, so concurrent front-end threads only contend when they touch
//! the same shard. Eviction is byte-budgeted LRU per shard, backed by a
//! tick-ordered index so each eviction is O(log n): inserts that push a
//! shard over `byte_budget / N` evict least-recently-used entries first,
//! and an entry larger than a whole shard's budget is refused outright
//! (it would otherwise churn every resident entry out on its way to
//! being evicted itself). Hit/miss/eviction counters are lock-free
//! atomics; `misses` counts missed [`PlanCache::get`] probes only — the
//! service's double-check probe is uncounted, so one request records at
//! most one miss.

use super::fingerprint::Fingerprint;
use std::collections::{BTreeMap, HashMap};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

/// Fixed per-entry bookkeeping charge (key + tick + map overhead),
/// added to the JSON length when accounting against the byte budget.
const ENTRY_OVERHEAD: usize = 64;

struct Entry {
    plan_json: String,
    /// Shard-local logical clock value at last touch (insert or hit);
    /// also this entry's key in the shard's `lru` index.
    last_used: u64,
}

#[derive(Default)]
struct Shard {
    map: HashMap<u64, Entry>,
    /// LRU index: `last_used` tick -> fingerprint. Ticks are unique per
    /// shard (monotonic under the shard lock), so the first key is
    /// always the least-recently-used entry.
    lru: BTreeMap<u64, u64>,
    tick: u64,
    bytes: usize,
}

impl Shard {
    fn touch(&mut self, key: u64) -> Option<String> {
        self.tick += 1;
        let tick = self.tick;
        let e = self.map.get_mut(&key)?;
        self.lru.remove(&e.last_used);
        e.last_used = tick;
        self.lru.insert(tick, key);
        Some(e.plan_json.clone())
    }

    /// Evict LRU entries until `bytes <= budget`. Returns evictions.
    fn evict_to(&mut self, budget: usize) -> u64 {
        let mut evicted = 0;
        while self.bytes > budget && !self.map.is_empty() {
            let (&tick, &victim) = self.lru.iter().next().expect("lru index in sync with map");
            self.lru.remove(&tick);
            let e = self.map.remove(&victim).expect("victim present");
            self.bytes -= e.plan_json.len() + ENTRY_OVERHEAD;
            evicted += 1;
        }
        evicted
    }
}

/// Aggregate cache statistics (counters are monotonic).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct CacheStats {
    pub hits: u64,
    pub misses: u64,
    pub evictions: u64,
    pub entries: usize,
    pub bytes: usize,
}

pub struct PlanCache {
    shards: Vec<Mutex<Shard>>,
    shard_budget: usize,
    hits: AtomicU64,
    misses: AtomicU64,
    evictions: AtomicU64,
}

impl PlanCache {
    /// `num_shards` lock stripes sharing `byte_budget` bytes of plan
    /// JSON (split evenly across shards).
    pub fn new(num_shards: usize, byte_budget: usize) -> PlanCache {
        let n = num_shards.max(1);
        PlanCache {
            shards: (0..n).map(|_| Mutex::new(Shard::default())).collect(),
            shard_budget: byte_budget / n,
            hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
            evictions: AtomicU64::new(0),
        }
    }

    fn shard(&self, fp: Fingerprint) -> &Mutex<Shard> {
        // The fingerprint is already well-mixed; low bits pick the stripe.
        &self.shards[(fp.0 % self.shards.len() as u64) as usize]
    }

    /// Look up a plan; a hit refreshes the entry's LRU position.
    pub fn get(&self, fp: Fingerprint) -> Option<String> {
        let got = self.shard(fp).lock().expect("cache shard poisoned").touch(fp.0);
        match &got {
            Some(_) => self.hits.fetch_add(1, Ordering::Relaxed),
            None => self.misses.fetch_add(1, Ordering::Relaxed),
        };
        got
    }

    /// Like [`PlanCache::get`], but a miss is not counted. Used for the
    /// service's double-check under the in-flight lock, so a request
    /// that probes twice before searching still records one miss.
    pub fn probe(&self, fp: Fingerprint) -> Option<String> {
        let got = self.shard(fp).lock().expect("cache shard poisoned").touch(fp.0);
        if got.is_some() {
            self.hits.fetch_add(1, Ordering::Relaxed);
        }
        got
    }

    /// Insert (or replace) a plan, then evict LRU entries while the
    /// shard exceeds its byte budget. An entry larger than the whole
    /// shard budget is refused without touching resident entries
    /// (counted as an eviction).
    pub fn put(&self, fp: Fingerprint, plan_json: String) {
        let cost = plan_json.len() + ENTRY_OVERHEAD;
        if cost > self.shard_budget {
            self.evictions.fetch_add(1, Ordering::Relaxed);
            return;
        }
        let mut shard = self.shard(fp).lock().expect("cache shard poisoned");
        shard.tick += 1;
        let tick = shard.tick;
        if let Some(old) = shard.map.insert(fp.0, Entry { plan_json, last_used: tick }) {
            shard.bytes -= old.plan_json.len() + ENTRY_OVERHEAD;
            shard.lru.remove(&old.last_used);
        }
        shard.lru.insert(tick, fp.0);
        shard.bytes += cost;
        let evicted = shard.evict_to(self.shard_budget);
        if evicted > 0 {
            self.evictions.fetch_add(evicted, Ordering::Relaxed);
        }
    }

    pub fn stats(&self) -> CacheStats {
        let mut entries = 0;
        let mut bytes = 0;
        for s in &self.shards {
            let s = s.lock().expect("cache shard poisoned");
            debug_assert_eq!(s.map.len(), s.lru.len(), "lru index out of sync");
            entries += s.map.len();
            bytes += s.bytes;
        }
        CacheStats {
            hits: self.hits.load(Ordering::Relaxed),
            misses: self.misses.load(Ordering::Relaxed),
            evictions: self.evictions.load(Ordering::Relaxed),
            entries,
            bytes,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fp(x: u64) -> Fingerprint {
        Fingerprint(x)
    }

    #[test]
    fn get_put_roundtrip_and_counters() {
        let c = PlanCache::new(4, 1 << 20);
        assert_eq!(c.get(fp(1)), None);
        c.put(fp(1), "{\"plan\":1}".to_string());
        assert_eq!(c.get(fp(1)).as_deref(), Some("{\"plan\":1}"));
        let s = c.stats();
        assert_eq!((s.hits, s.misses, s.entries), (1, 1, 1));
        assert!(s.bytes > 0);
    }

    #[test]
    fn probe_counts_hits_but_not_misses() {
        let c = PlanCache::new(2, 1 << 20);
        assert_eq!(c.probe(fp(1)), None);
        assert_eq!(c.stats().misses, 0, "probe misses are uncounted");
        c.put(fp(1), "{}".to_string());
        assert!(c.probe(fp(1)).is_some());
        assert_eq!(c.stats().hits, 1);
    }

    #[test]
    fn lru_eviction_under_tiny_budget() {
        // One shard so insertion order fully determines eviction order.
        // Budget fits two small entries but not three.
        let entry = "x".repeat(100);
        let c = PlanCache::new(1, 2 * (100 + ENTRY_OVERHEAD));
        c.put(fp(1), entry.clone());
        c.put(fp(2), entry.clone());
        assert_eq!(c.stats().evictions, 0);
        // Touch 1 so 2 becomes the LRU victim.
        assert!(c.get(fp(1)).is_some());
        c.put(fp(3), entry.clone());
        let s = c.stats();
        assert_eq!(s.evictions, 1);
        assert_eq!(s.entries, 2);
        assert!(c.get(fp(2)).is_none(), "LRU entry must have been evicted");
        assert!(c.get(fp(1)).is_some());
        assert!(c.get(fp(3)).is_some());
    }

    #[test]
    fn oversized_entry_is_refused_without_evicting_residents() {
        let small = "s".repeat(32);
        let c = PlanCache::new(1, 2 * (100 + ENTRY_OVERHEAD));
        c.put(fp(1), small.clone());
        c.put(fp(9), "y".repeat(4096));
        let s = c.stats();
        assert_eq!(s.entries, 1, "resident entry must survive an oversized put");
        assert_eq!(s.evictions, 1, "the refusal is counted");
        assert!(c.get(fp(9)).is_none());
        assert!(c.get(fp(1)).is_some());
    }

    #[test]
    fn replacing_an_entry_does_not_leak_bytes() {
        let c = PlanCache::new(1, 1 << 20);
        c.put(fp(5), "a".repeat(500));
        let b1 = c.stats().bytes;
        c.put(fp(5), "b".repeat(500));
        assert_eq!(c.stats().bytes, b1);
        assert_eq!(c.stats().entries, 1);
        assert_eq!(c.get(fp(5)).unwrap().as_bytes()[0], b'b');
    }

    #[test]
    fn eviction_order_follows_touch_order_under_pressure() {
        let entry = "e".repeat(100);
        let per = 100 + ENTRY_OVERHEAD;
        let c = PlanCache::new(1, 4 * per);
        for i in 0..4 {
            c.put(fp(i), entry.clone());
        }
        // Refresh 0 and 2; inserting two more must evict 1 then 3.
        assert!(c.get(fp(0)).is_some());
        assert!(c.get(fp(2)).is_some());
        c.put(fp(10), entry.clone());
        c.put(fp(11), entry.clone());
        assert!(c.get(fp(1)).is_none());
        assert!(c.get(fp(3)).is_none());
        for k in [0, 2, 10, 11] {
            assert!(c.get(fp(k)).is_some(), "key {k} should be resident");
        }
        assert_eq!(c.stats().evictions, 2);
    }

    #[test]
    fn concurrent_access_is_safe() {
        let c = std::sync::Arc::new(PlanCache::new(8, 1 << 20));
        std::thread::scope(|s| {
            for t in 0..4u64 {
                let c = c.clone();
                s.spawn(move || {
                    for i in 0..200u64 {
                        let k = fp(t * 1000 + i);
                        c.put(k, format!("{{\"v\":{i}}}"));
                        assert!(c.get(k).is_some());
                    }
                });
            }
        });
        assert_eq!(c.stats().entries, 800);
    }
}
