//! Replica anti-entropy over the persistent plan tier (DESIGN.md §15).
//!
//! The paper's fleet framing — plans as reusable artifacts amortized
//! across many replicas serving shared model families — needs the local
//! plan log (DESIGN.md §13) to flow *between* replicas. This module
//! implements that exchange as an anti-entropy protocol:
//!
//! 1. **Summarize.** Each replica digests its live record set as a
//!    two-level Merkle tree: 256 bucket digests over fingerprint ranges
//!    (bucket = top byte of the fingerprint), rolled into one root. All
//!    hashing is the same FNV-1a substrate the request fingerprints use
//!    ([`crate::util::hash::Fnv64`]); no file I/O — digests come off the
//!    in-memory `(fingerprint, crc)` index.
//! 2. **Diff.** Equal roots mean nothing to do. Otherwise only the
//!    differing buckets are listed, and only fingerprints that are
//!    missing locally (or carry a different CRC) are requested.
//! 3. **Pull.** Deltas arrive as length+CRC-framed record batches — the
//!    PR 8 log framing verbatim, so a delta batch is a valid log tail.
//!    Every frame is CRC-verified on receipt; corrupt or malformed
//!    frames are QUARANTINED to `sync-frame.corrupt-*` files (pruned to
//!    the same cap as log quarantines), never applied and never fatal.
//! 4. **Merge + land.** Missing records append through the normal
//!    `put` path (later-record-wins). A same-fingerprint CRC conflict —
//!    which deterministic search should never produce, so it implies
//!    corruption or version skew upstream — is resolved by a symmetric
//!    tie-break (lexicographically smaller payload wins) so every
//!    replica picks the same winner. The merged log then lands via
//!    [`DiskTier::compact_canonical`]: tmp+fsync+rename with the
//!    generation set to the content digest, so converged replicas hold
//!    **byte-identical** `plans.plog` files and a crash at any point
//!    leaves a valid log.
//!
//! Transport is a trait ([`SyncTransport`]) with two offline impls: a
//! shared-directory **mailbox** ([`MailboxTransport`]) where replicas
//! drop snapshot files for peers to pick up, and an in-process peer
//! table ([`InProcessTransport`]) for tests. Version-skewed snapshots
//! are skipped whole (counted in `sync.peer_skew`, never applied, never
//! fatal); transient transport failures retry with capped deterministic
//! backoff and then skip the peer for the round. The
//! `sync.frame_corrupt` / `sync.conn_drop` / `sync.partial_write`
//! failpoints make whole fault schedules replay byte-identically
//! (serial counter-keyed draws; sync rounds are single-threaded).

use std::collections::HashMap;
use std::fs::File;
use std::io::Write;
use std::path::{Path, PathBuf};
use std::time::Duration;

use anyhow::{bail, Context, Result};

use super::persist::{crc32, prune_quarantines, DiskTier, MAX_QUARANTINES};
use crate::obs::metrics::{metrics, names};
use crate::util::failpoints::{failpoints, SYNC_CONN_DROP, SYNC_FRAME_CORRUPT, SYNC_PARTIAL_WRITE};
use crate::util::hash::Fnv64;

/// Snapshot file magic (the mailbox transport's on-disk format).
pub const SYNC_MAGIC: [u8; 4] = *b"PSYN";
/// Sync protocol / snapshot format version this build speaks. Peers on
/// any other version are skipped whole (counted, never applied).
pub const SYNC_VERSION: u16 = 1;
/// Fingerprint ranges in the digest tree: bucket = `fp >> 56`.
pub const BUCKETS: usize = 256;
/// Fixed snapshot header size: magic + version + reserved + root + count.
const SNAP_HEADER_LEN: usize = 24;
/// Bytes per snapshot index row: fingerprint, crc, len, payload offset.
const INDEX_ROW_LEN: usize = 24;
/// Transport attempts per operation before the peer is skipped.
const MAX_ATTEMPTS: u32 = 3;
/// Deterministic backoff: `BASE << attempt` ms, capped.
const BACKOFF_BASE_MS: u64 = 1;
const BACKOFF_CAP_MS: u64 = 4;

/// Two-level Merkle digest of a live record set.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct DigestTree {
    pub root: u64,
    pub buckets: Vec<u64>,
    pub count: u64,
}

/// Digest a fingerprint-sorted `(fingerprint, crc)` listing. Empty
/// buckets digest to 0 so they compare (and skip) cheaply.
pub fn digest_tree(live: &[(u64, u32)]) -> DigestTree {
    let mut buckets = vec![0u64; BUCKETS];
    let mut i = 0;
    while i < live.len() {
        let b = (live[i].0 >> 56) as usize;
        let mut h = Fnv64::new();
        h.str("automap-sync-bucket-v1");
        let mut j = i;
        while j < live.len() && (live[j].0 >> 56) as usize == b {
            h.u64(live[j].0).u64(live[j].1 as u64);
            j += 1;
        }
        buckets[b] = h.finish();
        i = j;
    }
    let mut r = Fnv64::new();
    r.str("automap-sync-root-v1");
    r.u64(live.len() as u64);
    for &d in &buckets {
        r.u64(d);
    }
    DigestTree { root: r.finish(), buckets, count: live.len() as u64 }
}

/// What a peer advertises before any records move: protocol version and
/// its digest tree.
#[derive(Debug, Clone)]
pub struct PeerSummary {
    pub version: u16,
    pub root: u64,
    pub buckets: Vec<u64>,
    pub count: u64,
}

/// How a replica reaches its peers. Implementations must be safe to
/// retry: every method is idempotent from the protocol's view.
pub trait SyncTransport {
    /// Replica names visible to this transport (may include the caller).
    fn peers(&self) -> Result<Vec<String>>;
    /// A peer's digest-tree summary.
    fn summary(&self, peer: &str) -> Result<PeerSummary>;
    /// A peer's `(fingerprint, crc)` listing for one bucket.
    fn bucket(&self, peer: &str, bucket: usize) -> Result<Vec<(u64, u32)>>;
    /// Length+CRC-framed record batch for the requested fingerprints
    /// (PR 8 log framing; unknown fingerprints are silently absent).
    fn records(&self, peer: &str, fps: &[u64]) -> Result<Vec<u8>>;
    /// Publish this replica's snapshot for peers to pull. Atomic: a
    /// failed publish must leave the previous snapshot serving.
    fn publish(&self, replica: &str, snapshot: &[u8]) -> Result<()>;
}

/// Frame records with the log framing: `[len u32 | crc u32 | payload]`.
pub fn frame_records(records: &[(u64, Vec<u8>)]) -> Vec<u8> {
    let mut out = Vec::with_capacity(records.iter().map(|(_, p)| 8 + p.len()).sum());
    for (_, payload) in records {
        out.extend_from_slice(&(payload.len() as u32).to_le_bytes());
        out.extend_from_slice(&crc32(payload).to_le_bytes());
        out.extend_from_slice(payload);
    }
    out
}

/// Encode a snapshot: header, bucket digest table, record index, then
/// the framed records in fingerprint order.
///
/// ```text
/// 0    4     magic b"PSYN"
/// 4    2     protocol version (u16)
/// 6    2     reserved, zero
/// 8    8     root digest (u64)
/// 16   8     record count (u64)
/// 24   2048  bucket digests (256 × u64)
/// 2072 24×n  index rows: fp u64, crc u32, len u32, payload offset u64
/// ...        frames: [len u32 | crc u32 | payload] × n
/// ```
pub fn encode_snapshot(records: &[(u64, Vec<u8>)]) -> Vec<u8> {
    let live: Vec<(u64, u32)> = records.iter().map(|(fp, p)| (*fp, crc32(p))).collect();
    let tree = digest_tree(&live);
    let mut out = Vec::new();
    out.extend_from_slice(&SYNC_MAGIC);
    out.extend_from_slice(&SYNC_VERSION.to_le_bytes());
    out.extend_from_slice(&[0u8; 2]);
    out.extend_from_slice(&tree.root.to_le_bytes());
    out.extend_from_slice(&(records.len() as u64).to_le_bytes());
    for &d in &tree.buckets {
        out.extend_from_slice(&d.to_le_bytes());
    }
    let frames_base = SNAP_HEADER_LEN + BUCKETS * 8 + records.len() * INDEX_ROW_LEN;
    let mut offset = frames_base as u64;
    for ((fp, payload), (_, crc)) in records.iter().zip(&live) {
        out.extend_from_slice(&fp.to_le_bytes());
        out.extend_from_slice(&crc.to_le_bytes());
        out.extend_from_slice(&(payload.len() as u32).to_le_bytes());
        // Offset of the payload (past its 8-byte frame header).
        out.extend_from_slice(&(offset + 8).to_le_bytes());
        offset += 8 + payload.len() as u64;
    }
    out.extend_from_slice(&frame_records(records));
    out
}

/// Parsed snapshot header + index (frame bytes stay in `bytes`).
pub struct Snapshot {
    pub version: u16,
    pub root: u64,
    pub buckets: Vec<u64>,
    /// `(fingerprint, crc, len, payload offset)` per record, fp order.
    pub index: Vec<(u64, u32, u32, u64)>,
}

fn le_u16(b: &[u8], at: usize) -> u16 {
    u16::from_le_bytes([b[at], b[at + 1]])
}

fn le_u32(b: &[u8], at: usize) -> u32 {
    u32::from_le_bytes([b[at], b[at + 1], b[at + 2], b[at + 3]])
}

fn le_u64(b: &[u8], at: usize) -> u64 {
    let mut x = [0u8; 8];
    x.copy_from_slice(&b[at..at + 8]);
    u64::from_le_bytes(x)
}

/// Parse a snapshot's header and index, bounds-checked. A version-skewed
/// snapshot parses to just its version (empty tree) so the caller can
/// count the skew; anything malformed is an error (the caller treats the
/// peer as unreachable this round).
pub fn parse_snapshot(bytes: &[u8]) -> Result<Snapshot> {
    if bytes.len() < SNAP_HEADER_LEN || bytes[..4] != SYNC_MAGIC {
        bail!("not a sync snapshot (bad magic or truncated header)");
    }
    let version = le_u16(bytes, 4);
    if version != SYNC_VERSION {
        return Ok(Snapshot { version, root: 0, buckets: Vec::new(), index: Vec::new() });
    }
    let root = le_u64(bytes, 8);
    let count = le_u64(bytes, 16) as usize;
    let buckets_end = SNAP_HEADER_LEN + BUCKETS * 8;
    let index_end = count
        .checked_mul(INDEX_ROW_LEN)
        .and_then(|n| n.checked_add(buckets_end))
        .unwrap_or(usize::MAX);
    if bytes.len() < index_end {
        bail!("sync snapshot truncated (declares {count} records)");
    }
    let buckets: Vec<u64> = (0..BUCKETS).map(|i| le_u64(bytes, SNAP_HEADER_LEN + i * 8)).collect();
    let mut index = Vec::with_capacity(count);
    for i in 0..count {
        let at = buckets_end + i * INDEX_ROW_LEN;
        index.push((le_u64(bytes, at), le_u32(bytes, at + 8), le_u32(bytes, at + 12), le_u64(bytes, at + 16)));
    }
    Ok(Snapshot { version, root, buckets, index })
}

/// Shared-directory "mailbox" transport: each replica publishes one
/// `<name>.psyn` snapshot into the sync dir and pulls from every other
/// snapshot there. Publishes are atomic (tmp + rename), so readers only
/// ever see complete snapshots — a torn publish leaves the previous one
/// serving.
pub struct MailboxTransport {
    dir: PathBuf,
}

impl MailboxTransport {
    pub fn new(dir: &Path) -> Result<MailboxTransport> {
        std::fs::create_dir_all(dir)
            .with_context(|| format!("creating sync dir {}", dir.display()))?;
        Ok(MailboxTransport { dir: dir.to_path_buf() })
    }

    pub fn dir(&self) -> &Path {
        &self.dir
    }

    fn snapshot_path(&self, replica: &str) -> PathBuf {
        self.dir.join(format!("{replica}.psyn"))
    }

    /// Read a peer's snapshot, with the connection-drop failpoint in
    /// front (a dropped pull is an error the engine retries).
    fn load(&self, peer: &str) -> Result<Vec<u8>> {
        if failpoints().should_fail(SYNC_CONN_DROP) {
            bail!("injected failpoint: {SYNC_CONN_DROP} (pulling from {peer})");
        }
        std::fs::read(self.snapshot_path(peer))
            .with_context(|| format!("reading snapshot for peer {peer}"))
    }
}

impl SyncTransport for MailboxTransport {
    fn peers(&self) -> Result<Vec<String>> {
        let mut out = Vec::new();
        for entry in std::fs::read_dir(&self.dir).context("listing sync dir")?.flatten() {
            let name = entry.file_name().to_string_lossy().into_owned();
            if let Some(stem) = name.strip_suffix(".psyn") {
                out.push(stem.to_string());
            }
        }
        out.sort();
        Ok(out)
    }

    fn summary(&self, peer: &str) -> Result<PeerSummary> {
        let bytes = self.load(peer)?;
        let snap = parse_snapshot(&bytes)?;
        let count = snap.index.len() as u64;
        Ok(PeerSummary { version: snap.version, root: snap.root, buckets: snap.buckets, count })
    }

    fn bucket(&self, peer: &str, bucket: usize) -> Result<Vec<(u64, u32)>> {
        let bytes = self.load(peer)?;
        let snap = parse_snapshot(&bytes)?;
        Ok(snap
            .index
            .iter()
            .filter(|(fp, _, _, _)| (*fp >> 56) as usize == bucket)
            .map(|(fp, crc, _, _)| (*fp, *crc))
            .collect())
    }

    fn records(&self, peer: &str, fps: &[u64]) -> Result<Vec<u8>> {
        let bytes = self.load(peer)?;
        let snap = parse_snapshot(&bytes)?;
        let by_fp: HashMap<u64, (u64, u32)> =
            snap.index.iter().map(|(fp, _, len, off)| (*fp, (*off, *len))).collect();
        let mut out = Vec::new();
        for fp in fps {
            let Some(&(off, len)) = by_fp.get(fp) else { continue };
            // The frame starts 8 bytes before its payload.
            let start = (off as usize).saturating_sub(8);
            let end = off as usize + len as usize;
            if start + 8 != off as usize || end > bytes.len() {
                bail!("snapshot for {peer} has an out-of-range frame for {fp:016x}");
            }
            out.extend_from_slice(&bytes[start..end]);
        }
        Ok(out)
    }

    fn publish(&self, replica: &str, snapshot: &[u8]) -> Result<()> {
        let tmp = self.dir.join(format!("{replica}.psyn.tmp"));
        // Injected torn publish: write a prefix of the snapshot and fail
        // BEFORE the rename — the previous snapshot keeps serving, and
        // the stale tmp is truncated by the next attempt's create.
        if failpoints().should_fail(SYNC_PARTIAL_WRITE) {
            let mut f = File::create(&tmp)
                .with_context(|| format!("creating {}", tmp.display()))?;
            let _ = f.write_all(&snapshot[..snapshot.len() / 2]);
            bail!("injected failpoint: {SYNC_PARTIAL_WRITE} (publishing {replica})");
        }
        let mut f =
            File::create(&tmp).with_context(|| format!("creating {}", tmp.display()))?;
        f.write_all(snapshot).context("writing sync snapshot")?;
        f.sync_all().context("fsyncing sync snapshot")?;
        drop(f);
        std::fs::rename(&tmp, self.snapshot_path(replica))
            .context("installing sync snapshot")?;
        Ok(())
    }
}

/// In-process transport for tests: peers are live [`DiskTier`]s in the
/// same process; reads come straight off their indexes. Subject to the
/// same connection-drop / partial-write failpoints as the mailbox so
/// chaos schedules exercise identical protocol paths.
#[derive(Default)]
pub struct InProcessTransport {
    tiers: std::collections::BTreeMap<String, std::sync::Arc<DiskTier>>,
}

impl InProcessTransport {
    pub fn new() -> InProcessTransport {
        InProcessTransport::default()
    }

    pub fn register(&mut self, name: &str, tier: std::sync::Arc<DiskTier>) {
        self.tiers.insert(name.to_string(), tier);
    }

    fn tier(&self, peer: &str) -> Result<&DiskTier> {
        if failpoints().should_fail(SYNC_CONN_DROP) {
            bail!("injected failpoint: {SYNC_CONN_DROP} (pulling from {peer})");
        }
        self.tiers
            .get(peer)
            .map(|t| t.as_ref())
            .ok_or_else(|| anyhow::anyhow!("unknown peer {peer}"))
    }
}

impl SyncTransport for InProcessTransport {
    fn peers(&self) -> Result<Vec<String>> {
        Ok(self.tiers.keys().cloned().collect())
    }

    fn summary(&self, peer: &str) -> Result<PeerSummary> {
        let tree = digest_tree(&self.tier(peer)?.live_index());
        Ok(PeerSummary {
            version: SYNC_VERSION,
            root: tree.root,
            buckets: tree.buckets,
            count: tree.count,
        })
    }

    fn bucket(&self, peer: &str, bucket: usize) -> Result<Vec<(u64, u32)>> {
        Ok(self
            .tier(peer)?
            .live_index()
            .into_iter()
            .filter(|(fp, _)| (*fp >> 56) as usize == bucket)
            .collect())
    }

    fn records(&self, peer: &str, fps: &[u64]) -> Result<Vec<u8>> {
        Ok(frame_records(&self.tier(peer)?.export_records(fps)))
    }

    fn publish(&self, replica: &str, _snapshot: &[u8]) -> Result<()> {
        // Peers read the live tier, so there is nothing to install — but
        // the torn-publish failpoint still fires here so in-process
        // chaos schedules cover the retry path.
        if failpoints().should_fail(SYNC_PARTIAL_WRITE) {
            bail!("injected failpoint: {SYNC_PARTIAL_WRITE} (publishing {replica})");
        }
        Ok(())
    }
}

/// What one anti-entropy round did.
#[derive(Debug, Default, Clone, Copy, PartialEq, Eq)]
pub struct SyncReport {
    /// Peers this round attempted to pull from (self excluded).
    pub peers: u64,
    /// Peers skipped after exhausted retries or version skew.
    pub peers_skipped: u64,
    /// Version-skewed peers (a subset of `peers_skipped`).
    pub peer_skew: u64,
    /// Remote records applied to the local log.
    pub records_pulled: u64,
    /// Same-fingerprint CRC conflicts resolved by the tie-break.
    pub conflicts: u64,
    /// Frames that failed CRC/UTF-8 verification and were quarantined.
    pub frames_quarantined: u64,
    /// Transport attempts that failed and were retried.
    pub retries: u64,
    /// Whether this round changed the local log.
    pub changed: bool,
}

/// Retry `op` up to [`MAX_ATTEMPTS`] times with capped deterministic
/// backoff, counting retries in the report. `None` means every attempt
/// failed and the caller should skip this peer for the round.
fn with_retries<T>(mut op: impl FnMut() -> Result<T>, report: &mut SyncReport) -> Option<T> {
    for attempt in 0..MAX_ATTEMPTS {
        match op() {
            Ok(v) => return Some(v),
            Err(_) => {
                if attempt + 1 < MAX_ATTEMPTS {
                    report.retries += 1;
                    let ms = (BACKOFF_BASE_MS << attempt).min(BACKOFF_CAP_MS);
                    std::thread::sleep(Duration::from_millis(ms));
                }
            }
        }
    }
    None
}

/// Quarantine one received frame (or trailing garbage) next to the plan
/// log, pruning old sync-frame quarantines to the shared cap.
fn quarantine_frame(dir: &Path, frame: &[u8], report: &mut SyncReport) {
    report.frames_quarantined += 1;
    let mut h = Fnv64::new();
    h.bytes(frame);
    let tag = h.finish();
    let mut path = dir.join(format!("sync-frame.corrupt-{tag:016x}"));
    let mut i = 1u32;
    while path.exists() {
        path = dir.join(format!("sync-frame.corrupt-{tag:016x}.{i}"));
        i += 1;
    }
    let _ = std::fs::write(&path, frame);
    let pruned = prune_quarantines(dir, "sync-frame", MAX_QUARANTINES);
    if pruned > 0 {
        metrics().counter(names::PERSIST_QUARANTINE_PRUNED).add(pruned);
    }
}

/// Best-effort snapshot publish with retries; a replica whose publish
/// keeps failing still pulls normally (peers just see its last
/// successful snapshot).
fn publish_snapshot(
    replica: &str,
    tier: &DiskTier,
    transport: &dyn SyncTransport,
    report: &mut SyncReport,
) {
    let fps: Vec<u64> = tier.live_index().into_iter().map(|(fp, _)| fp).collect();
    let snapshot = encode_snapshot(&tier.export_records(&fps));
    let _ = with_retries(|| transport.publish(replica, &snapshot), report);
}

/// Run one anti-entropy round for `replica` against every peer the
/// transport can see. On return the local log is in canonical form
/// (fingerprint-ordered, content-digest generation), so replicas that
/// hold the same plans hold byte-identical `plans.plog` files.
pub fn sync_once(
    replica: &str,
    tier: &DiskTier,
    transport: &dyn SyncTransport,
) -> Result<SyncReport> {
    let mut report = SyncReport::default();
    tier.compact_canonical().context("canonicalizing local log before sync")?;
    publish_snapshot(replica, tier, transport, &mut report);

    let mut peers = transport.peers().context("listing sync peers")?;
    peers.sort();
    peers.dedup();
    let quarantine_dir = tier
        .log_path()
        .parent()
        .map(Path::to_path_buf)
        .unwrap_or_else(|| PathBuf::from("."));

    let mut applied_any = false;
    for peer in peers.iter().filter(|p| p.as_str() != replica) {
        report.peers += 1;
        let local_live = tier.live_index();
        let local = digest_tree(&local_live);
        let Some(summary) = with_retries(|| transport.summary(peer), &mut report) else {
            report.peers_skipped += 1;
            continue;
        };
        if summary.version != SYNC_VERSION {
            // Version-skew policy: never apply, never fail. The peer's
            // snapshot stays untouched for a build that can read it.
            report.peer_skew += 1;
            report.peers_skipped += 1;
            continue;
        }
        if summary.root == local.root {
            continue;
        }
        let local_idx: HashMap<u64, u32> = local_live.into_iter().collect();
        let mut wanted: Vec<u64> = Vec::new();
        let mut reachable = true;
        for (b, (mine, theirs)) in local.buckets.iter().zip(&summary.buckets).enumerate() {
            if mine == theirs {
                continue;
            }
            match with_retries(|| transport.bucket(peer, b), &mut report) {
                Some(listing) => {
                    for (fp, crc) in listing {
                        match local_idx.get(&fp) {
                            None => wanted.push(fp),
                            Some(&lc) if lc != crc => wanted.push(fp),
                            _ => {}
                        }
                    }
                }
                None => {
                    reachable = false;
                    break;
                }
            }
        }
        if !reachable {
            report.peers_skipped += 1;
            continue;
        }
        if wanted.is_empty() {
            continue;
        }
        wanted.sort_unstable();
        wanted.dedup();
        let Some(batch) = with_retries(|| transport.records(peer, &wanted), &mut report) else {
            report.peers_skipped += 1;
            continue;
        };

        // Walk the frames, verifying each before it can touch the log.
        let mut pos = 0usize;
        while pos < batch.len() {
            if batch.len() - pos < 8 {
                quarantine_frame(&quarantine_dir, &batch[pos..], &mut report);
                break;
            }
            let len = le_u32(&batch, pos) as usize;
            let crc = le_u32(&batch, pos + 4);
            let start = pos + 8;
            if len < 8 || batch.len() - start < len {
                quarantine_frame(&quarantine_dir, &batch[pos..], &mut report);
                break;
            }
            let mut payload = batch[start..start + len].to_vec();
            let frame_end = start + len;
            // Injected wire corruption: flip a payload byte so the CRC
            // check below must catch (and quarantine) the frame.
            if failpoints().should_fail(SYNC_FRAME_CORRUPT) {
                let last = payload.len() - 1;
                payload[last] ^= 0x40;
            }
            let plan = match std::str::from_utf8(&payload[8..]) {
                Ok(p) if crc32(&payload) == crc => p.to_string(),
                _ => {
                    // Corrupt frame: quarantine the bytes as received
                    // (framing included), skip, keep going. NEVER applied.
                    let mut frame = Vec::with_capacity(8 + payload.len());
                    frame.extend_from_slice(&batch[pos..start]);
                    frame.extend_from_slice(&payload);
                    quarantine_frame(&quarantine_dir, &frame, &mut report);
                    pos = frame_end;
                    continue;
                }
            };
            let fp = le_u64(&payload, 0);
            pos = frame_end;
            match local_idx.get(&fp) {
                Some(&lc) if lc == crc => {} // identical record, nothing to do
                Some(_) => {
                    // Conflicting record for a fingerprint deterministic
                    // search should map to one plan: corruption or skew
                    // upstream. Symmetric tie-break so every replica
                    // converges on the same winner.
                    report.conflicts += 1;
                    let local_payload =
                        tier.export_records(&[fp]).pop().map(|(_, p)| p);
                    let remote_wins = match &local_payload {
                        Some(lp) => payload < *lp,
                        None => true,
                    };
                    if remote_wins && tier.put(fp, &plan).is_ok() {
                        report.records_pulled += 1;
                        applied_any = true;
                    }
                }
                None => {
                    // Missing record: apply through the normal append
                    // path (later-record-wins; a failed put retries on
                    // the next round — the digests still differ).
                    if tier.put(fp, &plan).is_ok() {
                        report.records_pulled += 1;
                        applied_any = true;
                    }
                }
            }
        }
    }

    if applied_any {
        tier.compact_canonical().context("canonicalizing local log after merge")?;
        publish_snapshot(replica, tier, transport, &mut report);
    }
    report.changed = applied_any;

    let m = metrics();
    m.counter(names::SYNC_ROUNDS).add(1);
    m.counter(names::SYNC_RECORDS_PULLED).add(report.records_pulled);
    m.counter(names::SYNC_CONFLICTS).add(report.conflicts);
    m.counter(names::SYNC_FRAMES_QUARANTINED).add(report.frames_quarantined);
    m.counter(names::SYNC_PEER_SKEW).add(report.peer_skew);
    m.counter(names::SYNC_RETRIES).add(report.retries);
    m.counter(names::SYNC_PEERS_SKIPPED).add(report.peers_skipped);
    Ok(report)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::{Arc, Mutex};

    // Tests that arm the process-global failpoint registry serialize on
    // this lock and disarm on exit (same idiom as tests/chaos_service.rs).
    static FP_LOCK: Mutex<()> = Mutex::new(());

    struct Disarm;

    impl Drop for Disarm {
        fn drop(&mut self) {
            failpoints().disarm_all();
        }
    }

    fn with_failpoints<T>(body: impl FnOnce() -> T) -> T {
        let _guard = FP_LOCK.lock().unwrap_or_else(|e| e.into_inner());
        failpoints().disarm_all();
        let _disarm = Disarm;
        body()
    }

    fn temp_dir(name: &str) -> PathBuf {
        let dir =
            std::env::temp_dir().join(format!("automap-sync-unit-{}-{name}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        dir
    }

    fn tier_with(dir: &Path, plans: &[(u64, &str)]) -> Arc<DiskTier> {
        let tier = DiskTier::open(dir).unwrap();
        for (fp, plan) in plans {
            tier.put(*fp, plan).unwrap();
        }
        Arc::new(tier)
    }

    fn log_bytes(tier: &DiskTier) -> Vec<u8> {
        std::fs::read(tier.log_path()).unwrap()
    }

    #[test]
    fn digest_tree_separates_buckets_and_orders() {
        let a = digest_tree(&[(1, 10), (2, 20)]);
        let b = digest_tree(&[(1, 10), (2, 20)]);
        assert_eq!(a, b, "pure function of the listing");
        let c = digest_tree(&[(1, 10), (2, 21)]);
        assert_ne!(a.root, c.root, "a CRC change must change the root");
        assert_eq!(a.buckets[1], c.buckets[1], "unrelated buckets unchanged");
        assert_ne!(a.buckets[0], c.buckets[0]);
        // A fingerprint in a different range lands in a different bucket.
        let hi = 7u64 << 56 | 3;
        let d = digest_tree(&[(1, 10), (hi, 30)]);
        assert_ne!(d.buckets[7], 0);
        assert_eq!(d.buckets[7], digest_tree(&[(hi, 30)]).buckets[7]);
    }

    #[test]
    fn snapshot_round_trips() {
        let records: Vec<(u64, Vec<u8>)> = vec![
            (5, [&5u64.to_le_bytes()[..], b"{\"p\":5}"].concat()),
            (9, [&9u64.to_le_bytes()[..], b"{\"p\":9}"].concat()),
        ];
        let bytes = encode_snapshot(&records);
        let snap = parse_snapshot(&bytes).unwrap();
        assert_eq!(snap.version, SYNC_VERSION);
        let live: Vec<(u64, u32)> = records.iter().map(|(fp, p)| (*fp, crc32(p))).collect();
        let tree = digest_tree(&live);
        assert_eq!(snap.root, tree.root);
        assert_eq!(snap.buckets, tree.buckets);
        assert_eq!(snap.index.len(), 2);
        // The index offsets point at the exact payload bytes.
        for ((fp, payload), (ifp, icrc, ilen, ioff)) in records.iter().zip(&snap.index) {
            assert_eq!(fp, ifp);
            assert_eq!(*icrc, crc32(payload));
            assert_eq!(*ilen as usize, payload.len());
            let at = *ioff as usize;
            assert_eq!(&bytes[at..at + payload.len()], &payload[..]);
        }
        assert!(parse_snapshot(b"nonsense").is_err());
        assert!(parse_snapshot(&bytes[..10]).is_err());
    }

    #[test]
    fn in_process_sync_converges_byte_identically() {
        with_failpoints(|| {
            let dir_a = temp_dir("inproc-a");
            let dir_b = temp_dir("inproc-b");
            // Disjoint + overlapping sets, plus one CRC conflict on fp 3.
            let a = tier_with(&dir_a, &[(1, "{\"p\":1}"), (2, "{\"p\":2}"), (3, "{\"x\":1}")]);
            let b = tier_with(&dir_b, &[(2, "{\"p\":2}"), (3, "{\"y\":2}"), (4, "{\"p\":4}")]);
            let mut t = InProcessTransport::new();
            t.register("a", a.clone());
            t.register("b", b.clone());
            let ra = sync_once("a", &a, &t).unwrap();
            assert!(ra.changed);
            assert_eq!(ra.conflicts, 1, "fp 3 differs across replicas");
            let rb = sync_once("b", &b, &t).unwrap();
            assert!(rb.changed);
            assert_eq!(a.live_index(), b.live_index());
            assert_eq!(log_bytes(&a), log_bytes(&b), "canonical logs must be byte-identical");
            // The symmetric tie-break picked ONE fp-3 plan on both sides.
            assert_eq!(a.get(3), b.get(3));
            // A third round is a no-op: roots match.
            let ra2 = sync_once("a", &a, &t).unwrap();
            assert!(!ra2.changed);
            assert_eq!(ra2.records_pulled, 0);
            let _ = std::fs::remove_dir_all(&dir_a);
            let _ = std::fs::remove_dir_all(&dir_b);
        });
    }

    #[test]
    fn mailbox_sync_converges_via_snapshot_files() {
        with_failpoints(|| {
            let dir_a = temp_dir("mail-a");
            let dir_b = temp_dir("mail-b");
            let sync_dir = temp_dir("mail-sync");
            let a = tier_with(&dir_a, &[(10, "{\"p\":10}"), (11, "{\"p\":11}")]);
            let b = tier_with(&dir_b, &[(12, "{\"p\":12}")]);
            let t = MailboxTransport::new(&sync_dir).unwrap();
            // A publishes; B pulls A's corpus; A pulls B's new record.
            sync_once("a", &a, &t).unwrap();
            let rb = sync_once("b", &b, &t).unwrap();
            assert_eq!(rb.records_pulled, 2);
            let ra = sync_once("a", &a, &t).unwrap();
            assert_eq!(ra.records_pulled, 1);
            assert_eq!(log_bytes(&a), log_bytes(&b));
            assert_eq!(a.get(12).as_deref(), Some("{\"p\":12}"));
            assert_eq!(b.get(10).as_deref(), Some("{\"p\":10}"));
            let _ = std::fs::remove_dir_all(&dir_a);
            let _ = std::fs::remove_dir_all(&dir_b);
            let _ = std::fs::remove_dir_all(&sync_dir);
        });
    }

    #[test]
    fn version_skewed_snapshots_are_skipped_not_applied() {
        with_failpoints(|| {
            let dir_a = temp_dir("skew-a");
            let sync_dir = temp_dir("skew-sync");
            let a = tier_with(&dir_a, &[(1, "{\"p\":1}")]);
            let t = MailboxTransport::new(&sync_dir).unwrap();
            // A "future" replica's snapshot: valid magic, version + 1.
            let mut snap = encode_snapshot(&[(
                99,
                [&99u64.to_le_bytes()[..], b"{\"future\":true}"].concat(),
            )]);
            snap[4..6].copy_from_slice(&(SYNC_VERSION + 1).to_le_bytes());
            std::fs::write(sync_dir.join("future.psyn"), &snap).unwrap();
            let r = sync_once("a", &a, &t).unwrap();
            assert_eq!(r.peer_skew, 1);
            assert_eq!(r.peers_skipped, 1);
            assert_eq!(r.records_pulled, 0, "skewed records must never apply");
            assert_eq!(a.get(99), None);
            let _ = std::fs::remove_dir_all(&dir_a);
            let _ = std::fs::remove_dir_all(&sync_dir);
        });
    }

    #[test]
    fn corrupt_frames_are_quarantined_never_applied_never_fatal() {
        with_failpoints(|| {
            let dir_a = temp_dir("corrupt-a");
            let dir_b = temp_dir("corrupt-b");
            let a = tier_with(&dir_a, &[(1, "{\"p\":1}"), (2, "{\"p\":2}")]);
            let b = tier_with(&dir_b, &[]);
            let mut t = InProcessTransport::new();
            t.register("a", a.clone());
            t.register("b", b.clone());
            // Corrupt EVERY pulled frame: nothing applies, nothing fails.
            failpoints().arm(SYNC_FRAME_CORRUPT, 1.0, 7).unwrap();
            let r = sync_once("b", &b, &t).unwrap();
            assert_eq!(r.records_pulled, 0);
            assert_eq!(r.frames_quarantined, 2);
            assert!(!r.changed);
            assert_eq!(b.live_index().len(), 0, "corrupt frames must never be applied");
            let quarantined: Vec<String> = std::fs::read_dir(&dir_b)
                .unwrap()
                .flatten()
                .map(|e| e.file_name().to_string_lossy().into_owned())
                .filter(|n| n.starts_with("sync-frame.corrupt-"))
                .collect();
            assert_eq!(quarantined.len(), 2, "{quarantined:?}");
            // Disarm: the next round pulls both records cleanly.
            failpoints().disarm_all();
            let r2 = sync_once("b", &b, &t).unwrap();
            assert_eq!(r2.records_pulled, 2);
            // Canonicalize A (it has only been a peer so far) before the
            // byte-compare; it holds nothing B doesn't.
            let ra = sync_once("a", &a, &t).unwrap();
            assert_eq!(ra.records_pulled, 0);
            assert_eq!(log_bytes(&a), log_bytes(&b));
            let _ = std::fs::remove_dir_all(&dir_a);
            let _ = std::fs::remove_dir_all(&dir_b);
        });
    }

    #[test]
    fn torn_publish_leaves_previous_snapshot_serving() {
        with_failpoints(|| {
            let dir_a = temp_dir("torn-a");
            let sync_dir = temp_dir("torn-sync");
            let a = tier_with(&dir_a, &[(1, "{\"p\":1}")]);
            let t = MailboxTransport::new(&sync_dir).unwrap();
            sync_once("a", &a, &t).unwrap();
            let before = std::fs::read(sync_dir.join("a.psyn")).unwrap();
            // Every publish attempt tears: the old snapshot must survive.
            a.put(2, "{\"p\":2}").unwrap();
            failpoints().arm(SYNC_PARTIAL_WRITE, 1.0, 3).unwrap();
            let r = sync_once("a", &a, &t).unwrap();
            assert!(r.retries > 0, "torn publishes must be retried");
            let after = std::fs::read(sync_dir.join("a.psyn")).unwrap();
            assert_eq!(before, after, "a torn publish must not clobber the snapshot");
            assert!(parse_snapshot(&after).is_ok());
            let _ = std::fs::remove_dir_all(&dir_a);
            let _ = std::fs::remove_dir_all(&sync_dir);
        });
    }

    #[test]
    fn dropped_connections_retry_then_skip_the_peer() {
        with_failpoints(|| {
            let dir_a = temp_dir("drop-a");
            let dir_b = temp_dir("drop-b");
            let a = tier_with(&dir_a, &[(1, "{\"p\":1}")]);
            let b = tier_with(&dir_b, &[]);
            let mut t = InProcessTransport::new();
            t.register("a", a.clone());
            t.register("b", b.clone());
            failpoints().arm(SYNC_CONN_DROP, 1.0, 5).unwrap();
            let r = sync_once("b", &b, &t).unwrap();
            assert_eq!(r.peers_skipped, 1, "unreachable peer is skipped, not fatal");
            assert!(r.retries > 0);
            assert_eq!(b.live_index().len(), 0);
            let _ = std::fs::remove_dir_all(&dir_a);
            let _ = std::fs::remove_dir_all(&dir_b);
        });
    }
}
