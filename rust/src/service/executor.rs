//! Root-parallel MCTS executor (DESIGN.md §9): one partition request
//! fans out to `K` worker threads, each running an independent seeded
//! search over its own session, and the best evaluation wins.
//!
//! Root parallelism (independent trees, merged at the end) was chosen
//! over tree parallelism (one shared tree) because episodes are cheap
//! and the tree is tiny — sharing it would serialise on a lock for no
//! statistical gain, whereas independent trees with distinct RNG streams
//! explore *more* of the space per wall-clock second.
//!
//! Determinism: worker `w` searches with [`worker_seed`]`(seed, w)`, the
//! merge compares costs with a strict `<` so the lowest-indexed worker
//! wins ties, and the winning plan's `wall_seconds` is zeroed (wall time
//! is reported separately on [`ExecutorReport`]). A fixed `(seed, K)`
//! therefore reproduces the same best plan — byte-identical JSON — on
//! every run.

use crate::cost::composite::CostWeights;
use crate::ir::Func;
use crate::partir::mesh::Mesh;
use crate::search::env::SearchOptions;
use crate::search::mcts::MctsConfig;
use crate::search::worker_seed;
use crate::service::fingerprint::{request_fingerprint, Fingerprint};
use crate::session::{PartitionPlan, Session, Tactic};
use crate::sim::device::Device;
use anyhow::{anyhow, Result};

/// One fully-resolved unit of work: everything a worker needs to run a
/// search, plus the executor fan-out configuration.
#[derive(Clone)]
pub struct PlanJob {
    pub func: Func,
    pub mesh: Mesh,
    pub device: Device,
    pub weights: CostWeights,
    pub options: SearchOptions,
    /// Stages run before the search on every worker (Manual / Filter).
    pub pre_tactics: Vec<Tactic>,
    pub budget: usize,
    pub seed: u64,
    /// Worker thread count `K` (clamped to >= 1).
    pub workers: usize,
    pub mcts: MctsConfig,
}

/// Result of one root-parallel execution.
pub struct ExecutorReport {
    /// The winning plan (its `wall_seconds` is zeroed for determinism;
    /// see `wall_seconds` here for the measured time).
    pub plan: PartitionPlan,
    /// Index of the worker whose plan won.
    pub winner: usize,
    /// Final cost per worker, in worker order.
    pub worker_costs: Vec<f64>,
    /// Total episodes run across all workers (`K * budget`).
    pub episodes_total: usize,
    /// Measured wall time of the whole fan-out.
    pub wall_seconds: f64,
}

impl PlanJob {
    /// The cache key covering everything that can change the plan.
    pub fn fingerprint(&self) -> Fingerprint {
        request_fingerprint(
            &self.func,
            &self.mesh,
            &self.device,
            &self.weights,
            &self.options,
            &self.pre_tactics,
            self.budget,
            self.seed,
            self.workers,
            &self.mcts,
        )
    }

    /// The tactic pipeline worker `w` runs.
    fn worker_tactics(&self, w: usize) -> Vec<Tactic> {
        let mut tactics = self.pre_tactics.clone();
        tactics.push(Tactic::Search {
            budget: self.budget,
            seed: worker_seed(self.seed, w),
            mcts: self.mcts.clone(),
        });
        tactics.push(Tactic::InferRest);
        tactics.push(Tactic::Lower);
        tactics
    }

    /// Run the job: `K` scoped worker threads, each with a fresh session
    /// (own program, propagator, and RNG stream), merged by best cost.
    pub fn run(&self) -> Result<ExecutorReport> {
        let t0 = std::time::Instant::now();
        let k = self.workers.max(1);
        let mut slots: Vec<Option<Result<PartitionPlan>>> = Vec::new();
        slots.resize_with(k, || None);
        std::thread::scope(|scope| {
            let handles: Vec<_> = (0..k)
                .map(|w| {
                    let job = &*self;
                    scope.spawn(move || {
                        let tactics = job.worker_tactics(w);
                        Session::plan_for(
                            job.func.clone(),
                            job.mesh.clone(),
                            job.device.clone(),
                            job.weights.clone(),
                            job.options.clone(),
                            &tactics,
                        )
                    })
                })
                .collect();
            for (w, h) in handles.into_iter().enumerate() {
                slots[w] = Some(
                    h.join().unwrap_or_else(|_| Err(anyhow!("search worker {w} panicked"))),
                );
            }
        });

        let mut worker_costs = Vec::with_capacity(k);
        let mut best: Option<(usize, PartitionPlan)> = None;
        for (w, slot) in slots.into_iter().enumerate() {
            let plan = slot.expect("worker slot filled")?;
            worker_costs.push(plan.eval.cost);
            let better = match &best {
                None => true,
                // Strict `<`: ties go to the lowest worker index, which
                // keeps the merge deterministic.
                Some((_, b)) => plan.eval.cost < b.eval.cost,
            };
            if better {
                best = Some((w, plan));
            }
        }
        let (winner, mut plan) = best.expect("k >= 1 workers");
        plan.wall_seconds = 0.0;
        Ok(ExecutorReport {
            plan,
            winner,
            worker_costs,
            episodes_total: k * self.budget,
            wall_seconds: t0.elapsed().as_secs_f64(),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::mlp::{build_mlp, MlpConfig};
    use crate::session::ShardingConstraint;

    fn job(workers: usize, seed: u64) -> PlanJob {
        PlanJob {
            func: build_mlp(&MlpConfig::small()).func,
            mesh: Mesh::new(&[("batch", 2), ("model", 4)]),
            device: Device::tpu_v3(),
            weights: CostWeights::default(),
            options: SearchOptions::default(),
            pre_tactics: vec![Tactic::Manual {
                constraints: vec![ShardingConstraint::new("x", 0, "batch")],
                manual_axes: vec!["batch".to_string()],
            }],
            budget: 60,
            seed,
            workers,
            mcts: MctsConfig::default(),
        }
    }

    #[test]
    fn fixed_seed_and_k_reproduce_the_same_plan() {
        let j = job(4, 7);
        let a = j.run().unwrap();
        let b = j.run().unwrap();
        assert_eq!(a.winner, b.winner);
        assert_eq!(a.worker_costs, b.worker_costs);
        assert_eq!(
            a.plan.to_json().to_string(),
            b.plan.to_json().to_string(),
            "root-parallel executor must be deterministic for fixed (seed, K)"
        );
        assert_eq!(a.episodes_total, 4 * 60);
    }

    #[test]
    fn winner_has_the_minimum_cost() {
        let r = job(4, 3).run().unwrap();
        let min = r.worker_costs.iter().cloned().fold(f64::INFINITY, f64::min);
        assert_eq!(r.worker_costs[r.winner], min);
        assert_eq!(r.plan.eval.cost, min);
        assert_eq!(r.plan.wall_seconds, 0.0, "plan wall time is zeroed for determinism");
        assert!(r.wall_seconds > 0.0);
    }

    #[test]
    fn manual_constraints_survive_every_worker() {
        let r = job(3, 5).run().unwrap();
        let x = r.plan.input_specs.iter().find(|s| s.name == "x").unwrap();
        assert!(x.tiled_on("batch"), "pre-tactic pin must survive the fan-out");
    }

    #[test]
    fn different_seeds_change_the_fingerprint_not_determinism() {
        let a = job(2, 1);
        let b = job(2, 2);
        assert_ne!(a.fingerprint(), b.fingerprint());
        assert_eq!(a.fingerprint(), job(2, 1).fingerprint());
    }
}
