//! Root-parallel MCTS executor with deterministic work stealing
//! (DESIGN.md §9): one partition request fans out to `K` worker trees
//! over ONE shared environment, episodes run in fixed rounds with a
//! barrier between them, and trees whose root visit-count entropy (the
//! tree's "temperature") stops moving forfeit their remaining budget to
//! the best tree.
//!
//! Root parallelism (independent trees, merged at the end) was chosen
//! over tree parallelism (one shared tree) because episodes are cheap
//! and the tree is tiny — sharing it would serialise on a lock for no
//! statistical gain, whereas independent trees with distinct RNG streams
//! explore *more* of the space per wall-clock second. Workers share one
//! immutable program/propagator/env by reference (scoped threads)
//! instead of the K full `Func`/`Mesh`/`Propagator` clones the previous
//! design paid per request.
//!
//! Determinism: the round schedule is a pure function of
//! `(seed, K, budget)` — round size derives from `budget`, worker `w`
//! searches with [`worker_seed`]`(seed, w)`, rounds are fork-join
//! barriers (no cross-thread mutable state), and the steal decisions
//! after each barrier depend only on the deterministic per-tree best
//! rewards. The merge compares costs with a strict `<` so the
//! lowest-indexed worker wins ties, and the winning plan's
//! `wall_seconds` is zeroed (wall time is reported separately on
//! [`ExecutorReport`]). A fixed `(seed, K)` therefore reproduces the
//! same best plan — byte-identical JSON — on every run, regardless of
//! how the OS interleaves the worker threads.

use crate::cost::composite::{evaluate_pipelined, stage_timeline, CostWeights};
use crate::ir::Func;
use crate::obs::recorder::recorder;
use crate::obs::telemetry::RoundSample;
use crate::partir::mesh::Mesh;
use crate::pipeline::{simulate_1f1b_slices, PipelineSpec};
use crate::search::env::{RewriteEnv, SearchOptions};
use crate::search::mcts::{Mcts, MctsConfig, SearchResult};
use crate::search::worker_seed;
use crate::service::fingerprint::{request_fingerprint, Fingerprint};
use crate::session::{PartitionPlan, Session, Tactic};
use crate::sim::device::Device;
use crate::util::failpoints::{failpoints, SEARCH_SLOW_ROUND, WORKER_PANIC};
use anyhow::Result;

/// Target number of barrier rounds a full-budget tree runs (the round
/// size is `budget / STEAL_ROUNDS`, rounded up).
pub const STEAL_ROUNDS: usize = 8;

/// How long the [`SEARCH_SLOW_ROUND`] failpoint stalls a worker's round
/// when it fires — long enough to trip millisecond deadlines in tests,
/// short enough to keep chaos runs fast.
pub const SLOW_ROUND_SLEEP_MS: u64 = 25;

/// Consecutive flat-temperature rounds after which a non-leading tree
/// forfeits its remaining budget to the leader.
pub const STALL_ROUNDS: usize = 2;

/// Minimum movement of a tree's root visit-count entropy (its
/// "temperature", [`Mcts::root_visit_entropy`]) between consecutive
/// barriers for the tree to count as still searching. A healthy tree
/// keeps re-shaping its root distribution — cooling as visits
/// concentrate on the emerging winner, or warming as expansion uncovers
/// new arms. A tree whose temperature moved less than this AND whose
/// best reward did not improve is either converged (concentrated and
/// stable) or flat (uniform and stable, no signal to chase); in both
/// cases its marginal episodes teach nothing and the budget is better
/// spent by the leader. (The reward guard matters when the root has
/// fewer than two arms — entropy is constant 0.0 there — and late in
/// long budgets where per-round entropy movement decays as O(1/visits):
/// a tree still strictly improving must never forfeit.)
pub const STALL_ENTROPY_EPS: f64 = 1e-3;

/// One fully-resolved unit of work: everything a worker needs to run a
/// search, plus the executor fan-out configuration.
#[derive(Clone)]
pub struct PlanJob {
    pub func: Func,
    pub mesh: Mesh,
    pub device: Device,
    pub weights: CostWeights,
    pub options: SearchOptions,
    /// Stages run before the search on every worker (Manual / Filter).
    pub pre_tactics: Vec<Tactic>,
    pub budget: usize,
    pub seed: u64,
    /// Worker thread count `K` (clamped to >= 1).
    pub workers: usize,
    pub mcts: MctsConfig,
    /// Soft wall-clock deadline for the whole fan-out, in milliseconds
    /// (0 = none). Enforced at round barriers: a search past its
    /// deadline stops and returns the best-so-far anytime plan instead
    /// of blocking. NOT part of the fingerprint — the deadline shapes
    /// how long we search, never which plan a completed search yields.
    pub deadline_ms: u64,
}

/// Result of one root-parallel execution.
pub struct ExecutorReport {
    /// The winning plan (its `wall_seconds` is zeroed for determinism;
    /// see `wall_seconds` here for the measured time).
    pub plan: PartitionPlan,
    /// Index of the worker whose plan won.
    pub winner: usize,
    /// Final PLAN cost per worker (its best state replayed through
    /// infer-rest + lowering), in worker order — the quantity the merge
    /// ranks on, so `plan.eval.cost == worker_costs[winner]` always.
    pub worker_costs: Vec<f64>,
    /// Episodes actually run per worker — work stealing moves budget
    /// between trees, so these differ when forfeiture fired; they always
    /// sum to `episodes_total`.
    pub worker_episodes: Vec<usize>,
    /// Total episodes actually run across all workers. Equals
    /// `K * budget` when no deadline hit and no worker panicked (budget
    /// is conserved by stealing); smaller when the search was cut short.
    pub episodes_total: usize,
    /// Barrier rounds executed.
    pub rounds: usize,
    /// Budget-forfeiture events (stalled tree → leader).
    pub steals: usize,
    /// Measured wall time of the whole fan-out.
    pub wall_seconds: f64,
    /// Terminal-state evaluations requested across all workers.
    pub eval_lookups: usize,
    /// Evaluations served by the per-tree memos (first-level cache).
    pub eval_memo_hits: usize,
    /// Memo misses answered by the incremental cost ledgers.
    pub ledger_refreshes: usize,
    /// Node cost terms served from the ledgers (work the full pipeline
    /// would have redone).
    pub ledger_nodes_reused: usize,
    /// Node cost terms the ledgers recomputed (the dirty frontier).
    pub ledger_nodes_recomputed: usize,
    /// One telemetry sample per barrier round (reward curve, entropy
    /// timeline, cumulative steals, ledger reuse rate) — collected
    /// unconditionally: it reads a handful of counters from
    /// deterministic search state at most [`STEAL_ROUNDS`] times.
    pub timeline: Vec<RoundSample>,
    /// Worker trees poisoned by a panic (caught, excluded from the
    /// merge; their budget was forfeited to the survivors).
    pub worker_panics: usize,
    /// The round loop stopped at a barrier because the deadline passed;
    /// `plan` is the best-so-far anytime plan (or the fallback).
    pub deadline_hit: bool,
    /// No worker completed a single episode (deadline before round 1,
    /// or every tree poisoned): `plan` is the guaranteed fallback —
    /// pre-tactics + InferRest + Lower, no search decisions.
    pub fallback: bool,
}

impl PlanJob {
    /// The cache key covering everything that can change the plan.
    pub fn fingerprint(&self) -> Fingerprint {
        request_fingerprint(
            &self.func,
            &self.mesh,
            &self.device,
            &self.weights,
            &self.options,
            &self.pre_tactics,
            self.budget,
            self.seed,
            self.workers,
            &self.mcts,
        )
    }

    /// The guaranteed zero-search plan: pre-tactics + InferRest + Lower
    /// on a fresh session. Served when a search cannot run at all — a
    /// deadline that expired before the first round, every worker tree
    /// poisoned by panics, or a shed request with no cached plan
    /// (DESIGN.md §14). Always succeeds when the pre-tactics do.
    pub fn fallback_plan(&self) -> Result<PartitionPlan> {
        let mut session = Session::with_options(
            self.func.clone(),
            self.mesh.clone(),
            self.device.clone(),
            self.weights.clone(),
            self.options.clone(),
        );
        for t in &self.pre_tactics {
            session.apply(t)?;
        }
        let mut plan = session.run(&[Tactic::InferRest, Tactic::Lower])?;
        plan.wall_seconds = 0.0;
        Ok(plan)
    }

    /// Run the job: pre-tactics replayed once on a session whose program
    /// all `K` workers share immutably, then round-based root-parallel
    /// search with stall forfeiture, then ONE plan assembly from the
    /// winning tree.
    pub fn run(&self) -> Result<ExecutorReport> {
        let t0 = std::time::Instant::now();
        let k = self.workers.max(1);
        let budget = self.budget.max(1);
        let round_size = budget.div_ceil(STEAL_ROUNDS);
        let deadline =
            (self.deadline_ms > 0).then(|| t0 + std::time::Duration::from_millis(self.deadline_ms));
        // Span correlation id: the job fingerprint, so every worker's
        // round spans group under the request that spawned them. Only
        // computed when tracing is on (the fingerprint hash walks the
        // program).
        let req = if recorder().enabled() { self.fingerprint().0 } else { 0 };

        let mut session = Session::with_options(
            self.func.clone(),
            self.mesh.clone(),
            self.device.clone(),
            self.weights.clone(),
            self.options.clone(),
        );
        for t in &self.pre_tactics {
            session.apply(t)?;
        }
        let worklist = session.resolved_worklist();
        let seed_state = session.state().clone();
        // A `Pipeline` pre-tactic leaves its spec on the session; every
        // worker tree then searches stage-cut moves alongside tile moves.
        let pipe_spec = session.pipeline_spec().cloned();

        let mut rounds = 0usize;
        let mut steals = 0usize;
        let mut worker_panics = 0usize;
        let mut deadline_hit = false;
        let mut timeline: Vec<RoundSample> = Vec::with_capacity(STEAL_ROUNDS);
        let (results, worker_episodes, targets) = {
            let mut env = RewriteEnv::with_seed(
                &session.program,
                self.device.clone(),
                self.weights.clone(),
                self.options.clone(),
                &worklist,
                seed_state,
            );
            if let Some(spec) = &pipe_spec {
                env.set_pipeline(spec.clone());
            }
            let env = env;
            let mut searchers: Vec<Mcts> = (0..k)
                .map(|w| Mcts::new(&env, self.mcts.clone(), worker_seed(self.seed, w)))
                .collect();
            let mut remaining = vec![budget; k];
            let mut best_so_far = vec![f64::NEG_INFINITY; k];
            // Tree-temperature stall detector: per-tree root visit
            // entropy at the previous barrier (NaN = no reading yet) and
            // the count of consecutive barriers it failed to move by
            // STALL_ENTROPY_EPS. Entropy is a pure function of the
            // tree's deterministic visit counts, so the stall schedule
            // stays a pure function of (seed, K, budget).
            let mut prev_entropy = vec![f64::NAN; k];
            let mut stall = vec![0usize; k];
            // Trees poisoned by a caught panic: excluded from quotas,
            // leadership, and the final merge; their remaining budget is
            // forfeited to the leader through the steal protocol.
            let mut poisoned = vec![false; k];
            loop {
                let quotas: Vec<usize> = remaining.iter().map(|&r| r.min(round_size)).collect();
                if quotas.iter().all(|&q| q == 0) {
                    break;
                }
                // Deadline gate, checked only at barriers (after the
                // exhausted-budget break, so a search that finished in
                // time is never marked degraded): past the deadline the
                // search stops and whatever the trees found so far
                // becomes the anytime plan (DESIGN.md §14). A request
                // that waited out its whole deadline in the queue stops
                // here with zero rounds and gets the fallback plan.
                if let Some(d) = deadline {
                    if std::time::Instant::now() >= d {
                        deadline_hit = true;
                        break;
                    }
                }
                rounds += 1;
                // Fork-join round: each live tree runs its quota on its
                // own thread; no shared mutable state, so scheduling
                // cannot change any result. Panics are caught per
                // worker: the failpoint site key (round, worker) keeps
                // injected fault schedules independent of thread
                // interleaving, and `catch_unwind` turns a panic into a
                // poisoned tree instead of a dead service.
                let round_results: Vec<(usize, bool)> = std::thread::scope(|scope| {
                    let mut handles = Vec::with_capacity(k);
                    for (w, (m, &q)) in searchers.iter_mut().zip(&quotas).enumerate() {
                        if q == 0 {
                            continue;
                        }
                        let site = ((rounds as u64) << 32) | w as u64;
                        handles.push((
                            w,
                            scope.spawn(move || {
                                let _round = recorder().span_with_args(
                                    "search.round",
                                    "search",
                                    req,
                                    &[("worker", w as i64), ("quota", q as i64)],
                                );
                                std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                                    if failpoints().should_fail_at(WORKER_PANIC, site) {
                                        panic!("failpoint {WORKER_PANIC} fired (worker {w})");
                                    }
                                    if failpoints().should_fail_at(SEARCH_SLOW_ROUND, site) {
                                        std::thread::sleep(std::time::Duration::from_millis(
                                            SLOW_ROUND_SLEEP_MS,
                                        ));
                                    }
                                    m.run_episodes(q);
                                }))
                                .is_ok()
                            }),
                        ));
                    }
                    handles.into_iter().map(|(w, h)| (w, h.join().unwrap_or(false))).collect()
                });
                // Barrier bookkeeping: leader rewards + temperature
                // movement. The first reading of a tree's entropy never
                // counts as a stall (there is nothing to compare it to),
                // and a strict best-reward improvement always resets the
                // counter — an improving tree must never forfeit, even
                // when its root temperature cannot move (see
                // STALL_ENTROPY_EPS). A worker that panicked this round
                // is poisoned: its quota is consumed (the budget moves
                // to the leader below) and its tree never re-enters the
                // merge — a half-run episode may have left it mid-update.
                for &(w, ok) in &round_results {
                    remaining[w] -= quotas[w];
                    if !ok {
                        poisoned[w] = true;
                        worker_panics += 1;
                        best_so_far[w] = f64::NEG_INFINITY;
                        recorder().instant(
                            "search.worker_panic",
                            "search",
                            req,
                            &[("worker", w as i64)],
                        );
                        continue;
                    }
                    let improved = searchers[w].best_reward() > best_so_far[w];
                    if improved {
                        best_so_far[w] = searchers[w].best_reward();
                    }
                    let h = searchers[w].root_visit_entropy();
                    let moved = prev_entropy[w].is_nan()
                        || (h - prev_entropy[w]).abs() >= STALL_ENTROPY_EPS;
                    if moved || improved {
                        stall[w] = 0;
                    } else {
                        stall[w] += 1;
                    }
                    prev_entropy[w] = h;
                }
                // Leader = best reward among live trees, ties to the
                // lowest index. With every tree poisoned there is no one
                // left to search — fall through to the fallback plan.
                let live: Vec<usize> = (0..k).filter(|&w| !poisoned[w]).collect();
                let Some(&leader0) = live.first() else {
                    break;
                };
                let mut leader = leader0;
                for &w in &live {
                    if best_so_far[w] > best_so_far[leader] {
                        leader = w;
                    }
                }
                // Stalled non-leaders and poisoned trees forfeit their
                // remaining budget to the leader (budget is conserved,
                // never dropped — panic isolation rides the same steal
                // protocol as stall forfeiture).
                for w in 0..k {
                    let forfeits = poisoned[w] || stall[w] >= STALL_ROUNDS;
                    if w != leader && forfeits && remaining[w] > 0 {
                        remaining[leader] += remaining[w];
                        remaining[w] = 0;
                        steals += 1;
                        recorder().instant(
                            "search.steal",
                            "search",
                            req,
                            &[("from", w as i64), ("to", leader as i64)],
                        );
                    }
                }
                // Barrier telemetry sample (DESIGN.md §12): pure counter
                // reads over deterministic search state, at most
                // STEAL_ROUNDS times per request — collected whether or
                // not tracing is on, and feeding nothing back.
                let episodes: usize = searchers.iter().map(|m| m.episodes_run()).sum();
                let best = best_so_far.iter().copied().fold(f64::NEG_INFINITY, f64::max);
                let known: Vec<f64> =
                    prev_entropy.iter().copied().filter(|h| !h.is_nan()).collect();
                let mean_entropy = if known.is_empty() {
                    0.0
                } else {
                    known.iter().sum::<f64>() / known.len() as f64
                };
                let (mut reused, mut recomputed) = (0usize, 0usize);
                for m in searchers.iter() {
                    let (_, r, c) = m.ledger_counters();
                    reused += r;
                    recomputed += c;
                }
                let denom = reused + recomputed;
                let reuse_rate = if denom == 0 { 0.0 } else { reused as f64 / denom as f64 };
                timeline.push(RoundSample {
                    round: rounds,
                    episodes,
                    best_reward: best,
                    mean_entropy,
                    steals,
                    ledger_reuse_rate: reuse_rate,
                });
            }
            // Poisoned trees never re-enter the merge; a live tree with
            // no completed episode (deadline before its first round)
            // has nothing to contribute either.
            let results: Vec<Option<SearchResult>> = searchers
                .iter()
                .enumerate()
                .map(|(w, m)| if poisoned[w] { None } else { m.result_opt() })
                .collect();
            let episodes: Vec<usize> = searchers.iter().map(|m| m.episodes_run()).collect();
            (results, episodes, env.targets.len())
        };

        // Rank workers by the cost of the PLAN each tree would produce
        // (replay + infer-rest + lower), not the search-time eval: with
        // `auto_infer_rest` disabled the two differ, and the merge must
        // never pick a tree whose final plan is worse than a rival's.
        // With auto-infer on (the service default) these costs equal the
        // search evals bit-for-bit. Trees excluded from the merge
        // (poisoned, or no completed episode) rank at +inf.
        let mut worker_costs = vec![f64::INFINITY; k];
        for (w, r) in results.iter().enumerate() {
            let Some(r) = r else { continue };
            let (mut dm, mut stats) = session.program.apply(&r.best_state);
            session.program.prop.infer_rest(
                &session.program.func,
                &session.program.mesh,
                &mut dm,
                &mut stats,
            );
            // Each tree may have refined the stage cuts differently; its
            // plan must be priced through ITS schedule, not the seed's.
            let spec = pipe_spec
                .as_ref()
                .map(|s| PipelineSpec { cuts: r.best_cuts.clone(), ..s.clone() });
            worker_costs[w] = evaluate_pipelined(
                &session.program,
                &dm,
                &self.device,
                &self.weights,
                spec.as_ref(),
            )
            .cost;
        }
        // Strict `<`: ties go to the lowest worker index, which keeps
        // the merge deterministic.
        let mut winner = 0usize;
        for w in 1..k {
            if worker_costs[w] < worker_costs[winner] {
                winner = w;
            }
        }
        let fallback = results[winner].is_none();
        // Tracing only: replay the WINNING plan's 1F1B schedule into the
        // flight recorder as per-(stage, microbatch) slices on the
        // simulated-time track. Once per pipelined request, never on the
        // episode hot path; `stage_timeline` shares the pricing path's
        // accumulation, so the traced schedule is exactly the priced one.
        if let (Some(spec0), Some(win)) = (
            pipe_spec.as_ref().filter(|_| recorder().enabled()),
            results[winner].as_ref(),
        ) {
            let spec = PipelineSpec { cuts: win.best_cuts.clone(), ..spec0.clone() };
            let (mut dm, mut stats) = session.program.apply(&win.best_state);
            session.program.prop.infer_rest(
                &session.program.func,
                &session.program.mesh,
                &mut dm,
                &mut stats,
            );
            let (stage_seconds, xfer) = stage_timeline(&session.program, &dm, &self.device, &spec);
            let m = spec.microbatches.max(1);
            let (_, slices) = simulate_1f1b_slices(&stage_seconds, &xfer, m);
            for sl in &slices {
                let dur = ((sl.end_seconds - sl.start_seconds) * 1e9).max(0.0) as u64;
                recorder().slice(
                    "pipeline.stage",
                    "pipeline",
                    req,
                    sl.stage as u32,
                    (sl.start_seconds * 1e9) as u64,
                    dur,
                    &[("microbatch", sl.microbatch as i64)],
                );
            }
        }
        // With at least one surviving tree the winning result is adopted
        // as usual; with none, the session holds exactly the pre-tactic
        // state and InferRest + Lower alone synthesise the guaranteed
        // fallback plan — zero search decisions, but always a plan.
        if let Some(win) = results[winner].as_ref() {
            session.adopt_search_result(win, targets, worklist.len());
        }
        let mut plan = session.run(&[Tactic::InferRest, Tactic::Lower])?;
        plan.wall_seconds = 0.0;
        let results: Vec<SearchResult> = results.into_iter().flatten().collect();
        Ok(ExecutorReport {
            plan,
            winner,
            worker_costs,
            worker_episodes: worker_episodes.clone(),
            episodes_total: worker_episodes.iter().sum(),
            rounds,
            steals,
            wall_seconds: t0.elapsed().as_secs_f64(),
            eval_lookups: results.iter().map(|r| r.eval_lookups).sum(),
            eval_memo_hits: results.iter().map(|r| r.eval_memo_hits).sum(),
            ledger_refreshes: results.iter().map(|r| r.ledger_refreshes).sum(),
            ledger_nodes_reused: results.iter().map(|r| r.ledger_nodes_reused).sum(),
            ledger_nodes_recomputed: results.iter().map(|r| r.ledger_nodes_recomputed).sum(),
            timeline,
            worker_panics,
            deadline_hit,
            fallback,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::mlp::{build_mlp, MlpConfig};
    use crate::session::ShardingConstraint;

    fn job(workers: usize, seed: u64) -> PlanJob {
        PlanJob {
            func: build_mlp(&MlpConfig::small()).func,
            mesh: Mesh::new(&[("batch", 2), ("model", 4)]),
            device: Device::tpu_v3(),
            weights: CostWeights::default(),
            options: SearchOptions::default(),
            pre_tactics: vec![Tactic::Manual {
                constraints: vec![ShardingConstraint::new("x", 0, "batch")],
                manual_axes: vec!["batch".to_string()],
            }],
            budget: 60,
            seed,
            workers,
            mcts: MctsConfig::default(),
            deadline_ms: 0,
        }
    }

    #[test]
    fn fixed_seed_and_k_reproduce_the_same_plan() {
        let j = job(4, 7);
        let a = j.run().unwrap();
        let b = j.run().unwrap();
        assert_eq!(a.winner, b.winner);
        assert_eq!(a.worker_costs, b.worker_costs);
        assert_eq!(a.worker_episodes, b.worker_episodes);
        assert_eq!((a.rounds, a.steals), (b.rounds, b.steals));
        assert_eq!(
            a.plan.to_json().to_string(),
            b.plan.to_json().to_string(),
            "root-parallel executor must be deterministic for fixed (seed, K)"
        );
        assert_eq!(a.episodes_total, 4 * 60);
        assert_eq!(
            a.worker_episodes.iter().sum::<usize>(),
            a.episodes_total,
            "work stealing must conserve the total budget"
        );
    }

    #[test]
    fn winner_has_the_minimum_cost() {
        let r = job(4, 3).run().unwrap();
        let min = r.worker_costs.iter().cloned().fold(f64::INFINITY, f64::min);
        assert_eq!(r.worker_costs[r.winner], min);
        assert_eq!(r.plan.eval.cost, min);
        assert_eq!(r.plan.wall_seconds, 0.0, "plan wall time is zeroed for determinism");
        assert!(r.wall_seconds > 0.0);
        assert!(r.rounds >= 1);
    }

    #[test]
    fn report_surfaces_memo_and_ledger_counters() {
        let r = job(4, 3).run().unwrap();
        // One evaluation per episode, routed through the memos.
        assert_eq!(r.eval_lookups, r.episodes_total);
        assert!(r.eval_memo_hits < r.eval_lookups);
        // Every memo miss was answered by a ledger refresh, and the
        // ledgers actually reused cached node terms (how many depends on
        // how far apart consecutive terminal states land).
        assert_eq!(r.ledger_refreshes, r.eval_lookups - r.eval_memo_hits);
        assert!(r.ledger_nodes_reused > 0, "ledger must reuse some node terms");
        // Deterministic alongside everything else.
        let r2 = job(4, 3).run().unwrap();
        assert_eq!(r.eval_memo_hits, r2.eval_memo_hits);
        assert_eq!(r.ledger_nodes_recomputed, r2.ledger_nodes_recomputed);
    }

    #[test]
    fn round_timeline_tracks_the_barriers() {
        let r = job(4, 3).run().unwrap();
        assert_eq!(r.timeline.len(), r.rounds, "one sample per barrier");
        for w in r.timeline.windows(2) {
            assert!(w[1].episodes >= w[0].episodes, "episode counts are cumulative");
            assert!(w[1].best_reward >= w[0].best_reward, "best reward is monotone");
            assert!(w[1].steals >= w[0].steals, "steal counts are cumulative");
        }
        let last = r.timeline.last().unwrap();
        assert_eq!(last.episodes, r.episodes_total);
        assert_eq!(last.steals, r.steals);
        assert!(last.ledger_reuse_rate > 0.0 && last.ledger_reuse_rate <= 1.0);
        // The timeline reads deterministic state, so it is reproducible.
        let r2 = job(4, 3).run().unwrap();
        assert_eq!(r.timeline.len(), r2.timeline.len());
        for (a, b) in r.timeline.iter().zip(&r2.timeline) {
            assert_eq!(a.episodes, b.episodes);
            assert_eq!(a.best_reward, b.best_reward);
            assert_eq!(a.mean_entropy, b.mean_entropy);
        }
    }

    #[test]
    fn manual_constraints_survive_every_worker() {
        let r = job(3, 5).run().unwrap();
        let x = r.plan.input_specs.iter().find(|s| s.name == "x").unwrap();
        assert!(x.tiled_on("batch"), "pre-tactic pin must survive the fan-out");
    }

    #[test]
    fn different_seeds_change_the_fingerprint_not_determinism() {
        let a = job(2, 1);
        let b = job(2, 2);
        assert_ne!(a.fingerprint(), b.fingerprint());
        assert_eq!(a.fingerprint(), job(2, 1).fingerprint());
    }

    #[test]
    fn deadline_is_not_part_of_the_fingerprint() {
        // The deadline shapes how long we search, never which plan a
        // completed search yields — so it must share the cache line.
        let mut d = job(2, 1);
        d.deadline_ms = 5000;
        assert_eq!(d.fingerprint(), job(2, 1).fingerprint());
    }

    #[test]
    fn generous_deadline_changes_nothing() {
        // A deadline the search beats easily must leave the plan
        // byte-identical to the undeadlined run — the determinism
        // contract (DESIGN.md §14) depends on it.
        let a = job(4, 7).run().unwrap();
        let mut j = job(4, 7);
        j.deadline_ms = 600_000;
        let b = j.run().unwrap();
        assert!(!b.deadline_hit && !b.fallback && b.worker_panics == 0);
        assert_eq!(a.plan.to_json().to_string(), b.plan.to_json().to_string());
        assert_eq!(a.worker_episodes, b.worker_episodes);
    }

    #[test]
    fn fallback_plan_needs_no_search_and_keeps_pins() {
        let p = job(4, 7).fallback_plan().unwrap();
        assert_eq!(p.wall_seconds, 0.0);
        let x = p.input_specs.iter().find(|s| s.name == "x").unwrap();
        assert!(x.tiled_on("batch"), "pre-tactic pin must survive the fallback path");
        // Deterministic: the fallback is a pure function of the job.
        let q = job(4, 7).fallback_plan().unwrap();
        assert_eq!(p.to_json().to_string(), q.to_json().to_string());
    }

    #[test]
    fn tight_deadline_returns_the_anytime_plan_not_an_error() {
        // A budget far too large for a 1 ms deadline: the barrier gate
        // must stop the search early and return the best-so-far plan —
        // degraded, but a real plan, never a hang or an Err.
        let mut j = job(4, 7);
        j.budget = 100_000;
        j.deadline_ms = 1;
        let r = j.run().unwrap();
        assert!(r.deadline_hit, "the gate must report the deadline");
        assert!(r.rounds < STEAL_ROUNDS, "the search must have been cut short");
        assert!(
            r.episodes_total < 4 * j.budget,
            "a deadline-hit run cannot have spent the whole budget"
        );
        if !r.fallback {
            // At least one round completed somewhere: the anytime plan
            // is a genuine merge over the surviving trees.
            assert!(r.worker_costs[r.winner].is_finite());
            assert_eq!(r.plan.eval.cost, r.worker_costs[r.winner]);
        } else {
            // Zero completed episodes: the guaranteed fallback.
            assert_eq!(
                r.plan.to_json().to_string(),
                j.fallback_plan().unwrap().to_json().to_string()
            );
        }
    }
}
