//! Request/response wire schema for the partition-plan service
//! (DESIGN.md §9), one JSON document per line (JSONL).
//!
//! Request:
//!
//! ```json
//! {"id": "r1", "model": "mlp", "mesh": "batch=2,model=4",
//!  "pin": ["batch"], "shard": ["x:0:batch"],
//!  "budget": 300, "seed": 7, "workers": 4,
//!  "filter": "heuristic", "top_k": 25, "layers": 4}
//! ```
//!
//! Instead of naming a built-in `"model"`, a request may carry an
//! arbitrary program in the textual IR form (DESIGN.md §10) under
//! `"program"`: either the program text inline, or `"@path/to/f.pir"`
//! to read it from a file (resolved against the service's working
//! directory). `@` files are sniffed by content: a pallas-bin header
//! (DESIGN.md §13) selects binary decode — `"@path/to/f.pbp"` — and
//! anything else is parsed as textual IR. The program is verified before
//! planning, and
//! the request fingerprint is computed over the *parsed* structure, so
//! a program request and an equivalent built-in-model request share a
//! cache line. `"model"` and `"program"` are mutually exclusive.
//!
//! Trust note: `@path` is read with the service process's own
//! filesystem privileges, and parse diagnostics echo a short prefix of
//! whatever was read (expected/found messages). The serve/batch front
//! ends take requests from stdin or an operator-named file — treat
//! request authorship as operator-trusted, and prefer inline
//! `"program"` text when relaying requests from anyone else.
//!
//! Only `id` is required; everything else has defaults. Response:
//!
//! ```json
//! {"id": "r1", "fingerprint": "89ab...", "cached": false,
//!  "dedup": false, "plan": { ... PartitionPlan ... }}
//! ```
//!
//! or `{"id": "r1", "error": "..."}` when the request is malformed or
//! the pipeline fails. `plan` is the exact serialised [`PartitionPlan`];
//! cache hits return it byte-identically.

use super::executor::PlanJob;
use crate::cost::composite::CostWeights;
use crate::ir::Func;
use crate::partir::mesh::Mesh;
use crate::search::env::SearchOptions;
use crate::search::mcts::MctsConfig;
use crate::session::{RankerSpec, ShardingConstraint, Tactic};
use crate::sim::device::Device;
use crate::util::json::{parse, Json};
use anyhow::{anyhow, bail, Context, Result};

/// One partition request, as parsed off the wire.
#[derive(Debug, Clone, PartialEq)]
pub struct PartitionRequest {
    pub id: String,
    /// `mlp` | `transformer` | `graphnet` (ignored when `program` is set).
    pub model: String,
    /// Arbitrary program in textual IR form: inline text, or `@path`
    /// to a `.pir` file. Mutually exclusive with an explicit `model`.
    pub program: Option<String>,
    /// Transformer depth (ignored by the other models).
    pub layers: usize,
    /// Mesh spec, `"name=size[,name=size]"`.
    pub mesh: String,
    /// Mesh axes excluded from search (paper Fig 5 `manual_axes`).
    pub pin: Vec<String>,
    /// Pre-shardings in CLI syntax `name:dim:axis`.
    pub shard: Vec<String>,
    /// Worklist filter: `none` | `heuristic`.
    pub filter: String,
    /// Pipeline-parallelism flag, `"stages=K[,microbatches=M][,axis=N]"`
    /// (empty = no pipeline tactic). The named axis is appended to the
    /// mesh with size `K` when absent, marked non-searchable.
    pub pipeline: String,
    pub top_k: usize,
    pub budget: usize,
    pub seed: u64,
    pub workers: usize,
    /// Soft deadline for this request's search, in milliseconds
    /// (0 = inherit the service default; the default's default is no
    /// deadline). A deadline-hit search returns the best-so-far anytime
    /// plan marked `"degraded":"deadline"` (DESIGN.md §14).
    pub deadline_ms: u64,
}

impl Default for PartitionRequest {
    fn default() -> Self {
        PartitionRequest {
            id: String::new(),
            model: "transformer".to_string(),
            program: None,
            layers: 2,
            mesh: "model=4".to_string(),
            pin: Vec::new(),
            shard: Vec::new(),
            filter: "none".to_string(),
            pipeline: String::new(),
            top_k: crate::learner::ranker::TOP_K,
            budget: 300,
            seed: 0,
            workers: 2,
            deadline_ms: 0,
        }
    }
}

fn str_list(j: &Json, key: &str) -> Result<Vec<String>> {
    match j.get(key) {
        None => Ok(Vec::new()),
        Some(v) => v
            .as_arr()
            .with_context(|| format!("'{key}' must be an array of strings"))?
            .iter()
            .map(|s| {
                s.as_str()
                    .map(str::to_string)
                    .with_context(|| format!("'{key}' must contain only strings"))
            })
            .collect(),
    }
}

impl PartitionRequest {
    pub fn from_json(j: &Json) -> Result<PartitionRequest> {
        let d = PartitionRequest::default();
        let id = j
            .get("id")
            .and_then(|v| v.as_str())
            .context("request missing required string 'id'")?
            .to_string();
        // Absent fields default; present fields of the wrong type or
        // value are hard errors (a silently-defaulted or truncated seed
        // or worker count would change the fingerprint — and the plan —
        // without warning). The JSON substrate carries numbers as f64,
        // so exact integers are bounded by 2^53.
        let get_str = |key: &str, def: &str| -> Result<String> {
            match j.get(key) {
                None => Ok(def.to_string()),
                Some(v) => v
                    .as_str()
                    .map(str::to_string)
                    .with_context(|| format!("'{key}' must be a string")),
            }
        };
        const MAX_EXACT: f64 = 9_007_199_254_740_992.0; // 2^53
        let get_uint = |key: &str, def: u64| -> Result<u64> {
            match j.get(key) {
                None => Ok(def),
                Some(v) => {
                    let x =
                        v.as_f64().with_context(|| format!("'{key}' must be a number"))?;
                    if !(0.0..=MAX_EXACT).contains(&x) || x.fract() != 0.0 {
                        bail!("'{key}' must be a non-negative integer <= 2^53, got {x}");
                    }
                    Ok(x as u64)
                }
            }
        };
        let get_usize = |key: &str, def: usize| -> Result<usize> {
            get_uint(key, def as u64).map(|x| x as usize)
        };
        let seed = get_uint("seed", d.seed)?;
        let program = j
            .get("program")
            .map(|v| {
                v.as_str()
                    .map(str::to_string)
                    .context("'program' must be a string (inline text or '@file.pir')")
            })
            .transpose()?;
        if program.is_some() && j.get("model").is_some() {
            bail!("request has both 'model' and 'program'; they are mutually exclusive");
        }
        Ok(PartitionRequest {
            id,
            model: get_str("model", &d.model)?,
            program,
            layers: get_usize("layers", d.layers)?,
            mesh: get_str("mesh", &d.mesh)?,
            pin: str_list(j, "pin")?,
            shard: str_list(j, "shard")?,
            filter: get_str("filter", &d.filter)?,
            pipeline: get_str("pipeline", &d.pipeline)?,
            top_k: get_usize("top_k", d.top_k)?,
            budget: get_usize("budget", d.budget)?.max(1),
            seed,
            workers: get_usize("workers", d.workers)?.max(1),
            deadline_ms: get_uint("deadline_ms", d.deadline_ms)?,
        })
    }

    /// Parse one JSONL line.
    pub fn parse_line(line: &str) -> Result<PartitionRequest> {
        let j = parse(line.trim()).map_err(|e| anyhow!("bad request json: {e}"))?;
        PartitionRequest::from_json(&j)
    }

    pub fn to_json(&self) -> Json {
        let strs = |xs: &[String]| Json::Arr(xs.iter().map(|s| Json::str(s.clone())).collect());
        // `model` and `program` are mutually exclusive on the wire, so
        // emit whichever one this request actually uses.
        let source = match &self.program {
            Some(p) => ("program", Json::str(p.clone())),
            None => ("model", Json::str(self.model.clone())),
        };
        let mut fields = vec![
            ("id", Json::str(self.id.clone())),
            source,
            ("layers", Json::num(self.layers as f64)),
            ("mesh", Json::str(self.mesh.clone())),
            ("pin", strs(&self.pin)),
            ("shard", strs(&self.shard)),
            ("filter", Json::str(self.filter.clone())),
            ("top_k", Json::num(self.top_k as f64)),
            ("budget", Json::num(self.budget as f64)),
            ("seed", Json::num(self.seed as f64)),
            ("workers", Json::num(self.workers as f64)),
        ];
        if !self.pipeline.is_empty() {
            fields.push(("pipeline", Json::str(self.pipeline.clone())));
        }
        // Key present only when set, so pre-deadline requests keep their
        // wire shape (and round-trip) unchanged.
        if self.deadline_ms > 0 {
            fields.push(("deadline_ms", Json::num(self.deadline_ms as f64)));
        }
        Json::obj(fields)
    }

    fn build_func(&self, max_program_bytes: u64) -> Result<Func> {
        if let Some(src) = &self.program {
            // `@path` files are sniffed by content, not extension: a
            // pallas-bin header means binary decode (`.pbp`), anything
            // else is parsed as textual IR (`.pir`). Both spellings of
            // the same program fingerprint identically because the
            // fingerprint hashes the decoded structure.
            if let Some(path) = src.strip_prefix('@') {
                let bytes = read_capped(path, max_program_bytes)?;
                if crate::ir::binary::is_pallas_bin(&bytes) {
                    return crate::ir::binary::decode_program(&bytes)
                        .map_err(|e| anyhow!("program '{path}': {e}"));
                }
                let text = String::from_utf8(bytes)
                    .map_err(|e| anyhow!("program file '{path}' is not UTF-8: {e}"))?;
                return crate::ir::parser::parse_func(&text).map_err(|e| anyhow!("program: {e}"));
            }
            return crate::ir::parser::parse_func(src).map_err(|e| anyhow!("program: {e}"));
        }
        crate::models::build_by_name(&self.model, self.layers).ok_or_else(|| {
            anyhow!("unknown model '{}' (want mlp|transformer|graphnet)", self.model)
        })
    }

    /// Resolve the request into a runnable [`PlanJob`] under the
    /// service's device/cost/search configuration.
    pub fn build_job(&self, defaults: &JobDefaults) -> Result<PlanJob> {
        let func = self.build_func(defaults.max_program_bytes)?;
        let mut mesh = Mesh::parse(&self.mesh).map_err(|e| anyhow!("{e}"))?;
        let mut pre_tactics = Vec::new();
        if !self.pin.is_empty() || !self.shard.is_empty() {
            let constraints = self
                .shard
                .iter()
                .map(|s| ShardingConstraint::parse(s))
                .collect::<Result<Vec<_>>>()?;
            pre_tactics.push(Tactic::Manual { constraints, manual_axes: self.pin.clone() });
        }
        if !self.pipeline.is_empty() {
            let flag = crate::pipeline::parse_pipeline_flag(&self.pipeline)?;
            // Give the pipeline tactic a dedicated mesh axis when the
            // request's mesh spec doesn't already name one.
            if !mesh.axes.iter().any(|a| a.name == flag.axis) {
                if mesh.axes.len() >= crate::partir::mesh::MAX_AXES {
                    bail!(
                        "mesh '{}' is full ({} axes); cannot add pipeline axis '{}'",
                        self.mesh,
                        mesh.axes.len(),
                        flag.axis
                    );
                }
                mesh.axes.push(crate::partir::mesh::Axis {
                    name: flag.axis.clone(),
                    size: flag.stages as i64,
                    searchable: false,
                });
            }
            pre_tactics.push(Tactic::Pipeline {
                axis: flag.axis,
                stages: flag.stages,
                microbatches: flag.microbatches,
            });
        }
        match self.filter.as_str() {
            "none" => {}
            "heuristic" => pre_tactics
                .push(Tactic::Filter { ranker: RankerSpec::Heuristic, top_k: self.top_k }),
            other => bail!("unknown filter '{other}' (want none|heuristic)"),
        }
        Ok(PlanJob {
            func,
            mesh,
            device: defaults.device.clone(),
            weights: defaults.weights.clone(),
            options: defaults.options.clone(),
            pre_tactics,
            budget: self.budget,
            seed: self.seed,
            workers: self.workers,
            mcts: defaults.mcts.clone(),
            deadline_ms: if self.deadline_ms > 0 { self.deadline_ms } else { defaults.deadline_ms },
        })
    }
}

/// Read a request-referenced file, refusing anything over `max_bytes`.
/// The cap is enforced on the bytes actually read (`take`), not a
/// pre-checked length, so a file growing between stat and read cannot
/// slip past it — one oversized `@path` must never OOM the service.
fn read_capped(path: &str, max_bytes: u64) -> Result<Vec<u8>> {
    use std::io::Read;
    let f = std::fs::File::open(path).map_err(|e| anyhow!("reading program file '{path}': {e}"))?;
    let mut bytes = Vec::new();
    f.take(max_bytes.saturating_add(1))
        .read_to_end(&mut bytes)
        .map_err(|e| anyhow!("reading program file '{path}': {e}"))?;
    if bytes.len() as u64 > max_bytes {
        bail!(
            "request file cap: program file '{path}' exceeds the {max_bytes}-byte limit \
             (raise JobDefaults::max_program_bytes to serve it)"
        );
    }
    Ok(bytes)
}

/// Service-level configuration shared by every request: the device and
/// cost model plans are evaluated against, plus search hyperparameters.
#[derive(Clone)]
pub struct JobDefaults {
    pub device: Device,
    pub weights: CostWeights,
    pub options: SearchOptions,
    pub mcts: MctsConfig,
    /// Default search deadline in milliseconds for requests that carry
    /// no `deadline_ms` of their own (0 = no deadline).
    pub deadline_ms: u64,
    /// Upper bound on `@path` request file reads (bytes); oversized
    /// files are refused with a "request file cap" diagnostic.
    pub max_program_bytes: u64,
}

/// Default `@path` request file cap: 64 MiB.
pub const DEFAULT_MAX_PROGRAM_BYTES: u64 = 64 << 20;

impl Default for JobDefaults {
    fn default() -> Self {
        JobDefaults {
            device: Device::tpu_v3(),
            weights: CostWeights::default(),
            options: SearchOptions::default(),
            mcts: MctsConfig::default(),
            deadline_ms: 0,
            max_program_bytes: DEFAULT_MAX_PROGRAM_BYTES,
        }
    }
}

/// Cache-effectiveness statistics of the search a response ran:
/// attached to freshly searched responses so memo and ledger hit rates
/// are observable per request (cache/dedup hits return the stored plan
/// and omit them — they ran no search to report on). Deterministic for
/// a fixed `(seed, K, budget)`, like everything else the executor does.
#[derive(Debug, Clone, PartialEq)]
pub struct SearchStats {
    /// Episodes run across all workers.
    pub episodes: usize,
    /// Barrier rounds / budget-forfeiture events of the fan-out.
    pub rounds: usize,
    pub steals: usize,
    /// Terminal-state evaluations requested / served by the eval memos.
    pub eval_lookups: usize,
    pub eval_memo_hits: usize,
    /// Node cost terms the ledgers reused vs recomputed on memo misses.
    pub ledger_nodes_reused: usize,
    pub ledger_nodes_recomputed: usize,
    /// Pipeline-parallel shape of the winning plan (0/0/0.0 when the
    /// request ran no `Pipeline` tactic).
    pub stages: usize,
    pub microbatches: usize,
    pub bubble_fraction: f64,
    /// Worker trees poisoned by a caught panic and excluded from the
    /// merge (their budget was forfeited to the survivors).
    pub worker_panics: usize,
}

impl SearchStats {
    pub fn from_report(r: &crate::service::executor::ExecutorReport) -> SearchStats {
        let pe = r.plan.eval.pipeline.as_ref();
        SearchStats {
            episodes: r.episodes_total,
            rounds: r.rounds,
            steals: r.steals,
            eval_lookups: r.eval_lookups,
            eval_memo_hits: r.eval_memo_hits,
            ledger_nodes_reused: r.ledger_nodes_reused,
            ledger_nodes_recomputed: r.ledger_nodes_recomputed,
            stages: pe.map(|p| p.stages).unwrap_or(0),
            microbatches: pe.map(|p| p.microbatches).unwrap_or(0),
            bubble_fraction: pe.map(|p| p.bubble_fraction).unwrap_or(0.0),
            worker_panics: r.worker_panics,
        }
    }

    /// Fraction of evaluations served by the memos.
    pub fn memo_hit_rate(&self) -> f64 {
        crate::util::stats::fraction(self.eval_memo_hits as u64, self.eval_lookups as u64)
    }

    /// Fraction of node cost terms the ledgers served from cache.
    pub fn ledger_reuse_rate(&self) -> f64 {
        let total = self.ledger_nodes_reused + self.ledger_nodes_recomputed;
        crate::util::stats::fraction(self.ledger_nodes_reused as u64, total as u64)
    }

    pub fn to_json(&self) -> Json {
        let mut fields = vec![
            ("episodes", Json::num(self.episodes as f64)),
            ("rounds", Json::num(self.rounds as f64)),
            ("steals", Json::num(self.steals as f64)),
            ("eval_lookups", Json::num(self.eval_lookups as f64)),
            ("eval_memo_hits", Json::num(self.eval_memo_hits as f64)),
            ("eval_memo_hit_rate", Json::Num(self.memo_hit_rate())),
            ("ledger_nodes_reused", Json::num(self.ledger_nodes_reused as f64)),
            ("ledger_nodes_recomputed", Json::num(self.ledger_nodes_recomputed as f64)),
            ("ledger_reuse_rate", Json::Num(self.ledger_reuse_rate())),
        ];
        if self.stages > 0 {
            fields.push(("stages", Json::num(self.stages as f64)));
            fields.push(("microbatches", Json::num(self.microbatches as f64)));
            fields.push(("bubble_fraction", Json::Num(self.bubble_fraction)));
        }
        // Fault-free responses keep their wire shape: the key appears
        // only when a worker actually panicked.
        if self.worker_panics > 0 {
            fields.push(("worker_panics", Json::num(self.worker_panics as f64)));
        }
        Json::obj(fields)
    }
}

/// One response line. Exactly one of `plan_json` / `error` is set.
#[derive(Debug, Clone)]
pub struct PlanResponse {
    pub id: String,
    /// Hex request fingerprint (empty on parse errors).
    pub fingerprint: String,
    /// Served without running a search (plan cache or in-flight dedup).
    pub cached: bool,
    /// Served by waiting on another request's in-flight search.
    pub dedup: bool,
    /// Served from the persistent disk tier (implies `cached`; the plan
    /// was promoted back into the memory tier on the way out).
    pub disk: bool,
    /// The serialised `PartitionPlan` (byte-identical across cache hits).
    pub plan_json: Option<String>,
    /// Degradation marker (DESIGN.md §14): `"deadline"` (anytime plan,
    /// search cut short), `"panic"` (every worker tree poisoned —
    /// fallback plan), or `"shed"` (admission control refused the
    /// search; cached or fallback plan). `None` = full-quality plan.
    /// Degraded plans are never cached, so a later request re-searches.
    pub degraded: Option<String>,
    /// The plan is the zero-search fallback (pre-tactics + InferRest).
    pub fallback: bool,
    /// Search-cache statistics — present exactly when this response ran
    /// the search itself (never on cache hits, dedup waits, or errors).
    pub search: Option<SearchStats>,
    pub error: Option<String>,
}

impl PlanResponse {
    pub fn error(id: &str, fingerprint: &str, msg: String) -> PlanResponse {
        PlanResponse {
            id: id.to_string(),
            fingerprint: fingerprint.to_string(),
            cached: false,
            dedup: false,
            disk: false,
            plan_json: None,
            degraded: None,
            fallback: false,
            search: None,
            error: Some(msg),
        }
    }

    /// Serialise as one compact JSONL line. The plan document is
    /// spliced in verbatim — it is already compact serialised JSON —
    /// so a cache hit pays no re-parse/re-print and stays
    /// byte-identical by construction.
    pub fn to_json_line(&self) -> String {
        let mut fields = vec![("id", Json::str(self.id.clone()))];
        if !self.fingerprint.is_empty() {
            fields.push(("fingerprint", Json::str(self.fingerprint.clone())));
        }
        match (&self.plan_json, &self.error) {
            (Some(p), _) => {
                fields.push(("cached", Json::Bool(self.cached)));
                fields.push(("dedup", Json::Bool(self.dedup)));
                // Key present only for disk-tier hits: memory hits and
                // fresh searches keep their pre-disk-tier wire shape.
                if self.disk {
                    fields.push(("disk", Json::Bool(true)));
                }
                // Degradation markers appear only on degraded responses,
                // keeping fault-free wire output byte-identical to the
                // pre-failure-model service.
                if let Some(d) = &self.degraded {
                    fields.push(("degraded", Json::str(d.clone())));
                }
                if self.fallback {
                    fields.push(("fallback", Json::Bool(true)));
                }
                if let Some(s) = &self.search {
                    fields.push(("search", s.to_json()));
                }
                let mut line = Json::obj(fields).to_string();
                debug_assert!(line.ends_with('}'), "compact object form");
                line.pop();
                line.push_str(",\"plan\":");
                line.push_str(p);
                line.push('}');
                line
            }
            (None, Some(e)) => {
                fields.push(("error", Json::str(e.clone())));
                Json::obj(fields).to_string()
            }
            (None, None) => {
                fields.push(("error", Json::str("internal: empty response")));
                Json::obj(fields).to_string()
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_a_minimal_request_with_defaults() {
        let r = PartitionRequest::parse_line("{\"id\":\"r1\"}").unwrap();
        assert_eq!(r.id, "r1");
        assert_eq!(r.model, "transformer");
        assert_eq!(r.workers, 2);
        assert!(r.pin.is_empty());
    }

    #[test]
    fn parses_a_full_request_and_round_trips() {
        let line = "{\"id\":\"a\",\"model\":\"mlp\",\"mesh\":\"batch=2,model=4\",\
                    \"pin\":[\"batch\"],\"shard\":[\"x:0:batch\"],\"budget\":50,\
                    \"seed\":9,\"workers\":3,\"filter\":\"heuristic\",\"top_k\":10}";
        let r = PartitionRequest::parse_line(line).unwrap();
        assert_eq!(r.mesh, "batch=2,model=4");
        assert_eq!(r.pin, vec!["batch"]);
        assert_eq!(r.shard, vec!["x:0:batch"]);
        assert_eq!((r.budget, r.seed, r.workers, r.top_k), (50, 9, 3, 10));
        let back = PartitionRequest::from_json(&parse(&r.to_json().to_string()).unwrap()).unwrap();
        assert_eq!(back, r);
    }

    #[test]
    fn rejects_malformed_requests() {
        assert!(PartitionRequest::parse_line("not json").is_err());
        assert!(PartitionRequest::parse_line("{}").is_err(), "id is required");
        assert!(PartitionRequest::parse_line("{\"id\":\"x\",\"pin\":\"batch\"}").is_err());
        // Wrong-typed or wrong-valued fields must error, not silently
        // default/truncate (that would change the plan unnoticed).
        assert!(PartitionRequest::parse_line("{\"id\":\"x\",\"seed\":\"7\"}").is_err());
        assert!(PartitionRequest::parse_line("{\"id\":\"x\",\"workers\":\"8\"}").is_err());
        assert!(PartitionRequest::parse_line("{\"id\":\"x\",\"model\":3}").is_err());
        assert!(PartitionRequest::parse_line("{\"id\":\"x\",\"seed\":-1}").is_err());
        assert!(PartitionRequest::parse_line("{\"id\":\"x\",\"budget\":2.7}").is_err());
        assert!(PartitionRequest::parse_line("{\"id\":\"x\",\"seed\":1e17}").is_err());
        assert!(PartitionRequest::parse_line("{\"id\":\"x\",\"seed\":9007199254740992}").is_ok());
    }

    #[test]
    fn program_requests_parse_build_and_round_trip() {
        let text = crate::ir::printer::print_func(
            &crate::models::mlp::build_mlp(&crate::models::mlp::MlpConfig::small()).func,
        );
        let j = Json::obj(vec![
            ("id", Json::str("p1".to_string())),
            ("program", Json::str(text.clone())),
            ("mesh", Json::str("model=4".to_string())),
        ]);
        let r = PartitionRequest::from_json(&parse(&j.to_string()).unwrap()).unwrap();
        assert_eq!(r.program.as_deref(), Some(text.as_str()));
        let job = r.build_job(&JobDefaults::default()).unwrap();
        assert_eq!(job.func.name, "mlp_update");
        // The parsed program fingerprints identically to the built-in
        // model it was printed from (the acceptance criterion that lets
        // external frontends share the cache with built-in requests).
        let model_req = PartitionRequest {
            id: "m1".into(),
            model: "mlp".into(),
            mesh: "model=4".into(),
            ..Default::default()
        };
        let model_job = model_req.build_job(&JobDefaults::default()).unwrap();
        assert_eq!(job.fingerprint(), model_job.fingerprint());
        // Wire round-trip: to_json emits 'program' (not 'model').
        let back = PartitionRequest::from_json(&parse(&r.to_json().to_string()).unwrap()).unwrap();
        assert_eq!(back, r);
    }

    #[test]
    fn binary_program_files_are_sniffed_and_fingerprint_identically() {
        let func = crate::models::mlp::build_mlp(&crate::models::mlp::MlpConfig::small()).func;
        let path = std::env::temp_dir()
            .join(format!("automap-request-pbp-{}.pbp", std::process::id()));
        std::fs::write(&path, crate::ir::binary::encode_program(&func)).unwrap();
        let bin_req = PartitionRequest {
            id: "b1".into(),
            program: Some(format!("@{}", path.display())),
            ..Default::default()
        };
        let text_req = PartitionRequest {
            id: "t1".into(),
            program: Some(crate::ir::printer::print_func(&func)),
            ..Default::default()
        };
        let model_req = PartitionRequest {
            id: "m1".into(),
            model: "mlp".into(),
            ..Default::default()
        };
        let d = JobDefaults::default();
        let bin_job = bin_req.build_job(&d).unwrap();
        assert_eq!(bin_job.func.name, "mlp_update");
        // All three spellings of the same program share one cache line.
        assert_eq!(bin_job.fingerprint(), text_req.build_job(&d).unwrap().fingerprint());
        assert_eq!(bin_job.fingerprint(), model_req.build_job(&d).unwrap().fingerprint());
        std::fs::remove_file(&path).ok();
        // A corrupt binary file fails with the path in the message.
        let bad = std::env::temp_dir()
            .join(format!("automap-request-pbp-bad-{}.pbp", std::process::id()));
        let mut bytes = crate::ir::binary::encode_program(&func);
        let last = bytes.len() - 1;
        bytes[last] ^= 0x01;
        std::fs::write(&bad, &bytes).unwrap();
        let req = PartitionRequest {
            id: "x".into(),
            program: Some(format!("@{}", bad.display())),
            ..Default::default()
        };
        let e = req.build_job(&d).unwrap_err();
        assert!(e.to_string().contains("pallas-bin decode error"), "{e}");
        std::fs::remove_file(&bad).ok();
    }

    #[test]
    fn program_requests_reject_conflicts_and_bad_programs() {
        let both = "{\"id\":\"x\",\"model\":\"mlp\",\"program\":\"func @f() -> () { return }\"}";
        let e = PartitionRequest::parse_line(both).unwrap_err();
        assert!(e.to_string().contains("mutually exclusive"), "{e}");
        assert!(PartitionRequest::parse_line("{\"id\":\"x\",\"program\":3}").is_err());
        // A malformed program fails at build time with a positioned error.
        let r = PartitionRequest::parse_line("{\"id\":\"x\",\"program\":\"func nope\"}").unwrap();
        let e = r.build_job(&JobDefaults::default()).unwrap_err();
        assert!(e.to_string().contains("line 1"), "{e}");
        // A missing @file fails with the path in the message.
        let line = "{\"id\":\"x\",\"program\":\"@/no/such.pir\"}";
        let e = PartitionRequest::parse_line(line)
            .unwrap()
            .build_job(&JobDefaults::default())
            .unwrap_err();
        assert!(e.to_string().contains("/no/such.pir"), "{e}");
    }

    #[test]
    fn build_job_resolves_models_and_tactics() {
        let r = PartitionRequest {
            id: "j".into(),
            model: "mlp".into(),
            mesh: "batch=2,model=4".into(),
            pin: vec!["batch".into()],
            shard: vec!["x:0:batch".into()],
            filter: "heuristic".into(),
            ..Default::default()
        };
        let job = r.build_job(&JobDefaults::default()).unwrap();
        assert_eq!(job.mesh.num_axes(), 2);
        assert_eq!(job.pre_tactics.len(), 2, "manual + filter");
        let bad = PartitionRequest { model: "resnet".into(), ..r.clone() };
        assert!(bad.build_job(&JobDefaults::default()).is_err());
        let bad_mesh = PartitionRequest { mesh: "nope".into(), ..r };
        assert!(bad_mesh.build_job(&JobDefaults::default()).is_err());
    }

    #[test]
    fn pipeline_requests_extend_the_mesh_and_round_trip() {
        let line = "{\"id\":\"p\",\"model\":\"mlp\",\"mesh\":\"model=4\",\
                    \"pipeline\":\"stages=2,microbatches=4\"}";
        let r = PartitionRequest::parse_line(line).unwrap();
        assert_eq!(r.pipeline, "stages=2,microbatches=4");
        let job = r.build_job(&JobDefaults::default()).unwrap();
        // The default "pipe" axis is appended, sized by the stage count
        // and excluded from the tile search.
        let pipe = job.mesh.axes.iter().find(|a| a.name == "pipe").expect("pipe axis added");
        assert_eq!(pipe.size, 2);
        assert!(!pipe.searchable);
        assert!(matches!(
            job.pre_tactics.as_slice(),
            [Tactic::Pipeline { stages: 2, microbatches: 4, .. }]
        ));
        // Wire round-trip keeps the flag; plain requests omit the key.
        let back = PartitionRequest::from_json(&parse(&r.to_json().to_string()).unwrap()).unwrap();
        assert_eq!(back, r);
        let plain = PartitionRequest { id: "q".into(), ..Default::default() };
        assert!(parse(&plain.to_json().to_string()).unwrap().get("pipeline").is_none());
        // A full mesh cannot grow a pipeline axis.
        let full = PartitionRequest {
            mesh: "a=2,b=2,c=2,d=2".into(),
            ..r.clone()
        };
        let e = full.build_job(&JobDefaults::default()).unwrap_err();
        assert!(e.to_string().contains("pipeline axis"), "{e}");
        // A bad flag fails at build time, not parse time.
        let bad = PartitionRequest { pipeline: "microbatches=4".into(), ..r };
        assert!(bad.build_job(&JobDefaults::default()).is_err());
    }

    #[test]
    fn deadline_requests_round_trip_and_resolve_against_defaults() {
        let r = PartitionRequest::parse_line("{\"id\":\"d\",\"deadline_ms\":250}").unwrap();
        assert_eq!(r.deadline_ms, 250);
        let back = PartitionRequest::from_json(&parse(&r.to_json().to_string()).unwrap()).unwrap();
        assert_eq!(back, r);
        // No-deadline requests keep their wire shape (no key at all).
        let plain = PartitionRequest { id: "q".into(), ..Default::default() };
        assert!(parse(&plain.to_json().to_string()).unwrap().get("deadline_ms").is_none());
        // Per-request deadline wins; otherwise the service default.
        let d = JobDefaults { deadline_ms: 900, ..Default::default() };
        assert_eq!(r.build_job(&d).unwrap().deadline_ms, 250);
        assert_eq!(plain.build_job(&d).unwrap().deadline_ms, 900);
        // The deadline never reaches the fingerprint: a deadlined and an
        // undeadlined spelling of the same request share a cache line.
        assert_eq!(
            plain.build_job(&d).unwrap().fingerprint(),
            plain.build_job(&JobDefaults::default()).unwrap().fingerprint()
        );
        assert!(PartitionRequest::parse_line("{\"id\":\"d\",\"deadline_ms\":-1}").is_err());
    }

    #[test]
    fn oversized_program_files_are_refused_by_the_cap() {
        let path = std::env::temp_dir()
            .join(format!("automap-request-cap-{}.pir", std::process::id()));
        let text = crate::ir::printer::print_func(
            &crate::models::mlp::build_mlp(&crate::models::mlp::MlpConfig::small()).func,
        );
        std::fs::write(&path, &text).unwrap();
        let req = PartitionRequest {
            id: "c".into(),
            program: Some(format!("@{}", path.display())),
            ..Default::default()
        };
        let mut d = JobDefaults { max_program_bytes: 16, ..Default::default() };
        let e = req.build_job(&d).unwrap_err();
        assert!(e.to_string().contains("request file cap"), "{e}");
        assert!(e.to_string().contains("16-byte limit"), "{e}");
        // At or under the cap the same file parses fine.
        d.max_program_bytes = text.len() as u64;
        assert!(req.build_job(&d).is_ok(), "exactly-at-cap file must be served");
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn response_lines_render_plan_or_error() {
        let ok = PlanResponse {
            id: "r".into(),
            fingerprint: "00ff".into(),
            cached: true,
            dedup: false,
            disk: false,
            plan_json: Some("{\"decisions\":3}".into()),
            degraded: None,
            fallback: false,
            search: None,
            error: None,
        };
        let line = ok.to_json_line();
        let j = parse(&line).unwrap();
        assert_eq!(j.get("id").unwrap().as_str(), Some("r"));
        assert_eq!(j.get("cached").unwrap().as_bool(), Some(true));
        assert_eq!(j.get("plan").unwrap().get("decisions").unwrap().as_usize(), Some(3));
        assert!(j.get("search").is_none(), "cache hits carry no search stats");
        let err = PlanResponse::error("e", "", "boom".into());
        let j = parse(&err.to_json_line()).unwrap();
        assert_eq!(j.get("error").unwrap().as_str(), Some("boom"));
        assert!(j.get("fingerprint").is_none());
    }

    #[test]
    fn fresh_responses_render_search_stats_with_rates() {
        let stats = SearchStats {
            episodes: 120,
            rounds: 8,
            steals: 1,
            eval_lookups: 120,
            eval_memo_hits: 30,
            ledger_nodes_reused: 900,
            ledger_nodes_recomputed: 100,
            stages: 4,
            microbatches: 8,
            bubble_fraction: 0.272727,
            worker_panics: 0,
        };
        assert!((stats.memo_hit_rate() - 0.25).abs() < 1e-12);
        assert!((stats.ledger_reuse_rate() - 0.9).abs() < 1e-12);
        let resp = PlanResponse {
            id: "r".into(),
            fingerprint: "00ff".into(),
            cached: false,
            dedup: false,
            disk: false,
            plan_json: Some("{\"decisions\":3}".into()),
            degraded: None,
            fallback: false,
            search: Some(stats),
            error: None,
        };
        let j = parse(&resp.to_json_line()).unwrap();
        let s = j.get("search").expect("fresh response carries search stats");
        assert_eq!(s.get("eval_lookups").unwrap().as_usize(), Some(120));
        assert_eq!(s.get("eval_memo_hits").unwrap().as_usize(), Some(30));
        assert!((s.get("ledger_reuse_rate").unwrap().as_f64().unwrap() - 0.9).abs() < 1e-12);
        // The plan document still round-trips untouched after the splice.
        assert_eq!(j.get("plan").unwrap().get("decisions").unwrap().as_usize(), Some(3));
        // Degenerate stats never divide by zero.
        let empty = SearchStats {
            episodes: 0,
            rounds: 0,
            steals: 0,
            eval_lookups: 0,
            eval_memo_hits: 0,
            ledger_nodes_reused: 0,
            ledger_nodes_recomputed: 0,
            stages: 0,
            microbatches: 0,
            bubble_fraction: 0.0,
            worker_panics: 0,
        };
        assert_eq!(empty.memo_hit_rate(), 0.0);
        assert_eq!(empty.ledger_reuse_rate(), 0.0);
        // Non-pipelined stats omit the pipeline keys entirely.
        let j = parse(&empty.to_json().to_string()).unwrap();
        assert!(j.get("stages").is_none());
        assert!(j.get("bubble_fraction").is_none());
    }
}
