//! Search-throughput measurement shared by `benches/search_throughput.rs`
//! and the tier-1 smoke test, so `BENCH_search.json` at the repo root is
//! produced by whichever ran last with the same schema.
//!
//! Numbers that matter for the service (DESIGN.md §8/§9):
//!   * root-parallel scaling — episodes/sec (and evaluations/sec) with
//!     `K` workers vs one;
//!   * eval-pipeline timings — median ns of one env step (incremental
//!     propagation) and one terminal evaluation, measured both through
//!     the incremental cost ledger (the production path) and through
//!     the full infer-rest + lower + liveness + roofline pipeline (the
//!     reference it must beat);
//!   * eval-memo hit rate and ledger term-reuse rate of the search runs;
//!   * cache-hit latency — how fast a repeat request is served;
//!   * the work-stealing schedule the multi-worker run settled on.
//!
//! When `configs/perf_floor.json` is present its recorded baseline is
//! copied into the report, so the JSON carries both the pre-overhaul
//! number and the current one — the perf trajectory in one document.

use super::executor::PlanJob;
use super::request::{JobDefaults, PartitionRequest};
use super::server::{PlanService, ServiceConfig};
use crate::cost::composite::CostWeights;
use crate::partir::mesh::Mesh;
use crate::partir::program::PartirProgram;
use crate::search::env::{EnvAction, RewriteEnv, SearchOptions};
use crate::sim::device::Device;
use crate::util::json::Json;
use crate::util::stats::fraction;
use anyhow::{anyhow, Context, Result};
use std::hint::black_box;
use std::time::Instant;

/// Measurement configuration.
#[derive(Debug, Clone)]
pub struct ThroughputConfig {
    /// Episodes per worker per run.
    pub budget: usize,
    /// Multi-worker fan-out `K`.
    pub workers: usize,
    /// Timed repetitions per variant (best run wins, to shed scheduler
    /// noise).
    pub reps: usize,
    /// Repeat requests timed against the cache.
    pub cache_probes: usize,
    /// Samples for the per-step / per-eval micro timings.
    pub micro_samples: usize,
}

impl ThroughputConfig {
    /// Quick profile for the tier-1 smoke test (a few seconds).
    pub fn quick() -> ThroughputConfig {
        ThroughputConfig { budget: 800, workers: 4, reps: 3, cache_probes: 50, micro_samples: 64 }
    }

    /// Fuller profile for `cargo bench`.
    pub fn full() -> ThroughputConfig {
        ThroughputConfig {
            budget: 2000,
            workers: 4,
            reps: 5,
            cache_probes: 500,
            micro_samples: 256,
        }
    }
}

/// Measured throughput numbers.
#[derive(Debug, Clone)]
pub struct ThroughputReport {
    pub budget: usize,
    pub workers: usize,
    pub single_episodes_per_sec: f64,
    pub multi_episodes_per_sec: f64,
    /// `multi / single` episodes-per-second ratio.
    pub speedup: f64,
    /// Terminal evaluations per second (one per episode; the quantity
    /// the search budget actually buys).
    pub single_evals_per_sec: f64,
    pub multi_evals_per_sec: f64,
    pub cache_hit_median_ns: f64,
    pub cache_probes: usize,
    /// Median ns of one tile step (incremental propagation included).
    pub step_median_ns: f64,
    /// Median ns of one terminal evaluation on the production path:
    /// the incremental cost ledger (infer-rest + diff + re-cost + re-sum).
    pub eval_median_ns: f64,
    /// Median ns of the same evaluation through the full pipeline
    /// (infer-rest + lower + liveness + roofline from scratch).
    pub eval_full_median_ns: f64,
    /// `eval_full_median_ns / eval_median_ns` — how much the ledger
    /// buys per memo-missing evaluation.
    pub eval_ledger_speedup: f64,
    /// Eval-memo hit rate / ledger term-reuse rate of the multi-worker
    /// search run.
    pub eval_memo_hit_rate: f64,
    pub ledger_reuse_rate: f64,
    /// Median ns of one 1F1B schedule simulation (8 stages, 16
    /// microbatches) — the term the pipeline tactic adds to every
    /// episode evaluation, so it must stay microscopic next to
    /// `eval_median_ns`.
    pub schedule_sim_median_ns: f64,
    /// Median ns to parse the bench program from textual IR — the cold
    /// cost of a `@file.pir` request.
    pub parse_median_ns: f64,
    /// Median ns to decode the same program from pallas-bin — the cold
    /// cost of a `@file.pbp` request (includes verification).
    pub decode_median_ns: f64,
    /// `parse_median_ns / decode_median_ns` — what the binary
    /// interchange buys on cold program loads.
    pub binary_load_speedup: f64,
    /// Barrier rounds / steal events of the best multi-worker run.
    pub rounds: usize,
    pub steals: usize,
    /// Pre-overhaul episodes/sec recorded in `configs/perf_floor.json`
    /// (absent when the file is missing or unreadable).
    pub baseline_single_episodes_per_sec: Option<f64>,
    /// Where the numbers came from: `measured at <git-sha> (<profile>)`.
    /// A real measurement always stamps this, so the committed
    /// "SEED VALUES, UNMEASURED" placeholder can never masquerade as a
    /// CI result (`python/check_perf_floor.py` hard-fails on it).
    pub provenance: String,
}

/// Provenance string for a report produced by an actual run: the git
/// commit (CI's `GITHUB_SHA`, else `git rev-parse`) plus the build
/// profile, since debug and release numbers are not comparable.
fn bench_provenance() -> String {
    let sha = std::env::var("GITHUB_SHA")
        .ok()
        .map(|s| s.trim().to_string())
        .filter(|s| !s.is_empty())
        .or_else(|| {
            std::process::Command::new("git")
                .args(["rev-parse", "--short=12", "HEAD"])
                .output()
                .ok()
                .filter(|o| o.status.success())
                .map(|o| String::from_utf8_lossy(&o.stdout).trim().to_string())
                .filter(|s| !s.is_empty())
        })
        .unwrap_or_else(|| "unknown".to_string());
    let profile = if cfg!(debug_assertions) { "debug" } else { "release" };
    format!("measured at {sha} ({profile})")
}

fn bench_job(workers: usize, budget: usize) -> PlanJob {
    // The standard request the service benchmarks against: a small
    // transformer, heavy enough that propagation dominates thread
    // bookkeeping.
    let req = PartitionRequest {
        id: "bench".to_string(),
        model: "transformer".to_string(),
        layers: 2,
        mesh: "model=4".to_string(),
        budget,
        seed: 42,
        workers,
        ..Default::default()
    };
    req.build_job(&JobDefaults::default()).expect("bench request is well-formed")
}

/// One executor run's throughput measurements (best of `reps`).
struct RunMeasure {
    episodes_per_sec: f64,
    evals_per_sec: f64,
    rounds: usize,
    steals: usize,
    memo_hit_rate: f64,
    ledger_reuse_rate: f64,
}

/// Best-of-`reps` episodes/sec (and evaluations/sec) for a
/// `workers`-way executor run, plus the (deterministic) round/steal
/// schedule and search-cache rates it ran.
fn run_throughput(workers: usize, budget: usize, reps: usize) -> Result<RunMeasure> {
    let job = bench_job(workers, budget);
    let mut best = RunMeasure {
        episodes_per_sec: 0.0,
        evals_per_sec: 0.0,
        rounds: 0,
        steals: 0,
        memo_hit_rate: 0.0,
        ledger_reuse_rate: 0.0,
    };
    for _ in 0..reps.max(1) {
        let report = job.run()?;
        let wall = report.wall_seconds.max(1e-9);
        let eps = report.episodes_total as f64 / wall;
        if eps > best.episodes_per_sec {
            let terms = report.ledger_nodes_reused + report.ledger_nodes_recomputed;
            let memo_hit_rate = fraction(report.eval_memo_hits as u64, report.eval_lookups as u64);
            best = RunMeasure {
                episodes_per_sec: eps,
                evals_per_sec: report.eval_lookups as f64 / wall,
                rounds: report.rounds,
                steals: report.steals,
                memo_hit_rate,
                ledger_reuse_rate: fraction(report.ledger_nodes_reused as u64, terms as u64),
            };
        }
    }
    Ok(best)
}

fn median(mut samples: Vec<f64>) -> f64 {
    samples.sort_by(|a, b| a.partial_cmp(b).expect("no NaN timings"));
    samples[samples.len() / 2]
}

/// Median ns of one env tile step and one terminal evaluation — the
/// latter through both the full pipeline and the incremental cost
/// ledger — on the bench program (tiny transformer, `model=4`).
/// Returns `(step, eval_full, eval_ledger)`.
///
/// The ledger is timed on the pattern the episode loop actually
/// produces: alternating between two *adjacent* terminal states (same
/// prefix, one extra decision), so every refresh pays a real diff +
/// re-cost of the decision's dirty region, not an empty no-op diff.
/// NOTE: debug builds cross-check every ledger evaluation against the
/// full pipeline inside `evaluate_episode_ledger`, so their ledger
/// numbers are slower than the full path by construction — release
/// numbers are the meaningful ones (the `debug_build` flag in
/// `BENCH_search.json` marks this).
fn micro_timings(samples: usize) -> Result<(f64, f64, f64)> {
    let func = crate::models::build_by_name("transformer", 2).context("builtin transformer")?;
    let program = PartirProgram::new(func, Mesh::parse("model=4").map_err(|e| anyhow!("{e}"))?);
    let wl = RewriteEnv::default_worklist(&program);
    let env = RewriteEnv::new(
        &program,
        Device::tpu_v3(),
        CostWeights::default(),
        SearchOptions::default(),
        &wl,
    );
    let root = env.reset();
    let tile = env
        .legal_actions(&root)
        .into_iter()
        .find(|a| matches!(a, EnvAction::Tile { .. }))
        .context("bench program must offer a tile action")?;
    let n = samples.max(8);
    let mut step_samples = Vec::with_capacity(n);
    let mut ep = root.clone();
    for _ in 0..n {
        ep.clone_from(&root);
        let t0 = Instant::now();
        env.step(&mut ep, tile);
        step_samples.push(t0.elapsed().as_nanos() as f64);
        black_box(ep.decisions);
    }
    // Terminal evaluation on the stepped episode, full-pipeline path.
    env.step(&mut ep, EnvAction::Stop);
    let mut full_samples = Vec::with_capacity(n);
    for _ in 0..n {
        let t0 = Instant::now();
        let eval = env.evaluate_episode(&ep);
        full_samples.push(t0.elapsed().as_nanos() as f64);
        black_box(eval.cost);
    }
    // Ledger path: two adjacent terminal states share one ledger, which
    // hops between them so every evaluation re-syncs across one
    // decision's worth of changed values.
    let mut ep_a = env.reset();
    env.step(&mut ep_a, tile);
    let mut ep_b = ep_a.clone();
    // Hard requirement, like the first tile above: without a second
    // distinct decision the two states are identical, every refresh
    // diffs zero values, and the "ledger" median would time a no-op —
    // vacuously passing the blocking CI speedup gate.
    let second = env
        .legal_actions(&ep_b)
        .into_iter()
        .find(|a| matches!(a, EnvAction::Tile { .. }))
        .context("bench program must offer a second tile action for the ledger timing")?;
    env.step(&mut ep_b, second);
    env.step(&mut ep_a, EnvAction::Stop);
    env.step(&mut ep_b, EnvAction::Stop);
    env.attach_ledger(&mut ep_a);
    black_box(env.evaluate_episode_ledger(&mut ep_a).cost); // warm build
    let mut ledger_samples = Vec::with_capacity(n);
    for i in 0..n {
        if i % 2 == 0 {
            ep_b.ledger = ep_a.ledger.take();
            let t0 = Instant::now();
            let eval = env.evaluate_episode_ledger(&mut ep_b);
            ledger_samples.push(t0.elapsed().as_nanos() as f64);
            black_box(eval.cost);
        } else {
            ep_a.ledger = ep_b.ledger.take();
            let t0 = Instant::now();
            let eval = env.evaluate_episode_ledger(&mut ep_a);
            ledger_samples.push(t0.elapsed().as_nanos() as f64);
            black_box(eval.cost);
        }
    }
    Ok((median(step_samples), median(full_samples), median(ledger_samples)))
}

/// Median ns of one 1F1B schedule simulation on the shape the pipeline
/// tactic prices per episode evaluation (8 stages, 16 microbatches).
fn schedule_sim_timing(samples: usize) -> f64 {
    let stage = vec![1.25e-3; 8];
    let xfer = vec![2.0e-5; 7];
    let n = samples.max(8);
    let mut out = Vec::with_capacity(n);
    for _ in 0..n {
        let t0 = Instant::now();
        let r = crate::pipeline::simulate_1f1b(&stage, &xfer, 16);
        out.push(t0.elapsed().as_nanos() as f64);
        black_box(r.bubble_fraction);
    }
    median(out)
}

/// Median ns of a cold program load through both interchange formats
/// on the bench program: `parse_func` over its printed textual IR vs
/// `decode_program` over its pallas-bin encoding (DESIGN.md §13).
/// Returns `(parse, decode)`.
fn interchange_timings(samples: usize) -> Result<(f64, f64)> {
    let func = crate::models::build_by_name("transformer", 2).context("builtin transformer")?;
    let text = crate::ir::print_func(&func);
    let bytes = crate::ir::binary::encode_program(&func);
    let n = samples.max(8);
    let mut parse_samples = Vec::with_capacity(n);
    for _ in 0..n {
        let t0 = Instant::now();
        let parsed = crate::ir::parse_func(&text).map_err(|e| anyhow!("{e}"))?;
        parse_samples.push(t0.elapsed().as_nanos() as f64);
        black_box(parsed.nodes.len());
    }
    let mut decode_samples = Vec::with_capacity(n);
    for _ in 0..n {
        let t0 = Instant::now();
        let decoded = crate::ir::binary::decode_program(&bytes).map_err(|e| anyhow!("{e}"))?;
        decode_samples.push(t0.elapsed().as_nanos() as f64);
        black_box(decoded.nodes.len());
    }
    Ok((median(parse_samples), median(decode_samples)))
}

/// Repo root (one level above the crate manifest).
fn repo_root() -> Result<std::path::PathBuf> {
    Ok(std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
        .parent()
        .context("crate dir has a parent")?
        .to_path_buf())
}

/// The pre-overhaul baseline recorded next to the advisory floor, if
/// the config exists.
fn load_baseline() -> Option<f64> {
    let path = repo_root().ok()?.join("configs/perf_floor.json");
    let text = std::fs::read_to_string(path).ok()?;
    let j = crate::util::json::parse(&text).ok()?;
    j.get("baseline_single_episodes_per_sec")?.as_f64()
}

/// Run the full measurement.
pub fn measure(cfg: &ThroughputConfig) -> Result<ThroughputReport> {
    let single = run_throughput(1, cfg.budget, cfg.reps)?;
    let multi = run_throughput(cfg.workers, cfg.budget, cfg.reps)?;
    let (step_median_ns, eval_full_median_ns, eval_median_ns) = micro_timings(cfg.micro_samples)?;
    let (parse_median_ns, decode_median_ns) = interchange_timings(cfg.micro_samples)?;

    // Cache-hit latency: prime the service with one search, then time
    // repeat requests (all hits).
    let svc = PlanService::new(ServiceConfig::default());
    let req = PartitionRequest {
        id: "probe".to_string(),
        model: "mlp".to_string(),
        mesh: "model=4".to_string(),
        budget: 60,
        seed: 7,
        workers: 1,
        ..Default::default()
    };
    let primed = svc.handle(&req);
    if let Some(e) = primed.error {
        anyhow::bail!("cache priming failed: {e}");
    }
    let mut samples: Vec<f64> = Vec::with_capacity(cfg.cache_probes.max(1));
    for _ in 0..cfg.cache_probes.max(1) {
        let t0 = Instant::now();
        let r = svc.handle(&req);
        let dt = t0.elapsed().as_nanos() as f64;
        assert!(r.cached, "probe request must be a cache hit");
        samples.push(dt);
    }
    let cache_hit_median_ns = median(samples);

    Ok(ThroughputReport {
        budget: cfg.budget,
        workers: cfg.workers,
        single_episodes_per_sec: single.episodes_per_sec,
        multi_episodes_per_sec: multi.episodes_per_sec,
        speedup: multi.episodes_per_sec / single.episodes_per_sec.max(1e-9),
        single_evals_per_sec: single.evals_per_sec,
        multi_evals_per_sec: multi.evals_per_sec,
        cache_hit_median_ns,
        cache_probes: cfg.cache_probes,
        step_median_ns,
        eval_median_ns,
        eval_full_median_ns,
        eval_ledger_speedup: eval_full_median_ns / eval_median_ns.max(1e-9),
        eval_memo_hit_rate: multi.memo_hit_rate,
        ledger_reuse_rate: multi.ledger_reuse_rate,
        schedule_sim_median_ns: schedule_sim_timing(cfg.micro_samples),
        parse_median_ns,
        decode_median_ns,
        binary_load_speedup: parse_median_ns / decode_median_ns.max(1e-9),
        rounds: multi.rounds,
        steals: multi.steals,
        baseline_single_episodes_per_sec: load_baseline(),
        provenance: bench_provenance(),
    })
}

impl ThroughputReport {
    pub fn to_json(&self) -> Json {
        let mut fields = vec![
            ("bench", Json::str("search_throughput")),
            ("budget_per_worker", Json::num(self.budget as f64)),
            ("workers", Json::num(self.workers as f64)),
            ("single_episodes_per_sec", Json::Num(self.single_episodes_per_sec)),
            ("multi_episodes_per_sec", Json::Num(self.multi_episodes_per_sec)),
            ("speedup", Json::Num(self.speedup)),
            ("single_evals_per_sec", Json::Num(self.single_evals_per_sec)),
            ("multi_evals_per_sec", Json::Num(self.multi_evals_per_sec)),
            ("cache_hit_median_ns", Json::Num(self.cache_hit_median_ns)),
            ("cache_probes", Json::num(self.cache_probes as f64)),
            ("step_median_ns", Json::Num(self.step_median_ns)),
            ("eval_median_ns", Json::Num(self.eval_median_ns)),
            ("eval_full_median_ns", Json::Num(self.eval_full_median_ns)),
            ("eval_ledger_speedup", Json::Num(self.eval_ledger_speedup)),
            ("eval_memo_hit_rate", Json::Num(self.eval_memo_hit_rate)),
            ("ledger_reuse_rate", Json::Num(self.ledger_reuse_rate)),
            ("schedule_sim_median_ns", Json::Num(self.schedule_sim_median_ns)),
            ("parse_median_ns", Json::Num(self.parse_median_ns)),
            ("decode_median_ns", Json::Num(self.decode_median_ns)),
            ("binary_load_speedup", Json::Num(self.binary_load_speedup)),
            ("rounds", Json::num(self.rounds as f64)),
            ("steals", Json::num(self.steals as f64)),
            // Debug builds run the per-step incremental-vs-full
            // cross-check inside env.step, so their step/eps numbers are
            // NOT comparable to release ones — readers (and the CI floor
            // check) must key off this flag.
            ("debug_build", Json::Bool(cfg!(debug_assertions))),
            ("provenance", Json::str(self.provenance.clone())),
        ];
        if let Some(b) = self.baseline_single_episodes_per_sec {
            fields.push(("baseline_single_episodes_per_sec", Json::Num(b)));
            fields.push((
                "improvement_over_baseline",
                Json::Num(self.single_episodes_per_sec / b.max(1e-9)),
            ));
        }
        Json::obj(fields)
    }

    pub fn describe(&self) -> String {
        format!(
            "single {:.0} eps/s ({:.0} evals/s) | {} workers {:.0} eps/s ({:.2}x, {} rounds, \
             {} steals) | step {:.1}us eval ledger {:.1}us vs full {:.1}us ({:.2}x) | \
             memo {:.0}% hit, ledger {:.0}% reuse | schedule sim {:.2}us | \
             cold load parse {:.1}us vs pallas-bin {:.1}us ({:.2}x) | \
             cache hit median {:.1}us",
            self.single_episodes_per_sec,
            self.single_evals_per_sec,
            self.workers,
            self.multi_episodes_per_sec,
            self.speedup,
            self.rounds,
            self.steals,
            self.step_median_ns / 1e3,
            self.eval_median_ns / 1e3,
            self.eval_full_median_ns / 1e3,
            self.eval_ledger_speedup,
            100.0 * self.eval_memo_hit_rate,
            100.0 * self.ledger_reuse_rate,
            self.schedule_sim_median_ns / 1e3,
            self.parse_median_ns / 1e3,
            self.decode_median_ns / 1e3,
            self.binary_load_speedup,
            self.cache_hit_median_ns / 1e3
        )
    }
}

/// Write the report to `BENCH_search.json` at the repo root (one level
/// above the crate manifest), returning the path written.
pub fn write_report(report: &ThroughputReport) -> Result<std::path::PathBuf> {
    let path = repo_root()?.join("BENCH_search.json");
    std::fs::write(&path, report.to_json().pretty()).context("writing BENCH_search.json")?;
    Ok(path)
}
