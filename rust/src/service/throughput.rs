//! Search-throughput measurement shared by `benches/search_throughput.rs`
//! and the tier-1 smoke test, so `BENCH_search.json` at the repo root is
//! produced by whichever ran last with the same schema.
//!
//! Numbers that matter for the service (DESIGN.md §8/§9):
//!   * root-parallel scaling — episodes/sec with `K` workers vs one;
//!   * eval-pipeline timings — median ns of one env step (incremental
//!     propagation) and one terminal evaluation (infer-rest + lower +
//!     liveness + roofline), the two per-episode building blocks;
//!   * cache-hit latency — how fast a repeat request is served;
//!   * the work-stealing schedule the multi-worker run settled on.
//!
//! When `configs/perf_floor.json` is present its recorded baseline is
//! copied into the report, so the JSON carries both the pre-overhaul
//! number and the current one — the perf trajectory in one document.

use super::executor::PlanJob;
use super::request::{JobDefaults, PartitionRequest};
use super::server::{PlanService, ServiceConfig};
use crate::cost::composite::CostWeights;
use crate::partir::mesh::Mesh;
use crate::partir::program::PartirProgram;
use crate::search::env::{EnvAction, RewriteEnv, SearchOptions};
use crate::sim::device::Device;
use crate::util::json::Json;
use anyhow::{anyhow, Context, Result};
use std::hint::black_box;
use std::time::Instant;

/// Measurement configuration.
#[derive(Debug, Clone)]
pub struct ThroughputConfig {
    /// Episodes per worker per run.
    pub budget: usize,
    /// Multi-worker fan-out `K`.
    pub workers: usize,
    /// Timed repetitions per variant (best run wins, to shed scheduler
    /// noise).
    pub reps: usize,
    /// Repeat requests timed against the cache.
    pub cache_probes: usize,
    /// Samples for the per-step / per-eval micro timings.
    pub micro_samples: usize,
}

impl ThroughputConfig {
    /// Quick profile for the tier-1 smoke test (a few seconds).
    pub fn quick() -> ThroughputConfig {
        ThroughputConfig { budget: 800, workers: 4, reps: 3, cache_probes: 50, micro_samples: 64 }
    }

    /// Fuller profile for `cargo bench`.
    pub fn full() -> ThroughputConfig {
        ThroughputConfig {
            budget: 2000,
            workers: 4,
            reps: 5,
            cache_probes: 500,
            micro_samples: 256,
        }
    }
}

/// Measured throughput numbers.
#[derive(Debug, Clone)]
pub struct ThroughputReport {
    pub budget: usize,
    pub workers: usize,
    pub single_episodes_per_sec: f64,
    pub multi_episodes_per_sec: f64,
    /// `multi / single` episodes-per-second ratio.
    pub speedup: f64,
    pub cache_hit_median_ns: f64,
    pub cache_probes: usize,
    /// Median ns of one tile step (incremental propagation included).
    pub step_median_ns: f64,
    /// Median ns of one terminal evaluation (full cost pipeline).
    pub eval_median_ns: f64,
    /// Barrier rounds / steal events of the best multi-worker run.
    pub rounds: usize,
    pub steals: usize,
    /// Pre-overhaul episodes/sec recorded in `configs/perf_floor.json`
    /// (absent when the file is missing or unreadable).
    pub baseline_single_episodes_per_sec: Option<f64>,
}

fn bench_job(workers: usize, budget: usize) -> PlanJob {
    // The standard request the service benchmarks against: a small
    // transformer, heavy enough that propagation dominates thread
    // bookkeeping.
    let req = PartitionRequest {
        id: "bench".to_string(),
        model: "transformer".to_string(),
        layers: 2,
        mesh: "model=4".to_string(),
        budget,
        seed: 42,
        workers,
        ..Default::default()
    };
    req.build_job(&JobDefaults::default()).expect("bench request is well-formed")
}

/// Best-of-`reps` episodes/sec for a `workers`-way executor run, plus
/// the (deterministic) round/steal schedule it ran.
fn episodes_per_sec(workers: usize, budget: usize, reps: usize) -> Result<(f64, usize, usize)> {
    let job = bench_job(workers, budget);
    let mut best = 0.0f64;
    let mut rounds = 0usize;
    let mut steals = 0usize;
    for _ in 0..reps.max(1) {
        let report = job.run()?;
        let eps = report.episodes_total as f64 / report.wall_seconds.max(1e-9);
        if eps > best {
            best = eps;
            rounds = report.rounds;
            steals = report.steals;
        }
    }
    Ok((best, rounds, steals))
}

fn median(mut samples: Vec<f64>) -> f64 {
    samples.sort_by(|a, b| a.partial_cmp(b).expect("no NaN timings"));
    samples[samples.len() / 2]
}

/// Median ns of one env tile step and one terminal evaluation on the
/// bench program (tiny transformer, `model=4`).
fn micro_timings(samples: usize) -> Result<(f64, f64)> {
    let func = crate::models::build_by_name("transformer", 2).context("builtin transformer")?;
    let program = PartirProgram::new(func, Mesh::parse("model=4").map_err(|e| anyhow!("{e}"))?);
    let wl = RewriteEnv::default_worklist(&program);
    let env = RewriteEnv::new(
        &program,
        Device::tpu_v3(),
        CostWeights::default(),
        SearchOptions::default(),
        &wl,
    );
    let root = env.reset();
    let tile = env
        .legal_actions(&root)
        .into_iter()
        .find(|a| matches!(a, EnvAction::Tile { .. }))
        .context("bench program must offer a tile action")?;
    let n = samples.max(8);
    let mut step_samples = Vec::with_capacity(n);
    let mut ep = root.clone();
    for _ in 0..n {
        ep.clone_from(&root);
        let t0 = Instant::now();
        env.step(&mut ep, tile);
        step_samples.push(t0.elapsed().as_nanos() as f64);
        black_box(ep.decisions);
    }
    // Terminal evaluation on the stepped episode (uncached path).
    env.step(&mut ep, EnvAction::Stop);
    let mut eval_samples = Vec::with_capacity(n);
    for _ in 0..n {
        let t0 = Instant::now();
        let eval = env.evaluate_episode(&ep);
        eval_samples.push(t0.elapsed().as_nanos() as f64);
        black_box(eval.cost);
    }
    Ok((median(step_samples), median(eval_samples)))
}

/// Repo root (one level above the crate manifest).
fn repo_root() -> Result<std::path::PathBuf> {
    Ok(std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
        .parent()
        .context("crate dir has a parent")?
        .to_path_buf())
}

/// The pre-overhaul baseline recorded next to the advisory floor, if
/// the config exists.
fn load_baseline() -> Option<f64> {
    let path = repo_root().ok()?.join("configs/perf_floor.json");
    let text = std::fs::read_to_string(path).ok()?;
    let j = crate::util::json::parse(&text).ok()?;
    j.get("baseline_single_episodes_per_sec")?.as_f64()
}

/// Run the full measurement.
pub fn measure(cfg: &ThroughputConfig) -> Result<ThroughputReport> {
    let (single, _, _) = episodes_per_sec(1, cfg.budget, cfg.reps)?;
    let (multi, rounds, steals) = episodes_per_sec(cfg.workers, cfg.budget, cfg.reps)?;
    let (step_median_ns, eval_median_ns) = micro_timings(cfg.micro_samples)?;

    // Cache-hit latency: prime the service with one search, then time
    // repeat requests (all hits).
    let svc = PlanService::new(ServiceConfig::default());
    let req = PartitionRequest {
        id: "probe".to_string(),
        model: "mlp".to_string(),
        mesh: "model=4".to_string(),
        budget: 60,
        seed: 7,
        workers: 1,
        ..Default::default()
    };
    let primed = svc.handle(&req);
    if let Some(e) = primed.error {
        anyhow::bail!("cache priming failed: {e}");
    }
    let mut samples: Vec<f64> = Vec::with_capacity(cfg.cache_probes.max(1));
    for _ in 0..cfg.cache_probes.max(1) {
        let t0 = Instant::now();
        let r = svc.handle(&req);
        let dt = t0.elapsed().as_nanos() as f64;
        assert!(r.cached, "probe request must be a cache hit");
        samples.push(dt);
    }
    let cache_hit_median_ns = median(samples);

    Ok(ThroughputReport {
        budget: cfg.budget,
        workers: cfg.workers,
        single_episodes_per_sec: single,
        multi_episodes_per_sec: multi,
        speedup: multi / single.max(1e-9),
        cache_hit_median_ns,
        cache_probes: cfg.cache_probes,
        step_median_ns,
        eval_median_ns,
        rounds,
        steals,
        baseline_single_episodes_per_sec: load_baseline(),
    })
}

impl ThroughputReport {
    pub fn to_json(&self) -> Json {
        let mut fields = vec![
            ("bench", Json::str("search_throughput")),
            ("budget_per_worker", Json::num(self.budget as f64)),
            ("workers", Json::num(self.workers as f64)),
            ("single_episodes_per_sec", Json::Num(self.single_episodes_per_sec)),
            ("multi_episodes_per_sec", Json::Num(self.multi_episodes_per_sec)),
            ("speedup", Json::Num(self.speedup)),
            ("cache_hit_median_ns", Json::Num(self.cache_hit_median_ns)),
            ("cache_probes", Json::num(self.cache_probes as f64)),
            ("step_median_ns", Json::Num(self.step_median_ns)),
            ("eval_median_ns", Json::Num(self.eval_median_ns)),
            ("rounds", Json::num(self.rounds as f64)),
            ("steals", Json::num(self.steals as f64)),
            // Debug builds run the per-step incremental-vs-full
            // cross-check inside env.step, so their step/eps numbers are
            // NOT comparable to release ones — readers (and the CI floor
            // check) must key off this flag.
            ("debug_build", Json::Bool(cfg!(debug_assertions))),
        ];
        if let Some(b) = self.baseline_single_episodes_per_sec {
            fields.push(("baseline_single_episodes_per_sec", Json::Num(b)));
            fields.push((
                "improvement_over_baseline",
                Json::Num(self.single_episodes_per_sec / b.max(1e-9)),
            ));
        }
        Json::obj(fields)
    }

    pub fn describe(&self) -> String {
        format!(
            "single {:.0} eps/s | {} workers {:.0} eps/s ({:.2}x, {} rounds, {} steals) | \
             step {:.1}us eval {:.1}us | cache hit median {:.1}us",
            self.single_episodes_per_sec,
            self.workers,
            self.multi_episodes_per_sec,
            self.speedup,
            self.rounds,
            self.steals,
            self.step_median_ns / 1e3,
            self.eval_median_ns / 1e3,
            self.cache_hit_median_ns / 1e3
        )
    }
}

/// Write the report to `BENCH_search.json` at the repo root (one level
/// above the crate manifest), returning the path written.
pub fn write_report(report: &ThroughputReport) -> Result<std::path::PathBuf> {
    let path = repo_root()?.join("BENCH_search.json");
    std::fs::write(&path, report.to_json().pretty()).context("writing BENCH_search.json")?;
    Ok(path)
}
