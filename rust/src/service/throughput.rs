//! Search-throughput measurement shared by `benches/search_throughput.rs`
//! and the tier-1 smoke test, so `BENCH_search.json` at the repo root is
//! produced by whichever ran last with the same schema.
//!
//! Two numbers matter for the service (DESIGN.md §9):
//!   * root-parallel scaling — episodes/sec with `K` workers vs one;
//!   * cache-hit latency — how fast a repeat request is served.

use super::executor::PlanJob;
use super::request::{JobDefaults, PartitionRequest};
use super::server::{PlanService, ServiceConfig};
use crate::util::json::Json;
use anyhow::{Context, Result};
use std::time::Instant;

/// Measurement configuration.
#[derive(Debug, Clone)]
pub struct ThroughputConfig {
    /// Episodes per worker per run.
    pub budget: usize,
    /// Multi-worker fan-out `K`.
    pub workers: usize,
    /// Timed repetitions per variant (best run wins, to shed scheduler
    /// noise).
    pub reps: usize,
    /// Repeat requests timed against the cache.
    pub cache_probes: usize,
}

impl ThroughputConfig {
    /// Quick profile for the tier-1 smoke test (a few seconds).
    pub fn quick() -> ThroughputConfig {
        ThroughputConfig { budget: 800, workers: 4, reps: 3, cache_probes: 50 }
    }

    /// Fuller profile for `cargo bench`.
    pub fn full() -> ThroughputConfig {
        ThroughputConfig { budget: 2000, workers: 4, reps: 5, cache_probes: 500 }
    }
}

/// Measured throughput numbers.
#[derive(Debug, Clone)]
pub struct ThroughputReport {
    pub budget: usize,
    pub workers: usize,
    pub single_episodes_per_sec: f64,
    pub multi_episodes_per_sec: f64,
    /// `multi / single` episodes-per-second ratio.
    pub speedup: f64,
    pub cache_hit_median_ns: f64,
    pub cache_probes: usize,
}

fn bench_job(workers: usize, budget: usize) -> PlanJob {
    // The standard request the service benchmarks against: a small
    // transformer, heavy enough that propagation dominates thread
    // bookkeeping.
    let req = PartitionRequest {
        id: "bench".to_string(),
        model: "transformer".to_string(),
        layers: 2,
        mesh: "model=4".to_string(),
        budget,
        seed: 42,
        workers,
        ..Default::default()
    };
    req.build_job(&JobDefaults::default()).expect("bench request is well-formed")
}

/// Best-of-`reps` episodes/sec for a `workers`-way executor run.
fn episodes_per_sec(workers: usize, budget: usize, reps: usize) -> Result<f64> {
    let job = bench_job(workers, budget);
    let mut best = 0.0f64;
    for _ in 0..reps.max(1) {
        let report = job.run()?;
        let eps = report.episodes_total as f64 / report.wall_seconds.max(1e-9);
        best = best.max(eps);
    }
    Ok(best)
}

/// Run the full measurement.
pub fn measure(cfg: &ThroughputConfig) -> Result<ThroughputReport> {
    let single = episodes_per_sec(1, cfg.budget, cfg.reps)?;
    let multi = episodes_per_sec(cfg.workers, cfg.budget, cfg.reps)?;

    // Cache-hit latency: prime the service with one search, then time
    // repeat requests (all hits).
    let svc = PlanService::new(ServiceConfig::default());
    let req = PartitionRequest {
        id: "probe".to_string(),
        model: "mlp".to_string(),
        mesh: "model=4".to_string(),
        budget: 60,
        seed: 7,
        workers: 1,
        ..Default::default()
    };
    let primed = svc.handle(&req);
    if let Some(e) = primed.error {
        anyhow::bail!("cache priming failed: {e}");
    }
    let mut samples: Vec<f64> = Vec::with_capacity(cfg.cache_probes.max(1));
    for _ in 0..cfg.cache_probes.max(1) {
        let t0 = Instant::now();
        let r = svc.handle(&req);
        let dt = t0.elapsed().as_nanos() as f64;
        assert!(r.cached, "probe request must be a cache hit");
        samples.push(dt);
    }
    samples.sort_by(|a, b| a.partial_cmp(b).expect("no NaN timings"));
    let cache_hit_median_ns = samples[samples.len() / 2];

    Ok(ThroughputReport {
        budget: cfg.budget,
        workers: cfg.workers,
        single_episodes_per_sec: single,
        multi_episodes_per_sec: multi,
        speedup: multi / single.max(1e-9),
        cache_hit_median_ns,
        cache_probes: cfg.cache_probes,
    })
}

impl ThroughputReport {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("bench", Json::str("search_throughput")),
            ("budget_per_worker", Json::num(self.budget as f64)),
            ("workers", Json::num(self.workers as f64)),
            ("single_episodes_per_sec", Json::Num(self.single_episodes_per_sec)),
            ("multi_episodes_per_sec", Json::Num(self.multi_episodes_per_sec)),
            ("speedup", Json::Num(self.speedup)),
            ("cache_hit_median_ns", Json::Num(self.cache_hit_median_ns)),
            ("cache_probes", Json::num(self.cache_probes as f64)),
        ])
    }

    pub fn describe(&self) -> String {
        format!(
            "single {:.0} eps/s | {} workers {:.0} eps/s ({:.2}x) | cache hit median {:.1}us",
            self.single_episodes_per_sec,
            self.workers,
            self.multi_episodes_per_sec,
            self.speedup,
            self.cache_hit_median_ns / 1e3
        )
    }
}

/// Write the report to `BENCH_search.json` at the repo root (one level
/// above the crate manifest), returning the path written.
pub fn write_report(report: &ThroughputReport) -> Result<std::path::PathBuf> {
    let path = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
        .parent()
        .context("crate dir has a parent")?
        .join("BENCH_search.json");
    std::fs::write(&path, report.to_json().pretty()).context("writing BENCH_search.json")?;
    Ok(path)
}
