//! Concurrent serving front-end (DESIGN.md §9): the [`PlanService`]
//! answers partition requests through the fingerprint cache, deduplicates
//! identical in-flight searches, and drains batches through a bounded
//! work queue over a thread pool.
//!
//! Request lifecycle:
//!
//! 1. resolve the request into a [`PlanJob`](super::executor::PlanJob)
//!    and fingerprint it;
//! 2. probe the plan cache — a hit is served immediately; on a memory
//!    miss, probe the persistent disk tier (when configured) and promote
//!    a hit into the memory tier (probe order memory → disk → search);
//! 3. probe the in-flight table — if an identical search is already
//!    running, wait for its result instead of starting another
//!    (two concurrent duplicate requests run ONE search);
//! 4. otherwise become the leader: run the root-parallel executor,
//!    publish the plan to the cache, wake all waiters.
//!
//! The leader publishes to the cache *before* clearing the in-flight
//! entry, and would-be leaders re-probe the cache while holding the
//! in-flight lock, so a fingerprint can never run two searches — the
//! `searches` counter is exact, which the batch acceptance test pins.
//!
//! Under failure the service degrades rather than errors (DESIGN.md
//! §14): deadline-hit and panic-salvaged plans come back marked
//! `degraded` and are NEVER cached, and when the pending queue is full
//! new arrivals are shed with a cached-or-fallback response instead of
//! blocking the intake thread behind slow searches.

use super::cache::{CacheStats, PlanCache};
use super::persist::{DiskTier, DiskTierStats};
use super::request::{JobDefaults, PartitionRequest, PlanResponse, SearchStats};
use anyhow::Result;
use crate::obs::metrics::{metrics, names, register_service_metrics, Histogram};
use crate::obs::metrics::{Counter, Gauge, HistogramSnapshot};
use crate::obs::recorder::recorder;
use crate::obs::telemetry::{telemetry, RequestTelemetry, RoundSample};
use std::collections::{HashMap, VecDeque};
use std::io::{BufRead, Write};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex};

/// Result slot one in-flight search publishes to its waiters: the plan
/// JSON plus its degraded marker, so a waiter that joined a search
/// which later hit its deadline relays the degradation honestly.
struct Inflight {
    slot: Mutex<Option<Result<(String, Option<String>), String>>>,
    cv: Condvar,
}

impl Inflight {
    fn new() -> Inflight {
        Inflight { slot: Mutex::new(None), cv: Condvar::new() }
    }

    fn publish(&self, r: Result<(String, Option<String>), String>) {
        *self.slot.lock().expect("inflight slot poisoned") = Some(r);
        self.cv.notify_all();
    }

    fn wait(&self) -> Result<(String, Option<String>), String> {
        let mut g = self.slot.lock().expect("inflight slot poisoned");
        while g.is_none() {
            g = self.cv.wait(g).expect("inflight slot poisoned");
        }
        g.clone().expect("checked Some")
    }
}

/// Service configuration.
#[derive(Clone)]
pub struct ServiceConfig {
    pub defaults: JobDefaults,
    /// Lock stripes in the plan cache.
    pub cache_shards: usize,
    /// Total cache byte budget across all shards.
    pub cache_bytes: usize,
    /// Directory for the persistent plan-cache log (`plans.plog`,
    /// DESIGN.md §13). `None` disables the disk tier.
    pub persist_path: Option<std::path::PathBuf>,
    /// Admission-control bound on the `serve_jsonl` pending queue;
    /// arrivals beyond it are shed with a cached-or-fallback response
    /// marked `degraded:"shed"`. `0` means `2 * pool` (the
    /// pre-admission-control default).
    pub max_pending: usize,
    /// Failpoint spec (`"name=prob[@seed],..."`, see
    /// [`crate::util::failpoints`]) armed at service construction — the
    /// programmatic twin of the `PALLAS_FAILPOINTS` environment
    /// variable. Arms the process-global registry.
    pub failpoints: Option<String>,
    /// Shared "mailbox" directory for replica anti-entropy (DESIGN.md
    /// §15). `None` disables sync; setting it requires `persist_path`
    /// (the sync protocol replicates the persistent tier).
    pub sync_dir: Option<std::path::PathBuf>,
    /// Seconds between background anti-entropy rounds while serving.
    /// `0` disables the ticker (the one-shot `automap sync` subcommand
    /// still works against the same sync dir).
    pub sync_interval_secs: u64,
    /// Replica name for this process's snapshot in the sync dir.
    /// `None` derives `replica-<pid>`.
    pub replica: Option<String>,
}

impl Default for ServiceConfig {
    fn default() -> Self {
        ServiceConfig {
            defaults: JobDefaults::default(),
            cache_shards: 8,
            cache_bytes: 64 << 20,
            persist_path: None,
            max_pending: 0,
            failpoints: None,
            sync_dir: None,
            sync_interval_secs: 0,
            replica: None,
        }
    }
}

/// Cached handles into the process-global metrics registry
/// (`obs::metrics`), resolved once at service construction so the
/// request path never touches the registry lock.
struct ServiceMetrics {
    requests: Arc<Counter>,
    errors: Arc<Counter>,
    cache_hits: Arc<Counter>,
    cache_misses: Arc<Counter>,
    dedup_served: Arc<Counter>,
    searches: Arc<Counter>,
    episodes: Arc<Counter>,
    rounds: Arc<Counter>,
    steals: Arc<Counter>,
    eval_lookups: Arc<Counter>,
    eval_memo_hits: Arc<Counter>,
    ledger_refreshes: Arc<Counter>,
    ledger_nodes_reused: Arc<Counter>,
    ledger_nodes_recomputed: Arc<Counter>,
    pipelined: Arc<Counter>,
    deadline_hits: Arc<Counter>,
    shed: Arc<Counter>,
    fallback_plans: Arc<Counter>,
    worker_panics: Arc<Counter>,
    inflight_searches: Arc<Gauge>,
    queue_depth: Arc<Gauge>,
    request_latency: Arc<Histogram>,
    search_run: Arc<Histogram>,
}

impl ServiceMetrics {
    fn new() -> ServiceMetrics {
        register_service_metrics();
        let m = metrics();
        ServiceMetrics {
            requests: m.counter(names::SERVICE_REQUESTS),
            errors: m.counter(names::SERVICE_ERRORS),
            cache_hits: m.counter(names::SERVICE_CACHE_HITS),
            cache_misses: m.counter(names::SERVICE_CACHE_MISSES),
            dedup_served: m.counter(names::SERVICE_DEDUP_SERVED),
            searches: m.counter(names::SERVICE_SEARCHES),
            episodes: m.counter(names::SEARCH_EPISODES),
            rounds: m.counter(names::SEARCH_ROUNDS),
            steals: m.counter(names::SEARCH_STEALS),
            eval_lookups: m.counter(names::EVAL_LOOKUPS),
            eval_memo_hits: m.counter(names::EVAL_MEMO_HITS),
            ledger_refreshes: m.counter(names::LEDGER_REFRESHES),
            ledger_nodes_reused: m.counter(names::LEDGER_NODES_REUSED),
            ledger_nodes_recomputed: m.counter(names::LEDGER_NODES_RECOMPUTED),
            pipelined: m.counter(names::PIPELINE_SEARCHES),
            deadline_hits: m.counter(names::SERVICE_DEADLINE_HITS),
            shed: m.counter(names::SERVICE_SHED),
            fallback_plans: m.counter(names::SERVICE_FALLBACK_PLANS),
            worker_panics: m.counter(names::SEARCH_WORKER_PANICS),
            inflight_searches: m.gauge(names::SERVICE_INFLIGHT_SEARCHES),
            queue_depth: m.gauge(names::SERVICE_QUEUE_DEPTH),
            request_latency: m.histogram(names::SERVICE_REQUEST_LATENCY_NS),
            search_run: m.histogram(names::SEARCH_RUN_NS),
        }
    }
}

/// The partition-plan service: cache + in-flight dedup + executor.
/// Shared by reference across front-end threads.
pub struct PlanService {
    pub cache: PlanCache,
    /// Persistent tier under the LRU (probe order memory → disk →
    /// search); `None` when the service runs memory-only.
    disk: Option<DiskTier>,
    defaults: JobDefaults,
    inflight: Mutex<HashMap<u64, Arc<Inflight>>>,
    searches: AtomicU64,
    dedup_served: AtomicU64,
    // Metrics handles plus a SERVICE-OWNED end-to-end latency histogram:
    // run summaries diff snapshots of the owned histogram, so parallel
    // tests sharing the process-global registry cannot pollute a run's
    // percentiles (the global `service.request_latency_ns` is still
    // double-recorded for `--metrics-out` snapshots).
    mx: ServiceMetrics,
    latency: Histogram,
    // Search-cache effectiveness aggregates across every search this
    // service ran (mirrors the per-response `search` stats object).
    eval_lookups: AtomicU64,
    eval_memo_hits: AtomicU64,
    ledger_nodes_reused: AtomicU64,
    ledger_nodes_recomputed: AtomicU64,
    // Pipeline-parallel observability: searches whose winning plan ran a
    // `Pipeline` tactic, and their summed 1F1B bubble fractions in
    // microunits (1e-6; integer so it can live in an atomic).
    pipelined_searches: AtomicU64,
    bubble_micros: AtomicU64,
    // Degraded-mode accounting (DESIGN.md §14): deadline-hit anytime
    // plans, shed requests, poisoned search workers, and searches (or
    // sheds) answered with the search-free fallback plan.
    deadline_hits: AtomicU64,
    shed: AtomicU64,
    worker_panics: AtomicU64,
    fallback_plans: AtomicU64,
    // Replica anti-entropy (DESIGN.md §15): mailbox dir, ticker period,
    // this replica's snapshot name, and per-service round accounting.
    sync_dir: Option<std::path::PathBuf>,
    sync_interval_secs: u64,
    replica: String,
    sync_rounds: AtomicU64,
    sync_records_pulled: AtomicU64,
    sync_frames_quarantined: AtomicU64,
}

impl PlanService {
    /// Infallible constructor for memory-only configs (the common case in
    /// tests and embedding). Panics only if `persist_path` is set and the
    /// cache log cannot be opened — use [`PlanService::try_new`] to
    /// handle that.
    pub fn new(cfg: ServiceConfig) -> PlanService {
        Self::try_new(cfg).expect("opening persistent plan-cache tier")
    }

    /// Construct the service, opening the persistent tier when
    /// `persist_path` is configured.
    pub fn try_new(cfg: ServiceConfig) -> Result<PlanService> {
        if let Some(spec) = &cfg.failpoints {
            crate::util::failpoints::failpoints().arm_spec(spec)?;
        }
        let disk = match &cfg.persist_path {
            Some(dir) => Some(DiskTier::open(dir)?),
            None => None,
        };
        if cfg.sync_dir.is_some() && disk.is_none() {
            anyhow::bail!("replica sync replicates the persistent tier: --sync-dir requires --cache-dir");
        }
        let replica = cfg
            .replica
            .clone()
            .unwrap_or_else(|| format!("replica-{}", std::process::id()));
        Ok(PlanService {
            cache: PlanCache::new(cfg.cache_shards, cfg.cache_bytes),
            disk,
            defaults: cfg.defaults,
            inflight: Mutex::new(HashMap::new()),
            searches: AtomicU64::new(0),
            dedup_served: AtomicU64::new(0),
            eval_lookups: AtomicU64::new(0),
            eval_memo_hits: AtomicU64::new(0),
            ledger_nodes_reused: AtomicU64::new(0),
            ledger_nodes_recomputed: AtomicU64::new(0),
            pipelined_searches: AtomicU64::new(0),
            bubble_micros: AtomicU64::new(0),
            deadline_hits: AtomicU64::new(0),
            shed: AtomicU64::new(0),
            worker_panics: AtomicU64::new(0),
            fallback_plans: AtomicU64::new(0),
            sync_dir: cfg.sync_dir,
            sync_interval_secs: cfg.sync_interval_secs,
            replica,
            sync_rounds: AtomicU64::new(0),
            sync_records_pulled: AtomicU64::new(0),
            sync_frames_quarantined: AtomicU64::new(0),
            mx: ServiceMetrics::new(),
            latency: Histogram::new(),
        })
    }

    /// Whether a sync mailbox dir is configured (`--sync-dir`).
    pub fn sync_configured(&self) -> bool {
        self.sync_dir.is_some()
    }

    /// Background sync ticker period in seconds (`0` = no ticker).
    pub fn sync_interval_secs(&self) -> u64 {
        self.sync_interval_secs
    }

    /// This replica's snapshot name in the sync dir.
    pub fn replica_name(&self) -> &str {
        &self.replica
    }

    /// Run ONE anti-entropy round against the configured sync dir
    /// (DESIGN.md §15): canonicalize the local log, publish a snapshot,
    /// pull missing/superseded records from every peer snapshot, land
    /// the merge via canonical compaction. Pulled plans become visible
    /// to requests through the normal memory → disk probe order.
    pub fn sync_once(&self) -> Result<super::sync::SyncReport> {
        let disk = self.disk.as_ref().ok_or_else(|| {
            anyhow::anyhow!("replica sync requires a persistent tier (--cache-dir)")
        })?;
        let dir = self
            .sync_dir
            .as_ref()
            .ok_or_else(|| anyhow::anyhow!("replica sync requires --sync-dir"))?;
        let transport = super::sync::MailboxTransport::new(dir)?;
        let report = super::sync::sync_once(&self.replica, disk, &transport)?;
        self.sync_rounds.fetch_add(1, Ordering::Relaxed);
        self.sync_records_pulled.fetch_add(report.records_pulled, Ordering::Relaxed);
        self.sync_frames_quarantined
            .fetch_add(report.frames_quarantined, Ordering::Relaxed);
        Ok(report)
    }

    /// Replica-sync counters for this service: (rounds run, records
    /// pulled, frames quarantined).
    pub fn sync_counters(&self) -> (u64, u64, u64) {
        (
            self.sync_rounds.load(Ordering::Relaxed),
            self.sync_records_pulled.load(Ordering::Relaxed),
            self.sync_frames_quarantined.load(Ordering::Relaxed),
        )
    }

    /// Requests served from the persistent tier (0 when disabled).
    pub fn disk_hits(&self) -> u64 {
        self.disk.as_ref().map_or(0, |d| d.stats().hits)
    }

    /// Counters and sizes of the persistent tier, if one is attached.
    pub fn disk_stats(&self) -> Option<DiskTierStats> {
        self.disk.as_ref().map(|d| d.stats())
    }

    /// Snapshot of this service's end-to-end request latency histogram
    /// (nanoseconds). Run summaries diff two snapshots for run-scoped
    /// percentiles.
    pub fn latency_snapshot(&self) -> HistogramSnapshot {
        self.latency.snapshot()
    }

    /// Searches actually executed (exact: dedup + double-check make
    /// duplicate fingerprints share one run).
    pub fn searches_run(&self) -> u64 {
        self.searches.load(Ordering::Relaxed)
    }

    /// Requests served by waiting on another request's in-flight search.
    pub fn dedup_served(&self) -> u64 {
        self.dedup_served.load(Ordering::Relaxed)
    }

    /// Requests served without running a search (plan-cache hits plus
    /// in-flight dedup waits).
    pub fn served_without_search(&self) -> u64 {
        self.cache.stats().hits + self.dedup_served()
    }

    pub fn cache_stats(&self) -> CacheStats {
        self.cache.stats()
    }

    /// Aggregate search-cache counters over every search this service
    /// ran: (eval lookups, memo hits, ledger nodes reused, recomputed).
    pub fn search_cache_counters(&self) -> (u64, u64, u64, u64) {
        (
            self.eval_lookups.load(Ordering::Relaxed),
            self.eval_memo_hits.load(Ordering::Relaxed),
            self.ledger_nodes_reused.load(Ordering::Relaxed),
            self.ledger_nodes_recomputed.load(Ordering::Relaxed),
        )
    }

    /// Pipeline-parallel counters: (searches whose winning plan was
    /// pipelined, summed bubble fractions in microunits).
    pub fn pipelined_counters(&self) -> (u64, u64) {
        (
            self.pipelined_searches.load(Ordering::Relaxed),
            self.bubble_micros.load(Ordering::Relaxed),
        )
    }

    /// Degraded-mode counters (DESIGN.md §14): (deadline hits, shed
    /// requests, worker panics, fallback plans).
    pub fn degraded_counters(&self) -> (u64, u64, u64, u64) {
        (
            self.deadline_hits.load(Ordering::Relaxed),
            self.shed.load(Ordering::Relaxed),
            self.worker_panics.load(Ordering::Relaxed),
            self.fallback_plans.load(Ordering::Relaxed),
        )
    }

    /// Handle one parsed request end to end, wrapping the core lifecycle
    /// in a `service.request` trace span and recording latency, metrics,
    /// and per-request telemetry on every path.
    pub fn handle(&self, req: &PartitionRequest) -> PlanResponse {
        let rec = recorder();
        let trace_id = if rec.enabled() { rec.new_request_id() } else { 0 };
        let span = rec.span("service.request", "service", trace_id);
        let t0 = std::time::Instant::now();
        let (resp, timeline) = self.handle_inner(req, trace_id);
        let latency_ns = t0.elapsed().as_nanos() as u64;
        drop(span);
        self.latency.record(latency_ns);
        self.mx.request_latency.record(latency_ns);
        self.mx.requests.add(1);
        if resp.error.is_some() {
            self.mx.errors.add(1);
        }
        telemetry().record(RequestTelemetry {
            id: resp.id.clone(),
            fingerprint: u64::from_str_radix(&resp.fingerprint, 16).unwrap_or(0),
            latency_ns,
            cached: resp.cached && !resp.dedup,
            dedup: resp.dedup,
            samples: timeline,
        });
        resp
    }

    /// The request lifecycle proper. Returns the response plus the round
    /// telemetry timeline when this request's thread led a search (empty
    /// for cache hits, dedup waits, and errors).
    fn handle_inner(
        &self,
        req: &PartitionRequest,
        trace_id: u64,
    ) -> (PlanResponse, Vec<RoundSample>) {
        let rec = recorder();
        let job = match req.build_job(&self.defaults) {
            Ok(j) => j,
            Err(e) => return (PlanResponse::error(&req.id, "", format!("{e:#}")), Vec::new()),
        };
        let fp = job.fingerprint();
        let hex = fp.hex();

        let probe = rec.span("cache.probe", "service", trace_id);
        let hit = self.cache.get(fp);
        drop(probe);
        if let Some(plan_json) = hit {
            self.mx.cache_hits.add(1);
            let resp = PlanResponse {
                id: req.id.clone(),
                fingerprint: hex,
                cached: true,
                dedup: false,
                disk: false,
                degraded: None,
                fallback: false,
                plan_json: Some(plan_json),
                search: None,
                error: None,
            };
            return (resp, Vec::new());
        }

        // Memory missed: probe the persistent tier. A hit is promoted to
        // the memory tier so the next identical request never seeks.
        if let Some(disk) = &self.disk {
            let dprobe = rec.span("disk.probe", "service", trace_id);
            let found = disk.get(fp.0);
            drop(dprobe);
            if let Some(plan_json) = found {
                self.cache.put(fp, plan_json.clone());
                let resp = PlanResponse {
                    id: req.id.clone(),
                    fingerprint: hex,
                    cached: true,
                    dedup: false,
                    disk: true,
                    degraded: None,
                    fallback: false,
                    plan_json: Some(plan_json),
                    search: None,
                    error: None,
                };
                return (resp, Vec::new());
            }
        }

        // Join an identical in-flight search, or become its leader. The
        // cache re-probe under the lock closes the window between the
        // miss above and a concurrent leader's publish.
        let (entry, leader) = {
            let mut table = self.inflight.lock().expect("inflight table poisoned");
            if let Some(existing) = table.get(&fp.0) {
                (existing.clone(), false)
            } else if let Some(plan_json) = self.cache.probe(fp) {
                self.mx.cache_hits.add(1);
                let resp = PlanResponse {
                    id: req.id.clone(),
                    fingerprint: hex,
                    cached: true,
                    dedup: false,
                    disk: false,
                    degraded: None,
                    fallback: false,
                    plan_json: Some(plan_json),
                    search: None,
                    error: None,
                };
                return (resp, Vec::new());
            } else {
                let fresh = Arc::new(Inflight::new());
                table.insert(fp.0, fresh.clone());
                (fresh, true)
            }
        };

        if !leader {
            let wait = rec.span("dedup.wait", "service", trace_id);
            let published = entry.wait();
            drop(wait);
            let resp = match published {
                Ok((plan_json, degraded)) => {
                    // Counted only on success, so served_without_search
                    // never includes requests that came back as errors.
                    self.dedup_served.fetch_add(1, Ordering::Relaxed);
                    self.mx.dedup_served.add(1);
                    PlanResponse {
                        id: req.id.clone(),
                        fingerprint: hex,
                        cached: true,
                        dedup: true,
                        disk: false,
                        degraded,
                        fallback: false,
                        plan_json: Some(plan_json),
                        search: None,
                        error: None,
                    }
                }
                Err(e) => {
                    let mut resp = PlanResponse::error(&req.id, &hex, e);
                    resp.dedup = true;
                    resp
                }
            };
            return (resp, Vec::new());
        }

        self.searches.fetch_add(1, Ordering::Relaxed);
        self.mx.cache_misses.add(1);
        self.mx.searches.add(1);
        self.mx.inflight_searches.add(1);
        let run_span = rec.span("search.run", "service", trace_id);
        let run_result = job.run();
        drop(run_span);
        self.mx.inflight_searches.add(-1);
        let mut timeline = Vec::new();
        let outcome = match run_result {
            Ok(mut report) => {
                let stats = SearchStats::from_report(&report);
                self.eval_lookups.fetch_add(stats.eval_lookups as u64, Ordering::Relaxed);
                self.eval_memo_hits.fetch_add(stats.eval_memo_hits as u64, Ordering::Relaxed);
                self.ledger_nodes_reused
                    .fetch_add(stats.ledger_nodes_reused as u64, Ordering::Relaxed);
                self.ledger_nodes_recomputed
                    .fetch_add(stats.ledger_nodes_recomputed as u64, Ordering::Relaxed);
                self.mx.episodes.add(report.episodes_total as u64);
                self.mx.rounds.add(report.rounds as u64);
                self.mx.steals.add(report.steals as u64);
                self.mx.eval_lookups.add(stats.eval_lookups as u64);
                self.mx.eval_memo_hits.add(stats.eval_memo_hits as u64);
                self.mx.ledger_refreshes.add(report.ledger_refreshes as u64);
                self.mx.ledger_nodes_reused.add(stats.ledger_nodes_reused as u64);
                self.mx.ledger_nodes_recomputed.add(stats.ledger_nodes_recomputed as u64);
                self.mx.search_run.record((report.wall_seconds * 1e9) as u64);
                if stats.stages > 0 {
                    self.pipelined_searches.fetch_add(1, Ordering::Relaxed);
                    self.bubble_micros
                        .fetch_add((stats.bubble_fraction * 1e6) as u64, Ordering::Relaxed);
                    self.mx.pipelined.add(1);
                }
                timeline = std::mem::take(&mut report.timeline);
                // Degraded-mode accounting: a deadline hit wins the
                // label (it is the cause even when it also forced the
                // fallback plan); panics that poisoned every worker
                // surface as `"panic"`.
                let degraded: Option<String> = if report.deadline_hit {
                    Some("deadline".to_string())
                } else if report.fallback {
                    Some("panic".to_string())
                } else {
                    None
                };
                if report.deadline_hit {
                    self.deadline_hits.fetch_add(1, Ordering::Relaxed);
                    self.mx.deadline_hits.add(1);
                }
                if report.fallback {
                    self.fallback_plans.fetch_add(1, Ordering::Relaxed);
                    self.mx.fallback_plans.add(1);
                }
                if report.worker_panics > 0 {
                    self.worker_panics
                        .fetch_add(report.worker_panics as u64, Ordering::Relaxed);
                    self.mx.worker_panics.add(report.worker_panics as u64);
                }
                let plan_json = report.plan.to_json().to_string();
                if degraded.is_none() {
                    let publish = rec.span("cache.publish", "service", trace_id);
                    self.cache.put(fp, plan_json.clone());
                    if let Some(disk) = &self.disk {
                        // Write-through: a failed append degrades
                        // durability but must never fail the request.
                        let _ = disk.put(fp.0, &plan_json);
                    }
                    drop(publish);
                }
                // Degraded plans are NEVER cached (memory or disk): the
                // deadline is not part of the fingerprint, so a plan
                // truncated by one request's budget must not be served
                // as the canonical answer for the fingerprint.
                Ok((plan_json, degraded, stats, report.fallback))
            }
            Err(e) => Err(format!("{e:#}")),
        };
        // Publish order: cache first (above), then clear the in-flight
        // entry, then wake waiters — latecomers either find the entry
        // (and wait) or re-probe the cache and hit. Waiters get the plan
        // and its degraded marker; the search stats belong to the
        // request that ran it.
        self.inflight.lock().expect("inflight table poisoned").remove(&fp.0);
        entry.publish(outcome.clone().map(|(plan_json, degraded, _, _)| (plan_json, degraded)));

        let resp = match outcome {
            Ok((plan_json, degraded, stats, fallback)) => PlanResponse {
                id: req.id.clone(),
                fingerprint: hex,
                cached: false,
                dedup: false,
                disk: false,
                degraded,
                fallback,
                plan_json: Some(plan_json),
                search: Some(stats),
                error: None,
            },
            Err(e) => PlanResponse::error(&req.id, &hex, e),
        };
        (resp, timeline)
    }

    /// Parse and handle one JSONL line.
    pub fn handle_line(&self, line: &str) -> PlanResponse {
        match PartitionRequest::parse_line(line) {
            Ok(req) => self.handle(&req),
            Err(e) => PlanResponse::error("", "", format!("{e:#}")),
        }
    }

    /// Admission-control path: answer `req` WITHOUT entering the search
    /// queue. Serves the cached plan when one exists (memory, then
    /// disk), otherwise the search-free fallback plan — every answer is
    /// marked `degraded:"shed"` so callers can tell the plan skipped
    /// the search. Counted in requests/errors/latency like any other
    /// request, but never runs or joins a search.
    pub fn handle_shed(&self, req: &PartitionRequest) -> PlanResponse {
        let t0 = std::time::Instant::now();
        self.shed.fetch_add(1, Ordering::Relaxed);
        self.mx.shed.add(1);
        let resp = self.handle_shed_inner(req);
        let latency_ns = t0.elapsed().as_nanos() as u64;
        self.latency.record(latency_ns);
        self.mx.request_latency.record(latency_ns);
        self.mx.requests.add(1);
        if resp.error.is_some() {
            self.mx.errors.add(1);
        }
        resp
    }

    fn handle_shed_inner(&self, req: &PartitionRequest) -> PlanResponse {
        let job = match req.build_job(&self.defaults) {
            Ok(j) => j,
            Err(e) => return PlanResponse::error(&req.id, "", format!("{e:#}")),
        };
        let fp = job.fingerprint();
        let hex = fp.hex();
        if let Some(plan_json) = self.cache.get(fp) {
            self.mx.cache_hits.add(1);
            return PlanResponse {
                id: req.id.clone(),
                fingerprint: hex,
                cached: true,
                dedup: false,
                disk: false,
                degraded: Some("shed".to_string()),
                fallback: false,
                plan_json: Some(plan_json),
                search: None,
                error: None,
            };
        }
        if let Some(disk) = &self.disk {
            if let Some(plan_json) = disk.get(fp.0) {
                self.cache.put(fp, plan_json.clone());
                return PlanResponse {
                    id: req.id.clone(),
                    fingerprint: hex,
                    cached: true,
                    dedup: false,
                    disk: true,
                    degraded: Some("shed".to_string()),
                    fallback: false,
                    plan_json: Some(plan_json),
                    search: None,
                    error: None,
                };
            }
        }
        // Nothing cached anywhere: answer with the search-free fallback
        // plan rather than block or error. It is NOT cached — the next
        // uncontended request for this fingerprint runs a real search.
        match job.fallback_plan() {
            Ok(plan) => {
                self.fallback_plans.fetch_add(1, Ordering::Relaxed);
                self.mx.fallback_plans.add(1);
                PlanResponse {
                    id: req.id.clone(),
                    fingerprint: hex,
                    cached: false,
                    dedup: false,
                    disk: false,
                    degraded: Some("shed".to_string()),
                    fallback: true,
                    plan_json: Some(plan.to_json().to_string()),
                    search: None,
                    error: None,
                }
            }
            Err(e) => PlanResponse::error(&req.id, &hex, format!("{e:#}")),
        }
    }

    /// Parse and shed one JSONL line (the queue-full path of
    /// [`serve_jsonl`]).
    pub fn handle_shed_line(&self, line: &str) -> PlanResponse {
        match PartitionRequest::parse_line(line) {
            Ok(req) => self.handle_shed(&req),
            Err(e) => PlanResponse::error("", "", format!("{e:#}")),
        }
    }
}

/// Bounded MPMC work queue: producers block when full, workers block
/// when empty, `close` drains and releases everyone.
struct BoundedQueue<T> {
    state: Mutex<QueueState<T>>,
    not_empty: Condvar,
    not_full: Condvar,
    bound: usize,
}

struct QueueState<T> {
    items: VecDeque<T>,
    closed: bool,
}

impl<T> BoundedQueue<T> {
    fn new(bound: usize) -> BoundedQueue<T> {
        BoundedQueue {
            state: Mutex::new(QueueState { items: VecDeque::new(), closed: false }),
            not_empty: Condvar::new(),
            not_full: Condvar::new(),
            bound: bound.max(1),
        }
    }

    fn push(&self, item: T) {
        let mut st = self.state.lock().expect("queue poisoned");
        while st.items.len() >= self.bound && !st.closed {
            st = self.not_full.wait(st).expect("queue poisoned");
        }
        st.items.push_back(item);
        self.not_empty.notify_one();
    }

    /// Non-blocking push: `Err(item)` when the queue is full or closed,
    /// handing the item back so the caller can shed it instead of
    /// waiting behind slow consumers.
    fn try_push(&self, item: T) -> std::result::Result<(), T> {
        let mut st = self.state.lock().expect("queue poisoned");
        if st.closed || st.items.len() >= self.bound {
            return Err(item);
        }
        st.items.push_back(item);
        self.not_empty.notify_one();
        Ok(())
    }

    fn pop(&self) -> Option<T> {
        let mut st = self.state.lock().expect("queue poisoned");
        loop {
            if let Some(item) = st.items.pop_front() {
                self.not_full.notify_one();
                return Some(item);
            }
            if st.closed {
                return None;
            }
            st = self.not_empty.wait(st).expect("queue poisoned");
        }
    }

    fn close(&self) {
        self.state.lock().expect("queue poisoned").closed = true;
        self.not_empty.notify_all();
        self.not_full.notify_all();
    }
}

/// Summary of a batch/serve run.
#[derive(Debug, Clone)]
pub struct ServeSummary {
    pub requests: usize,
    pub errors: usize,
    pub searches: u64,
    pub cache_hits: u64,
    /// Requests served from the persistent tier (DESIGN.md §13); always
    /// 0 when the service runs without a cache dir.
    pub disk_hits: u64,
    pub dedup_served: u64,
    pub wall_seconds: f64,
    /// Terminal-state evaluations the run's searches requested / served
    /// from the eval memos.
    pub eval_lookups: u64,
    pub eval_memo_hits: u64,
    /// Node cost terms the run's ledgers reused vs recomputed.
    pub ledger_nodes_reused: u64,
    pub ledger_nodes_recomputed: u64,
    /// Searches in this run whose winning plan was pipelined, and their
    /// summed 1F1B bubble fractions in microunits (1e-6).
    pub pipelined_searches: u64,
    pub bubble_micros: u64,
    /// End-to-end per-request latency percentiles for THIS run, in
    /// milliseconds — a snapshot diff of the service-owned histogram
    /// (`obs::metrics::Histogram`), so a batch of mixed hot/cold
    /// requests finally has a latency signal beyond `wall_seconds`.
    pub latency_p50_ms: f64,
    pub latency_p99_ms: f64,
    /// Degraded-mode accounting for this run (DESIGN.md §14): searches
    /// stopped at their deadline, requests shed at admission, search
    /// workers lost to panics, and requests answered with the
    /// search-free fallback plan. All 0 on a healthy run.
    pub deadline_hits: u64,
    pub shed: u64,
    pub worker_panics: u64,
    pub fallback_plans: u64,
    /// Replica anti-entropy during this run (DESIGN.md §15): background
    /// rounds the sync ticker completed, records pulled from peers, and
    /// received frames quarantined as corrupt. All 0 without `--sync-dir`.
    pub sync_rounds: u64,
    pub sync_records_pulled: u64,
    pub sync_frames_quarantined: u64,
}

impl ServeSummary {
    /// Mean 1F1B bubble fraction over the run's pipelined searches.
    pub fn mean_bubble_fraction(&self) -> f64 {
        if self.pipelined_searches == 0 {
            return 0.0;
        }
        (self.bubble_micros as f64 / 1e6) / self.pipelined_searches as f64
    }
    /// Fraction of evaluations served by the eval memos.
    pub fn memo_hit_rate(&self) -> f64 {
        crate::util::stats::fraction(self.eval_memo_hits, self.eval_lookups)
    }

    /// Fraction of node cost terms served from the ledgers.
    pub fn ledger_reuse_rate(&self) -> f64 {
        let total = self.ledger_nodes_reused + self.ledger_nodes_recomputed;
        crate::util::stats::fraction(self.ledger_nodes_reused, total)
    }

    pub fn describe(&self) -> String {
        let mut s = format!(
            "{} requests: {} searches, {} cache hits, {} in-flight dedups, {} errors in {:.2}s \
             (eval memo {:.0}% hit, ledger {:.0}% reuse)",
            self.requests,
            self.searches,
            self.cache_hits,
            self.dedup_served,
            self.errors,
            self.wall_seconds,
            100.0 * self.memo_hit_rate(),
            100.0 * self.ledger_reuse_rate()
        );
        s.push_str(&format!(
            ", latency p50 {:.2}ms / p99 {:.2}ms",
            self.latency_p50_ms, self.latency_p99_ms
        ));
        if self.disk_hits > 0 {
            s.push_str(&format!(", {} disk-tier hits", self.disk_hits));
        }
        if self.deadline_hits > 0 {
            s.push_str(&format!(", {} deadline-hit", self.deadline_hits));
        }
        if self.shed > 0 {
            s.push_str(&format!(", {} shed", self.shed));
        }
        if self.worker_panics > 0 {
            s.push_str(&format!(", {} worker panics", self.worker_panics));
        }
        if self.fallback_plans > 0 {
            s.push_str(&format!(", {} fallback plans", self.fallback_plans));
        }
        if self.pipelined_searches > 0 {
            s.push_str(&format!(
                ", {} pipelined (mean bubble {:.1}%)",
                self.pipelined_searches,
                100.0 * self.mean_bubble_fraction()
            ));
        }
        if self.sync_rounds > 0 {
            s.push_str(&format!(
                ", {} sync rounds ({} records pulled)",
                self.sync_rounds, self.sync_records_pulled
            ));
        }
        if self.sync_frames_quarantined > 0 {
            s.push_str(&format!(
                ", {} sync frames quarantined",
                self.sync_frames_quarantined
            ));
        }
        s
    }
}

/// Run a batch of requests through `pool` worker threads over a bounded
/// queue, preserving input order in the returned responses.
pub fn run_batch(
    service: &PlanService,
    requests: &[PartitionRequest],
    pool: usize,
    queue_bound: usize,
) -> (Vec<PlanResponse>, ServeSummary) {
    let t0 = std::time::Instant::now();
    let searches0 = service.searches_run();
    let hits0 = service.cache.stats().hits;
    let disk0 = service.disk_hits();
    let dedup0 = service.dedup_served();
    let sc0 = service.search_cache_counters();
    let pp0 = service.pipelined_counters();
    let dg0 = service.degraded_counters();
    let sy0 = service.sync_counters();
    let lat0 = service.latency_snapshot();

    let queue: BoundedQueue<usize> = BoundedQueue::new(queue_bound);
    let results: Mutex<Vec<Option<PlanResponse>>> = Mutex::new(vec![None; requests.len()]);
    std::thread::scope(|scope| {
        for _ in 0..pool.max(1) {
            scope.spawn(|| {
                while let Some(i) = queue.pop() {
                    service.mx.queue_depth.add(-1);
                    recorder().instant("queue.dequeue", "service", 0, &[("index", i as i64)]);
                    let resp = service.handle(&requests[i]);
                    results.lock().expect("results poisoned")[i] = Some(resp);
                }
            });
        }
        for i in 0..requests.len() {
            recorder().instant("queue.enqueue", "service", 0, &[("index", i as i64)]);
            queue.push(i);
            service.mx.queue_depth.add(1);
        }
        queue.close();
    });

    let responses: Vec<PlanResponse> = results
        .into_inner()
        .expect("results poisoned")
        .into_iter()
        .map(|r| r.expect("every request handled"))
        .collect();
    let sc1 = service.search_cache_counters();
    let pp1 = service.pipelined_counters();
    let dg1 = service.degraded_counters();
    let sy1 = service.sync_counters();
    let lat = service.latency_snapshot().delta(&lat0);
    let summary = ServeSummary {
        requests: responses.len(),
        errors: responses.iter().filter(|r| r.error.is_some()).count(),
        searches: service.searches_run() - searches0,
        cache_hits: service.cache.stats().hits - hits0,
        disk_hits: service.disk_hits() - disk0,
        dedup_served: service.dedup_served() - dedup0,
        wall_seconds: t0.elapsed().as_secs_f64(),
        eval_lookups: sc1.0 - sc0.0,
        eval_memo_hits: sc1.1 - sc0.1,
        ledger_nodes_reused: sc1.2 - sc0.2,
        ledger_nodes_recomputed: sc1.3 - sc0.3,
        pipelined_searches: pp1.0 - pp0.0,
        bubble_micros: pp1.1 - pp0.1,
        latency_p50_ms: lat.percentile(0.50) / 1e6,
        latency_p99_ms: lat.percentile(0.99) / 1e6,
        deadline_hits: dg1.0 - dg0.0,
        shed: dg1.1 - dg0.1,
        worker_panics: dg1.2 - dg0.2,
        fallback_plans: dg1.3 - dg0.3,
        sync_rounds: sy1.0 - sy0.0,
        sync_records_pulled: sy1.1 - sy0.1,
        sync_frames_quarantined: sy1.2 - sy0.2,
    };
    (responses, summary)
}

/// Stream JSONL requests from `input`, writing one response line per
/// request to `out` as each completes (use the `id` field to correlate;
/// completion order is not input order). `max_pending` bounds the
/// pending queue for admission control: arrivals beyond it are answered
/// inline on the intake thread via [`PlanService::handle_shed`]
/// (`degraded:"shed"`) instead of blocking behind slow searches; `0`
/// means `2 * pool`, under which intake blocks as before. Returns the
/// run summary.
pub fn serve_jsonl<R: BufRead, W: Write + Send>(
    service: &PlanService,
    input: R,
    out: &Mutex<W>,
    pool: usize,
    max_pending: usize,
) -> std::io::Result<ServeSummary> {
    let t0 = std::time::Instant::now();
    let searches0 = service.searches_run();
    let hits0 = service.cache.stats().hits;
    let disk0 = service.disk_hits();
    let dedup0 = service.dedup_served();
    let sc0 = service.search_cache_counters();
    let pp0 = service.pipelined_counters();
    let dg0 = service.degraded_counters();
    let sy0 = service.sync_counters();
    let lat0 = service.latency_snapshot();
    let requests = std::sync::atomic::AtomicU64::new(0);
    let errors = std::sync::atomic::AtomicU64::new(0);

    let shedding = max_pending > 0;
    let bound = if shedding { max_pending } else { 2 * pool.max(1) };
    let queue: BoundedQueue<String> = BoundedQueue::new(bound);
    let io_err: Mutex<Option<std::io::Error>> = Mutex::new(None);
    let write_line = |resp: &PlanResponse| {
        let mut w = out.lock().expect("output poisoned");
        if let Err(e) = writeln!(w, "{}", resp.to_json_line()) {
            let mut slot = io_err.lock().expect("io_err poisoned");
            if slot.is_none() {
                *slot = Some(e);
            }
        }
    };
    // Background anti-entropy ticker (DESIGN.md §15): while serving,
    // run a sync round every `sync_interval_secs`. Round failures are
    // degradation, not errors — the next tick retries from scratch.
    let ticker_stop = (Mutex::new(false), Condvar::new());
    let stop_ticker = || {
        *ticker_stop.0.lock().expect("sync ticker poisoned") = true;
        ticker_stop.1.notify_all();
    };
    std::thread::scope(|scope| -> std::io::Result<()> {
        if service.sync_configured() && service.sync_interval_secs() > 0 {
            let interval = std::time::Duration::from_secs(service.sync_interval_secs());
            let (lock, cv) = &ticker_stop;
            scope.spawn(move || {
                let mut stopped = lock.lock().expect("sync ticker poisoned");
                while !*stopped {
                    let (g, timeout) =
                        cv.wait_timeout(stopped, interval).expect("sync ticker poisoned");
                    stopped = g;
                    if *stopped {
                        break;
                    }
                    if timeout.timed_out() {
                        drop(stopped);
                        let _ = service.sync_once();
                        stopped = lock.lock().expect("sync ticker poisoned");
                    }
                }
            });
        }
        for _ in 0..pool.max(1) {
            scope.spawn(|| {
                while let Some(line) = queue.pop() {
                    service.mx.queue_depth.add(-1);
                    recorder().instant("queue.dequeue", "service", 0, &[]);
                    let resp = service.handle_line(&line);
                    requests.fetch_add(1, Ordering::Relaxed);
                    if resp.error.is_some() {
                        errors.fetch_add(1, Ordering::Relaxed);
                    }
                    write_line(&resp);
                }
            });
        }
        for line in input.lines() {
            let line = match line {
                Ok(l) => l,
                Err(e) => {
                    queue.close();
                    stop_ticker();
                    return Err(e);
                }
            };
            if line.trim().is_empty() {
                continue;
            }
            recorder().instant("queue.enqueue", "service", 0, &[]);
            if shedding {
                match queue.try_push(line) {
                    Ok(()) => service.mx.queue_depth.add(1),
                    Err(line) => {
                        // Queue full: shed at admission — answered from
                        // cache or the fallback plan, never dropped.
                        recorder().instant("queue.shed", "service", 0, &[]);
                        let resp = service.handle_shed_line(&line);
                        requests.fetch_add(1, Ordering::Relaxed);
                        if resp.error.is_some() {
                            errors.fetch_add(1, Ordering::Relaxed);
                        }
                        write_line(&resp);
                    }
                }
            } else {
                queue.push(line);
                service.mx.queue_depth.add(1);
            }
        }
        queue.close();
        stop_ticker();
        Ok(())
    })?;
    if let Some(e) = io_err.into_inner().expect("io_err poisoned") {
        return Err(e);
    }
    let sc1 = service.search_cache_counters();
    let pp1 = service.pipelined_counters();
    let dg1 = service.degraded_counters();
    let sy1 = service.sync_counters();
    let lat = service.latency_snapshot().delta(&lat0);
    Ok(ServeSummary {
        requests: requests.load(Ordering::Relaxed) as usize,
        errors: errors.load(Ordering::Relaxed) as usize,
        searches: service.searches_run() - searches0,
        cache_hits: service.cache.stats().hits - hits0,
        disk_hits: service.disk_hits() - disk0,
        dedup_served: service.dedup_served() - dedup0,
        wall_seconds: t0.elapsed().as_secs_f64(),
        eval_lookups: sc1.0 - sc0.0,
        eval_memo_hits: sc1.1 - sc0.1,
        ledger_nodes_reused: sc1.2 - sc0.2,
        ledger_nodes_recomputed: sc1.3 - sc0.3,
        pipelined_searches: pp1.0 - pp0.0,
        bubble_micros: pp1.1 - pp0.1,
        latency_p50_ms: lat.percentile(0.50) / 1e6,
        latency_p99_ms: lat.percentile(0.99) / 1e6,
        deadline_hits: dg1.0 - dg0.0,
        shed: dg1.1 - dg0.1,
        worker_panics: dg1.2 - dg0.2,
        fallback_plans: dg1.3 - dg0.3,
        sync_rounds: sy1.0 - sy0.0,
        sync_records_pulled: sy1.1 - sy0.1,
        sync_frames_quarantined: sy1.2 - sy0.2,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req(id: &str, seed: u64) -> PartitionRequest {
        PartitionRequest {
            id: id.to_string(),
            model: "mlp".to_string(),
            mesh: "model=4".to_string(),
            budget: 40,
            seed,
            workers: 2,
            ..Default::default()
        }
    }

    fn tiny_service() -> PlanService {
        PlanService::new(ServiceConfig::default())
    }

    #[test]
    fn first_request_searches_second_hits_cache_byte_identically() {
        let svc = tiny_service();
        let a = svc.handle(&req("a", 1));
        assert!(a.error.is_none(), "{:?}", a.error);
        assert!(!a.cached);
        let b = svc.handle(&req("b", 1));
        assert!(b.cached);
        assert!(!b.dedup);
        assert_eq!(svc.searches_run(), 1);
        assert_eq!(a.plan_json, b.plan_json, "cache hit must be byte-identical");
        assert_eq!(a.fingerprint, b.fingerprint);
        // The request that ran the search reports its cache stats; the
        // cache hit (which ran nothing) does not.
        let stats = a.search.as_ref().expect("fresh response must carry search stats");
        assert!(stats.eval_lookups > 0);
        assert!(stats.ledger_nodes_reused > 0);
        assert!(b.search.is_none());
        let (lookups, hits, reused, recomputed) = svc.search_cache_counters();
        assert_eq!(lookups, stats.eval_lookups as u64);
        assert_eq!(hits, stats.eval_memo_hits as u64);
        assert_eq!(reused, stats.ledger_nodes_reused as u64);
        assert_eq!(recomputed, stats.ledger_nodes_recomputed as u64);
    }

    #[test]
    fn concurrent_identical_requests_share_one_search() {
        let svc = tiny_service();
        let r = req("c", 2);
        std::thread::scope(|s| {
            let h1 = s.spawn(|| svc.handle(&r));
            let h2 = s.spawn(|| svc.handle(&r));
            let (a, b) = (h1.join().unwrap(), h2.join().unwrap());
            assert!(a.error.is_none() && b.error.is_none());
            assert_eq!(a.plan_json, b.plan_json);
        });
        assert_eq!(svc.searches_run(), 1, "in-flight dedup must collapse duplicates");
    }

    #[test]
    fn program_requests_share_the_cache_with_equivalent_model_requests() {
        let svc = tiny_service();
        let a = svc.handle(&req("a", 1));
        assert!(a.error.is_none(), "{:?}", a.error);
        // The same program, submitted as text by an "external frontend".
        let text = crate::ir::print_func(
            &crate::models::mlp::build_mlp(&crate::models::mlp::MlpConfig::small()).func,
        );
        let r = PartitionRequest { program: Some(text), model: String::new(), ..req("b", 1) };
        let b = svc.handle(&r);
        assert!(b.error.is_none(), "{:?}", b.error);
        assert_eq!(a.fingerprint, b.fingerprint, "parsed program must fingerprint identically");
        assert!(b.cached, "program request must hit the model request's cache line");
        assert_eq!(a.plan_json, b.plan_json);
        assert_eq!(svc.searches_run(), 1);
    }

    #[test]
    fn malformed_requests_become_error_responses() {
        let svc = tiny_service();
        let resp = svc.handle_line("{\"id\":\"x\",\"model\":\"resnet\"}");
        assert!(resp.error.is_some());
        assert!(resp.plan_json.is_none());
        assert_eq!(svc.searches_run(), 0);
        let resp = svc.handle_line("garbage");
        assert!(resp.error.is_some());
    }

    #[test]
    fn batch_preserves_order_and_counts() {
        let svc = tiny_service();
        let reqs: Vec<PartitionRequest> =
            (0..6).map(|i| req(&format!("r{i}"), (i % 2) as u64)).collect();
        let (responses, summary) = run_batch(&svc, &reqs, 3, 4);
        assert_eq!(responses.len(), 6);
        for (i, r) in responses.iter().enumerate() {
            assert_eq!(r.id, format!("r{i}"), "input order preserved");
            assert!(r.error.is_none());
        }
        assert_eq!(summary.searches, 2, "two unique fingerprints");
        assert_eq!(summary.cache_hits + summary.dedup_served, 4);
        assert_eq!(summary.errors, 0);
        // The summary aggregates the two searches' cache effectiveness.
        assert!(summary.eval_lookups > 0);
        assert!((0.0..=1.0).contains(&summary.memo_hit_rate()));
        assert!((0.0..=1.0).contains(&summary.ledger_reuse_rate()));
        assert!(summary.ledger_nodes_reused > 0);
    }

    #[test]
    fn pipelined_requests_surface_in_stats_and_summary() {
        let svc = tiny_service();
        let r = PartitionRequest {
            pipeline: "stages=2,microbatches=4".to_string(),
            mesh: "model=2".to_string(),
            ..req("p", 4)
        };
        let (responses, summary) = run_batch(&svc, std::slice::from_ref(&r), 1, 2);
        assert!(responses[0].error.is_none(), "{:?}", responses[0].error);
        let stats = responses[0].search.as_ref().expect("fresh response");
        assert_eq!((stats.stages, stats.microbatches), (2, 4));
        assert!(stats.bubble_fraction > 0.0, "a 2-stage 1F1B schedule has a warm-up bubble");
        assert_eq!(summary.pipelined_searches, 1);
        assert!(summary.bubble_micros > 0);
        assert!(summary.describe().contains("pipelined"), "{}", summary.describe());
        // Non-pipelined runs keep the old summary wording.
        let (_, plain) = run_batch(&svc, &[req("q", 5)], 1, 2);
        assert_eq!(plain.pipelined_searches, 0);
        assert!(!plain.describe().contains("pipelined"));
    }

    #[test]
    fn serve_jsonl_streams_responses() {
        let svc = tiny_service();
        let input = "{\"id\":\"a\",\"model\":\"mlp\",\"budget\":30,\"workers\":1}\n\
                     \n\
                     {\"id\":\"b\",\"model\":\"mlp\",\"budget\":30,\"workers\":1}\n\
                     bad json\n";
        let out = Mutex::new(Vec::<u8>::new());
        let summary =
            serve_jsonl(&svc, std::io::BufReader::new(input.as_bytes()), &out, 2, 0).unwrap();
        assert_eq!(summary.requests, 3, "blank lines are skipped");
        assert_eq!(summary.errors, 1);
        let text = String::from_utf8(out.into_inner().unwrap()).unwrap();
        assert_eq!(text.lines().count(), 3);
        for line in text.lines() {
            assert!(crate::util::json::parse(line).is_ok(), "bad response line: {line}");
        }
    }

    #[test]
    fn shed_requests_serve_cache_or_fallback_without_searching() {
        let svc = tiny_service();
        // Cold shed: nothing cached → the search-free fallback plan.
        let a = svc.handle_shed(&req("cold", 9));
        assert!(a.error.is_none(), "{:?}", a.error);
        assert_eq!(a.degraded.as_deref(), Some("shed"));
        assert!(a.fallback);
        assert!(!a.cached);
        assert!(a.plan_json.is_some());
        assert_eq!(svc.searches_run(), 0, "shedding must never search");
        // Warm shed: a real search first, then shed the same fingerprint.
        let b = svc.handle(&req("warm", 10));
        assert!(b.error.is_none(), "{:?}", b.error);
        let c = svc.handle_shed(&req("warm2", 10));
        assert_eq!(c.degraded.as_deref(), Some("shed"));
        assert!(!c.fallback);
        assert!(c.cached);
        assert_eq!(c.plan_json, b.plan_json, "warm shed serves the cached plan");
        let (_, shed, _, fallbacks) = svc.degraded_counters();
        assert_eq!(shed, 2);
        assert_eq!(fallbacks, 1);
        // The fallback plan was NOT cached: a later unshed request for
        // the cold fingerprint still runs its own search.
        let d = svc.handle(&req("cold2", 9));
        assert!(!d.cached, "fallback plans must never be cached");
        assert_eq!(svc.searches_run(), 2);
    }

    #[test]
    fn bounded_queue_try_push_sheds_when_full_or_closed() {
        let q: BoundedQueue<u32> = BoundedQueue::new(1);
        assert!(q.try_push(1).is_ok());
        assert_eq!(q.try_push(2), Err(2), "full queue hands the item back");
        assert_eq!(q.pop(), Some(1));
        q.close();
        assert_eq!(q.try_push(3), Err(3), "closed queue refuses new items");
    }

    #[test]
    fn bounded_queue_backpressure_and_close() {
        let q: BoundedQueue<u32> = BoundedQueue::new(2);
        std::thread::scope(|s| {
            s.spawn(|| {
                for i in 0..100 {
                    q.push(i);
                }
                q.close();
            });
            let mut got = Vec::new();
            while let Some(x) = q.pop() {
                got.push(x);
            }
            assert_eq!(got.len(), 100);
        });
        assert!(q.pop().is_none(), "closed queue drains to None");
    }
}
