//! Simple MLP training-step builder: the quickstart workload and a small
//! regression target for the partitioner (a stack of dense layers ending
//! in an L2 loss, with optional backward + SGD update).

use crate::ir::autodiff::gradients;
use crate::ir::{ArgKind, Func, GraphBuilder, TensorType, ValueId};

#[derive(Debug, Clone)]
pub struct MlpConfig {
    pub batch: i64,
    pub dims: Vec<i64>,
    pub training: bool,
}

impl MlpConfig {
    pub fn small() -> MlpConfig {
        MlpConfig { batch: 8, dims: vec![64, 256, 256, 16], training: true }
    }
}

pub struct MlpModel {
    pub func: Func,
    pub weights: Vec<ValueId>,
    pub biases: Vec<ValueId>,
    pub loss: ValueId,
}

pub fn build_mlp(cfg: &MlpConfig) -> MlpModel {
    assert!(cfg.dims.len() >= 2);
    let mut b = GraphBuilder::new("mlp_update");
    let x = b.arg("x", TensorType::f32(&[cfg.batch, cfg.dims[0]]), ArgKind::Input);
    let target = b.arg(
        "target",
        TensorType::f32(&[cfg.batch, *cfg.dims.last().unwrap()]),
        ArgKind::Input,
    );
    let mut weights = Vec::new();
    let mut biases = Vec::new();
    for l in 0..cfg.dims.len() - 1 {
        b.push_scope(&format!("dense_{l}"));
        weights.push(b.arg(
            format!("dense_{l}/w"),
            TensorType::f32(&[cfg.dims[l], cfg.dims[l + 1]]),
            ArgKind::Parameter,
        ));
        biases.push(b.arg(
            format!("dense_{l}/b"),
            TensorType::f32(&[cfg.dims[l + 1]]),
            ArgKind::Parameter,
        ));
        b.pop_scope();
    }

    let mut h = x;
    for l in 0..cfg.dims.len() - 1 {
        b.push_scope(&format!("dense_{l}"));
        let y = b.matmul(h, weights[l]);
        let ty = b.ty(y).clone();
        let bb = b.broadcast_to(biases[l], ty);
        let z = b.add(y, bb);
        h = if l + 2 < cfg.dims.len() { b.gelu(z) } else { z };
        b.pop_scope();
    }
    let diff = b.sub(h, target);
    let sq = b.mul(diff, diff);
    let tot = b.reduce_sum(sq, vec![0, 1]);
    let loss = b.scale(tot, 1.0 / (cfg.batch * cfg.dims.last().unwrap()) as f64);

    if cfg.training {
        let params: Vec<ValueId> = weights.iter().chain(&biases).copied().collect();
        let grads = gradients(&mut b, loss, &params);
        for (i, &p) in params.iter().enumerate() {
            if let Some(g) = grads[i] {
                let step = b.scale(g, 1e-2);
                let p_new = b.sub(p, step);
                b.output(p_new);
            }
        }
    }
    b.output(loss);
    MlpModel { func: b.finish(), weights, biases, loss }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::interp::{eval_all, Tensor};
    use crate::ir::verify::verify;
    use crate::util::rng::Rng;

    #[test]
    fn builds_and_verifies() {
        let m = build_mlp(&MlpConfig::small());
        verify(&m.func).unwrap();
        assert_eq!(m.weights.len(), 3);
    }

    #[test]
    fn sgd_steps_reduce_loss() {
        let cfg = MlpConfig { batch: 4, dims: vec![8, 16, 4], training: true };
        let m = build_mlp(&cfg);
        let mut rng = Rng::new(3);
        let mut args: Vec<Tensor> = m
            .func
            .args
            .iter()
            .map(|a| {
                let n = a.ty.num_elements() as usize;
                Tensor::new(&a.ty.dims, (0..n).map(|_| (rng.gen_f64() - 0.5) * 0.5).collect())
            })
            .collect();
        let mut prev = f64::INFINITY;
        for _ in 0..3 {
            let vals = eval_all(&m.func, &args);
            let loss = vals[m.loss.index()].data[0];
            assert!(loss < prev);
            prev = loss;
            let n_params = m.weights.len() + m.biases.len();
            for i in 0..n_params {
                let p = if i < m.weights.len() {
                    m.weights[i]
                } else {
                    m.biases[i - m.weights.len()]
                };
                args[p.index()] = vals[m.func.outputs[i].index()].clone();
            }
        }
    }
}
