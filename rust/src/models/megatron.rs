//! Megatron-LM reference sharding (Shoeybi et al. 2019) for transformer
//! layers, and the collective-statistics detector the paper uses to
//! measure search success ("Achieving Megatron is measured through
//! gathering statistics on collectives in the partitioned model", §3).
//!
//! Megatron intra-layer model parallelism: QKV projections column-sharded
//! (per attention head), attention output row-sharded; MLP first matmul
//! column-sharded, second row-sharded — exactly one all-reduce after the
//! attention block and one after the MLP block (per direction).

use super::transformer::TransformerModel;
use crate::cost::composite::{evaluate, CostWeights, Evaluation};
use crate::partir::actions::{Action, DecisionState};
use crate::partir::mesh::AxisId;
use crate::partir::program::PartirProgram;
use crate::sim::device::Device;

/// The expert Megatron decision sequence for `model` on `axis`:
/// 6 tile decisions per layer (wq/wk/wv out-dim, wo in-dim, w1 out-dim,
/// w2 in-dim).
pub fn reference_state(model: &TransformerModel, axis: AxisId) -> DecisionState {
    let mut actions = Vec::new();
    for lp in &model.layers {
        actions.push(Action::Tile { v: lp.wq, dim: 1, axis });
        actions.push(Action::Tile { v: lp.wk, dim: 1, axis });
        actions.push(Action::Tile { v: lp.wv, dim: 1, axis });
        actions.push(Action::Tile { v: lp.wo, dim: 0, axis });
        actions.push(Action::Tile { v: lp.w1, dim: 1, axis });
        actions.push(Action::Tile { v: lp.w2, dim: 0, axis });
    }
    // Shard the matching biases / optimiser state for free memory savings.
    actions.push(Action::InferRest);
    DecisionState { actions, atomic: Default::default() }
}

/// Reference evaluation (collective profile + runtime) of Megatron.
pub fn reference_evaluation(
    program: &PartirProgram,
    model: &TransformerModel,
    axis: AxisId,
    dev: &Device,
    w: &CostWeights,
) -> Evaluation {
    let st = reference_state(model, axis);
    let (dm, _) = program.apply(&st);
    evaluate(program, &dm, dev, w)
}

/// Verdict on a found solution vs. the Megatron reference.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct MegatronVerdict {
    /// Collective profile matches the reference (same all-reduce count
    /// and bytes within 1%, no all-gathers) — "discovered Megatron".
    pub is_megatron: bool,
    /// Few redundant collectives: total comm bytes within 25% and
    /// runtime within 10% of reference — the paper's "near Megatron".
    pub near_megatron: bool,
    /// Collectives beyond the reference count.
    pub redundant_collectives: usize,
}

/// Compare a found solution's evaluation against the reference's.
pub fn check(found: &Evaluation, reference: &Evaluation) -> MegatronVerdict {
    let ref_ar = reference.collectives.all_reduce_count;
    let ref_bytes = reference.collectives.total_bytes() as f64;
    let fb = found.collectives.total_bytes() as f64;
    let is_megatron = found.collectives.all_gather_count == 0
        && found.collectives.all_reduce_count == ref_ar
        && (fb - ref_bytes).abs() <= 0.01 * ref_bytes.max(1.0)
        && found.fits_memory == reference.fits_memory
        && found.memory.peak_bytes <= (reference.memory.peak_bytes as f64 * 1.02) as i64;
    let near_megatron = !is_megatron
        && found.fits_memory == reference.fits_memory
        && fb <= 1.25 * ref_bytes.max(1.0)
        && found.runtime.total_seconds() <= 1.10 * reference.runtime.total_seconds();
    let redundant =
        found.collectives.total_count().saturating_sub(reference.collectives.total_count());
    MegatronVerdict { is_megatron, near_megatron, redundant_collectives: redundant }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::transformer::{build_transformer, TransformerConfig};
    use crate::partir::mesh::Mesh;

    fn setup(layers: usize) -> (PartirProgram, TransformerModel) {
        let cfg = TransformerConfig::tiny(layers);
        let model = build_transformer(&cfg);
        let program =
            PartirProgram::new(model.func.clone(), Mesh::new(&[("model", 4)]));
        (program, model)
    }

    #[test]
    fn megatron_yields_two_allreduce_per_layer_fwd() {
        let (program, model) = setup(2);
        let dev = Device::tpu_v3();
        let w = CostWeights::default();
        let e = reference_evaluation(&program, &model, AxisId(0), &dev, &w);
        // fwd: 2 per layer (attn out + mlp out). bwd mirrors with partial
        // sums for input grads; adam adds none. Expect no all-gathers and
        // all-reduce count proportional to layers.
        assert_eq!(e.collectives.all_gather_count, 0, "{:?}", e.collectives);
        assert!(e.collectives.all_reduce_count >= 4, "{:?}", e.collectives);
        // per-layer collective count identical across depths
        let (p1, m1) = setup(1);
        let e1 = reference_evaluation(&p1, &m1, AxisId(0), &dev, &w);
        let per_layer = e.collectives.all_reduce_count - e1.collectives.all_reduce_count;
        assert_eq!(
            e1.collectives.all_reduce_count + per_layer,
            e.collectives.all_reduce_count
        );
    }

    #[test]
    fn reference_matches_itself() {
        let (program, model) = setup(1);
        let dev = Device::tpu_v3();
        let w = CostWeights::default();
        let e = reference_evaluation(&program, &model, AxisId(0), &dev, &w);
        let v = check(&e, &e);
        assert!(v.is_megatron);
        assert_eq!(v.redundant_collectives, 0);
    }

    #[test]
    fn empty_solution_is_not_megatron() {
        let (program, model) = setup(1);
        let dev = Device::tpu_v3();
        let w = CostWeights::default();
        let reference = reference_evaluation(&program, &model, AxisId(0), &dev, &w);
        let dm = crate::partir::dist::DistMap::new(&program.func, &program.mesh);
        let found = evaluate(&program, &dm, &dev, &w);
        let v = check(&found, &reference);
        // No sharding: zero collectives BUT higher peak memory -> not Megatron.
        assert!(!v.is_megatron);
    }

    #[test]
    fn megatron_reduces_memory_vs_replicated() {
        // Paper setting: the model does NOT fit one device replicated
        // (26 GB model vs 16 GB TPU v3) — shrink HBM to recreate that
        // pressure at test scale.
        let (program, model) = setup(2);
        let dm0 = crate::partir::dist::DistMap::new(&program.func, &program.mesh);
        let w = CostWeights::default();
        let probe = evaluate(&program, &dm0, &Device::tpu_v3(), &w);
        let dev = Device { hbm_bytes: probe.memory.peak_bytes * 3 / 4, ..Device::tpu_v3() };
        let e_ref = reference_evaluation(&program, &model, AxisId(0), &dev, &w);
        let e0 = evaluate(&program, &dm0, &dev, &w);
        assert!(e_ref.memory.peak_bytes < e0.memory.peak_bytes);
        assert!(e_ref.fits_memory && !e0.fits_memory);
        assert!(e_ref.cost < e0.cost);
    }
}
