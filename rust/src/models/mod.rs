//! Model zoo: the paper's evaluation workloads built directly in the
//! base dialect — GPT-style transformer (with full training step), MLP,
//! Interaction-Network GraphNet — plus the Megatron reference strategy
//! and its collective-statistics detector.

pub mod graphnet;
pub mod megatron;
pub mod mlp;
pub mod transformer;

pub use graphnet::{build_graphnet, GraphNetConfig, GraphNetModel};
pub use megatron::{check, reference_evaluation, reference_state, MegatronVerdict};
pub use mlp::{build_mlp, MlpConfig, MlpModel};
pub use transformer::{build_transformer, TransformerConfig, TransformerModel};
