//! Model zoo: the paper's evaluation workloads built directly in the
//! base dialect — GPT-style transformer (with full training step), MLP,
//! Interaction-Network GraphNet — plus the Megatron reference strategy
//! and its collective-statistics detector.

pub mod graphnet;
pub mod megatron;
pub mod mlp;
pub mod transformer;

pub use graphnet::{build_graphnet, GraphNetConfig, GraphNetModel};
pub use megatron::{check, reference_evaluation, reference_state, MegatronVerdict};
pub use mlp::{build_mlp, MlpConfig, MlpModel};
pub use transformer::{build_transformer, TransformerConfig, TransformerModel};

/// Build a built-in model by its request/CLI name (`mlp` | `graphnet` |
/// `transformer`); `layers` applies to the transformer only and is
/// clamped to >= 1. The single source of truth for the name→model map —
/// the service (`PartitionRequest::build_func`) and the CLI
/// (`partition`/`print`) both resolve through it.
pub fn build_by_name(name: &str, layers: usize) -> Option<crate::ir::Func> {
    match name {
        "mlp" => Some(build_mlp(&MlpConfig::small()).func),
        "graphnet" => Some(build_graphnet(&GraphNetConfig::small()).func),
        "transformer" => {
            Some(build_transformer(&TransformerConfig::tiny(layers.max(1))).func)
        }
        _ => None,
    }
}
