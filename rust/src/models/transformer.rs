//! GPT-style decoder-only transformer *training step* builder — the
//! paper's evaluation workload (§3): "a GPT-3 style 24-layer transformer
//! model which requires ≈26 GB of memory at batch size 1 ... just over
//! 50k operations, and 1150 arguments".
//!
//! The graph is the full update function: forward, cross-entropy loss,
//! reverse-mode backward (via `ir::autodiff`), and an Adam update for
//! every parameter — so the partitioner sees parameters, gradients and
//! optimiser state exactly as the paper's partitioner does.

use crate::ir::autodiff::gradients;
use crate::ir::{ArgKind, CmpDir, DType, DotDims, Func, GraphBuilder, TensorType, ValueId};

/// Transformer configuration.
#[derive(Debug, Clone)]
pub struct TransformerConfig {
    pub layers: usize,
    pub d_model: i64,
    pub n_heads: i64,
    pub d_ff: i64,
    pub vocab: i64,
    pub seq: i64,
    pub batch: i64,
    /// Include backward pass + Adam update (the paper's setting).
    pub training: bool,
}

impl TransformerConfig {
    /// The paper's GPT-3-style model: 24 layers, d=2048 (GPT-3 XL scale,
    /// ~1.3B params -> ~26 GB for param+grad+Adam in f32 at batch 1).
    pub fn paper() -> TransformerConfig {
        TransformerConfig {
            layers: 24,
            d_model: 2048,
            n_heads: 16,
            d_ff: 8192,
            vocab: 50304,
            seq: 1024,
            batch: 1,
            training: true,
        }
    }

    /// A small config for tests and CI-scale experiments. Proportions
    /// follow the paper's regime — layer weights dominate memory
    /// (d_ff = 4·d_model, modest vocab/seq) — so the optimal strategy
    /// is the same *kind* of strategy as at paper scale (Megatron).
    pub fn tiny(layers: usize) -> TransformerConfig {
        TransformerConfig {
            layers,
            d_model: 128,
            n_heads: 4,
            d_ff: 512,
            vocab: 128,
            seq: 16,
            batch: 2,
            training: true,
        }
    }

    pub fn head_dim(&self) -> i64 {
        self.d_model / self.n_heads
    }

    /// Approximate parameter count.
    pub fn param_count(&self) -> i64 {
        let d = self.d_model;
        let per_layer = 4 * d * d + 2 * d * self.d_ff + 13 * d + 2 * self.d_ff;
        self.vocab * d + self.seq * d + self.layers as i64 * per_layer + 2 * d
    }
}

/// Per-layer parameter value ids (for Megatron reference strategies).
#[derive(Debug, Clone)]
pub struct LayerParams {
    pub wq: ValueId,
    pub wk: ValueId,
    pub wv: ValueId,
    pub wo: ValueId,
    pub w1: ValueId,
    pub w2: ValueId,
}

/// A built transformer training graph plus metadata the partitioner and
/// the Megatron detector need.
pub struct TransformerModel {
    pub func: Func,
    pub config: TransformerConfig,
    pub layers: Vec<LayerParams>,
    /// All parameter arg ids.
    pub params: Vec<ValueId>,
    pub loss: ValueId,
}

struct ParamDecl {
    id: ValueId,
}

/// Build the transformer training-step graph.
pub fn build_transformer(cfg: &TransformerConfig) -> TransformerModel {
    let mut b = GraphBuilder::new("transformer_update");
    let d = cfg.d_model;
    let h = cfg.n_heads;
    let dh = cfg.head_dim();
    let (bs, s, v, ff) = (cfg.batch, cfg.seq, cfg.vocab, cfg.d_ff);

    // ---- argument declarations (all before the first node) -------------
    let mut params: Vec<ValueId> = Vec::new();
    let decl = |b: &mut GraphBuilder,
                params: &mut Vec<ValueId>,
                scope: &str,
                name: &str,
                dims: &[i64]|
     -> ParamDecl {
        if !scope.is_empty() {
            b.push_scope(scope);
        }
        let full = if scope.is_empty() { name.to_string() } else { format!("{scope}/{name}") };
        let id = b.arg(full, TensorType::f32(dims), ArgKind::Parameter);
        if !scope.is_empty() {
            b.pop_scope();
        }
        params.push(id);
        ParamDecl { id }
    };

    let embed = decl(&mut b, &mut params, "", "embed", &[v, d]).id;
    let pos = decl(&mut b, &mut params, "", "pos_embed", &[s, d]).id;
    let mut layers = Vec::with_capacity(cfg.layers);
    let mut layer_lns = Vec::with_capacity(cfg.layers);
    let mut layer_biases = Vec::with_capacity(cfg.layers);
    for l in 0..cfg.layers {
        let ls = format!("layer_{l}");
        let attn = format!("{ls}/attn");
        let mlp = format!("{ls}/mlp");
        let ln1_g = decl(&mut b, &mut params, &ls, "ln1_g", &[d]).id;
        let ln1_b = decl(&mut b, &mut params, &ls, "ln1_b", &[d]).id;
        let wq = decl(&mut b, &mut params, &attn, "wq", &[d, d]).id;
        let bq = decl(&mut b, &mut params, &attn, "bq", &[d]).id;
        let wk = decl(&mut b, &mut params, &attn, "wk", &[d, d]).id;
        let bk = decl(&mut b, &mut params, &attn, "bk", &[d]).id;
        let wv = decl(&mut b, &mut params, &attn, "wv", &[d, d]).id;
        let bv = decl(&mut b, &mut params, &attn, "bv", &[d]).id;
        let wo = decl(&mut b, &mut params, &attn, "wo", &[d, d]).id;
        let bo = decl(&mut b, &mut params, &attn, "bo", &[d]).id;
        let ln2_g = decl(&mut b, &mut params, &ls, "ln2_g", &[d]).id;
        let ln2_b = decl(&mut b, &mut params, &ls, "ln2_b", &[d]).id;
        let w1 = decl(&mut b, &mut params, &mlp, "w1", &[d, ff]).id;
        let b1 = decl(&mut b, &mut params, &mlp, "b1", &[ff]).id;
        let w2 = decl(&mut b, &mut params, &mlp, "w2", &[ff, d]).id;
        let b2 = decl(&mut b, &mut params, &mlp, "b2", &[d]).id;
        layers.push(LayerParams { wq, wk, wv, wo, w1, w2 });
        layer_lns.push((ln1_g, ln1_b, ln2_g, ln2_b));
        layer_biases.push((bq, bk, bv, bo, b1, b2));
    }
    let lnf_g = decl(&mut b, &mut params, "", "lnf_g", &[d]).id;
    let lnf_b = decl(&mut b, &mut params, "", "lnf_b", &[d]).id;

    let mask = b.arg("causal_mask", TensorType::f32(&[s, s]), ArgKind::Constant);
    let tokens = b.arg("tokens", TensorType::new(DType::I32, &[bs, s]), ArgKind::Input);
    let targets = b.arg("targets", TensorType::new(DType::I32, &[bs, s]), ArgKind::Input);

    // Adam state (declared after params so ids don't interleave).
    let (mut m_state, mut v_state) = (Vec::new(), Vec::new());
    if cfg.training {
        for (i, &p) in params.clone().iter().enumerate() {
            let ty = b.ty(p).clone();
            let name = b.func.args[p.index()].name.clone();
            let scope_id = b.func.args[p.index()].scope;
            b.push_scope_id(scope_id);
            let m = b.arg(format!("{name}.adam_m"), ty.clone(), ArgKind::OptState);
            let vv = b.arg(format!("{name}.adam_v"), ty, ArgKind::OptState);
            b.pop_scope();
            m_state.push(m);
            v_state.push(vv);
            let _ = i;
        }
    }

    // ---- forward --------------------------------------------------------
    let x_tok = b.gather(embed, tokens); // [B,S,D]
    let xty = b.ty(x_tok).clone();
    let pos_b = b.broadcast(pos, vec![1, 2], xty.clone());
    let mut x = b.add(x_tok, pos_b); // residual stream [B,S,D]

    let dot_proj = DotDims {
        lhs_batch: vec![],
        rhs_batch: vec![],
        lhs_contract: vec![2],
        rhs_contract: vec![0],
    };

    for l in 0..cfg.layers {
        let lp = &layers[l];
        let (ln1_g, ln1_b, ln2_g, ln2_b) = layer_lns[l];
        let (bq, bk, bv, bo, b1, b2) = layer_biases[l];
        b.push_scope(&format!("layer_{l}"));

        // -- attention block
        b.push_scope("attn");
        let xn = b.layer_norm(x, ln1_g, ln1_b);
        let proj = |b: &mut GraphBuilder, w: ValueId, bias: ValueId, xn: ValueId| {
            let p = b.dot(dot_proj.clone(), xn, w); // [B,S,D]
            let pty = b.ty(p).clone();
            let bb = b.broadcast_to(bias, pty);
            b.add(p, bb)
        };
        let q = proj(&mut b, lp.wq, bq, xn);
        let k = proj(&mut b, lp.wk, bk, xn);
        let vv = proj(&mut b, lp.wv, bv, xn);
        let split = |b: &mut GraphBuilder, t: ValueId| {
            let r = b.reshape(t, &[bs, s, h, dh]);
            b.transpose(r, vec![0, 2, 1, 3]) // [B,H,S,Dh]
        };
        let q4 = split(&mut b, q);
        let k4 = split(&mut b, k);
        let v4 = split(&mut b, vv);
        let scores_d = DotDims {
            lhs_batch: vec![0, 1],
            rhs_batch: vec![0, 1],
            lhs_contract: vec![3],
            rhs_contract: vec![3],
        };
        let scores = b.dot(scores_d, q4, k4); // [B,H,S,S]
        let scaled = b.scale(scores, 1.0 / (dh as f64).sqrt());
        let sty = b.ty(scaled).clone();
        let mask_b = b.broadcast(mask, vec![2, 3], sty);
        let masked = b.add(scaled, mask_b);
        let probs = b.softmax_last(masked);
        let attn_d = DotDims {
            lhs_batch: vec![0, 1],
            rhs_batch: vec![0, 1],
            lhs_contract: vec![3],
            rhs_contract: vec![2],
        };
        let ctx = b.dot(attn_d, probs, v4); // [B,H,S,Dh]
        let ctx_t = b.transpose(ctx, vec![0, 2, 1, 3]); // [B,S,H,Dh]
        let ctx_m = b.reshape(ctx_t, &[bs, s, d]); // [B,S,D]
        let attn_out = proj(&mut b, lp.wo, bo, ctx_m);
        b.pop_scope();
        x = b.add(x, attn_out);

        // -- MLP block
        b.push_scope("mlp");
        let xn2 = b.layer_norm(x, ln2_g, ln2_b);
        let h1 = b.dot(dot_proj.clone(), xn2, lp.w1); // [B,S,F]
        let h1ty = b.ty(h1).clone();
        let b1b = b.broadcast_to(b1, h1ty);
        let h1b = b.add(h1, b1b);
        let act = b.gelu(h1b);
        let h2 = b.dot(dot_proj.clone(), act, lp.w2); // [B,S,D]
        let h2ty = b.ty(h2).clone();
        let b2b = b.broadcast_to(b2, h2ty);
        let mlp_out = b.add(h2, b2b);
        b.pop_scope();
        x = b.add(x, mlp_out);
        b.pop_scope();
    }

    // ---- loss (tied-embedding LM head + softmax cross-entropy) ----------
    let xf = b.layer_norm(x, lnf_g, lnf_b);
    let logits_d = DotDims {
        lhs_batch: vec![],
        rhs_batch: vec![],
        lhs_contract: vec![2],
        rhs_contract: vec![1],
    };
    let logits = b.dot(logits_d, xf, embed); // [B,S,V]
    let mx = b.reduce_max(logits, vec![2]);
    let lty = b.ty(logits).clone();
    let mxb = b.broadcast(mx, vec![0, 1], lty.clone());
    let centered = b.sub(logits, mxb);
    let e = b.exp(centered);
    let sum_e = b.reduce_sum(e, vec![2]);
    let lse = b.log(sum_e);
    let lseb = b.broadcast(lse, vec![0, 1], lty.clone());
    let logp = b.sub(centered, lseb);
    // one-hot(targets) via iota == broadcast(targets)
    let iota_v = b.iota(2, lty.clone());
    let tgt_f = b.convert(targets, DType::F32);
    let tgt_b = b.broadcast(tgt_f, vec![0, 1], lty.clone());
    let eq = b.compare(CmpDir::Eq, iota_v, tgt_b);
    let ones = b.constant(1.0, lty.clone());
    let zeros = b.constant(0.0, lty);
    let onehot = b.select(eq, ones, zeros);
    let picked = b.mul(logp, onehot);
    let total = b.reduce_sum(picked, vec![0, 1, 2]);
    let nll = b.neg(total);
    let loss = b.scale(nll, 1.0 / (bs * s) as f64);

    // ---- backward + Adam -------------------------------------------------
    if cfg.training {
        let grads = gradients(&mut b, loss, &params);
        let (b1c, b2c, lr, eps) = (0.9, 0.999, 1e-4, 1e-8);
        for (i, &p) in params.iter().enumerate() {
            let g = match grads[i] {
                Some(g) => g,
                None => continue,
            };
            let scope_id = b.func.args[p.index()].scope;
            b.push_scope_id(scope_id);
            let m_old = m_state[i];
            let v_old = v_state[i];
            let m_scaled = b.scale(m_old, b1c);
            let g_scaled = b.scale(g, 1.0 - b1c);
            let m_new = b.add(m_scaled, g_scaled);
            let v_scaled = b.scale(v_old, b2c);
            let g2 = b.mul(g, g);
            let g2_scaled = b.scale(g2, 1.0 - b2c);
            let v_new = b.add(v_scaled, g2_scaled);
            let v_sqrt = b.sqrt(v_new);
            let v_eps = b.shift(v_sqrt, eps);
            let upd = b.div(m_new, v_eps);
            let upd_lr = b.scale(upd, lr);
            let p_new = b.sub(p, upd_lr);
            b.pop_scope();
            b.output(p_new);
            b.output(m_new);
            b.output(v_new);
        }
    }
    b.output(loss);

    TransformerModel { func: b.finish(), config: cfg.clone(), layers, params, loss }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::verify::verify;

    #[test]
    fn tiny_transformer_builds_and_verifies() {
        let m = build_transformer(&TransformerConfig::tiny(2));
        verify(&m.func).unwrap();
        assert_eq!(m.layers.len(), 2);
        // args: 2 + 16*2 + 2 params = 36, x3 (adam) + mask + tokens + targets
        assert_eq!(m.func.num_args(), 36 * 3 + 3);
        // outputs: 3 per param + loss
        assert_eq!(m.func.outputs.len(), 36 * 3 + 1);
    }

    #[test]
    fn inference_only_has_no_opt_state() {
        let mut cfg = TransformerConfig::tiny(1);
        cfg.training = false;
        let m = build_transformer(&cfg);
        verify(&m.func).unwrap();
        assert_eq!(m.func.count_args(crate::ir::ArgKind::OptState), 0);
        assert_eq!(m.func.outputs.len(), 1);
    }

    #[test]
    fn paper_scale_arg_count_and_memory() {
        // Build the paper config STRUCTURALLY (no tensor data involved).
        let cfg = TransformerConfig::paper();
        let m = build_transformer(&cfg);
        let n_args = m.func.num_args();
        // paper: 1150 arguments
        assert!(
            (1100..=1300).contains(&n_args),
            "expected ~1150 args like the paper, got {n_args}"
        );
        // paper: ~26 GB at batch size 1 (params+grads+adam+activations)
        let param_bytes = cfg.param_count() * 4;
        assert!(param_bytes > 4 * (1 << 30));
        // ~1.3B params like GPT-3 XL
        assert!((1_200_000_000..1_500_000_000).contains(&cfg.param_count()));
    }

    #[test]
    fn scopes_cover_layers() {
        let m = build_transformer(&TransformerConfig::tiny(3));
        let f = &m.func;
        let mut saw_attn = false;
        for n in &f.nodes {
            if f.scope_path(n.scope).contains("layer_2/attn") {
                saw_attn = true;
            }
        }
        assert!(saw_attn);
    }

    #[test]
    fn loss_decreases_under_sgd_step_numerically() {
        // End-to-end numeric sanity on the tiniest config: evaluate the
        // update function, apply the new params, and check loss drops.
        use crate::ir::interp::{eval_all, Tensor};
        use crate::util::rng::Rng;
        let mut cfg = TransformerConfig::tiny(1);
        cfg.d_model = 16;
        cfg.n_heads = 2;
        cfg.d_ff = 32;
        cfg.vocab = 32;
        cfg.seq = 8;
        cfg.batch = 1;
        let m = build_transformer(&cfg);
        let mut rng = Rng::new(7);
        let mut args: Vec<Tensor> = m
            .func
            .args
            .iter()
            .map(|a| {
                let n = a.ty.num_elements() as usize;
                match a.name.as_str() {
                    "causal_mask" => {
                        let s = cfg.seq as usize;
                        let mut d = vec![0.0; s * s];
                        for i in 0..s {
                            for j in (i + 1)..s {
                                d[i * s + j] = -1e9;
                            }
                        }
                        Tensor::new(&a.ty.dims, d)
                    }
                    "tokens" | "targets" => Tensor::new(
                        &a.ty.dims,
                        (0..n).map(|_| rng.gen_range(cfg.vocab as usize) as f64).collect(),
                    ),
                    _ if a.name.ends_with(".adam_m") || a.name.ends_with(".adam_v") => {
                        Tensor::new(&a.ty.dims, vec![0.0; n])
                    }
                    _ => Tensor::new(
                        &a.ty.dims,
                        (0..n).map(|_| (rng.gen_f64() * 2.0 - 1.0) * 0.05).collect(),
                    ),
                }
            })
            .collect();
        let vals = eval_all(&m.func, &args);
        let loss0 = vals[m.loss.index()].data[0];
        assert!(loss0.is_finite() && loss0 > 0.0, "loss0={loss0}");
        // outputs: (p', m', v') per param then loss — write them back.
        for (i, &p) in m.params.iter().enumerate() {
            let p_new = m.func.outputs[3 * i];
            args[p.index()] = vals[p_new.index()].clone();
        }
        let vals2 = eval_all(&m.func, &args);
        let loss1 = vals2[m.loss.index()].data[0];
        assert!(
            loss1 < loss0,
            "one Adam step should reduce loss: {loss0} -> {loss1}"
        );
    }
}
